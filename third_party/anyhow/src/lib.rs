//! Offline, API-compatible subset of the `anyhow` crate.
//!
//! The build environment resolves dependencies without network access, so
//! the subset of `anyhow` this repository actually uses is vendored here:
//!
//! * [`Error`] — an erased error value carrying a message chain.
//! * [`Result<T>`] — alias with `Error` as the default error type.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` (for
//!   both `std` errors and [`Error`] itself) and on `Option`.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — macro constructors.
//!
//! Semantics match upstream for everything exercised in-tree: `{}` prints
//! the outermost message, `{:#}` prints the full cause chain separated by
//! `": "`, `{:?}` prints the chain as a "Caused by" list, and any
//! `std::error::Error + Send + Sync + 'static` converts via `?`.

use std::fmt;

/// An erased error: an outermost message plus its cause chain.
///
/// Unlike upstream this stores the chain as rendered strings — the repo
/// only ever formats errors, never downcasts them.
pub struct Error {
    /// `chain[0]` is the outermost (most recent) message.
    chain: Vec<String>,
}

/// `Result<T, Error>` by default; the second parameter keeps call sites
/// like `Result<Vec<f32>, String>` valid.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct an error from any displayable message.
    pub fn msg<M>(message: M) -> Self
    where
        M: fmt::Display + Send + Sync + 'static,
    {
        Error { chain: vec![message.to_string()] }
    }

    fn push_context(mut self, context: String) -> Self {
        self.chain.insert(0, context);
        self
    }

    /// The cause chain, outermost first (rendered messages).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if f.alternate() {
            for cause in &self.chain[1..] {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }
}

mod ext {
    /// Converts an error value into [`crate::Error`].  Implemented for
    /// `std` errors and for `Error` itself; the two impls are disjoint
    /// because `Error` deliberately does not implement
    /// `std::error::Error` (same coherence trick as upstream anyhow).
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// Attach context to errors (mirrors `anyhow::Context`).
pub trait Context<T, E> {
    /// Wrap the error with a new outermost message.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Like [`Context::context`], evaluated lazily on the error path.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::IntoError,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| {
            ext::IntoError::into_error(e).push_context(context.to_string())
        })
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| ext::IntoError::into_error(e).push_context(f().to_string()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tok:tt)*) => {
        return Err($crate::anyhow!($($tok)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($tok:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($tok)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Result::<(), _>::Err(io_err())
            .context("reading checkpoint")
            .unwrap_err();
        assert_eq!(e.to_string(), "reading checkpoint");
        assert_eq!(format!("{e:#}"), "reading checkpoint: disk on fire");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "disk on fire");
    }

    #[test]
    fn macros_build_errors() {
        fn fails(n: usize) -> Result<usize> {
            ensure!(n < 10, "n too large: {n}");
            if n == 5 {
                bail!("five is right out");
            }
            Err(anyhow!("fell through with {}", n))
        }
        assert_eq!(fails(12).unwrap_err().to_string(), "n too large: 12");
        assert_eq!(fails(5).unwrap_err().to_string(), "five is right out");
        assert_eq!(fails(1).unwrap_err().to_string(), "fell through with 1");
        let from_string = Error::msg(String::from("owned"));
        assert_eq!(from_string.to_string(), "owned");
    }

    #[test]
    fn context_on_anyhow_error_and_option() {
        let e = Result::<(), _>::Err(anyhow!("inner"))
            .with_context(|| "outer")
            .unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
        let missing: Option<u32> = None;
        let e = missing.context("nothing there").unwrap_err();
        assert_eq!(e.to_string(), "nothing there");
    }
}
