//! Offline stub of the `xla` crate (xla-rs PJRT bindings).
//!
//! The real crate links the native XLA/PJRT C++ runtime, which is not
//! available in the offline build environment.  This stub exposes the
//! exact API surface `tvq::runtime` compiles against so the rest of the
//! system builds and tests offline; every entry point that would need the
//! native runtime returns [`Error::PjrtUnavailable`].  The failure
//! surfaces at [`PjRtClient::cpu`], so callers gate cleanly ("PJRT
//! unavailable") instead of crashing mid-execution.
//!
//! To run the real AOT artifacts, replace the `xla` path dependency in
//! the workspace `Cargo.toml` with the actual xla-rs crate — the API
//! subset here is call-compatible.

use std::fmt;

/// Stub error: the native PJRT runtime is absent.
#[derive(Debug, Clone)]
pub enum Error {
    PjrtUnavailable,
    Other(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::PjrtUnavailable => f.write_str(
                "PJRT unavailable: offline xla stub (vendor the real xla-rs \
                 crate and run `make artifacts` to enable the runtime)",
            ),
            Error::Other(msg) => f.write_str(msg),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can hold (subset used in-tree).
pub trait ElementType: Copy {}
impl ElementType for f32 {}
impl ElementType for f64 {}
impl ElementType for i32 {}
impl ElementType for i64 {}
impl ElementType for u8 {}

/// Host-side literal value.  The stub stores nothing: literals are only
/// ever constructed on the way into an executable, and no executable can
/// exist without a client.
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: ElementType>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _private: () })
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::PjrtUnavailable)
    }

    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>> {
        Err(Error::PjrtUnavailable)
    }
}

/// Device buffer handle returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::PjrtUnavailable)
    }
}

/// Compiled executable handle.  Unconstructible through the stub (the
/// only constructor, [`PjRtClient::compile`], always fails).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::BorrowMut<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::PjrtUnavailable)
    }
}

/// PJRT client handle.  [`PjRtClient::cpu`] is where the stub fails, so
/// every dependent path degrades with one clear message.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::PjrtUnavailable)
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::PjrtUnavailable)
    }
}

/// Parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::PjrtUnavailable)
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_fails_with_clear_message() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT unavailable"));
    }

    #[test]
    fn literal_construction_is_cheap_and_safe() {
        let l = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2, 1]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
        assert!(l.to_tuple().is_err());
    }
}
