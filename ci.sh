#!/usr/bin/env bash
# Tier-1 CI gate for the tvq crate.  Run from anywhere; fails fast.
#
#   ./ci.sh          # build + tests + fmt + clippy
#   ./ci.sh --quick  # build + tests only
#
# The workspace vendors its only dependency (third_party/anyhow), so every
# step below works fully offline (--offline keeps cargo from trying the
# network on machines without a registry mirror).

set -euo pipefail
cd "$(dirname "$0")"

CARGO_FLAGS=(--offline)

echo "==> cargo build --release"
cargo build --release "${CARGO_FLAGS[@]}"

echo "==> cargo test -q"
cargo test -q "${CARGO_FLAGS[@]}"

if [[ "${1:-}" == "--quick" ]]; then
    echo "ci: quick gate passed"
    exit 0
fi

echo "==> example packed_registry"
cargo run --release "${CARGO_FLAGS[@]}" --example packed_registry > /dev/null

echo "==> planner experiment tabP (smoke)"
TVQ_SMOKE=1 cargo run --release "${CARGO_FLAGS[@]}" --bin tvq -- experiment tabP > /dev/null

echo "==> cargo doc --no-deps (warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps "${CARGO_FLAGS[@]}" > /dev/null

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy -- -D warnings"
# --all-targets covers the planner/ module (lib + its tests), the new
# planner_integration test, and the tabP bench; warnings fail the gate.
cargo clippy --all-targets "${CARGO_FLAGS[@]}" -- -D warnings

echo "ci: all gates passed"
