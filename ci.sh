#!/usr/bin/env bash
# Tier-1 CI gate for the tvq crate — staged, timed, selectable.
#
#   ./ci.sh                    # full gate: every stage below, in order
#   ./ci.sh --quick            # quick gate: build + test + control only
#   ./ci.sh --stage clippy     # run a single named stage
#   ./ci.sh --list             # list stage names and what they run
#
# Stages (in order):
#   preflight   toolchain sanity (cargo/rustc present) — pointed error if not
#   build       cargo build --release
#   test        cargo test -q
#   control     control-plane suite (hot-swap/drain) at smoke scale
#               (TVQ_SMOKE=1 cargo test --test control_plane)
#   obs         observability suite: lock-free histograms, watch
#               streaming, trace export
#               (TVQ_SMOKE=1 cargo test --test obs_integration)
#   dynmerge    dynamic-merging suite: routed delta patches bit-identical
#               to full re-merges, router determinism
#               (TVQ_SMOKE=1 cargo test --test dynamic_merge)
#   shard       sharded-registry suite: MANIFEST.qtvm round-trip, tier-0
#               vs tier-1 bit-exactness, fail-closed corruption quartet
#               (TVQ_SMOKE=1 cargo test --test sharded_registry)
#   simd        SIMD kernel parity: scalar vs every detected vector
#               kernel bit-identical on all four dispatched primitives,
#               run under both auto detection and TVQ_SIMD=off, plus
#               pool_determinism with SIMD active; chains the Python
#               cross-runtime byte-parity test when python3+jax exist
#               (cargo test --test simd_parity / --test pool_determinism)
#   example     packed_registry example end-to-end
#   tabP        planner + dynamic-merge experiment smoke (TVQ_SMOKE=1,
#               runs `experiment tabP` then `experiment tabR`)
#   bench-diff  perf_registry bench -> BENCH_registry.json -> tvq bench diff
#               against rust/benches/baselines/BENCH_registry.json (±20%;
#               uncalibrated baselines record instead of gating, but the
#               within-run ordering invariants — mmap vs pread, threaded
#               vs sequential, delta patch vs full re-merge, cached
#               remote section fetch vs 2x local — always apply)
#   doc         cargo doc --no-deps with warnings denied
#   fmt         cargo fmt --check
#   clippy      cargo clippy --all-targets with warnings denied
#
# Every stage is timed; a summary table prints at the end (or on failure,
# with the failing stage marked).  The workspace vendors its dependencies
# (third_party/), so every step runs fully offline (--offline keeps cargo
# off the network on machines without a registry mirror).

set -euo pipefail
cd "$(dirname "$0")"

CARGO_FLAGS=(--offline)
BENCH_TOLERANCE="${TVQ_BENCH_TOLERANCE:-0.20}"

STAGE_NAMES=(preflight build test control obs dynmerge shard simd example tabP bench-diff doc fmt clippy)
QUICK_STAGES=(preflight build test control obs dynmerge shard simd)

declare -a RAN_STAGES=()
declare -a RAN_TIMES=()
declare -a RAN_STATUS=()

stage_preflight() {
    # A bare `cargo: command not found` mid-gate helps nobody; fail here,
    # once, with the fix spelled out.
    local missing=()
    command -v cargo >/dev/null 2>&1 || missing+=(cargo)
    command -v rustc >/dev/null 2>&1 || missing+=(rustc)
    if ((${#missing[@]})); then
        echo "ci: preflight FAILED — Rust toolchain missing: ${missing[*]}" >&2
        echo "    This gate needs cargo + rustc on PATH (any recent stable)." >&2
        echo "    Install via rustup:  curl https://sh.rustup.rs -sSf | sh" >&2
        echo "    or point PATH at an existing toolchain, then re-run ./ci.sh" >&2
        return 2
    fi
    echo "toolchain: $(rustc --version) / $(cargo --version)"
}

stage_build() {
    cargo build --release "${CARGO_FLAGS[@]}"
}

stage_test() {
    cargo test -q "${CARGO_FLAGS[@]}"
}

stage_control() {
    # The full `test` stage already runs this suite at full scale; this
    # named stage re-runs it at smoke scale so `--stage control` gives a
    # fast, isolated signal on the hot-swap/drain machinery.
    TVQ_SMOKE=1 cargo test -q "${CARGO_FLAGS[@]}" --test control_plane
}

stage_obs() {
    # Same pattern as `control`: the full `test` stage runs this suite
    # too; the named stage gives an isolated signal on the histogram /
    # watch-stream / trace-export acceptance criteria.
    TVQ_SMOKE=1 cargo test -q "${CARGO_FLAGS[@]}" --test obs_integration
}

stage_dynmerge() {
    # Same pattern as `control` / `obs`: the full `test` stage runs this
    # suite too; the named stage gives an isolated signal on the routed
    # delta-patch bit-exactness contract.
    TVQ_SMOKE=1 cargo test -q "${CARGO_FLAGS[@]}" --test dynamic_merge
}

stage_shard() {
    # Sharded registries (ISSUE 9): manifest round-trip + dedup, tier-0
    # vs tier-1 bit-exactness across thread counts, the fail-closed
    # corruption quartet erroring identically across tiers, and the
    # generational manifest swap.
    TVQ_SMOKE=1 cargo test -q "${CARGO_FLAGS[@]}" --test sharded_registry
}

stage_simd() {
    # SIMD dequant-axpy parity (ISSUE 10): every detected kernel must be
    # bit-identical to the scalar reference, both under auto detection
    # and with vector kernels forced off (TVQ_SIMD=off exercises the
    # env-override path and the scalar dispatch), and pool_determinism
    # must stay green with SIMD active — the "any thread count × any
    # kernel" contract.  The simd_parity run also exports the
    # cross-runtime fixture (target/parity/) consumed by the Python
    # byte-parity test, which chains here when python3 + jax exist.
    # && chain for the run_stage errexit-suppression reason above.
    TVQ_SMOKE=1 cargo test -q "${CARGO_FLAGS[@]}" --test simd_parity \
        && TVQ_SMOKE=1 TVQ_SIMD=off cargo test -q "${CARGO_FLAGS[@]}" --test simd_parity \
        && TVQ_SMOKE=1 cargo test -q "${CARGO_FLAGS[@]}" --test pool_determinism \
        && if command -v python3 > /dev/null 2>&1 \
                && python3 -c 'import pytest, jax' > /dev/null 2>&1; then
            (cd python && python3 -m pytest -q tests/test_packed_merge_parity.py)
        else
            echo "simd: python3+jax unavailable — skipping cross-runtime byte parity"
        fi
}

stage_example() {
    cargo run --release "${CARGO_FLAGS[@]}" --example packed_registry > /dev/null
}

stage_tabP() {
    # && chain for the same errexit-suppression reason as bench-diff.
    TVQ_SMOKE=1 cargo run --release "${CARGO_FLAGS[@]}" --bin tvq -- experiment tabP > /dev/null \
        && TVQ_SMOKE=1 cargo run --release "${CARGO_FLAGS[@]}" --bin tvq -- experiment tabR > /dev/null
}

stage_bench-diff() {
    # && chain, not separate lines: run_stage calls stages inside an `if`,
    # where bash suppresses errexit — without the chain a failed bench
    # would still run the diff.
    mkdir -p target \
        && TVQ_BENCH_OUT=target/BENCH_registry.json \
            cargo bench "${CARGO_FLAGS[@]}" --bench perf_registry \
        && cargo run --release "${CARGO_FLAGS[@]}" --bin tvq -- bench diff \
            --current target/BENCH_registry.json \
            --baseline rust/benches/baselines/BENCH_registry.json \
            --tolerance "${BENCH_TOLERANCE}"
}

stage_doc() {
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps "${CARGO_FLAGS[@]}" > /dev/null
}

stage_fmt() {
    cargo fmt --check
}

stage_clippy() {
    # --all-targets covers the lib, tests, examples and benches; warnings
    # fail the gate.
    cargo clippy --all-targets "${CARGO_FLAGS[@]}" -- -D warnings
}

print_summary() {
    local total=0
    echo
    echo "ci summary:"
    printf '  %-12s %8s  %s\n' "stage" "time" "status"
    local i
    for i in "${!RAN_STAGES[@]}"; do
        printf '  %-12s %7ss  %s\n' "${RAN_STAGES[$i]}" "${RAN_TIMES[$i]}" "${RAN_STATUS[$i]}"
        total=$((total + ${RAN_TIMES[$i]}))
    done
    printf '  %-12s %7ss\n' "total" "${total}"
}

run_stage() {
    local name="$1"
    echo "==> stage ${name}"
    local t0=${SECONDS}
    if "stage_${name}"; then
        RAN_STAGES+=("${name}"); RAN_TIMES+=($((SECONDS - t0))); RAN_STATUS+=("ok")
    else
        local rc=$?
        RAN_STAGES+=("${name}"); RAN_TIMES+=($((SECONDS - t0))); RAN_STATUS+=("FAILED")
        print_summary
        echo "ci: stage ${name} failed (exit ${rc})" >&2
        exit "${rc}"
    fi
}

list_stages() {
    # The stage table at the top of this file is the documentation; print
    # the names machine-readably for --stage completion.
    printf '%s\n' "${STAGE_NAMES[@]}"
}

main() {
    local selection=("${STAGE_NAMES[@]}")
    case "${1:-}" in
        "") ;;
        --quick)
            selection=("${QUICK_STAGES[@]}")
            ;;
        --list)
            list_stages
            exit 0
            ;;
        --stage)
            local want="${2:-}"
            if [[ -z "${want}" ]]; then
                echo "ci: --stage needs a name; one of: ${STAGE_NAMES[*]}" >&2
                exit 2
            fi
            local found=""
            for s in "${STAGE_NAMES[@]}"; do
                [[ "$s" == "${want}" ]] && found=1
            done
            if [[ -z "${found}" ]]; then
                echo "ci: unknown stage '${want}'; one of: ${STAGE_NAMES[*]}" >&2
                exit 2
            fi
            # Preflight always runs first: a missing toolchain should
            # never surface as a cryptic cargo error inside a stage.
            if [[ "${want}" != preflight ]]; then
                selection=(preflight "${want}")
            else
                selection=(preflight)
            fi
            ;;
        --help|-h)
            # Print the header comment block (everything up to the first
            # non-comment line), stripped of its leading '# '.
            awk 'NR > 1 { if (!/^#/) exit; sub(/^# ?/, ""); print }' "$0"
            exit 0
            ;;
        *)
            echo "ci: unknown option '$1' (try --help)" >&2
            exit 2
            ;;
    esac

    for s in "${selection[@]}"; do
        run_stage "$s"
    done
    print_summary
    case "${1:-}" in
        --quick) echo "ci: quick gate passed" ;;
        --stage) echo "ci: stage ${2} passed (partial run — not the full gate)" ;;
        *)       echo "ci: all gates passed" ;;
    esac
}

main "$@"
