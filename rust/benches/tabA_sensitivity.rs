//! Regenerates paper artifact `tabA` (see DESIGN.md §5 experiment index).
//!
//! Run: `cargo bench --bench tabA_sensitivity` — equivalent to
//! `tvq experiment tabA`; results land in `target/results/tabA.md`.

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    tvq::exp::run_experiment("tabA")?;
    eprintln!("[bench:tabA] regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
