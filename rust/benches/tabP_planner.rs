//! Regenerates artifact `tabP`: planned mixed precision vs uniform
//! schemes at the same measured byte budget (pack-planner companion to
//! Table 5).
//!
//! Run: `cargo bench --bench tabP_planner` — equivalent to
//! `tvq experiment tabP`; results land in `target/results/tabP.md`.

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    tvq::exp::run_experiment("tabP")?;
    eprintln!("[bench:tabP] regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
