//! Performance bench for the packed task-vector registry: open (index
//! only), lazy single-task load under both section-read modes (pread vs
//! reopen-per-read), full merge materialization from packed payloads,
//! the same merge from f32 `TVQC` checkpoints, and the planner's fused
//! dequant-merge over a mixed-precision registry — the cold-start cost a
//! serving node actually pays.
//!
//! Run: `cargo bench --bench perf_registry`

use tvq::checkpoint::{Checkpoint, CheckpointStore};
use tvq::merge::TaskArithmetic;
use tvq::planner::{build_planned_registry, fused_merge, PlannerConfig};
use tvq::quant::QuantScheme;
use tvq::registry::{
    build_registry, merge_from_source, uniform_registry_bytes, F32ZooSource, IoMode,
    PackedRegistrySource, Registry,
};
use tvq::tensor::Tensor;
use tvq::util::bench::{report, Bench};
use tvq::util::rng::Rng;

const N_TASKS: usize = 8;

fn zoo(seed: u64) -> (Checkpoint, Vec<Checkpoint>) {
    let mut rng = Rng::new(seed);
    let mut pre = Checkpoint::new();
    // ~0.6M params/ckpt: big enough that load/dequant dominates.
    for blk in 0..4 {
        pre.insert(&format!("blk{blk:02}/w"), Tensor::randn(&[384, 384], 0.3, &mut rng));
    }
    pre.insert("head/b", Tensor::randn(&[384], 0.1, &mut rng));
    let fts = (0..N_TASKS)
        .map(|_| {
            let mut tau = Checkpoint::new();
            for (name, t) in pre.iter() {
                tau.insert(name, Tensor::randn(t.shape(), 0.01, &mut rng));
            }
            pre.add(&tau).unwrap()
        })
        .collect();
    (pre, fts)
}

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let (pre, fts) = zoo(0xBE9C);
    let params = pre.numel();
    let dir = std::env::temp_dir().join("tvq_perf_registry");
    std::fs::remove_dir_all(&dir).ok();

    // Materialize both durable forms.
    let store = CheckpointStore::new(dir.join("f32"));
    for (t, ft) in fts.iter().enumerate() {
        store.save(&format!("task{t:02}"), ft)?;
    }
    let path = dir.join("zoo.qtvc");
    let summary = build_registry(&pre, &fts, QuantScheme::Tvq(4), &path)?;
    eprintln!(
        "[bench:registry] {} tasks x {params} params; registry {} B on disk",
        N_TASKS, summary.file_bytes
    );

    let b = Bench::quick();
    let mut results = Vec::new();

    // Open = header + offset table only; independent of payload size.
    results.push(b.run("registry_open_index", || {
        std::hint::black_box(Registry::open(&path).unwrap());
    }));

    // One lazy task: one section read + dequantize, under both IO
    // modes — pread keeps a single handle (no open/seek per section),
    // reopen is the conservative fallback path.
    let reg = Registry::open_with_io(&path, IoMode::Pread)?;
    results.push(b.run_throughput("registry_lazy_task_pread", params as f64, || {
        std::hint::black_box(reg.load_task_vector(3).unwrap());
    }));
    let reg_reopen = Registry::open_with_io(&path, IoMode::Reopen)?;
    results.push(b.run_throughput("registry_lazy_task_reopen", params as f64, || {
        std::hint::black_box(reg_reopen.load_task_vector(3).unwrap());
    }));

    // Cold merge straight from packed payloads (all 8 tasks).
    let ta = TaskArithmetic::default();
    results.push(b.run_throughput(
        "merge8_from_packed_registry",
        (params * N_TASKS) as f64,
        || {
            let src = PackedRegistrySource::open(&path).unwrap();
            std::hint::black_box(merge_from_source(&ta, &pre, &src, None).unwrap());
        },
    ));

    // Same merge from f32 checkpoints loaded off disk (the old path).
    results.push(b.run_throughput(
        "merge8_from_f32_checkpoints",
        (params * N_TASKS) as f64,
        || {
            let fts: Vec<Checkpoint> = (0..N_TASKS)
                .map(|t| store.load(&format!("task{t:02}")).unwrap())
                .collect();
            let src = F32ZooSource::new(&pre, &fts);
            std::hint::black_box(merge_from_source(&ta, &pre, &src, None).unwrap());
        },
    ));

    // Subset materialization: 2 of 8 tasks, the lazy win.
    results.push(b.run_throughput(
        "merge2of8_from_packed_registry",
        (params * 2) as f64,
        || {
            let src = PackedRegistrySource::open(&path).unwrap();
            std::hint::black_box(
                merge_from_source(&ta, &pre, &src, Some(&[2, 5])).unwrap(),
            );
        },
    ));

    // Planner path: compile a mixed-precision registry at the uniform
    // TVQ-INT4 byte budget, then serve it through the fused
    // dequant-merge over kind-2 group sections.
    let budget = uniform_registry_bytes(&pre, &fts, QuantScheme::Tvq(4))?;
    let planned_path = dir.join("planned.qtvc");
    let cfg = PlannerConfig {
        // A slimmer, dense-only candidate set keeps the probe a one-off
        // cost and pins this bench to the kind-2 group-section fused
        // path (sparse kind-4 serving is not what's measured here).
        tvq_bits: vec![2, 3, 4, 6],
        rtvq_arms: vec![(3, 2), (4, 2)],
        dare_arms: vec![],
        tall_arms: vec![],
        ..PlannerConfig::default()
    };
    let t_plan = std::time::Instant::now();
    let (plan, summary) = build_planned_registry(&pre, &fts, budget, &cfg, &planned_path)?;
    eprintln!(
        "[bench:registry] planned registry: {} B of {} B budget in {:.1}s",
        summary.file_bytes,
        budget,
        t_plan.elapsed().as_secs_f64()
    );
    let planned = Registry::open(&planned_path)?;
    let lams = vec![0.3f32; plan.n_tasks()];
    results.push(b.run_throughput(
        "merge8_fused_from_planned_registry",
        (params * N_TASKS) as f64,
        || {
            std::hint::black_box(fused_merge(&planned, &pre, &lams, None).unwrap());
        },
    ));

    report("registry load/merge", &results);
    std::fs::remove_dir_all(&dir).ok();
    eprintln!("[bench:registry] done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
