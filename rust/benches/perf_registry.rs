//! Performance bench for the packed task-vector registry: open (index
//! only), raw CRC-checked section reads and lazy single-task loads under
//! all three section-read modes (mmap vs pread vs reopen-per-read), full
//! merge materialization from packed payloads, the same merge from f32
//! `TVQC` checkpoints, and the planner's fused dequant-merge over a
//! mixed-precision registry — the cold-start cost a serving node actually
//! pays.
//!
//! Thread scaling is benched explicitly: fused merge and registry build
//! pinned to 1 / 2 / N pool threads (`merge8_fused_threads_*`,
//! `registry_build_threads_*`), with "tN" meaning all cores on the
//! running machine.
//!
//! Besides the human-readable table, the run writes a machine-readable
//! `BENCH_registry.json` (path overridable via `TVQ_BENCH_OUT`) that
//! `tvq bench diff` gates in CI: within-run ordering invariants (mmap
//! section reads must not be slower than pread, N-thread fused merge
//! must not be slower than sequential, the SIMD-kernel fused merge must
//! not be slower than the scalar one at t1, and a one-task routed delta
//! patch must not be slower than the full re-merge it replaces) always
//! apply, per-case regression vs the committed baseline applies once
//! the baseline is calibrated.  See `rust/src/util/benchcmp.rs`.
//!
//! Run: `cargo bench --bench perf_registry`

use std::sync::Arc;

use tvq::checkpoint::{Checkpoint, CheckpointStore};
use tvq::coordinator::router::{merge_spec, MergeSpec};
use tvq::coordinator::{SectionFetchPool, TcpFront};
use tvq::merge::{MergedModel, TaskArithmetic};
use tvq::planner::{build_planned_registry, fused_merge, PlannerConfig};
use tvq::quant::{simd, Kernel, QuantScheme};
use tvq::registry::{
    build_registry, build_registry_with_pool, merge_from_source, shard_registry,
    uniform_registry_bytes, F32ZooSource, IoMode, OpenOptions, PackedRegistrySource, Registry,
    SectionScratch, ShardOptions, ShardedRegistry,
};
use tvq::tensor::Tensor;
use tvq::util::bench::{json_report, report, Bench};
use tvq::util::exec::ExecCtx;
use tvq::util::pool::Pool;
use tvq::util::rng::Rng;

const N_TASKS: usize = 8;

fn zoo(seed: u64) -> (Checkpoint, Vec<Checkpoint>) {
    let mut rng = Rng::new(seed);
    let mut pre = Checkpoint::new();
    // ~0.6M params/ckpt: big enough that load/dequant dominates.
    for blk in 0..4 {
        pre.insert(&format!("blk{blk:02}/w"), Tensor::randn(&[384, 384], 0.3, &mut rng));
    }
    pre.insert("head/b", Tensor::randn(&[384], 0.1, &mut rng));
    let fts = (0..N_TASKS)
        .map(|_| {
            let mut tau = Checkpoint::new();
            for (name, t) in pre.iter() {
                tau.insert(name, Tensor::randn(t.shape(), 0.01, &mut rng));
            }
            pre.add(&tau).unwrap()
        })
        .collect();
    (pre, fts)
}

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let (pre, fts) = zoo(0xBE9C);
    let params = pre.numel();
    let dir = std::env::temp_dir().join("tvq_perf_registry");
    std::fs::remove_dir_all(&dir).ok();

    // Materialize both durable forms.
    let store = CheckpointStore::new(dir.join("f32"));
    for (t, ft) in fts.iter().enumerate() {
        store.save(&format!("task{t:02}"), ft)?;
    }
    let path = dir.join("zoo.qtvc");
    let summary = build_registry(&pre, &fts, QuantScheme::Tvq(4), &path)?;
    eprintln!(
        "[bench:registry] {} tasks x {params} params; registry {} B on disk",
        N_TASKS, summary.file_bytes
    );

    let b = Bench::quick();
    let mut results = Vec::new();

    // Open = header + offset table only; independent of payload size
    // (and, in mmap mode, one mmap(2) call).
    results.push(b.run("registry_open_index", || {
        std::hint::black_box(Registry::open(&path).unwrap());
    }));

    // One registry per IO mode.  `Registry::open` defaults to Mmap with
    // automatic fallback; the bench pins each mode explicitly and reports
    // what actually took effect.
    let modes =
        [("mmap", IoMode::Mmap), ("pread", IoMode::Pread), ("reopen", IoMode::Reopen)];
    let mut regs: Vec<(&str, Registry)> = Vec::new();
    for (name, mode) in modes {
        regs.push((name, Registry::open_with(&path, OpenOptions::new().io(mode))?));
    }
    for (name, reg) in &regs {
        eprintln!("[bench:registry] requested {name}: effective {:?}", reg.io_mode());
    }

    // Raw per-section cost: one CRC-checked section fetch, no decode.
    // Mmap borrows from the mapping (CRC pass only); pread/reopen stage
    // through the reusable scratch.  This is the "ns/section" number the
    // regression gate tracks per mode.
    for (name, reg) in &regs {
        let entry = reg
            .entries()
            .iter()
            .find(|e| e.name == "task03")
            .expect("uniform registry carries task03");
        let section_bytes = entry.length as f64;
        let mut scratch = SectionScratch::default();
        results.push(b.run_throughput(
            &format!("section_read_{name}"),
            section_bytes,
            || {
                std::hint::black_box(reg.section_bytes(entry, &mut scratch).unwrap());
            },
        ));
    }

    // One lazy task: one section read + full dequantize, per IO mode.
    for (name, reg) in &regs {
        results.push(b.run_throughput(
            &format!("lazy_task_{name}"),
            params as f64,
            || {
                std::hint::black_box(reg.load_task_vector(3, &ExecCtx::sequential()).unwrap());
            },
        ));
    }

    // Cold merge straight from packed payloads (all 8 tasks).
    let ta = TaskArithmetic::default();
    results.push(b.run_throughput(
        "merge8_from_packed_registry",
        (params * N_TASKS) as f64,
        || {
            let src = PackedRegistrySource::open(&path).unwrap();
            std::hint::black_box(
                merge_from_source(&ta, &pre, &src, None, &ExecCtx::default()).unwrap(),
            );
        },
    ));

    // Same merge from f32 checkpoints loaded off disk (the old path).
    results.push(b.run_throughput(
        "merge8_from_f32_checkpoints",
        (params * N_TASKS) as f64,
        || {
            let fts: Vec<Checkpoint> = (0..N_TASKS)
                .map(|t| store.load(&format!("task{t:02}")).unwrap())
                .collect();
            let src = F32ZooSource::new(&pre, &fts);
            std::hint::black_box(
                merge_from_source(&ta, &pre, &src, None, &ExecCtx::default()).unwrap(),
            );
        },
    ));

    // Subset materialization: 2 of 8 tasks, the lazy win.
    results.push(b.run_throughput(
        "merge2of8_from_packed_registry",
        (params * 2) as f64,
        || {
            let src = PackedRegistrySource::open(&path).unwrap();
            std::hint::black_box(
                merge_from_source(&ta, &pre, &src, Some(&[2, 5]), &ExecCtx::default()).unwrap(),
            );
        },
    ));

    // Planner path: compile a mixed-precision registry at the uniform
    // TVQ-INT4 byte budget, then serve it through the fused
    // dequant-merge — which under mmap dequantizes borrowed section
    // views straight out of the mapping (zero payload copies).
    let budget = uniform_registry_bytes(&pre, &fts, QuantScheme::Tvq(4))?;
    let planned_path = dir.join("planned.qtvc");
    let cfg = PlannerConfig {
        // A slimmer, dense-only candidate set keeps the probe a one-off
        // cost and pins this bench to the kind-2 group-section fused
        // path (sparse kind-4 serving is not what's measured here).
        tvq_bits: vec![2, 3, 4, 6],
        rtvq_arms: vec![(3, 2), (4, 2)],
        dare_arms: vec![],
        tall_arms: vec![],
        ..PlannerConfig::default()
    };
    let t_plan = std::time::Instant::now();
    let (plan, summary) = build_planned_registry(&pre, &fts, budget, &cfg, &planned_path)?;
    eprintln!(
        "[bench:registry] planned registry: {} B of {} B budget in {:.1}s",
        summary.file_bytes,
        budget,
        t_plan.elapsed().as_secs_f64()
    );
    let lams = vec![0.3f32; plan.n_tasks()];
    for (name, mode) in [("mmap", IoMode::Mmap), ("pread", IoMode::Pread)] {
        let planned = Registry::open_with(&planned_path, OpenOptions::new().io(mode))?;
        results.push(b.run_throughput(
            &format!("merge8_fused_planned_{name}"),
            (params * N_TASKS) as f64,
            || {
                std::hint::black_box(
                    fused_merge(&planned, &pre, &lams, None, &ExecCtx::default()).unwrap(),
                );
            },
        ));
    }

    // Thread scaling: the same fused merge and a full registry build
    // pinned to 1 / 2 / N worker threads.  Case names are machine-
    // independent ("tN" = all cores, whatever N is here), so the
    // committed baseline stays comparable across machine classes; the
    // within-run invariant below gates that the N-thread fused merge is
    // not slower than the sequential path.
    let n_auto = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    eprintln!("[bench:registry] thread scaling: tN = {n_auto} threads");
    let planned_mmap = Registry::open_with(&planned_path, OpenOptions::new().io(IoMode::Mmap))?;
    let build_path = dir.join("build_scaling.qtvc");
    for (tag, width) in [("t1", 1usize), ("t2", 2), ("tN", n_auto)] {
        let pool = Pool::new(width);
        results.push(b.run_throughput(
            &format!("merge8_fused_threads_{tag}"),
            (params * N_TASKS) as f64,
            || {
                let ctx = ExecCtx::with_pool(&pool);
                std::hint::black_box(
                    fused_merge(&planned_mmap, &pre, &lams, None, &ctx).unwrap(),
                );
            },
        ));
        results.push(b.run_throughput(
            &format!("registry_build_threads_{tag}"),
            (params * N_TASKS) as f64,
            || {
                std::hint::black_box(
                    build_registry_with_pool(&pre, &fts, QuantScheme::Tvq(4), &build_path, &pool)
                        .unwrap(),
                );
            },
        ));
    }

    // SIMD kernel dispatch (ISSUE 10): the same fused merge pinned to
    // one thread under the scalar reference kernel vs the detected SIMD
    // kernel.  Output floats are bit-identical (simd_parity.rs proves
    // it); the invariant below gates that the SIMD kernel is not slower
    // than scalar at t1.  Under `TVQ_SIMD=off` both cases run scalar and
    // the invariant holds trivially.
    let kern = simd::active();
    eprintln!("[bench:registry] simd kernel: {} (of {:?})", kern.label(),
        simd::detected().iter().map(|k| k.label()).collect::<Vec<_>>());
    let pool1 = Pool::new(1);
    for (tag, k) in [("scalar", Kernel::Scalar), ("simd", kern)] {
        let ctx = ExecCtx::with_pool(&pool1).with_kernel(k);
        results.push(b.run_throughput(
            &format!("fused_merge_{tag}"),
            (params * N_TASKS) as f64,
            || {
                std::hint::black_box(
                    fused_merge(&planned_mmap, &pre, &lams, None, &ctx).unwrap(),
                );
            },
        ));
    }

    // Per-primitive microbenches: the four dispatched inner loops on a
    // 64Ki-element working set, scalar vs the active kernel.  Recorded
    // for the regression baseline but not gated pairwise — at this size
    // a shared runner's noise floor would flake on the small deltas.
    {
        const N: usize = 1 << 16;
        let packed = vec![0xA7u8; N / 2]; // width-4 codes
        let mut codes = vec![0u32; N];
        let dst0: Vec<f32> = (0..N).map(|i| i as f32 * 0.25).collect();
        let code_words: Vec<u32> = (0..N as u32).map(|i| i % 256).collect();
        let mask = vec![0xEDu8; N / 8];
        let vals = vec![0.125f32; N + simd::SPARSE_VALS_SLACK];
        let signs = vec![0x5Bu8; N / 8];
        for (tag, k) in [("scalar", Kernel::Scalar), ("simd", kern)] {
            results.push(b.run_throughput(&format!("unpack_w4_{tag}"), N as f64, || {
                std::hint::black_box(simd::unpack_blocks(k, 4, &packed, &mut codes));
            }));
            let mut dst = dst0.clone();
            results.push(b.run_throughput(&format!("axpy_affine_{tag}"), N as f64, || {
                simd::axpy_affine(k, 0.125, -0.5, &code_words, &mut dst);
                std::hint::black_box(&mut dst);
            }));
            let mut out = dst0.clone();
            results.push(b.run_throughput(&format!("sparse_scatter_{tag}"), N as f64, || {
                simd::sparse_scatter_axpy(k, 0.5, &mask, &vals, 0, &mut out);
                std::hint::black_box(&mut out);
            }));
            let mut acc = dst0.clone();
            results.push(b.run_throughput(&format!("signed_axpy_{tag}"), N as f64, || {
                simd::signed_axpy(k, 0.25, &signs, 0, &mut acc);
                std::hint::black_box(&mut acc);
            }));
        }
    }

    // Dynamic routing: the one-task delta patch the ModelCache serves on
    // a warm neighbor (clone cached floats + decode one tau + one axpy)
    // vs the full canonical re-merge of the same 4-task spec.  The patch
    // touches 1/4 of the task vectors, so within one run it must not be
    // slower than the re-merge — that ordering is the whole point of
    // delta patching, and the invariant below gates it.
    let src = PackedRegistrySource::open(&path)?;
    let spec = MergeSpec::new(&[0, 1, 2, 3], &[0.3, 0.2, -0.1, 0.25])?;
    let (parent_spec, patch_task, patch_lam) = spec.parent().expect("4-task spec has a parent");
    let pool = Pool::global();
    let ctx = ExecCtx::with_pool(pool);
    let parent = match merge_spec(&parent_spec, &pre, &src, &ctx)? {
        MergedModel::Shared(ck) => ck,
        _ => unreachable!("routed merges are shared"),
    };
    results.push(b.run_throughput("routed_patch_one_task", params as f64, || {
        let tau = src.registry().load_task_vector(patch_task, &ctx).unwrap();
        let mut out = parent.clone();
        out.axpy(patch_lam, &tau).unwrap();
        std::hint::black_box(out);
    }));
    results.push(b.run_throughput(
        "routed_full_remerge_4task",
        (params * spec.len()) as f64,
        || {
            std::hint::black_box(merge_spec(&spec, &pre, &src, &ctx).unwrap());
        },
    ));

    // Tiered section fetch (ISSUE 9): one verified planned-section read
    // from tier 0 (local shard mmap) vs tier 1 (a live TCP fetch-server)
    // with a warm LRU chunk cache.  A cache hit is a map probe + copy,
    // so cached-remote must stay within 2x of a local read; the diff
    // gate has one global tolerance, so the invariant compares the
    // remote case against `section_fetch_local_x2` — two local fetches
    // per iteration, i.e. exactly the 2x bound.
    let shard_dir = dir.join("shards");
    std::fs::create_dir_all(&shard_dir)?;
    let shard_src = Registry::open(&planned_path)?;
    let shards = shard_registry(&shard_src, &shard_dir, &ShardOptions::default())?;
    eprintln!(
        "[bench:registry] sharded planned registry: {} sections, {} unique chunks, {} B",
        shards.n_sections,
        shards.n_unique_chunks,
        shards.total_bytes()
    );
    let fetch_pool = Arc::new(SectionFetchPool::open(&shards.manifest_path, 2)?);
    let mut front = TcpFront::bind_sections("127.0.0.1:0", fetch_pool, 8)?;
    let local = ShardedRegistry::open(&shards.manifest_path)?;
    let remote = ShardedRegistry::open_remote(
        &shards.manifest_path,
        &front.addr().to_string(),
        64 << 20,
        OpenOptions::default(),
    )?;
    remote.load_task_vector(0, &ExecCtx::sequential())?; // warm the chunk cache
    let mut scratch = SectionScratch::default();
    results.push(b.run("section_fetch_local", || {
        std::hint::black_box(local.planned_task_view(0, 0, &mut scratch).unwrap());
    }));
    let mut scratch = SectionScratch::default();
    results.push(b.run("section_fetch_local_x2", || {
        std::hint::black_box(local.planned_task_view(0, 0, &mut scratch).unwrap());
        std::hint::black_box(local.planned_task_view(0, 0, &mut scratch).unwrap());
    }));
    let mut scratch = SectionScratch::default();
    results.push(b.run("section_fetch_remote_cached", || {
        std::hint::black_box(remote.planned_task_view(0, 0, &mut scratch).unwrap());
    }));
    front.shutdown();

    report("registry load/merge", &results);

    // Machine-readable report for the CI regression gate.  The declared
    // invariants are exactly the acceptance bars: mmap section reads
    // must not be slower than pread, and the N-thread fused merge must
    // not be slower than the sequential one (both within the diff
    // tolerance — on a single-core runner tN degenerates to t1 and the
    // invariant holds trivially).  The lazy and fused mmap-vs-pread
    // cases are recorded but not gated against each other — they are
    // dominated by identical dequantize work, so the gap there is noise
    // a shared CI runner would flake on.
    let out = std::env::var("TVQ_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_registry.json".to_string());
    let doc = json_report(
        "perf_registry",
        &results,
        &[
            ("section_read_mmap", "section_read_pread"),
            ("merge8_fused_threads_tN", "merge8_fused_threads_t1"),
            ("fused_merge_simd", "fused_merge_scalar"),
            ("routed_patch_one_task", "routed_full_remerge_4task"),
            ("section_fetch_remote_cached", "section_fetch_local_x2"),
        ],
    );
    std::fs::write(&out, doc.to_string_compact())?;
    eprintln!("[bench:registry] wrote {out}");

    std::fs::remove_dir_all(&dir).ok();
    eprintln!("[bench:registry] done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
