//! Regenerates paper artifact `fig8` (see DESIGN.md §5 experiment index).
//!
//! Run: `cargo bench --bench fig8_landscape` — equivalent to
//! `tvq experiment fig8`; results land in `target/results/fig8.md`.

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    tvq::exp::run_experiment("fig8")?;
    eprintln!("[bench:fig8] regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
