//! Regenerates paper artifact `fig4` (see DESIGN.md §5 experiment index).
//!
//! Run: `cargo bench --bench fig4_quant_error` — equivalent to
//! `tvq experiment fig4`; results land in `target/results/fig4.md`.

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    tvq::exp::run_experiment("fig4")?;
    eprintln!("[bench:fig4] regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
