//! Regenerates paper artifact `fig10` (see DESIGN.md §5 experiment index).
//!
//! Run: `cargo bench --bench fig10_error_correction` — equivalent to
//! `tvq experiment fig10`; results land in `target/results/fig10.md`.

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    tvq::exp::run_experiment("fig10")?;
    eprintln!("[bench:fig10] regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
