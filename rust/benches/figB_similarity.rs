//! Regenerates paper artifact `figB` (see DESIGN.md §5 experiment index).
//!
//! Run: `cargo bench --bench figB_similarity` — equivalent to
//! `tvq experiment figB`; results land in `target/results/figB.md`.

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    tvq::exp::run_experiment("figB")?;
    eprintln!("[bench:figB] regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
