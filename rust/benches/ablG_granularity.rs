//! Extension ablation `ablG` (see rust/src/exp/ablations.rs).
//!
//! Run: `cargo bench --bench ablG_granularity` — equivalent to
//! `tvq experiment ablG`; results land in `target/results/ablG.md`.

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    tvq::exp::run_experiment("ablG")?;
    eprintln!("[bench:ablG] regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
