//! Regenerates paper artifact `tab4` (see DESIGN.md §5 experiment index).
//!
//! Run: `cargo bench --bench tab4_cross_task` — equivalent to
//! `tvq experiment tab4`; results land in `target/results/tab4.md`.

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    tvq::exp::run_experiment("tab4")?;
    eprintln!("[bench:tab4] regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
