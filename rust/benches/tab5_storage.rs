//! Regenerates paper artifact `tab5` (see DESIGN.md §5 experiment index).
//!
//! Run: `cargo bench --bench tab5_storage` — equivalent to
//! `tvq experiment tab5`; results land in `target/results/tab5.md`.

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    tvq::exp::run_experiment("tab5")?;
    eprintln!("[bench:tab5] regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
