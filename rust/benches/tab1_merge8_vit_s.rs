//! Regenerates paper artifact `tab1` (see DESIGN.md §5 experiment index).
//!
//! Run: `cargo bench --bench tab1_merge8_vit_s` — equivalent to
//! `tvq experiment tab1`; results land in `target/results/tab1.md`.

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    tvq::exp::run_experiment("tab1")?;
    eprintln!("[bench:tab1] regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
