//! Regenerates paper artifact `figA` (see DESIGN.md §5 experiment index).
//!
//! Run: `cargo bench --bench figA_sparsity` — equivalent to
//! `tvq experiment figA`; results land in `target/results/figA.md`.

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    tvq::exp::run_experiment("figA")?;
    eprintln!("[bench:figA] regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
