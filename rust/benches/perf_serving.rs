//! End-to-end serving performance: the coordinator under closed-loop
//! concurrent load across configurations (executors x batching policy).
//! Reports throughput and latency percentiles — the §Perf L3 target.
//!
//! Run: `cargo bench --bench perf_serving`

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use tvq::coordinator::{Server, ServerConfig, ServeModel};
use tvq::exp;
use tvq::merge::{Merger, TaskArithmetic};
use tvq::quant::QuantScheme;
use tvq::runtime::Runtime;
use tvq::tensor::Tensor;
use tvq::util::rng::Rng;

fn main() -> Result<()> {
    let rt = Runtime::new()?;
    let zoo = exp::zoo(&rt, &tvq::data::VIT_S, 8)?;
    let st = exp::scheme_taus(&zoo.pre, &zoo.fts, QuantScheme::Tvq(3))?;
    let merged = Arc::new(TaskArithmetic::default().merge(&zoo.pre, &st.taus)?);
    let heads = Arc::new(
        zoo.suite.tasks.iter().map(|t| t.head.clone()).collect::<Vec<_>>(),
    );

    println!("| executors | max_batch | delay | req/s | p50 us | p99 us | avg batch |");
    println!("|---|---|---|---|---|---|---|");
    for (executors, max_batch, delay_ms) in [
        (1usize, 1usize, 0u64),   // no batching baseline
        (1, 32, 2),
        (2, 32, 2),
        (4, 32, 2),
        (2, 8, 1),
        (2, 32, 8),
    ] {
        let cfg = ServerConfig {
            max_batch,
            max_delay: Duration::from_millis(delay_ms),
            queue_cap: 8192,
            executors,
            ..Default::default()
        };
        let model = ServeModel {
            preset: zoo.preset,
            merged: merged.clone(),
            heads: heads.clone(),
        };
        let server = Arc::new(Server::start(cfg, model)?);
        // Warmup: compile every serve bucket before measuring so latency
        // percentiles reflect steady state, not one-time PJRT compilation.
        // Concurrent bursts of 1/8/32 force each bucket to form at least
        // once on every executor.
        {
            let mut rng = Rng::new(0xA0);
            for _ in 0..(2 * executors) {
                for burst in [1usize, 8, 32] {
                    let rxs: Vec<_> = (0..burst)
                        .map(|_| {
                            let x = Tensor::randn(
                                &[tvq::data::VIT_S.tokens, tvq::data::VIT_S.token_dim],
                                1.0,
                                &mut rng,
                            );
                            server.submit(0, &x).unwrap()
                        })
                        .collect();
                    for rx in rxs {
                        rx.recv().unwrap().map_err(anyhow::Error::msg)?;
                    }
                }
            }
            server.reset_metrics_window();
        }
        // Skewed load: 16 closed-loop clients over 2 hot tasks, so dynamic
        // batching has material per-task concurrency to work with (uniform
        // traffic over 8 tasks leaves ~1 outstanding per task and batching
        // degenerates to size 1 regardless of policy).
        let clients = 16usize;
        let per_client = 64usize;
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for c in 0..clients {
            let s = server.clone();
            handles.push(std::thread::spawn(move || -> Result<()> {
                let mut rng = Rng::new(0x9E2F + c as u64);
                for _ in 0..per_client {
                    let task = c % 2;
                    let x = Tensor::randn(
                        &[tvq::data::VIT_S.tokens, tvq::data::VIT_S.token_dim],
                        1.0,
                        &mut rng,
                    );
                    s.infer(task, &x)?;
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().expect("client panicked")?;
        }
        let dt = t0.elapsed().as_secs_f64();
        let m = server.metrics();
        println!(
            "| {executors} | {max_batch} | {delay_ms}ms | {:.0} | {:.0} | {:.0} | {:.1} |",
            (clients * per_client) as f64 / dt,
            m.latency_p50_us,
            m.latency_p99_us,
            m.mean_batch_size,
        );
    }
    Ok(())
}
