//! Regenerates paper artifact `tab2` (see DESIGN.md §5 experiment index).
//!
//! Run: `cargo bench --bench tab2_merge8_vit_m` — equivalent to
//! `tvq experiment tab2`; results land in `target/results/tab2.md`.

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    tvq::exp::run_experiment("tab2")?;
    eprintln!("[bench:tab2] regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
