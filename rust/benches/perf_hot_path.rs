//! Performance benches for the L3 serving hot path: bit unpacking,
//! affine quantize/dequantize, and the fused dequantize-and-merge kernel
//! (checkpoint and flat/grouped variants, TVQ and RTVQ).
//!
//! This is the criterion-style microbench suite used by the §Perf pass in
//! EXPERIMENTS.md; results are throughput in parameters/second.
//!
//! Run: `cargo bench --bench perf_hot_path`

use tvq::checkpoint::Checkpoint;
use tvq::quant::{fused, AffineParams, BitPacked, GroupQuantized, QuantizedCheckpoint};
use tvq::tensor::Tensor;
use tvq::util::bench::{report, Bench};
use tvq::util::rng::Rng;

/// Parameter count for flat benches — ViT-B/32-scale padded tensor.
const N: usize = 1 << 22; // ~4.2M params
const GROUP: usize = 1024;
const TASKS: usize = 8;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(0xBE7C);
    let b = Bench::default();
    let mut results = Vec::new();

    // --- bit unpack throughput per width --------------------------------
    let mut codes_buf = vec![0u32; N];
    for bits in [2u8, 3, 4, 8] {
        let codes: Vec<u32> =
            (0..N).map(|_| rng.next_u64() as u32 & ((1 << bits) - 1)).collect();
        let packed = BitPacked::pack(&codes, bits)?;
        results.push(b.run_throughput(
            &format!("unpack_{bits}bit"),
            N as f64,
            || packed.unpack_into(&mut codes_buf),
        ));
    }

    // --- affine quantize / dequantize ------------------------------------
    let mut data = vec![0.0f32; N];
    rng.fill_normal(&mut data, 0.02);
    let params = AffineParams::from_slice(&data, 4)?;
    results.push(b.run_throughput("affine_quantize_4bit", N as f64, || {
        std::hint::black_box(params.quantize_slice(&data));
    }));

    // --- group quantize + fused dequant-merge (flat TVQ path) ------------
    let gqs: Vec<GroupQuantized> = (0..TASKS)
        .map(|_| {
            let mut tau = vec![0.0f32; N];
            rng.fill_normal(&mut tau, 0.02);
            GroupQuantized::quantize(&tau, 3, GROUP).unwrap()
        })
        .collect();
    let gq_refs: Vec<&GroupQuantized> = gqs.iter().collect();
    let mut pre = vec![0.0f32; N];
    rng.fill_normal(&mut pre, 0.3);
    let lams = vec![0.3f32; TASKS];
    let mut out = Vec::with_capacity(N);
    results.push(b.run_throughput(
        &format!("dequant_merge_flat_{TASKS}tasks_3bit"),
        (N * TASKS) as f64,
        || fused::dequant_merge_flat(&pre, &gq_refs, &lams, &mut out).unwrap(),
    ));

    // --- RTVQ flat path ---------------------------------------------------
    let base = GroupQuantized::quantize(&pre.iter().map(|v| v * 0.05).collect::<Vec<_>>(), 3, GROUP)?;
    results.push(b.run_throughput(
        &format!("dequant_merge_rtvq_flat_{TASKS}tasks"),
        (N * (TASKS + 1)) as f64,
        || fused::dequant_merge_rtvq_flat(&pre, &base, &gq_refs, &lams, &mut out).unwrap(),
    ));

    // --- named-checkpoint fused merge (the serving rebuild path) ---------
    let ck = {
        let mut c = Checkpoint::new();
        c.insert("w0", Tensor::randn(&[512, 512], 0.3, &mut rng));
        c.insert("w1", Tensor::randn(&[512, 512], 0.3, &mut rng));
        c
    };
    let qcks: Vec<QuantizedCheckpoint> = (0..TASKS)
        .map(|_| {
            let mut tau = Checkpoint::new();
            for (name, t) in ck.iter() {
                tau.insert(name, Tensor::randn(t.shape(), 0.02, &mut rng));
            }
            QuantizedCheckpoint::quantize(&tau, 3).unwrap()
        })
        .collect();
    let qck_refs: Vec<&QuantizedCheckpoint> = qcks.iter().collect();
    results.push(b.run_throughput(
        "dequant_merge_checkpoints_8tasks",
        (ck.numel() * TASKS) as f64,
        || {
            std::hint::black_box(
                fused::dequant_merge_checkpoints(&ck, &qck_refs, &lams).unwrap(),
            );
        },
    ));

    report("perf_hot_path (params/s)", &results);
    Ok(())
}
