//! Regenerates paper artifact `tab3` (see DESIGN.md §5 experiment index).
//!
//! Run: `cargo bench --bench tab3_dense` — equivalent to
//! `tvq experiment tab3`; results land in `target/results/tab3.md`.

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    tvq::exp::run_experiment("tab3")?;
    eprintln!("[bench:tab3] regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
