//! Extension ablation `ablD` (see rust/src/exp/ablations.rs).
//!
//! Run: `cargo bench --bench ablD_dare` — equivalent to
//! `tvq experiment ablD`; results land in `target/results/ablD.md`.

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    tvq::exp::run_experiment("ablD")?;
    eprintln!("[bench:ablD] regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
