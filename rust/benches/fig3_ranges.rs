//! Regenerates paper artifact `fig3` (see DESIGN.md §5 experiment index).
//!
//! Run: `cargo bench --bench fig3_ranges` — equivalent to
//! `tvq experiment fig3`; results land in `target/results/fig3.md`.

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    tvq::exp::run_experiment("fig3")?;
    eprintln!("[bench:fig3] regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
