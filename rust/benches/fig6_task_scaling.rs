//! Regenerates paper artifact `fig6` (see DESIGN.md §5 experiment index).
//!
//! Run: `cargo bench --bench fig6_task_scaling` — equivalent to
//! `tvq experiment fig6`; results land in `target/results/fig6.md`.

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    tvq::exp::run_experiment("fig6")?;
    eprintln!("[bench:fig6] regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
