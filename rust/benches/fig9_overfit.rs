//! Regenerates paper artifact `fig9` (see DESIGN.md §5 experiment index).
//!
//! Run: `cargo bench --bench fig9_overfit` — equivalent to
//! `tvq experiment fig9`; results land in `target/results/fig9.md`.

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    tvq::exp::run_experiment("fig9")?;
    eprintln!("[bench:fig9] regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
