//! Table R: routed per-request dynamic merging vs static variant
//! serving at equal bytes (the serving companion to Table P).
//!
//! The claim under test is the router + delta-patch engine's reason to
//! exist: a node holding **one** packed registry can serve an open-ended
//! family of `(task subset, lambdas)` variants — each built on first
//! request, each one-task extension served as a single signed axpy over
//! a cached neighbor — where a static deployment must pre-materialize
//! (and pay fp32 bytes for) every variant it might be asked for.  Every
//! served variant is checked bit-for-bit against an independent
//! from-scratch canonical merge; the table reports how each request was
//! served (full build / delta patch / cache hit) and what the two
//! strategies pay in bytes for the same variant family.
//!
//! Runs without PJRT (like `tab5`/`tabP`): `tvq experiment tabR`, or in
//! CI smoke mode with `TVQ_SMOKE=1` (smaller zoo, same assertions).

use anyhow::Result;

use super::planner::synthetic_planner_zoo;
use super::report::{finish, Table};
use crate::coordinator::router::merge_spec;
use crate::coordinator::{ModelCache, Router};
use crate::planner::{probe, solve, write_planned_registry, PlannerConfig};
use crate::registry::PackedRegistrySource;
use crate::util::exec::ExecCtx;
use crate::util::pool::Pool;

fn smoke() -> bool {
    std::env::var_os("TVQ_SMOKE").is_some()
}

/// The deterministic request script: a growing patch chain over the
/// first tasks (each step appends the next task — the delta-patch fast
/// path), a revisit of the chain head (cache hit), then a disjoint
/// subset and a lambda retune (both full merges: no cached neighbor).
/// Returned as `(tasks, lambdas)` pairs fed through the [`Router`].
pub fn request_script(n_tasks: usize) -> Vec<(Vec<usize>, Vec<f32>)> {
    assert!(n_tasks >= 4, "script needs at least 4 tasks, got {n_tasks}");
    let lam = 0.3f32;
    let mut reqs = Vec::new();
    // Chain: {0}, {0,1}, ..., {0..chain_len-1} — every step after the
    // first has its predecessor cached.
    let chain_len = n_tasks.min(4);
    for k in 1..=chain_len {
        let tasks: Vec<usize> = (0..k).collect();
        reqs.push((tasks, vec![lam; k]));
    }
    // Revisit the full chain (pure cache hit).
    reqs.push(((0..chain_len).collect(), vec![lam; chain_len]));
    // A disjoint pair: no neighbor, full merge.
    reqs.push((vec![n_tasks - 1, n_tasks - 2], vec![0.2, -0.1]));
    // Retune the chain's lambdas: same subset, different coefficients —
    // a different variant that must NOT patch off the old chain.
    reqs.push(((0..chain_len).collect(), vec![lam * 0.5; chain_len]));
    reqs
}

/// Regenerate Table R.
pub fn tabr_dynamic() -> Result<Vec<Table>> {
    let n_tasks = if smoke() { 4 } else { 8 };
    let (pre, fts) = synthetic_planner_zoo(n_tasks, 0xD19A);
    let dir = crate::util::repo_path("target/results/tabR_files");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir)?;

    // Pack the zoo once; the whole variant family serves from this file.
    let profile = probe(&pre, &fts, &PlannerConfig::default())?;
    let plan = solve(&profile, u64::MAX)?;
    let path = dir.join("zoo.qtvc");
    let summary = write_planned_registry(&pre, &fts, &plan, &path)?;
    let source = PackedRegistrySource::open(&path)?;

    let cache = ModelCache::new();
    let metrics = std::sync::Arc::new(crate::coordinator::Metrics::new());
    cache.set_metrics(metrics.clone());
    let router = Router::new(n_tasks);
    let pool = Pool::global();

    let mut table = Table::new(
        "tabR",
        "Routed dynamic merging over one packed registry: how each \
         request was served, and bit-exactness vs an independent \
         from-scratch merge of the same spec",
        &["Request", "tasks", "served via", "wall ms", "bit-exact"],
    );

    let mut distinct_variants = 0usize;
    for (i, (tasks, lambdas)) in request_script(n_tasks).iter().enumerate() {
        let spec = router.route(tasks, lambdas)?;
        let before = metrics.snapshot();
        let t0 = std::time::Instant::now();
        let served = cache.get_or_merge_routed(&spec, &pre, &source)?;
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        let after = metrics.snapshot();
        let via = if after.delta_patches > before.delta_patches {
            distinct_variants += 1;
            "delta patch"
        } else if after.merge_builds > before.merge_builds {
            distinct_variants += 1;
            "full build"
        } else {
            "cache hit"
        };
        // Independent canonical merge of the same spec, from scratch.
        let reference = merge_spec(&spec, &pre, &source, &ExecCtx::with_pool(pool))?;
        let mismatched = served
            .for_task(0)
            .iter()
            .zip(reference.for_task(0).iter())
            .flat_map(|((_, a), (_, b))| a.data().iter().zip(b.data()))
            .filter(|(x, y)| x.to_bits() != y.to_bits())
            .count();
        anyhow::ensure!(
            mismatched == 0,
            "request {i} served {mismatched} floats differing from the canonical merge"
        );
        table.push_row(vec![
            format!("r{i}"),
            format!("{tasks:?}"),
            via.to_string(),
            format!("{wall_ms:.2}"),
            "yes".to_string(),
        ]);
    }

    // Equal-bytes comparison: what each strategy pays to hold this
    // variant family.  Static serving materializes every distinct
    // variant in fp32; the dynamic node holds the packed registry plus
    // whatever the cache currently pins (LRU-bounded in production).
    let static_bytes = distinct_variants * pre.fp32_bytes();
    let s = metrics.snapshot();
    let mut bytes = Table::new(
        "tabR",
        "Bytes to serve the same variant family: static pre-materialized \
         fp32 variants vs one packed registry + dynamic cache",
        &["Strategy", "bytes", "variants", "full builds", "delta patches"],
    );
    bytes.push_row(vec![
        "static fp32 variants".into(),
        static_bytes.to_string(),
        distinct_variants.to_string(),
        distinct_variants.to_string(),
        "-".into(),
    ]);
    bytes.push_row(vec![
        "dynamic (registry + cache)".into(),
        (summary.file_bytes as usize + cache.resident_bytes()).to_string(),
        distinct_variants.to_string(),
        s.merge_builds.to_string(),
        s.delta_patches.to_string(),
    ]);
    anyhow::ensure!(
        s.delta_patches >= 1,
        "the chained request script must exercise the delta-patch path"
    );
    finish("tabR", vec![table, bytes])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_script_exercises_patch_hit_and_miss() {
        let reqs = request_script(8);
        // Chain steps 2.. have their predecessor issued first.
        for k in 1..4 {
            assert_eq!(reqs[k].0, (0..=k).collect::<Vec<_>>());
            assert_eq!(reqs[k - 1].0, (0..k).collect::<Vec<_>>());
        }
        // The revisit duplicates the chain head exactly.
        assert_eq!(reqs[4], reqs[3]);
        // The retune shares the subset but not the lambdas.
        let last = reqs.last().unwrap();
        assert_eq!(last.0, reqs[3].0);
        assert_ne!(last.1, reqs[3].1);
        // Scripts are deterministic.
        assert_eq!(request_script(8), request_script(8));
    }
}
