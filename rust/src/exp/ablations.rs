//! Extension ablations beyond the paper's figures:
//!
//! * `ablG` — quantization-granularity ablation: per-tensor vs per-group
//!   vs per-channel error/storage trade-off on real task vectors (the
//!   design choice behind the Pallas kernel's BlockSpec group size).
//! * `ablD` — DARE sparsification (related-work baseline [61]) under
//!   quantization: does drop-and-rescale survive low-bit task vectors?

use anyhow::Result;

use super::report::{finish, Table};
use super::schemes::scheme_taus;
use crate::data::VIT_S;
use crate::merge::{Dare, Merger};
use crate::quant::channel::{quantize_error_storage, Granularity};
use crate::quant::QuantScheme;
use crate::runtime::Runtime;

/// ablG: error x storage per granularity on the zoo's 2-D task-vector
/// tensors, per bit width.
pub fn ablg_granularity(rt: &Runtime) -> Result<Vec<Table>> {
    let zoo = super::zoo(rt, &VIT_S, 8)?;
    let taus = zoo.task_vectors()?;
    let grans = [
        Granularity::PerTensor,
        Granularity::PerGroup(1024),
        Granularity::PerGroup(256),
        Granularity::PerChannel,
    ];
    let mut tables = Vec::new();
    for bits in [2u8, 3, 4] {
        let mut cols: Vec<String> = vec!["Granularity".into()];
        cols.push("L2 err (x1e6/param)".into());
        cols.push("storage (% fp32)".into());
        let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
        let mut table = Table::new(
            "ablG",
            &format!("Quantization granularity at INT{bits} (8-task mean, 2-D tensors)"),
            &col_refs,
        );
        for gran in grans {
            let mut err = 0.0f64;
            let mut bytes = 0usize;
            let mut fp32 = 0usize;
            let mut params = 0usize;
            for tau in &taus {
                for (_, t) in tau.iter() {
                    if t.shape().len() != 2 {
                        continue;
                    }
                    let (e, b) = quantize_error_storage(t, bits, gran)?;
                    err += e;
                    bytes += b;
                    fp32 += t.numel() * 4;
                    params += t.numel();
                }
            }
            table.push_row(vec![
                gran.label(),
                format!("{:.2}", 1e6 * err / params as f64),
                format!("{:.2}", 100.0 * bytes as f64 / fp32 as f64),
            ]);
        }
        tables.push(table);
    }
    finish("ablG", tables)
}

/// ablD: DARE drop-rate sweep under FP32 and 3-bit task vectors.
pub fn abld_dare(rt: &Runtime) -> Result<Vec<Table>> {
    let zoo = super::zoo(rt, &VIT_S, 8)?;
    let drops = [0.0f32, 0.5, 0.9, 0.99];
    let schemes = [QuantScheme::Fp32, QuantScheme::Tvq(3)];
    let mut cols: Vec<String> = vec!["Drop rate".into()];
    cols.extend(schemes.iter().map(|s| s.label()));
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        "ablD",
        "DARE drop-and-rescale under quantization (avg acc %, 8 tasks)",
        &col_refs,
    );
    for &p in &drops {
        let mut row = vec![format!("{p:.2}")];
        for &scheme in &schemes {
            let st = scheme_taus(&zoo.pre, &zoo.fts, scheme)?;
            let dare = Dare::new(0.3, p, 0xDA7E);
            let merged = dare.merge(&zoo.pre, &st.taus)?;
            let accs = super::classify::eval_merged(rt, &zoo, &merged)?;
            let avg = accs.iter().sum::<f64>() / accs.len() as f64;
            eprintln!("[exp:ablD] drop {p} {} -> {avg:.1}", scheme.label());
            row.push(format!("{avg:.1}"));
        }
        table.push_row(row);
    }
    finish("ablD", vec![table])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn granularity_labels_distinct() {
        let labels: Vec<String> = [
            Granularity::PerTensor,
            Granularity::PerGroup(1024),
            Granularity::PerChannel,
        ]
        .iter()
        .map(|g| g.label())
        .collect();
        let mut d = labels.clone();
        d.dedup();
        assert_eq!(labels, d);
    }
}
