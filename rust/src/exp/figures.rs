//! Analysis figures and the storage table: Figs. 3, 4, 8, 9, 10, A, B and
//! Table 5.

use anyhow::Result;

use super::report::{finish, save_raw, Table};
use crate::checkpoint::Checkpoint;
use crate::data::VIT_S;
use crate::quant::{QuantScheme, QuantizedCheckpoint, Rtvq, StorageReport};
use crate::quant::storage::VIT_L14_PARAMS;
use crate::runtime::Runtime;
use crate::util::exec::ExecCtx;
use crate::util::stats;

/// Fig. 3: weight range of the fine-tuned checkpoint vs its task vector —
/// the observation motivating TVQ.  Also saves value histograms.
pub fn fig3_weight_ranges(rt: &Runtime) -> Result<Vec<Table>> {
    let zoo = super::zoo(rt, &VIT_S, 8)?;
    let mut table = Table::new(
        "fig3",
        "Weight ranges: fine-tuned checkpoint vs task vector (paper Fig. 3)",
        &["Task", "ft range", "tau range", "ratio ft/tau"],
    );
    let mut hist_csv = String::from("task,kind,bin_lo,bin_hi,count\n");
    let mut ratios = Vec::new();
    for (t, ft) in zoo.fts.iter().enumerate() {
        let tau = ft.sub(&zoo.pre)?;
        let (flo, fhi) = ft.weight_range();
        let (tlo, thi) = tau.weight_range();
        let fr = (fhi - flo) as f64;
        let tr = (thi - tlo) as f64;
        let ratio = fr / tr.max(1e-12);
        ratios.push(ratio);
        table.push_row(vec![
            format!("task{t:02}"),
            format!("[{flo:.3}, {fhi:.3}] ({fr:.3})"),
            format!("[{tlo:.4}, {thi:.4}] ({tr:.4})"),
            format!("{ratio:.1}x"),
        ]);
        // Histograms over the first task only (representative, keeps the
        // raw artifact small) — matches the paper's single-dataset plots.
        if t == 0 {
            for (kind, ck, lo, hi) in
                [("ft", ft, flo, fhi), ("tau", &tau, tlo, thi)]
            {
                let flat: Vec<f32> = ck
                    .iter()
                    .flat_map(|(_, t)| t.data().iter().copied())
                    .collect();
                let bins = 64;
                let h = stats::histogram(&flat, lo, hi, bins);
                for (b, c) in h.iter().enumerate() {
                    let blo = lo + (hi - lo) * b as f32 / bins as f32;
                    let bhi = lo + (hi - lo) * (b + 1) as f32 / bins as f32;
                    hist_csv.push_str(&format!("{t},{kind},{blo},{bhi},{c}\n"));
                }
            }
        }
    }
    let mean_ratio = ratios.iter().sum::<f64>() / ratios.len() as f64;
    table.push_row(vec![
        "mean".into(),
        "-".into(),
        "-".into(),
        format!("{mean_ratio:.1}x"),
    ]);
    save_raw("fig3_histograms.csv", &hist_csv)?;
    finish("fig3", vec![table])
}

/// Fig. 4: L2 quantization error (per-parameter, log scale in the paper)
/// of FQ vs TVQ vs RTVQ across bit widths, averaged over the 8 tasks.
pub fn fig4_quant_error(rt: &Runtime) -> Result<Vec<Table>> {
    let zoo = super::zoo(rt, &VIT_S, 8)?;
    let taus: Vec<Checkpoint> = zoo.task_vectors()?;
    let n_params = zoo.pre.numel() as f64;
    let bits = [2u8, 3, 4, 8];

    let mut table = Table::new(
        "fig4",
        "Mean L2 quant error per parameter (x1e6), 8 tasks (paper Fig. 4)",
        &["Scheme", "INT2", "INT3", "INT4", "INT8"],
    );
    // FQ: distance between true tau and (dq(Q(ft)) - pre).
    let mut fq_row = vec!["FQ".to_string()];
    for &b in &bits {
        let mut err = 0.0;
        for (ft, tau) in zoo.fts.iter().zip(&taus) {
            let q = QuantizedCheckpoint::quantize(ft, b)?;
            let tau_hat = q.dequantize()?.sub(&zoo.pre)?;
            err += tau.l2_dist(&tau_hat)?;
        }
        fq_row.push(format!("{:.2}", 1e6 * err / (taus.len() as f64 * n_params)));
    }
    table.push_row(fq_row);
    // TVQ: dq(Q(tau)).
    let mut tvq_row = vec!["TVQ".to_string()];
    for &b in &bits {
        let mut err = 0.0;
        for tau in &taus {
            let q = QuantizedCheckpoint::quantize(tau, b)?;
            err += q.quant_error(tau)?;
        }
        tvq_row.push(format!("{:.2}", 1e6 * err / (taus.len() as f64 * n_params)));
    }
    table.push_row(tvq_row);
    // RTVQ at a comparable budget: base = b+1, offset = b (so effective
    // bits/task = b + (b+1)/8, slightly above b like the paper's 2.375).
    let mut rtvq_row = vec!["RTVQ (B=b+1,O=b)".to_string()];
    for &b in &bits {
        let r =
            Rtvq::quantize(&zoo.pre, &zoo.fts, (b + 1).min(8), b, true, &ExecCtx::sequential())?;
        let err = r.total_quant_error(&zoo.pre, &zoo.fts)?;
        rtvq_row.push(format!("{:.2}", 1e6 * err / (taus.len() as f64 * n_params)));
    }
    table.push_row(rtvq_row);
    finish("fig4", vec![table])
}

/// Fig. 8 (+ Appendix F-K): loss-landscape grids around pre + a*tau_a +
/// b*tau_b, comparing FP32 task vectors against 2-bit TVQ.  Emits the
/// full grids as CSV and a summary table of minima.
pub fn fig8_landscape(rt: &Runtime) -> Result<Vec<Table>> {
    let zoo = super::zoo(rt, &VIT_S, 8)?;
    let taus = zoo.task_vectors()?;
    let q2: Vec<Checkpoint> = zoo
        .fts
        .iter()
        .map(|ft| {
            let tau = ft.sub(&zoo.pre)?;
            QuantizedCheckpoint::quantize(&tau, 2)?.dequantize()
        })
        .collect::<Result<_>>()?;
    let grid = 8; // 16x16 in the paper; 8x8 keeps PJRT time in check
    let range = (-0.5f32, 1.5f32);
    let eval_n = 128;
    let mut table = Table::new(
        "fig8",
        "Loss landscape minima: FP32 vs 2-bit TVQ task vectors (paper Fig. 8)",
        &["Pair (eval on A)", "FP32 min loss", "TVQ2 min loss", "FP32 argmin", "TVQ2 argmin"],
    );
    // Target pair (EuroSAT-model-on-EuroSAT analog) and a cross pair
    // (GTSRB-model-on-EuroSAT analog).
    for (a, b) in [(0usize, 0usize), (1usize, 0usize)] {
        let task = &zoo.suite.tasks[b];
        let g_fp = crate::eval::landscape::loss_grid(
            rt, zoo.preset, &zoo.pre, &taus[a], &taus[b], task, grid, range, eval_n,
        )?;
        let g_q = crate::eval::landscape::loss_grid(
            rt, zoo.preset, &zoo.pre, &q2[a], &q2[b], task, grid, range, eval_n,
        )?;
        save_raw(&format!("fig8_fp32_a{a}_b{b}.csv"), &g_fp.to_csv())?;
        save_raw(&format!("fig8_tvq2_a{a}_b{b}.csv"), &g_q.to_csv())?;
        let min_of = |g: &crate::eval::landscape::LossGrid| {
            let mut best = (f64::INFINITY, 0usize, 0usize);
            for i in 0..g.grid {
                for j in 0..g.grid {
                    if g.at(i, j) < best.0 {
                        best = (g.at(i, j), i, j);
                    }
                }
            }
            best
        };
        let (mf, fi, fj) = min_of(&g_fp);
        let (mq, qi, qj) = min_of(&g_q);
        eprintln!("[exp:fig8] pair ({a},{b}): fp32 min {mf:.3}, tvq2 min {mq:.3}");
        table.push_row(vec![
            format!("tau{a} x tau{b} on task{b}"),
            format!("{mf:.3}"),
            format!("{mq:.3}"),
            format!("({:.2},{:.2})", g_fp.alphas[fi], g_fp.betas[fj]),
            format!("({:.2},{:.2})", g_q.alphas[qi], g_q.betas[qj]),
        ]);
    }
    finish("fig8", vec![table])
}

/// Fig. 9: train vs test accuracy of the original vs 3-bit-quantized task
/// vector across fine-tuning epochs (the overfitting-suppression claim).
pub fn fig9_overfit(rt: &Runtime) -> Result<Vec<Table>> {
    use crate::runtime::Value;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    let zoo = super::zoo(rt, &VIT_S, 8)?;
    let preset = zoo.preset;
    let task = &zoo.suite.tasks[0]; // the hardest-dataset analog
    let cfg = super::default_train_config();
    let art = rt.load(&format!("{}_train_b{}", preset.name, preset.train_batch))?;
    let b = preset.train_batch;
    let img = preset.tokens * preset.token_dim;
    let (pool_x, pool_y) = task.train_pool(cfg.pool);
    let epoch_steps = 25usize;
    let epochs = 8usize;

    let mut table = Table::new(
        "fig9",
        "Train/test accuracy by epoch: FP32 tau vs 3-bit TVQ tau (paper Fig. 9)",
        &["Epoch", "train FP32", "train TVQ3", "test FP32", "test TVQ3"],
    );

    let mut rng = Rng::new(task.seed ^ 0xF19);
    let mut ck = zoo.pre.clone();
    let mut xbuf = Tensor::zeros(&[b, preset.tokens, preset.token_dim]);
    let mut ybuf = vec![0i32; b];
    // Train-accuracy probe set: a fixed slice of the training pool.
    let probe_n = 256.min(cfg.pool);
    let probe_x = Tensor::new(
        vec![probe_n, preset.tokens, preset.token_dim],
        pool_x.data()[..probe_n * img].to_vec(),
    )?;
    let probe_y: Vec<i32> = pool_y[..probe_n].to_vec();

    for epoch in 1..=epochs {
        for _ in 0..epoch_steps {
            for i in 0..b {
                let j = rng.below(cfg.pool);
                xbuf.data_mut()[i * img..(i + 1) * img]
                    .copy_from_slice(&pool_x.data()[j * img..(j + 1) * img]);
                ybuf[i] = pool_y[j];
            }
            let y = Value::I32(vec![b], ybuf.clone());
            let (next, _) =
                crate::runtime::train_step(&art, &ck, &task.head, &xbuf, &y, cfg.lr)?;
            ck = next;
        }
        let tau = ck.sub(&zoo.pre)?;
        let tau_q = QuantizedCheckpoint::quantize(&tau, 3)?.dequantize()?;
        let model_fp = ck.clone();
        let mut model_q = zoo.pre.clone();
        model_q.axpy(1.0, &tau_q)?;
        let acc_on = |model: &Checkpoint, x: &Tensor, y: &[i32]| -> Result<f64> {
            let logits = crate::eval::batched_logits(rt, preset, model, &task.head, x)?;
            let c = *logits.shape().last().unwrap();
            let correct = logits
                .data()
                .chunks_exact(c)
                .zip(y)
                .filter(|(row, &t)| {
                    let am = row
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0;
                    am == t as usize
                })
                .count();
            Ok(100.0 * correct as f64 / y.len() as f64)
        };
        let (ex, ey) = task.eval_set(crate::eval::EVAL_N);
        let train_fp = acc_on(&model_fp, &probe_x, &probe_y)?;
        let train_q = acc_on(&model_q, &probe_x, &probe_y)?;
        let test_fp = acc_on(&model_fp, &ex, &ey)?;
        let test_q = acc_on(&model_q, &ex, &ey)?;
        eprintln!(
            "[exp:fig9] epoch {epoch}: train {train_fp:.1}/{train_q:.1}, test {test_fp:.1}/{test_q:.1}"
        );
        table.push_row(vec![
            epoch.to_string(),
            format!("{train_fp:.1}"),
            format!("{train_q:.1}"),
            format!("{test_fp:.1}"),
            format!("{test_q:.1}"),
        ]);
    }
    finish("fig9", vec![table])
}

/// Fig. 10: RTVQ quantization error with vs without error correction
/// across base-bit and offset-bit configurations.
pub fn fig10_error_correction(rt: &Runtime) -> Result<Vec<Table>> {
    let zoo = super::zoo(rt, &VIT_S, 8)?;
    let n = zoo.pre.numel() as f64 * zoo.fts.len() as f64;
    let mut tables = Vec::new();
    for ec in [true, false] {
        let mut table = Table::new(
            "fig10",
            &format!(
                "RTVQ error correction ablation (x1e6/param), EC={} (paper Fig. 10)",
                if ec { "on" } else { "off" }
            ),
            &["Offset \\ Base", "B2", "B3", "B4", "B8"],
        );
        for bo in [2u8, 3, 4] {
            let mut row = vec![format!("O{bo}")];
            for bb in [2u8, 3, 4, 8] {
                let r = Rtvq::quantize(&zoo.pre, &zoo.fts, bb, bo, ec, &ExecCtx::sequential())?;
                let err = r.total_quant_error(&zoo.pre, &zoo.fts)?;
                row.push(format!("{:.2}", 1e6 * err / n));
            }
            table.push_row(row);
        }
        tables.push(table);
    }
    finish("fig10", tables)
}

/// Table 5: practical storage for the real ViT-L/14 parameter count at
/// 8/14/20 tasks under each scheme (exact bit accounting).
pub fn tab5_storage() -> Result<Vec<Table>> {
    let schemes = [
        QuantScheme::Fp32,
        QuantScheme::Tvq(8),
        QuantScheme::Tvq(4),
        QuantScheme::Tvq(2),
        QuantScheme::Rtvq(3, 2),
    ];
    let mut cols: Vec<String> = vec!["# Tasks".into()];
    cols.extend(schemes.iter().map(|s| s.label()));
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        "tab5",
        "Checkpoint storage for ViT-L/14 (303.97M params; paper Table 5)",
        &col_refs,
    );
    for &n in &[8usize, 14, 20] {
        let mut row = vec![n.to_string()];
        for &s in &schemes {
            let rep = StorageReport::ideal(s, n, VIT_L14_PARAMS);
            row.push(format!("{:.1} GB ({:.1}%)", rep.gib(), 100.0 * rep.fraction_of_fp32()));
        }
        table.push_row(row);
    }
    let measured = tab5_measured_table()?;
    finish("tab5", vec![table, measured])
}

/// Companion to Table 5: the same storage ratios measured from **real
/// files** — packed `QTVC` registries written to disk next to the f32
/// `TVQC` zoo they replace — instead of bit arithmetic.  The "overhead"
/// column is the measured gap to [`StorageReport::ideal`] (index + affine
/// params + tensor names).
fn tab5_measured_table() -> Result<Table> {
    use crate::checkpoint::CheckpointStore;
    use crate::registry::{build_registry, f32_store_bytes, DiskAccounting, Registry};
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    // Synthetic 8-task zoo, large enough that per-tensor metadata is a
    // sub-percent effect (as it is at model scale).
    let n_tasks = 8usize;
    let mut rng = Rng::new(0x7AB5);
    let mut pre = Checkpoint::new();
    pre.insert("blk00/w", Tensor::randn(&[128, 64], 0.3, &mut rng));
    pre.insert("blk01/w", Tensor::randn(&[128, 64], 0.3, &mut rng));
    pre.insert("head/w", Tensor::randn(&[64, 10], 0.1, &mut rng));
    let fts: Vec<Checkpoint> = (0..n_tasks)
        .map(|_| {
            let mut tau = Checkpoint::new();
            for (name, t) in pre.iter() {
                tau.insert(name, Tensor::randn(t.shape(), 0.01, &mut rng));
            }
            pre.add(&tau).unwrap()
        })
        .collect();

    let dir = crate::util::repo_path("target/results/tab5_files");
    std::fs::remove_dir_all(&dir).ok();
    let store = CheckpointStore::new(dir.join("f32"));
    for (t, ft) in fts.iter().enumerate() {
        store.save(&format!("task{t:02}"), ft)?;
    }
    let f32_bytes = f32_store_bytes(&store)?;

    let mut table = Table::new(
        "tab5",
        "Measured on-disk bytes: QTVC registries vs the f32 TVQC zoo \
         (8 synthetic tasks, real files)",
        &["Scheme", "file bytes", "ideal bytes", "overhead", "% of f32 files"],
    );
    table.push_row(vec![
        "FP32 (TVQC v1)".into(),
        f32_bytes.to_string(),
        ((pre.fp32_bytes() * n_tasks) as u64).to_string(),
        "-".into(),
        "100.0".into(),
    ]);
    for scheme in [
        QuantScheme::Tvq(8),
        QuantScheme::Tvq(4),
        QuantScheme::Tvq(2),
        QuantScheme::Rtvq(3, 2),
    ] {
        let path = dir.join(format!("{}.qtvc", scheme.label()));
        build_registry(&pre, &fts, scheme, &path)?;
        let reg = Registry::open(&path)?;
        let acc = DiskAccounting::measure(&reg)?;
        table.push_row(vec![
            scheme.label(),
            acc.file_bytes.to_string(),
            acc.ideal_bytes.to_string(),
            format!("{:.2}%", 100.0 * acc.overhead_fraction()),
            format!("{:.1}", 100.0 * acc.file_bytes as f64 / f32_bytes as f64),
        ]);
    }
    Ok(table)
}

/// Fig. A: sparsity induced by 3-bit TVQ — fraction of exactly-zero
/// values in the task vector before vs after quantization.
pub fn figa_sparsity(rt: &Runtime) -> Result<Vec<Table>> {
    let zoo = super::zoo(rt, &VIT_S, 8)?;
    let mut table = Table::new(
        "figA",
        "Task-vector sparsity before/after 3-bit TVQ (paper Fig. A)",
        &["Task", "zeros before (%)", "zeros after (%)"],
    );
    let mut before_acc = 0.0;
    let mut after_acc = 0.0;
    for (t, ft) in zoo.fts.iter().enumerate() {
        let tau = ft.sub(&zoo.pre)?;
        let tau_hat = QuantizedCheckpoint::quantize(&tau, 3)?.dequantize()?;
        let frac_zero = |ck: &Checkpoint| -> f64 {
            let total: usize = ck.numel();
            let zeros: usize = ck
                .iter()
                .map(|(_, t)| t.data().iter().filter(|&&v| v == 0.0).count())
                .sum();
            100.0 * zeros as f64 / total as f64
        };
        let b = frac_zero(&tau);
        let a = frac_zero(&tau_hat);
        before_acc += b;
        after_acc += a;
        table.push_row(vec![format!("task{t:02}"), format!("{b:.1}"), format!("{a:.1}")]);
    }
    let n = zoo.fts.len() as f64;
    table.push_row(vec![
        "mean".into(),
        format!("{:.1}", before_acc / n),
        format!("{:.1}", after_acc / n),
    ]);
    finish("figA", vec![table])
}

/// Fig. B: cosine-similarity confusion of 20 task vectors, FP32 vs 3-bit
/// (quantization pushes off-diagonal similarity toward zero).
pub fn figb_similarity(rt: &Runtime) -> Result<Vec<Table>> {
    let zoo = super::zoo(rt, &VIT_S, 20)?;
    let taus = zoo.task_vectors()?;
    let q3: Vec<Checkpoint> = taus
        .iter()
        .map(|tau| QuantizedCheckpoint::quantize(tau, 3)?.dequantize())
        .collect::<Result<_>>()?;
    let flat = |ck: &Checkpoint| -> Vec<f32> {
        ck.iter().flat_map(|(_, t)| t.data().iter().copied()).collect()
    };
    let cos_matrix = |cks: &[Checkpoint]| -> (Vec<Vec<f64>>, f64) {
        let flats: Vec<Vec<f32>> = cks.iter().map(flat).collect();
        let n = flats.len();
        let mut m = vec![vec![0.0f64; n]; n];
        let mut off = 0.0;
        for i in 0..n {
            for j in 0..n {
                m[i][j] = stats::cosine(&flats[i], &flats[j]);
                if i != j {
                    off += m[i][j].abs();
                }
            }
        }
        (m, off / (n * (n - 1)) as f64)
    };
    let (m_fp, off_fp) = cos_matrix(&taus);
    let (m_q, off_q) = cos_matrix(&q3);
    // Persist the matrices for plotting.
    let to_csv = |m: &[Vec<f64>]| {
        m.iter()
            .map(|row| {
                row.iter().map(|v| format!("{v:.4}")).collect::<Vec<_>>().join(",")
            })
            .collect::<Vec<_>>()
            .join("\n")
    };
    save_raw("figB_cosine_fp32.csv", &to_csv(&m_fp))?;
    save_raw("figB_cosine_tvq3.csv", &to_csv(&m_q))?;
    let mut table = Table::new(
        "figB",
        "Mean |off-diagonal| cosine similarity among 20 task vectors (paper Fig. B)",
        &["Representation", "mean |cos| off-diag"],
    );
    table.push_row(vec!["FP32".into(), format!("{off_fp:.4}")]);
    table.push_row(vec!["TVQ-INT3".into(), format!("{off_q:.4}")]);
    finish("figB", vec![table])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tab5_matches_paper_arithmetic() {
        // FP32 @ 20 tasks on 303.97M params ≈ 22.8 GB (paper Table 5).
        let rep = StorageReport::ideal(QuantScheme::Fp32, 20, VIT_L14_PARAMS);
        assert!((rep.gib() - 22.8).abs() < 0.5, "gib={}", rep.gib());
        // TVQ INT2 is ~1/16 of FP32.
        let rep2 = StorageReport::ideal(QuantScheme::Tvq(2), 20, VIT_L14_PARAMS);
        assert!((rep2.fraction_of_fp32() - 0.0625).abs() < 0.01);
    }
}
