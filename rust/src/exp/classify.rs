//! Classification experiment grids: Tables 1-2 (8 tasks, two ViT scales),
//! Fig. 6 (8/14/20-task scaling), Table 4 (target vs cross-task), and
//! Table A (RTVQ bit-sensitivity).

use anyhow::Result;

use super::report::{finish, Table};
use super::schemes::{classification_schemes, scheme_taus};
use crate::data::{VIT_M, VIT_S};
use crate::merge::{standard_methods, AdaMerging, MergedModel, Merger};
use crate::quant::QuantScheme;
use crate::runtime::Runtime;
use crate::train::Zoo;

/// Adaptation-set size for the AdaMerging entropy oracle (kept modest:
/// the oracle runs once per candidate coefficient vector).
const ADA_EVAL_N: usize = 128;

/// Per-task accuracies of a merged model on the zoo's suite.
pub fn eval_merged(rt: &Runtime, zoo: &Zoo, merged: &MergedModel) -> Result<Vec<f64>> {
    zoo.suite
        .tasks
        .iter()
        .enumerate()
        .map(|(t, task)| {
            crate::eval::classify_accuracy(rt, zoo.preset, merged.for_task(t), task)
        })
        .collect()
}

fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len().max(1) as f64
}

/// One (method, scheme) cell: average accuracy across tasks.
pub fn method_scheme_accuracy(
    rt: &Runtime,
    zoo: &Zoo,
    method: &dyn Merger,
    scheme: QuantScheme,
) -> Result<f64> {
    let st = scheme_taus(&zoo.pre, &zoo.fts, scheme)?;
    let merged = method.merge(&zoo.pre, &st.taus)?;
    Ok(mean(&eval_merged(rt, zoo, &merged)?))
}

/// "Individual" row: each reconstructed single-task model evaluated on its
/// own task (FP32 = the fine-tuned checkpoint itself).
pub fn individual_accuracy(rt: &Runtime, zoo: &Zoo, scheme: QuantScheme) -> Result<f64> {
    let st = scheme_taus(&zoo.pre, &zoo.fts, scheme)?;
    let mut accs = Vec::with_capacity(st.taus.len());
    for (t, tau) in st.taus.iter().enumerate() {
        let mut ck = zoo.pre.clone();
        ck.axpy(1.0, tau)?;
        accs.push(crate::eval::classify_accuracy(
            rt,
            zoo.preset,
            &ck,
            &zoo.suite.tasks[t],
        )?);
    }
    Ok(mean(&accs))
}

/// AdaMerging cell: test-time coefficient optimization against the mean
/// entropy over all tasks' unlabeled eval inputs.
pub fn adamerging_accuracy(
    rt: &Runtime,
    zoo: &Zoo,
    scheme: QuantScheme,
) -> Result<f64> {
    let st = scheme_taus(&zoo.pre, &zoo.fts, scheme)?;
    let ada = AdaMerging::default();
    let mut oracle = |ck: &crate::checkpoint::Checkpoint| -> Result<f64> {
        let mut acc = 0.0;
        for task in &zoo.suite.tasks {
            acc +=
                crate::eval::classify_entropy_norm(rt, zoo.preset, ck, task, ADA_EVAL_N)?;
        }
        Ok(acc / zoo.suite.tasks.len() as f64)
    };
    let (merged, _lams, _trace) = ada.optimize(&zoo.pre, &st.taus, &mut oracle)?;
    Ok(mean(&eval_merged(rt, zoo, &merged)?))
}

/// The full methods × schemes grid (the layout of Tables 1-2).
pub fn merge_table(
    rt: &Runtime,
    zoo: &Zoo,
    id: &str,
    title: &str,
    schemes: &[QuantScheme],
    with_adamerging: bool,
) -> Result<Table> {
    let mut cols: Vec<String> = vec!["Method".into()];
    cols.extend(schemes.iter().map(|s| s.label()));
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(id, title, &col_refs);

    // Individual row.
    {
        let mut row = vec!["Individual".to_string()];
        let mut baseline = f64::NAN;
        for (i, &scheme) in schemes.iter().enumerate() {
            let acc = individual_accuracy(rt, zoo, scheme)?;
            if i == 0 {
                baseline = acc;
                row.push(format!("{acc:.1}"));
            } else {
                row.push(Table::cell_with_delta(acc, baseline));
            }
            eprintln!("[exp:{id}] Individual {} -> {acc:.1}", scheme.label());
        }
        table.push_row(row);
    }

    // Task-vector merging methods.
    for method in standard_methods() {
        let mut row = vec![method.name().to_string()];
        let mut baseline = f64::NAN;
        for (i, &scheme) in schemes.iter().enumerate() {
            let acc = method_scheme_accuracy(rt, zoo, method.as_ref(), scheme)?;
            if i == 0 {
                baseline = acc;
                row.push(format!("{acc:.1}"));
            } else {
                row.push(Table::cell_with_delta(acc, baseline));
            }
            eprintln!("[exp:{id}] {} {} -> {acc:.1}", method.name(), scheme.label());
        }
        table.push_row(row);
    }

    // AdaMerging (test-time optimization; driven separately from the
    // Merger trait because it needs the entropy oracle).
    if with_adamerging {
        let mut row = vec!["AdaMerging".to_string()];
        let mut baseline = f64::NAN;
        for (i, &scheme) in schemes.iter().enumerate() {
            let acc = adamerging_accuracy(rt, zoo, scheme)?;
            if i == 0 {
                baseline = acc;
                row.push(format!("{acc:.1}"));
            } else {
                row.push(Table::cell_with_delta(acc, baseline));
            }
            eprintln!("[exp:{id}] AdaMerging {} -> {acc:.1}", scheme.label());
        }
        table.push_row(row);
    }
    Ok(table)
}

/// Table 1: merging 8 classification tasks, small ViT (ViT-B/32 analog).
pub fn tab1_vit_s(rt: &Runtime) -> Result<Vec<Table>> {
    let zoo = super::zoo(rt, &VIT_S, 8)?;
    let t = merge_table(
        rt,
        &zoo,
        "tab1",
        "Merging 8 classification tasks, vit_s (paper Table 1, ViT-B/32)",
        &classification_schemes(),
        true,
    )?;
    finish("tab1", vec![t])
}

/// Table 2: merging 8 classification tasks, larger ViT (ViT-L/14 analog).
pub fn tab2_vit_m(rt: &Runtime) -> Result<Vec<Table>> {
    let zoo = super::zoo(rt, &VIT_M, 8)?;
    let t = merge_table(
        rt,
        &zoo,
        "tab2",
        "Merging 8 classification tasks, vit_m (paper Table 2, ViT-L/14)",
        &classification_schemes(),
        true,
    )?;
    finish("tab2", vec![t])
}

/// Fig. 6 (+ Tables B/C): scaling to 8, 14 and 20 tasks.  One table per
/// task count; AdaMerging included (the paper sweeps the same methods).
pub fn fig6_task_scaling(rt: &Runtime) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    for &n in &[8usize, 14, 20] {
        let zoo = super::zoo(rt, &VIT_S, n)?;
        // RTVQ B3O2: the paper quotes 2.375 / 2.21 / 2.15 bits per task.
        let schemes = classification_schemes();
        let t = merge_table(
            rt,
            &zoo,
            "fig6",
            &format!(
                "Scaling to {n} tasks, vit_s (paper Fig. 6 / Tables B-C); RTVQ = {:.3} bits/task",
                QuantScheme::Rtvq(3, 2).effective_bits(n)
            ),
            &schemes,
            n == 8, // AdaMerging on the 8-task suite only (cost control)
        )?;
        tables.push(t);
    }
    finish("fig6", tables)
}

/// Table 4: target-task vs cross-task accuracy of *single-task* models
/// under each scheme (each task is the target once; the other tasks are
/// the cross tasks).
pub fn tab4_cross_task(rt: &Runtime) -> Result<Vec<Table>> {
    let zoo = super::zoo(rt, &VIT_S, 8)?;
    let schemes = [
        QuantScheme::Fp32,
        QuantScheme::Tvq(8),
        QuantScheme::Tvq(4),
        QuantScheme::Tvq(3),
        QuantScheme::Tvq(2),
        QuantScheme::Rtvq(3, 2),
    ];
    let mut cols: Vec<String> = vec!["Task".into()];
    cols.extend(schemes.iter().map(|s| s.label()));
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        "tab4",
        "Target vs cross-task accuracy, 8 tasks vit_s (paper Table 4)",
        &col_refs,
    );
    let mut target_row = vec!["Target".to_string()];
    let mut cross_row = vec!["Cross".to_string()];
    for &scheme in &schemes {
        let st = scheme_taus(&zoo.pre, &zoo.fts, scheme)?;
        let mut target_acc = Vec::new();
        let mut cross_acc = Vec::new();
        for (t, tau) in st.taus.iter().enumerate() {
            let mut ck = zoo.pre.clone();
            ck.axpy(1.0, tau)?;
            for (u, task) in zoo.suite.tasks.iter().enumerate() {
                let acc = crate::eval::classify_accuracy(rt, zoo.preset, &ck, task)?;
                if u == t {
                    target_acc.push(acc);
                } else {
                    cross_acc.push(acc);
                }
            }
        }
        eprintln!(
            "[exp:tab4] {}: target {:.1}, cross {:.1}",
            scheme.label(),
            mean(&target_acc),
            mean(&cross_acc)
        );
        target_row.push(format!("{:.1}", mean(&target_acc)));
        cross_row.push(format!("{:.1}", mean(&cross_acc)));
    }
    table.push_row(target_row);
    table.push_row(cross_row);
    finish("tab4", vec![table])
}

/// Table A: RTVQ sensitivity over base × offset bit-widths with task
/// arithmetic on the 8-task suite.
pub fn taba_sensitivity(rt: &Runtime) -> Result<Vec<Table>> {
    let zoo = super::zoo(rt, &VIT_S, 8)?;
    let bits = [2u8, 3, 4, 8];
    let mut cols: Vec<String> = vec!["Offset \\ Base".into()];
    cols.extend(bits.iter().map(|b| format!("INT{b}")));
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        "tabA",
        "RTVQ bit sensitivity (task arithmetic, 8 tasks; paper Table A)",
        &col_refs,
    );
    let ta = crate::merge::TaskArithmetic::default();
    for &bo in &bits {
        let mut row = vec![format!("INT{bo}")];
        for &bb in &bits {
            let acc =
                method_scheme_accuracy(rt, &zoo, &ta, QuantScheme::Rtvq(bb, bo))?;
            eprintln!("[exp:tabA] B{bb}O{bo} -> {acc:.1}");
            row.push(format!("{acc:.1}"));
        }
        table.push_row(row);
    }
    finish("tabA", vec![table])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_empty_is_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
    }
}
