//! Scheme semantics: reconstruct task vectors under each quantization
//! scheme — the one place FQ / TVQ / RTVQ are defined for experiments.
//!
//! Given the zoo (`pre`, `fts`), a [`QuantScheme`] yields the dequantized
//! task vectors tau_hat_t the merging methods consume:
//!
//! * `Fp32`    — tau_t = theta_ft^t - theta_pre (exact).
//! * `Fq(b)`   — dq(Q(theta_ft^t, b)) - theta_pre (Fig. 5a baseline: the
//!   *whole fine-tuned checkpoint* is quantized, so the wide weight range
//!   dominates the error).
//! * `Tvq(b)`  — dq(Q(tau_t, b)) (Fig. 5b, Section 4.2).
//! * `Rtvq(bb, bo)` — Algorithm 1 with error correction on (Fig. 5c).

use anyhow::Result;

use crate::checkpoint::Checkpoint;
use crate::quant::{QuantScheme, QuantizedCheckpoint, Rtvq};
use crate::util::exec::ExecCtx;

/// Dequantized task vectors for a scheme, plus exact storage accounting.
pub struct SchemeTaus {
    pub scheme: QuantScheme,
    pub taus: Vec<Checkpoint>,
    /// Exact bytes the quantized representation occupies (fp32: 4B/param).
    pub storage_bytes: usize,
}

/// Reconstruct task vectors for `scheme` from (pre, fts).
pub fn scheme_taus(
    pre: &Checkpoint,
    fts: &[Checkpoint],
    scheme: QuantScheme,
) -> Result<SchemeTaus> {
    let (taus, storage_bytes) = match scheme {
        QuantScheme::Fp32 => {
            let taus: Vec<Checkpoint> =
                fts.iter().map(|ft| ft.sub(pre)).collect::<Result<_>>()?;
            let bytes = fts.iter().map(|ft| ft.fp32_bytes()).sum();
            (taus, bytes)
        }
        QuantScheme::Fq(bits) => {
            let mut taus = Vec::with_capacity(fts.len());
            let mut bytes = 0usize;
            for ft in fts {
                let q = QuantizedCheckpoint::quantize(ft, bits)?;
                bytes += q.storage_bytes();
                taus.push(q.dequantize()?.sub(pre)?);
            }
            (taus, bytes)
        }
        QuantScheme::Tvq(bits) => {
            let mut taus = Vec::with_capacity(fts.len());
            let mut bytes = 0usize;
            for ft in fts {
                let tau = ft.sub(pre)?;
                let q = QuantizedCheckpoint::quantize(&tau, bits)?;
                bytes += q.storage_bytes();
                taus.push(q.dequantize()?);
            }
            (taus, bytes)
        }
        QuantScheme::Rtvq(bb, bo) => {
            let r = Rtvq::quantize(pre, fts, bb, bo, true, &ExecCtx::sequential())?;
            let bytes = r.storage_bytes();
            (r.dequantize_all()?, bytes)
        }
    };
    Ok(SchemeTaus { scheme, taus, storage_bytes })
}

/// The classification-table scheme lineup (Tables 1-2 columns):
/// FP32, FQ8, FQ4, TVQ 8/4/3/2, RTVQ B3O2.
pub fn classification_schemes() -> Vec<QuantScheme> {
    vec![
        QuantScheme::Fp32,
        QuantScheme::Fq(8),
        QuantScheme::Fq(4),
        QuantScheme::Tvq(8),
        QuantScheme::Tvq(4),
        QuantScheme::Tvq(3),
        QuantScheme::Tvq(2),
        QuantScheme::Rtvq(3, 2),
    ]
}

/// The dense-prediction lineup (Table 3 columns): FP32, TVQ4, TVQ2,
/// RTVQ B2O2 (the paper quantizes both base and offset to 2 bits there).
pub fn dense_schemes() -> Vec<QuantScheme> {
    vec![
        QuantScheme::Fp32,
        QuantScheme::Tvq(4),
        QuantScheme::Tvq(2),
        QuantScheme::Rtvq(2, 2),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn zoo(n: usize) -> (Checkpoint, Vec<Checkpoint>) {
        let mut rng = Rng::new(7);
        let mut pre = Checkpoint::new();
        pre.insert("w", Tensor::randn(&[64, 32], 0.3, &mut rng));
        pre.insert("b", Tensor::randn(&[32], 0.3, &mut rng));
        let fts = (0..n)
            .map(|_| {
                let mut ft = pre.clone();
                for (_, t) in ft.iter_mut() {
                    for v in t.data_mut() {
                        *v += rng.normal_f32(0.02);
                    }
                }
                ft
            })
            .collect();
        (pre, fts)
    }

    #[test]
    fn fp32_is_exact() {
        let (pre, fts) = zoo(3);
        let s = scheme_taus(&pre, &fts, QuantScheme::Fp32).unwrap();
        let tau0 = fts[0].sub(&pre).unwrap();
        assert_eq!(s.taus[0], tau0);
        assert_eq!(s.storage_bytes, 3 * pre.fp32_bytes());
    }

    #[test]
    fn tvq_error_much_smaller_than_fq_at_4bits() {
        // The paper's core observation (Fig. 4): task vectors have a far
        // narrower range than fine-tuned weights, so TVQ-INT4 error is
        // orders of magnitude below FQ-INT4 error.
        let (pre, fts) = zoo(4);
        let exact = scheme_taus(&pre, &fts, QuantScheme::Fp32).unwrap().taus;
        let fq = scheme_taus(&pre, &fts, QuantScheme::Fq(4)).unwrap().taus;
        let tvq = scheme_taus(&pre, &fts, QuantScheme::Tvq(4)).unwrap().taus;
        let err = |a: &[Checkpoint], b: &[Checkpoint]| -> f64 {
            a.iter().zip(b).map(|(x, y)| x.l2_dist(y).unwrap()).sum()
        };
        let e_fq = err(&exact, &fq);
        let e_tvq = err(&exact, &tvq);
        assert!(
            e_tvq * 5.0 < e_fq,
            "expected TVQ error well below FQ: tvq={e_tvq}, fq={e_fq}"
        );
    }

    #[test]
    fn storage_shrinks_with_bits() {
        let (pre, fts) = zoo(4);
        let s8 = scheme_taus(&pre, &fts, QuantScheme::Tvq(8)).unwrap().storage_bytes;
        let s2 = scheme_taus(&pre, &fts, QuantScheme::Tvq(2)).unwrap().storage_bytes;
        let fp = scheme_taus(&pre, &fts, QuantScheme::Fp32).unwrap().storage_bytes;
        assert!(s2 < s8 && s8 < fp);
        // INT2 is ~16x below fp32 up to per-tensor affine overhead.
        assert!((fp as f64 / s2 as f64) > 10.0);
    }

    #[test]
    fn rtvq_storage_between_tvq2_and_tvq3() {
        let (pre, fts) = zoo(8);
        let s2 = scheme_taus(&pre, &fts, QuantScheme::Tvq(2)).unwrap().storage_bytes;
        let s3 = scheme_taus(&pre, &fts, QuantScheme::Tvq(3)).unwrap().storage_bytes;
        let sr = scheme_taus(&pre, &fts, QuantScheme::Rtvq(3, 2)).unwrap().storage_bytes;
        assert!(s2 < sr && sr < s3, "s2={s2} sr={sr} s3={s3}");
    }

    #[test]
    fn lineups_contain_fp32_baseline() {
        assert_eq!(classification_schemes()[0], QuantScheme::Fp32);
        assert_eq!(dense_schemes()[0], QuantScheme::Fp32);
    }
}
