//! Table P: budget-planned mixed precision vs uniform schemes at equal
//! measured byte cost (the pack-planner companion to Table 5).
//!
//! The claim under test is the planner's reason to exist: at the **same
//! on-disk byte budget** as a uniform RTVQ-B3O2 registry (measured from
//! real files, index and all), a sensitivity-planned mixed-precision
//! registry reconstructs the task vectors with lower total error.  The
//! zoo is deliberately heterogeneous across layers — per-layer task-
//! vector scales spanning ~30x (what real fine-tuning produces, paper
//! Fig. 3) plus **localized** layers where each task touches only a
//! small task-specific subset of weights — the regime the sparse
//! (DARE / TALL) arms exploit.
//!
//! Since PR 3 the table also sweeps the planner down-budget with two
//! candidate sets — dense arms only (the PR-2 planner) vs the full set
//! with sparse arms — showing where the solver starts picking sparse
//! arms and what that buys at equal real file bytes.
//!
//! Runs without PJRT (like `tab5`): `tvq experiment tabP`, or in CI smoke
//! mode with `TVQ_SMOKE=1` (smaller zoo, same assertions-by-table).

use anyhow::Result;

use super::report::{finish, Table};
use crate::checkpoint::Checkpoint;
use crate::planner::{probe, solve, write_planned_registry, PlannerConfig};
use crate::quant::QuantScheme;
use crate::registry::{build_registry, DiskAccounting, Registry};
use crate::tensor::Tensor;
use crate::util::exec::ExecCtx;
use crate::util::rng::Rng;

/// True when `TVQ_SMOKE` is set: shrink the zoo so CI finishes fast.
fn smoke() -> bool {
    std::env::var_os("TVQ_SMOKE").is_some()
}

/// Heterogeneous synthetic zoo: common drift + per-task offsets with
/// per-layer scales spanning ~30x, plus localized layers where each task
/// perturbs only a small random subset of weights (no common drift) —
/// approximately-sparse deltas like real fine-tuning produces.  Mirrors
/// the regimes the planner's dense and sparse arms are built for; also
/// used by `tvq registry pack --synthetic`.
pub fn synthetic_planner_zoo(n_tasks: usize, seed: u64) -> (Checkpoint, Vec<Checkpoint>) {
    let mut rng = Rng::new(seed);
    let stds: &[f32] = if smoke() {
        &[0.002, 0.008, 0.032, 0.064]
    } else {
        &[0.002, 0.004, 0.008, 0.016, 0.032, 0.064]
    };
    let n_localized = if smoke() { 1 } else { 2 };
    let shape: &[usize] = if smoke() { &[48, 32] } else { &[96, 64] };
    let mut pre = Checkpoint::new();
    for (i, _) in stds.iter().enumerate() {
        pre.insert(&format!("blk{i:02}/w"), Tensor::randn(shape, 0.3, &mut rng));
    }
    for i in 0..n_localized {
        pre.insert(&format!("loc{i:02}/w"), Tensor::randn(shape, 0.3, &mut rng));
    }
    let mut drift = Checkpoint::new();
    for (i, &std) in stds.iter().enumerate() {
        drift.insert(&format!("blk{i:02}/w"), Tensor::randn(shape, std, &mut rng));
    }
    for i in 0..n_localized {
        // Localized layers share no drift: their deltas are per-task.
        drift.insert(&format!("loc{i:02}/w"), Tensor::zeros(shape));
    }
    let fts = (0..n_tasks)
        .map(|_| {
            let mut off = Checkpoint::new();
            for (i, &std) in stds.iter().enumerate() {
                off.insert(
                    &format!("blk{i:02}/w"),
                    Tensor::randn(shape, std * 0.4, &mut rng),
                );
            }
            // Localized layers: ~8% task-specific hot weights, the rest
            // untouched — tau is approximately sparse, no shared base.
            for i in 0..n_localized {
                let mut t = Tensor::zeros(shape);
                for v in t.data_mut() {
                    if rng.f32() < 0.08 {
                        *v = rng.normal_f32(0.08);
                    }
                }
                off.insert(&format!("loc{i:02}/w"), t);
            }
            pre.add(&drift).unwrap().add(&off).unwrap()
        })
        .collect();
    (pre, fts)
}

/// Sum over tasks of squared L2 reconstruction error, measured through
/// the registry's own serving path (`load_task_vector`).
fn registry_sse(reg: &Registry, pre: &Checkpoint, fts: &[Checkpoint]) -> Result<f64> {
    let mut sse = 0.0;
    for (t, ft) in fts.iter().enumerate() {
        let tau = ft.sub(pre)?;
        let d = tau.l2_dist(&reg.load_task_vector(t, &ExecCtx::sequential())?)?;
        sse += d * d;
    }
    Ok(sse)
}

/// Regenerate Table P.
pub fn tabp_planner() -> Result<Vec<Table>> {
    let n_tasks = if smoke() { 4 } else { 8 };
    let (pre, fts) = synthetic_planner_zoo(n_tasks, 0x7AB9);
    let dir = crate::util::repo_path("target/results/tabP_files");
    std::fs::remove_dir_all(&dir).ok();

    let mut table = Table::new(
        "tabP",
        "Planned mixed precision (dense-only vs +sparse arms) vs uniform \
         schemes: real file bytes and total squared reconstruction error \
         (lower is better)",
        &["Scheme", "file bytes", "% of B3O2 budget", "total SSE", "sparse arms"],
    );

    // Uniform baselines, measured from real files through the same
    // serving path the planner will be judged on.
    let mut budget = 0u64;
    let mut uniform_rows = Vec::new();
    for scheme in [
        QuantScheme::Tvq(2),
        QuantScheme::Tvq(3),
        QuantScheme::Tvq(4),
        QuantScheme::Rtvq(3, 2),
    ] {
        let path = dir.join(format!("{}.qtvc", scheme.label()));
        build_registry(&pre, &fts, scheme, &path)?;
        let reg = Registry::open(&path)?;
        let acc = DiskAccounting::measure(&reg)?;
        let sse = registry_sse(&reg, &pre, &fts)?;
        if scheme == QuantScheme::Rtvq(3, 2) {
            budget = acc.file_bytes;
        }
        uniform_rows.push((scheme.label(), acc.file_bytes, sse));
    }
    for (label, bytes, sse) in &uniform_rows {
        table.push_row(vec![
            label.clone(),
            bytes.to_string(),
            format!("{:.1}", 100.0 * *bytes as f64 / budget as f64),
            format!("{sse:.4e}"),
            "-".to_string(),
        ]);
    }

    // The planner sweep: dense-only candidates (the PR-2 set) vs the full
    // set with DARE / TALL sparse arms, at the B3O2 budget and below it.
    // Both plans at each step get exactly the same byte budget; every
    // plan is compiled to a real file and measured through the serving
    // path, so the SSE column is what a reader would actually get back.
    let full_profile = probe(&pre, &fts, &PlannerConfig::default())?;
    let dense_profile = probe(&pre, &fts, &PlannerConfig::dense_only())?;
    let mut last_full_plan = None;
    for (pct, num, den) in [(100u32, 1u64, 1u64), (70, 7, 10), (55, 11, 20)] {
        let step_budget = budget * num / den;
        for (tag, profile) in [("DENSE", &dense_profile), ("SPARSE", &full_profile)] {
            let plan = solve(profile, step_budget)?;
            let path = dir.join(format!("PLAN-{tag}-{pct}.qtvc"));
            let summary = write_planned_registry(&pre, &fts, &plan, &path)?;
            let reg = Registry::open(&path)?;
            let sse = registry_sse(&reg, &pre, &fts)?;
            let n_sparse =
                plan.assignments.iter().filter(|a| a.arm.is_sparse()).count();
            table.push_row(vec![
                format!("PLAN-{tag} @ {pct}%"),
                summary.file_bytes.to_string(),
                format!("{:.1}", 100.0 * summary.file_bytes as f64 / budget as f64),
                format!("{sse:.4e}"),
                format!("{n_sparse}/{}", plan.n_tensors()),
            ]);
            if tag == "SPARSE" {
                last_full_plan = Some((pct, plan));
            }
        }
    }

    // Where the tightest budget went: the per-layer allocation, arm
    // family named per tensor (the sparse arms should own the localized
    // layers).
    let (pct, plan) = last_full_plan.expect("sweep ran");
    let mut alloc = Table::new(
        "tabP",
        &format!(
            "Planner allocation at {pct}% of the B3O2 budget (full arm set): \
             per-layer arm family, byte share, probed error"
        ),
        &["Tensor", "arm", "bytes", "% of payload", "probed SSE"],
    );
    let total_cost: u64 = plan.assignments.iter().map(|a| a.cost_bytes).sum();
    for (tensor, a) in plan.tensors.iter().zip(&plan.assignments) {
        alloc.push_row(vec![
            tensor.name.clone(),
            a.arm.label(),
            a.cost_bytes.to_string(),
            format!("{:.1}", 100.0 * a.cost_bytes as f64 / total_cost as f64),
            format!("{:.4e}", a.error),
        ]);
    }
    finish("tabP", vec![table, alloc])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn localized_layers_have_sparse_taus() {
        let (pre, fts) = synthetic_planner_zoo(3, 2);
        let tau = fts[0].sub(&pre).unwrap();
        let t = tau.get("loc00/w").unwrap();
        let zeros = t.data().iter().filter(|&&v| v == 0.0).count();
        let frac = zeros as f64 / t.numel() as f64;
        assert!(
            frac > 0.8,
            "localized layer tau should be mostly zeros, got {frac:.2}"
        );
    }

    #[test]
    fn zoo_layers_are_heterogeneous() {
        let (pre, fts) = synthetic_planner_zoo(3, 1);
        let tau = fts[0].sub(&pre).unwrap();
        let norms: Vec<f64> = tau.iter().map(|(_, t)| t.l2_norm()).collect();
        let (min, max) = norms
            .iter()
            .fold((f64::INFINITY, 0.0f64), |(lo, hi), &n| (lo.min(n), hi.max(n)));
        assert!(
            max / min > 5.0,
            "layer scales too uniform for the experiment: {norms:?}"
        );
    }
}
