//! Dense-prediction experiments: Table 3 / Table D — merging the NYUv2
//! analog (segmentation, depth, normal estimation) under each scheme.

use anyhow::Result;

use super::report::{finish, Table};
use super::schemes::{dense_schemes, scheme_taus};
use crate::data::dense::DenseTaskKind;
use crate::merge::{dense_methods, MergedModel};
use crate::runtime::Runtime;
use crate::train::DenseZoo;

/// Evaluation batches per dense task (deterministic seeds).
const EVAL_BATCHES: usize = 4;

/// Headline metric per task kind (Table 3): mIoU (up), relative depth
/// error (down), mean angular error (down).
pub fn headline(scores: &crate::eval::DenseScores, kind: DenseTaskKind) -> f64 {
    match kind {
        DenseTaskKind::Seg => scores.miou,
        DenseTaskKind::Depth => scores.rel_err,
        DenseTaskKind::Normal => scores.mean_angle,
    }
}

/// Evaluate a merged model family on all three dense tasks.
pub fn eval_dense_merged(
    rt: &Runtime,
    zoo: &DenseZoo,
    merged: &MergedModel,
) -> Result<Vec<(DenseTaskKind, crate::eval::DenseScores)>> {
    zoo.fts
        .iter()
        .enumerate()
        .map(|(t, (kind, _))| {
            let scores = crate::eval::dense_eval(
                rt,
                &zoo.preset,
                merged.for_task(t),
                *kind,
                zoo.head(*kind),
                EVAL_BATCHES,
            )?;
            Ok((*kind, scores))
        })
        .collect()
}

/// Table 3: one table per dense task (seg / depth / normal), rows are
/// methods (plus Individual), columns the dense scheme lineup.
pub fn tab3_dense(rt: &Runtime) -> Result<Vec<Table>> {
    let zoo = DenseZoo::build_or_load(rt, &super::default_train_config())?;
    let schemes = dense_schemes();

    // metric cache: per (method row, scheme) -> per-kind headline.
    let mut rows: Vec<(String, Vec<Vec<f64>>)> = Vec::new(); // (name, [scheme][kind])

    // Individual: reconstructed single-task models on their own tasks.
    {
        let mut per_scheme = Vec::new();
        for &scheme in &schemes {
            let st = scheme_taus(&zoo.pre, &taus_src(&zoo), scheme)?;
            let mut per_kind = Vec::new();
            for (t, (kind, _)) in zoo.fts.iter().enumerate() {
                let mut ck = zoo.pre.clone();
                ck.axpy(1.0, &st.taus[t])?;
                let scores = crate::eval::dense_eval(
                    rt,
                    &zoo.preset,
                    &ck,
                    *kind,
                    zoo.head(*kind),
                    EVAL_BATCHES,
                )?;
                per_kind.push(headline(&scores, *kind));
            }
            eprintln!("[exp:tab3] Individual {} -> {:?}", scheme.label(), per_kind);
            per_scheme.push(per_kind);
        }
        rows.push(("Individual".into(), per_scheme));
    }

    for method in dense_methods() {
        let mut per_scheme = Vec::new();
        for &scheme in &schemes {
            let st = scheme_taus(&zoo.pre, &taus_src(&zoo), scheme)?;
            let merged = method.merge(&zoo.pre, &st.taus)?;
            let evals = eval_dense_merged(rt, &zoo, &merged)?;
            let per_kind: Vec<f64> =
                evals.iter().map(|(k, s)| headline(s, *k)).collect();
            eprintln!(
                "[exp:tab3] {} {} -> {:?}",
                method.name(),
                scheme.label(),
                per_kind
            );
            per_scheme.push(per_kind);
        }
        rows.push((method.name().to_string(), per_scheme));
    }

    // Emit one table per task kind.
    let kinds = DenseTaskKind::all();
    let mut tables = Vec::new();
    for (ki, kind) in kinds.iter().enumerate() {
        let metric = match kind {
            DenseTaskKind::Seg => "mIoU ↑",
            DenseTaskKind::Depth => "Rel Err ↓",
            DenseTaskKind::Normal => "Mean angular err ↓",
        };
        let mut cols: Vec<String> = vec!["Method".into()];
        cols.extend(schemes.iter().map(|s| s.label()));
        let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
        let mut table = Table::new(
            "tab3",
            &format!("Dense prediction — {} ({metric}; paper Table 3)", kind.name()),
            &col_refs,
        );
        for (name, per_scheme) in &rows {
            let mut row = vec![name.clone()];
            let baseline = per_scheme[0][ki];
            for (si, per_kind) in per_scheme.iter().enumerate() {
                if si == 0 {
                    row.push(format!("{:.1}", per_kind[ki]));
                } else {
                    row.push(Table::cell_with_delta(per_kind[ki], baseline));
                }
            }
            table.push_row(row);
        }
        tables.push(table);
    }
    finish("tab3", tables)
}

/// The dense zoo's fine-tuned checkpoints in task order.
fn taus_src(zoo: &DenseZoo) -> Vec<crate::checkpoint::Checkpoint> {
    zoo.fts.iter().map(|(_, ck)| ck.clone()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_picks_the_right_metric() {
        let s = crate::eval::DenseScores {
            miou: 52.0,
            pix_acc: 74.0,
            abs_err: 41.0,
            rel_err: 17.0,
            mean_angle: 24.0,
        };
        assert_eq!(headline(&s, DenseTaskKind::Seg), 52.0);
        assert_eq!(headline(&s, DenseTaskKind::Depth), 17.0);
        assert_eq!(headline(&s, DenseTaskKind::Normal), 24.0);
    }
}
