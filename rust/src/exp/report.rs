//! Result tables: construction, markdown rendering, persistence.
//!
//! Every experiment produces one or more [`Table`]s.  A table renders to
//! GitHub-flavoured markdown (the same layout the paper's tables use,
//! including the `value (delta)` convention against an FP32 baseline
//! column) and persists under `target/results/<id>.md` so EXPERIMENTS.md
//! can reference regenerated numbers.

use std::fmt::Write as _;
use std::path::PathBuf;

use anyhow::Result;

/// A rendered experiment table.
#[derive(Clone, Debug)]
pub struct Table {
    /// Identifier (`tab1`, `fig4`, ...) — also the results file stem.
    pub id: String,
    /// Human title printed above the table.
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(id: &str, title: &str, columns: &[&str]) -> Self {
        Self {
            id: id.to_string(),
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push_row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// `value (delta)` cell formatting used throughout the paper's tables.
    pub fn cell_with_delta(value: f64, baseline: f64) -> String {
        format!("{:.1} ({:+.1})", value, value - baseline)
    }

    /// Render as GitHub-flavoured markdown.
    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "### {} — {}\n", self.id, self.title);
        let _ = writeln!(s, "| {} |", self.columns.join(" | "));
        let _ = writeln!(
            s,
            "|{}|",
            self.columns.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            let _ = writeln!(s, "| {} |", row.join(" | "));
        }
        s
    }

    /// Print to stdout.
    pub fn print(&self) {
        println!("\n{}", self.to_markdown());
    }

    /// Persist under `target/results/<id>.md` (several tables with the
    /// same id append into one file via [`save_all`]).
    pub fn save(&self) -> Result<PathBuf> {
        save_all(&self.id, std::slice::from_ref(self))
    }
}

/// Directory where regenerated experiment tables are written.
pub fn results_dir() -> PathBuf {
    crate::util::repo_path("target/results")
}

/// Write all tables of one experiment to `target/results/<id>.md`.
pub fn save_all(id: &str, tables: &[Table]) -> Result<PathBuf> {
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{id}.md"));
    let mut out = String::new();
    for t in tables {
        out.push_str(&t.to_markdown());
        out.push('\n');
    }
    std::fs::write(&path, out)?;
    Ok(path)
}

/// Print + persist a finished experiment; returns the tables unchanged
/// (the standard tail of every experiment entrypoint).
pub fn finish(id: &str, tables: Vec<Table>) -> Result<Vec<Table>> {
    for t in &tables {
        t.print();
    }
    let path = save_all(id, &tables)?;
    eprintln!("[exp] {id}: results saved to {}", path.display());
    Ok(tables)
}

/// Write a raw text artifact (CSV grids, histograms) next to the tables.
pub fn save_raw(name: &str, contents: &str) -> Result<PathBuf> {
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(name);
    std::fs::write(&path, contents)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_layout() {
        let mut t = Table::new("tx", "demo", &["Method", "FP32", "INT3"]);
        t.push_row(vec!["TA".into(), "69.2".into(), Table::cell_with_delta(71.2, 69.2)]);
        let md = t.to_markdown();
        assert!(md.contains("### tx — demo"));
        assert!(md.contains("| Method | FP32 | INT3 |"));
        assert!(md.contains("| TA | 69.2 | 71.2 (+2.0) |"));
    }

    #[test]
    fn delta_formatting_signs() {
        assert_eq!(Table::cell_with_delta(68.1, 69.2), "68.1 (-1.1)");
        assert_eq!(Table::cell_with_delta(69.2, 69.2), "69.2 (+0.0)");
    }

    #[test]
    fn save_roundtrip() {
        let mut t = Table::new("test_report_roundtrip", "x", &["a"]);
        t.push_row(vec!["1".into()]);
        let p = t.save().unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert!(text.contains("| 1 |"));
    }
}
