//! Elementwise and reduction operations on [`Tensor`].

use anyhow::{bail, Result};

use super::Tensor;

impl Tensor {
    fn check_same_shape(&self, other: &Tensor) -> Result<()> {
        if self.shape() != other.shape() {
            bail!(
                "shape mismatch: {:?} vs {:?}",
                self.shape(),
                other.shape()
            );
        }
        Ok(())
    }

    /// Elementwise `self + other`.
    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        self.check_same_shape(other)?;
        let data = self
            .data()
            .iter()
            .zip(other.data())
            .map(|(a, b)| a + b)
            .collect();
        Tensor::new(self.shape().to_vec(), data)
    }

    /// Elementwise `self - other`.
    pub fn sub(&self, other: &Tensor) -> Result<Tensor> {
        self.check_same_shape(other)?;
        let data = self
            .data()
            .iter()
            .zip(other.data())
            .map(|(a, b)| a - b)
            .collect();
        Tensor::new(self.shape().to_vec(), data)
    }

    /// Elementwise `self * other` (Hadamard).
    pub fn mul(&self, other: &Tensor) -> Result<Tensor> {
        self.check_same_shape(other)?;
        let data = self
            .data()
            .iter()
            .zip(other.data())
            .map(|(a, b)| a * b)
            .collect();
        Tensor::new(self.shape().to_vec(), data)
    }

    /// `self * s` (scalar).
    pub fn scale(&self, s: f32) -> Tensor {
        let data = self.data().iter().map(|a| a * s).collect();
        Tensor::new(self.shape().to_vec(), data).unwrap()
    }

    /// In-place `self += alpha * other` (the merge hot path primitive).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) -> Result<()> {
        self.check_same_shape(other)?;
        for (a, b) in self.data_mut().iter_mut().zip(other.data()) {
            *a += alpha * b;
        }
        Ok(())
    }

    /// Sum of all elements (f64 accumulator).
    pub fn sum(&self) -> f64 {
        self.data().iter().map(|&x| x as f64).sum()
    }

    /// Mean of all elements.
    pub fn mean(&self) -> f64 {
        if self.numel() == 0 {
            0.0
        } else {
            self.sum() / self.numel() as f64
        }
    }

    /// (min, max) over all elements.
    pub fn min_max(&self) -> (f32, f32) {
        crate::util::stats::min_max(self.data())
    }

    /// L2 norm.
    pub fn l2_norm(&self) -> f64 {
        crate::util::stats::l2_norm(self.data())
    }

    /// L2 distance to another tensor.
    pub fn l2_dist(&self, other: &Tensor) -> Result<f64> {
        self.check_same_shape(other)?;
        Ok(crate::util::stats::l2_dist(self.data(), other.data()))
    }

    /// Fraction of exactly-zero elements.
    pub fn sparsity(&self) -> f64 {
        if self.numel() == 0 {
            return 0.0;
        }
        let zeros = self.data().iter().filter(|&&x| x == 0.0).count();
        zeros as f64 / self.numel() as f64
    }

    /// Magnitude threshold below which `frac` of |values| fall
    /// (used by Ties trimming / Breadcrumbs filtering).
    pub fn abs_quantile(&self, frac: f64) -> f32 {
        if self.numel() == 0 {
            return 0.0;
        }
        let mut mags: Vec<f32> = self.data().iter().map(|x| x.abs()).collect();
        mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((frac * (mags.len() - 1) as f64).round() as usize).min(mags.len() - 1);
        mags[idx]
    }

    /// Apply a function elementwise, returning a new tensor.
    pub fn map<F: Fn(f32) -> f32>(&self, f: F) -> Tensor {
        let data = self.data().iter().map(|&x| f(x)).collect();
        Tensor::new(self.shape().to_vec(), data).unwrap()
    }

    /// Binary zip-map.
    pub fn zip<F: Fn(f32, f32) -> f32>(&self, other: &Tensor, f: F) -> Result<Tensor> {
        self.check_same_shape(other)?;
        let data = self
            .data()
            .iter()
            .zip(other.data())
            .map(|(&a, &b)| f(a, b))
            .collect();
        Tensor::new(self.shape().to_vec(), data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: &[f32]) -> Tensor {
        Tensor::from_vec(v.to_vec())
    }

    #[test]
    fn add_sub_mul_scale() {
        let a = t(&[1.0, 2.0]);
        let b = t(&[3.0, -1.0]);
        assert_eq!(a.add(&b).unwrap().data(), &[4.0, 1.0]);
        assert_eq!(a.sub(&b).unwrap().data(), &[-2.0, 3.0]);
        assert_eq!(a.mul(&b).unwrap().data(), &[3.0, -2.0]);
        assert_eq!(a.scale(2.0).data(), &[2.0, 4.0]);
    }

    #[test]
    fn shape_mismatch_errors() {
        let a = t(&[1.0, 2.0]);
        let b = t(&[1.0]);
        assert!(a.add(&b).is_err());
        assert!(a.sub(&b).is_err());
        assert!(a.l2_dist(&b).is_err());
    }

    #[test]
    fn axpy_accumulates() {
        let mut a = t(&[1.0, 1.0]);
        a.axpy(0.5, &t(&[2.0, 4.0])).unwrap();
        assert_eq!(a.data(), &[2.0, 3.0]);
    }

    #[test]
    fn reductions() {
        let a = t(&[1.0, -2.0, 3.0]);
        assert_eq!(a.sum(), 2.0);
        assert_eq!(a.min_max(), (-2.0, 3.0));
        assert!((a.mean() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn sparsity_counts_zeros() {
        let a = t(&[0.0, 1.0, 0.0, 2.0]);
        assert_eq!(a.sparsity(), 0.5);
    }

    #[test]
    fn abs_quantile_monotone() {
        let a = t(&[-4.0, 1.0, -2.0, 3.0]);
        assert_eq!(a.abs_quantile(0.0), 1.0);
        assert_eq!(a.abs_quantile(1.0), 4.0);
        let q50 = a.abs_quantile(0.5);
        assert!(q50 >= 1.0 && q50 <= 4.0);
    }

    #[test]
    fn map_zip() {
        let a = t(&[1.0, -2.0]);
        assert_eq!(a.map(|x| x.abs()).data(), &[1.0, 2.0]);
        let b = t(&[3.0, 5.0]);
        assert_eq!(a.zip(&b, |x, y| x + y).unwrap().data(), &[4.0, 3.0]);
    }
}
