//! Dense f32 tensor substrate.
//!
//! The checkpoint/quantization/merging stack operates on named f32 tensors;
//! this module provides the shaped container plus the (deliberately small)
//! set of operations the system needs.  Heavy model compute never happens
//! here — that is the PJRT runtime's job — so the focus is correctness and
//! predictable performance of elementwise/checkpoint-scale math.

mod ops;

use anyhow::{bail, Result};

/// A dense, row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Create from shape + data; validates element count.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!(
                "shape {:?} implies {} elements, got {}",
                shape,
                n,
                data.len()
            );
        }
        Ok(Self { shape, data })
    }

    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// All-`v` tensor.
    pub fn full(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![v; n] }
    }

    /// 1-D tensor from a vec.
    pub fn from_vec(data: Vec<f32>) -> Self {
        Self { shape: vec![data.len()], data }
    }

    /// Random-normal tensor (mean 0, given std).
    pub fn randn(shape: &[usize], std: f32, rng: &mut crate::util::rng::Rng) -> Self {
        let mut t = Self::zeros(shape);
        rng.fill_normal(&mut t.data, std);
        t
    }

    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reshape (same element count).
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {} elements to {:?}", self.data.len(), shape);
        }
        self.shape = shape;
        Ok(self)
    }

    /// 2-D indexing helper (row-major).
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn new_validates_shape() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn zeros_full_from_vec() {
        assert_eq!(Tensor::zeros(&[2, 2]).data(), &[0.0; 4]);
        assert_eq!(Tensor::full(&[3], 2.5).data(), &[2.5; 3]);
        let t = Tensor::from_vec(vec![1.0, 2.0]);
        assert_eq!(t.shape(), &[2]);
    }

    #[test]
    fn reshape_checks_count() {
        let t = Tensor::zeros(&[4]);
        assert!(t.clone().reshape(vec![2, 2]).is_ok());
        assert!(t.reshape(vec![3]).is_err());
    }

    #[test]
    fn randn_has_reasonable_std() {
        let mut rng = Rng::new(0);
        let t = Tensor::randn(&[10_000], 0.1, &mut rng);
        let std = crate::util::stats::std_dev(
            &t.data().iter().map(|&x| x as f64).collect::<Vec<_>>(),
        );
        assert!((std - 0.1).abs() < 0.01, "std={std}");
    }

    #[test]
    fn at2_row_major() {
        let t = Tensor::new(vec![2, 3], vec![0., 1., 2., 3., 4., 5.]).unwrap();
        assert_eq!(t.at2(0, 2), 2.0);
        assert_eq!(t.at2(1, 0), 3.0);
    }
}
