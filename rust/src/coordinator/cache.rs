//! Merged-model cache keyed by (merge method, quantization scheme).
//!
//! A deployment typically keeps several merged variants warm (e.g. task
//! arithmetic at TVQ-INT3 next to EMR at RTVQ-B3O2) while sharing one
//! pre-trained trunk and the packed task-vector payloads.  The cache
//! builds variants on first request and reports exactly how much memory
//! each one holds.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::merge::MergedModel;

/// Cache key: (merge method name, scheme label).
pub type VariantKey = (String, String);

/// Thread-safe build-on-miss cache of merged model variants.
#[derive(Default)]
pub struct ModelCache {
    inner: Mutex<HashMap<VariantKey, Arc<MergedModel>>>,
}

impl ModelCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get the cached variant, building it with `build` on a miss.
    /// Concurrent misses on the same key may both build; the first insert
    /// wins (builds are deterministic, so both results are identical).
    pub fn get_or_build<F>(&self, method: &str, scheme: &str, build: F) -> Result<Arc<MergedModel>>
    where
        F: FnOnce() -> Result<MergedModel>,
    {
        let key = (method.to_string(), scheme.to_string());
        if let Some(m) = self.inner.lock().unwrap().get(&key) {
            return Ok(m.clone());
        }
        let built = Arc::new(build()?);
        let mut map = self.inner.lock().unwrap();
        Ok(map.entry(key).or_insert(built).clone())
    }

    pub fn contains(&self, method: &str, scheme: &str) -> bool {
        self.inner
            .lock()
            .unwrap()
            .contains_key(&(method.to_string(), scheme.to_string()))
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Evict one variant; returns whether it was present.
    pub fn evict(&self, method: &str, scheme: &str) -> bool {
        self.inner
            .lock()
            .unwrap()
            .remove(&(method.to_string(), scheme.to_string()))
            .is_some()
    }

    /// Resident fp32 bytes across all cached variants.
    pub fn resident_bytes(&self) -> usize {
        self.inner
            .lock()
            .unwrap()
            .values()
            .map(|m| match m.as_ref() {
                MergedModel::Shared(ck) => ck.fp32_bytes(),
                MergedModel::PerTask(cks) => cks.iter().map(|c| c.fp32_bytes()).sum(),
            })
            .sum()
    }

    /// Keys currently resident (sorted for deterministic output).
    pub fn keys(&self) -> Vec<VariantKey> {
        let mut keys: Vec<VariantKey> =
            self.inner.lock().unwrap().keys().cloned().collect();
        keys.sort();
        keys
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::Checkpoint;
    use crate::tensor::Tensor;

    fn model() -> MergedModel {
        let mut ck = Checkpoint::new();
        ck.insert("w", Tensor::zeros(&[4, 4]));
        MergedModel::Shared(ck)
    }

    #[test]
    fn builds_once_then_hits() {
        let cache = ModelCache::new();
        let mut builds = 0;
        for _ in 0..3 {
            let m = cache
                .get_or_build("ta", "TVQ-INT3", || {
                    builds += 1;
                    Ok(model())
                })
                .unwrap();
            assert_eq!(m.n_variants(), 1);
        }
        assert_eq!(builds, 1);
        assert_eq!(cache.len(), 1);
        assert!(cache.contains("ta", "TVQ-INT3"));
    }

    #[test]
    fn build_failure_propagates_and_caches_nothing() {
        let cache = ModelCache::new();
        let r = cache.get_or_build("ta", "x", || anyhow::bail!("boom"));
        assert!(r.is_err());
        assert!(cache.is_empty());
    }

    #[test]
    fn evict_and_resident_bytes() {
        let cache = ModelCache::new();
        cache.get_or_build("ta", "FP32", || Ok(model())).unwrap();
        assert_eq!(cache.resident_bytes(), 16 * 4);
        assert!(cache.evict("ta", "FP32"));
        assert!(!cache.evict("ta", "FP32"));
        assert_eq!(cache.resident_bytes(), 0);
    }

    #[test]
    fn concurrent_access_is_safe() {
        let cache = std::sync::Arc::new(ModelCache::new());
        let mut handles = Vec::new();
        for i in 0..8 {
            let c = cache.clone();
            handles.push(std::thread::spawn(move || {
                let scheme = format!("s{}", i % 2);
                c.get_or_build("ta", &scheme, || Ok(model())).unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(cache.len(), 2);
    }
}
