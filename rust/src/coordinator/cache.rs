//! Merged-model cache keyed by (merge method, quantization scheme).
//!
//! A deployment typically keeps several merged variants warm (e.g. task
//! arithmetic at TVQ-INT3 next to EMR at RTVQ-B3O2) while sharing one
//! pre-trained trunk and the packed task-vector payloads.  The cache
//! builds variants on first request — **once** per key even under
//! concurrent misses (single-flight in-flight guard) — and reports
//! exactly how much memory each one holds.
//!
//! Variants can be built from any
//! [`TaskVectorSource`](crate::registry::TaskVectorSource); with the
//! packed-registry backend the build reads only the quantized sections it
//! needs, so a cold serving node goes registry-file → merged variant
//! without ever materializing the f32 zoo
//! ([`get_or_build_merged`](ModelCache::get_or_build_merged)).

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

use anyhow::Result;

use crate::checkpoint::Checkpoint;
use crate::merge::{MergedModel, Merger};
use crate::registry::{merge_from_source, TaskVectorSource};

/// Cache key: (merge method name, scheme label).
pub type VariantKey = (String, String);

/// Single-flight ticket: waiters block on the condvar until the leader
/// flips the flag.
type Ticket = Arc<(Mutex<bool>, Condvar)>;

/// Thread-safe build-on-miss cache of merged model variants.
#[derive(Default)]
pub struct ModelCache {
    inner: Mutex<HashMap<VariantKey, Arc<MergedModel>>>,
    inflight: Mutex<HashMap<VariantKey, Ticket>>,
}

/// Clears the in-flight ticket and wakes waiters when the leader exits —
/// including by error return or panic, so waiters never hang.
struct TicketGuard<'a> {
    cache: &'a ModelCache,
    key: VariantKey,
}

impl Drop for TicketGuard<'_> {
    fn drop(&mut self) {
        let ticket = self.cache.inflight.lock().unwrap().remove(&self.key);
        if let Some(t) = ticket {
            let (done, cv) = &*t;
            *done.lock().unwrap() = true;
            cv.notify_all();
        }
    }
}

impl ModelCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get the cached variant, building it with `build` on a miss.
    ///
    /// Concurrent misses on the same key build **once**: the first caller
    /// becomes the leader, everyone else blocks until the leader
    /// publishes (or fails — then one waiter takes over and rebuilds).
    /// Builds run outside all cache locks, so slow builds of different
    /// keys proceed in parallel.
    pub fn get_or_build<F>(&self, method: &str, scheme: &str, build: F) -> Result<Arc<MergedModel>>
    where
        F: FnOnce() -> Result<MergedModel>,
    {
        let key = (method.to_string(), scheme.to_string());
        let mut build = Some(build);
        loop {
            if let Some(m) = self.inner.lock().unwrap().get(&key) {
                return Ok(m.clone());
            }
            // Miss: become the single-flight leader or wait for one.
            let wait_on: Option<Ticket> = {
                let mut inflight = self.inflight.lock().unwrap();
                // Re-check the cache under the in-flight lock: a leader
                // publishes *before* clearing its ticket, so no ticket +
                // a cache hit here means the work already finished.
                if let Some(m) = self.inner.lock().unwrap().get(&key) {
                    return Ok(m.clone());
                }
                let existing = inflight.get(&key).cloned();
                if existing.is_none() {
                    inflight.insert(
                        key.clone(),
                        Arc::new((Mutex::new(false), Condvar::new())),
                    );
                }
                existing
            };
            match wait_on {
                Some(ticket) => {
                    let (done, cv) = &*ticket;
                    let mut done = done.lock().unwrap();
                    while !*done {
                        done = cv.wait(done).unwrap();
                    }
                    // Re-loop: cache hit if the leader succeeded; if it
                    // failed, this thread may become the next leader.
                }
                None => {
                    let _guard = TicketGuard { cache: self, key: key.clone() };
                    let built = (build.take().expect("a caller leads at most once"))()?;
                    let arc = Arc::new(built);
                    self.inner.lock().unwrap().insert(key, arc.clone());
                    return Ok(arc);
                }
            }
        }
    }

    /// Build (or fetch) the variant for `merger` over `source`'s task
    /// vectors, keyed by (method name, source identity).  The identity
    /// ([`TaskVectorSource::source_id`]) qualifies the scheme label with
    /// the backing artifact (registry path), so two zoos packed at the
    /// same scheme never share a cached variant.  With a
    /// [`PackedRegistrySource`](crate::registry::PackedRegistrySource)
    /// this materializes a merged model straight from packed payloads.
    pub fn get_or_build_merged(
        &self,
        merger: &dyn Merger,
        pre: &Checkpoint,
        source: &dyn TaskVectorSource,
    ) -> Result<Arc<MergedModel>> {
        self.get_or_build(merger.name(), &source.source_id(), || {
            merge_from_source(merger, pre, source, None)
        })
    }

    pub fn contains(&self, method: &str, scheme: &str) -> bool {
        self.inner
            .lock()
            .unwrap()
            .contains_key(&(method.to_string(), scheme.to_string()))
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Evict one variant; returns whether it was present.
    pub fn evict(&self, method: &str, scheme: &str) -> bool {
        self.inner
            .lock()
            .unwrap()
            .remove(&(method.to_string(), scheme.to_string()))
            .is_some()
    }

    /// Resident fp32 bytes across all cached variants.
    pub fn resident_bytes(&self) -> usize {
        self.inner
            .lock()
            .unwrap()
            .values()
            .map(|m| match m.as_ref() {
                MergedModel::Shared(ck) => ck.fp32_bytes(),
                MergedModel::PerTask(cks) => cks.iter().map(|c| c.fp32_bytes()).sum(),
            })
            .sum()
    }

    /// Keys currently resident (sorted for deterministic output).
    pub fn keys(&self) -> Vec<VariantKey> {
        let mut keys: Vec<VariantKey> =
            self.inner.lock().unwrap().keys().cloned().collect();
        keys.sort();
        keys
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::Checkpoint;
    use crate::tensor::Tensor;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    fn model() -> MergedModel {
        let mut ck = Checkpoint::new();
        ck.insert("w", Tensor::zeros(&[4, 4]));
        MergedModel::Shared(ck)
    }

    #[test]
    fn builds_once_then_hits() {
        let cache = ModelCache::new();
        let mut builds = 0;
        for _ in 0..3 {
            let m = cache
                .get_or_build("ta", "TVQ-INT3", || {
                    builds += 1;
                    Ok(model())
                })
                .unwrap();
            assert_eq!(m.n_variants(), 1);
        }
        assert_eq!(builds, 1);
        assert_eq!(cache.len(), 1);
        assert!(cache.contains("ta", "TVQ-INT3"));
    }

    #[test]
    fn build_failure_propagates_and_caches_nothing() {
        let cache = ModelCache::new();
        let r = cache.get_or_build("ta", "x", || anyhow::bail!("boom"));
        assert!(r.is_err());
        assert!(cache.is_empty());
        // The failed build must not leave a stuck in-flight ticket.
        let ok = cache.get_or_build("ta", "x", || Ok(model()));
        assert!(ok.is_ok());
    }

    #[test]
    fn evict_and_resident_bytes() {
        let cache = ModelCache::new();
        cache.get_or_build("ta", "FP32", || Ok(model())).unwrap();
        assert_eq!(cache.resident_bytes(), 16 * 4);
        assert!(cache.evict("ta", "FP32"));
        assert!(!cache.evict("ta", "FP32"));
        assert_eq!(cache.resident_bytes(), 0);
    }

    #[test]
    fn concurrent_access_is_safe() {
        let cache = std::sync::Arc::new(ModelCache::new());
        let mut handles = Vec::new();
        for i in 0..8 {
            let c = cache.clone();
            handles.push(std::thread::spawn(move || {
                let scheme = format!("s{}", i % 2);
                c.get_or_build("ta", &scheme, || Ok(model())).unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn concurrent_misses_build_exactly_once() {
        // The duplicate-build race: N threads miss the same key at once;
        // the slow build must run exactly once.
        let cache = Arc::new(ModelCache::new());
        let builds = Arc::new(AtomicUsize::new(0));
        let barrier = Arc::new(std::sync::Barrier::new(8));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let c = cache.clone();
            let b = builds.clone();
            let bar = barrier.clone();
            handles.push(std::thread::spawn(move || {
                bar.wait();
                let m = c
                    .get_or_build("emr", "RTVQ-B3O2", || {
                        b.fetch_add(1, Ordering::SeqCst);
                        std::thread::sleep(Duration::from_millis(40));
                        Ok(model())
                    })
                    .unwrap();
                assert_eq!(m.n_variants(), 1);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(builds.load(Ordering::SeqCst), 1, "concurrent misses double-built");
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn failed_leader_hands_off_to_a_waiter() {
        let cache = Arc::new(ModelCache::new());
        let attempts = Arc::new(AtomicUsize::new(0));
        let barrier = Arc::new(std::sync::Barrier::new(4));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = cache.clone();
            let a = attempts.clone();
            let bar = barrier.clone();
            handles.push(std::thread::spawn(move || {
                bar.wait();
                c.get_or_build("ta", "flaky", || {
                    let n = a.fetch_add(1, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(20));
                    if n == 0 {
                        anyhow::bail!("first build fails")
                    }
                    Ok(model())
                })
                .is_ok()
            }));
        }
        let oks = handles
            .into_iter()
            .map(|h| h.join().unwrap())
            .filter(|&ok| ok)
            .count();
        // Exactly the first leader fails; exactly one waiter rebuilds.
        assert_eq!(oks, 3);
        assert_eq!(attempts.load(Ordering::SeqCst), 2);
        assert!(cache.contains("ta", "flaky"));
    }
}
