//! Merged-model cache keyed by (merge method, quantization scheme).
//!
//! A deployment typically keeps several merged variants warm (e.g. task
//! arithmetic at TVQ-INT3 next to EMR at RTVQ-B3O2) while sharing one
//! pre-trained trunk and the packed task-vector payloads.  The cache
//! builds variants on first request — **once** per key even under
//! concurrent misses (single-flight in-flight guard) — and reports
//! exactly how much memory each one holds.
//!
//! # Capacity bound + LRU eviction
//!
//! [`ModelCache::with_byte_cap`] bounds the resident fp32 bytes: every
//! publish evicts least-recently-used variants (hits and publishes both
//! refresh recency) until the cap holds.  Single-flight builds **in
//! progress count against the cap** through their caller-supplied size
//! estimate ([`get_or_build_sized`](ModelCache::get_or_build_sized));
//! a publish therefore leaves headroom for concurrent leaders instead of
//! filling the cap and forcing them to evict what was just built.  A
//! single variant larger than the whole cap is still cached (refusing to
//! serve it would be worse) — it simply becomes the next eviction victim.
//!
//! Variants can be built from any
//! [`TaskVectorSource`](crate::registry::TaskVectorSource); with the
//! packed-registry backend the build reads only the quantized sections it
//! needs, so a cold serving node goes registry-file → merged variant
//! without ever materializing the f32 zoo
//! ([`get_or_build_merged`](ModelCache::get_or_build_merged)).
//!
//! # Mapped vs owned source accounting
//!
//! Sources themselves occupy memory while serving, and the two kinds must
//! not be conflated: a registry opened with `IoMode::Mmap` serves its
//! payload bytes out of the **file mapping** (OS page cache, reclaimable
//! under pressure — reported via
//! [`source_mapped_bytes`](ModelCache::source_mapped_bytes), never charged
//! against the cap), while its index and decoded base caches are **owned
//! heap** (charged against the cap as an unevictable floor once the
//! source is registered).  [`get_or_build_merged`](ModelCache::get_or_build_merged)
//! registers its source automatically; eviction only ever removes merged
//! variants, so a cap smaller than the registered source overhead simply
//! leaves no room for cached models.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::metrics::Metrics;
use super::router::{merge_spec, MergeSpec};
use crate::checkpoint::Checkpoint;
use crate::merge::{MergedModel, Merger};
use crate::obs;
use crate::registry::{merge_from_source, TaskVectorSource};
use crate::util::exec::ExecCtx;
use crate::util::pool::Pool;

/// Cache key: (merge method name, scheme label).
pub type VariantKey = (String, String);

/// Single-flight ticket: waiters block on the condvar until the leader
/// flips the flag.
type Ticket = Arc<(Mutex<bool>, Condvar)>;

struct Entry {
    model: Arc<MergedModel>,
    bytes: usize,
    /// Logical clock of the last hit or publish (LRU order).
    last_used: u64,
}

/// Memory footprint of one registered task-vector source.
#[derive(Clone, Copy, Default)]
struct SourceFootprint {
    /// Owned heap bytes (index + decoded base caches) — counted against
    /// the cap.
    owned: usize,
    /// File-mapped bytes (page cache) — reported, never counted.
    mapped: u64,
}

#[derive(Default)]
struct CacheState {
    entries: HashMap<VariantKey, Entry>,
    tick: u64,
    /// Estimated bytes of builds currently in flight (leaders register
    /// their estimate for the duration of the build).
    pending_bytes: usize,
    evictions: u64,
    /// Registered serving sources, keyed by source identity.
    sources: HashMap<String, SourceFootprint>,
}

impl CacheState {
    /// fp32 bytes held by cached variants alone.
    fn variant_bytes(&self) -> usize {
        self.entries.values().map(|e| e.bytes).sum()
    }

    /// Bytes charged against the cap: cached variants plus the owned
    /// overhead of registered sources (mapped bytes excluded — they are
    /// reclaimable page cache, not heap).
    fn resident(&self) -> usize {
        self.variant_bytes() + self.sources.values().map(|s| s.owned).sum::<usize>()
    }
}

/// Thread-safe build-on-miss cache of merged model variants.
#[derive(Default)]
pub struct ModelCache {
    state: Mutex<CacheState>,
    inflight: Mutex<HashMap<VariantKey, Ticket>>,
    /// Resident-byte cap; `None` = unbounded.
    cap: Option<usize>,
    /// Optional metrics sink: merge builds record wall/busy timing here
    /// ([`ModelCache::set_metrics`]).
    metrics: OnceLock<Arc<Metrics>>,
}

/// Clears the in-flight ticket and wakes waiters when the leader exits —
/// including by error return or panic, so waiters never hang.  Also
/// returns the leader's pending-size reservation.
struct TicketGuard<'a> {
    cache: &'a ModelCache,
    key: VariantKey,
    est_bytes: usize,
}

impl Drop for TicketGuard<'_> {
    fn drop(&mut self) {
        {
            let mut state = self.cache.state.lock().unwrap();
            state.pending_bytes = state.pending_bytes.saturating_sub(self.est_bytes);
        }
        let ticket = self.cache.inflight.lock().unwrap().remove(&self.key);
        if let Some(t) = ticket {
            let (done, cv) = &*t;
            *done.lock().unwrap() = true;
            cv.notify_all();
        }
    }
}

fn model_bytes(m: &MergedModel) -> usize {
    match m {
        MergedModel::Shared(ck) => ck.fp32_bytes(),
        MergedModel::PerTask(cks) => cks.iter().map(|c| c.fp32_bytes()).sum(),
    }
}

impl ModelCache {
    /// An unbounded cache (no eviction except [`evict`](Self::evict)).
    pub fn new() -> Self {
        Self::default()
    }

    /// A cache bounded to `cap` resident fp32 bytes with LRU eviction.
    pub fn with_byte_cap(cap: usize) -> Self {
        Self { cap: Some(cap), ..Self::default() }
    }

    pub fn byte_cap(&self) -> Option<usize> {
        self.cap
    }

    /// Variants evicted by the capacity bound so far (manual
    /// [`evict`](Self::evict) calls not included).
    pub fn evictions(&self) -> u64 {
        self.state.lock().unwrap().evictions
    }

    /// Cache hit: bump recency and clone the handle.
    fn hit(state: &mut CacheState, key: &VariantKey) -> Option<Arc<MergedModel>> {
        state.tick += 1;
        let tick = state.tick;
        state.entries.get_mut(key).map(|e| {
            let _s = obs::span(obs::Category::Cache, "hit");
            e.last_used = tick;
            e.model.clone()
        })
    }

    /// Insert the freshly built variant — atomically releasing the
    /// leader's pending reservation, so its bytes are never counted
    /// twice (estimate + resident) — then run the cap walk against the
    /// **actual** merged size.  This is where an in-flight size estimate
    /// gets re-checked on completion: an underestimating build simply
    /// evicts more here.  The just-published key is never its own victim.
    fn publish(&self, key: &VariantKey, model: Arc<MergedModel>, my_est: usize) {
        let mut state = self.state.lock().unwrap();
        state.pending_bytes = state.pending_bytes.saturating_sub(my_est);
        state.tick += 1;
        let tick = state.tick;
        let bytes = model_bytes(&model);
        state.entries.insert(key.clone(), Entry { model, bytes, last_used: tick });
        self.enforce_cap(&mut state, Some(key));
    }

    /// Evict LRU variants until resident bytes (variants + source floor)
    /// plus in-flight build estimates fit the cap.  `protect` (a freshly
    /// published key) is never chosen as a victim, and the last remaining
    /// variant is never evicted either — once nothing (else) is
    /// evictable, an over-cap state is tolerated: serving an oversized
    /// variant beats refusing to, and evicting the sole survivor when a
    /// registered source's unevictable floor alone exceeds the cap would
    /// turn the cache into a 100%-miss rebuild loop.
    fn enforce_cap(&self, state: &mut CacheState, protect: Option<&VariantKey>) {
        let Some(cap) = self.cap else { return };
        while state.resident() + state.pending_bytes > cap && state.entries.len() > 1 {
            let victim = state
                .entries
                .iter()
                .filter(|(k, _)| protect.map_or(true, |p| p != *k))
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    let _s = obs::span(obs::Category::Cache, "evict");
                    state.entries.remove(&k);
                    state.evictions += 1;
                }
                None => break,
            }
        }
    }

    /// Get the cached variant, building it with `build` on a miss.
    ///
    /// Concurrent misses on the same key build **once**: the first caller
    /// becomes the leader, everyone else blocks until the leader
    /// publishes (or fails — then one waiter takes over and rebuilds).
    /// Builds run outside all cache locks, so slow builds of different
    /// keys proceed in parallel.
    pub fn get_or_build<F>(&self, method: &str, scheme: &str, build: F) -> Result<Arc<MergedModel>>
    where
        F: FnOnce() -> Result<MergedModel>,
    {
        self.get_or_build_sized(method, scheme, 0, build)
    }

    /// [`get_or_build`](Self::get_or_build) with a size estimate for the
    /// build in flight; the estimate counts against the byte cap while
    /// the leader works, so concurrent publishes leave room for it.
    pub fn get_or_build_sized<F>(
        &self,
        method: &str,
        scheme: &str,
        est_bytes: usize,
        build: F,
    ) -> Result<Arc<MergedModel>>
    where
        F: FnOnce() -> Result<MergedModel>,
    {
        let key = (method.to_string(), scheme.to_string());
        let mut build = Some(build);
        loop {
            if let Some(m) = Self::hit(&mut self.state.lock().unwrap(), &key) {
                return Ok(m);
            }
            // Miss: become the single-flight leader or wait for one.
            let wait_on: Option<Ticket> = {
                let mut inflight = self.inflight.lock().unwrap();
                // Re-check the cache under the in-flight lock: a leader
                // publishes *before* clearing its ticket, so no ticket +
                // a cache hit here means the work already finished.
                if let Some(m) = Self::hit(&mut self.state.lock().unwrap(), &key) {
                    return Ok(m);
                }
                let existing = inflight.get(&key).cloned();
                if existing.is_none() {
                    inflight.insert(
                        key.clone(),
                        Arc::new((Mutex::new(false), Condvar::new())),
                    );
                    self.state.lock().unwrap().pending_bytes += est_bytes;
                }
                existing
            };
            match wait_on {
                Some(ticket) => {
                    let (done, cv) = &*ticket;
                    let mut done = done.lock().unwrap();
                    while !*done {
                        done = cv.wait(done).unwrap();
                    }
                    // Re-loop: cache hit if the leader succeeded; if it
                    // failed, this thread may become the next leader.
                }
                None => {
                    let mut guard = TicketGuard { cache: self, key: key.clone(), est_bytes };
                    let build_span = obs::span(obs::Category::Cache, "build");
                    let built = (build.take().expect("a caller leads at most once"))()?;
                    drop(build_span);
                    let arc = Arc::new(built);
                    self.publish(&key, arc.clone(), est_bytes);
                    // publish released the reservation; the guard must
                    // not subtract it a second time on drop.
                    guard.est_bytes = 0;
                    return Ok(arc);
                }
            }
        }
    }

    /// Build (or fetch) the variant for `merger` over `source`'s task
    /// vectors, keyed by (method name, source identity).  The identity
    /// ([`TaskVectorSource::source_id`]) qualifies the scheme label with
    /// the backing artifact (registry path), so two zoos packed at the
    /// same scheme never share a cached variant.  With a
    /// [`PackedRegistrySource`](crate::registry::PackedRegistrySource)
    /// this materializes a merged model straight from packed payloads.
    /// The in-flight size estimate is one trunk (`pre.fp32_bytes()`) — a
    /// lower bound for per-task mergers, exact for shared ones; the
    /// estimate is re-checked against the actual merged size on
    /// completion (the publish's cap walk uses real bytes).
    ///
    /// The build routes its task-vector loads through the process-wide
    /// shared [`Pool`] — sized once for the whole process, never a new
    /// pool per build.  Single-flight semantics are unchanged: the pool
    /// only parallelizes *inside* the one build that runs per key, and
    /// each build's fan-out is bounded by the pool width.  With a
    /// metrics sink attached
    /// ([`set_metrics`](Self::set_metrics)) each build records its
    /// wall/busy timing, from which the coordinator reports realized
    /// parallel speedup.
    pub fn get_or_build_merged(
        &self,
        merger: &dyn Merger,
        pre: &Checkpoint,
        source: &dyn TaskVectorSource,
    ) -> Result<Arc<MergedModel>> {
        // Register before the build (so the source's owned floor is
        // visible to concurrent publishes) and refresh after (the build
        // may have warmed decoded base caches, growing the owned figure).
        self.register_source(source);
        let pool = Pool::global();
        let built =
            self.get_or_build_sized(merger.name(), &source.source_id(), pre.fp32_bytes(), || {
                // Leader-only, so single-flight yields one timing sample
                // per build.  Pool busy time is an aggregate counter:
                // the delta approximates this build's decode work (exact
                // when builds don't overlap on the pool).
                let wall = Instant::now();
                let busy0 = pool.busy_ns();
                let ctx = ExecCtx::with_pool(pool).traced("cache_merge_build");
                let built = merge_from_source(merger, pre, source, None, &ctx);
                if let (Some(metrics), Ok(_)) = (self.metrics.get(), &built) {
                    metrics.record_merge_build(
                        wall.elapsed(),
                        Duration::from_nanos(pool.busy_ns().saturating_sub(busy0)),
                    );
                }
                built
            })?;
        self.register_source(source);
        Ok(built)
    }

    /// Build (or fetch) the routed dynamic variant for `spec` — the
    /// incremental-merge serving path.
    ///
    /// On a miss the leader first looks for the spec's one-step patch
    /// ancestor ([`MergeSpec::parent`](super::router::MergeSpec::parent):
    /// the same request minus its highest task) among cached variants.
    /// If present, the new variant is `parent + lambda_t * tau_t` — one
    /// task-vector decode plus one signed axpy over the cached floats,
    /// instead of a full re-merge.  Because the canonical routed merge
    /// ([`merge_spec`](super::router::merge_spec))
    /// accumulates sequentially in ascending task order, the patch
    /// replays exactly its final accumulation step: **every** variant
    /// this method serves — patched or fully merged, at any thread
    /// count — is bit-identical to the canonical full merge of its spec,
    /// so patch chains (A -> B -> back to A) return byte-identical
    /// floats.  Pinned by `tests/dynamic_merge.rs`.
    ///
    /// Patches record [`Metrics::record_delta_patch`]; full builds
    /// record [`Metrics::record_merge_build`], as elsewhere.
    /// Single-flight, capacity and source-registration semantics are
    /// those of [`get_or_build_merged`](Self::get_or_build_merged).
    pub fn get_or_merge_routed(
        &self,
        spec: &MergeSpec,
        pre: &Checkpoint,
        source: &dyn TaskVectorSource,
    ) -> Result<Arc<MergedModel>> {
        self.register_source(source);
        let source_id = source.source_id();
        let (method, scheme) = spec.variant_key(&source_id);
        let pool = Pool::global();
        let built = self.get_or_build_sized(&method, &scheme, pre.fp32_bytes(), || {
            // One-task delta patch: the parent lookup is a plain cache
            // hit (bumping its recency, so a live patch lineage resists
            // eviction).  The parent Arc is cloned out under the lock
            // and the patch itself runs lock-free.
            if let Some((parent, t, lam)) = spec.parent() {
                let parent_key = parent.variant_key(&source_id);
                let base = Self::hit(&mut self.state.lock().unwrap(), &parent_key);
                if let Some(base) = base {
                    if let MergedModel::Shared(cached) = &*base {
                        let _s = obs::span(obs::Category::Cache, "delta_patch");
                        let wall = Instant::now();
                        let tau = source.task_vector_with_pool(t, pool)?;
                        let mut out = cached.clone();
                        out.axpy(lam, &tau)?;
                        if let Some(metrics) = self.metrics.get() {
                            metrics.record_delta_patch(wall.elapsed());
                        }
                        return Ok(MergedModel::Shared(out));
                    }
                }
            }
            // No cached neighbor: full canonical merge.
            let wall = Instant::now();
            let busy0 = pool.busy_ns();
            let ctx = ExecCtx::with_pool(pool).traced("routed_merge_build");
            let built = merge_spec(spec, pre, source, &ctx);
            if let (Some(metrics), Ok(_)) = (self.metrics.get(), &built) {
                metrics.record_merge_build(
                    wall.elapsed(),
                    Duration::from_nanos(pool.busy_ns().saturating_sub(busy0)),
                );
            }
            built
        })?;
        self.register_source(source);
        Ok(built)
    }

    /// Attach a [`Metrics`] sink: every merge build completed through
    /// [`get_or_build_merged`](Self::get_or_build_merged) records its
    /// wall/busy timing there.  First call wins; later calls are no-ops.
    pub fn set_metrics(&self, metrics: Arc<Metrics>) {
        let _ = self.metrics.set(metrics);
    }

    pub fn contains(&self, method: &str, scheme: &str) -> bool {
        self.state
            .lock()
            .unwrap()
            .entries
            .contains_key(&(method.to_string(), scheme.to_string()))
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Evict one variant; returns whether it was present.
    pub fn evict(&self, method: &str, scheme: &str) -> bool {
        self.state
            .lock()
            .unwrap()
            .entries
            .remove(&(method.to_string(), scheme.to_string()))
            .is_some()
    }

    /// Resident fp32 bytes across all cached variants (registered source
    /// overhead not included; see
    /// [`source_overhead_bytes`](Self::source_overhead_bytes)).
    pub fn resident_bytes(&self) -> usize {
        self.state.lock().unwrap().variant_bytes()
    }

    /// Record (or refresh) a serving source's memory footprint, keyed by
    /// its identity: owned bytes join the capped total as an unevictable
    /// floor, mapped bytes are tracked for observability only.  Re-register
    /// after base caches warm up to keep the owned figure current;
    /// [`get_or_build_merged`](Self::get_or_build_merged) does both
    /// automatically.  A refresh that *grows* the floor (decoded base
    /// caches warmed during a build) runs the cap walk immediately, so
    /// the correction lands now rather than at some future publish.
    pub fn register_source(&self, source: &dyn TaskVectorSource) {
        let mut state = self.state.lock().unwrap();
        state.sources.insert(
            source.source_id(),
            SourceFootprint {
                owned: source.resident_overhead_bytes(),
                mapped: source.mapped_bytes(),
            },
        );
        self.enforce_cap(&mut state, None);
    }

    /// Whether the node byte budget can take on `est_bytes` more
    /// *unevictable* resident bytes.  The admission test is against the
    /// floor the cap walk can never reclaim — registered source
    /// overheads plus in-flight build reservations — not against
    /// currently resident variants, which are evictable and would be
    /// shed by [`enforce_cap`] to make room.  The control plane calls
    /// this before `Loading` a variant: a registry whose overhead (plus
    /// its estimated merged model) cannot fit even after evicting
    /// everything is refused up front with a typed error instead of
    /// thrashing the cache.  Uncapped caches admit everything.
    pub fn can_admit(&self, est_bytes: usize) -> bool {
        let Some(cap) = self.cap else { return true };
        let state = self.state.lock().unwrap();
        let floor: usize = state.sources.values().map(|s| s.owned).sum();
        floor + state.pending_bytes + est_bytes <= cap
    }

    /// Owned heap bytes pinned by registered sources (counted against the
    /// byte cap).
    pub fn source_overhead_bytes(&self) -> usize {
        let state = self.state.lock().unwrap();
        state.sources.values().map(|s| s.owned).sum()
    }

    /// File-mapped bytes served by registered sources (page cache;
    /// reported, never charged against the cap).
    pub fn source_mapped_bytes(&self) -> u64 {
        let state = self.state.lock().unwrap();
        state.sources.values().map(|s| s.mapped).sum()
    }

    /// Keys currently resident (sorted for deterministic output).
    pub fn keys(&self) -> Vec<VariantKey> {
        let mut keys: Vec<VariantKey> =
            self.state.lock().unwrap().entries.keys().cloned().collect();
        keys.sort();
        keys
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::Checkpoint;
    use crate::tensor::Tensor;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Barrier;
    use std::time::Duration;

    /// 4x4 f32 = 64 resident bytes per variant.
    fn model() -> MergedModel {
        let mut ck = Checkpoint::new();
        ck.insert("w", Tensor::zeros(&[4, 4]));
        MergedModel::Shared(ck)
    }

    const MODEL_BYTES: usize = 64;

    #[test]
    fn builds_once_then_hits() {
        let cache = ModelCache::new();
        let mut builds = 0;
        for _ in 0..3 {
            let m = cache
                .get_or_build("ta", "TVQ-INT3", || {
                    builds += 1;
                    Ok(model())
                })
                .unwrap();
            assert_eq!(m.n_variants(), 1);
        }
        assert_eq!(builds, 1);
        assert_eq!(cache.len(), 1);
        assert!(cache.contains("ta", "TVQ-INT3"));
        assert_eq!(cache.byte_cap(), None);
    }

    #[test]
    fn build_failure_propagates_and_caches_nothing() {
        let cache = ModelCache::new();
        let r = cache.get_or_build("ta", "x", || anyhow::bail!("boom"));
        assert!(r.is_err());
        assert!(cache.is_empty());
        // The failed build must not leave a stuck in-flight ticket or a
        // leaked pending reservation.
        let ok = cache.get_or_build_sized("ta", "x", 1 << 20, || Ok(model()));
        assert!(ok.is_ok());
        assert_eq!(cache.state.lock().unwrap().pending_bytes, 0);
    }

    #[test]
    fn evict_and_resident_bytes() {
        let cache = ModelCache::new();
        cache.get_or_build("ta", "FP32", || Ok(model())).unwrap();
        assert_eq!(cache.resident_bytes(), MODEL_BYTES);
        assert!(cache.evict("ta", "FP32"));
        assert!(!cache.evict("ta", "FP32"));
        assert_eq!(cache.resident_bytes(), 0);
    }

    #[test]
    fn lru_evicts_least_recently_used_under_cap() {
        // Cap fits two variants exactly.
        let cache = ModelCache::with_byte_cap(2 * MODEL_BYTES);
        cache.get_or_build("ta", "a", || Ok(model())).unwrap();
        cache.get_or_build("ta", "b", || Ok(model())).unwrap();
        // Touch "a" so "b" becomes the LRU victim.
        cache.get_or_build("ta", "a", || unreachable!("must hit")).unwrap();
        cache.get_or_build("ta", "c", || Ok(model())).unwrap();
        assert!(cache.contains("ta", "a"), "recently-used variant evicted");
        assert!(!cache.contains("ta", "b"), "LRU variant survived past the cap");
        assert!(cache.contains("ta", "c"));
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 1);
        assert!(cache.resident_bytes() <= 2 * MODEL_BYTES);
    }

    #[test]
    fn oversized_variant_is_still_served() {
        let cache = ModelCache::with_byte_cap(MODEL_BYTES / 2);
        let m = cache.get_or_build("ta", "big", || Ok(model())).unwrap();
        assert_eq!(m.n_variants(), 1);
        // Kept despite exceeding the cap alone (never evict the fresh
        // publish) — but it is the next victim.
        assert!(cache.contains("ta", "big"));
        cache.get_or_build("ta", "next", || Ok(model())).unwrap();
        assert!(!cache.contains("ta", "big"));
    }

    #[test]
    fn pending_builds_count_against_cap() {
        // Cap fits two variants.  A slow build of A holds a reservation;
        // publishing C must evict B (resident) rather than trust the
        // full cap, so A lands without displacing anything.
        let cache = Arc::new(ModelCache::with_byte_cap(2 * MODEL_BYTES));
        let entered = Arc::new(Barrier::new(2));
        let release = Arc::new(Barrier::new(2));
        let c = cache.clone();
        let (e2, r2) = (entered.clone(), release.clone());
        let slow = std::thread::spawn(move || {
            c.get_or_build_sized("ta", "A", MODEL_BYTES, || {
                e2.wait(); // A's build is now in flight
                r2.wait(); // ...and stays there until released
                Ok(model())
            })
            .unwrap();
        });
        entered.wait();
        cache.get_or_build("ta", "B", || Ok(model())).unwrap();
        cache.get_or_build("ta", "C", || Ok(model())).unwrap();
        // C's publish saw resident B + pending A: B had to go.
        assert!(!cache.contains("ta", "B"), "pending build was not counted");
        assert!(cache.contains("ta", "C"));
        release.wait();
        slow.join().unwrap();
        assert!(cache.contains("ta", "A"));
        assert_eq!(cache.len(), 2);
        assert!(cache.resident_bytes() <= 2 * MODEL_BYTES);
        assert_eq!(cache.state.lock().unwrap().pending_bytes, 0);
    }

    #[test]
    fn underestimating_build_corrects_cap_on_completion() {
        // The in-flight estimate claims 0 bytes; the real model is a full
        // MODEL_BYTES.  While it builds, other publishes legitimately
        // fill the cap — completion must re-check against the actual
        // size and evict, not trust the stale estimate.
        let cache = Arc::new(ModelCache::with_byte_cap(2 * MODEL_BYTES));
        let entered = Arc::new(Barrier::new(2));
        let release = Arc::new(Barrier::new(2));
        let c = cache.clone();
        let (e2, r2) = (entered.clone(), release.clone());
        let slow = std::thread::spawn(move || {
            c.get_or_build_sized("ta", "under", 0, || {
                e2.wait();
                r2.wait();
                Ok(model())
            })
            .unwrap();
        });
        entered.wait();
        cache.get_or_build("ta", "b", || Ok(model())).unwrap();
        cache.get_or_build("ta", "c", || Ok(model())).unwrap();
        assert_eq!(cache.len(), 2, "estimate 0 must not block concurrent publishes");
        release.wait();
        slow.join().unwrap();
        assert!(cache.contains("ta", "under"), "fresh publish must never self-evict");
        assert!(
            cache.resident_bytes() <= 2 * MODEL_BYTES,
            "actual size must correct the cap on completion (resident {})",
            cache.resident_bytes()
        );
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 1);
        assert_eq!(cache.state.lock().unwrap().pending_bytes, 0);
    }

    /// A fake serving source with a fixed memory footprint.
    struct FakeSource {
        id: &'static str,
        owned: usize,
        mapped: u64,
    }

    impl crate::registry::TaskVectorSource for FakeSource {
        fn n_tasks(&self) -> usize {
            1
        }
        fn task_name(&self, _t: usize) -> String {
            "task00".into()
        }
        fn task_vector(&self, _t: usize) -> Result<Checkpoint> {
            let mut ck = Checkpoint::new();
            ck.insert("w", Tensor::zeros(&[4, 4]));
            Ok(ck)
        }
        fn scheme_label(&self) -> String {
            "FAKE".into()
        }
        fn source_id(&self) -> String {
            self.id.into()
        }
        fn resident_overhead_bytes(&self) -> usize {
            self.owned
        }
        fn mapped_bytes(&self) -> u64 {
            self.mapped
        }
    }

    #[test]
    fn source_owned_bytes_count_against_cap_mapped_do_not() {
        // Cap fits two variants with nothing else registered.
        let cache = ModelCache::with_byte_cap(2 * MODEL_BYTES);
        cache.get_or_build("ta", "a", || Ok(model())).unwrap();
        cache.get_or_build("ta", "b", || Ok(model())).unwrap();
        assert_eq!(cache.len(), 2);

        // An mmap-backed source: huge mapped span, tiny owned overhead.
        // Mapped bytes are page cache — registering it must NOT squeeze
        // variants out.
        cache.register_source(&FakeSource { id: "mmap", owned: 0, mapped: 1 << 30 });
        cache.get_or_build("ta", "a", || unreachable!("must hit")).unwrap();
        assert_eq!(cache.source_mapped_bytes(), 1 << 30);
        assert_eq!(cache.source_overhead_bytes(), 0);
        assert_eq!(cache.len(), 2, "mapped bytes wrongly charged against the cap");

        // An owned-overhead source (pread-style decoded caches) is an
        // unevictable floor: the next publish must evict a variant to
        // stay under cap.
        cache.register_source(&FakeSource { id: "owned", owned: MODEL_BYTES, mapped: 0 });
        assert_eq!(cache.source_overhead_bytes(), MODEL_BYTES);
        cache.get_or_build("ta", "c", || Ok(model())).unwrap();
        assert_eq!(
            cache.resident_bytes() + cache.source_overhead_bytes(),
            2 * MODEL_BYTES,
            "variants + source floor must fit the cap"
        );
        assert!(cache.contains("ta", "c"));
        // Re-registering the same id refreshes in place, not double-counts.
        cache.register_source(&FakeSource { id: "owned", owned: MODEL_BYTES / 2, mapped: 0 });
        assert_eq!(cache.source_overhead_bytes(), MODEL_BYTES / 2);
    }

    #[test]
    fn can_admit_tests_the_unevictable_floor_only() {
        // Uncapped: everything is admissible.
        assert!(ModelCache::new().can_admit(usize::MAX));

        let cache = ModelCache::with_byte_cap(2 * MODEL_BYTES);
        assert!(cache.can_admit(2 * MODEL_BYTES));
        assert!(!cache.can_admit(2 * MODEL_BYTES + 1));

        // Resident variants are evictable and do not reduce headroom.
        cache.get_or_build("ta", "a", || Ok(model())).unwrap();
        cache.get_or_build("ta", "b", || Ok(model())).unwrap();
        assert!(cache.can_admit(2 * MODEL_BYTES));

        // Registered source overhead is an unevictable floor and does.
        cache.register_source(&FakeSource { id: "s", owned: MODEL_BYTES, mapped: 0 });
        assert!(cache.can_admit(MODEL_BYTES));
        assert!(!cache.can_admit(MODEL_BYTES + 1));
        // Mapped bytes are page cache, never charged.
        cache.register_source(&FakeSource { id: "m", owned: 0, mapped: 1 << 30 });
        assert!(cache.can_admit(MODEL_BYTES));
    }

    #[test]
    fn source_floor_growth_corrects_cap_on_refresh() {
        let cache = ModelCache::with_byte_cap(2 * MODEL_BYTES);
        cache.register_source(&FakeSource { id: "s", owned: 0, mapped: 0 });
        cache.get_or_build("ta", "a", || Ok(model())).unwrap();
        cache.get_or_build("ta", "b", || Ok(model())).unwrap();
        assert_eq!(cache.len(), 2);
        // The source's decoded base caches warm up (as during a merge
        // build): the refreshed, larger floor must trigger the cap walk
        // at registration time, not linger until a future publish.
        cache.register_source(&FakeSource { id: "s", owned: MODEL_BYTES, mapped: 0 });
        assert_eq!(cache.len(), 1, "grown source floor must evict immediately");
        assert!(
            cache.resident_bytes() + cache.source_overhead_bytes() <= 2 * MODEL_BYTES
        );
        assert_eq!(cache.evictions(), 1);
    }

    #[test]
    fn sole_variant_survives_source_floor_exceeding_cap() {
        // A source whose unevictable owned floor plus the one merged
        // variant exceeds the cap: the publish tolerance keeps the
        // oversized variant, and the register_source refresh right
        // after it must NOT evict the sole survivor (that would make
        // every request a full rebuild while freeing nothing the floor
        // doesn't still occupy).
        let cache = ModelCache::with_byte_cap(MODEL_BYTES + MODEL_BYTES / 2);
        let src = FakeSource { id: "big-floor", owned: MODEL_BYTES, mapped: 0 };
        let mut pre = Checkpoint::new();
        pre.insert("w", Tensor::zeros(&[4, 4]));
        let ta = crate::merge::TaskArithmetic::default();
        let builds = AtomicUsize::new(0);
        for _ in 0..3 {
            cache
                .get_or_build_sized("ta", &src.source_id(), 0, || {
                    builds.fetch_add(1, Ordering::SeqCst);
                    crate::registry::merge_from_source(&ta, &pre, &src, None, &ExecCtx::default())
                })
                .unwrap();
            cache.register_source(&src);
        }
        assert_eq!(builds.load(Ordering::SeqCst), 1, "sole variant was evicted between hits");
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.evictions(), 0);
    }

    #[test]
    fn get_or_build_merged_registers_its_source_and_records_metrics() {
        let cache = ModelCache::new();
        let metrics = Arc::new(crate::coordinator::Metrics::new());
        cache.set_metrics(metrics.clone());
        let src = FakeSource { id: "auto", owned: 123, mapped: 456 };
        let mut pre = Checkpoint::new();
        pre.insert("w", Tensor::zeros(&[4, 4]));
        let ta = crate::merge::TaskArithmetic::default();
        cache.get_or_build_merged(&ta, &pre, &src).unwrap();
        assert_eq!(cache.source_overhead_bytes(), 123);
        assert_eq!(cache.source_mapped_bytes(), 456);
        // The (leader-only) build recorded exactly one timing sample...
        assert_eq!(metrics.snapshot().merge_builds, 1);
        // ...and a cache hit records nothing further.
        cache.get_or_build_merged(&ta, &pre, &src).unwrap();
        assert_eq!(metrics.snapshot().merge_builds, 1);
    }

    /// A deterministic multi-task source for routed-merge tests.
    struct RoutedZoo {
        taus: Vec<Checkpoint>,
    }

    impl RoutedZoo {
        fn new(n_tasks: usize) -> Self {
            let taus = (0..n_tasks)
                .map(|t| {
                    let mut rng = crate::util::rng::Rng::new(90 + t as u64);
                    let mut ck = Checkpoint::new();
                    ck.insert("w", Tensor::randn(&[6, 6], 0.05, &mut rng));
                    ck
                })
                .collect();
            Self { taus }
        }
    }

    impl crate::registry::TaskVectorSource for RoutedZoo {
        fn n_tasks(&self) -> usize {
            self.taus.len()
        }
        fn task_name(&self, t: usize) -> String {
            format!("task{t:02}")
        }
        fn task_vector(&self, t: usize) -> Result<Checkpoint> {
            Ok(self.taus[t].clone())
        }
        fn scheme_label(&self) -> String {
            "FAKE".into()
        }
        fn source_id(&self) -> String {
            "routed-zoo".into()
        }
    }

    fn bits_equal(a: &Checkpoint, b: &Checkpoint) -> bool {
        a.len() == b.len()
            && a.iter().zip(b.iter()).all(|((na, ta), (nb, tb))| {
                na == nb
                    && ta.data().len() == tb.data().len()
                    && ta
                        .data()
                        .iter()
                        .zip(tb.data())
                        .all(|(x, y)| x.to_bits() == y.to_bits())
            })
    }

    #[test]
    fn routed_patch_is_bit_identical_to_full_merge() {
        let zoo = RoutedZoo::new(3);
        let mut rng = crate::util::rng::Rng::new(7);
        let mut pre = Checkpoint::new();
        pre.insert("w", Tensor::randn(&[6, 6], 0.1, &mut rng));

        let warm = ModelCache::new();
        let metrics = Arc::new(crate::coordinator::Metrics::new());
        warm.set_metrics(metrics.clone());
        let parent = MergeSpec::new(&[0, 1], &[0.3, 0.2]).unwrap();
        let child = MergeSpec::new(&[0, 1, 2], &[0.3, 0.2, -0.1]).unwrap();
        warm.get_or_merge_routed(&parent, &pre, &zoo).unwrap();
        let patched = warm.get_or_merge_routed(&child, &pre, &zoo).unwrap();
        assert_eq!(metrics.snapshot().merge_builds, 1, "parent was a full build");
        assert_eq!(metrics.snapshot().delta_patches, 1, "child must patch, not re-merge");

        // A cold cache full-merges the same spec: bytes must match.
        let cold = ModelCache::new();
        let full = cold.get_or_merge_routed(&child, &pre, &zoo).unwrap();
        assert!(bits_equal(patched.for_task(0), full.for_task(0)));
        // Repeat requests hit, recording nothing further.
        warm.get_or_merge_routed(&child, &pre, &zoo).unwrap();
        assert_eq!(metrics.snapshot().delta_patches, 1);
    }

    #[test]
    fn patch_requires_identical_prefix_lambdas() {
        // A prefix at *different* lambdas is a different parent key, so
        // the request full-merges instead of patching off the wrong base.
        let zoo = RoutedZoo::new(3);
        let mut pre = Checkpoint::new();
        pre.insert("w", Tensor::zeros(&[6, 6]));
        let cache = ModelCache::new();
        let metrics = Arc::new(crate::coordinator::Metrics::new());
        cache.set_metrics(metrics.clone());
        cache
            .get_or_merge_routed(&MergeSpec::new(&[0, 1], &[0.3, 0.2]).unwrap(), &pre, &zoo)
            .unwrap();
        cache
            .get_or_merge_routed(
                &MergeSpec::new(&[0, 1, 2], &[0.3, 0.25, -0.1]).unwrap(),
                &pre,
                &zoo,
            )
            .unwrap();
        let s = metrics.snapshot();
        assert_eq!(s.merge_builds, 2);
        assert_eq!(s.delta_patches, 0);
    }

    #[test]
    fn concurrent_access_is_safe() {
        let cache = std::sync::Arc::new(ModelCache::new());
        let mut handles = Vec::new();
        for i in 0..8 {
            let c = cache.clone();
            handles.push(std::thread::spawn(move || {
                let scheme = format!("s{}", i % 2);
                c.get_or_build("ta", &scheme, || Ok(model())).unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn concurrent_misses_build_exactly_once() {
        // The duplicate-build race: N threads miss the same key at once;
        // the slow build must run exactly once.
        let cache = Arc::new(ModelCache::new());
        let builds = Arc::new(AtomicUsize::new(0));
        let barrier = Arc::new(Barrier::new(8));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let c = cache.clone();
            let b = builds.clone();
            let bar = barrier.clone();
            handles.push(std::thread::spawn(move || {
                bar.wait();
                let m = c
                    .get_or_build("emr", "RTVQ-B3O2", || {
                        b.fetch_add(1, Ordering::SeqCst);
                        std::thread::sleep(Duration::from_millis(40));
                        Ok(model())
                    })
                    .unwrap();
                assert_eq!(m.n_variants(), 1);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(builds.load(Ordering::SeqCst), 1, "concurrent misses double-built");
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn failed_leader_hands_off_to_a_waiter() {
        let cache = Arc::new(ModelCache::new());
        let attempts = Arc::new(AtomicUsize::new(0));
        let barrier = Arc::new(Barrier::new(4));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = cache.clone();
            let a = attempts.clone();
            let bar = barrier.clone();
            handles.push(std::thread::spawn(move || {
                bar.wait();
                c.get_or_build("ta", "flaky", || {
                    let n = a.fetch_add(1, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(20));
                    if n == 0 {
                        anyhow::bail!("first build fails")
                    }
                    Ok(model())
                })
                .is_ok()
            }));
        }
        let oks = handles
            .into_iter()
            .map(|h| h.join().unwrap())
            .filter(|&ok| ok)
            .count();
        // Exactly the first leader fails; exactly one waiter rebuilds.
        assert_eq!(oks, 3);
        assert_eq!(attempts.load(Ordering::SeqCst), 2);
        assert!(cache.contains("ta", "flaky"));
    }
}
