//! The running service: ingress queue → router thread → executor pool.
//!
//! The PJRT [`Runtime`](crate::runtime::Runtime) is `!Send`, so each
//! executor thread constructs its own client/backend via a factory; the
//! merged model and heads are plain data and shared by `Arc`.  The
//! executor side is abstracted behind [`Backend`] so the threading and
//! batching machinery is unit-testable without PJRT.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::batcher::{Batch, Batcher};
use super::metrics::{Metrics, MetricsSnapshot};
use super::pick_bucket;
use crate::data::VitPreset;
use crate::merge::MergedModel;
use crate::tensor::Tensor;

/// Everything an executor needs to serve one deployment (all `Send`).
#[derive(Clone)]
pub struct ServeModel {
    pub preset: &'static VitPreset,
    pub merged: Arc<MergedModel>,
    /// Per-task classification heads (frozen, as in the paper: only the
    /// trunk is merged).
    pub heads: Arc<Vec<Tensor>>,
}

impl ServeModel {
    pub fn n_tasks(&self) -> usize {
        self.heads.len()
    }
}

/// Server tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Max requests per formed batch (clamped to the largest AOT bucket).
    pub max_batch: usize,
    /// Max time a request may wait for batch-mates.
    pub max_delay: Duration,
    /// Ingress queue capacity; beyond this, `submit` rejects (backpressure).
    pub queue_cap: usize,
    /// Executor threads (each owns a PJRT client).
    pub executors: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            max_batch: 32,
            max_delay: Duration::from_millis(2),
            queue_cap: 1024,
            executors: 2,
        }
    }
}

/// Response payload: logits for one request.
pub type InferResult = Result<Vec<f32>, String>;

/// What executors actually run. `infer` receives a padded `[bucket,
/// tokens, token_dim]` tensor plus the number of valid rows and returns
/// one logits vector per valid row.
pub trait Backend {
    fn infer(&mut self, task: usize, x: &Tensor, n_valid: usize) -> Result<Vec<Vec<f32>>>;
}

/// The production backend: bucketed forward artifacts through PJRT.
pub struct PjrtBackend {
    rt: crate::runtime::Runtime,
    model: ServeModel,
}

impl PjrtBackend {
    pub fn new(model: ServeModel) -> Result<Self> {
        Ok(Self { rt: crate::runtime::Runtime::new()?, model })
    }
}

impl Backend for PjrtBackend {
    fn infer(&mut self, task: usize, x: &Tensor, n_valid: usize) -> Result<Vec<Vec<f32>>> {
        let b = x.shape()[0];
        let art = self
            .rt
            .load(&format!("{}_forward_b{}", self.model.preset.name, b))?;
        let logits = crate::runtime::forward_logits(
            &art,
            self.model.merged.for_task(task),
            &self.model.heads[task],
            x,
        )?;
        let c = *logits.shape().last().unwrap();
        Ok(logits
            .data()
            .chunks_exact(c)
            .take(n_valid)
            .map(|row| row.to_vec())
            .collect())
    }
}

struct SubmitItem {
    x: Vec<f32>,
    resp: SyncSender<InferResult>,
    submitted: Instant,
}

/// A running multi-task inference service.
pub struct Server {
    ingress: Option<SyncSender<(usize, SubmitItem)>>,
    metrics: Arc<Metrics>,
    preset: &'static VitPreset,
    n_tasks: usize,
    router: Option<JoinHandle<()>>,
    executors: Vec<JoinHandle<()>>,
}

impl Server {
    /// Start serving `model` with PJRT executors.
    pub fn start(cfg: ServerConfig, model: ServeModel) -> Result<Server> {
        let preset = model.preset;
        let n_tasks = model.n_tasks();
        Self::start_with_backend(cfg, preset, n_tasks, move || PjrtBackend::new(model.clone()))
    }

    /// Start with a custom backend factory (one backend per executor
    /// thread) — the seam tests use to run without PJRT.
    pub fn start_with_backend<B, F>(
        cfg: ServerConfig,
        preset: &'static VitPreset,
        n_tasks: usize,
        factory: F,
    ) -> Result<Server>
    where
        B: Backend + 'static,
        F: Fn() -> Result<B> + Send + Sync + 'static,
    {
        if cfg.executors == 0 {
            bail!("need at least one executor");
        }
        let max_bucket = preset
            .serve_buckets
            .iter()
            .copied()
            .max()
            .ok_or_else(|| anyhow!("preset has no serve buckets"))?;
        let max_batch = cfg.max_batch.min(max_bucket).max(1);

        let metrics = Arc::new(Metrics::new());
        let (ingress_tx, ingress_rx) =
            mpsc::sync_channel::<(usize, SubmitItem)>(cfg.queue_cap.max(1));
        let (batch_tx, batch_rx) =
            mpsc::sync_channel::<Batch<SubmitItem>>(cfg.executors * 2);
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        // Router thread: stage + flush.
        let router_metrics = metrics.clone();
        let max_delay = cfg.max_delay;
        let router = std::thread::Builder::new()
            .name("tvq-router".into())
            .spawn(move || {
                router_loop(ingress_rx, batch_tx, n_tasks, max_batch, max_delay, router_metrics)
            })?;

        // Executor pool.
        let factory = Arc::new(factory);
        let mut executors = Vec::with_capacity(cfg.executors);
        for i in 0..cfg.executors {
            let rx = batch_rx.clone();
            let m = metrics.clone();
            let f = factory.clone();
            executors.push(
                std::thread::Builder::new()
                    .name(format!("tvq-exec-{i}"))
                    .spawn(move || executor_loop(rx, preset, f.as_ref(), m))?,
            );
        }

        Ok(Server {
            ingress: Some(ingress_tx),
            metrics,
            preset,
            n_tasks,
            router: Some(router),
            executors,
        })
    }

    /// Submit one request; returns a one-shot receiver for the logits.
    /// Errors immediately on invalid input or a full queue (backpressure).
    pub fn submit(&self, task: usize, x: &Tensor) -> Result<Receiver<InferResult>> {
        if task >= self.n_tasks {
            bail!("task {task} out of range ({} tasks)", self.n_tasks);
        }
        let want = self.preset.tokens * self.preset.token_dim;
        if x.numel() != want {
            bail!("input has {} values, expected {want}", x.numel());
        }
        let (resp_tx, resp_rx) = mpsc::sync_channel(1);
        let item = SubmitItem {
            x: x.data().to_vec(),
            resp: resp_tx,
            submitted: Instant::now(),
        };
        let ingress = self
            .ingress
            .as_ref()
            .ok_or_else(|| anyhow!("server is shut down"))?;
        match ingress.try_send((task, item)) {
            Ok(()) => {
                self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(resp_rx)
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                bail!("queue full ({} pending)", self.metrics.snapshot().submitted)
            }
            Err(TrySendError::Disconnected(_)) => bail!("server is shut down"),
        }
    }

    /// Blocking convenience: submit and wait for the logits.
    pub fn infer(&self, task: usize, x: &Tensor) -> Result<Vec<f32>> {
        let rx = self.submit(task, x)?;
        rx.recv()
            .map_err(|_| anyhow!("server dropped response"))?
            .map_err(|e| anyhow!(e))
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Reset latency/batch windows (e.g. after a warmup phase).
    pub fn reset_metrics_window(&self) {
        self.metrics.reset_window();
    }

    /// Graceful shutdown: drain staged requests, then join all threads.
    pub fn shutdown(&mut self) {
        self.ingress = None; // disconnects the router's ingress
        if let Some(r) = self.router.take() {
            let _ = r.join();
        }
        for h in self.executors.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn router_loop(
    ingress: Receiver<(usize, SubmitItem)>,
    batch_tx: SyncSender<Batch<SubmitItem>>,
    n_tasks: usize,
    max_batch: usize,
    max_delay: Duration,
    _metrics: Arc<Metrics>,
) {
    let mut batcher: Batcher<SubmitItem> = Batcher::new(n_tasks, max_batch, max_delay);
    loop {
        // Sleep until the next deadline (or idle-poll at max_delay).
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(max_delay.max(Duration::from_millis(1)));
        match ingress.recv_timeout(timeout) {
            Ok((task, item)) => {
                let at = item.submitted;
                batcher.push(task, at, item);
                // Opportunistically drain everything already queued.
                while let Ok((task, item)) = ingress.try_recv() {
                    let at = item.submitted;
                    batcher.push(task, at, item);
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                for b in batcher.drain_all() {
                    if batch_tx.send(b).is_err() {
                        return;
                    }
                }
                return; // dropping batch_tx stops the executors
            }
        }
        let now = Instant::now();
        while let Some(b) = batcher.pop_ready(now) {
            if batch_tx.send(b).is_err() {
                return;
            }
        }
    }
}

fn executor_loop<B, F>(
    rx: Arc<Mutex<Receiver<Batch<SubmitItem>>>>,
    preset: &'static VitPreset,
    factory: &F,
    metrics: Arc<Metrics>,
) where
    B: Backend,
    F: Fn() -> Result<B>,
{
    let mut backend = match factory() {
        Ok(b) => b,
        Err(e) => {
            eprintln!("[coordinator] backend init failed: {e:#}");
            return;
        }
    };
    let img = preset.tokens * preset.token_dim;
    loop {
        let batch = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(b) => b,
                Err(_) => return, // router gone: shutdown
            }
        };
        let n = batch.items.len();
        let bucket = match pick_bucket(preset.serve_buckets, n) {
            Some(b) => b,
            None => {
                for s in batch.items {
                    let _ = s.payload.resp.send(Err(format!(
                        "batch of {n} exceeds largest serve bucket"
                    )));
                    metrics.failed.fetch_add(1, Ordering::Relaxed);
                }
                continue;
            }
        };
        // Pack (padded) input tensor.
        let mut x = Tensor::zeros(&[bucket, preset.tokens, preset.token_dim]);
        for (i, s) in batch.items.iter().enumerate() {
            x.data_mut()[i * img..(i + 1) * img].copy_from_slice(&s.payload.x);
        }
        metrics.record_batch(n);
        match backend.infer(batch.task, &x, n) {
            Ok(rows) => {
                for (s, row) in batch.items.into_iter().zip(rows) {
                    metrics.completed.fetch_add(1, Ordering::Relaxed);
                    metrics.record_latency(s.payload.submitted.elapsed());
                    let _ = s.payload.resp.send(Ok(row));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for s in batch.items {
                    metrics.failed.fetch_add(1, Ordering::Relaxed);
                    let _ = s.payload.resp.send(Err(msg.clone()));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::VIT_S;

    /// Test backend: logits row = [sum(x_i), task as f32].
    struct MockBackend;

    impl Backend for MockBackend {
        fn infer(&mut self, task: usize, x: &Tensor, n_valid: usize) -> Result<Vec<Vec<f32>>> {
            let img = x.numel() / x.shape()[0];
            Ok((0..n_valid)
                .map(|i| {
                    let s: f32 = x.data()[i * img..(i + 1) * img].iter().sum();
                    vec![s, task as f32]
                })
                .collect())
        }
    }

    fn mock_server(cfg: ServerConfig, n_tasks: usize) -> Server {
        Server::start_with_backend(cfg, &VIT_S, n_tasks, || Ok(MockBackend)).unwrap()
    }

    fn input(v: f32) -> Tensor {
        Tensor::full(&[VIT_S.tokens, VIT_S.token_dim], v)
    }

    #[test]
    fn serves_single_request() {
        let server = mock_server(ServerConfig::default(), 2);
        let out = server.infer(1, &input(1.0)).unwrap();
        let img = (VIT_S.tokens * VIT_S.token_dim) as f32;
        assert_eq!(out, vec![img, 1.0]);
        let m = server.metrics();
        assert_eq!(m.completed, 1);
        assert_eq!(m.failed, 0);
    }

    #[test]
    fn rejects_bad_task_and_shape() {
        let server = mock_server(ServerConfig::default(), 2);
        assert!(server.submit(5, &input(0.0)).is_err());
        assert!(server.submit(0, &Tensor::zeros(&[3])).is_err());
        assert_eq!(server.metrics().completed, 0);
    }

    #[test]
    fn concurrent_load_conserves_requests() {
        let cfg = ServerConfig {
            max_batch: 8,
            max_delay: Duration::from_millis(1),
            queue_cap: 4096,
            executors: 3,
        };
        let server = Arc::new(mock_server(cfg, 4));
        let mut handles = Vec::new();
        let per_thread = 50;
        for t in 0..4usize {
            let s = server.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per_thread {
                    let out = s.infer(t, &input(i as f32)).unwrap();
                    assert_eq!(out[1], t as f32, "routed to wrong task model");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = server.metrics();
        assert_eq!(m.completed, 4 * per_thread as u64);
        assert_eq!(m.failed, 0);
        assert!(m.batches <= m.completed);
        assert!(m.mean_batch_size >= 1.0);
    }

    #[test]
    fn backpressure_rejects_when_queue_full() {
        // Slow backend + tiny queue: the second wave must be rejected.
        struct SlowBackend;
        impl Backend for SlowBackend {
            fn infer(&mut self, _t: usize, _x: &Tensor, n: usize) -> Result<Vec<Vec<f32>>> {
                std::thread::sleep(Duration::from_millis(50));
                Ok(vec![vec![0.0]; n])
            }
        }
        let cfg = ServerConfig {
            max_batch: 1,
            max_delay: Duration::from_millis(0),
            queue_cap: 1,
            executors: 1,
        };
        let server =
            Server::start_with_backend(cfg, &VIT_S, 1, || Ok(SlowBackend)).unwrap();
        let mut rejected = 0;
        let mut pending = Vec::new();
        for _ in 0..20 {
            match server.submit(0, &input(0.0)) {
                Ok(rx) => pending.push(rx),
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected > 0, "expected backpressure rejections");
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        assert_eq!(server.metrics().rejected, rejected);
    }

    #[test]
    fn shutdown_completes_in_flight_work() {
        let mut server = mock_server(
            ServerConfig { max_delay: Duration::from_millis(20), ..Default::default() },
            1,
        );
        let rx = server.submit(0, &input(2.0)).unwrap();
        server.shutdown();
        // The staged request was drained and answered before exit.
        let out = rx.recv().unwrap().unwrap();
        assert_eq!(out[1], 0.0);
        // Submitting after shutdown fails.
        assert!(server.submit(0, &input(0.0)).is_err());
    }

    #[test]
    fn backend_error_propagates_to_all_batch_members() {
        struct FailBackend;
        impl Backend for FailBackend {
            fn infer(&mut self, _t: usize, _x: &Tensor, _n: usize) -> Result<Vec<Vec<f32>>> {
                anyhow::bail!("injected failure")
            }
        }
        let server =
            Server::start_with_backend(ServerConfig::default(), &VIT_S, 1, || Ok(FailBackend))
                .unwrap();
        let err = server.infer(0, &input(0.0)).unwrap_err();
        assert!(err.to_string().contains("injected failure"));
        assert_eq!(server.metrics().failed, 1);
    }
}
