//! The running service: ingress queue → router thread → executor pool.
//!
//! The PJRT [`Runtime`](crate::runtime::Runtime) is `!Send`, so each
//! executor thread constructs its own client/backend via a factory; the
//! merged model and heads are plain data and shared by `Arc`.  The
//! executor side is abstracted behind [`Backend`] so the threading and
//! batching machinery is unit-testable without PJRT.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::batcher::{Batch, Batcher};
use super::metrics::{Metrics, MetricsSnapshot};
use super::{bucket_chunks, pick_bucket};
use crate::data::VitPreset;
use crate::obs::trace;
use crate::merge::MergedModel;
use crate::tensor::Tensor;

/// Everything an executor needs to serve one deployment (all `Send`).
#[derive(Clone)]
pub struct ServeModel {
    pub preset: &'static VitPreset,
    pub merged: Arc<MergedModel>,
    /// Per-task classification heads (frozen, as in the paper: only the
    /// trunk is merged).
    pub heads: Arc<Vec<Tensor>>,
}

impl ServeModel {
    pub fn n_tasks(&self) -> usize {
        self.heads.len()
    }
}

/// Server tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Max requests per formed batch.  May exceed the largest AOT
    /// bucket: executors split oversized batches across buckets.
    pub max_batch: usize,
    /// Max time a request may wait for batch-mates.
    pub max_delay: Duration,
    /// Ingress queue capacity; beyond this, `submit` rejects (backpressure).
    pub queue_cap: usize,
    /// Per-task staged-request cap inside the router's batcher; beyond
    /// it requests are answered with [`ServeError::Overloaded`] instead
    /// of letting one hot task absorb the whole ingress queue.
    pub task_queue_cap: usize,
    /// Executor threads (each owns a PJRT client).
    pub executors: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            max_batch: 32,
            max_delay: Duration::from_millis(2),
            queue_cap: 1024,
            task_queue_cap: 1024,
            executors: 2,
        }
    }
}

/// Typed per-request serving failures (what comes back on the response
/// channel when a request cannot be answered with logits).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The task's staged queue hit `task_queue_cap`: shed load, retry.
    Overloaded { task: usize },
    /// The preset exposes no serve buckets at all (misconfiguration).
    NoServeBucket { batch: usize },
    /// The backend failed; the rendered error chain is retained.
    Backend(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded { task } => {
                write!(f, "task {task} queue is full (per-task backpressure)")
            }
            ServeError::NoServeBucket { batch } => {
                write!(f, "no serve bucket can hold a batch of {batch}")
            }
            ServeError::Backend(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Response payload: logits for one request.
pub type InferResult = Result<Vec<f32>, ServeError>;

/// What executors actually run. `infer` receives a padded `[bucket,
/// tokens, token_dim]` tensor plus the number of valid rows and returns
/// one logits vector per valid row.
pub trait Backend {
    fn infer(&mut self, task: usize, x: &Tensor, n_valid: usize) -> Result<Vec<Vec<f32>>>;
}

/// The production backend: bucketed forward artifacts through PJRT.
pub struct PjrtBackend {
    rt: crate::runtime::Runtime,
    model: ServeModel,
}

impl PjrtBackend {
    pub fn new(model: ServeModel) -> Result<Self> {
        Ok(Self { rt: crate::runtime::Runtime::new()?, model })
    }
}

impl Backend for PjrtBackend {
    fn infer(&mut self, task: usize, x: &Tensor, n_valid: usize) -> Result<Vec<Vec<f32>>> {
        let b = x.shape()[0];
        let art = self
            .rt
            .load(&format!("{}_forward_b{}", self.model.preset.name, b))?;
        let logits = crate::runtime::forward_logits(
            &art,
            self.model.merged.for_task(task),
            &self.model.heads[task],
            x,
        )?;
        let c = *logits.shape().last().unwrap();
        Ok(logits
            .data()
            .chunks_exact(c)
            .take(n_valid)
            .map(|row| row.to_vec())
            .collect())
    }
}

struct SubmitItem {
    x: Vec<f32>,
    resp: SyncSender<InferResult>,
    submitted: Instant,
}

/// A running multi-task inference service.
pub struct Server {
    ingress: Option<SyncSender<(usize, SubmitItem)>>,
    metrics: Arc<Metrics>,
    preset: &'static VitPreset,
    n_tasks: usize,
    router: Option<JoinHandle<()>>,
    executors: Vec<JoinHandle<()>>,
}

impl Server {
    /// Start serving `model` with PJRT executors.
    pub fn start(cfg: ServerConfig, model: ServeModel) -> Result<Server> {
        let preset = model.preset;
        let n_tasks = model.n_tasks();
        Self::start_with_backend(cfg, preset, n_tasks, move || PjrtBackend::new(model.clone()))
    }

    /// Start with a custom backend factory (one backend per executor
    /// thread) — the seam tests use to run without PJRT.
    pub fn start_with_backend<B, F>(
        cfg: ServerConfig,
        preset: &'static VitPreset,
        n_tasks: usize,
        factory: F,
    ) -> Result<Server>
    where
        B: Backend + 'static,
        F: Fn() -> Result<B> + Send + Sync + 'static,
    {
        if cfg.executors == 0 {
            bail!("need at least one executor");
        }
        if preset.serve_buckets.is_empty() {
            bail!("preset has no serve buckets");
        }
        // Not clamped to the largest bucket: executors split oversized
        // batches across buckets (`bucket_chunks`), so a max_batch above
        // it just means fewer, larger router flushes.
        let max_batch = cfg.max_batch.max(1);

        let metrics = Arc::new(Metrics::new());
        let (ingress_tx, ingress_rx) =
            mpsc::sync_channel::<(usize, SubmitItem)>(cfg.queue_cap.max(1));
        let (batch_tx, batch_rx) =
            mpsc::sync_channel::<Batch<SubmitItem>>(cfg.executors * 2);
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        // Router thread: stage + flush.
        let router_metrics = metrics.clone();
        let max_delay = cfg.max_delay;
        let task_queue_cap = cfg.task_queue_cap.max(1);
        let router = std::thread::Builder::new()
            .name("tvq-router".into())
            .spawn(move || {
                router_loop(
                    ingress_rx,
                    batch_tx,
                    n_tasks,
                    max_batch,
                    max_delay,
                    task_queue_cap,
                    router_metrics,
                )
            })?;

        // Executor pool.
        let factory = Arc::new(factory);
        let mut executors = Vec::with_capacity(cfg.executors);
        for i in 0..cfg.executors {
            let rx = batch_rx.clone();
            let m = metrics.clone();
            let f = factory.clone();
            executors.push(
                std::thread::Builder::new()
                    .name(format!("tvq-exec-{i}"))
                    .spawn(move || executor_loop(rx, preset, f.as_ref(), m))?,
            );
        }

        Ok(Server {
            ingress: Some(ingress_tx),
            metrics,
            preset,
            n_tasks,
            router: Some(router),
            executors,
        })
    }

    /// Submit one request; returns a one-shot receiver for the logits.
    /// Errors immediately on invalid input or a full queue (backpressure).
    pub fn submit(&self, task: usize, x: &Tensor) -> Result<Receiver<InferResult>> {
        if task >= self.n_tasks {
            bail!("task {task} out of range ({} tasks)", self.n_tasks);
        }
        let want = self.preset.tokens * self.preset.token_dim;
        if x.numel() != want {
            bail!("input has {} values, expected {want}", x.numel());
        }
        let (resp_tx, resp_rx) = mpsc::sync_channel(1);
        let item = SubmitItem {
            x: x.data().to_vec(),
            resp: resp_tx,
            submitted: Instant::now(),
        };
        let ingress = self
            .ingress
            .as_ref()
            .ok_or_else(|| anyhow!("server is shut down"))?;
        match ingress.try_send((task, item)) {
            Ok(()) => {
                self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(resp_rx)
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                bail!("queue full ({} pending)", self.metrics.snapshot().submitted)
            }
            Err(TrySendError::Disconnected(_)) => bail!("server is shut down"),
        }
    }

    /// Blocking convenience: submit and wait for the logits.
    pub fn infer(&self, task: usize, x: &Tensor) -> Result<Vec<f32>> {
        let rx = self.submit(task, x)?;
        rx.recv()
            .map_err(|_| anyhow!("server dropped response"))?
            .map_err(|e| anyhow!(e))
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Shared handle to the live metrics registry — the watch stream
    /// samples it on its own cadence instead of snapshotting per
    /// request.
    pub fn metrics_handle(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// Reset latency/batch windows (e.g. after a warmup phase).
    pub fn reset_metrics_window(&self) {
        self.metrics.reset_window();
    }

    /// Graceful shutdown: drain staged requests, then join all threads.
    pub fn shutdown(&mut self) {
        self.ingress = None; // disconnects the router's ingress
        if let Some(r) = self.router.take() {
            let _ = r.join();
        }
        for h in self.executors.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Stage `item` for `task`, answering with a typed `Overloaded`
/// rejection when the task's queue is at cap (per-task backpressure —
/// one hot task cannot absorb the whole ingress queue).
fn stage(
    batcher: &mut Batcher<SubmitItem>,
    task: usize,
    item: SubmitItem,
    metrics: &Metrics,
) {
    let at = item.submitted;
    if let Err(item) = batcher.try_push(task, at, item) {
        metrics.rejected.fetch_add(1, Ordering::Relaxed);
        let _ = item.resp.send(Err(ServeError::Overloaded { task }));
    }
}

fn router_loop(
    ingress: Receiver<(usize, SubmitItem)>,
    batch_tx: SyncSender<Batch<SubmitItem>>,
    n_tasks: usize,
    max_batch: usize,
    max_delay: Duration,
    task_queue_cap: usize,
    metrics: Arc<Metrics>,
) {
    let mut batcher: Batcher<SubmitItem> =
        Batcher::with_queue_cap(n_tasks, max_batch, max_delay, task_queue_cap);
    loop {
        // Sleep until the next deadline (or idle-poll at max_delay).
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(max_delay.max(Duration::from_millis(1)));
        match ingress.recv_timeout(timeout) {
            Ok((task, item)) => {
                stage(&mut batcher, task, item, &metrics);
                // Opportunistically drain everything already queued.
                while let Ok((task, item)) = ingress.try_recv() {
                    stage(&mut batcher, task, item, &metrics);
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                for b in batcher.drain_all() {
                    if batch_tx.send(b).is_err() {
                        return;
                    }
                }
                return; // dropping batch_tx stops the executors
            }
        }
        let now = Instant::now();
        while let Some(b) = batcher.pop_ready(now) {
            if batch_tx.send(b).is_err() {
                return;
            }
        }
    }
}

fn executor_loop<B, F>(
    rx: Arc<Mutex<Receiver<Batch<SubmitItem>>>>,
    preset: &'static VitPreset,
    factory: &F,
    metrics: Arc<Metrics>,
) where
    B: Backend,
    F: Fn() -> Result<B>,
{
    let mut backend = match factory() {
        Ok(b) => b,
        Err(e) => {
            eprintln!("[coordinator] backend init failed: {e:#}");
            return;
        }
    };
    let img = preset.tokens * preset.token_dim;
    loop {
        let batch = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(b) => b,
                Err(_) => return, // router gone: shutdown
            }
        };
        let n = batch.items.len();
        // A batch larger than the biggest AOT bucket is split into
        // bucket-sized chunks and served back-to-back; `None` only when
        // the preset has no buckets at all (guarded at start, but keep
        // the typed rejection rather than a panic).
        let chunk_sizes = match bucket_chunks(preset.serve_buckets, n) {
            Some(c) => c,
            None => {
                for s in batch.items {
                    let _ = s.payload.resp.send(Err(ServeError::NoServeBucket { batch: n }));
                    metrics.failed.fetch_add(1, Ordering::Relaxed);
                }
                continue;
            }
        };
        let mut remaining = batch.items;
        for chunk_len in chunk_sizes {
            let rest = remaining.split_off(chunk_len);
            let chunk = std::mem::replace(&mut remaining, rest);
            let bucket = pick_bucket(preset.serve_buckets, chunk_len)
                .expect("bucket_chunks only emits servable chunk sizes");
            // Pack (padded) input tensor.  Pickup time is the end of
            // each item's queue wait (submit -> executor).
            let mut x = Tensor::zeros(&[bucket, preset.tokens, preset.token_dim]);
            for (i, s) in chunk.iter().enumerate() {
                metrics.record_queue_wait(s.payload.submitted.elapsed());
                x.data_mut()[i * img..(i + 1) * img].copy_from_slice(&s.payload.x);
            }
            metrics.record_batch(chunk_len);
            let infer_span = trace::span(trace::Category::Serve, "infer_batch")
                .with_arg("items", chunk_len as u64);
            let inferred = backend.infer(batch.task, &x, chunk_len);
            drop(infer_span);
            match inferred {
                Ok(rows) => {
                    for (s, row) in chunk.into_iter().zip(rows) {
                        metrics.completed.fetch_add(1, Ordering::Relaxed);
                        metrics.record_latency(s.payload.submitted.elapsed());
                        let _ = s.payload.resp.send(Ok(row));
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    for s in chunk {
                        metrics.failed.fetch_add(1, Ordering::Relaxed);
                        let _ = s.payload.resp.send(Err(ServeError::Backend(msg.clone())));
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::VIT_S;

    /// Test backend: logits row = [sum(x_i), task as f32].
    struct MockBackend;

    impl Backend for MockBackend {
        fn infer(&mut self, task: usize, x: &Tensor, n_valid: usize) -> Result<Vec<Vec<f32>>> {
            let img = x.numel() / x.shape()[0];
            Ok((0..n_valid)
                .map(|i| {
                    let s: f32 = x.data()[i * img..(i + 1) * img].iter().sum();
                    vec![s, task as f32]
                })
                .collect())
        }
    }

    fn mock_server(cfg: ServerConfig, n_tasks: usize) -> Server {
        Server::start_with_backend(cfg, &VIT_S, n_tasks, || Ok(MockBackend)).unwrap()
    }

    fn input(v: f32) -> Tensor {
        Tensor::full(&[VIT_S.tokens, VIT_S.token_dim], v)
    }

    #[test]
    fn serves_single_request() {
        let server = mock_server(ServerConfig::default(), 2);
        let out = server.infer(1, &input(1.0)).unwrap();
        let img = (VIT_S.tokens * VIT_S.token_dim) as f32;
        assert_eq!(out, vec![img, 1.0]);
        let m = server.metrics();
        assert_eq!(m.completed, 1);
        assert_eq!(m.failed, 0);
        assert_eq!(m.latency_count, 1);
        assert_eq!(m.queue_wait.count, 1, "executor records queue wait per item");
    }

    #[test]
    fn rejects_bad_task_and_shape() {
        let server = mock_server(ServerConfig::default(), 2);
        assert!(server.submit(5, &input(0.0)).is_err());
        assert!(server.submit(0, &Tensor::zeros(&[3])).is_err());
        assert_eq!(server.metrics().completed, 0);
    }

    #[test]
    fn concurrent_load_conserves_requests() {
        let cfg = ServerConfig {
            max_batch: 8,
            max_delay: Duration::from_millis(1),
            queue_cap: 4096,
            executors: 3,
            ..Default::default()
        };
        let server = Arc::new(mock_server(cfg, 4));
        let mut handles = Vec::new();
        let per_thread = 50;
        for t in 0..4usize {
            let s = server.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per_thread {
                    let out = s.infer(t, &input(i as f32)).unwrap();
                    assert_eq!(out[1], t as f32, "routed to wrong task model");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = server.metrics();
        assert_eq!(m.completed, 4 * per_thread as u64);
        assert_eq!(m.failed, 0);
        assert!(m.batches <= m.completed);
        assert!(m.mean_batch_size >= 1.0);
    }

    #[test]
    fn backpressure_rejects_when_queue_full() {
        // Slow backend + tiny queue: the second wave must be rejected.
        struct SlowBackend;
        impl Backend for SlowBackend {
            fn infer(&mut self, _t: usize, _x: &Tensor, n: usize) -> Result<Vec<Vec<f32>>> {
                std::thread::sleep(Duration::from_millis(50));
                Ok(vec![vec![0.0]; n])
            }
        }
        let cfg = ServerConfig {
            max_batch: 1,
            max_delay: Duration::from_millis(0),
            queue_cap: 1,
            executors: 1,
            ..Default::default()
        };
        let server =
            Server::start_with_backend(cfg, &VIT_S, 1, || Ok(SlowBackend)).unwrap();
        let mut rejected = 0;
        let mut pending = Vec::new();
        for _ in 0..20 {
            match server.submit(0, &input(0.0)) {
                Ok(rx) => pending.push(rx),
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected > 0, "expected backpressure rejections");
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        assert_eq!(server.metrics().rejected, rejected);
    }

    #[test]
    fn shutdown_completes_in_flight_work() {
        let mut server = mock_server(
            ServerConfig { max_delay: Duration::from_millis(20), ..Default::default() },
            1,
        );
        let rx = server.submit(0, &input(2.0)).unwrap();
        server.shutdown();
        // The staged request was drained and answered before exit.
        let out = rx.recv().unwrap().unwrap();
        assert_eq!(out[1], 0.0);
        // Submitting after shutdown fails.
        assert!(server.submit(0, &input(0.0)).is_err());
    }

    #[test]
    fn oversized_batches_split_across_buckets_and_all_complete() {
        // max_batch 40 exceeds VIT_S's largest bucket (32): the router
        // may form a 40-item batch, which the executor must serve as
        // bucket-sized chunks (32 + 8) rather than erroring.
        let max_bucket = *VIT_S.serve_buckets.iter().max().unwrap();
        let total = max_bucket + 8;
        let cfg = ServerConfig {
            max_batch: total,
            // Large delay so all submissions coalesce into one flush.
            max_delay: Duration::from_millis(200),
            queue_cap: 4096,
            executors: 1,
            ..Default::default()
        };
        let server = mock_server(cfg, 1);
        let img = (VIT_S.tokens * VIT_S.token_dim) as f32;
        let pending: Vec<_> =
            (0..total).map(|i| server.submit(0, &input(i as f32)).unwrap()).collect();
        for (i, rx) in pending.into_iter().enumerate() {
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(out, vec![i as f32 * img, 0.0], "request {i} got wrong logits");
        }
        let m = server.metrics();
        assert_eq!(m.completed, total as u64);
        assert_eq!(m.failed, 0);
        assert!(m.batches >= 2, "expected the batch to split, got {} chunk(s)", m.batches);
    }

    #[test]
    fn per_task_queue_cap_rejects_with_typed_error() {
        // Block the single executor so staged requests pile up in the
        // router's batcher, then overflow one task's bounded queue.
        struct SlowBackend;
        impl Backend for SlowBackend {
            fn infer(&mut self, _t: usize, _x: &Tensor, n: usize) -> Result<Vec<Vec<f32>>> {
                std::thread::sleep(Duration::from_millis(30));
                Ok(vec![vec![0.0]; n])
            }
        }
        let cfg = ServerConfig {
            max_batch: 1,
            max_delay: Duration::from_millis(0),
            queue_cap: 512,
            task_queue_cap: 2,
            executors: 1,
        };
        let server =
            Server::start_with_backend(cfg, &VIT_S, 1, || Ok(SlowBackend)).unwrap();
        let mut pending = Vec::new();
        let mut overloaded = 0u64;
        for _ in 0..64 {
            // submit() itself stays Ok (ingress has room); rejections
            // arrive typed on the response channel from the router.
            pending.push(server.submit(0, &input(0.0)).unwrap());
        }
        for rx in pending {
            match rx.recv().unwrap() {
                Ok(_) => {}
                Err(ServeError::Overloaded { task }) => {
                    assert_eq!(task, 0);
                    overloaded += 1;
                }
                Err(other) => panic!("unexpected error: {other}"),
            }
        }
        assert!(overloaded > 0, "expected per-task overload rejections");
        assert_eq!(server.metrics().rejected, overloaded);
    }

    #[test]
    fn backend_error_propagates_to_all_batch_members() {
        struct FailBackend;
        impl Backend for FailBackend {
            fn infer(&mut self, _t: usize, _x: &Tensor, _n: usize) -> Result<Vec<Vec<f32>>> {
                anyhow::bail!("injected failure")
            }
        }
        let server =
            Server::start_with_backend(ServerConfig::default(), &VIT_S, 1, || Ok(FailBackend))
                .unwrap();
        let err = server.infer(0, &input(0.0)).unwrap_err();
        assert!(err.to_string().contains("injected failure"));
        assert_eq!(server.metrics().failed, 1);
    }
}
