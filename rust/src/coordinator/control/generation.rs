//! Generation-pinned registry serving: atomic rename-swap + reload.
//!
//! A serving path (`/srv/models/zoo.qtvc`) outlives any single file at
//! that path.  [`GenerationalRegistry`] models this directly: each opened
//! file is a numbered [`Generation`] holding its own
//! [`Registry`](crate::registry::Registry) (and therefore its own file
//! mapping or handle — which pins the **inode**, not the path).  The swap
//! protocol:
//!
//! 1. The publisher writes the replacement registry to the staged path
//!    `<path>.next` ([`GenerationalRegistry::stage_path`]) on the same
//!    filesystem.
//! 2. [`publish_staged`](GenerationalRegistry::publish_staged) validates
//!    that the staged file opens as a registry, atomically
//!    `rename(2)`s it over the serving path, and re-opens the path as
//!    generation N+1.  Validation happens **before** the rename — a
//!    corrupt stage never replaces a healthy registry, and a failed
//!    publish leaves generation N serving untouched.
//! 3. New work pins generation N+1 ([`pin`](GenerationalRegistry::pin));
//!    in-flight work keeps reading generation N bit-exactly through its
//!    own `Arc<Generation>` — the old inode stays alive under the rename.
//! 4. When the last pin drops, the `Arc` frees the old `Registry`, whose
//!    `Mmap` RAII guard unmaps the old file — refcount-zero unmap, with
//!    no explicit epoch machinery.
//!
//! This is exactly the mutation discipline `docs/WIRE_FORMAT.md` §7
//! mandates ("replace by rename, never modify in place"), promoted from a
//! hazard warning to the supported reload mechanism.
//!
//! Pinning requires an inode-holding I/O mode: `Mmap` and `Pread` both
//! qualify (mapping / file handle survive the rename).  `Reopen` mode
//! re-opens the *path* per section read and would observe the new file
//! mid-request, so [`GenerationalRegistry::open_with`] refuses it.
//!
//! Sharded zoos get the same discipline through
//! [`GenerationalManifest`]: the `MANIFEST.qtvm` file is the unit of
//! swap (staged at `MANIFEST.qtvm.next`, validated, renamed), while the
//! shard files it references are immutable and content-addressed —
//! publishers add new shard files rather than rewriting old ones, and
//! every chunk read is CRC- and content-hash-verified, so a manifest
//! can never silently serve bytes from the wrong shard vintage.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, Weak};

use anyhow::{bail, Context, Result};

use crate::obs;
use crate::registry::{
    IoMode, OpenOptions, PackedRegistrySource, Registry, ShardedRegistry, ShardedSource, Validation,
};
use crate::util::exec::ExecCtx;

/// Suffix of the staged next-generation file: publishing renames
/// `<path>.next` over `<path>`.
pub const STAGE_SUFFIX: &str = ".next";

/// One opened registry file, numbered within its serving path.  Holding
/// an `Arc<Generation>` pins the underlying mapping/handle: reads through
/// it are bit-exact against this file even after the path is swapped.
pub struct Generation {
    number: u64,
    source: PackedRegistrySource,
}

impl Generation {
    /// Monotonic generation number (the first open is generation 1).
    pub fn number(&self) -> u64 {
        self.number
    }

    /// The generation's registry as a merge-ready task-vector source.
    pub fn source(&self) -> &PackedRegistrySource {
        &self.source
    }

    pub fn registry(&self) -> &Registry {
        self.source.registry()
    }
}

/// A serving path plus its current (and still-pinned past) generations.
pub struct GenerationalRegistry {
    path: PathBuf,
    current: Mutex<Arc<Generation>>,
    /// Weak handles to every generation ever installed, oldest first.
    /// Upgradeable entries are still pinned by in-flight work; the
    /// history is how tests (and status) observe refcount-zero unmap.
    history: Mutex<Vec<Weak<Generation>>>,
    /// Serializes publish/reload: open-validate-rename-install must not
    /// interleave between two publishers.
    publish_lock: Mutex<()>,
}

impl GenerationalRegistry {
    /// Open `path` as generation 1 with the default [`OpenOptions`]
    /// (`Mmap`, degrading to `Pread` — both inode-pinning).
    pub fn open<P: AsRef<Path>>(path: P) -> Result<GenerationalRegistry> {
        Self::open_with(path, OpenOptions::default())
    }

    /// [`open`](Self::open) with explicit [`OpenOptions`].
    /// `IoMode::Reopen` is refused: per-read path opens cannot pin a
    /// generation across a rename-swap (a swapped path would feed a new
    /// file to an old generation's in-flight reads).
    pub fn open_with<P: AsRef<Path>>(path: P, opts: OpenOptions) -> Result<GenerationalRegistry> {
        let path = path.as_ref().to_path_buf();
        if opts.io_mode() == IoMode::Reopen {
            bail!(
                "IoMode::Reopen re-opens the path per read and cannot pin a \
                 generation across a rename-swap; use Mmap or Pread for {}",
                path.display()
            );
        }
        let registry = Registry::open_with(&path, opts)?;
        if registry.io_mode() == IoMode::Reopen {
            bail!(
                "registry {} fell back to IoMode::Reopen on this platform; \
                 generational serving needs an inode-pinning mode (Mmap/Pread)",
                path.display()
            );
        }
        let first = Arc::new(Generation {
            number: 1,
            source: PackedRegistrySource::from_registry(registry),
        });
        Ok(GenerationalRegistry {
            path,
            history: Mutex::new(vec![Arc::downgrade(&first)]),
            current: Mutex::new(first),
            publish_lock: Mutex::new(()),
        })
    }

    /// [`open`](Self::open) with an explicit [`IoMode`] — the PR-6
    /// spelling, superseded by [`open_with`](Self::open_with).
    #[deprecated(note = "use GenerationalRegistry::open_with(path, OpenOptions::new().io(mode))")]
    pub fn open_with_io<P: AsRef<Path>>(path: P, mode: IoMode) -> Result<GenerationalRegistry> {
        Self::open_with(path, OpenOptions::new().io(mode))
    }

    /// The serving path (what clients name; individual generations are
    /// anonymous inodes behind it).
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Where the next generation is staged: `<path>.next` on the same
    /// filesystem, so the publish rename is atomic.
    pub fn stage_path(&self) -> PathBuf {
        let mut os = self.path.as_os_str().to_os_string();
        os.push(STAGE_SUFFIX);
        PathBuf::from(os)
    }

    /// Pin the current generation for one unit of work.  The returned
    /// `Arc` keeps that generation's mapping alive (and its reads
    /// bit-exact) until dropped, regardless of concurrent publishes.
    pub fn pin(&self) -> Arc<Generation> {
        self.current.lock().unwrap().clone()
    }

    /// The current generation number.
    pub fn generation(&self) -> u64 {
        self.current.lock().unwrap().number
    }

    /// Numbers of the generations still alive — the current one plus any
    /// older ones pinned by in-flight work.  Prunes dead history as a
    /// side effect; a single-element result means every superseded
    /// mapping has been unmapped.
    pub fn live_generations(&self) -> Vec<u64> {
        let mut history = self.history.lock().unwrap();
        history.retain(|w| w.strong_count() > 0);
        history.iter().filter_map(|w| w.upgrade()).map(|g| g.number).collect()
    }

    /// Publish the staged file (`<path>.next`): validate, rename over the
    /// serving path, install as generation N+1.  In-flight pins of
    /// generation N are unaffected.  On error nothing changes and the
    /// staged file is left in place for inspection.
    pub fn publish_staged(&self) -> Result<u64> {
        self.publish_file(&self.stage_path())
    }

    /// [`publish_staged`](Self::publish_staged) for an arbitrary staged
    /// path (must be on the serving path's filesystem for the rename to
    /// be atomic).
    pub fn publish_file(&self, staged: &Path) -> Result<u64> {
        let _publishing = self.publish_lock.lock().unwrap();
        let _span = obs::span(obs::Category::Control, "publish");
        // Validate before touching the serving path: a corrupt stage must
        // never replace a healthy registry.  Reopen mode avoids holding a
        // second mapping of a file we are about to rename.
        Registry::open_with(staged, OpenOptions::new().io(IoMode::Reopen))
            .with_context(|| format!("validating staged registry {}", staged.display()))?;
        std::fs::rename(staged, &self.path).with_context(|| {
            format!("renaming {} over {}", staged.display(), self.path.display())
        })?;
        self.install_next().with_context(|| {
            format!(
                "staged registry published over {} but re-opening it failed; \
                 the previous generation keeps serving its (renamed-away) inode",
                self.path.display()
            )
        })
    }

    /// Re-open the serving path in place as generation N+1 (the path was
    /// replaced externally — e.g. by an orchestrator's own rename).  The
    /// new file is opened **before** the swap is visible to new work, so
    /// a broken file fails the reload and generation N keeps serving.
    pub fn reload(&self) -> Result<u64> {
        let _publishing = self.publish_lock.lock().unwrap();
        self.install_next()
    }

    /// Open the serving path at the originally *requested* I/O mode and
    /// make it current.  Caller holds `publish_lock`.
    fn install_next(&self) -> Result<u64> {
        let _span = obs::span(obs::Category::Control, "install_generation");
        let next = {
            let current = self.current.lock().unwrap();
            // Generation-aware reopen: same path, same requested mode,
            // fallbacks re-evaluated for the new file.
            let registry = current.registry().reopen()?;
            Arc::new(Generation {
                number: current.number + 1,
                source: PackedRegistrySource::from_registry(registry),
            })
        };
        let number = next.number;
        self.history.lock().unwrap().push(Arc::downgrade(&next));
        *self.current.lock().unwrap() = next;
        Ok(number)
    }
}

/// One opened sharded-zoo manifest, numbered within its serving path.
/// The `Arc<ManifestGeneration>` pins the opened [`ShardedRegistry`]
/// (manifest index pages, decoded base cache, any opened shard handles);
/// the shard files themselves are immutable, so a pin stays bit-exact
/// even while newer manifests are published beside it.
pub struct ManifestGeneration {
    number: u64,
    reg: Arc<ShardedRegistry>,
}

impl ManifestGeneration {
    /// Monotonic generation number (the first open is generation 1).
    pub fn number(&self) -> u64 {
        self.number
    }

    pub fn registry(&self) -> &ShardedRegistry {
        &self.reg
    }

    /// The generation's sharded zoo as a merge-ready task-vector source.
    pub fn source(&self) -> ShardedSource {
        ShardedSource::new(self.reg.clone())
    }
}

/// [`GenerationalRegistry`]'s twin for sharded zoos: the serving path is
/// a `MANIFEST.qtvm`, the staged next generation is `MANIFEST.qtvm.next`
/// in the same directory, and publishing validates-then-renames exactly
/// like the packed-file swap.  Validation opens the staged manifest as a
/// tier-0 [`ShardedRegistry`] at [`Validation::Deep`] — every referenced
/// chunk is fetched and CRC/content-hash checked — so a manifest naming
/// a missing shard, a truncated page, or a stale chunk address can never
/// replace a healthy generation.
///
/// Shard files are *not* part of the swap: they are content-addressed
/// and immutable, so successive generations may share them (dedup across
/// publishes), and a publisher only ever adds new ones.
pub struct GenerationalManifest {
    path: PathBuf,
    opts: OpenOptions,
    current: Mutex<Arc<ManifestGeneration>>,
    history: Mutex<Vec<Weak<ManifestGeneration>>>,
    publish_lock: Mutex<()>,
}

impl GenerationalManifest {
    /// Open `path` (a `MANIFEST.qtvm`) as generation 1 with the default
    /// [`OpenOptions`].
    pub fn open<P: AsRef<Path>>(path: P) -> Result<GenerationalManifest> {
        Self::open_with(path, OpenOptions::default())
    }

    /// [`open`](Self::open) with explicit [`OpenOptions`].  `Reopen` is
    /// refused for the same reason as
    /// [`GenerationalRegistry::open_with`]: shard reads must pin inodes,
    /// not paths, across a publish.
    pub fn open_with<P: AsRef<Path>>(path: P, opts: OpenOptions) -> Result<GenerationalManifest> {
        let path = path.as_ref().to_path_buf();
        if opts.io_mode() == IoMode::Reopen {
            bail!(
                "IoMode::Reopen re-opens shard paths per read and cannot pin a \
                 generation across a manifest swap; use Mmap or Pread for {}",
                path.display()
            );
        }
        let reg = ShardedRegistry::open_with(&path, opts)?;
        let first = Arc::new(ManifestGeneration { number: 1, reg: Arc::new(reg) });
        Ok(GenerationalManifest {
            path,
            opts,
            history: Mutex::new(vec![Arc::downgrade(&first)]),
            current: Mutex::new(first),
            publish_lock: Mutex::new(()),
        })
    }

    /// The serving manifest path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Where the next manifest is staged: `<path>.next` in the manifest's
    /// own directory, so the publish rename is atomic and the staged
    /// manifest resolves shard names against the same shard set.
    pub fn stage_path(&self) -> PathBuf {
        let mut os = self.path.as_os_str().to_os_string();
        os.push(STAGE_SUFFIX);
        PathBuf::from(os)
    }

    /// Pin the current generation for one unit of work.
    pub fn pin(&self) -> Arc<ManifestGeneration> {
        self.current.lock().unwrap().clone()
    }

    /// The current generation number.
    pub fn generation(&self) -> u64 {
        self.current.lock().unwrap().number
    }

    /// Numbers of the generations still alive (current plus any pinned
    /// older ones), pruning dead history as a side effect.
    pub fn live_generations(&self) -> Vec<u64> {
        let mut history = self.history.lock().unwrap();
        history.retain(|w| w.strong_count() > 0);
        history.iter().filter_map(|w| w.upgrade()).map(|g| g.number).collect()
    }

    /// Publish the staged manifest (`<path>.next`): deep-validate it
    /// against the shard set, rename over the serving path, install as
    /// generation N+1.  On error nothing changes and the staged file is
    /// left in place for inspection.
    pub fn publish_staged(&self) -> Result<u64> {
        self.publish_file(&self.stage_path())
    }

    /// [`publish_staged`](Self::publish_staged) for an arbitrary staged
    /// manifest path (must be in the serving manifest's directory: the
    /// rename must be atomic and shard names resolve relative to the
    /// manifest).
    pub fn publish_file(&self, staged: &Path) -> Result<u64> {
        let _publishing = self.publish_lock.lock().unwrap();
        let _span = obs::span(obs::Category::Control, "publish_manifest");
        // Deep validation fetches and verifies every chunk the staged
        // manifest references — Reopen mode so no shard mapping outlives
        // the check.
        ShardedRegistry::open_with(
            staged,
            OpenOptions::new().io(IoMode::Reopen).validation(Validation::Deep),
        )
        .with_context(|| format!("validating staged manifest {}", staged.display()))?;
        std::fs::rename(staged, &self.path).with_context(|| {
            format!("renaming {} over {}", staged.display(), self.path.display())
        })?;
        self.install_next().with_context(|| {
            format!(
                "staged manifest published over {} but re-opening it failed; \
                 the previous generation keeps serving its pinned shards",
                self.path.display()
            )
        })
    }

    /// Re-open the serving manifest in place as generation N+1 (the path
    /// was replaced externally).
    pub fn reload(&self) -> Result<u64> {
        let _publishing = self.publish_lock.lock().unwrap();
        self.install_next()
    }

    fn install_next(&self) -> Result<u64> {
        let _span = obs::span(obs::Category::Control, "install_manifest_generation");
        let next = {
            let current = self.current.lock().unwrap();
            let reg = ShardedRegistry::open_with(&self.path, self.opts)?;
            Arc::new(ManifestGeneration { number: current.number + 1, reg: Arc::new(reg) })
        };
        let number = next.number;
        self.history.lock().unwrap().push(Arc::downgrade(&next));
        *self.current.lock().unwrap() = next;
        Ok(number)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exp::planner::synthetic_planner_zoo;
    use crate::quant::QuantScheme;
    use crate::registry::build_registry;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("tvq-gen-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn pack(dir: &Path, name: &str, seed: u64) -> PathBuf {
        let (pre, fts) = synthetic_planner_zoo(3, seed);
        let path = dir.join(name);
        build_registry(&pre, &fts, QuantScheme::Tvq(4), &path).unwrap();
        path
    }

    #[test]
    fn reopen_mode_is_refused() {
        let dir = tmpdir("reject-reopen");
        let path = pack(&dir, "zoo.qtvc", 1);
        let err = GenerationalRegistry::open_with(&path, OpenOptions::new().io(IoMode::Reopen))
            .unwrap_err();
        assert!(err.to_string().contains("Reopen"), "{err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn publish_staged_advances_generation_and_pins_hold_old_data() {
        let dir = tmpdir("publish");
        let path = pack(&dir, "zoo.qtvc", 1);
        let served = GenerationalRegistry::open(&path).unwrap();
        assert_eq!(served.generation(), 1);

        // Pin generation 1 and remember its decode.
        let pinned = served.pin();
        let before = pinned.registry().load_task_vector(0, &ExecCtx::sequential()).unwrap();

        // Stage a different zoo and publish it.
        let staged = pack(&dir, "zoo.qtvc.next", 2);
        assert_eq!(staged, served.stage_path());
        let n = served.publish_staged().unwrap();
        assert_eq!(n, 2);
        assert_eq!(served.generation(), 2);
        assert!(!staged.exists(), "publish consumes the staged file");

        // The old pin still reads generation 1's bytes, bit-exactly.
        let still = pinned.registry().load_task_vector(0, &ExecCtx::sequential()).unwrap();
        assert_eq!(before, still, "pinned generation changed under a publish");

        // New pins see generation 2, whose data differs (different seed).
        let fresh = served.pin().registry().load_task_vector(0, &ExecCtx::sequential()).unwrap();
        assert_ne!(before, fresh, "publish did not change served data");

        // Both generations are live while the pin holds; dropping it
        // releases generation 1 (refcount-zero unmap).
        assert_eq!(served.live_generations(), vec![1, 2]);
        drop(pinned);
        drop(still);
        assert_eq!(served.live_generations(), vec![2]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_stage_never_replaces_a_healthy_registry() {
        let dir = tmpdir("corrupt-stage");
        let path = pack(&dir, "zoo.qtvc", 1);
        let served = GenerationalRegistry::open(&path).unwrap();
        std::fs::write(served.stage_path(), b"not a registry").unwrap();
        let err = served.publish_staged().unwrap_err();
        assert!(err.to_string().contains("validating"), "{err:#}");
        // Nothing changed: generation 1 still serves, the stage remains
        // for inspection, and the serving path still opens cleanly.
        assert_eq!(served.generation(), 1);
        assert!(served.stage_path().exists());
        served.pin().registry().load_task_vector(0, &ExecCtx::sequential()).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn publish_without_stage_is_an_error() {
        let dir = tmpdir("no-stage");
        let path = pack(&dir, "zoo.qtvc", 1);
        let served = GenerationalRegistry::open(&path).unwrap();
        assert!(served.publish_staged().is_err());
        assert_eq!(served.generation(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
