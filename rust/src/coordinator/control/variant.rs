//! Variant lifecycle: the state machine, the bounded admission queue,
//! and the worker that executes admitted jobs against pinned generations.
//!
//! ```text
//!            load ok                drain()             queue empty
//!  Loading ──────────► Ready ─────────────► Draining ──────────────► Terminated
//!     │                                        │ deadline expired:
//!     │ load error / budget refusal            │ flush queued jobs with
//!     ▼                                        │ DrainDeadlineExpired,
//!  Failed (error retained)                     └──────► Terminated
//! ```
//!
//! Admission ([`Variant::admit`]) happens under the state lock: the
//! lifecycle check, the generation pin, and the `try_send` into the
//! bounded queue are one atomic step, so no job can slip into a variant
//! after it flips to `Draining`, and every admitted job carries the
//! generation that was current at admission — a publish between
//! admission and execution does not retarget it.  Rejections are typed:
//! a full queue is [`ControlError::Overloaded`], a non-`Ready` state is
//! [`ControlError::VariantUnavailable`].
//!
//! Draining drops the queue's sender: the worker keeps completing queued
//! jobs until the channel reports disconnected (all work done → clean
//! `Terminated`) or the drain deadline passes first (the remainder is
//! flushed with [`ControlError::DrainDeadlineExpired`], each flushed
//! job's generation pin released unread).

use std::sync::atomic::Ordering;
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::generation::{Generation, GenerationalRegistry};
use super::ControlError;
use crate::checkpoint::Checkpoint;
use crate::coordinator::metrics::VariantMetrics;
use crate::obs;
use crate::util::exec::ExecCtx;

/// Lifecycle states of a variant.  `Failed` retains the load error so
/// status queries explain *why* a variant never became ready.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum VariantState {
    Loading,
    Ready,
    Draining,
    Terminated,
    Failed(String),
}

impl VariantState {
    /// Stable lowercase label (status JSON, error messages).
    pub fn label(&self) -> &'static str {
        match self {
            VariantState::Loading => "loading",
            VariantState::Ready => "ready",
            VariantState::Draining => "draining",
            VariantState::Terminated => "terminated",
            VariantState::Failed(_) => "failed",
        }
    }

    /// Whether the lifecycle permits moving from `self` to `to`.
    /// `Terminated` and `Failed` are terminal; the only cycle-free path
    /// is Loading → Ready → Draining → Terminated.
    pub fn can_transition(&self, to: &VariantState) -> bool {
        use VariantState::*;
        matches!(
            (self, to),
            (Loading, Ready) | (Loading, Failed(_)) | (Ready, Draining) | (Draining, Terminated)
        )
    }
}

/// Per-variant tuning knobs.
#[derive(Clone, Debug)]
pub struct VariantConfig {
    /// Bounded admission-queue depth; beyond it `admit` rejects with
    /// [`ControlError::Overloaded`] instead of blocking.
    pub queue_cap: usize,
    /// How long a draining variant may keep completing queued work
    /// before the remainder is flushed with typed errors.
    pub drain_deadline: Duration,
    /// Estimated resident bytes of the merged variant this registry will
    /// build, checked against the node byte budget at load time (0 = the
    /// caller only wants the source overhead budgeted).
    pub est_model_bytes: usize,
}

impl Default for VariantConfig {
    fn default() -> Self {
        Self {
            queue_cap: 256,
            drain_deadline: Duration::from_secs(1),
            est_model_bytes: 0,
        }
    }
}

/// An admitted unit of work: the closure plus the generation pinned for
/// it at admission time.
struct Job {
    pinned: Arc<Generation>,
    run: Box<dyn FnOnce(Result<&Generation, ControlError>) + Send>,
}

/// State + sender, guarded together: admission checks the state and
/// enqueues under one lock, drain flips the state and drops the sender
/// under the same lock — no job can race past a `Draining` decision.
struct Ctl {
    state: VariantState,
    tx: Option<SyncSender<Job>>,
}

struct Inner {
    name: String,
    registry: Arc<GenerationalRegistry>,
    ctl: Mutex<Ctl>,
    /// Set (before the state flips to Draining) to the instant after
    /// which still-queued jobs are flushed instead of run.
    drain_deadline_at: Mutex<Option<Instant>>,
    metrics: Arc<VariantMetrics>,
    queue_cap: usize,
}

impl Inner {
    fn set_terminated(&self) {
        let mut ctl = self.ctl.lock().unwrap();
        // Normal path is Draining → Terminated; the worker also forces
        // Terminated if it exits for any other reason, so a variant
        // without a live worker can never report itself admittable.
        ctl.state = VariantState::Terminated;
        ctl.tx = None;
    }
}

/// A lifecycle-managed serving variant: one generational registry, one
/// bounded queue, one worker thread executing admitted jobs in order.
pub struct Variant {
    inner: Arc<Inner>,
    worker: Mutex<Option<JoinHandle<()>>>,
}

impl Variant {
    /// Start a `Ready` variant serving `registry`.  (The `Loading` phase
    /// — opening the registry, checking the byte budget — happens in
    /// [`ControlPlane::load_variant`](super::ControlPlane::load_variant)
    /// before a `Variant` exists; a failed load is retained there as a
    /// `Failed` slot.)
    pub fn start(
        name: &str,
        registry: Arc<GenerationalRegistry>,
        cfg: &VariantConfig,
        metrics: Arc<VariantMetrics>,
    ) -> Result<Arc<Variant>> {
        let queue_cap = cfg.queue_cap.max(1);
        let (tx, rx) = mpsc::sync_channel::<Job>(queue_cap);
        metrics.generation.store(registry.generation(), Ordering::Relaxed);
        let inner = Arc::new(Inner {
            name: name.to_string(),
            registry,
            ctl: Mutex::new(Ctl { state: VariantState::Ready, tx: Some(tx) }),
            drain_deadline_at: Mutex::new(None),
            metrics,
            queue_cap,
        });
        let worker_inner = inner.clone();
        let worker = std::thread::Builder::new()
            .name(format!("tvq-variant-{name}"))
            .spawn(move || worker_loop(worker_inner, rx))?;
        Ok(Arc::new(Variant { inner, worker: Mutex::new(Some(worker)) }))
    }

    pub fn name(&self) -> &str {
        &self.inner.name
    }

    pub fn state(&self) -> VariantState {
        self.inner.ctl.lock().unwrap().state.clone()
    }

    pub fn registry(&self) -> &Arc<GenerationalRegistry> {
        &self.inner.registry
    }

    pub fn metrics(&self) -> &VariantMetrics {
        &self.inner.metrics
    }

    /// Admit one unit of work.  `run` executes on the variant's worker
    /// thread against the generation pinned *now*; if the job is flushed
    /// by a drain deadline it receives the typed error instead.  Returns
    /// the typed rejection without enqueueing when the variant is not
    /// `Ready` or its queue is full.
    pub fn admit<F>(&self, run: F) -> Result<(), ControlError>
    where
        F: FnOnce(Result<&Generation, ControlError>) + Send + 'static,
    {
        let _span = obs::span(obs::Category::Control, "admit");
        let ctl = self.inner.ctl.lock().unwrap();
        match &ctl.state {
            VariantState::Ready => {}
            other => {
                self.inner.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(ControlError::VariantUnavailable {
                    variant: self.inner.name.clone(),
                    state: other.label().to_string(),
                });
            }
        }
        let job = Job { pinned: self.inner.registry.pin(), run: Box::new(run) };
        let tx = ctl.tx.as_ref().expect("a Ready variant keeps its sender");
        // Count depth before the send: the worker decrements after
        // receiving, and channel recv synchronizes-with this send.
        self.inner.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
        match tx.try_send(job) {
            Ok(()) => {
                self.inner.metrics.admitted.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(TrySendError::Full(_)) => {
                self.inner.metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                self.inner.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(ControlError::Overloaded {
                    variant: self.inner.name.clone(),
                    queue_cap: self.inner.queue_cap,
                })
            }
            Err(TrySendError::Disconnected(_)) => {
                self.inner.metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                self.inner.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(ControlError::VariantUnavailable {
                    variant: self.inner.name.clone(),
                    state: VariantState::Terminated.label().to_string(),
                })
            }
        }
    }

    /// [`admit`](Self::admit) returning the job's value on a one-shot
    /// channel: `f` runs on the worker with the pinned generation; a
    /// drain flush delivers the typed error instead.
    pub fn submit<T, F>(&self, f: F) -> Result<Receiver<Result<T, ControlError>>, ControlError>
    where
        T: Send + 'static,
        F: FnOnce(&Generation) -> Result<T, ControlError> + Send + 'static,
    {
        let (tx, rx) = mpsc::sync_channel(1);
        self.admit(move |generation| {
            let _ = tx.send(match generation {
                Ok(g) => f(g),
                Err(e) => Err(e),
            });
        })?;
        Ok(rx)
    }

    /// Submit a task-vector reconstruction against the pinned
    /// generation.  Decodes through the default [`ExecCtx`] (shared
    /// global pool), so the result is bit-exact at every thread count
    /// (the PR-5 determinism contract).
    pub fn submit_task_vector(
        &self,
        t: usize,
    ) -> Result<Receiver<Result<Checkpoint, ControlError>>, ControlError> {
        self.submit(move |generation| {
            generation
                .registry()
                .load_task_vector(t, &ExecCtx::default())
                .map_err(|e| ControlError::JobFailed { error: format!("{e:#}") })
        })
    }

    /// Begin draining: reject new admissions immediately, let queued and
    /// in-flight work complete for up to `deadline`, then flush whatever
    /// is still queued with [`ControlError::DrainDeadlineExpired`].  The
    /// variant reaches `Terminated` either way; errors if it is not
    /// currently `Ready`.
    pub fn drain(&self, deadline: Duration) -> Result<(), ControlError> {
        let _span = obs::span(obs::Category::Control, "drain");
        // The worker reads the deadline between jobs; publish it before
        // the closed channel becomes observable.
        *self.inner.drain_deadline_at.lock().unwrap() = Some(Instant::now() + deadline);
        let mut ctl = self.inner.ctl.lock().unwrap();
        if !ctl.state.can_transition(&VariantState::Draining) {
            return Err(ControlError::VariantUnavailable {
                variant: self.inner.name.clone(),
                state: ctl.state.label().to_string(),
            });
        }
        ctl.state = VariantState::Draining;
        ctl.tx = None; // worker sees Disconnected once the queue empties
        Ok(())
    }

    /// Block until the variant reaches `want` (polling; ops/test
    /// helper).  Returns whether it got there within `timeout`.
    pub fn await_state(&self, want: &VariantState, timeout: Duration) -> bool {
        let t0 = Instant::now();
        loop {
            if self.state() == *want {
                return true;
            }
            if t0.elapsed() >= timeout {
                return self.state() == *want;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}

impl Drop for Variant {
    fn drop(&mut self) {
        // Graceful by default: complete everything already admitted
        // (mirrors Server::shutdown).  Already-draining/terminated
        // variants just join.
        let _ = self.drain(Duration::from_secs(60));
        if let Some(handle) = self.worker.lock().unwrap().take() {
            let _ = handle.join();
        }
    }
}

fn worker_loop(inner: Arc<Inner>, rx: Receiver<Job>) {
    loop {
        // Between jobs, an expired drain deadline flushes the remainder.
        // The worker never blocks while a deadline is pending: a set
        // deadline implies drain() already dropped the sender, so an
        // empty queue returns Disconnected instead of parking.
        let expired = inner
            .drain_deadline_at
            .lock()
            .unwrap()
            .is_some_and(|at| Instant::now() >= at);
        if expired {
            while let Ok(job) = rx.try_recv() {
                inner.metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                inner.metrics.drained.fetch_add(1, Ordering::Relaxed);
                (job.run)(Err(ControlError::DrainDeadlineExpired {
                    variant: inner.name.clone(),
                }));
                // job.pinned drops here without being read.
            }
            inner.set_terminated();
            return;
        }
        match rx.recv() {
            Ok(job) => {
                inner.metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                let Job { pinned, run } = job;
                let span = obs::span(obs::Category::Control, "service");
                let t0 = Instant::now();
                run(Ok(&pinned));
                inner.metrics.service.record_ns(t0.elapsed());
                drop(span);
                inner.metrics.completed.fetch_add(1, Ordering::Relaxed);
                // The in-flight pin releases only after the job ran.
                drop(pinned);
            }
            Err(_) => {
                // Sender dropped and queue fully consumed: every
                // admitted job completed before the deadline.
                inner.set_terminated();
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transition_table_is_exact() {
        use VariantState::*;
        let states = [Loading, Ready, Draining, Terminated, Failed("e".into())];
        let legal = [
            (Loading, Ready),
            (Loading, Failed("e".into())),
            (Ready, Draining),
            (Draining, Terminated),
        ];
        for from in &states {
            for to in &states {
                let want = legal.iter().any(|(f, t)| f == from && t == to);
                assert_eq!(
                    from.can_transition(to),
                    want,
                    "transition {from:?} -> {to:?}"
                );
            }
        }
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(VariantState::Loading.label(), "loading");
        assert_eq!(VariantState::Ready.label(), "ready");
        assert_eq!(VariantState::Draining.label(), "draining");
        assert_eq!(VariantState::Terminated.label(), "terminated");
        assert_eq!(VariantState::Failed("x".into()).label(), "failed");
    }
}
