//! Control plane: merged variants as first-class, lifecycle-managed
//! backends.
//!
//! Everything below this module is a library — registries decode bytes,
//! mergers combine task vectors, the [`ModelCache`](super::ModelCache)
//! holds what was built.  Operating a *fleet* of merged variants on one
//! node needs a layer those pieces deliberately don't have: loading a new
//! quantized registry without downtime, retiring a stale variant without
//! dropping in-flight work, and shedding load explicitly instead of
//! blocking.  That layer lives here, in three parts:
//!
//! * [`generation`] — registry hot-swap.  A [`GenerationalRegistry`]
//!   serves one path through a monotonically numbered sequence of opened
//!   generations; publishing renames a staged file over the serving path
//!   and re-opens it, while in-flight requests keep reading the old
//!   inode through their pinned generation (the mapping unmaps at
//!   refcount zero).  This turns the `docs/WIRE_FORMAT.md` §7 mutation
//!   hazard into the reload mechanism.
//! * [`variant`] — the lifecycle state machine
//!   (`Loading → Ready → Draining → Terminated`, plus `Failed` with the
//!   error retained) and the bounded admission queue in front of each
//!   variant's worker.
//! * [`plane`] — the node-level owner: a [`ControlPlane`] holds the
//!   variants and the shared `ModelCache`, enforces the node byte budget
//!   at load time, and snapshots per-variant status for the
//!   `tvq serve status` control API.
//!
//! Failure is always *typed* ([`ControlError`]): callers distinguish
//! "queue full, retry elsewhere" from "variant draining, pick another"
//! from "node over budget" without parsing strings.

pub mod generation;
pub mod plane;
pub mod variant;

pub use generation::{
    Generation, GenerationalManifest, GenerationalRegistry, ManifestGeneration, STAGE_SUFFIX,
};
pub use plane::{ControlPlane, PlaneStatus, VariantStatus};
pub use variant::{Variant, VariantConfig, VariantState};

use std::fmt;
use std::path::Path;

/// Typed control-plane failures.  Every rejection the plane can issue is
/// a distinct variant so callers (and the TCP front-end) can react
/// structurally — retry, fail over, or surface — instead of matching on
/// message text.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ControlError {
    /// The variant's bounded admission queue is full (backpressure):
    /// retry later or route to another replica.
    Overloaded { variant: String, queue_cap: usize },
    /// The variant exists but is not `Ready` (draining, terminated,
    /// failed, ...); `state` carries the lifecycle label.
    VariantUnavailable { variant: String, state: String },
    /// The drain deadline expired before this queued job ran; it was
    /// flushed without touching a generation.
    DrainDeadlineExpired { variant: String },
    /// The node byte budget (the `ModelCache` cap) cannot admit this
    /// variant's estimated resident footprint.
    BudgetExceeded { variant: String, needed_bytes: usize, budget_bytes: usize },
    /// A live (non-terminated) variant already holds this name.
    DuplicateVariant { variant: String },
    /// No variant under this name.
    UnknownVariant { variant: String },
    /// Loading or publishing the variant's registry failed; the message
    /// is retained (and kept visible in `Failed` status for loads).
    LoadFailed { variant: String, error: String },
    /// The admitted job itself failed (decode error, merge error, ...).
    JobFailed { error: String },
}

impl fmt::Display for ControlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ControlError::Overloaded { variant, queue_cap } => write!(
                f,
                "variant {variant:?} is overloaded (admission queue at cap {queue_cap})"
            ),
            ControlError::VariantUnavailable { variant, state } => {
                write!(f, "variant {variant:?} is not accepting work (state: {state})")
            }
            ControlError::DrainDeadlineExpired { variant } => {
                write!(f, "variant {variant:?} drain deadline expired before this job ran")
            }
            ControlError::BudgetExceeded { variant, needed_bytes, budget_bytes } => write!(
                f,
                "variant {variant:?} needs ~{needed_bytes} resident bytes but the node \
                 budget admits only {budget_bytes}"
            ),
            ControlError::DuplicateVariant { variant } => {
                write!(f, "a live variant named {variant:?} already exists")
            }
            ControlError::UnknownVariant { variant } => {
                write!(f, "no variant named {variant:?}")
            }
            ControlError::LoadFailed { variant, error } => {
                write!(f, "loading variant {variant:?} failed: {error}")
            }
            ControlError::JobFailed { error } => write!(f, "job failed: {error}"),
        }
    }
}

impl std::error::Error for ControlError {}

/// True when `path` is a swap artifact rather than a servable registry:
/// the writer's `.tmp` staging file (an interrupted atomic write) or the
/// control plane's `.next` staged generation (not yet published).  Both
/// are transient names a rename either consumes or abandons; tooling
/// (`tvq registry verify`) refuses them with a pointed message instead
/// of validating a file whose identity is about to change.
pub fn is_swap_artifact(path: &Path) -> bool {
    matches!(
        path.extension().and_then(|e| e.to_str()),
        Some("tmp") | Some("next")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn swap_artifacts_are_recognized() {
        assert!(is_swap_artifact(Path::new("zoo.tmp")));
        assert!(is_swap_artifact(Path::new("zoo.qtvc.next")));
        assert!(is_swap_artifact(Path::new("/srv/models/zoo.qtvc.next")));
        assert!(!is_swap_artifact(Path::new("zoo.qtvc")));
        assert!(!is_swap_artifact(Path::new("next.qtvc")));
        assert!(!is_swap_artifact(Path::new("tmp")));
    }

    #[test]
    fn errors_render_pointed_messages() {
        let e = ControlError::Overloaded { variant: "a".into(), queue_cap: 8 };
        assert!(e.to_string().contains("overloaded"));
        let e = ControlError::VariantUnavailable { variant: "a".into(), state: "draining".into() };
        assert!(e.to_string().contains("draining"));
        let e = ControlError::BudgetExceeded {
            variant: "a".into(),
            needed_bytes: 100,
            budget_bytes: 10,
        };
        assert!(e.to_string().contains("100") && e.to_string().contains("10"));
    }
}
