//! The node-level control plane: owns the variants and the shared
//! [`ModelCache`], enforces the byte budget at load time, and snapshots
//! per-variant status for the `tvq serve status` control API.
//!
//! A [`ControlPlane`] maps variant names to slots.  A slot is either a
//! live [`Variant`] (with its lifecycle state) or a retained load
//! failure — a variant that never became `Ready` stays visible in
//! status with its error, rather than vanishing.  Loads are refused
//! *before* any registry bytes become resident when the estimated
//! footprint does not fit under the cache's byte cap
//! ([`ControlError::BudgetExceeded`]); admitted registries are
//! registered as cache sources so their unevictable overhead counts
//! against the node budget from then on.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::cache::ModelCache;
use crate::coordinator::metrics::{VariantMetrics, VariantMetricsSnapshot};
use crate::coordinator::tcp::StatusSource;
use crate::obs;
use crate::util::json::Json;

use super::generation::GenerationalRegistry;
use super::variant::{Variant, VariantConfig, VariantState};
use super::ControlError;

enum Slot {
    Live {
        variant: Arc<Variant>,
        /// The configured default drain deadline, used when
        /// [`ControlPlane::drain_variant`] is called without an override.
        drain_deadline: Duration,
    },
    /// A load that failed; the error is retained for status queries.
    Failed { error: String },
}

/// Owner of a node's merged-variant fleet and its shared byte budget.
pub struct ControlPlane {
    cache: Arc<ModelCache>,
    slots: Mutex<BTreeMap<String, Slot>>,
}

impl ControlPlane {
    /// A plane sharing `cache` (and its byte cap) across all variants.
    pub fn new(cache: Arc<ModelCache>) -> ControlPlane {
        ControlPlane { cache, slots: Mutex::new(BTreeMap::new()) }
    }

    /// The shared model cache (merged variants and registry sources all
    /// count against its cap).
    pub fn cache(&self) -> &Arc<ModelCache> {
        &self.cache
    }

    /// Load `path` as a new variant named `name` and bring it `Ready`.
    ///
    /// The `Loading` phase runs here: the registry is opened, its
    /// unevictable overhead plus `cfg.est_model_bytes` is checked
    /// against the cache budget, and only then does a worker start.  On
    /// failure the error is retained as a `Failed` slot (visible in
    /// status) *and* returned.  A live (non-terminated) variant under
    /// the same name is a [`ControlError::DuplicateVariant`]; terminated
    /// and failed slots are replaced.
    pub fn load_variant(
        &self,
        name: &str,
        path: &Path,
        cfg: &VariantConfig,
    ) -> Result<Arc<Variant>, ControlError> {
        let mut slots = self.slots.lock().unwrap();
        if let Some(Slot::Live { variant, .. }) = slots.get(name) {
            if variant.state() != VariantState::Terminated {
                return Err(ControlError::DuplicateVariant { variant: name.to_string() });
            }
        }
        match self.load_locked(name, path, cfg) {
            Ok(variant) => {
                slots.insert(
                    name.to_string(),
                    Slot::Live { variant: variant.clone(), drain_deadline: cfg.drain_deadline },
                );
                Ok(variant)
            }
            Err(err) => {
                // Loading → Failed: keep the error where status can see it.
                slots.insert(name.to_string(), Slot::Failed { error: err.to_string() });
                Err(err)
            }
        }
    }

    /// The open + budget-check + start sequence (caller holds the slot
    /// map lock, which serializes loads against each other and against
    /// status snapshots).
    fn load_locked(
        &self,
        name: &str,
        path: &Path,
        cfg: &VariantConfig,
    ) -> Result<Arc<Variant>, ControlError> {
        let _span = obs::span(obs::Category::Control, "load_variant");
        let registry = GenerationalRegistry::open(path).map_err(|e| ControlError::LoadFailed {
            variant: name.to_string(),
            error: format!("{e:#}"),
        })?;
        // Budget gate: the registry's unevictable resident overhead plus
        // the caller's estimate of the merged model it will build must
        // fit under the cache cap alongside what is already pinned.
        let pin = registry.pin();
        let needed = pin.registry().resident_overhead_bytes() + cfg.est_model_bytes;
        if !self.cache.can_admit(needed) {
            return Err(ControlError::BudgetExceeded {
                variant: name.to_string(),
                needed_bytes: needed,
                budget_bytes: self.cache.byte_cap().unwrap_or(usize::MAX),
            });
        }
        self.cache.register_source(pin.source());
        drop(pin);
        let metrics = Arc::new(VariantMetrics::default());
        Variant::start(name, Arc::new(registry), cfg, metrics).map_err(|e| {
            ControlError::LoadFailed { variant: name.to_string(), error: format!("{e:#}") }
        })
    }

    /// Look up a live variant.
    pub fn get(&self, name: &str) -> Option<Arc<Variant>> {
        match self.slots.lock().unwrap().get(name) {
            Some(Slot::Live { variant, .. }) => Some(variant.clone()),
            _ => None,
        }
    }

    /// [`get`](Self::get) with a typed miss.
    pub fn variant(&self, name: &str) -> Result<Arc<Variant>, ControlError> {
        self.get(name).ok_or_else(|| ControlError::UnknownVariant { variant: name.to_string() })
    }

    /// Publish the variant's staged next generation (`<path>.next`):
    /// validate, rename-swap, reload.  In-flight work keeps its pinned
    /// generation; the variant's generation gauge advances.
    pub fn publish_staged(&self, name: &str) -> Result<u64, ControlError> {
        let variant = self.variant(name)?;
        let generation = variant.registry().publish_staged().map_err(|e| {
            ControlError::LoadFailed { variant: name.to_string(), error: format!("{e:#}") }
        })?;
        self.note_new_generation(&variant, generation);
        Ok(generation)
    }

    /// Re-open a variant's serving path in place (the file was replaced
    /// by an external rename) as the next generation.
    pub fn reload_variant(&self, name: &str) -> Result<u64, ControlError> {
        let variant = self.variant(name)?;
        let generation = variant.registry().reload().map_err(|e| {
            ControlError::LoadFailed { variant: name.to_string(), error: format!("{e:#}") }
        })?;
        self.note_new_generation(&variant, generation);
        Ok(generation)
    }

    fn note_new_generation(&self, variant: &Variant, generation: u64) {
        let _span =
            obs::span(obs::Category::Control, "generation_swap").with_arg("generation", generation);
        variant.metrics().generation.store(generation, Ordering::Relaxed);
        // Same source id (same path + scheme): refreshes the cache's
        // footprint entry to the new generation's overhead.
        self.cache.register_source(variant.registry().pin().source());
    }

    /// Begin draining `name`.  `deadline: None` uses the deadline the
    /// variant was loaded with.
    pub fn drain_variant(
        &self,
        name: &str,
        deadline: Option<Duration>,
    ) -> Result<(), ControlError> {
        let (variant, default_deadline) = match self.slots.lock().unwrap().get(name) {
            Some(Slot::Live { variant, drain_deadline }) => (variant.clone(), *drain_deadline),
            Some(Slot::Failed { .. }) | None => {
                return Err(ControlError::UnknownVariant { variant: name.to_string() })
            }
        };
        variant.drain(deadline.unwrap_or(default_deadline))
    }

    /// Remove a variant that has finished its lifecycle (`Terminated`)
    /// or never started it (`Failed`).  Live variants must drain first —
    /// removal never interrupts work.
    pub fn remove_variant(&self, name: &str) -> Result<(), ControlError> {
        let mut slots = self.slots.lock().unwrap();
        match slots.get(name) {
            None => Err(ControlError::UnknownVariant { variant: name.to_string() }),
            Some(Slot::Failed { .. }) => {
                slots.remove(name);
                Ok(())
            }
            Some(Slot::Live { variant, .. }) => match variant.state() {
                VariantState::Terminated => {
                    slots.remove(name);
                    Ok(())
                }
                state => Err(ControlError::VariantUnavailable {
                    variant: name.to_string(),
                    state: state.label().to_string(),
                }),
            },
        }
    }

    /// Names of all slots, live and failed.
    pub fn names(&self) -> Vec<String> {
        self.slots.lock().unwrap().keys().cloned().collect()
    }

    /// Snapshot the whole plane: every variant's lifecycle state,
    /// generation, queue metrics and resident footprint, plus the node
    /// budget picture.
    pub fn status(&self) -> PlaneStatus {
        let slots = self.slots.lock().unwrap();
        let variants = slots
            .iter()
            .map(|(name, slot)| match slot {
                Slot::Live { variant, .. } => {
                    let pin = variant.registry().pin();
                    VariantStatus {
                        name: name.clone(),
                        state: variant.state().label().to_string(),
                        error: match variant.state() {
                            VariantState::Failed(e) => Some(e),
                            _ => None,
                        },
                        generation: variant.registry().generation(),
                        live_generations: variant.registry().live_generations(),
                        resident_overhead_bytes: pin.registry().resident_overhead_bytes(),
                        n_tasks: pin.registry().n_tasks(),
                        metrics: variant.metrics().snapshot(),
                    }
                }
                Slot::Failed { error } => VariantStatus {
                    name: name.clone(),
                    state: "failed".to_string(),
                    error: Some(error.clone()),
                    generation: 0,
                    live_generations: Vec::new(),
                    resident_overhead_bytes: 0,
                    n_tasks: 0,
                    metrics: VariantMetricsSnapshot::default(),
                },
            })
            .collect();
        PlaneStatus {
            variants,
            resident_bytes: self.cache.resident_bytes(),
            source_overhead_bytes: self.cache.source_overhead_bytes(),
            byte_cap: self.cache.byte_cap(),
        }
    }
}

impl StatusSource for ControlPlane {
    fn status_json(&self) -> Json {
        self.status().to_json()
    }

    /// Per-variant Prometheus families, labelled `variant="<name>"`.
    fn prometheus_into(&self, out: &mut String) {
        use std::fmt::Write;
        let status = self.status();
        let mut family = |name: &str, ty: &str, get: &dyn Fn(&VariantStatus) -> f64| {
            let _ = writeln!(out, "# TYPE tvq_variant_{name} {ty}");
            for v in &status.variants {
                let _ = writeln!(out, "tvq_variant_{name}{{variant=\"{}\"}} {}", v.name, get(v));
            }
        };
        family("admitted_total", "counter", &|v| v.metrics.admitted as f64);
        family("rejected_total", "counter", &|v| v.metrics.rejected as f64);
        family("completed_total", "counter", &|v| v.metrics.completed as f64);
        family("drained_total", "counter", &|v| v.metrics.drained as f64);
        family("queue_depth", "gauge", &|v| v.metrics.queue_depth as f64);
        family("generation", "gauge", &|v| v.generation as f64);
        let _ = writeln!(out, "# TYPE tvq_variant_service_seconds summary");
        for v in &status.variants {
            let s = &v.metrics.service;
            for (q, ns) in [(0.5, s.p50), (0.9, s.p90), (0.99, s.p99)] {
                let _ = writeln!(
                    out,
                    "tvq_variant_service_seconds{{variant=\"{}\",quantile=\"{q}\"}} {}",
                    v.name,
                    ns as f64 / 1e9
                );
            }
            let _ = writeln!(
                out,
                "tvq_variant_service_seconds_count{{variant=\"{}\"}} {}",
                v.name, s.count
            );
            let _ = writeln!(
                out,
                "tvq_variant_service_seconds_sum{{variant=\"{}\"}} {}",
                v.name,
                s.sum as f64 / 1e9
            );
        }
        let _ = writeln!(out, "# TYPE tvq_node_resident_bytes gauge");
        let _ = writeln!(out, "tvq_node_resident_bytes {}", status.resident_bytes);
    }
}

/// One variant's row in a [`PlaneStatus`].
#[derive(Clone, Debug)]
pub struct VariantStatus {
    pub name: String,
    /// Lifecycle label (`loading`/`ready`/`draining`/`terminated`/`failed`).
    pub state: String,
    /// Retained error for failed loads / failed variants.
    pub error: Option<String>,
    /// Current generation number (0 for a failed load — none was opened).
    pub generation: u64,
    /// Generations still mapped: current plus any pinned by in-flight work.
    pub live_generations: Vec<u64>,
    /// The registry's unevictable resident bytes (index + plan caches).
    pub resident_overhead_bytes: usize,
    pub n_tasks: usize,
    pub metrics: VariantMetricsSnapshot,
}

impl VariantStatus {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::str(&self.name)),
            ("state", Json::str(&self.state)),
            ("generation", Json::num(self.generation as f64)),
            (
                "live_generations",
                Json::arr(self.live_generations.iter().map(|g| Json::num(*g as f64))),
            ),
            ("resident_overhead_bytes", Json::num(self.resident_overhead_bytes as f64)),
            ("n_tasks", Json::num(self.n_tasks as f64)),
            ("admitted", Json::num(self.metrics.admitted as f64)),
            ("rejected", Json::num(self.metrics.rejected as f64)),
            ("completed", Json::num(self.metrics.completed as f64)),
            ("drained", Json::num(self.metrics.drained as f64)),
            ("queue_depth", Json::num(self.metrics.queue_depth as f64)),
            // Per-variant service-time histogram (µs), quantiles bounded
            // by the log2-bucket relative error (see `obs::hist`).
            ("service_us", self.metrics.service.to_json_scaled(1e3)),
        ];
        if let Some(error) = &self.error {
            fields.push(("error", Json::str(error)));
        }
        Json::obj(fields)
    }
}

/// Snapshot of the whole plane (the `tvq serve status` payload).
#[derive(Clone, Debug)]
pub struct PlaneStatus {
    pub variants: Vec<VariantStatus>,
    /// Cache-resident bytes: merged variants plus source overheads.
    pub resident_bytes: usize,
    /// The unevictable floor contributed by registered registry sources.
    pub source_overhead_bytes: usize,
    pub byte_cap: Option<usize>,
}

impl PlaneStatus {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("variants", Json::arr(self.variants.iter().map(|v| v.to_json()))),
            ("resident_bytes", Json::num(self.resident_bytes as f64)),
            ("source_overhead_bytes", Json::num(self.source_overhead_bytes as f64)),
            (
                "byte_cap",
                match self.byte_cap {
                    Some(cap) => Json::num(cap as f64),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// Human-oriented multi-line rendering for the CLI.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        let cap = match self.byte_cap {
            Some(cap) => format!("{:.1} MiB", cap as f64 / (1024.0 * 1024.0)),
            None => "unbounded".to_string(),
        };
        s.push_str(&format!(
            "node: resident {:.1} MiB (sources {:.1} MiB), budget {cap}\n",
            self.resident_bytes as f64 / (1024.0 * 1024.0),
            self.source_overhead_bytes as f64 / (1024.0 * 1024.0),
        ));
        for v in &self.variants {
            s.push_str(&format!(
                "  {:<16} {:<10} gen {:>2} (live {:?})  admitted {:>6}  rejected {:>4}  \
                 completed {:>6}  drained {:>4}  depth {:>3}",
                v.name,
                v.state,
                v.generation,
                v.live_generations,
                v.metrics.admitted,
                v.metrics.rejected,
                v.metrics.completed,
                v.metrics.drained,
                v.metrics.queue_depth,
            ));
            if let Some(error) = &v.error {
                s.push_str(&format!("  error: {error}"));
            }
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exp::planner::synthetic_planner_zoo;
    use crate::quant::QuantScheme;
    use crate::registry::build_registry;
    use std::path::PathBuf;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("tvq-plane-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn pack(dir: &Path, name: &str, seed: u64) -> PathBuf {
        let (pre, fts) = synthetic_planner_zoo(3, seed);
        let path = dir.join(name);
        build_registry(&pre, &fts, QuantScheme::Tvq(4), &path).unwrap();
        path
    }

    #[test]
    fn load_submit_drain_remove_roundtrip() {
        let dir = tmpdir("roundtrip");
        let path = pack(&dir, "zoo.qtvc", 7);
        let plane = ControlPlane::new(Arc::new(ModelCache::new()));
        let v = plane.load_variant("zoo", &path, &VariantConfig::default()).unwrap();
        assert_eq!(v.state(), VariantState::Ready);

        let rx = v.submit_task_vector(0).unwrap();
        let tv = rx.recv().unwrap().unwrap();
        assert!(tv.numel() > 0);

        // Duplicate names are refused while the variant is live.
        let err = plane.load_variant("zoo", &path, &VariantConfig::default()).unwrap_err();
        assert!(matches!(err, ControlError::DuplicateVariant { .. }));
        // ... and so is removal.
        assert!(matches!(
            plane.remove_variant("zoo").unwrap_err(),
            ControlError::VariantUnavailable { .. }
        ));

        plane.drain_variant("zoo", None).unwrap();
        assert!(v.await_state(&VariantState::Terminated, Duration::from_secs(10)));
        // Terminated variants reject admissions with a typed error.
        assert!(matches!(
            v.submit_task_vector(0).unwrap_err(),
            ControlError::VariantUnavailable { .. }
        ));
        plane.remove_variant("zoo").unwrap();
        assert!(plane.get("zoo").is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn budget_refusal_is_typed_and_retained() {
        let dir = tmpdir("budget");
        let path = pack(&dir, "zoo.qtvc", 7);
        // A 1-byte budget cannot admit any registry overhead.
        let plane = ControlPlane::new(Arc::new(ModelCache::with_byte_cap(1)));
        let err = plane.load_variant("zoo", &path, &VariantConfig::default()).unwrap_err();
        assert!(matches!(err, ControlError::BudgetExceeded { .. }), "{err}");
        // The failure is retained in status, not silently dropped.
        let status = plane.status();
        assert_eq!(status.variants.len(), 1);
        assert_eq!(status.variants[0].state, "failed");
        assert!(status.variants[0].error.as_ref().unwrap().contains("budget"));
        // Nothing was registered against the budget.
        assert_eq!(plane.cache().source_overhead_bytes(), 0);
        // A roomier plane admits the same file and can replace the
        // failed slot under the same name.
        let plane = ControlPlane::new(Arc::new(ModelCache::new()));
        plane.load_variant("zoo", &path, &VariantConfig::default()).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_and_missing_file_paths() {
        let plane = ControlPlane::new(Arc::new(ModelCache::new()));
        assert!(matches!(
            plane.variant("nope").unwrap_err(),
            ControlError::UnknownVariant { .. }
        ));
        let err = plane
            .load_variant("ghost", Path::new("/nonexistent/zoo.qtvc"), &VariantConfig::default())
            .unwrap_err();
        assert!(matches!(err, ControlError::LoadFailed { .. }));
        let status = plane.status();
        assert_eq!(status.variants[0].state, "failed");
    }

    #[test]
    fn status_json_roundtrips() {
        let dir = tmpdir("status-json");
        let path = pack(&dir, "zoo.qtvc", 3);
        let plane = ControlPlane::new(Arc::new(ModelCache::new()));
        plane.load_variant("zoo", &path, &VariantConfig::default()).unwrap();
        let rendered = plane.status().to_json().to_string_compact();
        let parsed = Json::parse(&rendered).unwrap();
        let variants = parsed.req("variants").unwrap().as_arr().unwrap();
        assert_eq!(variants.len(), 1);
        assert_eq!(variants[0].req("name").unwrap().as_str().unwrap(), "zoo");
        assert_eq!(variants[0].req("state").unwrap().as_str().unwrap(), "ready");
        assert_eq!(variants[0].req("generation").unwrap().as_usize().unwrap(), 1);
        assert!(plane.status().summary().contains("zoo"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
