//! Pure batching logic: group pending requests by task, flush a batch
//! when it reaches `max_batch` items or its oldest item has waited
//! `max_delay`.  No threads, no clocks — time is passed in, so the flush
//! rules are directly property-testable.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// A request staged inside the batcher. Generic over the payload so the
/// logic can be tested without tensors.
#[derive(Debug)]
pub struct Staged<T> {
    pub task: usize,
    pub enqueued: Instant,
    pub payload: T,
}

/// A formed batch: all items share one task id.
#[derive(Debug)]
pub struct Batch<T> {
    pub task: usize,
    pub items: Vec<Staged<T>>,
}

/// Per-task pending queues with size/deadline flush rules and an
/// optional per-task depth bound ([`try_push`](Batcher::try_push)).
#[derive(Debug)]
pub struct Batcher<T> {
    queues: Vec<VecDeque<Staged<T>>>,
    pub max_batch: usize,
    pub max_delay: Duration,
    /// Per-task staged-item bound enforced by `try_push`.
    queue_cap: usize,
    len: usize,
}

impl<T> Batcher<T> {
    /// An unbounded batcher (per-task cap `usize::MAX`).
    pub fn new(n_tasks: usize, max_batch: usize, max_delay: Duration) -> Self {
        Self::with_queue_cap(n_tasks, max_batch, max_delay, usize::MAX)
    }

    /// A batcher whose per-task queues hold at most `queue_cap` staged
    /// items; beyond that [`try_push`](Batcher::try_push) rejects.
    pub fn with_queue_cap(
        n_tasks: usize,
        max_batch: usize,
        max_delay: Duration,
        queue_cap: usize,
    ) -> Self {
        assert!(max_batch >= 1, "max_batch must be >= 1");
        assert!(queue_cap >= 1, "queue_cap must be >= 1");
        Self {
            queues: (0..n_tasks).map(|_| VecDeque::new()).collect(),
            max_batch,
            max_delay,
            queue_cap,
            len: 0,
        }
    }

    pub fn n_tasks(&self) -> usize {
        self.queues.len()
    }

    /// Total staged items across all tasks.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Staged items for one task (its queue depth).
    pub fn queue_len(&self, task: usize) -> usize {
        self.queues[task].len()
    }

    /// Stage one request; panics if the task's queue is at cap (use
    /// [`try_push`](Batcher::try_push) where overflow is expected).
    pub fn push(&mut self, task: usize, enqueued: Instant, payload: T) {
        if self.try_push(task, enqueued, payload).is_err() {
            panic!("batcher queue for task {task} is at cap {}", self.queue_cap);
        }
    }

    /// Stage one request unless the task's queue is full; on overflow
    /// the payload is handed back so the caller can reply with a typed
    /// rejection instead of blocking.
    pub fn try_push(&mut self, task: usize, enqueued: Instant, payload: T) -> Result<(), T> {
        if self.queues[task].len() >= self.queue_cap {
            return Err(payload);
        }
        self.queues[task].push_back(Staged { task, enqueued, payload });
        self.len += 1;
        Ok(())
    }

    /// Pop the next flushable batch at time `now`:
    /// 1. any task with >= max_batch staged items flushes immediately
    ///    (largest backlog first);
    /// 2. otherwise the task whose *oldest* item has exceeded max_delay
    ///    flushes (oldest first).
    pub fn pop_ready(&mut self, now: Instant) -> Option<Batch<T>> {
        // Rule 1: full batch.
        let full = (0..self.queues.len())
            .filter(|&t| self.queues[t].len() >= self.max_batch)
            .max_by_key(|&t| self.queues[t].len());
        if let Some(t) = full {
            return Some(self.drain(t));
        }
        // Rule 2: deadline exceeded.
        let expired = (0..self.queues.len())
            .filter(|&t| {
                self.queues[t]
                    .front()
                    .is_some_and(|s| now.duration_since(s.enqueued) >= self.max_delay)
            })
            .min_by_key(|&t| self.queues[t].front().map(|s| s.enqueued).unwrap());
        expired.map(|t| self.drain(t))
    }

    /// Flush everything regardless of deadlines (shutdown path).
    pub fn drain_all(&mut self) -> Vec<Batch<T>> {
        let mut out = Vec::new();
        for t in 0..self.queues.len() {
            while !self.queues[t].is_empty() {
                out.push(self.drain(t));
            }
        }
        out
    }

    /// Earliest deadline among staged items (router sleep hint).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .iter()
            .filter_map(|q| q.front().map(|s| s.enqueued + self.max_delay))
            .min()
    }

    fn drain(&mut self, task: usize) -> Batch<T> {
        let take = self.queues[task].len().min(self.max_batch);
        let items: Vec<Staged<T>> = self.queues[task].drain(..take).collect();
        self.len -= items.len();
        Batch { task, items }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn t0() -> Instant {
        Instant::now()
    }

    #[test]
    fn flushes_on_full_batch() {
        let mut b = Batcher::new(2, 3, Duration::from_secs(100));
        let now = t0();
        b.push(0, now, 1u32);
        b.push(0, now, 2);
        assert!(b.pop_ready(now).is_none(), "not full, deadline far");
        b.push(0, now, 3);
        let batch = b.pop_ready(now).expect("full batch flushes");
        assert_eq!(batch.task, 0);
        assert_eq!(batch.items.len(), 3);
        assert!(b.is_empty());
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = Batcher::new(1, 100, Duration::from_millis(5));
        let now = t0();
        b.push(0, now, 7u32);
        assert!(b.pop_ready(now).is_none());
        let later = now + Duration::from_millis(6);
        let batch = b.pop_ready(later).expect("deadline flushes");
        assert_eq!(batch.items.len(), 1);
    }

    #[test]
    fn batches_never_mix_tasks() {
        let mut b = Batcher::new(3, 2, Duration::from_secs(0));
        let now = t0();
        b.push(0, now, 0u32);
        b.push(1, now, 1);
        b.push(2, now, 2);
        let mut seen = Vec::new();
        while let Some(batch) = b.pop_ready(now) {
            assert!(batch.items.iter().all(|s| s.task == batch.task));
            seen.push(batch.task);
        }
        seen.sort();
        assert_eq!(seen, vec![0, 1, 2]);
    }

    #[test]
    fn oversized_backlog_splits_into_max_batch_chunks() {
        let mut b = Batcher::new(1, 4, Duration::from_secs(0));
        let now = t0();
        for i in 0..10u32 {
            b.push(0, now, i);
        }
        let mut sizes = Vec::new();
        while let Some(batch) = b.pop_ready(now) {
            sizes.push(batch.items.len());
        }
        assert_eq!(sizes, vec![4, 4, 2]);
    }

    #[test]
    fn queue_cap_rejects_with_payload_returned() {
        let mut b = Batcher::with_queue_cap(2, 8, Duration::from_secs(100), 2);
        let now = t0();
        assert!(b.try_push(0, now, 1u32).is_ok());
        assert!(b.try_push(0, now, 2).is_ok());
        // Task 0 is at cap: the payload comes back untouched.
        assert_eq!(b.try_push(0, now, 3), Err(3));
        assert_eq!(b.queue_len(0), 2);
        // Caps are per task: task 1 still admits.
        assert!(b.try_push(1, now, 4).is_ok());
        assert_eq!(b.len(), 3);
        // Flushing frees capacity.
        let batch = b.pop_ready(now + Duration::from_secs(200)).unwrap();
        assert_eq!(batch.items.len(), 2);
        assert!(b.try_push(0, now, 5).is_ok());
    }

    #[test]
    fn next_deadline_is_earliest() {
        let mut b = Batcher::new(2, 10, Duration::from_millis(10));
        let now = t0();
        b.push(1, now + Duration::from_millis(3), 0u32);
        b.push(0, now, 1);
        assert_eq!(b.next_deadline(), Some(now + Duration::from_millis(10)));
    }

    /// Property: every pushed item comes back exactly once, no drops, no
    /// duplicates, FIFO within a task, and no batch exceeds max_batch.
    #[test]
    fn prop_conservation_and_bounds() {
        prop::check(
            prop::Config::default(),
            |rng: &mut Rng| {
                let n_tasks = 1 + rng.below(4);
                let max_batch = 1 + rng.below(8);
                let n = rng.below(64);
                let pushes: Vec<usize> =
                    (0..n).map(|_| rng.below(n_tasks)).collect();
                (n_tasks, max_batch, pushes)
            },
            |(n_tasks, max_batch, pushes)| {
                let mut b =
                    Batcher::new(*n_tasks, *max_batch, Duration::from_secs(0));
                let now = t0();
                for (i, &task) in pushes.iter().enumerate() {
                    b.push(task, now, i);
                }
                let mut seen: Vec<usize> = Vec::new();
                let mut last_per_task = vec![None::<usize>; *n_tasks];
                while let Some(batch) = b.pop_ready(now) {
                    if batch.items.len() > *max_batch {
                        return Err("batch exceeds max_batch".into());
                    }
                    for s in &batch.items {
                        if s.task != batch.task {
                            return Err("mixed-task batch".into());
                        }
                        // FIFO within task.
                        if let Some(prev) = last_per_task[s.task] {
                            if s.payload <= prev {
                                return Err("order violated".into());
                            }
                        }
                        last_per_task[s.task] = Some(s.payload);
                        seen.push(s.payload);
                    }
                }
                if !b.is_empty() {
                    return Err("batcher not drained".into());
                }
                seen.sort();
                let want: Vec<usize> = (0..pushes.len()).collect();
                if seen != want {
                    return Err("dropped or duplicated items".into());
                }
                Ok(())
            },
        );
    }
}
