//! Per-request dynamic merging: route a declared task subset + merge
//! coefficients to a deterministic variant key.
//!
//! A static deployment warms a handful of named variants; a *dynamic*
//! one lets each request declare which tasks it wants composed and at
//! what strengths ("tasks 2 and 5 at 0.3, drop task 7").  The router
//! turns that declaration into a canonical [`MergeSpec`] — sorted unique
//! task indices, coefficients carried bit-exactly — so every equivalent
//! request (any argument order, any lambda that round-trips to the same
//! f32 bits) lands on the **same** [`VariantKey`] and therefore the same
//! cached model, single-flight build, and delta-patch lineage
//! ([`ModelCache::get_or_merge_routed`](super::ModelCache::get_or_merge_routed)).
//!
//! The routed merge semantics are task arithmetic with per-task
//! coefficients:
//!
//! ```text
//! theta = theta_pre + sum_i lambda_i * tau_{t_i}      (ascending t_i)
//! ```
//!
//! accumulated **sequentially in ascending task order** — the canonical
//! accumulation every serving path replays, which is what makes a
//! one-task delta patch (`cached + lambda_t * tau_t`) bit-identical to
//! the full re-merge it replaces (see [`merge_spec`]).

use anyhow::{bail, Result};

use super::cache::VariantKey;
use crate::checkpoint::Checkpoint;
use crate::merge::MergedModel;
use crate::registry::TaskVectorSource;
use crate::util::exec::ExecCtx;
use crate::util::pool::Pool;

/// Method name under which routed dynamic variants are cached; keeps
/// them in a separate key namespace from named static mergers
/// (`"task_arithmetic"`, `"ties"`, ...).
pub const DYNAMIC_METHOD: &str = "dynmerge";

/// A canonical merge request: unique task indices in ascending order,
/// each with its signed coefficient.  Equality of specs is equality of
/// served bytes — the lambdas compare by `f32::to_bits`, so `0.3` and
/// `0.2 + 0.1` (which differ in the last ulp) are *different* variants,
/// exactly as they would be different float outputs.
#[derive(Clone, Debug, PartialEq)]
pub struct MergeSpec {
    /// `(task index, lambda)`, strictly ascending by task index.
    pairs: Vec<(usize, f32)>,
}

impl MergeSpec {
    /// Canonicalize a request: `tasks[i]` merges at `lambdas[i]`.
    /// Rejects empty requests, length mismatches, duplicate tasks and
    /// non-finite coefficients (NaN lambdas would break key equality).
    pub fn new(tasks: &[usize], lambdas: &[f32]) -> Result<Self> {
        if tasks.is_empty() {
            bail!("merge request names no tasks");
        }
        if tasks.len() != lambdas.len() {
            bail!(
                "merge request names {} tasks but {} lambdas",
                tasks.len(),
                lambdas.len()
            );
        }
        let mut pairs: Vec<(usize, f32)> = Vec::with_capacity(tasks.len());
        for (&t, &lam) in tasks.iter().zip(lambdas) {
            if !lam.is_finite() {
                bail!("task {t} has a non-finite lambda ({lam})");
            }
            pairs.push((t, lam));
        }
        pairs.sort_by_key(|&(t, _)| t);
        if let Some(w) = pairs.windows(2).find(|w| w[0].0 == w[1].0) {
            bail!("merge request names task {} twice", w[0].0);
        }
        Ok(Self { pairs })
    }

    /// `(task, lambda)` pairs, strictly ascending by task index.
    pub fn pairs(&self) -> &[(usize, f32)] {
        &self.pairs
    }

    /// Number of tasks in the request.
    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }

    /// Task indices, ascending.
    pub fn tasks(&self) -> Vec<usize> {
        self.pairs.iter().map(|&(t, _)| t).collect()
    }

    /// The one-step patch ancestor: this spec with its **highest** task
    /// dropped, plus the dropped `(task, lambda)`.  `None` for
    /// single-task specs.  The canonical merge accumulates in ascending
    /// task order, so `merge(self) == merge(parent) + lambda * tau` holds
    /// bit-for-bit — dropping any *other* task would not commute.
    pub fn parent(&self) -> Option<(MergeSpec, usize, f32)> {
        if self.pairs.len() < 2 {
            return None;
        }
        let mut pairs = self.pairs.clone();
        let (t, lam) = pairs.pop().expect("len >= 2");
        Some((MergeSpec { pairs }, t, lam))
    }

    /// The canonical key fragment: `t<idx>*<lambda bits as hex>` joined
    /// with `+`.  Bit-exact and order-independent — the router's
    /// determinism contract.
    pub fn key_fragment(&self) -> String {
        let mut s = String::new();
        for (i, &(t, lam)) in self.pairs.iter().enumerate() {
            if i > 0 {
                s.push('+');
            }
            s.push_str(&format!("t{t}*{:08x}", lam.to_bits()));
        }
        s
    }

    /// The [`ModelCache`](super::ModelCache) key this spec resolves to
    /// over a given source.  Qualified by the source identity so two
    /// registries packed at the same scheme never share a routed variant.
    pub fn variant_key(&self, source_id: &str) -> VariantKey {
        (DYNAMIC_METHOD.to_string(), format!("{source_id}|{}", self.key_fragment()))
    }
}

/// Validates requests against a source's task count and produces
/// canonical [`MergeSpec`]s.  Stateless beyond the bound task count —
/// routing the same request twice yields byte-identical keys.
#[derive(Clone, Copy, Debug)]
pub struct Router {
    n_tasks: usize,
}

impl Router {
    pub fn new(n_tasks: usize) -> Self {
        Self { n_tasks }
    }

    /// Task count this router validates against.
    pub fn n_tasks(&self) -> usize {
        self.n_tasks
    }

    /// Canonicalize and validate one request.
    pub fn route(&self, tasks: &[usize], lambdas: &[f32]) -> Result<MergeSpec> {
        let spec = MergeSpec::new(tasks, lambdas)?;
        if let Some(&(t, _)) = spec.pairs().last() {
            if t >= self.n_tasks {
                bail!("task index {t} out of range ({} tasks)", self.n_tasks);
            }
        }
        Ok(spec)
    }
}

/// The canonical routed merge: task-vector loads fan out across the
/// [`ExecCtx`]'s pool, the accumulate runs on the caller's thread
/// **sequentially in ascending task order** — so the merged floats are
/// bit-identical at every thread count, and bit-identical to a one-task
/// delta patch of the spec's [`parent`](MergeSpec::parent) (the patch
/// replays exactly the final accumulation step).
pub fn merge_spec(
    spec: &MergeSpec,
    pre: &Checkpoint,
    source: &dyn TaskVectorSource,
    ctx: &ExecCtx,
) -> Result<MergedModel> {
    let _op = ctx.op_span(crate::obs::Category::Merge);
    let pool = ctx.pool();
    for &(t, _) in spec.pairs() {
        if t >= source.n_tasks() {
            bail!("task index {t} out of range ({} tasks)", source.n_tasks());
        }
    }
    // Mirrors merge_from_source: one task parallelizes inside the load,
    // several parallelize across tasks — either way each tau is
    // bit-identical to its sequential decode.
    let taus: Vec<Checkpoint> = if spec.len() == 1 {
        vec![source.task_vector_with_pool(spec.pairs()[0].0, pool)?]
    } else {
        pool.try_map(spec.tasks(), |_, t| source.task_vector(t))?
    };
    let mut out = pre.clone();
    for (&(_, lam), tau) in spec.pairs().iter().zip(&taus) {
        out.axpy(lam, tau)?;
    }
    Ok(MergedModel::Shared(out))
}

/// [`merge_spec`] on an explicit pool — the PR-7 twin, superseded by
/// [`ExecCtx`].
#[deprecated(note = "use merge_spec(spec, pre, source, &ExecCtx::with_pool(pool))")]
pub fn merge_spec_with_pool(
    spec: &MergeSpec,
    pre: &Checkpoint,
    source: &dyn TaskVectorSource,
    pool: &Pool,
) -> Result<MergedModel> {
    merge_spec(spec, pre, source, &ExecCtx::with_pool(pool))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_is_deterministic_and_order_independent() {
        let router = Router::new(8);
        let a = router.route(&[5, 2, 7], &[0.1, 0.3, -0.2]).unwrap();
        let b = router.route(&[2, 7, 5], &[0.3, -0.2, 0.1]).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.variant_key("src"), b.variant_key("src"));
        assert_eq!(a.tasks(), vec![2, 5, 7]);
        // Routing the same request again yields the identical key.
        let c = router.route(&[5, 2, 7], &[0.1, 0.3, -0.2]).unwrap();
        assert_eq!(a.variant_key("src"), c.variant_key("src"));
    }

    #[test]
    fn key_is_bit_exact_in_lambda_and_qualified_by_source() {
        let router = Router::new(4);
        let a = router.route(&[1], &[0.3]).unwrap();
        let b = router.route(&[1], &[0.2 + 0.1]).unwrap(); // differs in the last ulp
        assert_ne!(0.3f32.to_bits(), (0.2f32 + 0.1f32).to_bits());
        assert_ne!(a.variant_key("src"), b.variant_key("src"));
        assert_eq!(a.key_fragment(), format!("t1*{:08x}", 0.3f32.to_bits()));
        // Same spec over two sources must not collide.
        assert_ne!(a.variant_key("zoo-a"), a.variant_key("zoo-b"));
        assert_eq!(a.variant_key("zoo-a").0, DYNAMIC_METHOD);
    }

    #[test]
    fn invalid_requests_are_rejected() {
        let router = Router::new(4);
        let err = |r: Result<MergeSpec>| r.unwrap_err().to_string();
        assert!(err(router.route(&[], &[])).contains("no tasks"));
        assert!(err(router.route(&[0, 1], &[0.3])).contains("2 tasks but 1 lambdas"));
        assert!(err(router.route(&[1, 1], &[0.3, 0.2])).contains("task 1 twice"));
        assert!(err(router.route(&[4], &[0.3])).contains("out of range"));
        assert!(err(router.route(&[0], &[f32::NAN])).contains("non-finite"));
        assert!(err(router.route(&[0], &[f32::INFINITY])).contains("non-finite"));
    }

    #[test]
    fn parent_drops_the_highest_task_only() {
        let spec = MergeSpec::new(&[7, 2, 5], &[-0.2, 0.3, 0.1]).unwrap();
        let (parent, t, lam) = spec.parent().unwrap();
        assert_eq!(t, 7);
        assert_eq!(lam, -0.2);
        assert_eq!(parent.tasks(), vec![2, 5]);
        let (grand, t2, _) = parent.parent().unwrap();
        assert_eq!(t2, 5);
        assert_eq!(grand.tasks(), vec![2]);
        assert!(grand.parent().is_none(), "single-task specs have no patch base");
    }
}
