//! Serving coordinator — the Layer-3 runtime that turns quantized
//! checkpoints into a deployable multi-task inference service.
//!
//! Architecture (threads + channels; tokio is unavailable offline, and the
//! PJRT [`Runtime`](crate::runtime::Runtime) is deliberately `!Send`, so
//! each executor thread owns its own client):
//!
//! ```text
//!  submit(task, x) ──► bounded queue ──► router thread
//!                                           │  groups by task,
//!                                           │  flushes on size/deadline
//!                                           ▼
//!                                     batch channel ──► executor threads
//!                                                       (own Runtime each,
//!                                                        bucketed forward)
//!                                           │
//!                 response channel ◄────────┘  per-request one-shot
//! ```
//!
//! * [`batcher`] — pure batching logic (size + deadline flush rules,
//!   bounded per-task queues), property-tested without threads.
//! * [`server`] — the running service: router, executor pool, backpressure.
//! * [`cache`] — merged-model cache keyed by (merge method, quant scheme),
//!   so a fleet of model variants shares one pre-trained trunk in memory.
//!   Doubles as the incremental-merge engine: routed requests that differ
//!   from a cached variant by one appended task are served by a single
//!   signed axpy over the cached floats instead of a full re-merge
//!   ([`ModelCache::get_or_merge_routed`]), bit-identically.
//! * [`router`] — per-request dynamic merging: canonicalizes a declared
//!   task subset + lambdas into a deterministic [`MergeSpec`]/variant
//!   key, and defines the canonical ascending-order merge those variants
//!   are built by.
//! * [`metrics`] — lock-free counters and log2-bucket histograms
//!   (latency, queue wait, merge build — see [`crate::obs`]), plus the
//!   per-variant counters the control plane reports.  The TCP front
//!   serves them as `status` JSON, Prometheus text (`metrics`) and a
//!   streaming NDJSON `watch` feed.
//! * [`control`] — the variant lifecycle layer above all of this:
//!   generational registry hot-swap, graceful drain, admission control,
//!   and the node byte budget (see its module docs).
//! * [`fetch`] — the tier-1 section server: a bounded-mailbox executor
//!   pool ([`SectionFetchPool`]) answering `fetch_section` requests over
//!   the shard files of one sharded zoo, exposed on the wire through
//!   [`TcpFront::bind_sections`].

pub mod batcher;
pub mod cache;
pub mod control;
pub mod fetch;
pub mod metrics;
pub mod router;
pub mod server;
pub mod tcp;

pub use batcher::{Batch, Batcher};
pub use cache::ModelCache;
pub use control::{
    ControlError, ControlPlane, GenerationalManifest, GenerationalRegistry, Variant,
    VariantConfig, VariantState,
};
pub use fetch::{SectionFetchPool, SectionProvider};
pub use metrics::{Metrics, MetricsSnapshot};
pub use router::{MergeSpec, Router};
pub use server::{ServeError, Server, ServerConfig, ServeModel};
pub use tcp::{StatusSource, TcpFront};

/// Select the smallest serving bucket that fits `n` items, if any.
/// Buckets are the batch sizes the AOT forward artifacts were lowered at
/// (e.g. `[1, 8, 32]` for `vit_s`); inputs are padded up to the bucket.
pub fn pick_bucket(buckets: &[usize], n: usize) -> Option<usize> {
    buckets.iter().copied().filter(|&b| b >= n).min()
}

/// Split `n` items into per-bucket chunk sizes when `n` exceeds the
/// largest bucket: greedy full buckets of the maximum size, then
/// [`pick_bucket`]-style padding for the remainder.  Returns `None` only
/// when `buckets` is empty.  With `n == 0` the split is empty.
pub fn bucket_chunks(buckets: &[usize], n: usize) -> Option<Vec<usize>> {
    let max = buckets.iter().copied().max()?;
    let mut chunks = Vec::new();
    let mut left = n;
    while left > max {
        chunks.push(max);
        left -= max;
    }
    if left > 0 {
        chunks.push(left);
    }
    Some(chunks)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        let buckets = [1usize, 8, 32];
        assert_eq!(pick_bucket(&buckets, 1), Some(1));
        assert_eq!(pick_bucket(&buckets, 2), Some(8));
        assert_eq!(pick_bucket(&buckets, 8), Some(8));
        assert_eq!(pick_bucket(&buckets, 9), Some(32));
        assert_eq!(pick_bucket(&buckets, 33), None);
    }

    #[test]
    fn bucket_selection_unordered_input() {
        assert_eq!(pick_bucket(&[32, 1, 8], 3), Some(8));
    }

    #[test]
    fn oversized_batches_split_across_buckets() {
        let buckets = [1usize, 8, 32];
        // Within the largest bucket: one chunk, same as pick_bucket.
        assert_eq!(bucket_chunks(&buckets, 5), Some(vec![5]));
        assert_eq!(bucket_chunks(&buckets, 32), Some(vec![32]));
        // Beyond it: greedy max-bucket chunks plus the remainder.
        assert_eq!(bucket_chunks(&buckets, 33), Some(vec![32, 1]));
        assert_eq!(bucket_chunks(&buckets, 70), Some(vec![32, 32, 6]));
        // Every chunk is itself servable.
        for chunk in bucket_chunks(&buckets, 100).unwrap() {
            assert!(pick_bucket(&buckets, chunk).is_some());
        }
        // Degenerate inputs.
        assert_eq!(bucket_chunks(&buckets, 0), Some(vec![]));
        assert_eq!(bucket_chunks(&[], 5), None);
        assert_eq!(bucket_chunks(&[32, 1, 8], 33), Some(vec![32, 1]));
    }
}
