//! Serving coordinator — the Layer-3 runtime that turns quantized
//! checkpoints into a deployable multi-task inference service.
//!
//! Architecture (threads + channels; tokio is unavailable offline, and the
//! PJRT [`Runtime`](crate::runtime::Runtime) is deliberately `!Send`, so
//! each executor thread owns its own client):
//!
//! ```text
//!  submit(task, x) ──► bounded queue ──► router thread
//!                                           │  groups by task,
//!                                           │  flushes on size/deadline
//!                                           ▼
//!                                     batch channel ──► executor threads
//!                                                       (own Runtime each,
//!                                                        bucketed forward)
//!                                           │
//!                 response channel ◄────────┘  per-request one-shot
//! ```
//!
//! * [`batcher`] — pure batching logic (size + deadline flush rules),
//!   property-tested without threads.
//! * [`server`] — the running service: router, executor pool, backpressure.
//! * [`cache`] — merged-model cache keyed by (merge method, quant scheme),
//!   so a fleet of model variants shares one pre-trained trunk in memory.
//! * [`metrics`] — atomic counters + latency summary.

pub mod batcher;
pub mod cache;
pub mod metrics;
pub mod server;
pub mod tcp;

pub use batcher::{Batch, Batcher};
pub use cache::ModelCache;
pub use metrics::{Metrics, MetricsSnapshot};
pub use server::{Server, ServerConfig, ServeModel};
pub use tcp::TcpFront;

/// Select the smallest serving bucket that fits `n` items, if any.
/// Buckets are the batch sizes the AOT forward artifacts were lowered at
/// (e.g. `[1, 8, 32]` for `vit_s`); inputs are padded up to the bucket.
pub fn pick_bucket(buckets: &[usize], n: usize) -> Option<usize> {
    buckets.iter().copied().filter(|&b| b >= n).min()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        let buckets = [1usize, 8, 32];
        assert_eq!(pick_bucket(&buckets, 1), Some(1));
        assert_eq!(pick_bucket(&buckets, 2), Some(8));
        assert_eq!(pick_bucket(&buckets, 8), Some(8));
        assert_eq!(pick_bucket(&buckets, 9), Some(32));
        assert_eq!(pick_bucket(&buckets, 33), None);
    }

    #[test]
    fn bucket_selection_unordered_input() {
        assert_eq!(pick_bucket(&[32, 1, 8], 3), Some(8));
    }
}
