//! TCP front-end: newline-delimited JSON over `std::net`, turning the
//! in-process [`Server`] into a network service (no HTTP stack needed —
//! the protocol is one JSON object per line in each direction).
//!
//! Request:  `{"task": 3, "x": [f32; tokens*token_dim]}`
//! Response: `{"logits": [f32; n_classes]}` or `{"error": "..."}`
//!
//! Control API (same wire, same framing):
//!
//! Request:  `{"cmd": "status"}`
//! Response: `{"server": {...metrics...}, "control": {...variants...}}`
//!
//! Two further control commands break the one-line-reply shape:
//!
//! * `{"cmd": "metrics"}` replies with a Prometheus text exposition —
//!   multiple lines, terminated by one blank line — then the
//!   connection returns to request/reply framing.
//! * `{"cmd": "watch", "interval_ms": N}` switches the connection into
//!   streaming mode: the server pushes one newline-delimited JSON
//!   *delta frame* every `N` ms (counters as deltas since the previous
//!   frame, histogram quantiles and pool busy as gauges, per-variant
//!   rows when a control plane is attached) until the client
//!   disconnects or the front-end shuts down.
//!
//! The `control` key appears when the front-end was bound with a
//! [`StatusSource`] (normally the
//! [`ControlPlane`](super::control::ControlPlane)) via
//! [`TcpFront::bind_with_status`]; a plain [`bind`](TcpFront::bind)
//! reports server metrics only.
//!
//! A front bound with [`TcpFront::bind_sections`] speaks one more
//! command — the tier-1 registry fetch protocol — and is the only
//! reply that breaks pure line framing with a *binary* body:
//!
//! Request:  `{"cmd": "fetch_section", "shard": S, "offset": O, "length": L}`
//! Response: `{"ok": true, "length": L, "crc": C}` + exactly `L` raw
//!           bytes, or an `{"error": "..."}` line with no body.
//!
//! One handler thread per connection (bounded by `max_conns`); each
//! inference request is forwarded through [`Server::submit`], so
//! batching, backpressure and metrics behave exactly as for in-process
//! callers, and each section fetch through the bounded-mailbox
//! [`SectionProvider`](super::fetch::SectionProvider) pool.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use super::fetch::SectionProvider;
use super::metrics::MetricsSnapshot;
use super::server::Server;
use crate::tensor::Tensor;
use crate::util::crc32;
use crate::util::json::Json;

/// Supplies the `control` section of a `{"cmd": "status"}` reply — the
/// seam through which the control plane exposes per-variant state on
/// the wire without [`TcpFront`] depending on it.
pub trait StatusSource: Send + Sync {
    fn status_json(&self) -> Json;

    /// Append this source's Prometheus text exposition (per-variant
    /// families) to `out`.  Default: contributes nothing.
    fn prometheus_into(&self, _out: &mut String) {}
}

/// A running TCP front-end bound to a local address.
pub struct TcpFront {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl TcpFront {
    /// Bind `addr` (e.g. `"127.0.0.1:0"`) and serve `server` until
    /// [`shutdown`](Self::shutdown). Accepts at most `max_conns`
    /// concurrent connections; extras are refused with an error line.
    pub fn bind(addr: &str, server: Arc<Server>, max_conns: usize) -> Result<TcpFront> {
        Self::bind_with_status(addr, server, max_conns, None)
    }

    /// [`bind`](Self::bind) with a [`StatusSource`] whose snapshot is
    /// embedded under `control` in `{"cmd": "status"}` replies.
    pub fn bind_with_status(
        addr: &str,
        server: Arc<Server>,
        max_conns: usize,
        status: Option<Arc<dyn StatusSource>>,
    ) -> Result<TcpFront> {
        Self::bind_inner(addr, Some(server), max_conns, status, None)
    }

    /// Bind a **section server**: no inference backend, just the tier-1
    /// registry fetch protocol (`fetch_section`) plus `status` answered
    /// from the provider.  Inference / metrics / watch requests get a
    /// pointed error line.
    pub fn bind_sections(
        addr: &str,
        provider: Arc<dyn SectionProvider>,
        max_conns: usize,
    ) -> Result<TcpFront> {
        Self::bind_inner(addr, None, max_conns, None, Some(provider))
    }

    fn bind_inner(
        addr: &str,
        server: Option<Arc<Server>>,
        max_conns: usize,
        status: Option<Arc<dyn StatusSource>>,
        sections: Option<Arc<dyn SectionProvider>>,
    ) -> Result<TcpFront> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let conns = Arc::new(AtomicUsize::new(0));
        let accept_thread = std::thread::Builder::new()
            .name("tvq-tcp-accept".into())
            .spawn(move || {
                // Poll with a timeout so shutdown is prompt.
                listener
                    .set_nonblocking(true)
                    .expect("nonblocking listener");
                loop {
                    if stop2.load(Ordering::Relaxed) {
                        return;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            if conns.load(Ordering::Relaxed) >= max_conns {
                                let mut s = stream;
                                let _ = writeln!(s, r#"{{"error":"too many connections"}}"#);
                                continue;
                            }
                            conns.fetch_add(1, Ordering::Relaxed);
                            let srv = server.clone();
                            let cd = conns.clone();
                            let st = stop2.clone();
                            let stat = status.clone();
                            let sect = sections.clone();
                            let _ = std::thread::Builder::new()
                                .name("tvq-tcp-conn".into())
                                .spawn(move || {
                                    let _ = handle_conn(stream, srv, stat, sect, st);
                                    cd.fetch_sub(1, Ordering::Relaxed);
                                });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => return,
                    }
                }
            })?;
        Ok(TcpFront { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting; existing connections finish their current line.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for TcpFront {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_conn(
    stream: TcpStream,
    server: Option<Arc<Server>>,
    status: Option<Arc<dyn StatusSource>>,
    sections: Option<Arc<dyn SectionProvider>>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {
                match handle_line(&line, server.as_deref(), status.as_deref(), sections.as_deref())
                {
                    Ok(Reply::Line(json)) => writeln!(writer, "{}", json.to_string_compact())?,
                    Ok(Reply::Text(text)) => {
                        // Multi-line exposition, blank-line terminated so a
                        // line-oriented client knows where it ends.
                        writer.write_all(text.as_bytes())?;
                        writeln!(writer)?;
                    }
                    Ok(Reply::Blob(header, body)) => {
                        // The one framing exception: a JSON header line
                        // followed by exactly `length` raw bytes.
                        writeln!(writer, "{}", header.to_string_compact())?;
                        writer.write_all(&body)?;
                        writer.flush()?;
                    }
                    Ok(Reply::Watch { interval }) => {
                        // The connection becomes a push stream; it ends on
                        // client disconnect or front-end shutdown.  (A
                        // watch is only reachable with a server bound.)
                        let srv = server.as_deref().expect("watch requires a server");
                        return watch_loop(&mut writer, interval, srv, status.as_deref(), &stop);
                    }
                    Err(e) => writeln!(
                        writer,
                        "{}",
                        Json::obj(vec![("error", Json::str(&format!("{e:#}")))])
                            .to_string_compact()
                    )?,
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(_) => return Ok(()),
        }
    }
}

/// How a handled request line is answered on the wire.
enum Reply {
    /// One JSON object on one line (the default framing).
    Line(Json),
    /// Pre-rendered multi-line text followed by one blank line.
    Text(String),
    /// A JSON header line followed by the raw bytes it describes
    /// (section fetch replies).
    Blob(Json, Vec<u8>),
    /// Switch the connection into streaming-watch mode.
    Watch { interval: Duration },
}

fn handle_line(
    line: &str,
    server: Option<&Server>,
    status: Option<&dyn StatusSource>,
    sections: Option<&dyn SectionProvider>,
) -> Result<Reply> {
    let req = Json::parse(line).context("malformed JSON request")?;
    let need_server = |server: Option<&Server>, cmd: &str| {
        server.ok_or_else(|| {
            anyhow::anyhow!("{cmd} needs an inference server; this endpoint serves sections only")
        })
    };
    if let Some(cmd) = req.get("cmd") {
        return match cmd.as_str()? {
            "status" => {
                let mut fields = Vec::new();
                if let Some(srv) = server {
                    fields.push(("server", srv.metrics().to_json()));
                }
                if let Some(s) = status {
                    fields.push(("control", s.status_json()));
                }
                if let Some(p) = sections {
                    fields.push(("sections", p.status_json()));
                }
                Ok(Reply::Line(Json::obj(fields)))
            }
            "metrics" => {
                let srv = need_server(server, "metrics")?;
                let mut out = String::new();
                srv.metrics().prometheus_into(&mut out);
                if let Some(s) = status {
                    s.prometheus_into(&mut out);
                }
                Ok(Reply::Text(out))
            }
            "watch" => {
                need_server(server, "watch")?;
                let interval_ms = match req.get("interval_ms") {
                    Some(v) => v.as_usize().context("watch interval_ms")?,
                    None => 1_000,
                };
                // Floor keeps a zero/tiny interval from busy-spinning the
                // handler thread against the snapshot locks.
                Ok(Reply::Watch { interval: Duration::from_millis(interval_ms.max(10) as u64) })
            }
            "fetch_section" => {
                let p = sections.ok_or_else(|| {
                    anyhow::anyhow!("this endpoint has no section store (no manifest attached)")
                })?;
                let shard = req.req("shard")?.as_usize()? as u32;
                let offset = req.req("offset")?.as_usize()? as u64;
                let length = req.req("length")?.as_usize()? as u64;
                // Provider errors flow to the generic error line, relayed
                // verbatim to the client's bail.
                let body = p.fetch_section(shard, offset, length)?;
                let header = Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("length", Json::num(body.len() as f64)),
                    ("crc", Json::num(crc32(&body) as f64)),
                ]);
                Ok(Reply::Blob(header, body))
            }
            other => anyhow::bail!(
                "unknown cmd {other:?} (supported: \"status\", \"metrics\", \"watch\", \
                 \"fetch_section\")"
            ),
        };
    }
    let task = req.req("task")?.as_usize()?;
    let xs = req.req("x")?.as_arr()?;
    let data: Vec<f32> = xs
        .iter()
        .map(|v| v.as_f64().map(|f| f as f32))
        .collect::<Result<_>>()?;
    let x = Tensor::from_vec(data);
    let server = need_server(server, "inference")?;
    let logits = server.infer(task, &x)?;
    Ok(Reply::Line(Json::obj(vec![(
        "logits",
        Json::arr(logits.into_iter().map(|v| Json::num(v as f64))),
    )])))
}

/// Per-variant counters remembered between watch frames, keyed by
/// variant name, so the stream can report deltas.
type VariantCounters = BTreeMap<String, (u64, u64, u64)>;

/// Push one delta frame per interval until the client disconnects (the
/// write fails) or the front-end stops.  The first frame's deltas are
/// against a zero snapshot, i.e. the totals accumulated so far.
fn watch_loop(
    writer: &mut TcpStream,
    interval: Duration,
    server: &Server,
    status: Option<&dyn StatusSource>,
    stop: &AtomicBool,
) -> Result<()> {
    let mut prev = MetricsSnapshot::default();
    let mut prev_variants = VariantCounters::new();
    let mut seq = 0u64;
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        let cur = server.metrics();
        let frame = watch_frame(seq, &prev, &cur, status, &mut prev_variants);
        if writeln!(writer, "{}", frame.to_string_compact()).is_err() {
            return Ok(()); // client went away — the normal way a watch ends
        }
        prev = cur;
        seq += 1;
        // Sleep in short slices so shutdown stays prompt even with a
        // long interval.
        let mut left = interval;
        while !left.is_zero() {
            if stop.load(Ordering::Relaxed) {
                return Ok(());
            }
            let slice = left.min(Duration::from_millis(100));
            std::thread::sleep(slice);
            left -= slice;
        }
    }
}

/// One newline-delimited JSON delta frame: monotone counters as deltas
/// since the previous frame, histogram quantiles / pool busy /
/// generation as gauges.
fn watch_frame(
    seq: u64,
    prev: &MetricsSnapshot,
    cur: &MetricsSnapshot,
    status: Option<&dyn StatusSource>,
    prev_variants: &mut VariantCounters,
) -> Json {
    let d = |c: u64, p: u64| Json::num(c.saturating_sub(p) as f64);
    let server = Json::obj(vec![
        ("submitted", d(cur.submitted, prev.submitted)),
        ("completed", d(cur.completed, prev.completed)),
        ("rejected", d(cur.rejected, prev.rejected)),
        ("failed", d(cur.failed, prev.failed)),
        ("batches", d(cur.batches, prev.batches)),
        ("merge_builds", d(cur.merge_builds, prev.merge_builds)),
        ("mean_batch_size", Json::num(cur.mean_batch_size)),
        ("latency_p50_us", Json::num(cur.latency_p50_us)),
        ("latency_p99_us", Json::num(cur.latency_p99_us)),
        ("queue_wait_p50_us", Json::num(cur.queue_wait.p50 as f64 / 1e3)),
        ("merge_build_speedup", Json::num(cur.merge_build_speedup())),
        ("pool_busy_mean_ms", Json::num(cur.pool_busy_mean_ms)),
    ]);
    let mut fields = vec![("seq", Json::num(seq as f64)), ("server", server)];
    if let Some(s) = status {
        let variants = variant_rows(&s.status_json(), prev_variants);
        fields.push(("variants", Json::arr(variants)));
    }
    Json::obj(fields)
}

/// Extract per-variant delta rows from a [`StatusSource`] snapshot.
/// Tolerates arbitrary status shapes (rows without the expected fields
/// are skipped) since the source is a trait object.
fn variant_rows(status: &Json, prev: &mut VariantCounters) -> Vec<Json> {
    let Some(variants) = status.get("variants").and_then(|v| v.as_arr().ok()) else {
        return Vec::new();
    };
    let mut rows = Vec::new();
    for v in variants {
        let Some(name) = v.get("name").and_then(|n| n.as_str().ok()) else {
            continue;
        };
        let counter = |key: &str| {
            v.get(key).and_then(|x| x.as_f64().ok()).unwrap_or(0.0) as u64
        };
        let (admitted, completed, rejected) =
            (counter("admitted"), counter("completed"), counter("rejected"));
        let (pa, pc, pr) =
            prev.insert(name.to_string(), (admitted, completed, rejected)).unwrap_or((0, 0, 0));
        let mut row = vec![
            ("name", Json::str(name)),
            ("admitted", Json::num(admitted.saturating_sub(pa) as f64)),
            ("completed", Json::num(completed.saturating_sub(pc) as f64)),
            ("rejected", Json::num(rejected.saturating_sub(pr) as f64)),
        ];
        for gauge in ["state", "generation", "queue_depth"] {
            if let Some(val) = v.get(gauge) {
                row.push((gauge, val.clone()));
            }
        }
        if let Some(p50) = v.get("service_us").and_then(|s| s.get("p50")) {
            row.push(("service_p50_us", p50.clone()));
        }
        rows.push(Json::obj(row));
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::{Backend, ServerConfig};
    use crate::data::VIT_S;
    use std::io::Write as _;

    struct EchoBackend;
    impl Backend for EchoBackend {
        fn infer(&mut self, task: usize, x: &Tensor, n: usize) -> Result<Vec<Vec<f32>>> {
            let img = x.numel() / x.shape()[0];
            Ok((0..n)
                .map(|i| vec![x.data()[i * img], task as f32])
                .collect())
        }
    }

    fn start() -> (TcpFront, Arc<Server>) {
        let server = Arc::new(
            Server::start_with_backend(ServerConfig::default(), &VIT_S, 4, || {
                Ok(EchoBackend)
            })
            .unwrap(),
        );
        let front = TcpFront::bind("127.0.0.1:0", server.clone(), 8).unwrap();
        (front, server)
    }

    fn roundtrip(addr: std::net::SocketAddr, line: &str) -> String {
        let mut conn = TcpStream::connect(addr).unwrap();
        writeln!(conn, "{line}").unwrap();
        let mut reader = BufReader::new(conn);
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        reply
    }

    fn req_line(task: usize, first: f32) -> String {
        let n = VIT_S.tokens * VIT_S.token_dim;
        let mut xs = vec!["0".to_string(); n];
        xs[0] = format!("{first}");
        format!(r#"{{"task": {task}, "x": [{}]}}"#, xs.join(","))
    }

    #[test]
    fn serves_json_over_tcp() {
        let (front, _server) = start();
        let reply = roundtrip(front.addr(), &req_line(2, 7.5));
        assert!(reply.contains("logits"), "reply: {reply}");
        assert!(reply.contains("7.5"), "echoed first value: {reply}");
        assert!(reply.contains('2'), "task id: {reply}");
    }

    #[test]
    fn malformed_and_invalid_requests_get_error_lines() {
        let (front, _server) = start();
        let reply = roundtrip(front.addr(), "this is not json");
        assert!(reply.contains("error"), "reply: {reply}");
        // Valid JSON, bad task index.
        let reply = roundtrip(front.addr(), &req_line(99, 0.0));
        assert!(reply.contains("error"), "reply: {reply}");
        // Wrong input length.
        let reply = roundtrip(front.addr(), r#"{"task": 0, "x": [1.0, 2.0]}"#);
        assert!(reply.contains("error"), "reply: {reply}");
    }

    #[test]
    fn multiple_requests_per_connection() {
        let (front, server) = start();
        let mut conn = TcpStream::connect(front.addr()).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        for i in 0..5 {
            writeln!(conn, "{}", req_line(i % 4, i as f32)).unwrap();
            let mut reply = String::new();
            reader.read_line(&mut reply).unwrap();
            assert!(reply.contains("logits"), "iter {i}: {reply}");
        }
        assert_eq!(server.metrics().completed, 5);
    }

    #[test]
    fn status_command_reports_server_and_control_sections() {
        struct FakePlane;
        impl StatusSource for FakePlane {
            fn status_json(&self) -> Json {
                Json::obj(vec![("variants", Json::arr(vec![Json::str("zoo")]))])
            }
        }
        let server = Arc::new(
            Server::start_with_backend(ServerConfig::default(), &VIT_S, 4, || {
                Ok(EchoBackend)
            })
            .unwrap(),
        );
        let front = TcpFront::bind_with_status(
            "127.0.0.1:0",
            server.clone(),
            8,
            Some(Arc::new(FakePlane)),
        )
        .unwrap();
        // One real request first so the metrics are non-trivial.
        let reply = roundtrip(front.addr(), &req_line(1, 3.0));
        assert!(reply.contains("logits"), "reply: {reply}");
        let reply = roundtrip(front.addr(), r#"{"cmd": "status"}"#);
        let parsed = Json::parse(reply.trim()).unwrap();
        let completed = parsed
            .req("server")
            .unwrap()
            .req("completed")
            .unwrap()
            .as_usize()
            .unwrap();
        assert_eq!(completed, 1, "reply: {reply}");
        let control = parsed.req("control").unwrap();
        assert_eq!(
            control.req("variants").unwrap().as_arr().unwrap()[0].as_str().unwrap(),
            "zoo"
        );
        // Unknown cmds get an error line, not a hang.
        let reply = roundtrip(front.addr(), r#"{"cmd": "reboot"}"#);
        assert!(reply.contains("error"), "reply: {reply}");
    }

    #[test]
    fn metrics_command_returns_prometheus_text() {
        let (front, _server) = start();
        let reply = roundtrip(front.addr(), &req_line(1, 2.0));
        assert!(reply.contains("logits"), "reply: {reply}");
        // The exposition is multi-line, blank-line terminated; read it
        // all on one connection.
        let mut conn = TcpStream::connect(front.addr()).unwrap();
        writeln!(conn, r#"{{"cmd": "metrics"}}"#).unwrap();
        let mut reader = BufReader::new(conn);
        let mut text = String::new();
        loop {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            if line.trim().is_empty() {
                break;
            }
            text.push_str(&line);
        }
        assert!(text.contains("tvq_requests_completed_total 1"), "exposition:\n{text}");
        assert!(text.contains("# TYPE tvq_request_latency_seconds summary"), "exposition:\n{text}");
        assert!(
            text.contains(r#"tvq_request_latency_seconds{quantile="0.5"}"#),
            "exposition:\n{text}"
        );
    }

    #[test]
    fn watch_command_streams_delta_frames() {
        let (front, _server) = start();
        let reply = roundtrip(front.addr(), &req_line(0, 1.0));
        assert!(reply.contains("logits"), "reply: {reply}");
        let mut conn = TcpStream::connect(front.addr()).unwrap();
        writeln!(conn, r#"{{"cmd": "watch", "interval_ms": 20}}"#).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut frames = Vec::new();
        for _ in 0..2 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            frames.push(Json::parse(line.trim()).unwrap());
        }
        // Frame 0 carries totals-so-far; frame 1 is a pure delta.
        assert_eq!(frames[0].req("seq").unwrap().as_usize().unwrap(), 0);
        assert_eq!(frames[1].req("seq").unwrap().as_usize().unwrap(), 1);
        let f0 = frames[0].req("server").unwrap();
        assert_eq!(f0.req("completed").unwrap().as_usize().unwrap(), 1);
        let f1 = frames[1].req("server").unwrap();
        assert_eq!(f1.req("completed").unwrap().as_usize().unwrap(), 0);
        // Dropping the client ends the stream server-side (no hang, no
        // panic) — nothing further to assert; the handler thread exits
        // on the failed write.
        drop(conn);
    }

    #[test]
    fn status_without_source_omits_control() {
        let (front, _server) = start();
        let reply = roundtrip(front.addr(), r#"{"cmd": "status"}"#);
        let parsed = Json::parse(reply.trim()).unwrap();
        assert!(parsed.get("server").is_some(), "reply: {reply}");
        assert!(parsed.get("control").is_none(), "reply: {reply}");
    }

    #[test]
    fn section_endpoint_serves_blobs_and_refuses_inference() {
        struct OneChunk;
        impl SectionProvider for OneChunk {
            fn fetch_section(&self, shard: u32, offset: u64, length: u64) -> Result<Vec<u8>> {
                if shard != 0 {
                    anyhow::bail!("fetch_section references shard {shard} of 1");
                }
                Ok((offset..offset + length).map(|b| b as u8).collect())
            }
            fn status_json(&self) -> Json {
                Json::obj(vec![("role", Json::str("section-server"))])
            }
        }
        let front = TcpFront::bind_sections("127.0.0.1:0", Arc::new(OneChunk), 4).unwrap();
        let mut conn = TcpStream::connect(front.addr()).unwrap();
        writeln!(conn, r#"{{"cmd":"fetch_section","shard":0,"offset":3,"length":4}}"#).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut header = String::new();
        reader.read_line(&mut header).unwrap();
        let parsed = Json::parse(header.trim()).unwrap();
        assert_eq!(parsed.req("length").unwrap().as_usize().unwrap(), 4, "header: {header}");
        let mut body = [0u8; 4];
        std::io::Read::read_exact(&mut reader, &mut body).unwrap();
        assert_eq!(body, [3, 4, 5, 6]);
        assert_eq!(
            parsed.req("crc").unwrap().as_f64().unwrap() as u32,
            crate::util::crc32(&body)
        );
        // Provider errors come back as a plain error line, verbatim.
        writeln!(conn, r#"{{"cmd":"fetch_section","shard":9,"offset":0,"length":1}}"#).unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        assert!(reply.contains("shard 9"), "reply: {reply}");
        // No inference server behind this endpoint.
        writeln!(conn, r#"{{"task": 0, "x": [1.0]}}"#).unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        assert!(reply.contains("sections only"), "reply: {reply}");
        // Status still answers, from the provider.
        writeln!(conn, r#"{{"cmd":"status"}}"#).unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        assert!(reply.contains("section-server"), "reply: {reply}");
    }

    #[test]
    fn shutdown_is_prompt() {
        let (mut front, _server) = start();
        let t0 = std::time::Instant::now();
        front.shutdown();
        assert!(t0.elapsed() < std::time::Duration::from_secs(2));
        assert!(TcpStream::connect(front.addr()).is_err() || {
            // Listener may linger in TIME_WAIT; a connect that succeeds
            // must at least get no service (accept loop exited).
            true
        });
    }
}
