//! Serving metrics: lock-free counters and histograms.
//!
//! Every record path — request latency, queue wait, merge builds,
//! per-variant service time — is relaxed atomics only ([`Histogram`]
//! buckets + counters).  The previous design funneled latencies
//! through a `Mutex<Vec<f64>>` reservoir indexed by the independently
//! incremented `completed` counter, so concurrent recorders clobbered
//! arbitrary slots and `reset_window` desynced the cursor; the
//! histogram migration removed the reservoir (and its `LATENCY_CAP`)
//! entirely.  `concurrent_latency_recording_is_exact` pins the
//! removal: N recorders on M threads must yield exactly N samples.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::obs::hist::{Histogram, HistogramSummary};
use crate::util::json::Json;
use crate::util::pool::Pool;

/// Shared metrics registry (one per [`Server`](super::Server)).
#[derive(Debug, Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub failed: AtomicU64,
    pub batches: AtomicU64,
    pub batch_items: AtomicU64,
    /// Merge builds completed through the model cache.
    pub merge_builds: AtomicU64,
    /// Total wall-clock time of those builds, microseconds.
    merge_build_wall_us: AtomicU64,
    /// Total worker-busy ("cpu") time of those builds, microseconds —
    /// the pool-side decode/quantize time summed across threads, so
    /// `busy / wall` is the realized parallel speedup.
    merge_build_busy_us: AtomicU64,
    /// One-task delta patches served by the model cache in place of a
    /// full re-merge (see `ModelCache::get_or_merge_routed`).
    pub delta_patches: AtomicU64,
    /// Total wall-clock time of those patches, microseconds.
    delta_patch_wall_us: AtomicU64,
    /// End-to-end latency (submit -> response), nanoseconds.
    pub latency: Histogram,
    /// Queue wait (submit -> executor pickup), nanoseconds.
    pub queue_wait: Histogram,
    /// Per-build merge wall time, nanoseconds.
    pub merge_build: Histogram,
    /// Per-patch wall time, nanoseconds (one task-vector decode + one
    /// axpy — compare against `merge_build` for the patch win).
    pub delta_patch: Histogram,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one end-to-end request latency.  Lock-free (histogram
    /// atomics only) — safe to call from any number of executors.
    pub fn record_latency(&self, d: Duration) {
        self.latency.record_ns(d);
    }

    /// Record one request's queue wait (submit -> executor pickup).
    pub fn record_queue_wait(&self, d: Duration) {
        self.queue_wait.record_ns(d);
    }

    pub fn record_batch(&self, items: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_items.fetch_add(items as u64, Ordering::Relaxed);
    }

    /// Record one merge build: `wall` is its elapsed time, `busy` the
    /// worker-pool busy time it consumed across threads (approximate
    /// when concurrent builds share the pool).  The snapshot reports
    /// `busy / wall` as the realized parallel speedup.
    pub fn record_merge_build(&self, wall: Duration, busy: Duration) {
        self.merge_builds.fetch_add(1, Ordering::Relaxed);
        self.merge_build_wall_us
            .fetch_add(wall.as_micros() as u64, Ordering::Relaxed);
        self.merge_build_busy_us
            .fetch_add(busy.as_micros() as u64, Ordering::Relaxed);
        self.merge_build.record_ns(wall);
    }

    /// Record one incremental delta patch: a cached neighbor variant was
    /// promoted to the requested one by a single signed axpy instead of
    /// a full re-merge.
    pub fn record_delta_patch(&self, wall: Duration) {
        self.delta_patches.fetch_add(1, Ordering::Relaxed);
        self.delta_patch_wall_us
            .fetch_add(wall.as_micros() as u64, Ordering::Relaxed);
        self.delta_patch.record_ns(wall);
    }

    /// Clear latency/queue-wait histograms and batch counters
    /// (post-warmup reset so percentiles reflect steady state);
    /// monotone counters and merge-build totals are kept.
    pub fn reset_window(&self) {
        self.latency.reset();
        self.queue_wait.reset();
        self.batches.store(0, Ordering::Relaxed);
        self.batch_items.store(0, Ordering::Relaxed);
    }

    /// Consistent point-in-time view.  Pool-busy spread is sampled
    /// from [`Pool::global`] (the hot paths' shared pool).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let lat = self.latency.summary();
        let batches = self.batches.load(Ordering::Relaxed);
        let items = self.batch_items.load(Ordering::Relaxed);
        let wall_us = self.merge_build_wall_us.load(Ordering::Relaxed);
        let busy_us = self.merge_build_busy_us.load(Ordering::Relaxed);
        let worker_busy = Pool::global().worker_busy_ns();
        let (bmin, bmax, bsum) = worker_busy.iter().fold((u64::MAX, 0u64, 0u64), |(lo, hi, s), &b| {
            (lo.min(b), hi.max(b), s + b)
        });
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            batches,
            mean_batch_size: if batches > 0 {
                items as f64 / batches as f64
            } else {
                0.0
            },
            latency_mean_us: lat.mean() / 1e3,
            latency_p50_us: lat.p50 as f64 / 1e3,
            latency_p90_us: lat.p90 as f64 / 1e3,
            latency_p99_us: lat.p99 as f64 / 1e3,
            latency_max_us: lat.max as f64 / 1e3,
            latency_count: lat.count,
            queue_wait: self.queue_wait.summary(),
            merge_builds: self.merge_builds.load(Ordering::Relaxed),
            merge_build_wall_ms: wall_us as f64 / 1e3,
            merge_build_busy_ms: busy_us as f64 / 1e3,
            merge_build_hist: self.merge_build.summary(),
            delta_patches: self.delta_patches.load(Ordering::Relaxed),
            delta_patch_wall_ms: self.delta_patch_wall_us.load(Ordering::Relaxed) as f64 / 1e3,
            delta_patch_hist: self.delta_patch.summary(),
            pool_workers: worker_busy.len(),
            pool_busy_min_ms: if worker_busy.is_empty() { 0.0 } else { bmin as f64 / 1e6 },
            pool_busy_max_ms: bmax as f64 / 1e6,
            pool_busy_mean_ms: if worker_busy.is_empty() {
                0.0
            } else {
                bsum as f64 / worker_busy.len() as f64 / 1e6
            },
        }
    }
}

/// Immutable metrics view for reporting.
#[derive(Clone, Copy, Debug, Default)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub failed: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub latency_mean_us: f64,
    pub latency_p50_us: f64,
    pub latency_p90_us: f64,
    pub latency_p99_us: f64,
    pub latency_max_us: f64,
    pub latency_count: u64,
    /// Queue-wait histogram summary, nanoseconds.
    pub queue_wait: HistogramSummary,
    pub merge_builds: u64,
    /// Total wall-clock of merge builds, ms.
    pub merge_build_wall_ms: f64,
    /// Total worker-busy ("cpu") time of merge builds, ms.
    pub merge_build_busy_ms: f64,
    /// Per-build wall-time histogram summary, nanoseconds.
    pub merge_build_hist: HistogramSummary,
    /// One-task delta patches served in place of full re-merges.
    pub delta_patches: u64,
    /// Total wall-clock of delta patches, ms.
    pub delta_patch_wall_ms: f64,
    /// Per-patch wall-time histogram summary, nanoseconds.
    pub delta_patch_hist: HistogramSummary,
    /// Global pool width and per-worker busy spread (shard-imbalance
    /// signal: a max far above the mean means uneven shards).
    pub pool_workers: usize,
    pub pool_busy_min_ms: f64,
    pub pool_busy_max_ms: f64,
    pub pool_busy_mean_ms: f64,
}

impl MetricsSnapshot {
    /// Realized parallel speedup of merge builds: pool busy time over
    /// wall time (~N = perfect scaling on N threads; 0.0 until a build
    /// has been recorded).  Busy time counts only work executed through
    /// the pool — build phases on the caller's thread (merge combine,
    /// checkpoint assembly) add wall but not busy, so a fully sequential
    /// build reports somewhat *below* 1.0 rather than exactly 1.0.
    pub fn merge_build_speedup(&self) -> f64 {
        if self.merge_build_wall_ms > 0.0 {
            self.merge_build_busy_ms / self.merge_build_wall_ms
        } else {
            0.0
        }
    }

    /// One-line summary for logs/benches.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "submitted {} completed {} rejected {} failed {} | batches {} (avg {:.1}) | latency p50 {:.0}us p99 {:.0}us",
            self.submitted,
            self.completed,
            self.rejected,
            self.failed,
            self.batches,
            self.mean_batch_size,
            self.latency_p50_us,
            self.latency_p99_us
        );
        if self.queue_wait.count > 0 {
            s.push_str(&format!(
                " | queue p50 {:.0}us",
                self.queue_wait.p50 as f64 / 1e3
            ));
        }
        if self.merge_builds > 0 {
            s.push_str(&format!(
                " | merge builds {} ({:.0} ms wall, x{:.2} parallel)",
                self.merge_builds,
                self.merge_build_wall_ms,
                self.merge_build_speedup()
            ));
        }
        if self.delta_patches > 0 {
            s.push_str(&format!(
                " | delta patches {} ({:.0} ms wall)",
                self.delta_patches, self.delta_patch_wall_ms
            ));
        }
        if self.pool_busy_max_ms > 0.0 {
            s.push_str(&format!(
                " | {} workers busy {:.0}/{:.0}/{:.0} ms min/mean/max",
                self.pool_workers,
                self.pool_busy_min_ms,
                self.pool_busy_mean_ms,
                self.pool_busy_max_ms
            ));
        }
        s
    }
}

/// Per-variant serving counters for the control plane (one per
/// [`Variant`](super::control::Variant)): admission outcomes, drain
/// flushes, queue depth, the registry generation gauge, and the
/// service-time histogram.  All relaxed atomics — the admission
/// queue's send/recv pairs provide the ordering that keeps
/// `queue_depth` consistent.
#[derive(Debug, Default)]
pub struct VariantMetrics {
    /// Jobs accepted into the bounded admission queue.
    pub admitted: AtomicU64,
    /// Typed rejections (queue full, variant not `Ready`).
    pub rejected: AtomicU64,
    /// Jobs the worker ran to completion.
    pub completed: AtomicU64,
    /// Queued jobs flushed with `DrainDeadlineExpired`.
    pub drained: AtomicU64,
    /// Jobs admitted but not yet picked up by the worker.
    pub queue_depth: AtomicU64,
    /// Current registry generation (gauge, updated on publish/reload).
    pub generation: AtomicU64,
    /// Per-job service time in the variant worker, nanoseconds.
    pub service: Histogram,
}

impl VariantMetrics {
    pub fn snapshot(&self) -> VariantMetricsSnapshot {
        VariantMetricsSnapshot {
            admitted: self.admitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            drained: self.drained.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            generation: self.generation.load(Ordering::Relaxed),
            service: self.service.summary(),
        }
    }
}

/// Immutable per-variant counter view.
#[derive(Clone, Copy, Debug, Default)]
pub struct VariantMetricsSnapshot {
    pub admitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub drained: u64,
    pub queue_depth: u64,
    pub generation: u64,
    /// Service-time histogram summary, nanoseconds.
    pub service: HistogramSummary,
}

impl MetricsSnapshot {
    /// JSON rendering for the `tvq serve status` control API.  One
    /// schema: every derived field the snapshot computes (speedup,
    /// histogram quantiles, pool busy spread) appears here too.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("submitted", Json::num(self.submitted as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("failed", Json::num(self.failed as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("mean_batch_size", Json::num(self.mean_batch_size)),
            ("latency_mean_us", Json::num(self.latency_mean_us)),
            ("latency_p50_us", Json::num(self.latency_p50_us)),
            ("latency_p90_us", Json::num(self.latency_p90_us)),
            ("latency_p99_us", Json::num(self.latency_p99_us)),
            ("latency_max_us", Json::num(self.latency_max_us)),
            ("latency_count", Json::num(self.latency_count as f64)),
            ("queue_wait_us", self.queue_wait.to_json_scaled(1e3)),
            ("merge_builds", Json::num(self.merge_builds as f64)),
            ("merge_build_wall_ms", Json::num(self.merge_build_wall_ms)),
            ("merge_build_busy_ms", Json::num(self.merge_build_busy_ms)),
            ("merge_build_speedup", Json::num(self.merge_build_speedup())),
            ("merge_build_ms", self.merge_build_hist.to_json_scaled(1e6)),
            ("delta_patches", Json::num(self.delta_patches as f64)),
            ("delta_patch_wall_ms", Json::num(self.delta_patch_wall_ms)),
            ("delta_patch_ms", self.delta_patch_hist.to_json_scaled(1e6)),
            (
                "pool",
                Json::obj(vec![
                    ("workers", Json::num(self.pool_workers as f64)),
                    ("busy_min_ms", Json::num(self.pool_busy_min_ms)),
                    ("busy_max_ms", Json::num(self.pool_busy_max_ms)),
                    ("busy_mean_ms", Json::num(self.pool_busy_mean_ms)),
                ]),
            ),
        ])
    }

    /// Prometheus text exposition for the `{"cmd": "metrics"}` API.
    pub fn prometheus_into(&self, out: &mut String) {
        use std::fmt::Write;
        let mut counter = |name: &str, help: &str, v: u64| {
            let _ = writeln!(out, "# HELP tvq_{name} {help}");
            let _ = writeln!(out, "# TYPE tvq_{name} counter");
            let _ = writeln!(out, "tvq_{name} {v}");
        };
        counter("requests_submitted_total", "Requests accepted by the server.", self.submitted);
        counter("requests_completed_total", "Requests answered successfully.", self.completed);
        counter("requests_rejected_total", "Requests rejected at admission.", self.rejected);
        counter("requests_failed_total", "Requests failed in execution.", self.failed);
        counter("batches_total", "Batches executed.", self.batches);
        counter("merge_builds_total", "Merge builds completed.", self.merge_builds);
        counter(
            "delta_patches_total",
            "One-task delta patches served instead of full re-merges.",
            self.delta_patches,
        );
        let _ = writeln!(out, "# TYPE tvq_mean_batch_size gauge");
        let _ = writeln!(out, "tvq_mean_batch_size {}", self.mean_batch_size);
        let _ = writeln!(out, "# TYPE tvq_merge_build_speedup gauge");
        let _ = writeln!(out, "tvq_merge_build_speedup {}", self.merge_build_speedup());
        prometheus_summary_us(
            out,
            "request_latency",
            "End-to-end request latency.",
            &[
                (0.5, self.latency_p50_us),
                (0.9, self.latency_p90_us),
                (0.99, self.latency_p99_us),
            ],
            self.latency_count,
            self.latency_mean_us * self.latency_count as f64,
        );
        prometheus_summary_ns(out, "queue_wait", "Submit-to-executor queue wait.", &self.queue_wait);
        prometheus_summary_ns(out, "merge_build", "Per-build merge wall time.", &self.merge_build_hist);
        prometheus_summary_ns(out, "delta_patch", "Per-patch incremental merge wall time.", &self.delta_patch_hist);
        let _ = writeln!(out, "# TYPE tvq_pool_workers gauge");
        let _ = writeln!(out, "tvq_pool_workers {}", self.pool_workers);
        for (k, v) in [
            ("min", self.pool_busy_min_ms),
            ("max", self.pool_busy_max_ms),
            ("mean", self.pool_busy_mean_ms),
        ] {
            let _ = writeln!(out, "tvq_pool_worker_busy_seconds{{stat=\"{k}\"}} {}", v / 1e3);
        }
    }
}

/// Prometheus summary block from a nanosecond [`HistogramSummary`],
/// reported in seconds.
pub fn prometheus_summary_ns(out: &mut String, name: &str, help: &str, h: &HistogramSummary) {
    prometheus_summary_us(
        out,
        name,
        help,
        &[
            (0.5, h.p50 as f64 / 1e3),
            (0.9, h.p90 as f64 / 1e3),
            (0.99, h.p99 as f64 / 1e3),
        ],
        h.count,
        h.sum as f64 / 1e3,
    );
}

fn prometheus_summary_us(
    out: &mut String,
    name: &str,
    help: &str,
    quantiles_us: &[(f64, f64)],
    count: u64,
    sum_us: f64,
) {
    use std::fmt::Write;
    let _ = writeln!(out, "# HELP tvq_{name}_seconds {help}");
    let _ = writeln!(out, "# TYPE tvq_{name}_seconds summary");
    for (q, us) in quantiles_us {
        let _ = writeln!(out, "tvq_{name}_seconds{{quantile=\"{q}\"}} {}", us / 1e6);
    }
    let _ = writeln!(out, "tvq_{name}_seconds_sum {}", sum_us / 1e6);
    let _ = writeln!(out, "tvq_{name}_seconds_count {count}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_counters() {
        let m = Metrics::new();
        m.submitted.fetch_add(3, Ordering::Relaxed);
        m.completed.fetch_add(2, Ordering::Relaxed);
        m.record_batch(2);
        m.record_batch(4);
        m.record_latency(Duration::from_micros(100));
        m.record_latency(Duration::from_micros(300));
        let s = m.snapshot();
        assert_eq!(s.submitted, 3);
        assert_eq!(s.completed, 2);
        assert_eq!(s.batches, 2);
        assert_eq!(s.latency_count, 2);
        assert!((s.mean_batch_size - 3.0).abs() < 1e-9);
        // Histogram quantiles: within the 12.5% relative bucket bound.
        assert!(s.latency_p50_us >= 100.0 && s.latency_p50_us <= 112.5);
        assert!(s.latency_p99_us >= 300.0 && s.latency_p99_us <= 337.5);
        assert!(s.latency_max_us >= 300.0);
        assert!(s.summary().contains("batches 2"));
    }

    #[test]
    fn queue_wait_histogram_records() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().queue_wait.count, 0);
        m.record_queue_wait(Duration::from_micros(50));
        m.record_queue_wait(Duration::from_micros(70));
        let s = m.snapshot();
        assert_eq!(s.queue_wait.count, 2);
        assert!(s.queue_wait.p50 >= 50_000);
        assert!(s.summary().contains("queue p50"), "{}", s.summary());
        m.reset_window();
        assert_eq!(m.snapshot().queue_wait.count, 0);
    }

    #[test]
    fn merge_build_timing_reports_speedup() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.merge_builds, 0);
        assert_eq!(s.merge_build_speedup(), 0.0);
        assert!(!s.summary().contains("merge builds"));
        // Two builds, 10 ms wall each, 30 ms busy each -> x3 speedup.
        m.record_merge_build(Duration::from_millis(10), Duration::from_millis(30));
        m.record_merge_build(Duration::from_millis(10), Duration::from_millis(30));
        let s = m.snapshot();
        assert_eq!(s.merge_builds, 2);
        assert_eq!(s.merge_build_hist.count, 2);
        assert!((s.merge_build_wall_ms - 20.0).abs() < 1e-9);
        assert!((s.merge_build_speedup() - 3.0).abs() < 1e-9);
        assert!(s.summary().contains("merge builds 2"), "{}", s.summary());
    }

    #[test]
    fn delta_patch_timing_records() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.delta_patches, 0);
        assert!(!s.summary().contains("delta patches"));
        m.record_delta_patch(Duration::from_millis(2));
        m.record_delta_patch(Duration::from_millis(3));
        let s = m.snapshot();
        assert_eq!(s.delta_patches, 2);
        assert_eq!(s.delta_patch_hist.count, 2);
        assert!((s.delta_patch_wall_ms - 5.0).abs() < 1e-9);
        assert!(s.summary().contains("delta patches 2"), "{}", s.summary());
        // One schema: JSON and Prometheus carry the same fields.
        let j = s.to_json();
        assert_eq!(j.req("delta_patches").unwrap().as_usize().unwrap(), 2);
        assert!(j.req("delta_patch_ms").unwrap().req("p99").is_ok());
        let mut text = String::new();
        s.prometheus_into(&mut text);
        assert!(text.contains("tvq_delta_patches_total 2"));
        assert!(text.contains("# TYPE tvq_delta_patch_seconds summary"));
    }

    #[test]
    fn variant_metrics_snapshot_and_json() {
        let v = VariantMetrics::default();
        v.admitted.fetch_add(5, Ordering::Relaxed);
        v.completed.fetch_add(4, Ordering::Relaxed);
        v.rejected.fetch_add(2, Ordering::Relaxed);
        v.drained.fetch_add(1, Ordering::Relaxed);
        v.queue_depth.fetch_add(1, Ordering::Relaxed);
        v.generation.store(3, Ordering::Relaxed);
        v.service.record_ns(Duration::from_micros(40));
        let s = v.snapshot();
        assert_eq!(
            (s.admitted, s.rejected, s.completed, s.drained, s.queue_depth, s.generation),
            (5, 2, 4, 1, 1, 3)
        );
        assert_eq!(s.service.count, 1);

        let m = Metrics::new();
        m.submitted.fetch_add(7, Ordering::Relaxed);
        let j = m.snapshot().to_json();
        assert_eq!(j.req("submitted").unwrap().as_usize().unwrap(), 7);
        // The derived fields ship in the same schema.
        assert!(j.req("merge_build_speedup").is_ok());
        assert!(j.req("queue_wait_us").unwrap().req("p99").is_ok());
        assert!(j.req("pool").unwrap().req("workers").is_ok());
        // Compact output reparses (the TCP status path round-trips it).
        let re = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(re.req("rejected").unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    fn concurrent_latency_recording_is_exact() {
        // Pins the reservoir removal: the old Mutex<Vec> + cursor
        // design lost samples under concurrency (recorders clobbered
        // each other's slots via the shared `completed` index); the
        // histogram must account for every single record.
        let m = Metrics::new();
        let threads: u64 = 8;
        let per: u64 = 4_000;
        std::thread::scope(|s| {
            for _ in 0..threads {
                let m = &m;
                s.spawn(move || {
                    for i in 0..per {
                        m.completed.fetch_add(1, Ordering::Relaxed);
                        m.record_latency(Duration::from_micros(10 + (i % 7)));
                        m.record_queue_wait(Duration::from_nanos(100));
                    }
                });
            }
            // Snapshots taken mid-flight must never deadlock or panic.
            let m = &m;
            s.spawn(move || {
                for _ in 0..100 {
                    let _ = m.snapshot();
                }
            });
        });
        let s = m.snapshot();
        assert_eq!(s.latency_count, threads * per);
        assert_eq!(s.queue_wait.count, threads * per);
        assert_eq!(s.completed, threads * per);
    }

    #[test]
    fn prometheus_exposition_renders() {
        let m = Metrics::new();
        m.submitted.fetch_add(4, Ordering::Relaxed);
        m.record_latency(Duration::from_micros(120));
        let mut text = String::new();
        m.snapshot().prometheus_into(&mut text);
        assert!(text.contains("tvq_requests_submitted_total 4"));
        assert!(text.contains("# TYPE tvq_request_latency_seconds summary"));
        assert!(text.contains("tvq_request_latency_seconds{quantile=\"0.99\"}"));
        assert!(text.contains("tvq_request_latency_seconds_count 1"));
    }
}
