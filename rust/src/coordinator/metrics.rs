//! Serving metrics: lock-free counters plus a bounded latency reservoir.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::json::Json;
use crate::util::stats;

/// Shared metrics registry (one per [`Server`](super::Server)).
#[derive(Debug, Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub failed: AtomicU64,
    pub batches: AtomicU64,
    pub batch_items: AtomicU64,
    /// Merge builds completed through the model cache.
    pub merge_builds: AtomicU64,
    /// Total wall-clock time of those builds, microseconds.
    merge_build_wall_us: AtomicU64,
    /// Total worker-busy ("cpu") time of those builds, microseconds —
    /// the pool-side decode/quantize time summed across threads, so
    /// `busy / wall` is the realized parallel speedup.
    merge_build_busy_us: AtomicU64,
    /// End-to-end latencies (submit -> response), bounded reservoir.
    latencies_us: Mutex<Vec<f64>>,
}

/// Cap on retained latency samples (reservoir keeps the newest).
const LATENCY_CAP: usize = 65_536;

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_latency(&self, d: Duration) {
        let mut v = self.latencies_us.lock().unwrap();
        if v.len() >= LATENCY_CAP {
            // Overwrite cyclically: cheap, keeps recent behaviour visible.
            let i = self.completed.load(Ordering::Relaxed) as usize % LATENCY_CAP;
            v[i] = d.as_secs_f64() * 1e6;
        } else {
            v.push(d.as_secs_f64() * 1e6);
        }
    }

    pub fn record_batch(&self, items: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_items.fetch_add(items as u64, Ordering::Relaxed);
    }

    /// Record one merge build: `wall` is its elapsed time, `busy` the
    /// worker-pool busy time it consumed across threads (approximate
    /// when concurrent builds share the pool).  The snapshot reports
    /// `busy / wall` as the realized parallel speedup.
    pub fn record_merge_build(&self, wall: Duration, busy: Duration) {
        self.merge_builds.fetch_add(1, Ordering::Relaxed);
        self.merge_build_wall_us
            .fetch_add(wall.as_micros() as u64, Ordering::Relaxed);
        self.merge_build_busy_us
            .fetch_add(busy.as_micros() as u64, Ordering::Relaxed);
    }

    /// Clear latency samples and batch counters (post-warmup reset so
    /// percentiles reflect steady state); monotone counters are kept.
    pub fn reset_window(&self) {
        self.latencies_us.lock().unwrap().clear();
        self.batches.store(0, Ordering::Relaxed);
        self.batch_items.store(0, Ordering::Relaxed);
    }

    /// Consistent point-in-time view.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let lat = self.latencies_us.lock().unwrap();
        let (p50, p99, mean) = if lat.is_empty() {
            (0.0, 0.0, 0.0)
        } else {
            (
                stats::percentile(&lat, 50.0),
                stats::percentile(&lat, 99.0),
                stats::mean(&lat),
            )
        };
        let batches = self.batches.load(Ordering::Relaxed);
        let items = self.batch_items.load(Ordering::Relaxed);
        let wall_us = self.merge_build_wall_us.load(Ordering::Relaxed);
        let busy_us = self.merge_build_busy_us.load(Ordering::Relaxed);
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            batches,
            mean_batch_size: if batches > 0 {
                items as f64 / batches as f64
            } else {
                0.0
            },
            latency_mean_us: mean,
            latency_p50_us: p50,
            latency_p99_us: p99,
            merge_builds: self.merge_builds.load(Ordering::Relaxed),
            merge_build_wall_ms: wall_us as f64 / 1e3,
            merge_build_busy_ms: busy_us as f64 / 1e3,
        }
    }
}

/// Immutable metrics view for reporting.
#[derive(Clone, Copy, Debug, Default)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub failed: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub latency_mean_us: f64,
    pub latency_p50_us: f64,
    pub latency_p99_us: f64,
    pub merge_builds: u64,
    /// Total wall-clock of merge builds, ms.
    pub merge_build_wall_ms: f64,
    /// Total worker-busy ("cpu") time of merge builds, ms.
    pub merge_build_busy_ms: f64,
}

impl MetricsSnapshot {
    /// Realized parallel speedup of merge builds: pool busy time over
    /// wall time (~N = perfect scaling on N threads; 0.0 until a build
    /// has been recorded).  Busy time counts only work executed through
    /// the pool — build phases on the caller's thread (merge combine,
    /// checkpoint assembly) add wall but not busy, so a fully sequential
    /// build reports somewhat *below* 1.0 rather than exactly 1.0.
    pub fn merge_build_speedup(&self) -> f64 {
        if self.merge_build_wall_ms > 0.0 {
            self.merge_build_busy_ms / self.merge_build_wall_ms
        } else {
            0.0
        }
    }

    /// One-line summary for logs/benches.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "submitted {} completed {} rejected {} failed {} | batches {} (avg {:.1}) | latency p50 {:.0}us p99 {:.0}us",
            self.submitted,
            self.completed,
            self.rejected,
            self.failed,
            self.batches,
            self.mean_batch_size,
            self.latency_p50_us,
            self.latency_p99_us
        );
        if self.merge_builds > 0 {
            s.push_str(&format!(
                " | merge builds {} ({:.0} ms wall, x{:.2} parallel)",
                self.merge_builds,
                self.merge_build_wall_ms,
                self.merge_build_speedup()
            ));
        }
        s
    }
}

/// Per-variant serving counters for the control plane (one per
/// [`Variant`](super::control::Variant)): admission outcomes, drain
/// flushes, queue depth, and the registry generation gauge.  All relaxed
/// atomics — the admission queue's send/recv pairs provide the ordering
/// that keeps `queue_depth` consistent.
#[derive(Debug, Default)]
pub struct VariantMetrics {
    /// Jobs accepted into the bounded admission queue.
    pub admitted: AtomicU64,
    /// Typed rejections (queue full, variant not `Ready`).
    pub rejected: AtomicU64,
    /// Jobs the worker ran to completion.
    pub completed: AtomicU64,
    /// Queued jobs flushed with `DrainDeadlineExpired`.
    pub drained: AtomicU64,
    /// Jobs admitted but not yet picked up by the worker.
    pub queue_depth: AtomicU64,
    /// Current registry generation (gauge, updated on publish/reload).
    pub generation: AtomicU64,
}

impl VariantMetrics {
    pub fn snapshot(&self) -> VariantMetricsSnapshot {
        VariantMetricsSnapshot {
            admitted: self.admitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            drained: self.drained.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            generation: self.generation.load(Ordering::Relaxed),
        }
    }
}

/// Immutable per-variant counter view.
#[derive(Clone, Copy, Debug, Default)]
pub struct VariantMetricsSnapshot {
    pub admitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub drained: u64,
    pub queue_depth: u64,
    pub generation: u64,
}

impl MetricsSnapshot {
    /// JSON rendering for the `tvq serve status` control API.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("submitted", Json::num(self.submitted as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("failed", Json::num(self.failed as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("mean_batch_size", Json::num(self.mean_batch_size)),
            ("latency_mean_us", Json::num(self.latency_mean_us)),
            ("latency_p50_us", Json::num(self.latency_p50_us)),
            ("latency_p99_us", Json::num(self.latency_p99_us)),
            ("merge_builds", Json::num(self.merge_builds as f64)),
            ("merge_build_wall_ms", Json::num(self.merge_build_wall_ms)),
            ("merge_build_busy_ms", Json::num(self.merge_build_busy_ms)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_counters() {
        let m = Metrics::new();
        m.submitted.fetch_add(3, Ordering::Relaxed);
        m.completed.fetch_add(2, Ordering::Relaxed);
        m.record_batch(2);
        m.record_batch(4);
        m.record_latency(Duration::from_micros(100));
        m.record_latency(Duration::from_micros(300));
        let s = m.snapshot();
        assert_eq!(s.submitted, 3);
        assert_eq!(s.completed, 2);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_size - 3.0).abs() < 1e-9);
        assert!(s.latency_p50_us >= 100.0 && s.latency_p99_us <= 301.0);
        assert!(s.summary().contains("batches 2"));
    }

    #[test]
    fn merge_build_timing_reports_speedup() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.merge_builds, 0);
        assert_eq!(s.merge_build_speedup(), 0.0);
        assert!(!s.summary().contains("merge builds"));
        // Two builds, 10 ms wall each, 30 ms busy each -> x3 speedup.
        m.record_merge_build(Duration::from_millis(10), Duration::from_millis(30));
        m.record_merge_build(Duration::from_millis(10), Duration::from_millis(30));
        let s = m.snapshot();
        assert_eq!(s.merge_builds, 2);
        assert!((s.merge_build_wall_ms - 20.0).abs() < 1e-9);
        assert!((s.merge_build_speedup() - 3.0).abs() < 1e-9);
        assert!(s.summary().contains("merge builds 2"), "{}", s.summary());
    }

    #[test]
    fn variant_metrics_snapshot_and_json() {
        let v = VariantMetrics::default();
        v.admitted.fetch_add(5, Ordering::Relaxed);
        v.completed.fetch_add(4, Ordering::Relaxed);
        v.rejected.fetch_add(2, Ordering::Relaxed);
        v.drained.fetch_add(1, Ordering::Relaxed);
        v.queue_depth.fetch_add(1, Ordering::Relaxed);
        v.generation.store(3, Ordering::Relaxed);
        let s = v.snapshot();
        assert_eq!(
            (s.admitted, s.rejected, s.completed, s.drained, s.queue_depth, s.generation),
            (5, 2, 4, 1, 1, 3)
        );

        let m = Metrics::new();
        m.submitted.fetch_add(7, Ordering::Relaxed);
        let j = m.snapshot().to_json();
        assert_eq!(j.req("submitted").unwrap().as_usize().unwrap(), 7);
        // Compact output reparses (the TCP status path round-trips it).
        let re = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(re.req("rejected").unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    fn latency_reservoir_is_bounded() {
        let m = Metrics::new();
        for _ in 0..(LATENCY_CAP + 100) {
            m.completed.fetch_add(1, Ordering::Relaxed);
            m.record_latency(Duration::from_micros(10));
        }
        assert!(m.latencies_us.lock().unwrap().len() <= LATENCY_CAP);
    }
}
