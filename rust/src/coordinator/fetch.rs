//! Section-fetch executor: the server half of tier-1 registry serving.
//!
//! A fetch server node owns the shard files of one sharded zoo and
//! answers `{"cmd": "fetch_section"}` requests from remote
//! [`ShardedRegistry`](crate::registry::ShardedRegistry) clients (see
//! [`TcpFront::bind_sections`](super::tcp::TcpFront::bind_sections)).
//! Chunk reads are cheap but jittery (page-cache hit vs. cold pread), so
//! the executor follows the bounded-mailbox pool idiom the in-process
//! [`Server`](super::server::Server) uses for inference:
//!
//! * `workers` threads, each owning a **bounded** mpsc mailbox
//!   ([`MAILBOX_DEPTH`] jobs deep) and a shared handle set over the
//!   shard files;
//! * connection handlers dispatch round-robin across mailboxes; a full
//!   mailbox makes `send` **block the dispatching connection**, which is
//!   the backpressure story — slow disks surface as slow replies, never
//!   as unbounded queue growth;
//! * the deep queue (rather than depth-1 rendezvous) keeps workers fed
//!   across the reply latency of their previous job.
//!
//! Replies carry the raw chunk bytes plus the server's CRC of what it
//! read.  The server deliberately does **not** verify chunks against a
//! manifest: the client verifies length, CRC32 *and* content hash
//! against its own manifest ([`ShardedRegistry`] does this identically
//! for every tier), so a corrupt or stale shard on the server fails
//! closed at the client with the same error it would raise locally.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::obs;
use crate::registry::{IoMode, LocalShardStore, Manifest};
use crate::util::json::Json;

/// Jobs a worker mailbox holds before `send` blocks the dispatcher.
pub const MAILBOX_DEPTH: usize = 128;

/// What a section server hands the TCP front: resolve one chunk range to
/// its raw bytes, and describe itself for `{"cmd": "status"}`.
pub trait SectionProvider: Send + Sync {
    /// The raw bytes of `[offset, offset+length)` in shard `shard`.
    /// Range-validated against the shard table; **not** CRC-verified
    /// (the client verifies against its manifest).
    fn fetch_section(&self, shard: u32, offset: u64, length: u64) -> Result<Vec<u8>>;

    /// Status snapshot for the front-end's `status` command.
    fn status_json(&self) -> Json;
}

/// One queued fetch: the range plus a rendezvous channel for the reply.
struct Job {
    shard: u32,
    offset: u64,
    length: u64,
    reply: SyncSender<Result<Vec<u8>>>,
}

/// The bounded-mailbox fetch executor over one manifest's shard set.
pub struct SectionFetchPool {
    manifest_path: PathBuf,
    n_shards: usize,
    mailboxes: Vec<SyncSender<Job>>,
    next: AtomicUsize,
    served: Arc<AtomicU64>,
    errors: Arc<AtomicU64>,
    workers: Vec<JoinHandle<()>>,
}

impl SectionFetchPool {
    /// Open `manifest_path` (a `MANIFEST.qtvm`), validate its header, and
    /// start `workers` fetch threads over its shard files.  Shards are
    /// opened lazily on first touch; a missing shard errors per-request,
    /// not at startup (a serving node may hold a manifest whose cold
    /// shards are still syncing).
    pub fn open(manifest_path: &Path, workers: usize) -> Result<SectionFetchPool> {
        let manifest = Manifest::read(manifest_path)
            .with_context(|| format!("opening manifest {}", manifest_path.display()))?;
        let dir = manifest_path.parent().unwrap_or_else(|| Path::new("."));
        let store = Arc::new(LocalShardStore::open(dir, manifest.shards(), IoMode::Mmap));
        let workers = workers.max(1);
        let served = Arc::new(AtomicU64::new(0));
        let errors = Arc::new(AtomicU64::new(0));
        let mut mailboxes = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = sync_channel::<Job>(MAILBOX_DEPTH);
            mailboxes.push(tx);
            let st = store.clone();
            let sv = served.clone();
            let er = errors.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("tvq-fetch-{w}"))
                    .spawn(move || fetch_worker(rx, st, sv, er))?,
            );
        }
        Ok(SectionFetchPool {
            manifest_path: manifest_path.to_path_buf(),
            n_shards: manifest.shards().len(),
            mailboxes,
            next: AtomicUsize::new(0),
            served,
            errors,
            workers: handles,
        })
    }

    /// Worker count.
    pub fn workers(&self) -> usize {
        self.mailboxes.len()
    }

    /// `(served, errored)` request totals.
    pub fn stats(&self) -> (u64, u64) {
        (self.served.load(Ordering::Relaxed), self.errors.load(Ordering::Relaxed))
    }
}

/// Worker body: drain the mailbox until every sender is gone.  Reply
/// sends ignore a vanished requester (connection dropped mid-fetch).
fn fetch_worker(
    rx: Receiver<Job>,
    store: Arc<LocalShardStore>,
    served: Arc<AtomicU64>,
    errors: Arc<AtomicU64>,
) {
    while let Ok(job) = rx.recv() {
        let _span = obs::span(obs::Category::Registry, "serve_section")
            .with_arg("bytes", job.length);
        let result = store.read_chunk(job.shard, job.offset, job.length);
        match &result {
            Ok(_) => served.fetch_add(1, Ordering::Relaxed),
            Err(_) => errors.fetch_add(1, Ordering::Relaxed),
        };
        let _ = job.reply.send(result);
    }
}

impl SectionProvider for SectionFetchPool {
    fn fetch_section(&self, shard: u32, offset: u64, length: u64) -> Result<Vec<u8>> {
        let (reply_tx, reply_rx) = sync_channel(1);
        let job = Job { shard, offset, length, reply: reply_tx };
        // Round-robin dispatch; a full mailbox blocks *this* caller
        // (per-connection backpressure) while other connections keep
        // dispatching to their own workers.
        let w = self.next.fetch_add(1, Ordering::Relaxed) % self.mailboxes.len();
        if self.mailboxes[w].send(job).is_err() {
            anyhow::bail!("section fetch pool is shut down");
        }
        reply_rx.recv().context("fetch worker dropped the reply")?
    }

    fn status_json(&self) -> Json {
        let (served, errors) = self.stats();
        Json::obj(vec![
            ("role", Json::str("section-server")),
            ("manifest", Json::str(&self.manifest_path.display().to_string())),
            ("shards", Json::num(self.n_shards as f64)),
            ("workers", Json::num(self.workers() as f64)),
            ("served", Json::num(served as f64)),
            ("errors", Json::num(errors as f64)),
        ])
    }
}

impl Drop for SectionFetchPool {
    fn drop(&mut self) {
        // Closing every mailbox ends each worker's recv loop.
        self.mailboxes.clear();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::registry::{fnv64, shard_registry, ShardOptions, MANIFEST_FILE_NAME};
    use crate::util::crc32;

    fn shard_fixture(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("tvq-fetchpool-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let (pre, fts) = crate::exp::planner::synthetic_planner_zoo(3, 11);
        let zoo = dir.join("zoo.qtvc");
        let plan = crate::planner::plan_pack(
            &pre,
            &fts,
            u64::MAX,
            &crate::planner::PlannerConfig::default(),
        )
        .unwrap();
        crate::planner::write_planned_registry(&pre, &fts, &plan, &zoo).unwrap();
        let src = crate::registry::Registry::open(&zoo).unwrap();
        shard_registry(&src, &dir, &ShardOptions { n_shards: 2, ..Default::default() }).unwrap();
        dir
    }

    #[test]
    fn pool_serves_chunks_and_counts() {
        let dir = shard_fixture("serve");
        let manifest_path = dir.join(MANIFEST_FILE_NAME);
        let manifest = Manifest::read(&manifest_path).unwrap();
        let rows = manifest.read_page(&manifest_path, 0).unwrap();
        let pool = SectionFetchPool::open(&manifest_path, 2).unwrap();
        for row in rows.iter().take(4) {
            let c = &row.chunk;
            let bytes = pool.fetch_section(c.shard, c.offset, c.length).unwrap();
            assert_eq!(bytes.len() as u64, c.length);
            assert_eq!(crc32(&bytes), c.crc, "chunk {:?}", row.name);
            assert_eq!(fnv64(&bytes), c.hash, "chunk {:?}", row.name);
        }
        let (served, errors) = pool.stats();
        assert_eq!(served, rows.len().min(4) as u64);
        assert_eq!(errors, 0);
        let status = pool.status_json();
        assert_eq!(status.req("shards").unwrap().as_usize().unwrap(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn out_of_range_and_bad_shard_error_without_killing_workers() {
        let dir = shard_fixture("range");
        let manifest_path = dir.join(MANIFEST_FILE_NAME);
        let pool = SectionFetchPool::open(&manifest_path, 1).unwrap();
        let err = pool.fetch_section(99, 8, 4).unwrap_err();
        assert!(err.to_string().contains("shard 99"), "{err:#}");
        let err = pool.fetch_section(0, 0, 4).unwrap_err();
        assert!(err.to_string().contains("outside shard"), "{err:#}");
        // The worker survives errors: a valid fetch still succeeds.
        let manifest = Manifest::read(&manifest_path).unwrap();
        let c = manifest.read_page(&manifest_path, 0).unwrap()[0].chunk;
        assert!(pool.fetch_section(c.shard, c.offset, c.length).is_ok());
        assert_eq!(pool.stats().1, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
