//! PJRT runtime: load and execute the AOT artifacts from Rust.
//!
//! `make artifacts` leaves `artifacts/<name>.hlo.txt` (HLO text) and
//! `<name>.json` (signature manifest) pairs; this module compiles them on
//! the PJRT CPU client once ([`Runtime`] caches executables) and exposes
//! typed entrypoints ([`Artifact::execute`], plus the model-level helpers
//! [`forward_logits`], [`train_step`], [`merged_forward`]).
//!
//! Python is *never* involved here — the HLO text is the entire contract.

mod manifest;

pub use manifest::{Dtype, IoSpec, Manifest};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::checkpoint::Checkpoint;
use crate::quant::GroupQuantized;
use crate::tensor::Tensor;

/// A runtime input value (host side).
#[derive(Clone, Debug)]
pub enum Value {
    F32(Vec<usize>, Vec<f32>),
    I32(Vec<usize>, Vec<i32>),
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(s, _) | Value::I32(s, _) => s,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            Value::F32(..) => Dtype::F32,
            Value::I32(..) => Dtype::I32,
        }
    }

    pub fn numel(&self) -> usize {
        match self {
            Value::F32(_, d) => d.len(),
            Value::I32(_, d) => d.len(),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Value::F32(_, d) => xla::Literal::vec1(d).reshape(&dims)?,
            Value::I32(_, d) => xla::Literal::vec1(d).reshape(&dims)?,
        };
        Ok(lit)
    }

    pub fn from_tensor(t: &Tensor) -> Value {
        Value::F32(t.shape().to_vec(), t.data().to_vec())
    }
}

/// A compiled artifact: manifest + PJRT executable.
pub struct Artifact {
    pub manifest: Manifest,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with validated inputs; returns one (shape, data) per output.
    pub fn execute(&self, inputs: &[Value]) -> Result<Vec<(Vec<usize>, Vec<f32>)>> {
        self.manifest.validate_inputs(inputs)?;
        let literals = inputs
            .iter()
            .map(|v| v.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        // Artifacts are lowered with return_tuple=True: always a tuple.
        let parts = tuple.to_tuple()?;
        if parts.len() != self.manifest.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.manifest.name,
                self.manifest.outputs.len(),
                parts.len()
            );
        }
        let mut outs = Vec::with_capacity(parts.len());
        for (part, spec) in parts.iter().zip(&self.manifest.outputs) {
            let data: Vec<f32> = part.to_vec()?;
            outs.push((spec.shape.clone(), data));
        }
        Ok(outs)
    }

    /// Batch size baked into this artifact (from meta), if any.
    pub fn batch(&self) -> Option<usize> {
        self.manifest.meta_usize("batch")
    }
}

/// Artifact loader + compile cache bound to one PJRT client.
///
/// NOT `Send`: each coordinator executor thread builds its own `Runtime`.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Artifact>>>,
}

impl Runtime {
    /// CPU-client runtime over the default artifacts directory.
    pub fn new() -> Result<Self> {
        Self::with_dir(crate::util::artifacts_dir())
    }

    pub fn with_dir(dir: PathBuf) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, dir, cache: RefCell::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (and cache) a compiled artifact by name.
    pub fn load(&self, name: &str) -> Result<Rc<Artifact>> {
        if let Some(a) = self.cache.borrow().get(name) {
            return Ok(a.clone());
        }
        let json_path = self.dir.join(format!("{name}.json"));
        let hlo_path = self.dir.join(format!("{name}.hlo.txt"));
        let manifest = Manifest::load(&json_path)
            .with_context(|| format!("loading manifest {}", json_path.display()))?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let art = Rc::new(Artifact { manifest, exe });
        self.cache.borrow_mut().insert(name.to_string(), art.clone());
        Ok(art)
    }

    /// Names of all artifacts available on disk (from index.json).
    pub fn available(&self) -> Result<Vec<String>> {
        let text = std::fs::read_to_string(self.dir.join("index.json"))?;
        let idx = crate::util::json::Json::parse(&text)?;
        Ok(idx.as_obj()?.keys().cloned().collect())
    }
}

// ---------------------------------------------------------------------------
// Model-level helpers shared by train/eval/coordinator
// ---------------------------------------------------------------------------

/// Pack a checkpoint into artifact inputs following the manifest's param
/// layout (order + shapes are validated).
pub fn pack_params(art: &Artifact, ck: &Checkpoint) -> Result<Vec<Value>> {
    let params = art
        .manifest
        .params
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("{} takes no params", art.manifest.name))?;
    let mut out = Vec::with_capacity(params.len());
    for (name, shape) in params {
        let t = ck.get(name)?;
        if t.shape() != shape.as_slice() {
            bail!(
                "param {name:?} shape {:?} != manifest {:?}",
                t.shape(),
                shape
            );
        }
        out.push(Value::from_tensor(t));
    }
    Ok(out)
}

/// Forward pass: logits (or dense prediction map) for one batch.
pub fn forward_logits(
    art: &Artifact,
    ck: &Checkpoint,
    head: &Tensor,
    x: &Tensor,
) -> Result<Tensor> {
    let mut inputs = pack_params(art, ck)?;
    inputs.push(Value::from_tensor(head));
    inputs.push(Value::from_tensor(x));
    let mut outs = art.execute(&inputs)?;
    let (shape, data) = outs.remove(0);
    Tensor::new(shape, data)
}

/// One SGD step through the train artifact; returns (updated ckpt, loss).
pub fn train_step(
    art: &Artifact,
    ck: &Checkpoint,
    head: &Tensor,
    x: &Tensor,
    y: &Value,
    lr: f32,
) -> Result<(Checkpoint, f32)> {
    let mut inputs = pack_params(art, ck)?;
    inputs.push(Value::from_tensor(head));
    inputs.push(Value::from_tensor(x));
    inputs.push(y.clone());
    inputs.push(Value::F32(vec![1], vec![lr]));
    let outs = art.execute(&inputs)?;
    let params = art.manifest.params.as_ref().unwrap();
    if outs.len() != params.len() + 1 {
        bail!("train artifact output arity mismatch");
    }
    let mut new_ck = Checkpoint::new();
    for ((name, _), (shape, data)) in params.iter().zip(&outs) {
        new_ck.insert(name, Tensor::new(shape.clone(), data.clone())?);
    }
    let loss = outs.last().unwrap().1[0];
    Ok((new_ck, loss))
}

/// The fused Pallas path: serve a batch straight from quantized task
/// vectors via the `*_merged_forward_*` artifact.
pub fn merged_forward(
    art: &Artifact,
    pre_flat: &[f32],
    taus: &[&GroupQuantized],
    lams: &[f32],
    head: &Tensor,
    x: &Tensor,
) -> Result<Tensor> {
    let t = taus.len();
    anyhow::ensure!(t == lams.len(), "taus/lams mismatch");
    let n = pre_flat.len();
    let g = taus
        .first()
        .map(|q| q.n_groups())
        .ok_or_else(|| anyhow::anyhow!("need at least one task"))?;
    let mut q = Vec::with_capacity(t * n);
    let mut scales = Vec::with_capacity(t * g);
    let mut zps = Vec::with_capacity(t * g);
    for gq in taus {
        anyhow::ensure!(gq.len() == n, "flat length mismatch");
        q.extend(gq.codes_f32());
        scales.extend_from_slice(&gq.scales);
        zps.extend_from_slice(&gq.zps);
    }
    let inputs = vec![
        Value::F32(vec![n], pre_flat.to_vec()),
        Value::F32(vec![t, n], q),
        Value::F32(vec![t, g], scales),
        Value::F32(vec![t, g], zps),
        Value::F32(vec![t], lams.to_vec()),
        Value::from_tensor(head),
        Value::from_tensor(x),
    ];
    let mut outs = art.execute(&inputs)?;
    let (shape, data) = outs.remove(0);
    Tensor::new(shape, data)
}

/// Run a standalone `packed_merge_*` kernel artifact: merged parameters
/// straight from bit-packed int32 payloads (32/bits codes per word) —
/// the bandwidth-proportional variant of [`merged_forward`]'s q-as-f32
/// convention.  `taus` must all be quantized at the artifact's bit width.
pub fn packed_merge(
    art: &Artifact,
    pre_flat: &[f32],
    taus: &[&GroupQuantized],
    lams: &[f32],
) -> Result<Vec<f32>> {
    let t = taus.len();
    anyhow::ensure!(t == lams.len(), "taus/lams mismatch");
    let bits = art
        .manifest
        .meta_usize("bits")
        .ok_or_else(|| anyhow::anyhow!("artifact missing bits meta"))? as u8;
    let n = pre_flat.len();
    let g = taus
        .first()
        .map(|q| q.n_groups())
        .ok_or_else(|| anyhow::anyhow!("need at least one task"))?;
    let mut words = Vec::new();
    let mut scales = Vec::with_capacity(t * g);
    let mut zps = Vec::with_capacity(t * g);
    for gq in taus {
        anyhow::ensure!(gq.bits == bits, "task quantized at {} bits, artifact wants {bits}", gq.bits);
        anyhow::ensure!(gq.len() == n, "flat length mismatch");
        words.extend(gq.codes.to_i32_words()?);
        scales.extend_from_slice(&gq.scales);
        zps.extend_from_slice(&gq.zps);
    }
    let nw = words.len() / t;
    let inputs = vec![
        Value::F32(vec![n], pre_flat.to_vec()),
        Value::I32(vec![t, nw], words),
        Value::F32(vec![t, g], scales),
        Value::F32(vec![t, g], zps),
        Value::F32(vec![t], lams.to_vec()),
    ];
    let mut outs = art.execute(&inputs)?;
    Ok(outs.remove(0).1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_shapes_and_dtypes() {
        let v = Value::F32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(v.shape(), &[2, 3]);
        assert_eq!(v.dtype(), Dtype::F32);
        assert_eq!(v.numel(), 6);
        let w = Value::I32(vec![4], vec![1, 2, 3, 4]);
        assert_eq!(w.dtype(), Dtype::I32);
    }
}
