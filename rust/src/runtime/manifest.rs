//! Artifact manifest parsing — the JSON signature files emitted by
//! `python/compile/aot.py` alongside each HLO text module.

use std::path::Path;

use anyhow::{bail, Result};

use crate::util::json::Json;

/// Element dtype understood by the runtime (the artifacts use only these).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype {other:?}"),
        }
    }
}

/// One input or output slot.
#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

/// Parsed manifest for one artifact.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    /// Trunk parameter layout (name, shape) in flattening order, when the
    /// artifact takes a checkpoint.
    pub params: Option<Vec<(String, Vec<usize>)>>,
    pub meta: Json,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text)?;
        let name = v.req("name")?.as_str()?.to_string();
        let inputs = v
            .req("inputs")?
            .as_arr()?
            .iter()
            .map(|e| {
                Ok(IoSpec {
                    name: e.req("name")?.as_str()?.to_string(),
                    shape: e.req("shape")?.as_shape()?,
                    dtype: Dtype::parse(e.req("dtype")?.as_str()?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let outputs = v
            .req("outputs")?
            .as_arr()?
            .iter()
            .enumerate()
            .map(|(i, e)| {
                Ok(IoSpec {
                    name: format!("out{i}"),
                    shape: e.req("shape")?.as_shape()?,
                    dtype: Dtype::parse(e.req("dtype")?.as_str()?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let params = match v.req("params")? {
            Json::Null => None,
            arr => Some(
                arr.as_arr()?
                    .iter()
                    .map(|e| {
                        Ok((
                            e.req("name")?.as_str()?.to_string(),
                            e.req("shape")?.as_shape()?,
                        ))
                    })
                    .collect::<Result<Vec<_>>>()?,
            ),
        };
        let meta = v.req("meta")?.clone();
        Ok(Manifest { name, inputs, outputs, params, meta })
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// usize meta field accessor.
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|v| v.as_usize().ok())
    }

    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).and_then(|v| v.as_str().ok())
    }

    /// Validate runtime inputs against the declared signature.
    pub fn validate_inputs(&self, inputs: &[super::Value]) -> Result<()> {
        if inputs.len() != self.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.inputs.len(),
                inputs.len()
            );
        }
        for (v, spec) in inputs.iter().zip(&self.inputs) {
            if v.shape() != spec.shape.as_slice() {
                bail!(
                    "{}: input {:?} shape {:?} != expected {:?}",
                    self.name,
                    spec.name,
                    v.shape(),
                    spec.shape
                );
            }
            if v.dtype() != spec.dtype {
                bail!(
                    "{}: input {:?} dtype {:?} != expected {:?}",
                    self.name,
                    spec.name,
                    v.dtype(),
                    spec.dtype
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Value;

    const SAMPLE: &str = r#"{
      "name": "toy_forward_b2",
      "inputs": [
        {"name": "param:w", "shape": [3, 4], "dtype": "f32"},
        {"name": "x", "shape": [2, 3], "dtype": "f32"},
        {"name": "y", "shape": [2], "dtype": "i32"}
      ],
      "outputs": [{"shape": [2, 4], "dtype": "f32"}],
      "params": [{"name": "w", "shape": [3, 4]}],
      "meta": {"batch": 2, "preset": "toy"}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "toy_forward_b2");
        assert_eq!(m.inputs.len(), 3);
        assert_eq!(m.inputs[2].dtype, Dtype::I32);
        assert_eq!(m.outputs[0].shape, vec![2, 4]);
        assert_eq!(m.params.as_ref().unwrap()[0].0, "w");
        assert_eq!(m.meta_usize("batch"), Some(2));
        assert_eq!(m.meta_str("preset"), Some("toy"));
    }

    #[test]
    fn null_params_allowed() {
        let src = SAMPLE.replace(
            r#""params": [{"name": "w", "shape": [3, 4]}]"#,
            r#""params": null"#,
        );
        let m = Manifest::parse(&src).unwrap();
        assert!(m.params.is_none());
    }

    #[test]
    fn validate_inputs_catches_mismatches() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let good = vec![
            Value::F32(vec![3, 4], vec![0.0; 12]),
            Value::F32(vec![2, 3], vec![0.0; 6]),
            Value::I32(vec![2], vec![0, 1]),
        ];
        assert!(m.validate_inputs(&good).is_ok());
        // wrong arity
        assert!(m.validate_inputs(&good[..2]).is_err());
        // wrong shape
        let mut bad = good.clone();
        bad[0] = Value::F32(vec![4, 3], vec![0.0; 12]);
        assert!(m.validate_inputs(&bad).is_err());
        // wrong dtype
        let mut bad = good;
        bad[2] = Value::F32(vec![2], vec![0.0; 2]);
        assert!(m.validate_inputs(&bad).is_err());
    }

    #[test]
    fn rejects_unknown_dtype() {
        let src = SAMPLE.replace("\"i32\"", "\"f64\"");
        assert!(Manifest::parse(&src).is_err());
    }
}
