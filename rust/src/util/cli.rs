//! Declarative command-line argument parser (clap is unavailable offline).
//!
//! Supports subcommands, `--flag value` / `--flag=value` options with
//! defaults, boolean switches, and auto-generated `--help` text — the
//! subset the `tvq` binary needs.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// One option specification.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_switch: bool,
}

/// Parsed arguments for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow::anyhow!("missing required option --{name}"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        Ok(self.get_str(name)?.parse()?)
    }

    pub fn get_u64(&self, name: &str) -> Result<u64> {
        Ok(self.get_str(name)?.parse()?)
    }

    pub fn get_f32(&self, name: &str) -> Result<f32> {
        Ok(self.get_str(name)?.parse()?)
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.get(name).copied().unwrap_or(false)
    }
}

/// A command with options; `parse` consumes raw argv tokens.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    /// Extended description printed by `--help` between the one-line
    /// about and the option list (clap's `long_about`).
    pub long_about: Option<&'static str>,
    /// One-line description of the positional arguments (printed in
    /// usage above the options; positionals are collected untyped).
    pub positional_help: Option<&'static str>,
    opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, long_about: None, positional_help: None, opts: Vec::new() }
    }

    /// Attach the extended `--help` text (examples, semantics, caveats).
    pub fn long_about(mut self, text: &'static str) -> Self {
        self.long_about = Some(text);
        self
    }

    /// Describe the positional arguments (e.g. `"<registry.qtvc>"`).
    pub fn positional_help(mut self, text: &'static str) -> Self {
        self.positional_help = Some(text);
        self
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: Some(default), is_switch: false });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_switch: false });
        self
    }

    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_switch: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\n", self.name, self.about);
        if let Some(long) = self.long_about {
            s.push_str(long.trim_end());
            s.push_str("\n\n");
        }
        if let Some(pos) = self.positional_help {
            s.push_str(&format!("arguments:\n  {pos}\n\n"));
        }
        s.push_str("options:\n");
        for o in &self.opts {
            let kind = if o.is_switch { "" } else { " <value>" };
            let def = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_else(|| if o.is_switch { String::new() } else { " (required)".into() });
            s.push_str(&format!("  --{}{kind}\t{}{def}\n", o.name, o.help));
        }
        s
    }

    pub fn parse(&self, argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        for o in &self.opts {
            if let Some(d) = o.default {
                args.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                println!("{}", self.usage());
                std::process::exit(0);
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{name}\n{}", self.usage()))?;
                if spec.is_switch {
                    if inline.is_some() {
                        bail!("switch --{name} does not take a value");
                    }
                    args.switches.insert(name, true);
                } else {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow::anyhow!("--{name} requires a value"))?
                        }
                    };
                    args.values.insert(name, value);
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        for o in &self.opts {
            if !o.is_switch && o.default.is_none() && !args.values.contains_key(o.name) {
                bail!("missing required option --{}\n{}", o.name, self.usage());
            }
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let cmd = Command::new("t", "test").opt("preset", "vit_s", "model preset");
        let a = cmd.parse(&argv(&[])).unwrap();
        assert_eq!(a.get_str("preset").unwrap(), "vit_s");
        let a = cmd.parse(&argv(&["--preset", "vit_m"])).unwrap();
        assert_eq!(a.get_str("preset").unwrap(), "vit_m");
        let a = cmd.parse(&argv(&["--preset=vit_l"])).unwrap();
        assert_eq!(a.get_str("preset").unwrap(), "vit_l");
    }

    #[test]
    fn required_and_switch() {
        let cmd = Command::new("t", "test").req("out", "output").switch("verbose", "chatty");
        assert!(cmd.parse(&argv(&[])).is_err());
        let a = cmd.parse(&argv(&["--out", "x", "--verbose"])).unwrap();
        assert_eq!(a.get_str("out").unwrap(), "x");
        assert!(a.switch("verbose"));
        assert!(!a.switch("other"));
    }

    #[test]
    fn long_about_appears_in_usage() {
        let cmd = Command::new("t", "test").long_about("extended help\nwith examples");
        let u = cmd.usage();
        assert!(u.contains("extended help\nwith examples"));
        assert!(u.contains("options:"));
        // Without long_about, usage is unchanged in shape.
        assert!(!Command::new("t", "test").usage().contains("extended"));
    }

    #[test]
    fn positional_help_appears_in_usage() {
        let cmd = Command::new("t", "test").positional_help("<registry.qtvc>  packed registry");
        let u = cmd.usage();
        assert!(u.contains("arguments:"));
        assert!(u.contains("<registry.qtvc>"));
        assert!(!Command::new("t", "test").usage().contains("arguments:"));
    }

    #[test]
    fn unknown_option_rejected() {
        let cmd = Command::new("t", "test");
        assert!(cmd.parse(&argv(&["--nope", "1"])).is_err());
    }

    #[test]
    fn positional_and_numbers() {
        let cmd = Command::new("t", "test").opt("n", "8", "count");
        let a = cmd.parse(&argv(&["file.txt", "--n", "20"])).unwrap();
        assert_eq!(a.positional, vec!["file.txt"]);
        assert_eq!(a.get_usize("n").unwrap(), 20);
    }
}
