//! Bench regression gate: diff a fresh `BENCH_*.json` against a committed
//! baseline with a relative tolerance.
//!
//! Two classes of check, because wall-clock baselines do not travel
//! between machines but *ratios within one run* do:
//!
//! 1. **Within-run ordering invariants** — the bench document declares
//!    `require_not_slower: [[fast, slow], ...]` pairs; each asserts
//!    `mean_ns(fast) <= mean_ns(slow) * (1 + tolerance)` *inside the
//!    current run*.  These always apply, on any machine (this is how CI
//!    enforces "Mmap section reads are at least as fast as Pread").
//! 2. **Cross-run regressions** — per-case `mean_ns` must not exceed the
//!    baseline's by more than the tolerance.  Applied only when the
//!    baseline is marked `calibrated: true`: a freshly-seeded repo (or a
//!    new machine class) commits an *uncalibrated* baseline, the first
//!    real CI run reports the measured numbers, and the operator commits
//!    them back with `calibrated` flipped — after which drift fails the
//!    gate.  Getting faster never fails.
//!
//! Consumed by `tvq bench diff` (the `bench-diff` stage of `ci.sh`).

use anyhow::{bail, Result};

use super::json::Json;

/// Outcome of one diff: human-readable notes plus hard failures.
#[derive(Debug, Default)]
pub struct DiffReport {
    /// Informational lines (one per checked case / invariant).
    pub notes: Vec<String>,
    /// Tolerance violations; non-empty means the gate fails.
    pub failures: Vec<String>,
}

impl DiffReport {
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }
}

fn mean_ns(doc: &Json, case: &str) -> Result<f64> {
    doc.req("cases")?
        .req(case)
        .map_err(|_| anyhow::anyhow!("bench case {case:?} missing from report"))?
        .req("mean_ns")?
        .as_f64()
}

/// Diff `current` against `baseline` at `tolerance` (e.g. `0.20` =
/// ±20%).  `baseline` may be `None` (no committed file yet) — then only
/// the within-run invariants apply.
pub fn diff_reports(current: &Json, baseline: Option<&Json>, tolerance: f64) -> Result<DiffReport> {
    if !(0.0..10.0).contains(&tolerance) {
        bail!("tolerance {tolerance} outside the sane range [0, 10)");
    }
    let mut report = DiffReport::default();

    // 1. Within-run ordering invariants, declared by the bench itself.
    if let Some(invariants) = current.get("require_not_slower") {
        for pair in invariants.as_arr()? {
            let pair = pair.as_arr()?;
            if pair.len() != 2 {
                bail!("require_not_slower entries must be [fast, slow] pairs");
            }
            let (fast, slow) = (pair[0].as_str()?, pair[1].as_str()?);
            let (f, s) = (mean_ns(current, fast)?, mean_ns(current, slow)?);
            let line = format!(
                "invariant {fast} ({f:.0} ns) <= {slow} ({s:.0} ns) * {:.2}",
                1.0 + tolerance
            );
            if f <= s * (1.0 + tolerance) {
                report.notes.push(format!("ok: {line}"));
            } else {
                report.failures.push(format!("violated: {line}"));
            }
        }
    }

    // 2. Cross-run regression vs the committed baseline.
    let Some(base) = baseline else {
        report.notes.push("no baseline: within-run invariants only".into());
        return Ok(report);
    };
    let calibrated = matches!(base.get("calibrated"), Some(Json::Bool(true)));
    if !calibrated {
        report.notes.push(
            "baseline is uncalibrated: recording run only — commit the fresh \
             report (calibrated: true) to arm the regression gate"
                .into(),
        );
        return Ok(report);
    }
    for (case, entry) in base.req("cases")?.as_obj()? {
        let base_ns = entry.req("mean_ns")?.as_f64()?;
        let Ok(cur_ns) = mean_ns(current, case) else {
            report
                .failures
                .push(format!("case {case:?} in baseline but missing from current run"));
            continue;
        };
        let ratio = cur_ns / base_ns;
        if ratio > 1.0 + tolerance {
            report.failures.push(format!(
                "regression: {case} {cur_ns:.0} ns vs baseline {base_ns:.0} ns \
                 (x{ratio:.2} > x{:.2})",
                1.0 + tolerance
            ));
        } else {
            report.notes.push(format!("ok: {case} x{ratio:.2} of baseline"));
        }
    }
    for (case, _) in current.req("cases")?.as_obj()? {
        if base.req("cases")?.get(case).is_none() {
            report
                .notes
                .push(format!("new case {case:?} (not in baseline; not gated)"));
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(cases: &[(&str, f64)], calibrated: bool) -> Json {
        let cases = Json::Obj(
            cases
                .iter()
                .map(|(n, ns)| {
                    (n.to_string(), Json::obj(vec![("mean_ns", Json::num(*ns))]))
                })
                .collect(),
        );
        Json::obj(vec![
            ("bench", Json::str("t")),
            ("calibrated", Json::Bool(calibrated)),
            ("cases", cases),
        ])
    }

    #[test]
    fn within_tolerance_passes_and_regression_fails() {
        let base = doc(&[("a", 100.0), ("b", 200.0)], true);
        let good = doc(&[("a", 115.0), ("b", 150.0)], true);
        let r = diff_reports(&good, Some(&base), 0.20).unwrap();
        assert!(r.ok(), "failures: {:?}", r.failures);

        let bad = doc(&[("a", 130.0), ("b", 200.0)], true);
        let r = diff_reports(&bad, Some(&base), 0.20).unwrap();
        assert!(!r.ok());
        assert!(r.failures[0].contains("regression: a"), "{:?}", r.failures);
    }

    #[test]
    fn uncalibrated_baseline_records_without_gating() {
        let base = doc(&[("a", 1.0)], false);
        let cur = doc(&[("a", 1e9)], true);
        let r = diff_reports(&cur, Some(&base), 0.20).unwrap();
        assert!(r.ok());
        assert!(r.notes.iter().any(|n| n.contains("uncalibrated")));
        // And no baseline at all is also non-fatal.
        assert!(diff_reports(&cur, None, 0.20).unwrap().ok());
    }

    #[test]
    fn missing_case_is_a_failure() {
        let base = doc(&[("a", 100.0), ("gone", 50.0)], true);
        let cur = doc(&[("a", 100.0)], true);
        let r = diff_reports(&cur, Some(&base), 0.20).unwrap();
        assert!(!r.ok());
        assert!(r.failures[0].contains("missing from current run"));
    }

    #[test]
    fn ordering_invariants_apply_within_run() {
        let mut cur = doc(&[("mmap", 90.0), ("pread", 100.0)], true);
        if let Json::Obj(m) = &mut cur {
            m.insert(
                "require_not_slower".into(),
                Json::arr([Json::arr([Json::str("mmap"), Json::str("pread")])]),
            );
        }
        let r = diff_reports(&cur, None, 0.20).unwrap();
        assert!(r.ok(), "{:?}", r.failures);

        // mmap 3x slower than pread: the invariant fires even with no
        // baseline to compare against.
        let mut bad = doc(&[("mmap", 300.0), ("pread", 100.0)], true);
        if let Json::Obj(m) = &mut bad {
            m.insert(
                "require_not_slower".into(),
                Json::arr([Json::arr([Json::str("mmap"), Json::str("pread")])]),
            );
        }
        let r = diff_reports(&bad, None, 0.20).unwrap();
        assert!(!r.ok());
        assert!(r.failures[0].contains("violated"));
        // Bad tolerance is rejected.
        assert!(diff_reports(&bad, None, -1.0).is_err());
    }
}
