//! Property-based testing harness (proptest is unavailable offline).
//!
//! `check` runs a property over `cases` randomized inputs drawn from a
//! caller-supplied generator; on failure it reports the failing case index
//! and the seed that reproduces it.  Deterministic: the root seed is fixed
//! per call site, so CI failures replay locally.

use super::rng::Rng;

/// Configuration for a property check.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 64, seed: 0xC0FFEE }
    }
}

/// Run `prop` over `cfg.cases` inputs produced by `gen`.
///
/// Panics (test failure) with the reproducing seed if the property returns
/// an `Err`. The generator receives a forked RNG per case.
pub fn check<T, G, P>(cfg: Config, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    let mut root = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut rng = root.fork(case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (seed {:#x}): {msg}\ninput: {input:?}",
                cfg.seed
            );
        }
    }
}

/// Convenience: generate a random f32 vector with length in [1, max_len]
/// and values N(0, scale).
pub fn gen_vec(rng: &mut Rng, max_len: usize, scale: f32) -> Vec<f32> {
    let len = 1 + rng.below(max_len);
    let mut v = vec![0.0f32; len];
    rng.fill_normal(&mut v, scale);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(
            Config { cases: 10, seed: 1 },
            |rng| rng.below(100),
            |&x| {
                count += 1;
                if x < 100 {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(
            Config { cases: 10, seed: 2 },
            |rng| rng.below(10),
            |&x| if x < 5 { Ok(()) } else { Err(format!("x={x}")) },
        );
    }

    #[test]
    fn gen_vec_respects_bounds() {
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let v = gen_vec(&mut rng, 17, 1.0);
            assert!(!v.is_empty() && v.len() <= 17);
        }
    }
}
