//! Small statistics helpers shared by evaluation and the bench runner.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile by linear interpolation on the sorted copy, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Min / max over a slice of f32.
pub fn min_max(xs: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in xs {
        if x < lo {
            lo = x;
        }
        if x > hi {
            hi = x;
        }
    }
    (lo, hi)
}

/// L2 norm of a slice.
pub fn l2_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// L2 distance between two slices (must be equal length).
pub fn l2_dist(a: &[f32], b: &[f32]) -> f64 {
    sse(a, b).sqrt()
}

/// Sum of squared differences between two slices (must be equal length) —
/// the reconstruction-error metric shared by the sensitivity probe, the
/// granularity ablation, and the quantizer tests.
pub fn sse(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
}

/// Cosine similarity between two slices.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let dot: f64 = a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum();
    let na = l2_norm(a);
    let nb = l2_norm(b);
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na * nb)
    }
}

/// Histogram of values over [lo, hi] with `bins` equal-width buckets.
pub fn histogram(xs: &[f32], lo: f32, hi: f32, bins: usize) -> Vec<usize> {
    let mut counts = vec![0usize; bins];
    let width = (hi - lo) / bins as f32;
    if width <= 0.0 {
        return counts;
    }
    for &x in xs {
        if x < lo || x > hi {
            continue;
        }
        let b = (((x - lo) / width) as usize).min(bins - 1);
        counts[b] += 1;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - 1.118033988749895).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn minmax_norms() {
        let xs = [3.0f32, -4.0];
        assert_eq!(min_max(&xs), (-4.0, 3.0));
        assert!((l2_norm(&xs) - 5.0).abs() < 1e-9);
        assert!((l2_dist(&xs, &[0.0, 0.0]) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn cosine_basic() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-12);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_buckets() {
        let xs = [0.0f32, 0.1, 0.5, 0.9, 1.0];
        // 0.5 lands in the upper bucket ([0.5, 1.0]); 1.0 clamps into it.
        let h = histogram(&xs, 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 3]);
    }
}
