//! Criterion-style micro/macro benchmark runner (criterion itself is not
//! available offline).  Used by every `harness = false` bench target.
//!
//! Features: warmup phase, fixed-duration measurement, mean/std/p50/p99
//! reporting, throughput units, and a markdown table emitter so bench
//! output can be pasted straight into EXPERIMENTS.md.

use std::time::{Duration, Instant};

use super::json::Json;
use super::stats;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    /// Optional items-per-iteration for throughput reporting.
    pub items: Option<f64>,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    /// items/second, if `items` was provided.
    pub fn throughput(&self) -> Option<f64> {
        self.items.map(|it| it / (self.mean_ns / 1e9))
    }

    /// The machine-readable form written into `BENCH_*.json` files.
    pub fn to_json(&self) -> Json {
        let mut entries = vec![
            ("iters", Json::num(self.iters as f64)),
            ("mean_ns", Json::num(self.mean_ns)),
            ("p50_ns", Json::num(self.p50_ns)),
            ("p99_ns", Json::num(self.p99_ns)),
        ];
        if let Some(items) = self.items {
            entries.push(("items", Json::num(items)));
        }
        if let Some(tp) = self.throughput() {
            entries.push(("throughput", Json::num(tp)));
        }
        Json::obj(entries)
    }
}

/// Assemble the `BENCH_<name>.json` document: one object per case keyed
/// by case name, a `calibrated: true` marker (committed baselines start
/// uncalibrated until a real run replaces them — see
/// [`crate::util::benchcmp`]), and the bench's self-declared ordering
/// invariants (`require_not_slower`: pairs `[fast, slow]` asserting the
/// first case's mean must not exceed the second's by more than the diff
/// tolerance).
pub fn json_report(
    bench: &str,
    results: &[BenchResult],
    require_not_slower: &[(&str, &str)],
) -> Json {
    let cases = Json::Obj(
        results
            .iter()
            .map(|r| (r.name.clone(), r.to_json()))
            .collect(),
    );
    Json::obj(vec![
        ("bench", Json::str(bench)),
        ("calibrated", Json::Bool(true)),
        ("cases", cases),
        (
            "require_not_slower",
            Json::arr(
                require_not_slower
                    .iter()
                    .map(|(a, b)| Json::arr([Json::str(a), Json::str(b)])),
            ),
        ),
    ])
}

/// Benchmark runner configuration.
#[derive(Clone, Debug)]
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: u64,
    pub max_iters: u64,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_iters: 5,
            max_iters: 1_000_000,
        }
    }
}

impl Bench {
    /// Quick profile for expensive end-to-end cases.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(0),
            measure: Duration::from_millis(200),
            min_iters: 2,
            max_iters: 1000,
        }
    }

    /// Run `f` repeatedly and collect timing statistics.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            f();
        }
        // Measure.
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        let mut iters = 0u64;
        while (start.elapsed() < self.measure || iters < self.min_iters)
            && iters < self.max_iters
        {
            let t = Instant::now();
            f();
            samples_ns.push(t.elapsed().as_nanos() as f64);
            iters += 1;
        }
        BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: stats::mean(&samples_ns),
            std_ns: stats::std_dev(&samples_ns),
            p50_ns: stats::percentile(&samples_ns, 50.0),
            p99_ns: stats::percentile(&samples_ns, 99.0),
            items: None,
        }
    }

    /// Like [`run`], tagging each iteration as processing `items` items.
    pub fn run_throughput<F: FnMut()>(&self, name: &str, items: f64, f: F) -> BenchResult {
        let mut r = self.run(name, f);
        r.items = Some(items);
        r
    }
}

fn fmt_time(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Print a table of bench results to stdout.
pub fn report(title: &str, results: &[BenchResult]) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>8} {:>12} {:>12} {:>12} {:>14}",
        "case", "iters", "mean", "p50", "p99", "throughput"
    );
    for r in results {
        let tp = r
            .throughput()
            .map(|t| {
                if t > 1e9 {
                    format!("{:.2} G/s", t / 1e9)
                } else if t > 1e6 {
                    format!("{:.2} M/s", t / 1e6)
                } else if t > 1e3 {
                    format!("{:.2} K/s", t / 1e3)
                } else {
                    format!("{t:.1} /s")
                }
            })
            .unwrap_or_else(|| "-".to_string());
        println!(
            "{:<44} {:>8} {:>12} {:>12} {:>12} {:>14}",
            r.name,
            r.iters,
            fmt_time(r.mean_ns),
            fmt_time(r.p50_ns),
            fmt_time(r.p99_ns),
            tp
        );
    }
}

/// A minimal markdown table printer used by the paper-table benches.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self, title: &str) {
        println!("\n### {title}\n");
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let body = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join(" | ");
            format!("| {body} |")
        };
        println!("{}", fmt_row(&self.header));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("{}", fmt_row(&sep));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let b = Bench {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            min_iters: 3,
            max_iters: 100_000,
        };
        let mut acc = 0u64;
        let r = b.run("noop", || {
            acc = acc.wrapping_add(1);
        });
        assert!(r.iters >= 3);
        assert!(r.mean_ns >= 0.0);
        assert!(r.p99_ns >= r.p50_ns);
    }

    #[test]
    fn throughput_computed() {
        let b = Bench::quick();
        let r = b.run_throughput("items", 100.0, || {
            std::hint::black_box(42);
        });
        assert!(r.throughput().unwrap() > 0.0);
    }

    #[test]
    fn json_report_roundtrips() {
        let b = Bench::quick();
        let r1 = b.run_throughput("fast_case", 100.0, || {
            std::hint::black_box(42);
        });
        let r2 = b.run("slow_case", || {
            std::hint::black_box(43);
        });
        let doc = json_report("perf_test", &[r1, r2], &[("fast_case", "slow_case")]);
        let back = Json::parse(&doc.to_string_compact()).unwrap();
        assert_eq!(back.req("bench").unwrap().as_str().unwrap(), "perf_test");
        assert_eq!(back.req("calibrated").unwrap(), &Json::Bool(true));
        let cases = back.req("cases").unwrap();
        assert!(cases.req("fast_case").unwrap().req("throughput").unwrap().as_f64().unwrap() > 0.0);
        assert!(cases.req("slow_case").unwrap().get("throughput").is_none());
        let inv = back.req("require_not_slower").unwrap().as_arr().unwrap();
        assert_eq!(inv[0].as_arr().unwrap()[0].as_str().unwrap(), "fast_case");
    }

    #[test]
    fn table_rejects_bad_width() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(vec!["only-one".into()])
        }));
        assert!(res.is_err());
    }
}
