//! [`ExecCtx`] — the single execution-context parameter behind every
//! merge/decode/quantize entry point.
//!
//! PR 5 grew `*_with_pool` twins next to each parallelizable operation
//! (`fused_merge` / `fused_merge_with_pool`, `load_task_vector` /
//! `load_task_vector_with_pool`, ...).  Two entry points per operation
//! scales badly: every new knob (tracing, priorities, quotas) would
//! double the surface again.  `ExecCtx` collapses the pair: one public
//! entry point per operation takes `&ExecCtx`, and the context carries
//! the pool choice plus an optional trace label.  The old twins survive
//! only as thin `#[deprecated]` shims.
//!
//! The determinism contract is unchanged: every operation taking an
//! `ExecCtx` produces bit-identical floats at every pool width, so the
//! context selects *where the cycles run*, never *what comes out*.
//!
//! ```no_run
//! use tvq::util::exec::ExecCtx;
//! use tvq::util::pool::Pool;
//!
//! let ctx = ExecCtx::default();          // shared global pool
//! let seq = ExecCtx::sequential();       // single-threaded reference path
//! let pool = Pool::new(4);
//! let four = ExecCtx::with_pool(&pool);  // explicit width
//! let traced = ExecCtx::default().traced("cache_merge_build");
//! # let _ = (ctx, seq, four, traced);
//! ```

use crate::obs;
use crate::quant::simd::Kernel;
use crate::util::pool::Pool;

/// Execution context for parallelizable registry / merge / quantize
/// operations: which [`Pool`] runs the work, which SIMD [`Kernel`]
/// drives the decode/axpy inner loops, and an optional span label under
/// which the operation reports itself to the tracing layer.
#[derive(Clone, Copy)]
pub struct ExecCtx<'p> {
    pool: &'p Pool,
    kernel: Kernel,
    trace: Option<&'static str>,
}

impl Default for ExecCtx<'static> {
    /// The shared global pool (width from `--threads` / `TVQ_THREADS`)
    /// and the detected SIMD kernel (overridable via `TVQ_SIMD`), no
    /// extra tracing — what the serve path wants.
    fn default() -> Self {
        ExecCtx { pool: Pool::global(), kernel: crate::quant::simd::active(), trace: None }
    }
}

impl<'p> ExecCtx<'p> {
    /// Context over an explicit pool (thread-scaling benches and the
    /// determinism suites pin widths through this).
    pub fn with_pool(pool: &'p Pool) -> ExecCtx<'p> {
        ExecCtx { pool, kernel: crate::quant::simd::active(), trace: None }
    }

    /// The single-threaded reference context — bit-exact twin of every
    /// parallel width, and the default for small one-shot loads where a
    /// worker spawn costs more than the decode.
    pub fn sequential() -> ExecCtx<'static> {
        static SEQ: std::sync::OnceLock<Pool> = std::sync::OnceLock::new();
        ExecCtx {
            pool: SEQ.get_or_init(Pool::sequential),
            kernel: crate::quant::simd::active(),
            trace: None,
        }
    }

    /// Pin the SIMD kernel for operations entered with this context —
    /// the parity suites compare `with_kernel(Kernel::Scalar)` against
    /// each detected kernel.  Panics if `kernel` is not available on
    /// this CPU (the dispatchers would hit undefined instructions).
    pub fn with_kernel(mut self, kernel: Kernel) -> Self {
        assert!(kernel.is_available(), "kernel {} not available on this CPU", kernel.label());
        self.kernel = kernel;
        self
    }

    /// Attach a trace label: the operation entered with this context
    /// opens one [`obs::span`] named `label` for its whole duration, so
    /// call sites (cache fill, routed patch, publish validation) show up
    /// attributed in trace exports.  Without a label no extra span is
    /// emitted — identical overhead to the pre-`ExecCtx` paths.
    pub fn traced(mut self, label: &'static str) -> Self {
        self.trace = Some(label);
        self
    }

    /// The pool operations fan work out on.
    pub fn pool(&self) -> &'p Pool {
        self.pool
    }

    /// The SIMD kernel driving the decode/axpy inner loops.
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// The trace label, if one was attached via [`ExecCtx::traced`].
    pub fn trace_label(&self) -> Option<&'static str> {
        self.trace
    }

    /// The operation-level span for this context, if tracing was
    /// requested.  Held by entry points for their full duration.
    pub(crate) fn op_span(&self, cat: obs::Category) -> Option<obs::SpanGuard> {
        self.trace.map(|label| obs::span(cat, label))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contexts_report_their_pools() {
        assert_eq!(ExecCtx::default().pool().threads(), Pool::global().threads());
        assert!(ExecCtx::sequential().pool().is_sequential());
        let pool = Pool::new(3);
        assert_eq!(ExecCtx::with_pool(&pool).pool().threads(), 3);
    }

    #[test]
    fn kernel_defaults_to_active_and_pins() {
        assert_eq!(ExecCtx::default().kernel(), crate::quant::simd::active());
        let scalar = ExecCtx::sequential().with_kernel(Kernel::Scalar);
        assert_eq!(scalar.kernel(), Kernel::Scalar);
        // Every detected kernel is accepted by the builder.
        for k in crate::quant::simd::detected() {
            assert_eq!(ExecCtx::default().with_kernel(k).kernel(), k);
        }
    }

    #[test]
    fn sequential_context_is_shared_and_stable() {
        let a = ExecCtx::sequential();
        let b = ExecCtx::sequential();
        assert!(std::ptr::eq(a.pool(), b.pool()), "one static sequential pool");
    }

    #[test]
    fn trace_label_round_trips() {
        let ctx = ExecCtx::default();
        assert!(ctx.trace_label().is_none());
        assert!(ctx.op_span(crate::obs::Category::Merge).is_none());
        let t = ctx.traced("unit_test_op");
        assert_eq!(t.trace_label(), Some("unit_test_op"));
        // With a label the span guard materializes (a no-op unless the
        // process-wide tracer is enabled — either way it must not panic).
        let g = t.op_span(crate::obs::Category::Merge);
        assert!(g.is_some());
    }
}
