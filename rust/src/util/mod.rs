//! Shared infrastructure substrates built in-house (the offline build
//! environment resolves only `xla` + `anyhow`): deterministic RNG, JSON,
//! statistics, a bench runner, a property-test harness, a CLI parser,
//! and the scoped worker pool behind the parallel decode/merge paths.

pub mod bench;
pub mod benchcmp;
pub mod cli;
pub mod exec;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;

/// Repo-root-relative path helper: resolves `rel` against the crate root
/// (`CARGO_MANIFEST_DIR`) so binaries work from any working directory.
pub fn repo_path(rel: &str) -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(rel)
}

/// CRC-32 (IEEE 802.3), table-driven — the integrity check shared by the
/// `TVQC` checkpoint container and the `QTVC` registry format.
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Directory where generated model zoos are cached between runs.
pub fn zoo_dir() -> std::path::PathBuf {
    repo_path("target/zoo")
}

/// Directory holding the AOT artifacts produced by `make artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    repo_path("artifacts")
}
