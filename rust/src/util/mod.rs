//! Shared infrastructure substrates built in-house (the offline build
//! environment resolves only `xla` + `anyhow`): deterministic RNG, JSON,
//! statistics, a bench runner, a property-test harness, and a CLI parser.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

/// Repo-root-relative path helper: resolves `rel` against the crate root
/// (`CARGO_MANIFEST_DIR`) so binaries work from any working directory.
pub fn repo_path(rel: &str) -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(rel)
}

/// Directory where generated model zoos are cached between runs.
pub fn zoo_dir() -> std::path::PathBuf {
    repo_path("target/zoo")
}

/// Directory holding the AOT artifacts produced by `make artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    repo_path("artifacts")
}
