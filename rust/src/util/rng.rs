//! Deterministic pseudo-random number generation.
//!
//! Implements SplitMix64 (seeding) and Xoshiro256** (bulk generation) from
//! Blackman & Vigna. Every stochastic component in the crate (data
//! generators, initializers, property tests) draws from this module so runs
//! are reproducible from a single `u64` seed.

/// SplitMix64 — used to expand a single seed into stream state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from the Box-Muller pair
    spare: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 per the reference implementation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare: None,
        }
    }

    /// Derive an independent stream (used to give each task its own RNG).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free bound is overkill here;
        // modulo bias is negligible for n << 2^64.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Standard normal as f32 scaled by `std`.
    #[inline]
    pub fn normal_f32(&mut self, std: f32) -> f32 {
        (self.normal() as f32) * std
    }

    /// Fill a slice with N(0, std) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(std);
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(11);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
