//! Minimal JSON parser and writer.
//!
//! serde/serde_json are unavailable in the offline build environment, so
//! this module implements the subset of JSON the system needs: parsing the
//! AOT artifact manifests emitted by `python/compile/aot.py` and writing
//! experiment/metrics output.  It is a complete RFC 8259 value model
//! (objects, arrays, strings with escapes, numbers, booleans, null) with
//! strict parsing and pretty/compact serialization.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing content at byte {}", p.pos);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow!("missing key {key:?} in JSON object"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => bail!("expected object, got {other:?}"),
        }
    }

    /// Shape helper: `[2, 3]` -> `vec![2, 3]`.
    pub fn as_shape(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // -- construction helpers ----------------------------------------------

    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            entries
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        write_json(self, &mut out);
        out
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => write_escaped(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(item, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, item)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_escaped(k, out);
                out.push(':');
                write_json(item, out);
            }
            out.push('}');
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON at byte {}", self.pos))
    }

    fn eat(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek()? as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected byte {:?} at {}", c as char, self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', found {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            self.pos += 4;
                            // Surrogate pairs for non-BMP characters.
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.bytes.get(self.pos) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .bytes
                                        .get(self.pos + 2..self.pos + 6)
                                        .ok_or_else(|| anyhow!("truncated surrogate"))?;
                                    let low = u32::from_str_radix(
                                        std::str::from_utf8(hex2)?,
                                        16,
                                    )?;
                                    self.pos += 6;
                                    0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                                } else {
                                    bail!("lone high surrogate");
                                }
                            } else {
                                code
                            };
                            s.push(
                                char::from_u32(ch)
                                    .ok_or_else(|| anyhow!("invalid codepoint {ch:#x}"))?,
                            );
                        }
                        c => bail!("invalid escape \\{:?}", c as char),
                    }
                }
                c if c < 0x20 => bail!("raw control character in string"),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            0xF0..=0xF7 => 4,
                            _ => bail!("invalid UTF-8 lead byte"),
                        };
                        let chunk = self
                            .bytes
                            .get(start..start + len)
                            .ok_or_else(|| anyhow!("truncated UTF-8"))?;
                        s.push_str(std::str::from_utf8(chunk)?);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek()? == b'-' {
            self.pos += 1;
        }
        while self
            .bytes
            .get(self.pos)
            .map(|b| b.is_ascii_digit() || matches!(b, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.req("c").unwrap().as_str().unwrap(), "x");
        let arr = v.req("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_usize().unwrap(), 1);
        assert_eq!(arr[2].req("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\ é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ \u{e9} \u{1F600}");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"obj":{"k":-1}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn shape_helper() {
        let v = Json::parse("[2, 3, 4]").unwrap();
        assert_eq!(v.as_shape().unwrap(), vec![2, 3, 4]);
        assert!(Json::parse("[2, -1]").unwrap().as_shape().is_err());
    }

    #[test]
    fn escaped_output_reparses() {
        let j = Json::str("line\nwith \"quotes\" \\ and tab\t");
        let back = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "name": "vit_s_forward_b8",
          "inputs": [{"name": "param:embed/b", "shape": [64], "dtype": "f32"}],
          "outputs": [{"shape": [8, 10], "dtype": "f32"}],
          "params": [{"name": "embed/b", "shape": [64]}],
          "meta": {"batch": 8}
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("name").unwrap().as_str().unwrap(), "vit_s_forward_b8");
        let inputs = v.req("inputs").unwrap().as_arr().unwrap();
        assert_eq!(
            inputs[0].req("shape").unwrap().as_shape().unwrap(),
            vec![64]
        );
    }
}
