//! Scoped worker pool for the decode/merge hot paths (rayon is
//! unavailable offline; this is the std-only substitute).
//!
//! Group-quantized payloads decompose into independently decodable
//! chunks, so every hot loop in the system — fused dequant-merge, lazy
//! task reconstruction, registry build/pack, the planner's sensitivity
//! probe — is a fan-out over independent work items or disjoint output
//! ranges.  [`Pool`] provides exactly those two shapes:
//!
//! * [`Pool::map`] — run one closure per item, results returned in item
//!   order (the fan-out shape: per-task quantization, per-tensor probe);
//! * [`Pool::for_each_shard`] — split one `&mut [T]` into at most
//!   `threads` contiguous, alignment-respecting shards and run a closure
//!   on each (the sharded-output shape: per-tensor axpy over disjoint
//!   group ranges).
//!
//! # Determinism contract
//!
//! The pool never performs reductions: outputs land in per-item slots
//! (`map`) or disjoint sub-slices (`for_each_shard`), so results are
//! **bit-identical for every thread count** as long as each item/shard
//! computation is itself deterministic — which is how the callers are
//! written (fixed accumulation order per output element, no
//! atomics-ordered float sums).  The determinism suite in
//! `rust/tests/pool_determinism.rs` pins this end to end.
//!
//! # Sequential mode
//!
//! A pool with `threads == 1` (or a single item/shard) runs every
//! closure **inline on the caller's thread** — no worker is spawned, no
//! channel is crossed.  This is the exact code path the parallel shards
//! also execute, just over the full range, so `--threads 1` is both the
//! determinism reference and the zero-overhead fallback.
//!
//! # Sizing
//!
//! [`Pool::global`] is the process-wide shared pool (the hot paths'
//! default).  Its width is resolved once: `TVQ_THREADS` env var if set
//! to a positive integer, else [`std::thread::available_parallelism`];
//! the `tvq` CLI's `--threads` flag overrides both via
//! [`Pool::init_global`] before first use.  Workers are *scoped* — threads
//! live only for the duration of one `map`/`for_each_shard` call — so a
//! shared pool costs nothing while idle and callers may also build
//! throwaway pools ([`Pool::new`]) for tests and thread-scaling benches.
//!
//! Nested use (a `map` job calling back into the same pool) spawns
//! additional scoped threads rather than deadlocking, but multiplies
//! thread counts — the hot paths therefore parallelize at exactly one
//! level (documented per call site).
//!
//! # Panics
//!
//! A panic inside a worker is caught at join and re-raised on the
//! calling thread ([`std::panic::resume_unwind`]) after every other
//! worker has finished — a poisoned shard can never be silently dropped.

use std::panic;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use anyhow::Result;

use crate::obs::trace;

/// A fixed-width scoped worker pool.  See the module docs for the
/// determinism and sequential-mode contracts.
pub struct Pool {
    threads: usize,
    /// Nanoseconds spent executing closures, **per worker slot** — the
    /// "cpu" side of merge-build wall/cpu timing, and the
    /// shard-imbalance signal (a slot far above the others means
    /// uneven shards).  Slot `w` accumulates what worker `w` of each
    /// `map`/`for_each_shard` call executed; inline sequential runs
    /// land in slot 0 (they run on the caller, which takes the place
    /// of worker 0).  Aggregates across all concurrent users.
    busy: Vec<AtomicU64>,
}

impl Pool {
    /// A pool running `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        Self { threads, busy: (0..threads).map(|_| AtomicU64::new(0)).collect() }
    }

    /// The single-threaded pool: every closure runs inline.
    pub fn sequential() -> Self {
        Self::new(1)
    }

    /// The process-wide shared pool used by the hot-path default entry
    /// points (`fused_merge`, `build_registry`, `probe`, ...).  Width:
    /// [`Pool::init_global`] override > `TVQ_THREADS` > available
    /// parallelism.
    pub fn global() -> &'static Pool {
        GLOBAL.get_or_init(|| Pool::new(default_threads()))
    }

    /// Fix the global pool's width (the CLI's `--threads`).  Returns
    /// `false` if the global pool was already initialized — the override
    /// must run before the first [`Pool::global`] call to take effect.
    pub fn init_global(threads: usize) -> bool {
        GLOBAL.set(Pool::new(threads)).is_ok()
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Whether every closure runs inline on the caller's thread.
    pub fn is_sequential(&self) -> bool {
        self.threads == 1
    }

    /// Cumulative busy time across all closures this pool has executed,
    /// in nanoseconds (summed over workers).  Sample before/after an
    /// operation to estimate its parallel "cpu time" (approximate when
    /// several operations share the pool concurrently).
    pub fn busy_ns(&self) -> u64 {
        self.busy.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Per-worker cumulative busy nanoseconds (one slot per worker;
    /// inline sequential runs count toward slot 0).  The spread across
    /// slots is the shard-imbalance signal surfaced in
    /// `MetricsSnapshot` and the watch stream.
    pub fn worker_busy_ns(&self) -> Vec<u64> {
        self.busy.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    fn timed<R>(&self, worker: usize, f: impl FnOnce() -> R) -> R {
        let t = Instant::now();
        let r = f();
        self.busy[worker]
            .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
        r
    }

    /// Run `f(index, item)` for every item, returning the outputs **in
    /// item order**.  Sequential pools (or single-item inputs) run
    /// inline, in order, on the caller's thread; parallel pools hand
    /// items to scoped workers through a shared queue, so completion
    /// order is arbitrary but the returned `Vec` never is.  A panicking
    /// closure propagates to the caller after all workers finish.
    pub fn map<I, T, F>(&self, items: Vec<I>, f: F) -> Vec<T>
    where
        I: Send,
        T: Send,
        F: Fn(usize, I) -> T + Sync,
    {
        let n = items.len();
        if self.threads == 1 || n <= 1 {
            return items
                .into_iter()
                .enumerate()
                .map(|(i, item)| self.timed(0, || f(i, item)))
                .collect();
        }
        let queue = Mutex::new(items.into_iter().enumerate());
        let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let workers = self.threads.min(n);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|w| {
                    let (queue, slots, f) = (&queue, &slots, &f);
                    s.spawn(move || {
                        // One span per worker per call: its duration is
                        // the worker's wall time draining the queue.
                        let _span = trace::span(trace::Category::Pool, "worker")
                            .with_arg("worker", w as u64);
                        loop {
                            // The closure runs outside the queue lock,
                            // so a panicking job can never poison the
                            // queue for its siblings.
                            let job = queue.lock().unwrap().next();
                            let Some((i, item)) = job else { break };
                            let out = self.timed(w, || f(i, item));
                            *slots[i].lock().unwrap() = Some(out);
                        }
                    })
                })
                .collect();
            // Join everything first, then re-raise the first panic: an
            // unwind must not race still-running siblings out of scope.
            let mut panicked = None;
            for h in handles {
                if let Err(p) = h.join() {
                    panicked.get_or_insert(p);
                }
            }
            if let Some(p) = panicked {
                panic::resume_unwind(p);
            }
        });
        slots
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .unwrap_or_else(|p| p.into_inner())
                    .expect("every map slot is filled before the scope exits")
            })
            .collect()
    }

    /// Fallible [`Pool::map`]: runs every item (errors do not cancel
    /// siblings — partial work must not leave skipped slots) and returns
    /// the first error by item order, or all outputs.
    pub fn try_map<I, T, F>(&self, items: Vec<I>, f: F) -> Result<Vec<T>>
    where
        I: Send,
        T: Send,
        F: Fn(usize, I) -> Result<T> + Sync,
    {
        self.map(items, f).into_iter().collect()
    }

    /// Split `data` into at most `threads` contiguous shards — every
    /// shard boundary a multiple of `align` elements — and run
    /// `f(start, shard)` on each, where `start` is the shard's offset
    /// into `data`.  Shards are disjoint `&mut` sub-slices: no two
    /// closures ever touch the same element, which is what makes sharded
    /// float accumulation bit-exact against the sequential pass.  With a
    /// sequential pool (or a single shard) this is exactly one inline
    /// `f(0, data)` call.  Returns the first shard error by offset
    /// order.
    pub fn for_each_shard<T, F>(&self, data: &mut [T], align: usize, f: F) -> Result<()>
    where
        T: Send,
        F: Fn(usize, &mut [T]) -> Result<()> + Sync,
    {
        assert!(align >= 1, "shard alignment must be >= 1");
        if data.is_empty() {
            return Ok(());
        }
        let units = data.len().div_ceil(align);
        let shards = self.threads.min(units);
        if shards == 1 {
            return self.timed(0, || f(0, data));
        }
        // Evenly spread whole alignment units; the final shard absorbs
        // the ragged tail.
        let per = units.div_ceil(shards) * align;
        let mut parts: Vec<(usize, &mut [T])> = Vec::with_capacity(shards);
        let mut rest = data;
        let mut start = 0;
        while !rest.is_empty() {
            let take = per.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            parts.push((start, head));
            start += take;
            rest = tail;
        }
        self.map(parts, |_, (off, shard)| f(off, shard))
            .into_iter()
            .collect()
    }
}

static GLOBAL: OnceLock<Pool> = OnceLock::new();

/// Default width for the global pool: `TVQ_THREADS` (positive integer)
/// if set, else the machine's available parallelism, else 1.
fn default_threads() -> usize {
    if let Ok(v) = std::env::var("TVQ_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
        eprintln!("warning: ignoring invalid TVQ_THREADS={v:?} (want a positive integer)");
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn map_preserves_item_order_at_any_width() {
        let items: Vec<usize> = (0..97).collect();
        for threads in [1, 2, 3, 8] {
            let pool = Pool::new(threads);
            let out = pool.map(items.clone(), |i, x| {
                assert_eq!(i, x);
                x * 3
            });
            assert_eq!(out, items.iter().map(|x| x * 3).collect::<Vec<_>>());
        }
    }

    #[test]
    fn sequential_pool_runs_inline_on_the_caller() {
        let pool = Pool::sequential();
        assert!(pool.is_sequential());
        let caller = std::thread::current().id();
        let ids = pool.map(vec![(); 4], |_, _| std::thread::current().id());
        assert!(ids.iter().all(|&id| id == caller), "threads=1 must not spawn");
        // And a single shard stays inline even on a wide pool.
        let wide = Pool::new(8);
        let mut data = [0u8; 4];
        wide.for_each_shard(&mut data, 8, |_, shard| {
            assert_eq!(std::thread::current().id(), caller);
            shard.fill(1);
            Ok(())
        })
        .unwrap();
        assert_eq!(data, [1; 4]);
    }

    #[test]
    fn worker_panic_propagates_to_the_caller() {
        let pool = Pool::new(4);
        let survivors = AtomicUsize::new(0);
        let r = panic::catch_unwind(panic::AssertUnwindSafe(|| {
            pool.map((0..32).collect::<Vec<usize>>(), |_, x| {
                if x == 7 {
                    panic!("shard 7 exploded");
                }
                survivors.fetch_add(1, Ordering::Relaxed);
                x
            });
        }));
        let payload = r.expect_err("worker panic must reach the caller");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .unwrap_or("(non-str payload)");
        assert!(msg.contains("shard 7"), "got: {msg}");
        // The pool stays usable after a panicked run.
        assert_eq!(pool.map(vec![1, 2], |_, x| x + 1), vec![2, 3]);
    }

    #[test]
    fn try_map_returns_first_error_by_item_order() {
        let pool = Pool::new(4);
        let err = pool
            .try_map((0..16).collect::<Vec<usize>>(), |_, x| {
                if x % 5 == 3 {
                    anyhow::bail!("item {x} failed")
                }
                Ok(x)
            })
            .unwrap_err();
        assert_eq!(err.to_string(), "item 3 failed");
        let ok = pool.try_map(vec![1, 2], |_, x| Ok::<_, anyhow::Error>(x * 2)).unwrap();
        assert_eq!(ok, vec![2, 4]);
    }

    #[test]
    fn shards_are_aligned_disjoint_and_complete() {
        // len = 103, align = 8: shard starts must be multiples of 8 and
        // together the shards must cover every element exactly once.
        for threads in [1, 2, 3, 5, 16, 64] {
            let pool = Pool::new(threads);
            let mut data = vec![0u32; 103];
            pool.for_each_shard(&mut data, 8, |start, shard| {
                assert_eq!(start % 8, 0, "shard start off alignment");
                for (i, v) in shard.iter_mut().enumerate() {
                    assert_eq!(*v, 0, "element visited twice");
                    *v = (start + i) as u32 + 1;
                }
                Ok(())
            })
            .unwrap();
            for (i, v) in data.iter().enumerate() {
                assert_eq!(*v, i as u32 + 1, "element {i} missed (threads={threads})");
            }
        }
    }

    #[test]
    fn shard_errors_surface_in_offset_order() {
        let pool = Pool::new(4);
        let mut data = vec![0u8; 64];
        let err = pool
            .for_each_shard(&mut data, 1, |start, _| {
                anyhow::bail!("shard at {start} failed")
            })
            .unwrap_err();
        assert_eq!(err.to_string(), "shard at 0 failed");
    }

    #[test]
    fn busy_ns_accumulates() {
        let pool = Pool::new(2);
        assert_eq!(pool.busy_ns(), 0);
        pool.map(vec![(); 4], |_, _| {
            std::thread::sleep(std::time::Duration::from_millis(2));
        });
        assert!(pool.busy_ns() >= 4 * 2_000_000, "busy {} ns", pool.busy_ns());
    }

    #[test]
    fn worker_busy_is_per_slot() {
        let pool = Pool::new(3);
        assert_eq!(pool.worker_busy_ns(), vec![0, 0, 0]);
        pool.map(vec![(); 6], |_, _| {
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        let per = pool.worker_busy_ns();
        assert_eq!(per.len(), 3);
        assert_eq!(per.iter().sum::<u64>(), pool.busy_ns());
        // Sequential (inline) runs land in slot 0.
        let seq = Pool::sequential();
        seq.map(vec![(); 2], |_, _| {
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        let per = seq.worker_busy_ns();
        assert_eq!(per.len(), 1);
        assert!(per[0] >= 2_000_000, "inline busy {} ns", per[0]);
    }
}
