//! Observability: lock-free histograms, span tracing, process-wide
//! stats.
//!
//! Three layers, all std-only:
//!
//! * [`hist`] — log2-bucket `AtomicU64` histograms with bounded
//!   relative quantile error (≤ 12.5%); the record path is a handful
//!   of relaxed atomics, safe on every hot path.
//! * [`trace`] — span tracing into per-thread bounded ring buffers,
//!   exported as Chrome trace-event JSON; one relaxed load per span
//!   site when disabled.
//! * [`stats`] — the process-wide histograms ([`stats()`]) for layers
//!   below the coordinator (registry section reads), which have no
//!   `Metrics` handle to record into.
//!
//! The serving stack threads these through every stage: request
//! latency / queue wait / merge build live in
//! `coordinator::Metrics`, per-variant service time in
//! `coordinator::metrics::VariantMetrics`, per-worker busy in
//! `util::Pool`, and section reads here.  `docs/ARCHITECTURE.md`
//! ("Observability") maps the span categories and histogram set.

pub mod hist;
pub mod trace;

pub use hist::{Histogram, HistogramSummary};
pub use trace::{span, Category};

use std::sync::OnceLock;

/// Process-wide histograms for layers that predate (and must not
/// depend on) the coordinator's `Metrics`.
#[derive(Debug, Default)]
pub struct GlobalStats {
    /// Per-section read+CRC time, nanoseconds
    /// (`registry::Registry::section_bytes`).
    pub section_read_ns: Histogram,
    /// Per-section bytes delivered by those reads.
    pub section_read_bytes: Histogram,
}

/// The process-wide stats. Lazily initialized, never reset implicitly.
pub fn stats() -> &'static GlobalStats {
    static S: OnceLock<GlobalStats> = OnceLock::new();
    S.get_or_init(GlobalStats::default)
}
