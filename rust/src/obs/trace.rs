//! Span tracing: per-thread bounded ring buffers of timed events,
//! exported as Chrome trace-event JSON.
//!
//! The hot paths (registry section reads, fused-merge phases, cache
//! builds, control-plane lifecycle) are instrumented with
//! [`span`] guards: a span records one *complete* event (begin
//! timestamp + duration, a category, an optional integer argument)
//! into the calling thread's ring buffer when the guard drops.
//!
//! # Cost contract
//!
//! Tracing is **off by default** and the off-path is one relaxed
//! atomic load per span site — no clock read, no allocation, no TLS
//! ring touched.  When on, a span costs two `Instant::now()` calls and
//! one push into a thread-local ring guarded by an uncontended mutex
//! (contended only during export).  Rings are bounded at
//! [`RING_CAP`] events per thread; beyond that the oldest events are
//! overwritten, so a trace can run indefinitely without growing.
//!
//! # Enabling
//!
//! * programmatic: [`enable`] / [`disable`];
//! * CLI: `tvq ... --trace out.json` (main enables at startup and
//!   exports at exit);
//! * environment: `TVQ_TRACE=out.json` — [`init_from_env`] enables if
//!   set, [`flush_env`] writes the file; the packed-registry example
//!   calls both, so `TVQ_TRACE=trace.json cargo run --example
//!   packed_registry` yields a loadable trace with no CLI plumbing.
//!
//! # Export format
//!
//! [`export_json`] renders the Chrome trace-event format (the JSON
//! array form wrapped in `{"traceEvents": [...]}`): one `"ph": "X"`
//! complete event per span with microsecond `ts`/`dur`, `pid` 1 and a
//! stable per-thread `tid`.  Load in `chrome://tracing` or Perfetto.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Per-thread ring capacity, in events.
pub const RING_CAP: usize = 1 << 14;

/// Span categories — the lanes of the serving stack.  Fixed set so
/// trace consumers (and the acceptance test) can filter by lane.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Category {
    /// Registry open + section reads (CRC, byte counts).
    Registry,
    /// Fused-merge phases: view decode vs sharded axpy.
    Merge,
    /// ModelCache build / hit / evict.
    Cache,
    /// Control plane: admission, drain, generation swap.
    Control,
    /// Worker-pool per-worker busy intervals.
    Pool,
    /// Server/batcher request handling.
    Serve,
}

impl Category {
    pub fn as_str(self) -> &'static str {
        match self {
            Category::Registry => "registry",
            Category::Merge => "merge",
            Category::Cache => "cache",
            Category::Control => "control",
            Category::Pool => "pool",
            Category::Serve => "serve",
        }
    }
}

/// One recorded complete event.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub name: &'static str,
    pub cat: Category,
    /// Nanoseconds since the trace epoch ([`enable`] time).
    pub ts_ns: u64,
    pub dur_ns: u64,
    pub tid: u64,
    /// Optional single integer argument (bytes read, tensor index, …).
    pub arg: Option<(&'static str, u64)>,
}

/// Bounded per-thread event ring.  Owned by an `Arc` registered in the
/// global collector so events survive thread exit (the pool's scoped
/// workers die after every `map` call).
struct Ring {
    events: Vec<Event>,
    /// Next write position once the ring is full (wraps).
    next: usize,
    /// Events overwritten after the ring filled.
    dropped: u64,
}

impl Ring {
    fn new() -> Self {
        Self { events: Vec::new(), next: 0, dropped: 0 }
    }

    fn push(&mut self, ev: Event) {
        if self.events.len() < RING_CAP {
            self.events.push(ev);
        } else {
            self.events[self.next] = ev;
            self.next = (self.next + 1) % RING_CAP;
            self.dropped += 1;
        }
    }
}

struct Collector {
    rings: Mutex<Vec<Arc<Mutex<Ring>>>>,
    epoch: Mutex<Instant>,
    next_tid: AtomicU64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);

fn collector() -> &'static Collector {
    static C: OnceLock<Collector> = OnceLock::new();
    C.get_or_init(|| Collector {
        rings: Mutex::new(Vec::new()),
        epoch: Mutex::new(Instant::now()),
        next_tid: AtomicU64::new(1),
    })
}

thread_local! {
    static LOCAL: RefCell<Option<(u64, Arc<Mutex<Ring>>)>> = const { RefCell::new(None) };
}

/// Whether tracing is currently recording.  One relaxed load — this is
/// the entire cost of a span site while tracing is off.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Start recording.  Resets the epoch (timestamps are relative to the
/// most recent `enable`) but keeps previously recorded events; call
/// [`clear`] first for a fresh trace.
pub fn enable() {
    let c = collector();
    *c.epoch.lock().unwrap() = Instant::now();
    ENABLED.store(true, Ordering::SeqCst);
}

/// Stop recording.  Recorded events remain available for export.
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Drop all recorded events (every thread's ring).
pub fn clear() {
    let rings = collector().rings.lock().unwrap();
    for r in rings.iter() {
        let mut r = r.lock().unwrap();
        r.events.clear();
        r.next = 0;
        r.dropped = 0;
    }
}

/// Enable tracing if the `TVQ_TRACE` environment variable names an
/// output path.  Returns the path when enabled.  Pair with
/// [`flush_env`] at process end.
pub fn init_from_env() -> Option<String> {
    let path = std::env::var("TVQ_TRACE").ok().filter(|p| !p.is_empty())?;
    enable();
    Some(path)
}

/// Write the trace to the `TVQ_TRACE` path if tracing was enabled via
/// [`init_from_env`].  No-op (Ok) when the variable is unset.
pub fn flush_env() -> Result<()> {
    match std::env::var("TVQ_TRACE").ok().filter(|p| !p.is_empty()) {
        Some(path) => export_to_file(&path),
        None => Ok(()),
    }
}

/// RAII span guard: records one complete event on drop.  Inert (and
/// cost-free beyond the flag check) when tracing is off at open time.
pub struct SpanGuard {
    live: Option<(Instant, Event)>,
}

impl SpanGuard {
    /// Attach an integer argument (bytes, index, …) to the event.
    pub fn with_arg(mut self, name: &'static str, value: u64) -> Self {
        if let Some((_, ev)) = self.live.as_mut() {
            ev.arg = Some((name, value));
        }
        self
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some((start, mut ev)) = self.live.take() else { return };
        ev.dur_ns = start.elapsed().as_nanos() as u64;
        LOCAL.with(|slot| {
            let mut slot = slot.borrow_mut();
            let (tid, ring) = slot.get_or_insert_with(|| {
                let c = collector();
                let tid = c.next_tid.fetch_add(1, Ordering::Relaxed);
                let ring = Arc::new(Mutex::new(Ring::new()));
                c.rings.lock().unwrap().push(Arc::clone(&ring));
                (tid, ring)
            });
            ev.tid = *tid;
            ring.lock().unwrap().push(ev);
        });
    }
}

/// Open a span.  `name` and `cat` label the event; the duration runs
/// until the returned guard drops.  When tracing is off this is a
/// single atomic load and the guard is inert.
#[inline]
pub fn span(cat: Category, name: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard { live: None };
    }
    let now = Instant::now();
    let ts_ns = {
        let epoch = *collector().epoch.lock().unwrap();
        now.duration_since(epoch).as_nanos() as u64
    };
    SpanGuard {
        live: Some((
            now,
            Event { name, cat, ts_ns, dur_ns: 0, tid: 0, arg: None },
        )),
    }
}

/// Snapshot every thread's recorded events, ordered by timestamp.
pub fn events() -> Vec<Event> {
    let rings = collector().rings.lock().unwrap();
    let mut out = Vec::new();
    for r in rings.iter() {
        out.extend(r.lock().unwrap().events.iter().copied());
    }
    out.sort_by_key(|e| e.ts_ns);
    out
}

/// Total events overwritten by full rings (trace truncation signal).
pub fn dropped() -> u64 {
    let rings = collector().rings.lock().unwrap();
    rings.iter().map(|r| r.lock().unwrap().dropped).sum()
}

/// Render the recorded events as Chrome trace-event JSON
/// (`{"traceEvents": [...]}`, `"ph": "X"` complete events,
/// microsecond timestamps).
pub fn export_json() -> Json {
    let evs = events()
        .into_iter()
        .map(|e| {
            let mut fields = vec![
                ("name", Json::str(e.name)),
                ("cat", Json::str(e.cat.as_str())),
                ("ph", Json::str("X")),
                ("ts", Json::num(e.ts_ns as f64 / 1e3)),
                ("dur", Json::num(e.dur_ns as f64 / 1e3)),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(e.tid as f64)),
            ];
            if let Some((k, v)) = e.arg {
                fields.push(("args", Json::obj(vec![(k, Json::num(v as f64))])));
            }
            Json::obj(fields)
        })
        .collect::<Vec<_>>();
    Json::obj(vec![
        ("traceEvents", Json::Arr(evs)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

/// Write [`export_json`] to `path` (compact, single line).
pub fn export_to_file(path: &str) -> Result<()> {
    std::fs::write(path, export_json().to_string_compact())
        .with_context(|| format!("writing trace to {path}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    // One combined test: tracing state is process-global and unit
    // tests run concurrently, so splitting these into separate #[test]
    // fns would race on enable/clear.  The full end-to-end check
    // (multi-category spans from real serving code, file export,
    // reparse) lives in rust/tests/obs_integration.rs, its own
    // process.
    #[test]
    fn spans_record_and_export_roundtrip() {
        // NOTE: while this test holds tracing enabled, concurrently
        // running unit tests on instrumented paths may record spans
        // too.  Assertions therefore filter by this test's unique span
        // names and never assert global counts.
        assert!(!enabled(), "tracing must default to off");
        // Off: spans are inert.
        {
            let _g = span(Category::Merge, "obs_test_ignored");
        }
        enable();
        {
            let _g = span(Category::Registry, "obs_test_outer").with_arg("bytes", 42);
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        std::thread::scope(|s| {
            s.spawn(|| {
                let _g = span(Category::Pool, "obs_test_worker");
            });
        });
        disable();
        let evs = events();
        assert!(
            !evs.iter().any(|e| e.name == "obs_test_ignored"),
            "disabled span must not record"
        );
        let reg = evs.iter().find(|e| e.name == "obs_test_outer").unwrap();
        assert_eq!(reg.cat, Category::Registry);
        assert_eq!(reg.arg, Some(("bytes", 42)));
        assert!(reg.dur_ns >= 1_000_000, "span measured its body");
        let pool = evs.iter().find(|e| e.name == "obs_test_worker").unwrap();
        assert_ne!(pool.tid, reg.tid, "per-thread tids differ");

        // Export reparses via util::json and preserves the fields.
        let text = export_json().to_string_compact();
        let parsed = Json::parse(&text).unwrap();
        let tes = parsed.req("traceEvents").unwrap().as_arr().unwrap();
        let ours: Vec<_> = tes
            .iter()
            .filter(|te| {
                te.req("name").unwrap().as_str().unwrap().starts_with("obs_test_")
            })
            .collect();
        assert_eq!(ours.len(), 2);
        for te in ours {
            assert_eq!(te.req("ph").unwrap().as_str().unwrap(), "X");
            assert_eq!(te.req("pid").unwrap().as_usize().unwrap(), 1);
        }
    }
}
