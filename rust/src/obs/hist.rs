//! Lock-free log2-bucket histograms for hot-path timing.
//!
//! The serving stack records a latency on every request, every section
//! read and every merge build; a mutex (or any shared cursor) on that
//! path serializes recorders and — as the old `Metrics` reservoir
//! demonstrated — invites lost updates.  [`Histogram`] is the
//! replacement: a fixed array of `AtomicU64` buckets plus running
//! count/sum/max, all updated with relaxed atomics.  Recording is three
//! `fetch_add`s and one `fetch_max`; there is nothing to contend on but
//! cache lines.
//!
//! # Bucket layout and error bound
//!
//! Values (u64, typically nanoseconds or bytes) map to buckets by a
//! log2-with-linear-subdivision rule: values below [`SUBS`] get one
//! exact bucket each; every higher power-of-two range `[2^k, 2^(k+1))`
//! is split into [`SUBS`] equal sub-buckets.  A bucket's width is
//! therefore at most `1/SUBS` of its lower bound, so any statistic that
//! answers with a value *inside* the containing bucket — which is how
//! [`Histogram::quantile`] answers — carries a **relative error of at
//! most 1/SUBS = 12.5%**, independent of the distribution.
//!
//! Quantiles are estimated by rank-walking the bucket counts and
//! returning the containing bucket's inclusive upper bound: exact for
//! values `< SUBS`, within one bucket width otherwise.
//!
//! # Concurrency semantics
//!
//! `record` never loses an update: count, sum and the bucket increment
//! are each atomic, so after all recorders finish, `count()` and
//! `sum()` are exact.  A concurrent `snapshot`/`quantile` may observe a
//! record "in flight" (bucket bumped, sum not yet) — point-in-time
//! reads are approximate by design, totals are not.  `reset` is a
//! non-atomic sweep intended for quiescent windows (post-warmup), not
//! for use concurrent with recorders.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::json::Json;

/// log2 of the per-octave sub-bucket count.
const LOG_SUBS: u32 = 3;
/// Linear sub-buckets per power-of-two range; also the bound below
/// which every value gets its own exact bucket.
pub const SUBS: u64 = 1 << LOG_SUBS;
/// Total bucket count: SUBS exact buckets + SUBS per octave for
/// octaves 2^3 .. 2^63.  Covers all of u64.
pub const BUCKETS: usize = (SUBS as usize) + (64 - LOG_SUBS as usize) * SUBS as usize;

/// Bucket index for a value.  Monotone in `value`; every u64 maps to
/// exactly one of the [`BUCKETS`] buckets.
#[inline]
pub fn bucket_index(value: u64) -> usize {
    if value < SUBS {
        return value as usize;
    }
    let top = 63 - value.leading_zeros(); // >= LOG_SUBS
    let shift = top - LOG_SUBS;
    let sub = (value >> shift) & (SUBS - 1);
    ((top - LOG_SUBS) as u64 * SUBS + SUBS + sub) as usize
}

/// Inclusive `[lo, hi]` value range of bucket `index`.  Every value in
/// the range maps back to `index` under [`bucket_index`].
#[inline]
pub fn bucket_bounds(index: usize) -> (u64, u64) {
    let i = index as u64;
    if i < SUBS {
        return (i, i);
    }
    let b = i - SUBS;
    let shift = (b / SUBS) as u32;
    let sub = b % SUBS;
    let lo = (SUBS + sub) << shift;
    let width_minus_1 = (1u64 << shift) - 1;
    (lo, lo + width_minus_1)
}

/// A lock-free histogram: fixed `AtomicU64` buckets + count/sum/max.
/// ~4 KiB; embed directly (no allocation) and share behind the owning
/// struct's `Arc`.
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram")
            .field("count", &self.count())
            .field("sum", &self.sum())
            .field("max", &self.max())
            .finish()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one value.  Lock-free: three relaxed `fetch_add`s and a
    /// `fetch_max`; concurrent recorders never lose an update.
    #[inline]
    pub fn record(&self, value: u64) {
        self.buckets[bucket_index(value)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value, Ordering::Relaxed);
        self.max.fetch_max(value, Ordering::Relaxed);
    }

    /// Record a duration in nanoseconds (the stack's timing unit).
    #[inline]
    pub fn record_ns(&self, d: Duration) {
        self.record(d.as_nanos() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 { 0.0 } else { self.sum() as f64 / c as f64 }
    }

    /// Quantile estimate for `q` in `[0, 1]`: the inclusive upper bound
    /// of the bucket containing the rank-`⌈q·count⌉` sample.  Exact for
    /// values `< SUBS`; otherwise within one bucket width of the true
    /// quantile (relative error ≤ 1/SUBS).  Returns 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        let counts: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_bounds(i).1;
            }
        }
        bucket_bounds(BUCKETS - 1).1
    }

    /// Point-in-time summary (count / sum / max / p50 / p90 / p99).
    pub fn summary(&self) -> HistogramSummary {
        HistogramSummary {
            count: self.count(),
            sum: self.sum(),
            max: self.max(),
            p50: self.quantile(0.50),
            p90: self.quantile(0.90),
            p99: self.quantile(0.99),
        }
    }

    /// Zero every bucket and counter.  Not atomic as a whole: intended
    /// for quiescent windows (post-warmup reset), where it leaves the
    /// histogram exactly empty.
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }
}

/// Immutable histogram summary.  Values carry the histogram's unit
/// (nanoseconds for the serving-stack timing histograms).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct HistogramSummary {
    pub count: u64,
    pub sum: u64,
    pub max: u64,
    pub p50: u64,
    pub p90: u64,
    pub p99: u64,
}

impl HistogramSummary {
    pub fn mean(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum as f64 / self.count as f64 }
    }

    /// JSON rendering with values divided by `scale` (e.g. 1e3 to
    /// report a nanosecond histogram in microseconds).
    pub fn to_json_scaled(&self, scale: f64) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("mean", Json::num(self.mean() / scale)),
            ("p50", Json::num(self.p50 as f64 / scale)),
            ("p90", Json::num(self.p90 as f64 / scale)),
            ("p99", Json::num(self.p99 as f64 / scale)),
            ("max", Json::num(self.max as f64 / scale)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn bucket_index_and_bounds_agree() {
        // Every probe value must land in a bucket whose bounds contain
        // it, and bucket bounds must tile u64 without gap or overlap.
        for v in (0..1024).chain([1 << 20, u64::MAX / 3, u64::MAX - 1, u64::MAX]) {
            let i = bucket_index(v);
            let (lo, hi) = bucket_bounds(i);
            assert!(lo <= v && v <= hi, "value {v} outside bucket {i} [{lo}, {hi}]");
        }
        let mut expected_lo = 0u64;
        for i in 0..BUCKETS {
            let (lo, hi) = bucket_bounds(i);
            assert_eq!(lo, expected_lo, "gap/overlap before bucket {i}");
            assert!(hi >= lo);
            if i + 1 < BUCKETS {
                expected_lo = hi + 1;
            } else {
                assert_eq!(hi, u64::MAX, "last bucket must end at u64::MAX");
            }
        }
    }

    #[test]
    fn prop_recorded_value_lands_in_containing_bucket() {
        prop::check(
            prop::Config::default(),
            |rng: &mut Rng| {
                let shift = rng.below(64) as u32;
                (rng.below(usize::MAX) as u64) >> shift
            },
            |&v| {
                let i = bucket_index(v);
                let (lo, hi) = bucket_bounds(i);
                if lo <= v && v <= hi {
                    Ok(())
                } else {
                    Err(format!("{v} -> bucket {i} [{lo}, {hi}]"))
                }
            },
        );
    }

    #[test]
    fn prop_quantile_within_one_bucket_width() {
        prop::check(
            prop::Config::default(),
            |rng: &mut Rng| {
                let n = 1 + rng.below(200);
                let vals: Vec<u64> =
                    (0..n).map(|_| rng.below(1 << 20) as u64).collect();
                let q = rng.below(101) as f64 / 100.0;
                (vals, q)
            },
            |(vals, q)| {
                let h = Histogram::new();
                for &v in vals {
                    h.record(v);
                }
                let mut sorted = vals.clone();
                sorted.sort_unstable();
                let rank = ((q * vals.len() as f64).ceil() as usize)
                    .clamp(1, vals.len());
                let truth = sorted[rank - 1];
                let est = h.quantile(*q);
                // The estimate is the containing bucket's upper bound,
                // so it must lie within that bucket's width of truth.
                let (lo, hi) = bucket_bounds(bucket_index(truth));
                if est < lo || est > hi {
                    return Err(format!(
                        "q={q}: est {est} outside truth bucket [{lo}, {hi}] (truth {truth})"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn concurrent_recording_sums_exactly() {
        // The whole point of the histogram migration: no recorder ever
        // loses an update, unlike the old cursor-indexed reservoir.
        let h = Histogram::new();
        let threads = 8;
        let per = 5_000u64;
        std::thread::scope(|s| {
            for t in 0..threads {
                let h = &h;
                s.spawn(move || {
                    for i in 0..per {
                        h.record(t * per + i);
                    }
                });
            }
        });
        let n = threads * per;
        assert_eq!(h.count(), n);
        assert_eq!(h.sum(), n * (n - 1) / 2);
        assert_eq!(h.max(), n - 1);
    }

    #[test]
    fn quantiles_and_reset() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0);
        for v in 0..8u64 {
            h.record(v);
        }
        // Values < SUBS are exact.
        assert_eq!(h.quantile(0.0), 0);
        assert_eq!(h.quantile(0.5), 3);
        assert_eq!(h.quantile(1.0), 7);
        assert_eq!(h.mean(), 3.5);
        let s = h.summary();
        assert_eq!(s.count, 8);
        assert_eq!(s.max, 7);
        let j = s.to_json_scaled(1.0);
        assert_eq!(j.req("count").unwrap().as_usize().unwrap(), 8);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.summary(), HistogramSummary::default());
    }
}
