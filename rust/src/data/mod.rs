//! Synthetic task suites (the stand-in for the paper's 8/14/20 vision
//! datasets and NYUv2 — see DESIGN.md §2 for the substitution argument).
//!
//! Every generator is deterministic from a task seed, so train/eval splits
//! are reproducible without storing datasets.

pub mod classify;
pub mod dense;

pub use classify::{ClassifyTask, TaskSuite};
pub use dense::{DenseBatch, DenseScene, DenseTaskKind};

/// Model-preset geometry shared with the Python side.  The integration
/// tests cross-check these constants against the AOT manifests' meta.
#[derive(Clone, Copy, Debug)]
pub struct VitPreset {
    pub name: &'static str,
    pub dim: usize,
    pub depth: usize,
    pub heads: usize,
    pub tokens: usize,
    pub token_dim: usize,
    pub n_classes: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub serve_buckets: &'static [usize],
}

pub const VIT_S: VitPreset = VitPreset {
    name: "vit_s",
    dim: 64,
    depth: 2,
    heads: 4,
    tokens: 16,
    token_dim: 16,
    n_classes: 10,
    train_batch: 32,
    eval_batch: 256,
    serve_buckets: &[1, 8, 32],
};

pub const VIT_M: VitPreset = VitPreset {
    name: "vit_m",
    dim: 128,
    depth: 4,
    heads: 4,
    tokens: 16,
    token_dim: 16,
    n_classes: 10,
    train_batch: 32,
    eval_batch: 256,
    serve_buckets: &[1, 32],
};

pub const VIT_L: VitPreset = VitPreset {
    name: "vit_l",
    dim: 192,
    depth: 6,
    heads: 6,
    tokens: 16,
    token_dim: 16,
    n_classes: 10,
    train_batch: 32,
    eval_batch: 256,
    serve_buckets: &[1, 32],
};

pub fn preset_by_name(name: &str) -> Option<&'static VitPreset> {
    match name {
        "vit_s" => Some(&VIT_S),
        "vit_m" => Some(&VIT_M),
        "vit_l" => Some(&VIT_L),
        _ => None,
    }
}

/// Dense-prediction geometry (matches `python/compile/model.py`).
#[derive(Clone, Copy, Debug)]
pub struct DensePreset {
    pub height: usize,
    pub width: usize,
    pub in_ch: usize,
    pub ch: usize,
    pub seg_classes: usize,
    pub batch: usize,
}

pub const DENSE: DensePreset = DensePreset {
    height: 16,
    width: 16,
    in_ch: 3,
    ch: 24,
    seg_classes: 6,
    batch: 8,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_by_name() {
        assert_eq!(preset_by_name("vit_s").unwrap().dim, 64);
        assert_eq!(preset_by_name("vit_l").unwrap().depth, 6);
        assert!(preset_by_name("vit_xxl").is_none());
    }
}
