//! Synthetic classification task suites.
//!
//! Each task draws class prototypes in input space ([tokens, token_dim]
//! "images") and labels samples by their generating prototype, with
//! additive Gaussian noise controlling difficulty.  Each task also owns a
//! frozen random classification head — the analog of CLIP's text-derived
//! per-task heads: only the trunk is fine-tuned and merged, exactly the
//! paper's protocol.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::VitPreset;

/// One synthetic classification task.
#[derive(Clone, Debug)]
pub struct ClassifyTask {
    pub id: usize,
    pub seed: u64,
    /// Class prototypes: n_classes tensors of [tokens, token_dim].
    prototypes: Vec<Tensor>,
    /// Frozen per-task head [dim, n_classes].
    pub head: Tensor,
    /// Sample noise std (higher = harder).
    pub noise: f32,
    tokens: usize,
    token_dim: usize,
    n_classes: usize,
}

impl ClassifyTask {
    pub fn new(preset: &VitPreset, id: usize, seed: u64) -> Self {
        Self::with_noise(preset, id, seed, 0.9)
    }

    pub fn with_noise(preset: &VitPreset, id: usize, seed: u64, noise: f32) -> Self {
        // Mix seed and id multiplicatively (plain XOR of nearby seeds and
        // ids collides: (s+1) ^ (c+1) == s ^ c for even s, c).
        let mut rng = Rng::new(
            seed.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (id as u64).wrapping_mul(0xA5A5_A5A5_A5A5_A5A5),
        );
        let prototypes = (0..preset.n_classes)
            .map(|_| Tensor::randn(&[preset.tokens, preset.token_dim], 1.0, &mut rng))
            .collect();
        let head = Tensor::randn(
            &[preset.dim, preset.n_classes],
            (preset.dim as f32).powf(-0.5),
            &mut rng,
        );
        Self {
            id,
            seed,
            prototypes,
            head,
            noise,
            tokens: preset.tokens,
            token_dim: preset.token_dim,
            n_classes: preset.n_classes,
        }
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    /// Sample a batch: returns (x `[n, tokens, token_dim]`, labels `[n]`).
    pub fn sample(&self, n: usize, rng: &mut Rng) -> (Tensor, Vec<i32>) {
        let mut x = Tensor::zeros(&[n, self.tokens, self.token_dim]);
        let mut y = Vec::with_capacity(n);
        let img = self.tokens * self.token_dim;
        for i in 0..n {
            let cls = rng.below(self.n_classes);
            y.push(cls as i32);
            let proto = self.prototypes[cls].data();
            let dst = &mut x.data_mut()[i * img..(i + 1) * img];
            for (d, &p) in dst.iter_mut().zip(proto) {
                *d = p + rng.normal_f32(self.noise);
            }
        }
        (x, y)
    }

    /// Deterministic held-out evaluation set (fixed derived seed).
    pub fn eval_set(&self, n: usize) -> (Tensor, Vec<i32>) {
        let mut rng = Rng::new(self.seed ^ 0xEEE1_7357);
        self.sample(n, &mut rng)
    }

    /// Deterministic training pool, disjoint seed from eval.
    pub fn train_pool(&self, n: usize) -> (Tensor, Vec<i32>) {
        let mut rng = Rng::new(self.seed ^ 0x7124_1A1A);
        self.sample(n, &mut rng)
    }
}

/// A suite of T tasks sharing a model preset (the 8/14/20-task settings).
#[derive(Clone, Debug)]
pub struct TaskSuite {
    pub preset: &'static VitPreset,
    pub tasks: Vec<ClassifyTask>,
}

impl TaskSuite {
    /// Standard suite: task i gets seed `base_seed + i`.
    pub fn new(preset: &'static VitPreset, n_tasks: usize, base_seed: u64) -> Self {
        let tasks = (0..n_tasks)
            .map(|i| ClassifyTask::new(preset, i, base_seed.wrapping_add(i as u64)))
            .collect();
        Self { preset, tasks }
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// The "generic" pre-training task (disjoint seed space): a mixture
    /// task standing in for CLIP's web-scale pre-training distribution.
    pub fn pretrain_task(&self) -> ClassifyTask {
        ClassifyTask::new(self.preset, usize::MAX, 0x9E37_79B9)
    }
}

#[cfg(test)]
mod tests {
    use super::super::VIT_S;
    use super::*;

    #[test]
    fn batch_shapes_and_labels() {
        let task = ClassifyTask::new(&VIT_S, 0, 1);
        let mut rng = Rng::new(0);
        let (x, y) = task.sample(17, &mut rng);
        assert_eq!(x.shape(), &[17, 16, 16]);
        assert_eq!(y.len(), 17);
        assert!(y.iter().all(|&c| (0..10).contains(&c)));
    }

    #[test]
    fn eval_set_is_deterministic() {
        let task = ClassifyTask::new(&VIT_S, 0, 2);
        let (x1, y1) = task.eval_set(32);
        let (x2, y2) = task.eval_set(32);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn eval_and_train_pools_differ() {
        let task = ClassifyTask::new(&VIT_S, 0, 3);
        let (xe, _) = task.eval_set(16);
        let (xt, _) = task.train_pool(16);
        assert!(xe != xt);
    }

    #[test]
    fn tasks_are_distinct() {
        let suite = TaskSuite::new(&VIT_S, 3, 100);
        let (x0, _) = suite.tasks[0].eval_set(8);
        let (x1, _) = suite.tasks[1].eval_set(8);
        assert!(x0 != x1);
        assert!(suite.tasks[0].head != suite.tasks[1].head);
    }

    #[test]
    fn labels_are_recoverable_by_nearest_prototype() {
        // Sanity: with moderate noise, nearest-prototype classification
        // gets well above chance — the tasks are learnable.
        let task = ClassifyTask::with_noise(&VIT_S, 0, 4, 0.5);
        let (x, y) = task.eval_set(200);
        let img = 16 * 16;
        let mut correct = 0;
        for i in 0..200 {
            let xi = &x.data()[i * img..(i + 1) * img];
            let mut best = (f64::INFINITY, 0usize);
            for (c, proto) in task.prototypes.iter().enumerate() {
                let d = crate::util::stats::l2_dist(xi, proto.data());
                if d < best.0 {
                    best = (d, c);
                }
            }
            if best.1 as i32 == y[i] {
                correct += 1;
            }
        }
        assert!(correct > 150, "nearest-prototype acc {correct}/200");
    }
}
