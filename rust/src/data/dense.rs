//! Synthetic dense-prediction scenes (the NYUv2 stand-in).
//!
//! Each scene is a 2-D composition of geometric primitives (rectangles and
//! discs) over a sloped background.  From one latent scene we derive all
//! three task targets so the tasks are *related but distinct*, mirroring
//! NYUv2's seg/depth/normal structure:
//!   * segmentation: per-pixel shape class (0 = background),
//!   * depth: background gradient + per-shape depth offsets,
//!   * normals: analytic surface normals of the depth field.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::DensePreset;

/// Which dense task a head/artifact serves.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DenseTaskKind {
    Seg,
    Depth,
    Normal,
}

impl DenseTaskKind {
    pub fn all() -> [DenseTaskKind; 3] {
        [DenseTaskKind::Seg, DenseTaskKind::Depth, DenseTaskKind::Normal]
    }

    pub fn name(&self) -> &'static str {
        match self {
            DenseTaskKind::Seg => "seg",
            DenseTaskKind::Depth => "depth",
            DenseTaskKind::Normal => "normal",
        }
    }

    pub fn out_ch(&self, preset: &DensePreset) -> usize {
        match self {
            DenseTaskKind::Seg => preset.seg_classes,
            DenseTaskKind::Depth => 1,
            DenseTaskKind::Normal => 3,
        }
    }
}

/// One generated scene with all targets.
#[derive(Clone, Debug)]
pub struct DenseScene {
    /// RGB input [H, W, 3].
    pub rgb: Vec<f32>,
    /// Segmentation labels [H, W] in 0..seg_classes.
    pub seg: Vec<i32>,
    /// Depth [H, W].
    pub depth: Vec<f32>,
    /// Unit normals [H, W, 3].
    pub normal: Vec<f32>,
}

/// A batch of scenes formatted for the AOT dense artifacts.
#[derive(Clone, Debug)]
pub struct DenseBatch {
    /// x [B, H, W, 3]
    pub x: Tensor,
    /// seg labels [B, H, W]
    pub seg: Vec<i32>,
    /// depth [B, H, W, 1]
    pub depth: Tensor,
    /// normals [B, H, W, 3]
    pub normal: Tensor,
}

pub fn generate_scene(preset: &DensePreset, rng: &mut Rng) -> DenseScene {
    let (h, w) = (preset.height, preset.width);
    let mut seg = vec![0i32; h * w];
    let mut depth = vec![0.0f32; h * w];
    // Background: depth increases with row (a floor receding upward).
    let slope = rng.uniform(0.3, 0.7);
    for y in 0..h {
        for x in 0..w {
            depth[y * w + x] = 1.0 + slope * (y as f32 / h as f32);
        }
    }
    // 1..=3 primitives.
    let n_shapes = 1 + rng.below(3);
    for _ in 0..n_shapes {
        let cls = 1 + rng.below(preset.seg_classes - 1);
        let d = rng.uniform(0.2, 0.9);
        if rng.below(2) == 0 {
            // rectangle
            let x0 = rng.below(w - 4);
            let y0 = rng.below(h - 4);
            let dw = 3 + rng.below((w - x0 - 3).min(8));
            let dh = 3 + rng.below((h - y0 - 3).min(8));
            for y in y0..(y0 + dh).min(h) {
                for x in x0..(x0 + dw).min(w) {
                    seg[y * w + x] = cls as i32;
                    depth[y * w + x] = d;
                }
            }
        } else {
            // disc
            let cx = rng.below(w) as f32;
            let cy = rng.below(h) as f32;
            let r = rng.uniform(2.0, 5.0);
            for y in 0..h {
                for x in 0..w {
                    let dx = x as f32 - cx;
                    let dy = y as f32 - cy;
                    if dx * dx + dy * dy <= r * r {
                        seg[y * w + x] = cls as i32;
                        // Spherical cap depth for curved normals.
                        let bulge = (r * r - dx * dx - dy * dy).max(0.0).sqrt() / r;
                        depth[y * w + x] = d - 0.2 * bulge;
                    }
                }
            }
        }
    }
    // Normals via central differences on the depth field.
    let mut normal = vec![0.0f32; h * w * 3];
    for y in 0..h {
        for x in 0..w {
            let xm = depth[y * w + x.saturating_sub(1)];
            let xp = depth[y * w + (x + 1).min(w - 1)];
            let ym = depth[y.saturating_sub(1) * w + x];
            let yp = depth[(y + 1).min(h - 1) * w + x];
            let gx = (xp - xm) * 0.5 * w as f32 / 4.0;
            let gy = (yp - ym) * 0.5 * h as f32 / 4.0;
            let inv = 1.0 / (gx * gx + gy * gy + 1.0).sqrt();
            let i = (y * w + x) * 3;
            normal[i] = -gx * inv;
            normal[i + 1] = -gy * inv;
            normal[i + 2] = inv;
        }
    }
    // RGB: class-correlated hue + depth shading + noise.
    let mut rgb = vec![0.0f32; h * w * 3];
    for p in 0..h * w {
        let cls = seg[p] as f32;
        let shade = 1.0 - 0.5 * depth[p];
        rgb[p * 3] = 0.3 * cls / preset.seg_classes as f32 + shade + rng.normal_f32(0.05);
        rgb[p * 3 + 1] =
            0.6 * (1.0 - cls / preset.seg_classes as f32) + shade + rng.normal_f32(0.05);
        rgb[p * 3 + 2] = 0.5 * shade + 0.2 * cls + rng.normal_f32(0.05);
    }
    DenseScene { rgb, seg, depth, normal }
}

/// Generate a batch of `b` scenes with the artifact layout.
pub fn generate_batch(preset: &DensePreset, b: usize, rng: &mut Rng) -> DenseBatch {
    let (h, w) = (preset.height, preset.width);
    let mut x = Tensor::zeros(&[b, h, w, 3]);
    let mut seg = Vec::with_capacity(b * h * w);
    let mut depth = Tensor::zeros(&[b, h, w, 1]);
    let mut normal = Tensor::zeros(&[b, h, w, 3]);
    for i in 0..b {
        let scene = generate_scene(preset, rng);
        x.data_mut()[i * h * w * 3..(i + 1) * h * w * 3].copy_from_slice(&scene.rgb);
        seg.extend_from_slice(&scene.seg);
        depth.data_mut()[i * h * w..(i + 1) * h * w].copy_from_slice(&scene.depth);
        normal.data_mut()[i * h * w * 3..(i + 1) * h * w * 3]
            .copy_from_slice(&scene.normal);
    }
    DenseBatch { x, seg, depth, normal }
}

/// Deterministic evaluation batch for a task seed.
pub fn eval_batch(preset: &DensePreset, b: usize, seed: u64) -> DenseBatch {
    let mut rng = Rng::new(seed ^ 0xDE45_EEE1);
    generate_batch(preset, b, &mut rng)
}

/// Frozen per-task head [1, 1, ch, out_ch].
pub fn dense_head(preset: &DensePreset, kind: DenseTaskKind, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed ^ 0x4EAD_0000 ^ kind.name().len() as u64);
    Tensor::randn(
        &[1, 1, preset.ch, kind.out_ch(preset)],
        (preset.ch as f32).powf(-0.5),
        &mut rng,
    )
}

#[cfg(test)]
mod tests {
    use super::super::DENSE;
    use super::*;

    #[test]
    fn scene_targets_consistent() {
        let mut rng = Rng::new(1);
        let s = generate_scene(&DENSE, &mut rng);
        let hw = DENSE.height * DENSE.width;
        assert_eq!(s.seg.len(), hw);
        assert_eq!(s.depth.len(), hw);
        assert_eq!(s.normal.len(), hw * 3);
        assert!(s.seg.iter().all(|&c| (0..DENSE.seg_classes as i32).contains(&c)));
        // normals are unit
        for p in 0..hw {
            let n = &s.normal[p * 3..p * 3 + 3];
            let norm: f32 = n.iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!((norm - 1.0).abs() < 1e-4);
        }
        // at least one foreground pixel
        assert!(s.seg.iter().any(|&c| c > 0));
    }

    #[test]
    fn batch_layout() {
        let mut rng = Rng::new(2);
        let b = generate_batch(&DENSE, 4, &mut rng);
        assert_eq!(b.x.shape(), &[4, 16, 16, 3]);
        assert_eq!(b.seg.len(), 4 * 256);
        assert_eq!(b.depth.shape(), &[4, 16, 16, 1]);
        assert_eq!(b.normal.shape(), &[4, 16, 16, 3]);
    }

    #[test]
    fn eval_batch_deterministic() {
        let a = eval_batch(&DENSE, 2, 7);
        let b = eval_batch(&DENSE, 2, 7);
        assert_eq!(a.x, b.x);
        assert_eq!(a.seg, b.seg);
    }

    #[test]
    fn heads_differ_per_task() {
        let hs = dense_head(&DENSE, DenseTaskKind::Seg, 0);
        let hd = dense_head(&DENSE, DenseTaskKind::Depth, 0);
        assert_eq!(hs.shape(), &[1, 1, 24, 6]);
        assert_eq!(hd.shape(), &[1, 1, 24, 1]);
    }
}
