//! # tvq-merge
//!
//! A production-grade reproduction of *Task Vector Quantization for
//! Memory-Efficient Model Merging* (cs.LG 2025) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! The paper's contribution — quantizing **task vectors** (the difference
//! between fine-tuned and pre-trained checkpoints) instead of full
//! checkpoints, plus **Residual Task Vector Quantization** (a shared base
//! vector + per-task low-bit offsets with error correction) — is implemented
//! natively in this crate ([`quant`]) together with every substrate it
//! needs: a tensor library ([`tensor`]), a checkpoint store
//! ([`checkpoint`]), the packed `QTVC` task-vector registry — quantized
//! payloads as the durable, lazily-loaded serving artifact ([`registry`]) —
//! a budget-aware pack planner that compiles sensitivity-driven
//! mixed-precision allocations — dense TVQ/RTVQ arms plus sparse DARE
//! drop-and-rescale and TALL-mask localization arms — into those
//! registries ([`planner`]),
//! eight merging algorithms ([`merge`]), synthetic task
//! suites ([`data`]), a PJRT runtime that executes the AOT-lowered JAX/
//! Pallas artifacts ([`runtime`]), fine-tuning drivers ([`train`]),
//! evaluation metrics ([`eval`]), a serving coordinator ([`coordinator`]),
//! an observability layer — lock-free histograms and span tracing ([`obs`]) —
//! and the experiment harness regenerating every table/figure of the paper
//! ([`exp`]).
//!
//! Python never runs on the request path: `make artifacts` AOT-lowers the
//! Layer-2 JAX models (which call the Layer-1 Pallas kernels) to HLO text
//! once; everything else is this crate.
//!
//! Longer-form documentation lives under `docs/`: `ARCHITECTURE.md` (the
//! build → plan → pack → serve pipeline mapped to modules),
//! `WIRE_FORMAT.md` (the normative `QTVC` on-disk spec, section kinds
//! 0–4), and `CLI.md` (every `tvq` subcommand with runnable examples).
//!
//! ## Quick tour
//!
//! ```no_run
//! use tvq::checkpoint::Checkpoint;
//! use tvq::quant::{Tvq, QuantScheme};
//! use tvq::merge::{Merger, TaskArithmetic};
//!
//! # fn main() -> anyhow::Result<()> {
//! let pre = Checkpoint::load("zoo/vit_s/pretrained.ckpt")?;
//! let ft = Checkpoint::load("zoo/vit_s/task00.ckpt")?;
//! // Task vector = fine-tuned - pre-trained; quantize it at 3 bits.
//! let tau = ft.sub(&pre)?;
//! let qtau = Tvq::quantize(&tau, 3)?;
//! println!("storage: {} bytes (fp32 would be {})",
//!          qtau.storage_bytes(), tau.numel() * 4);
//! let tau_hat = qtau.dequantize()?;
//! let merged = TaskArithmetic::new(0.3).merge(&pre, &[tau_hat])?;
//! # Ok(()) }
//! ```

pub mod checkpoint;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod exp;
pub mod merge;
pub mod obs;
pub mod planner;
pub mod quant;
pub mod registry;
pub mod runtime;
pub mod tensor;
pub mod train;
pub mod util;

pub use tensor::Tensor;
