//! Fine-tuning drivers: produce the checkpoint zoo the paper merges.
//!
//! Training runs the AOT train-step artifact in a loop from Rust — the
//! same HLO path the paper's authors would run under JAX, but with Python
//! long gone.  The zoo (pre-trained trunk + per-task fine-tuned
//! checkpoints + loss curves) is cached under `target/zoo/` keyed by
//! preset and suite size so experiments share it.

pub mod zoo;

pub use zoo::{DenseZoo, Zoo};

use anyhow::Result;

use crate::checkpoint::Checkpoint;
use crate::data::classify::ClassifyTask;
use crate::data::VitPreset;
use crate::runtime::{self, Artifact, Runtime, Value};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Hyper-parameters for one fine-tuning run.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    /// Size of the (deterministic) training pool sampled from.
    pub pool: usize,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self { steps: 200, lr: 0.5, pool: 4096, log_every: 50 }
    }
}

/// Random-init a ViT trunk from the artifact's parameter manifest, using
/// the same name-driven scheme as `python/compile/model.py::vit_init`
/// (gains -> 1, biases -> 0, pos -> N(0, 0.02), weights -> N(0, fan_in^-1/2)).
pub fn init_vit_checkpoint(art: &Artifact, rng: &mut Rng) -> Result<Checkpoint> {
    let params = art
        .manifest
        .params
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("artifact has no param manifest"))?;
    let mut ck = Checkpoint::new();
    for (name, shape) in params {
        let t = if name.ends_with("/g") {
            Tensor::full(shape, 1.0)
        } else if name.ends_with("/b") || name.ends_with("/bo") {
            Tensor::zeros(shape)
        } else if name == "pos" {
            Tensor::randn(shape, 0.02, rng)
        } else {
            let fan_in = if shape.len() >= 2 {
                shape[..shape.len() - 1].iter().product::<usize>()
            } else {
                shape[0]
            };
            Tensor::randn(shape, (fan_in as f32).powf(-0.5), rng)
        };
        ck.insert(name, t);
    }
    Ok(ck)
}

/// Fine-tune `init` on a classification task; returns (ckpt, loss curve).
pub fn finetune_classify(
    rt: &Runtime,
    preset: &VitPreset,
    init: &Checkpoint,
    task: &ClassifyTask,
    cfg: &TrainConfig,
) -> Result<(Checkpoint, Vec<f32>)> {
    let art = rt.load(&format!("{}_train_b{}", preset.name, preset.train_batch))?;
    let b = preset.train_batch;
    let (pool_x, pool_y) = task.train_pool(cfg.pool);
    let img = preset.tokens * preset.token_dim;
    let mut rng = Rng::new(task.seed ^ 0x7121_0001);
    let mut ck = init.clone();
    let mut losses = Vec::with_capacity(cfg.steps);
    let mut xbuf = Tensor::zeros(&[b, preset.tokens, preset.token_dim]);
    let mut ybuf = vec![0i32; b];
    for _step in 0..cfg.steps {
        // Sample a minibatch from the pool.
        for i in 0..b {
            let j = rng.below(cfg.pool);
            xbuf.data_mut()[i * img..(i + 1) * img]
                .copy_from_slice(&pool_x.data()[j * img..(j + 1) * img]);
            ybuf[i] = pool_y[j];
        }
        let y = Value::I32(vec![b], ybuf.clone());
        let (new_ck, loss) = runtime::train_step(&art, &ck, &task.head, &xbuf, &y, cfg.lr)?;
        ck = new_ck;
        losses.push(loss);
    }
    Ok((ck, losses))
}

/// Pre-train a trunk on the suite's generic task (the CLIP-pre-training
/// stand-in). Returns (ckpt, loss curve).
pub fn pretrain_classify(
    rt: &Runtime,
    preset: &VitPreset,
    task: &ClassifyTask,
    cfg: &TrainConfig,
    seed: u64,
) -> Result<(Checkpoint, Vec<f32>)> {
    let art = rt.load(&format!("{}_train_b{}", preset.name, preset.train_batch))?;
    let mut rng = Rng::new(seed);
    let init = init_vit_checkpoint(&art, &mut rng)?;
    finetune_classify(rt, preset, &init, task, cfg)
}

// ---------------------------------------------------------------------------
// Dense-prediction training
// ---------------------------------------------------------------------------

use crate::data::dense::{self, DenseTaskKind};
use crate::data::DensePreset;

/// Fine-tune the dense trunk on one task kind.
pub fn finetune_dense(
    rt: &Runtime,
    preset: &DensePreset,
    init: &Checkpoint,
    kind: DenseTaskKind,
    head: &Tensor,
    cfg: &TrainConfig,
    seed: u64,
) -> Result<(Checkpoint, Vec<f32>)> {
    let art = rt.load(&format!("dense_train_{}_b{}", kind.name(), preset.batch))?;
    let mut rng = Rng::new(seed ^ 0xD3A5_0001);
    let mut ck = init.clone();
    let mut losses = Vec::with_capacity(cfg.steps);
    for _ in 0..cfg.steps {
        let batch = dense::generate_batch(preset, preset.batch, &mut rng);
        let y = match kind {
            DenseTaskKind::Seg => Value::I32(
                vec![preset.batch, preset.height, preset.width],
                batch.seg.clone(),
            ),
            DenseTaskKind::Depth => Value::from_tensor(&batch.depth),
            DenseTaskKind::Normal => Value::from_tensor(&batch.normal),
        };
        let (new_ck, loss) = runtime::train_step(&art, &ck, head, &batch.x, &y, cfg.lr)?;
        ck = new_ck;
        losses.push(loss);
    }
    Ok((ck, losses))
}

/// Random-init the dense trunk from its artifact manifest.
pub fn init_dense_checkpoint(art: &Artifact, rng: &mut Rng) -> Result<Checkpoint> {
    let params = art
        .manifest
        .params
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("artifact has no param manifest"))?;
    let mut ck = Checkpoint::new();
    for (name, shape) in params {
        let t = if name.ends_with("/b") {
            Tensor::zeros(shape)
        } else {
            // conv kernels [kh, kw, cin, cout]
            let fan_in: usize = shape[..shape.len() - 1].iter().product();
            Tensor::randn(shape, (fan_in as f32).powf(-0.5), rng)
        };
        ck.insert(name, t);
    }
    Ok(ck)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn train_config_default_sane() {
        let c = TrainConfig::default();
        assert!(c.steps > 0 && c.lr > 0.0 && c.pool >= 32);
    }
}
