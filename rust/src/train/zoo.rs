//! Checkpoint-zoo construction and caching.
//!
//! A [`Zoo`] is the full input of every merging experiment: the
//! pre-trained trunk, the task suite, and one fine-tuned checkpoint per
//! task.  Building one takes a few minutes of PJRT training, so zoos are
//! cached under `target/zoo/<preset>_t<n>/` and shared by every bench and
//! example.  Cached files are CRC-checked; corrupt entries rebuild.

use anyhow::Result;

use super::{finetune_classify, finetune_dense, init_dense_checkpoint, pretrain_classify,
            TrainConfig};
use crate::checkpoint::{Checkpoint, CheckpointStore};
use crate::data::classify::TaskSuite;
use crate::data::dense::{self, DenseTaskKind};
use crate::data::{DensePreset, VitPreset, DENSE};
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// A classification checkpoint zoo.
pub struct Zoo {
    pub preset: &'static VitPreset,
    pub suite: TaskSuite,
    pub pre: Checkpoint,
    pub fts: Vec<Checkpoint>,
}

impl Zoo {
    /// Build (or load from cache) the zoo for `n_tasks` tasks.
    pub fn build_or_load(
        rt: &Runtime,
        preset: &'static VitPreset,
        n_tasks: usize,
        cfg: &TrainConfig,
    ) -> Result<Zoo> {
        let suite = TaskSuite::new(preset, n_tasks, 1000);
        let store = CheckpointStore::new(
            crate::util::zoo_dir().join(format!("{}_t{}", preset.name, n_tasks)),
        );
        // Pre-train long and hard (the CLIP-scale ancestor), fine-tune
        // short and gently — this reproduces the paper's Fig. 3 statistics
        // (task-vector range an order of magnitude below the checkpoint's).
        let pre_cfg = TrainConfig { steps: cfg.steps * 3, ..*cfg };
        let ft_cfg = TrainConfig { lr: cfg.lr * 0.2, ..*cfg };
        let pre = store.load_or_build("pretrained", || {
            eprintln!("[zoo] pre-training {} trunk...", preset.name);
            let (ck, losses) =
                pretrain_classify(rt, preset, &suite.pretrain_task(), &pre_cfg, 0x9E3)?;
            eprintln!(
                "[zoo] pretrain loss {:.3} -> {:.3}",
                losses.first().unwrap_or(&f32::NAN),
                losses.last().unwrap_or(&f32::NAN)
            );
            Ok(ck)
        })?;
        let mut fts = Vec::with_capacity(n_tasks);
        for (i, task) in suite.tasks.iter().enumerate() {
            let ft = store.load_or_build(&format!("task{i:02}"), || {
                eprintln!("[zoo] fine-tuning task {i:02}...");
                let (ck, losses) = finetune_classify(rt, preset, &pre, task, &ft_cfg)?;
                eprintln!(
                    "[zoo] task{i:02} loss {:.3} -> {:.3}",
                    losses.first().unwrap_or(&f32::NAN),
                    losses.last().unwrap_or(&f32::NAN)
                );
                Ok(ck)
            })?;
            fts.push(ft);
        }
        Ok(Zoo { preset, suite, pre, fts })
    }

    /// Task vectors tau_t = theta_ft^t - theta_pre.
    pub fn task_vectors(&self) -> Result<Vec<Checkpoint>> {
        self.fts.iter().map(|ft| ft.sub(&self.pre)).collect()
    }

    pub fn n_tasks(&self) -> usize {
        self.fts.len()
    }
}

/// The dense-prediction zoo: shared conv trunk + 3 task checkpoints.
pub struct DenseZoo {
    pub preset: DensePreset,
    pub pre: Checkpoint,
    pub fts: Vec<(DenseTaskKind, Checkpoint)>,
    pub heads: Vec<(DenseTaskKind, Tensor)>,
}

impl DenseZoo {
    pub fn build_or_load(rt: &Runtime, cfg: &TrainConfig) -> Result<DenseZoo> {
        let preset = DENSE;
        let store = CheckpointStore::new(crate::util::zoo_dir().join("dense"));
        let heads: Vec<(DenseTaskKind, Tensor)> = DenseTaskKind::all()
            .into_iter()
            .map(|k| (k, dense::dense_head(&preset, k, 2000)))
            .collect();
        // Pre-train: multi-task warmup (each task a full phase) so the
        // fine-tuned models share a strong common ancestor, like ImageNet
        // init; fine-tuning then runs gently (lower lr), which reproduces
        // the paper's narrow-task-vector statistics on the dense trunk.
        let ft_cfg = TrainConfig { lr: cfg.lr * 0.2, ..*cfg };
        let pre = store.load_or_build("pretrained", || {
            eprintln!("[zoo] pre-training dense trunk...");
            let art = rt.load(&format!("dense_train_seg_b{}", preset.batch))?;
            let mut rng = Rng::new(0xDE58);
            let mut ck = init_dense_checkpoint(&art, &mut rng)?;
            for (k, head) in &heads {
                let (next, _) = finetune_dense(rt, &preset, &ck, *k, head, cfg, 77)?;
                ck = next;
            }
            Ok(ck)
        })?;
        let mut fts = Vec::new();
        for (k, head) in &heads {
            let ft = store.load_or_build(k.name(), || {
                eprintln!("[zoo] fine-tuning dense task {}...", k.name());
                let (ck, losses) =
                    finetune_dense(rt, &preset, &pre, *k, head, &ft_cfg, 100 + k.name().len() as u64)?;
                eprintln!(
                    "[zoo] dense {} loss {:.3} -> {:.3}",
                    k.name(),
                    losses.first().unwrap_or(&f32::NAN),
                    losses.last().unwrap_or(&f32::NAN)
                );
                Ok(ck)
            })?;
            fts.push((*k, ft));
        }
        Ok(DenseZoo { preset, pre, fts, heads })
    }

    pub fn task_vectors(&self) -> Result<Vec<Checkpoint>> {
        self.fts.iter().map(|(_, ft)| ft.sub(&self.pre)).collect()
    }

    pub fn head(&self, kind: DenseTaskKind) -> &Tensor {
        &self.heads.iter().find(|(k, _)| *k == kind).unwrap().1
    }
}
