//! Residual Task Vector Quantization (paper Section 4.3, Algorithm 1).
//!
//! RTVQ decomposes each task vector into a shared **base vector**
//! (theta_ft_avg - theta_pre, quantized once at `base_bits`) and per-task
//! **offset vectors** (theta_ft^t - theta_ft_avg, quantized at
//! `offset_bits`).  Because the base is shared, the effective bits/task is
//! `b_o + b_b / T` (2.375 for B3O2 @ 8 tasks).
//!
//! **Error correction** (Eq. 6): the offsets are computed against the
//! *quantized* base reconstruction theta_ft_avg_ec = Q(base) + theta_pre,
//! so the base's quantization error is folded into what the offsets see
//! and partially cancelled — Fig. 10's ablation toggles this.

use anyhow::{bail, Result};

use super::tvq::QuantizedCheckpoint;
use crate::checkpoint::Checkpoint;
use crate::util::exec::ExecCtx;
use crate::util::pool::Pool;

/// A quantized RTVQ bundle for a suite of tasks.
#[derive(Clone, Debug)]
pub struct Rtvq {
    pub base_bits: u8,
    pub offset_bits: u8,
    pub error_correction: bool,
    /// Q(theta_ft_avg - theta_pre, base_bits) — stored once.
    pub base: QuantizedCheckpoint,
    /// Q(theta_ft^t - ref, offset_bits) per task.
    pub offsets: Vec<QuantizedCheckpoint>,
}

impl Rtvq {
    /// Quantize a task suite per Algorithm 1.
    ///
    /// `fts` are the fine-tuned checkpoints (NOT task vectors); the
    /// decomposition needs theta_ft_avg, which only the checkpoints give.
    ///
    /// The [`ExecCtx`] selects the pool the per-task offset quantization
    /// (Alg. 1 lines 4-5) fans out on.  Each offset is quantized
    /// independently against the same reference and collected in task
    /// order, so the bundle is bit-identical at every thread count — the
    /// registry build path rides on this.
    pub fn quantize(
        pre: &Checkpoint,
        fts: &[Checkpoint],
        base_bits: u8,
        offset_bits: u8,
        error_correction: bool,
        ctx: &ExecCtx,
    ) -> Result<Self> {
        let pool = ctx.pool();
        if fts.is_empty() {
            bail!("RTVQ needs at least one fine-tuned checkpoint");
        }
        // Alg.1 line 1: theta_ft_avg
        let refs: Vec<&Checkpoint> = fts.iter().collect();
        let ft_avg = Checkpoint::average(&refs)?;
        // line 2: base vector
        let base_vec = ft_avg.sub(pre)?;
        // line 3 (quantize base; optionally correct the reference)
        let base = QuantizedCheckpoint::quantize(&base_vec, base_bits)?;
        let reference = if error_correction {
            // theta_ft_avg_ec = Q(base) + theta_pre
            base.dequantize()?.add(pre)?
        } else {
            ft_avg
        };
        // line 4-5: per-task offsets
        let offsets = pool.try_map(fts.iter().collect(), |_, ft: &Checkpoint| {
            QuantizedCheckpoint::quantize(&ft.sub(&reference)?, offset_bits)
        })?;
        Ok(Self { base_bits, offset_bits, error_correction, base, offsets })
    }

    /// [`Rtvq::quantize`] on an explicit pool — the PR-5 twin, superseded
    /// by [`ExecCtx`].
    #[deprecated(note = "use Rtvq::quantize(..., &ExecCtx::with_pool(pool))")]
    pub fn quantize_with_pool(
        pre: &Checkpoint,
        fts: &[Checkpoint],
        base_bits: u8,
        offset_bits: u8,
        error_correction: bool,
        pool: &Pool,
    ) -> Result<Self> {
        let ctx = ExecCtx::with_pool(pool);
        Self::quantize(pre, fts, base_bits, offset_bits, error_correction, &ctx)
    }

    pub fn n_tasks(&self) -> usize {
        self.offsets.len()
    }

    /// Reconstruct task vector t: tau_hat_t = dq(offset_t) + dq(base)
    /// (Alg. 1 line 5).
    pub fn dequantize_task(&self, t: usize) -> Result<Checkpoint> {
        if t >= self.offsets.len() {
            bail!("task index {t} out of range ({} tasks)", self.offsets.len());
        }
        let base = self.base.dequantize()?;
        self.offsets[t].dequantize()?.add(&base)
    }

    /// Reconstruct every task vector.
    pub fn dequantize_all(&self) -> Result<Vec<Checkpoint>> {
        let base = self.base.dequantize()?;
        self.offsets
            .iter()
            .map(|off| off.dequantize()?.add(&base))
            .collect()
    }

    /// Total storage: one base + T offsets (exact bytes).
    pub fn storage_bytes(&self) -> usize {
        self.base.storage_bytes()
            + self.offsets.iter().map(|o| o.storage_bytes()).sum::<usize>()
    }

    /// Effective bits per task: b_o + b_b / T.
    pub fn effective_bits(&self) -> f64 {
        self.offset_bits as f64 + self.base_bits as f64 / self.n_tasks() as f64
    }

    /// Sum over tasks of ||tau_t - tau_hat_t||_2 (Fig. 4 metric).
    pub fn total_quant_error(&self, pre: &Checkpoint, fts: &[Checkpoint]) -> Result<f64> {
        if fts.len() != self.n_tasks() {
            bail!("task count mismatch");
        }
        let mut acc = 0.0;
        for (t, ft) in fts.iter().enumerate() {
            let tau = ft.sub(pre)?;
            let tau_hat = self.dequantize_task(t)?;
            acc += tau.l2_dist(&tau_hat)?;
        }
        Ok(acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    /// Build a synthetic suite: shared pre-trained + tasks that are all
    /// near a common fine-tuned mode (the regime RTVQ exploits).
    fn suite(n_tasks: usize, seed: u64) -> (Checkpoint, Vec<Checkpoint>) {
        let mut rng = Rng::new(seed);
        let mut pre = Checkpoint::new();
        pre.insert("w", Tensor::randn(&[64, 32], 0.3, &mut rng));
        pre.insert("b", Tensor::randn(&[32], 0.1, &mut rng));
        // Common drift (base) + small per-task offsets.
        let mut drift = Checkpoint::new();
        drift.insert("w", Tensor::randn(&[64, 32], 0.02, &mut rng));
        drift.insert("b", Tensor::randn(&[32], 0.02, &mut rng));
        let fts = (0..n_tasks)
            .map(|_| {
                let mut off = Checkpoint::new();
                off.insert("w", Tensor::randn(&[64, 32], 0.005, &mut rng));
                off.insert("b", Tensor::randn(&[32], 0.005, &mut rng));
                pre.add(&drift).unwrap().add(&off).unwrap()
            })
            .collect();
        (pre, fts)
    }

    #[test]
    fn effective_bits_and_counts() {
        let (pre, fts) = suite(8, 1);
        let r = Rtvq::quantize(&pre, &fts, 3, 2, true, &ExecCtx::sequential()).unwrap();
        assert_eq!(r.n_tasks(), 8);
        assert!((r.effective_bits() - 2.375).abs() < 1e-9);
    }

    #[test]
    fn rtvq_beats_low_bit_tvq_on_error() {
        // Paper Eq. 5 / Fig. 4: at ~equal bits, RTVQ error < TVQ error.
        let (pre, fts) = suite(8, 2);
        let rtvq = Rtvq::quantize(&pre, &fts, 3, 2, true, &ExecCtx::sequential()).unwrap();
        let rtvq_err = rtvq.total_quant_error(&pre, &fts).unwrap();

        let mut tvq_err = 0.0;
        for ft in &fts {
            let tau = ft.sub(&pre).unwrap();
            let q = QuantizedCheckpoint::quantize(&tau, 2).unwrap();
            tvq_err += q.quant_error(&tau).unwrap();
        }
        assert!(
            rtvq_err < tvq_err,
            "rtvq_err={rtvq_err} should beat 2-bit tvq_err={tvq_err}"
        );
    }

    #[test]
    fn error_correction_reduces_error() {
        // Fig. 10: with-EC error <= without-EC error.
        let (pre, fts) = suite(8, 3);
        for (bb, bo) in [(2u8, 2u8), (3, 2), (4, 3)] {
            let with_ec = Rtvq::quantize(&pre, &fts, bb, bo, true, &ExecCtx::sequential())
                .unwrap()
                .total_quant_error(&pre, &fts)
                .unwrap();
            let without = Rtvq::quantize(&pre, &fts, bb, bo, false, &ExecCtx::sequential())
                .unwrap()
                .total_quant_error(&pre, &fts)
                .unwrap();
            assert!(
                with_ec <= without * 1.02,
                "bb={bb} bo={bo}: ec={with_ec} > no-ec={without}"
            );
        }
    }

    #[test]
    fn storage_amortizes_base() {
        let (pre, fts) = suite(8, 4);
        let r = Rtvq::quantize(&pre, &fts, 3, 2, true, &ExecCtx::sequential()).unwrap();
        // Per-task cost should be well below a 3-bit TVQ per task.
        let tvq3: usize = fts
            .iter()
            .map(|ft| {
                let tau = ft.sub(&pre).unwrap();
                QuantizedCheckpoint::quantize(&tau, 3).unwrap().storage_bytes()
            })
            .sum();
        assert!(r.storage_bytes() < tvq3, "{} vs {}", r.storage_bytes(), tvq3);
    }

    #[test]
    fn dequantize_task_bounds_checked() {
        let (pre, fts) = suite(2, 5);
        let r = Rtvq::quantize(&pre, &fts, 3, 2, true, &ExecCtx::sequential()).unwrap();
        assert!(r.dequantize_task(1).is_ok());
        assert!(r.dequantize_task(2).is_err());
    }

    #[test]
    fn reconstruction_close_to_original_tau() {
        let (pre, fts) = suite(4, 6);
        let r = Rtvq::quantize(&pre, &fts, 8, 8, true, &ExecCtx::sequential()).unwrap();
        for (t, ft) in fts.iter().enumerate() {
            let tau = ft.sub(&pre).unwrap();
            let tau_hat = r.dequantize_task(t).unwrap();
            let rel = tau.l2_dist(&tau_hat).unwrap() / tau.l2_norm_ck();
            assert!(rel < 0.02, "task {t}: rel err {rel}");
        }
    }

    impl Checkpoint {
        fn l2_norm_ck(&self) -> f64 {
            let mut acc = 0.0;
            for (_, t) in self.iter() {
                let n = t.l2_norm();
                acc += n * n;
            }
            acc.sqrt()
        }
    }
}
