//! Per-group quantization of flat parameter vectors.
//!
//! This is the layout consumed by the AOT Pallas artifacts
//! (`quantize_*` / `dequant_merge_*` / `*_merged_forward_*`): a checkpoint
//! is flattened in manifest order, zero-padded to a multiple of the kernel
//! block size, and quantized with one (scale, zp) per `group` elements —
//! the BlockSpec granularity of the Layer-1 kernel.  Mirrors
//! `ref.group_quant_params_ref` exactly.

use anyhow::{bail, Result};

use super::affine::AffineParams;
use super::bitpack::{BitPacked, BitPackedView};

/// A flat vector quantized in fixed-size groups.
#[derive(Clone, Debug, PartialEq)]
pub struct GroupQuantized {
    pub bits: u8,
    pub group: usize,
    pub scales: Vec<f32>,
    pub zps: Vec<f32>,
    pub codes: BitPacked,
}

impl GroupQuantized {
    /// Quantize `data` (length divisible by `group`) at `bits`.
    pub fn quantize(data: &[f32], bits: u8, group: usize) -> Result<Self> {
        if group == 0 || data.len() % group != 0 {
            bail!(
                "data length {} not divisible by group {}",
                data.len(),
                group
            );
        }
        let g = data.len() / group;
        let mut scales = Vec::with_capacity(g);
        let mut zps = Vec::with_capacity(g);
        let mut codes = Vec::with_capacity(data.len());
        for chunk in data.chunks_exact(group) {
            let p = AffineParams::from_slice(chunk, bits)?;
            scales.push(p.scale);
            zps.push(p.zp);
            p.quantize_extend(chunk, &mut codes);
        }
        Ok(Self {
            bits,
            group,
            scales,
            zps,
            codes: BitPacked::pack(&codes, bits)?,
        })
    }

    /// Quantize `data` after zero-padding it up to the next multiple of
    /// `group` — the shared entry point for callers whose data is not
    /// already group-aligned (the sensitivity probe pads per plan-tensor
    /// geometry, the granularity ablation pads ad hoc; both must produce
    /// byte-identical payloads for the planner's cost model to hold).
    pub fn quantize_padded(data: &[f32], bits: u8, group: usize) -> Result<Self> {
        if group == 0 {
            bail!("group width must be >= 1");
        }
        let padded = data.len().div_ceil(group) * group;
        if padded == data.len() {
            return Self::quantize(data, bits, group);
        }
        let mut v = data.to_vec();
        v.resize(padded, 0.0);
        Self::quantize(&v, bits, group)
    }

    /// Sum of squared reconstruction error against the first `data.len()`
    /// elements (any zero-padding tail beyond the source is ignored).
    pub fn sse_against(&self, data: &[f32]) -> f64 {
        assert!(data.len() <= self.len(), "source longer than quantized vector");
        let dq = self.dequantize();
        crate::util::stats::sse(data, &dq[..data.len()])
    }

    pub fn len(&self) -> usize {
        self.codes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }

    pub fn n_groups(&self) -> usize {
        self.scales.len()
    }

    /// Dequantize to a fresh vector.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len()];
        self.dequantize_into(&mut out);
        out
    }

    /// Dequantize into a caller buffer (hot path, no allocation).
    pub fn dequantize_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len());
        let mut codes = vec![0u32; self.len()];
        self.codes.unpack_into(&mut codes);
        for (gi, chunk) in codes.chunks_exact(self.group).enumerate() {
            let scale = self.scales[gi];
            let zp = self.zps[gi];
            let base = gi * self.group;
            for (j, &c) in chunk.iter().enumerate() {
                out[base + j] = scale * (c as f32 - zp);
            }
        }
    }

    /// Codes as f32 (the representation the HLO artifacts take as input).
    pub fn codes_f32(&self) -> Vec<f32> {
        self.codes.iter().map(|c| c as f32).collect()
    }

    /// Exact storage bytes: packed codes + per-group scale/zp.
    pub fn storage_bytes(&self) -> usize {
        self.codes.storage_bytes() + self.n_groups() * 8
    }
}

/// A borrowed, zero-copy view over a group-quantized vector in its wire
/// layout: per-group affine params as raw little-endian f32 bytes plus a
/// [`BitPackedView`] over the packed codes.  The registry's mmap serving
/// path dequantizes straight out of this — scales/zps are decoded two
/// `f32::from_le_bytes` per group (the section body carries no alignment
/// guarantee, so the params cannot be reinterpreted as an `&[f32]`).
#[derive(Clone, Copy, Debug)]
pub struct GroupQuantizedView<'a> {
    bits: u8,
    group: usize,
    n_groups: usize,
    /// `scales` then `zps`, 4 LE bytes per group each (`8 * n_groups` total).
    params: &'a [u8],
    codes: BitPackedView<'a>,
}

impl<'a> GroupQuantizedView<'a> {
    /// Assemble from wire parts; `params` holds the scales then the zps
    /// (4 bytes per group each) and `codes` must cover exactly
    /// `group * n_groups` codes at `bits`.
    pub fn new(
        bits: u8,
        group: usize,
        n_groups: usize,
        params: &'a [u8],
        codes: BitPackedView<'a>,
    ) -> Result<Self> {
        if !(1..=8).contains(&bits) {
            bail!("QTVC group payload: invalid bit width {bits}");
        }
        if group == 0 {
            bail!("QTVC group payload: zero group size");
        }
        if params.len() != n_groups.checked_mul(8).ok_or_else(|| {
            anyhow::anyhow!("QTVC group payload: n_groups {n_groups} overflows")
        })? {
            bail!(
                "QTVC group payload: {} param bytes for {n_groups} groups (want {})",
                params.len(),
                n_groups * 8
            );
        }
        let len = group
            .checked_mul(n_groups)
            .ok_or_else(|| anyhow::anyhow!("QTVC group payload: group*n_groups overflows"))?;
        if codes.bits() != bits || codes.len() != len {
            bail!(
                "QTVC group payload: code stream is {} codes at {} bits, want {len} at {bits}",
                codes.len(),
                codes.bits()
            );
        }
        Ok(Self { bits, group, n_groups, params, codes })
    }

    #[inline]
    pub fn bits(&self) -> u8 {
        self.bits
    }

    #[inline]
    pub fn group(&self) -> usize {
        self.group
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.group * self.n_groups
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n_groups == 0
    }

    #[inline]
    pub fn n_groups(&self) -> usize {
        self.n_groups
    }

    #[inline]
    pub fn scale(&self, gi: usize) -> f32 {
        f32::from_le_bytes(self.params[gi * 4..gi * 4 + 4].try_into().unwrap())
    }

    #[inline]
    pub fn zp(&self, gi: usize) -> f32 {
        let base = self.n_groups * 4 + gi * 4;
        f32::from_le_bytes(self.params[base..base + 4].try_into().unwrap())
    }

    /// `out[i] += lam * dq(self)[i]` — the fused serve-path accumulate,
    /// decoding codes and params straight from the borrowed bytes.
    /// `codes_scratch` is reused across calls (resized, never shrunk).
    pub fn axpy_into(
        &self,
        lam: f32,
        out: &mut [f32],
        codes_scratch: &mut Vec<u32>,
    ) -> Result<()> {
        if out.len() != self.len() {
            bail!("flat length mismatch: {} vs {}", self.len(), out.len());
        }
        self.axpy_groups_into(lam, 0, out, codes_scratch)
    }

    /// Sharded accumulate: `out[i] += lam * dq(self)[g0 * group + i]`
    /// over the groups `[g0, g0 + out.len() / group)`, on the
    /// process-wide active kernel.
    pub fn axpy_groups_into(
        &self,
        lam: f32,
        g0: usize,
        out: &mut [f32],
        codes_scratch: &mut Vec<u32>,
    ) -> Result<()> {
        self.axpy_groups_into_k(super::simd::active(), lam, g0, out, codes_scratch)
    }

    /// [`axpy_groups_into`](Self::axpy_groups_into) over an explicit
    /// kernel.  `out` must be a whole number of groups that fits inside
    /// the payload.  The per-element arithmetic is the same
    /// `a * code + b` the full [`axpy_into`](Self::axpy_into) runs
    /// (which delegates here) — and every SIMD kernel replays that op
    /// sequence per lane — so a set of disjoint shards reproduces the
    /// full pass bit-for-bit on any kernel: the parallel fused-merge
    /// invariant, extended to "any thread count × any kernel".
    pub fn axpy_groups_into_k(
        &self,
        kernel: super::simd::Kernel,
        lam: f32,
        g0: usize,
        out: &mut [f32],
        codes_scratch: &mut Vec<u32>,
    ) -> Result<()> {
        if out.len() % self.group != 0 || g0 + out.len() / self.group > self.n_groups {
            bail!(
                "group shard [{g0}, +{} elems) does not tile the {} groups of {} elements",
                out.len(),
                self.n_groups,
                self.group
            );
        }
        codes_scratch.resize(out.len(), 0);
        self.codes.unpack_range_into_k(kernel, g0 * self.group, codes_scratch);
        for (li, chunk) in codes_scratch.chunks_exact(self.group).enumerate() {
            let gi = g0 + li;
            let a = lam * self.scale(gi);
            let b = -a * self.zp(gi);
            let base = li * self.group;
            super::simd::axpy_affine(kernel, a, b, chunk, &mut out[base..base + self.group]);
        }
        Ok(())
    }

    /// Dequantize into a caller buffer (overwrites all of `out`).
    /// Bit-identical to [`GroupQuantized::dequantize_into`] — both compute
    /// `scale * (code - zp)` — so a view-served reconstruction equals the
    /// owned one exactly, not approximately.
    pub fn dequantize_into(&self, out: &mut [f32], codes_scratch: &mut Vec<u32>) {
        assert_eq!(out.len(), self.len());
        self.dequantize_groups_into(0, out, codes_scratch);
    }

    /// [`dequantize_into`](Self::dequantize_into) over an explicit
    /// kernel (the serve paths thread
    /// [`ExecCtx::kernel`](crate::util::exec::ExecCtx::kernel) here).
    pub fn dequantize_into_k(
        &self,
        kernel: super::simd::Kernel,
        out: &mut [f32],
        codes_scratch: &mut Vec<u32>,
    ) {
        assert_eq!(out.len(), self.len());
        self.dequantize_groups_into_k(kernel, 0, out, codes_scratch);
    }

    /// Sharded dequantize: overwrite `out` with the decoded values of
    /// groups `[g0, g0 + out.len() / group)`, on the process-wide
    /// active kernel.
    pub fn dequantize_groups_into(
        &self,
        g0: usize,
        out: &mut [f32],
        codes_scratch: &mut Vec<u32>,
    ) {
        self.dequantize_groups_into_k(super::simd::active(), g0, out, codes_scratch);
    }

    /// [`dequantize_groups_into`](Self::dequantize_groups_into) over an
    /// explicit kernel.  Same per-element `scale * (code - zp)` as the
    /// full decode (which delegates here) on every kernel, so sharded
    /// readers are bit-exact.
    pub fn dequantize_groups_into_k(
        &self,
        kernel: super::simd::Kernel,
        g0: usize,
        out: &mut [f32],
        codes_scratch: &mut Vec<u32>,
    ) {
        assert!(
            out.len() % self.group == 0 && g0 + out.len() / self.group <= self.n_groups,
            "group shard [{g0}, +{} elems) does not tile {} groups of {}",
            out.len(),
            self.n_groups,
            self.group
        );
        codes_scratch.resize(out.len(), 0);
        self.codes.unpack_range_into_k(kernel, g0 * self.group, codes_scratch);
        for (li, chunk) in codes_scratch.chunks_exact(self.group).enumerate() {
            let gi = g0 + li;
            let scale = self.scale(gi);
            let zp = self.zp(gi);
            let base = li * self.group;
            super::simd::dequant_affine(kernel, scale, zp, chunk, &mut out[base..base + self.group]);
        }
    }

    /// Materialize an owned [`GroupQuantized`] (decodes params + codes).
    pub fn to_owned(self) -> GroupQuantized {
        GroupQuantized {
            bits: self.bits,
            group: self.group,
            scales: (0..self.n_groups).map(|g| self.scale(g)).collect(),
            zps: (0..self.n_groups).map(|g| self.zp(g)).collect(),
            codes: self.codes.to_owned(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};
    use crate::util::rng::Rng;

    #[test]
    fn rejects_bad_geometry() {
        assert!(GroupQuantized::quantize(&[0.0; 10], 4, 3).is_err());
        assert!(GroupQuantized::quantize(&[0.0; 10], 4, 0).is_err());
        assert!(GroupQuantized::quantize(&[0.0; 12], 4, 3).is_ok());
    }

    #[test]
    fn per_group_error_bound_holds() {
        check(
            Config { cases: 60, seed: 0x619 },
            |rng| {
                let groups = 1 + rng.below(6);
                let group = 8 * (1 + rng.below(16));
                let bits = 2 + rng.below(7) as u8;
                let mut v = vec![0.0f32; groups * group];
                rng.fill_normal(&mut v, 0.05);
                (v, bits, group)
            },
            |(v, bits, group)| {
                let q = GroupQuantized::quantize(v, *bits, *group)
                    .map_err(|e| e.to_string())?;
                let deq = q.dequantize();
                for (gi, chunk) in v.chunks_exact(*group).enumerate() {
                    let bound = q.scales[gi] / 2.0 * 1.001 + 1e-7;
                    for (j, &x) in chunk.iter().enumerate() {
                        let err = (x - deq[gi * group + j]).abs();
                        if err > bound {
                            return Err(format!("group {gi} err {err} > {bound}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn groupwise_beats_per_tensor_on_heterogeneous_data() {
        // Groups adapt to local ranges; a tensor with one wide region
        // should quantize better group-wise.
        let mut rng = Rng::new(4);
        let mut v = vec![0.0f32; 4096];
        rng.fill_normal(&mut v[..2048], 0.01);
        rng.fill_normal(&mut v[2048..], 1.0);
        let gq = GroupQuantized::quantize(&v, 3, 1024).unwrap();
        let pt = GroupQuantized::quantize(&v, 3, 4096).unwrap();
        let err_g: f64 = v
            .iter()
            .zip(gq.dequantize())
            .map(|(&x, y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let err_p: f64 = v
            .iter()
            .zip(pt.dequantize())
            .map(|(&x, y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(err_g < err_p, "group {err_g} vs per-tensor {err_p}");
    }

    #[test]
    fn quantize_padded_pins_manual_padding() {
        // The sensitivity probe (manual pad to plan geometry) and the
        // granularity ablation (quantize_padded) must produce the exact
        // same payload — the planner's byte/error model rides on it.
        let mut rng = Rng::new(6);
        for (len, bits, group) in [(100usize, 3u8, 64usize), (512, 2, 512), (7, 4, 16)] {
            let mut v = vec![0.0f32; len];
            rng.fill_normal(&mut v, 0.05);
            let mut manual = v.clone();
            manual.resize(len.div_ceil(group) * group, 0.0);
            let a = GroupQuantized::quantize(&manual, bits, group).unwrap();
            let b = GroupQuantized::quantize_padded(&v, bits, group).unwrap();
            assert_eq!(a, b, "len={len} bits={bits} group={group}");
            // And the shared error helper matches the manual SSE.
            let dq = a.dequantize();
            let want: f64 = v
                .iter()
                .zip(&dq)
                .map(|(&x, &y)| ((x - y) as f64).powi(2))
                .sum();
            assert!((a.sse_against(&v) - want).abs() < 1e-12);
        }
        assert!(GroupQuantized::quantize_padded(&[0.0; 4], 3, 0).is_err());
    }

    /// Wire parts for a view over `g`: (params bytes, packed code bytes).
    fn wire_parts(g: &GroupQuantized) -> (Vec<u8>, Vec<u8>) {
        let mut params = Vec::new();
        for &s in &g.scales {
            params.extend_from_slice(&s.to_le_bytes());
        }
        for &z in &g.zps {
            params.extend_from_slice(&z.to_le_bytes());
        }
        (params, g.codes.packed_bytes())
    }

    #[test]
    fn view_matches_owned_bit_exactly() {
        let mut rng = Rng::new(17);
        for (len, bits, group) in [(4096usize, 3u8, 512usize), (1024, 2, 256), (640, 8, 64)] {
            let mut v = vec![0.0f32; len];
            rng.fill_normal(&mut v, 0.05);
            let g = GroupQuantized::quantize(&v, bits, group).unwrap();
            let (params, code_bytes) = wire_parts(&g);
            let codes = BitPackedView::new(bits, len, &code_bytes).unwrap();
            let view =
                GroupQuantizedView::new(bits, group, g.n_groups(), &params, codes).unwrap();
            assert_eq!(view.len(), g.len());
            assert_eq!(view.n_groups(), g.n_groups());
            for gi in 0..g.n_groups() {
                assert_eq!(view.scale(gi), g.scales[gi]);
                assert_eq!(view.zp(gi), g.zps[gi]);
            }
            // Dequantization is bit-identical, not approximately equal.
            let mut scratch = Vec::new();
            let mut got = vec![0.0f32; len];
            view.dequantize_into(&mut got, &mut scratch);
            assert_eq!(got, g.dequantize(), "bits={bits} group={group}");
            // The axpy accumulate agrees with the owned fused loop.
            let mut acc = vec![1.0f32; len];
            view.axpy_into(0.25, &mut acc, &mut scratch).unwrap();
            let dq = g.dequantize();
            for i in 0..len {
                assert!((acc[i] - (1.0 + 0.25 * dq[i])).abs() < 1e-6);
            }
            // Owned materialization round-trips the whole struct.
            assert_eq!(view.to_owned(), g);
        }
    }

    #[test]
    fn group_range_decode_matches_full_decode_bit_exactly() {
        let mut rng = Rng::new(23);
        for (len, bits, group) in [(4096usize, 3u8, 512usize), (1024, 5, 128), (640, 2, 64)] {
            let mut v = vec![0.0f32; len];
            rng.fill_normal(&mut v, 0.05);
            let g = GroupQuantized::quantize(&v, bits, group).unwrap();
            let (params, code_bytes) = wire_parts(&g);
            let codes = BitPackedView::new(bits, len, &code_bytes).unwrap();
            let view =
                GroupQuantizedView::new(bits, group, g.n_groups(), &params, codes).unwrap();
            let mut scratch = Vec::new();

            // Full reference via the owned decoder.
            let full = g.dequantize();
            let mut want_acc = vec![1.5f32; len];
            view.axpy_into(0.75, &mut want_acc, &mut scratch).unwrap();

            // Stitch the full buffers back together from disjoint group
            // shards; every split must reproduce them bit-for-bit.
            for n_shards in [1usize, 2, 3, g.n_groups()] {
                let per = g.n_groups().div_ceil(n_shards);
                let mut deq = vec![0.0f32; len];
                let mut acc = vec![1.5f32; len];
                let mut g0 = 0;
                while g0 < g.n_groups() {
                    let gn = per.min(g.n_groups() - g0);
                    let lo = g0 * group;
                    let hi = lo + gn * group;
                    view.dequantize_groups_into(g0, &mut deq[lo..hi], &mut scratch);
                    view.axpy_groups_into(0.75, g0, &mut acc[lo..hi], &mut scratch)
                        .unwrap();
                    g0 += gn;
                }
                assert_eq!(deq, full, "{n_shards} shards: dequantize diverged");
                assert_eq!(acc, want_acc, "{n_shards} shards: axpy diverged");
            }

            // Misaligned / out-of-range shards fail closed.
            let mut bad = vec![0.0f32; group + 1];
            assert!(view.axpy_groups_into(1.0, 0, &mut bad, &mut scratch).is_err());
            let mut last = vec![0.0f32; group];
            assert!(view
                .axpy_groups_into(1.0, g.n_groups(), &mut last, &mut scratch)
                .is_err());
        }
    }

    #[test]
    fn view_rejects_inconsistent_geometry() {
        let mut rng = Rng::new(18);
        let mut v = vec![0.0f32; 512];
        rng.fill_normal(&mut v, 0.05);
        let g = GroupQuantized::quantize(&v, 4, 128).unwrap();
        let (params, code_bytes) = wire_parts(&g);
        let codes = BitPackedView::new(4, 512, &code_bytes).unwrap();
        // Bad bit width / zero group / params-vs-group-count mismatch /
        // code-count mismatch all fail closed.
        assert!(GroupQuantizedView::new(0, 128, 4, &params, codes).is_err());
        assert!(GroupQuantizedView::new(4, 0, 4, &params, codes).is_err());
        assert!(GroupQuantizedView::new(4, 128, 3, &params, codes).is_err());
        assert!(GroupQuantizedView::new(4, 128, 4, &params[..24], codes).is_err());
        assert!(GroupQuantizedView::new(4, 256, 4, &params, codes).is_err());
        let mismatched = BitPackedView::new(2, 512, &code_bytes[..128]).unwrap();
        assert!(GroupQuantizedView::new(4, 128, 4, &params, mismatched).is_err());
        // A length mismatch in axpy is an error, not a panic.
        let ok = GroupQuantizedView::new(4, 128, 4, &params, codes).unwrap();
        let mut short = vec![0.0f32; 100];
        assert!(ok.axpy_into(1.0, &mut short, &mut Vec::new()).is_err());
    }

    #[test]
    fn codes_f32_are_integral() {
        let mut rng = Rng::new(5);
        let mut v = vec![0.0f32; 2048];
        rng.fill_normal(&mut v, 0.1);
        let q = GroupQuantized::quantize(&v, 3, 1024).unwrap();
        for c in q.codes_f32() {
            assert_eq!(c.fract(), 0.0);
            assert!((0.0..=7.0).contains(&c));
        }
    }
}
