//! Per-group quantization of flat parameter vectors.
//!
//! This is the layout consumed by the AOT Pallas artifacts
//! (`quantize_*` / `dequant_merge_*` / `*_merged_forward_*`): a checkpoint
//! is flattened in manifest order, zero-padded to a multiple of the kernel
//! block size, and quantized with one (scale, zp) per `group` elements —
//! the BlockSpec granularity of the Layer-1 kernel.  Mirrors
//! `ref.group_quant_params_ref` exactly.

use anyhow::{bail, Result};

use super::affine::AffineParams;
use super::bitpack::BitPacked;

/// A flat vector quantized in fixed-size groups.
#[derive(Clone, Debug, PartialEq)]
pub struct GroupQuantized {
    pub bits: u8,
    pub group: usize,
    pub scales: Vec<f32>,
    pub zps: Vec<f32>,
    pub codes: BitPacked,
}

impl GroupQuantized {
    /// Quantize `data` (length divisible by `group`) at `bits`.
    pub fn quantize(data: &[f32], bits: u8, group: usize) -> Result<Self> {
        if group == 0 || data.len() % group != 0 {
            bail!(
                "data length {} not divisible by group {}",
                data.len(),
                group
            );
        }
        let g = data.len() / group;
        let mut scales = Vec::with_capacity(g);
        let mut zps = Vec::with_capacity(g);
        let mut codes = Vec::with_capacity(data.len());
        for chunk in data.chunks_exact(group) {
            let p = AffineParams::from_slice(chunk, bits)?;
            scales.push(p.scale);
            zps.push(p.zp);
            p.quantize_extend(chunk, &mut codes);
        }
        Ok(Self {
            bits,
            group,
            scales,
            zps,
            codes: BitPacked::pack(&codes, bits)?,
        })
    }

    /// Quantize `data` after zero-padding it up to the next multiple of
    /// `group` — the shared entry point for callers whose data is not
    /// already group-aligned (the sensitivity probe pads per plan-tensor
    /// geometry, the granularity ablation pads ad hoc; both must produce
    /// byte-identical payloads for the planner's cost model to hold).
    pub fn quantize_padded(data: &[f32], bits: u8, group: usize) -> Result<Self> {
        if group == 0 {
            bail!("group width must be >= 1");
        }
        let padded = data.len().div_ceil(group) * group;
        if padded == data.len() {
            return Self::quantize(data, bits, group);
        }
        let mut v = data.to_vec();
        v.resize(padded, 0.0);
        Self::quantize(&v, bits, group)
    }

    /// Sum of squared reconstruction error against the first `data.len()`
    /// elements (any zero-padding tail beyond the source is ignored).
    pub fn sse_against(&self, data: &[f32]) -> f64 {
        assert!(data.len() <= self.len(), "source longer than quantized vector");
        let dq = self.dequantize();
        crate::util::stats::sse(data, &dq[..data.len()])
    }

    pub fn len(&self) -> usize {
        self.codes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }

    pub fn n_groups(&self) -> usize {
        self.scales.len()
    }

    /// Dequantize to a fresh vector.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len()];
        self.dequantize_into(&mut out);
        out
    }

    /// Dequantize into a caller buffer (hot path, no allocation).
    pub fn dequantize_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len());
        let mut codes = vec![0u32; self.len()];
        self.codes.unpack_into(&mut codes);
        for (gi, chunk) in codes.chunks_exact(self.group).enumerate() {
            let scale = self.scales[gi];
            let zp = self.zps[gi];
            let base = gi * self.group;
            for (j, &c) in chunk.iter().enumerate() {
                out[base + j] = scale * (c as f32 - zp);
            }
        }
    }

    /// Codes as f32 (the representation the HLO artifacts take as input).
    pub fn codes_f32(&self) -> Vec<f32> {
        self.codes.iter().map(|c| c as f32).collect()
    }

    /// Exact storage bytes: packed codes + per-group scale/zp.
    pub fn storage_bytes(&self) -> usize {
        self.codes.storage_bytes() + self.n_groups() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};
    use crate::util::rng::Rng;

    #[test]
    fn rejects_bad_geometry() {
        assert!(GroupQuantized::quantize(&[0.0; 10], 4, 3).is_err());
        assert!(GroupQuantized::quantize(&[0.0; 10], 4, 0).is_err());
        assert!(GroupQuantized::quantize(&[0.0; 12], 4, 3).is_ok());
    }

    #[test]
    fn per_group_error_bound_holds() {
        check(
            Config { cases: 60, seed: 0x619 },
            |rng| {
                let groups = 1 + rng.below(6);
                let group = 8 * (1 + rng.below(16));
                let bits = 2 + rng.below(7) as u8;
                let mut v = vec![0.0f32; groups * group];
                rng.fill_normal(&mut v, 0.05);
                (v, bits, group)
            },
            |(v, bits, group)| {
                let q = GroupQuantized::quantize(v, *bits, *group)
                    .map_err(|e| e.to_string())?;
                let deq = q.dequantize();
                for (gi, chunk) in v.chunks_exact(*group).enumerate() {
                    let bound = q.scales[gi] / 2.0 * 1.001 + 1e-7;
                    for (j, &x) in chunk.iter().enumerate() {
                        let err = (x - deq[gi * group + j]).abs();
                        if err > bound {
                            return Err(format!("group {gi} err {err} > {bound}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn groupwise_beats_per_tensor_on_heterogeneous_data() {
        // Groups adapt to local ranges; a tensor with one wide region
        // should quantize better group-wise.
        let mut rng = Rng::new(4);
        let mut v = vec![0.0f32; 4096];
        rng.fill_normal(&mut v[..2048], 0.01);
        rng.fill_normal(&mut v[2048..], 1.0);
        let gq = GroupQuantized::quantize(&v, 3, 1024).unwrap();
        let pt = GroupQuantized::quantize(&v, 3, 4096).unwrap();
        let err_g: f64 = v
            .iter()
            .zip(gq.dequantize())
            .map(|(&x, y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let err_p: f64 = v
            .iter()
            .zip(pt.dequantize())
            .map(|(&x, y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(err_g < err_p, "group {err_g} vs per-tensor {err_p}");
    }

    #[test]
    fn quantize_padded_pins_manual_padding() {
        // The sensitivity probe (manual pad to plan geometry) and the
        // granularity ablation (quantize_padded) must produce the exact
        // same payload — the planner's byte/error model rides on it.
        let mut rng = Rng::new(6);
        for (len, bits, group) in [(100usize, 3u8, 64usize), (512, 2, 512), (7, 4, 16)] {
            let mut v = vec![0.0f32; len];
            rng.fill_normal(&mut v, 0.05);
            let mut manual = v.clone();
            manual.resize(len.div_ceil(group) * group, 0.0);
            let a = GroupQuantized::quantize(&manual, bits, group).unwrap();
            let b = GroupQuantized::quantize_padded(&v, bits, group).unwrap();
            assert_eq!(a, b, "len={len} bits={bits} group={group}");
            // And the shared error helper matches the manual SSE.
            let dq = a.dequantize();
            let want: f64 = v
                .iter()
                .zip(&dq)
                .map(|(&x, &y)| ((x - y) as f64).powi(2))
                .sum();
            assert!((a.sse_against(&v) - want).abs() < 1e-12);
        }
        assert!(GroupQuantized::quantize_padded(&[0.0; 4], 3, 0).is_err());
    }

    #[test]
    fn codes_f32_are_integral() {
        let mut rng = Rng::new(5);
        let mut v = vec![0.0f32; 2048];
        rng.fill_normal(&mut v, 0.1);
        let q = GroupQuantized::quantize(&v, 3, 1024).unwrap();
        for c in q.codes_f32() {
            assert_eq!(c.fract(), 0.0);
            assert!((0.0..=7.0).contains(&c));
        }
    }
}
