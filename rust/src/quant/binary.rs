//! 1-bit binary task-vector switches: a sign bitmap plus per-group (or
//! per-tensor) scales.
//!
//! This is the payload behind the planner's [`Arm::OneBit`] candidate and
//! the serve-time dynamic-merge path: 1bit-Merging (arXiv 2502.10743)
//! and Binary Task Switch (arXiv 2412.00054) show task vectors survive
//! binarization — element `i` reconstructs as `±scale(group_of(i))`, the
//! sign from one bitmap bit, so a task costs ~1 bit/weight and flipping
//! it on or off per request is a single signed axpy.  The scale is the
//! L2-optimal magnitude for fixed signs: the mean absolute value over
//! the group (one group spanning the whole tensor = per-tensor scale).
//!
//! On disk this is the `QTVC` kind-5 section (see `docs/WIRE_FORMAT.md`);
//! the wire codec lives in [`crate::registry::container`].
//!
//! [`Arm::OneBit`]: crate::planner::Arm::OneBit

use anyhow::{bail, Result};

/// Structural invariants shared by the owned container and the borrowed
/// view: both funnel through here so a corrupt section fails closed with
/// the same error no matter which decode path touched it first.
fn validate_parts(group: usize, n_groups: usize, signs: &[u8]) -> Result<usize> {
    if group == 0 {
        bail!("binary payload: zero group width");
    }
    if n_groups == 0 {
        bail!("binary payload: zero scale count");
    }
    let len = group
        .checked_mul(n_groups)
        .ok_or_else(|| anyhow::anyhow!("binary payload: length {group}x{n_groups} overflows"))?;
    if signs.len() != len.div_ceil(8) {
        bail!(
            "binary payload: truncated sign bitmap ({} bytes for length \
             {len}, expected {})",
            signs.len(),
            len.div_ceil(8)
        );
    }
    // Tail bits past len must be clear: the encoding is canonical, and a
    // re-stamped CRC over garbage tail bits must still fail closed.
    if len % 8 != 0 {
        let tail = signs[signs.len() - 1] >> (len % 8);
        if tail != 0 {
            bail!("binary payload: sign bits set past length {len}");
        }
    }
    Ok(len)
}

/// Accumulate `out[k] += lam * (±scale)` over the dense element range
/// `[start, start + out.len())`.  The per-group coefficient is computed
/// as `a = lam * scale(g)` exactly once per group touched — identical
/// arithmetic whatever range carves the call, so disjoint shards
/// reproduce the full pass bit-for-bit.
#[inline]
fn axpy_range(
    group: usize,
    scale_of: impl Fn(usize) -> f32,
    signs: &[u8],
    lam: f32,
    start: usize,
    out: &mut [f32],
) {
    let mut gi = usize::MAX;
    let mut a = 0.0f32;
    for (k, o) in out.iter_mut().enumerate() {
        let i = start + k;
        let g = i / group;
        if g != gi {
            gi = g;
            a = lam * scale_of(g);
        }
        let bit = (signs[i / 8] >> (i % 8)) & 1;
        *o += if bit == 1 { a } else { -a };
    }
}

/// A binarized flat vector: `group * scales.len()` logical f32s, each
/// reconstructing as `+scale` or `-scale` of its group, the sign from
/// one bitmap bit.
#[derive(Clone, Debug, PartialEq)]
pub struct BinarySwitch {
    /// Elements covered by each scale (== the full length for a single
    /// per-tensor scale).
    pub group: usize,
    /// One scale per group, in group order (mean |x| of the group).
    pub scales: Vec<f32>,
    /// LSB-first sign bitmap, `ceil(len / 8)` bytes; bit `i` set means
    /// element `i` is `+scale`, clear means `-scale`.  Bits past the
    /// length must be 0.
    pub signs: Vec<u8>,
}

impl BinarySwitch {
    /// Assemble from parts, validating every structural invariant — the
    /// wire decoder funnels through here so corrupt sections fail closed.
    pub fn new(group: usize, scales: Vec<f32>, signs: Vec<u8>) -> Result<Self> {
        validate_parts(group, scales.len(), &signs)?;
        Ok(Self { group, scales, signs })
    }

    /// Binarize `data` (length a multiple of `group`, as planner flats
    /// are): per group, scale = mean |x| and sign bit = `x >= 0`.
    pub fn quantize(data: &[f32], group: usize) -> Result<Self> {
        if group == 0 {
            bail!("binary quantization: zero group width");
        }
        if data.is_empty() || data.len() % group != 0 {
            bail!(
                "binary quantization: length {} is not a positive multiple \
                 of group {group}",
                data.len()
            );
        }
        let n_groups = data.len() / group;
        let mut scales = Vec::with_capacity(n_groups);
        let mut signs = vec![0u8; data.len().div_ceil(8)];
        for (g, chunk) in data.chunks_exact(group).enumerate() {
            let mean_abs: f32 =
                chunk.iter().map(|x| x.abs() as f64).sum::<f64>() as f32 / group as f32;
            scales.push(mean_abs);
            for (j, &x) in chunk.iter().enumerate() {
                if x >= 0.0 {
                    let i = g * group + j;
                    signs[i / 8] |= 1 << (i % 8);
                }
            }
        }
        Self::new(group, scales, signs)
    }

    /// Logical element count (`group * scales.len()`).
    pub fn len(&self) -> usize {
        self.group * self.scales.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn n_groups(&self) -> usize {
        self.scales.len()
    }

    /// Reconstruct the dense vector: `±scale` per element.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len()];
        self.axpy_into(1.0, &mut out);
        out
    }

    /// Fused serve path: `out[i] += lam * (±scale)` for every element.
    pub fn axpy_into(&self, lam: f32, out: &mut [f32]) {
        assert_eq!(out.len(), self.len());
        axpy_range(self.group, |g| self.scales[g], &self.signs, lam, 0, out);
    }

    /// Exact in-memory storage bytes: sign bitmap + scales.
    pub fn storage_bytes(&self) -> usize {
        self.signs.len() + self.scales.len() * 4
    }
}

/// A borrowed, zero-copy view over a binary section body: the scale
/// table and the sign bitmap both stay in the backing bytes (the
/// registry's file mapping); scales decode per access from raw LE bytes.
/// Construction runs the exact same structural validation as
/// [`BinarySwitch::new`], so corrupt sections fail closed identically on
/// either path.
#[derive(Clone, Copy, Debug)]
pub struct BinarySwitchView<'a> {
    group: usize,
    n_groups: usize,
    /// Raw little-endian scale table: 4 bytes per group.
    scales: &'a [u8],
    signs: &'a [u8],
}

impl<'a> BinarySwitchView<'a> {
    pub fn new(group: usize, n_groups: usize, scales: &'a [u8], signs: &'a [u8]) -> Result<Self> {
        if scales.len() != n_groups * 4 {
            bail!(
                "binary payload: scale table is {} bytes for {n_groups} \
                 groups (expected {})",
                scales.len(),
                n_groups * 4
            );
        }
        validate_parts(group, n_groups, signs)?;
        Ok(Self { group, n_groups, scales, signs })
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.group * self.n_groups
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    pub fn group(&self) -> usize {
        self.group
    }

    #[inline]
    pub fn n_groups(&self) -> usize {
        self.n_groups
    }

    #[inline]
    fn scale(&self, g: usize) -> f32 {
        f32::from_le_bytes(self.scales[g * 4..g * 4 + 4].try_into().unwrap())
    }

    /// Fused serve path: `out[i] += lam * (±scale)` for every element.
    pub fn axpy_into(&self, lam: f32, out: &mut [f32]) {
        assert_eq!(out.len(), self.len());
        self.axpy_range_into(lam, 0, out);
    }

    /// Sharded accumulate over the process-wide active kernel: `out`
    /// covers the dense element range `[byte0 * 8, byte0 * 8 +
    /// out.len())`, which must start on a sign-byte boundary and end on
    /// one (or at the full length) — the shard geometry the parallel
    /// fused merge carves.
    pub fn axpy_range_into(&self, lam: f32, byte0: usize, out: &mut [f32]) {
        self.axpy_range_into_k(super::simd::active(), lam, byte0, out);
    }

    /// [`axpy_range_into`](Self::axpy_range_into) over an explicit
    /// kernel.  Each element's increment is `lam * scale(g)` with the
    /// sign applied afterwards (an exact sign-bit flip on every
    /// kernel), computed identically in every shard, so disjoint shards
    /// reproduce the full pass bit-for-bit on any kernel.
    pub fn axpy_range_into_k(
        &self,
        kernel: super::simd::Kernel,
        lam: f32,
        byte0: usize,
        out: &mut [f32],
    ) {
        let start = byte0 * 8;
        let end = start + out.len();
        assert!(end <= self.len(), "element range [{start}, {end}) past {}", self.len());
        assert!(
            end == self.len() || end % 8 == 0,
            "binary shard must end on a sign-byte boundary or at the full length"
        );
        if kernel == super::simd::Kernel::Scalar {
            axpy_range(self.group, |g| self.scale(g), self.signs, lam, start, out);
            return;
        }
        // Vector path: one signed-axpy call per group overlapping the
        // range, so `a = lam * scale(g)` is computed exactly once per
        // group touched — the same op sequence as the scalar walk.
        let mut i = start;
        while i < end {
            let g = i / self.group;
            let g_end = ((g + 1) * self.group).min(end);
            let a = lam * self.scale(g);
            super::simd::signed_axpy(kernel, a, self.signs, i, &mut out[i - start..g_end - start]);
            i = g_end;
        }
    }

    /// Reconstruct into a caller buffer (overwrites all of `out`) —
    /// bit-identical to [`BinarySwitch::dequantize`].
    pub fn dequantize_into(&self, out: &mut [f32]) {
        self.dequantize_into_k(super::simd::active(), out);
    }

    /// [`dequantize_into`](Self::dequantize_into) over an explicit
    /// kernel (the serve paths thread
    /// [`ExecCtx::kernel`](crate::util::exec::ExecCtx::kernel) here).
    pub fn dequantize_into_k(&self, kernel: super::simd::Kernel, out: &mut [f32]) {
        assert_eq!(out.len(), self.len());
        out.fill(0.0);
        self.axpy_range_into_k(kernel, 1.0, 0, out);
    }

    /// Materialize an owned [`BinarySwitch`].
    pub fn to_owned(self) -> BinarySwitch {
        let scales =
            (0..self.n_groups).map(|g| self.scale(g)).collect();
        BinarySwitch { group: self.group, scales, signs: self.signs.to_vec() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0f32; len];
        rng.fill_normal(&mut v, 0.05);
        v
    }

    fn scale_bytes(b: &BinarySwitch) -> Vec<u8> {
        let mut out = Vec::new();
        for &s in &b.scales {
            out.extend_from_slice(&s.to_le_bytes());
        }
        out
    }

    #[test]
    fn roundtrip_preserves_signs_and_group_magnitude() {
        let v = sample(512, 1);
        let b = BinarySwitch::quantize(&v, 64).unwrap();
        assert_eq!(b.len(), 512);
        assert_eq!(b.n_groups(), 8);
        let dq = b.dequantize();
        for (i, (&x, &r)) in v.iter().zip(&dq).enumerate() {
            assert_eq!(
                r >= 0.0,
                x >= 0.0,
                "element {i}: sign flipped ({x} -> {r})"
            );
            let g = i / 64;
            assert_eq!(r.abs(), b.scales[g], "element {i}: magnitude is not the group scale");
        }
    }

    #[test]
    fn per_tensor_scale_is_a_single_group() {
        let v = sample(96, 2);
        let b = BinarySwitch::quantize(&v, 96).unwrap();
        assert_eq!(b.n_groups(), 1);
        let mean_abs: f32 = v.iter().map(|x| x.abs() as f64).sum::<f64>() as f32 / 96.0;
        assert_eq!(b.scales[0], mean_abs);
    }

    #[test]
    fn axpy_accumulates_the_signed_scale() {
        let v = sample(256, 3);
        let b = BinarySwitch::quantize(&v, 32).unwrap();
        let mut out = vec![7.0f32; 256];
        b.axpy_into(0.5, &mut out);
        let dq = b.dequantize();
        for i in 0..256 {
            assert_eq!(out[i], 7.0 + 0.5 * dq[i]);
        }
    }

    #[test]
    fn rejects_malformed_inputs() {
        let v = sample(64, 4);
        assert!(BinarySwitch::quantize(&v, 0).is_err());
        assert!(BinarySwitch::quantize(&v[..60], 64).is_err());
        assert!(BinarySwitch::quantize(&[], 8).is_err());

        let good = BinarySwitch::quantize(&v, 16).unwrap();
        // Truncated sign bitmap.
        assert!(BinarySwitch::new(16, good.scales.clone(), good.signs[..4].to_vec()).is_err());
        // Scale-count mismatch against the bitmap.
        assert!(BinarySwitch::new(16, good.scales[..2].to_vec(), good.signs.clone()).is_err());
        // Zero groups / zero group width.
        assert!(BinarySwitch::new(16, Vec::new(), good.signs.clone()).is_err());
        assert!(BinarySwitch::new(0, good.scales.clone(), good.signs.clone()).is_err());
        // Sign bits set past the logical length.
        let mut tail = vec![0u8; 1];
        tail[0] = 0b1110_0000; // bits 5..8 set, len = 5
        assert!(BinarySwitch::new(5, vec![0.1], tail).is_err());
    }

    #[test]
    fn view_matches_owned_bit_exactly() {
        let v = sample(1000, 5);
        let b = BinarySwitch::quantize(&v, 125).unwrap();
        let params = scale_bytes(&b);
        let view = BinarySwitchView::new(125, b.n_groups(), &params, &b.signs).unwrap();
        assert_eq!(view.len(), 1000);
        assert_eq!(view.group(), 125);

        let mut got = vec![0.0f32; 1000];
        view.dequantize_into(&mut got);
        assert_eq!(got, b.dequantize(), "view reconstruction must be bit-exact");

        let mut acc = vec![2.0f32; 1000];
        let mut want = vec![2.0f32; 1000];
        view.axpy_into(0.5, &mut acc);
        b.axpy_into(0.5, &mut want);
        assert_eq!(acc, want, "view axpy must match the owned path");

        assert_eq!(view.to_owned(), b);
    }

    #[test]
    fn range_axpy_matches_full_axpy_bit_exactly() {
        // Length not a multiple of 8, group not a multiple of 8: shard
        // boundaries cut through groups and the bitmap tail byte.
        let v = sample(1005, 6);
        let b = BinarySwitch::quantize(&v, 67).unwrap();
        let params = scale_bytes(&b);
        let view = BinarySwitchView::new(67, b.n_groups(), &params, &b.signs).unwrap();

        let mut want = vec![0.25f32; 1005];
        view.axpy_into(-0.75, &mut want);

        for shard_bytes in [1usize, 3, 16, 126] {
            let mut got = vec![0.25f32; 1005];
            let mut byte0 = 0;
            while byte0 * 8 < 1005 {
                let lo = byte0 * 8;
                let hi = (lo + shard_bytes * 8).min(1005);
                view.axpy_range_into(-0.75, byte0, &mut got[lo..hi]);
                byte0 += shard_bytes;
            }
            assert_eq!(got, want, "shard_bytes={shard_bytes}: accumulate diverged");
        }
    }

    #[test]
    fn view_validation_matches_owned() {
        let v = sample(64, 7);
        let b = BinarySwitch::quantize(&v, 16).unwrap();
        let params = scale_bytes(&b);
        // Truncated bitmap fails with the same message on both paths.
        let view_err = BinarySwitchView::new(16, b.n_groups(), &params, &b.signs[..4])
            .unwrap_err()
            .to_string();
        let owned_err = BinarySwitch::new(16, b.scales.clone(), b.signs[..4].to_vec())
            .unwrap_err()
            .to_string();
        assert_eq!(view_err, owned_err);
        assert!(view_err.contains("truncated sign bitmap"));
        // Scale-table length mismatch is view-specific (the owned side
        // holds decoded f32s) but still fails closed.
        assert!(BinarySwitchView::new(16, b.n_groups(), &params[..params.len() - 1], &b.signs)
            .is_err());
        assert!(BinarySwitchView::new(16, b.n_groups() + 1, &params, &b.signs).is_err());
    }

    #[test]
    fn storage_accounts_bitmap_and_scales() {
        let v = sample(128, 8);
        let b = BinarySwitch::quantize(&v, 32).unwrap();
        assert_eq!(b.storage_bytes(), 16 + 4 * 4);
    }
}
