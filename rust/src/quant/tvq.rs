//! Per-tensor quantized checkpoints: TVQ and the FQ baseline.
//!
//! [`Tvq::quantize`] quantizes a *task vector* (the paper's method,
//! Section 4.2); the same container quantizes a full fine-tuned
//! checkpoint for the FQ baseline (Fig. 5a) — the object quantized is the
//! caller's choice, the math is identical.  The paper's insight is that
//! task vectors have an order-of-magnitude narrower weight range, so the
//! Eq. 3 error bound — proportional to that range — is correspondingly
//! smaller at the same bit width.

use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::affine::AffineParams;
use super::bitpack::BitPacked;
use crate::checkpoint::Checkpoint;
use crate::tensor::Tensor;

/// One quantized tensor: affine params + packed codes + shape.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedTensor {
    pub shape: Vec<usize>,
    pub params: AffineParams,
    pub codes: BitPacked,
}

impl QuantizedTensor {
    pub fn quantize(t: &Tensor, bits: u8) -> Result<Self> {
        let params = AffineParams::from_slice(t.data(), bits)?;
        let codes = params.quantize_slice(t.data());
        Ok(Self {
            shape: t.shape().to_vec(),
            params,
            codes: BitPacked::pack(&codes, bits)?,
        })
    }

    pub fn dequantize(&self) -> Result<Tensor> {
        let mut data = vec![0.0f32; self.codes.len()];
        let mut codes = vec![0u32; self.codes.len()];
        self.codes.unpack_into(&mut codes);
        for (d, &c) in data.iter_mut().zip(&codes) {
            *d = self.params.dequantize_code(c);
        }
        Tensor::new(self.shape.clone(), data)
    }

    /// Exact storage: packed codes + scale/zp + shape descriptor.
    pub fn storage_bytes(&self) -> usize {
        self.codes.storage_bytes() + 2 * 4 + self.shape.len() * 8
    }

    pub fn numel(&self) -> usize {
        self.codes.len()
    }
}

/// A quantized checkpoint: every tensor quantized per-tensor at `bits`.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedCheckpoint {
    pub bits: u8,
    tensors: BTreeMap<String, QuantizedTensor>,
}

/// Alias matching the paper's terminology: quantize a task vector.
pub type Tvq = QuantizedCheckpoint;

impl QuantizedCheckpoint {
    /// Quantize every tensor of `ck` at `bits` (per-tensor granularity,
    /// as in the paper).
    pub fn quantize(ck: &Checkpoint, bits: u8) -> Result<Self> {
        let mut tensors = BTreeMap::new();
        for (name, t) in ck.iter() {
            tensors.insert(name.to_string(), QuantizedTensor::quantize(t, bits)?);
        }
        Ok(Self { bits, tensors })
    }

    /// Assemble from already-quantized tensors — the decode path of the
    /// `QTVC` v2 registry container (`crate::registry`).
    pub fn from_tensors(bits: u8, tensors: BTreeMap<String, QuantizedTensor>) -> Self {
        Self { bits, tensors }
    }

    /// Reconstruct the full-precision approximation (Eq. 2 per tensor).
    pub fn dequantize(&self) -> Result<Checkpoint> {
        self.dequantize_with_pool(&crate::util::pool::Pool::sequential())
    }

    /// [`dequantize`](Self::dequantize) with the per-tensor decode fanned
    /// out across `pool`.  Tensors decode independently and assemble in
    /// name order, so the reconstruction is bit-identical at every
    /// thread count — the registry's lazy serve path rides on this.
    pub fn dequantize_with_pool(&self, pool: &crate::util::pool::Pool) -> Result<Checkpoint> {
        let parts = pool.try_map(self.tensors.iter().collect(), |_, (name, qt)| {
            Ok((name, qt.dequantize()?))
        })?;
        let mut ck = Checkpoint::new();
        for (name, t) in parts {
            ck.insert(name, t);
        }
        Ok(ck)
    }

    pub fn get(&self, name: &str) -> Option<&QuantizedTensor> {
        self.tensors.get(name)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &QuantizedTensor)> {
        self.tensors.iter().map(|(k, v)| (k.as_str(), v))
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn numel(&self) -> usize {
        self.tensors.values().map(|t| t.numel()).sum()
    }

    /// Exact total storage in bytes (codes + per-tensor metadata + names).
    pub fn storage_bytes(&self) -> usize {
        self.tensors
            .iter()
            .map(|(k, v)| v.storage_bytes() + k.len())
            .sum()
    }

    /// Quantization error ||x - dq(q(x))||_2 against the source checkpoint.
    pub fn quant_error(&self, src: &Checkpoint) -> Result<f64> {
        let deq = self.dequantize()?;
        src.l2_dist(&deq)
    }

    // -- on-disk container (.tvq) ------------------------------------------

    const MAGIC: u32 = 0x5156_5451; // "QTVQ"

    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(&Self::MAGIC.to_le_bytes());
        buf.push(self.bits);
        buf.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, qt) in &self.tensors {
            let nb = name.as_bytes();
            buf.extend_from_slice(&(nb.len() as u32).to_le_bytes());
            buf.extend_from_slice(nb);
            buf.extend_from_slice(&(qt.shape.len() as u32).to_le_bytes());
            for &d in &qt.shape {
                buf.extend_from_slice(&(d as u64).to_le_bytes());
            }
            buf.extend_from_slice(&qt.params.scale.to_le_bytes());
            buf.extend_from_slice(&qt.params.zp.to_le_bytes());
            buf.extend_from_slice(&qt.codes.to_bytes());
        }
        std::fs::write(path, &buf).with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref();
        let mut bytes = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?
            .read_to_end(&mut bytes)?;
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > bytes.len() {
                bail!("truncated .tvq file");
            }
            let s = &bytes[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let magic = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        if magic != Self::MAGIC {
            bail!("not a .tvq container: {}", path.display());
        }
        let bits = take(&mut pos, 1)?[0];
        let count = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let nlen = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
            let name = std::str::from_utf8(take(&mut pos, nlen)?)?.to_string();
            let ndim = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize);
            }
            let scale = f32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
            let zp = f32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
            let (codes, used) = BitPacked::from_bytes(&bytes[pos..])?;
            pos += used;
            let numel: usize = shape.iter().product();
            if numel != codes.len() {
                bail!("tensor {name:?}: shape/code-count mismatch");
            }
            tensors.insert(
                name,
                QuantizedTensor {
                    shape,
                    params: AffineParams { scale, zp, bits },
                    codes,
                },
            );
        }
        Ok(Self { bits, tensors })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn task_vector(seed: u64, std: f32) -> Checkpoint {
        let mut rng = Rng::new(seed);
        let mut ck = Checkpoint::new();
        ck.insert("a/w", Tensor::randn(&[32, 16], std, &mut rng));
        ck.insert("a/b", Tensor::randn(&[16], std, &mut rng));
        ck.insert("z/w", Tensor::randn(&[8, 8], std, &mut rng));
        ck
    }

    #[test]
    fn quantize_dequantize_error_bounded() {
        let tau = task_vector(1, 0.01);
        for bits in [2u8, 3, 4, 8] {
            let q = QuantizedCheckpoint::quantize(&tau, bits).unwrap();
            let deq = q.dequantize().unwrap();
            for (name, t) in tau.iter() {
                let qt = q.get(name).unwrap();
                let bound = qt.params.error_bound() * 1.001 + 1e-7;
                for (x, y) in t.data().iter().zip(deq.get(name).unwrap().data()) {
                    assert!(
                        (x - y).abs() <= bound,
                        "bits={bits} err={} bound={bound}",
                        (x - y).abs()
                    );
                }
            }
        }
    }

    #[test]
    fn error_decreases_with_more_bits() {
        let tau = task_vector(2, 0.02);
        let errs: Vec<f64> = [2u8, 3, 4, 8]
            .iter()
            .map(|&b| {
                QuantizedCheckpoint::quantize(&tau, b)
                    .unwrap()
                    .quant_error(&tau)
                    .unwrap()
            })
            .collect();
        assert!(errs[0] > errs[1] && errs[1] > errs[2] && errs[2] > errs[3], "{errs:?}");
    }

    #[test]
    fn narrow_range_quantizes_better_than_wide() {
        // The paper's core claim at checkpoint scale: quantizing the
        // narrow task vector beats quantizing the wide fine-tuned weights.
        let pre = task_vector(3, 0.5);
        let tau = task_vector(4, 0.02); // narrow task vector
        let ft = pre.add(&tau).unwrap();
        let bits = 3;

        // FQ error measured on the reconstructed task vector
        let fq = QuantizedCheckpoint::quantize(&ft, bits).unwrap();
        let tau_from_fq = fq.dequantize().unwrap().sub(&pre).unwrap();
        let fq_err = tau.l2_dist(&tau_from_fq).unwrap();

        // TVQ error
        let tvq = QuantizedCheckpoint::quantize(&tau, bits).unwrap();
        let tvq_err = tvq.quant_error(&tau).unwrap();

        assert!(
            tvq_err * 5.0 < fq_err,
            "tvq_err={tvq_err} fq_err={fq_err} (expected order-of-magnitude gap)"
        );
    }

    #[test]
    fn storage_shrinks_with_bits() {
        let tau = task_vector(5, 0.01);
        let fp32 = tau.fp32_bytes();
        let q8 = QuantizedCheckpoint::quantize(&tau, 8).unwrap().storage_bytes();
        let q2 = QuantizedCheckpoint::quantize(&tau, 2).unwrap().storage_bytes();
        // Small test tensors make per-tensor metadata (name, shape,
        // scale/zp) a visible overhead; at model scale it vanishes.
        assert!(q8 < fp32 / 3, "q8={q8} fp32={fp32}");
        assert!(q2 < fp32 / 8, "q2={q2} fp32={fp32}");
    }

    #[test]
    fn save_load_roundtrip() {
        let tau = task_vector(6, 0.01);
        let q = QuantizedCheckpoint::quantize(&tau, 3).unwrap();
        let dir = std::env::temp_dir().join("tvq_qc_test");
        let path = dir.join("t.tvq");
        q.save(&path).unwrap();
        let back = QuantizedCheckpoint::load(&path).unwrap();
        assert_eq!(q, back);
        std::fs::remove_dir_all(&dir).ok();
    }
}
