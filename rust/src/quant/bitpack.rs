//! Dense bit-packed code storage.
//!
//! Quantized codes (1..=8 bits each) are packed contiguously into u64
//! words, little-endian within the word; codes may straddle word
//! boundaries (relevant for 3/5/6/7-bit widths).  This is the container
//! that actually realizes the paper's storage savings — `storage_bytes`
//! is exact, not estimated.

use anyhow::{bail, Result};

/// Decode full blocks of `CPB` codes (each `BITS` wide) from `BPB`-byte
/// chunks of the packed byte stream; returns how many codes were written.
/// The shifts are compile-time constants, so the inner loop unrolls.
#[inline]
fn unpack_byte_blocks<const BITS: usize, const BPB: usize, const CPB: usize>(
    bytes: &[u8],
    out: &mut [u32],
) -> usize {
    let mask = (1u64 << BITS) - 1;
    let n_blocks = (out.len() / CPB).min(bytes.len() / BPB);
    for (chunk, src) in out.chunks_exact_mut(CPB).zip(bytes.chunks_exact(BPB)) {
        let mut buf = [0u8; 8];
        buf[..BPB].copy_from_slice(src);
        let v = u64::from_le_bytes(buf);
        for (j, o) in chunk.iter_mut().enumerate() {
            *o = ((v >> (j * BITS)) & mask) as u32;
        }
    }
    n_blocks * CPB
}

/// Decode all full byte-blocks of `out` for any 1..=8-bit width by
/// dispatching to the right compile-time block shape: lcm(bits, 8) bits is
/// a whole number of bytes holding a whole number of codes (1 byte = eight
/// 1-bit codes, 3 bytes = eight 3-bit codes, ...).  Returns how many codes
/// were decoded; the caller finishes the ragged tail code-by-code.
///
/// This is the `Kernel::Scalar` block decoder — the determinism
/// reference the SIMD unpack kernels in [`crate::quant::simd`] are
/// pinned against (all kernels produce identical integer codes, so any
/// of them may decode any prefix).
#[inline]
pub(crate) fn unpack_blocks_scalar(bits: u8, bytes: &[u8], out: &mut [u32]) -> usize {
    match bits {
        1 => unpack_byte_blocks::<1, 1, 8>(bytes, out),
        2 => unpack_byte_blocks::<2, 1, 4>(bytes, out),
        3 => unpack_byte_blocks::<3, 3, 8>(bytes, out),
        4 => unpack_byte_blocks::<4, 1, 2>(bytes, out),
        5 => unpack_byte_blocks::<5, 5, 8>(bytes, out),
        6 => unpack_byte_blocks::<6, 3, 4>(bytes, out),
        7 => unpack_byte_blocks::<7, 7, 8>(bytes, out),
        8 => unpack_byte_blocks::<8, 1, 1>(bytes, out),
        _ => unreachable!("bit widths are validated to 1..=8"),
    }
}

/// A borrowed, zero-copy view over a packed code stream — the exact byte
/// layout of [`BitPacked::packed_bytes`], decoded in place.  This is what
/// the registry's mmap serving path hands out: the bytes stay in the file
/// mapping and are never copied into an owned container.  Stray bits in
/// the final byte past the last code are ignored (each decode masks per
/// code), so a view over an untrusted section decodes identically to
/// `BitPacked::from_packed_bytes` without the tail-clearing copy.
#[derive(Clone, Copy, Debug)]
pub struct BitPackedView<'a> {
    bits: u8,
    len: usize,
    bytes: &'a [u8],
}

impl<'a> BitPackedView<'a> {
    /// Borrow `bytes` as `len` codes of `bits` bits.  `bytes` must be
    /// exactly `ceil(len * bits / 8)` long — the same geometry
    /// [`BitPacked::from_packed_bytes`] enforces.
    pub fn new(bits: u8, len: usize, bytes: &'a [u8]) -> Result<Self> {
        if !(1..=8).contains(&bits) {
            bail!("bits must be in 1..=8, got {bits}");
        }
        let total_bits = len
            .checked_mul(bits as usize)
            .ok_or_else(|| anyhow::anyhow!("code count {len} at {bits} bits overflows"))?;
        let nbytes = total_bits.div_ceil(8);
        if bytes.len() != nbytes {
            bail!(
                "packed payload is {} bytes, expected {nbytes} for {len} codes at {bits} bits",
                bytes.len()
            );
        }
        Ok(Self { bits, len, bytes })
    }

    #[inline]
    pub fn bits(&self) -> u8 {
        self.bits
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Random access to one code (a code spans at most two bytes).
    #[inline]
    pub fn get(&self, i: usize) -> u32 {
        debug_assert!(i < self.len);
        let bits = self.bits as usize;
        let bitpos = i * bits;
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mask = (1u32 << bits) - 1;
        let mut v = (self.bytes[byte] as u32) >> off;
        if off + bits > 8 {
            v |= (self.bytes[byte + 1] as u32) << (8 - off);
        }
        v & mask
    }

    /// Unpack every code into `out` (must be `len` long), straight from
    /// the borrowed bytes — no intermediate word vector.
    pub fn unpack_into(&self, out: &mut [u32]) {
        assert_eq!(out.len(), self.len);
        self.unpack_range_into(0, out);
    }

    /// Unpack codes `[start, start + out.len())` into `out` — the
    /// range-addressable form of [`unpack_into`](Self::unpack_into),
    /// over the process-wide active kernel
    /// ([`simd::active`](crate::quant::simd::active)).
    pub fn unpack_range_into(&self, start: usize, out: &mut [u32]) {
        self.unpack_range_into_k(crate::quant::simd::active(), start, out);
    }

    /// [`unpack_range_into`](Self::unpack_range_into) over an explicit
    /// decode kernel.  Every code decodes with the same mask-and-shift
    /// arithmetic regardless of which range reads it **or which kernel
    /// decodes it** (codes are exact integers), so sharded readers
    /// reproduce the full decode bit-for-bit on any kernel (the
    /// parallel merge path relies on this).  Arbitrary `start` is
    /// allowed; unaligned lead-in codes decode one at a time until the
    /// bit cursor reaches a byte boundary, then the block decoder takes
    /// over.
    pub fn unpack_range_into_k(
        &self,
        kernel: crate::quant::simd::Kernel,
        start: usize,
        out: &mut [u32],
    ) {
        assert!(
            start.checked_add(out.len()).is_some_and(|end| end <= self.len),
            "code range [{start}, {start}+{}) outside 0..{}",
            out.len(),
            self.len
        );
        let bits = self.bits as usize;
        let mut i = 0;
        while i < out.len() && ((start + i) * bits) % 8 != 0 {
            out[i] = self.get(start + i);
            i += 1;
        }
        let aligned = &mut out[i..];
        if aligned.is_empty() {
            return;
        }
        let byte0 = ((start + i) * bits) / 8;
        let done = crate::quant::simd::unpack_blocks(kernel, self.bits, &self.bytes[byte0..], aligned);
        for (j, o) in aligned[done..].iter_mut().enumerate() {
            *o = self.get(start + i + done + j);
        }
    }

    /// Materialize an owned [`BitPacked`] (stray tail bits cleared).
    pub fn to_owned(self) -> BitPacked {
        BitPacked::from_packed_bytes(self.bits, self.len, self.bytes)
            .expect("view geometry validated at construction")
    }
}

/// A packed vector of `len` codes of `bits` bits each.
#[derive(Clone, Debug, PartialEq)]
pub struct BitPacked {
    bits: u8,
    len: usize,
    words: Vec<u64>,
}

impl BitPacked {
    /// Pack a slice of codes. Every code must fit in `bits` bits.
    pub fn pack(codes: &[u32], bits: u8) -> Result<Self> {
        if !(1..=8).contains(&bits) {
            bail!("bits must be in 1..=8, got {bits}");
        }
        let maxcode = (1u32 << bits) - 1;
        let total_bits = codes.len() * bits as usize;
        let mut words = vec![0u64; total_bits.div_ceil(64)];
        for (i, &c) in codes.iter().enumerate() {
            if c > maxcode {
                bail!("code {c} exceeds {bits}-bit range");
            }
            let bitpos = i * bits as usize;
            let w = bitpos / 64;
            let off = bitpos % 64;
            words[w] |= (c as u64) << off;
            if off + bits as usize > 64 {
                words[w + 1] |= (c as u64) >> (64 - off);
            }
        }
        Ok(Self { bits, len: codes.len(), words })
    }

    #[inline]
    pub fn bits(&self) -> u8 {
        self.bits
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Exact payload size in bytes.
    pub fn storage_bytes(&self) -> usize {
        (self.len * self.bits as usize).div_ceil(8)
    }

    /// Random access to one code.
    #[inline]
    pub fn get(&self, i: usize) -> u32 {
        debug_assert!(i < self.len);
        let bits = self.bits as usize;
        let bitpos = i * bits;
        let w = bitpos / 64;
        let off = bitpos % 64;
        let mask = (1u64 << bits) - 1;
        let mut v = self.words[w] >> off;
        if off + bits > 64 {
            v |= self.words[w + 1] << (64 - off);
        }
        (v & mask) as u32
    }

    /// Unpack every code into `out` (must be `len` long).  This is the
    /// serving hot path (§Perf in EXPERIMENTS.md): widths dividing 64
    /// take a word-aligned shift loop (no cross-word handling at all);
    /// straddling widths (3/5/6/7) run through a u128 bitstream
    /// accumulator — both avoid the per-code div/mod of the naive form.
    pub fn unpack_into(&self, out: &mut [u32]) {
        assert_eq!(out.len(), self.len);
        let bits = self.bits as usize;
        let mask = (1u64 << bits) - 1;
        if 64 % bits == 0 {
            // Aligned: each word holds exactly 64/bits codes.
            let per = 64 / bits;
            for (chunk, &w) in out.chunks_mut(per).zip(&self.words) {
                let mut v = w;
                for o in chunk {
                    *o = (v & mask) as u32;
                    v >>= bits;
                }
            }
        } else {
            // Straddling widths (3/5/6/7): the packed stream is byte-
            // continuous (words are little-endian), and lcm(bits, 8) bits
            // is a whole number of bytes holding a whole number of codes —
            // e.g. 3 bytes = eight 3-bit codes.  Decode block-at-a-time
            // from the byte view with fixed shifts (unrolled per width).
            // SAFETY: a &[u64] reinterpreted as &[u8] is always valid
            // (alignment 1, every byte initialized).
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(
                    self.words.as_ptr() as *const u8,
                    self.words.len() * 8,
                )
            };
            let done = unpack_blocks_scalar(self.bits, bytes, out);
            for (i, o) in out[done..].iter_mut().enumerate() {
                *o = self.get(done + i);
            }
        }
    }

    /// Allocate-and-unpack convenience.
    pub fn unpack(&self) -> Vec<u32> {
        let mut out = vec![0u32; self.len];
        self.unpack_into(&mut out);
        out
    }

    /// Iterate codes without materializing a buffer.
    pub fn iter(&self) -> impl Iterator<Item = u32> + '_ {
        (0..self.len).map(move |i| self.get(i))
    }

    /// Reinterpret the packed payload as little-endian i32 words — the
    /// input convention of the `packed_merge_*` Pallas artifacts.  Only
    /// valid for widths dividing 32 with a word-aligned code count.
    pub fn to_i32_words(&self) -> Result<Vec<i32>> {
        if 32 % self.bits as usize != 0 {
            bail!("bits={} does not divide 32", self.bits);
        }
        let total_bits = self.len * self.bits as usize;
        if total_bits % 32 != 0 {
            bail!("code count {} not i32-word aligned at {} bits", self.len, self.bits);
        }
        let n_words = total_bits / 32;
        let mut out = Vec::with_capacity(n_words);
        for (i, &w) in self.words.iter().enumerate() {
            out.push(w as u32 as i32);
            if out.len() == n_words {
                break;
            }
            out.push((w >> 32) as u32 as i32);
            if out.len() == n_words {
                break;
            }
            let _ = i;
        }
        Ok(out)
    }

    /// Serialize to bytes (for the .tvq container).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(13 + self.words.len() * 8);
        out.push(self.bits);
        out.extend_from_slice(&(self.len as u64).to_le_bytes());
        out.extend_from_slice(&(self.words.len() as u32).to_le_bytes());
        for w in &self.words {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out
    }

    /// Exact packed payload: the little-endian byte stream of the codes,
    /// `storage_bytes()` long.  Unlike [`to_bytes`](Self::to_bytes) this
    /// carries no header and no u64-word padding — it is the bit-exact
    /// wire form the `QTVC` v2 registry stores, so on-disk size equals
    /// `ceil(len * bits / 8)` to the byte.
    pub fn packed_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.words.len() * 8);
        for w in &self.words {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out.truncate(self.storage_bytes());
        out
    }

    /// Inverse of [`packed_bytes`](Self::packed_bytes): rebuild from the
    /// headerless byte stream.  `bytes` must be exactly
    /// `ceil(len * bits / 8)` long; stray bits past the final code are
    /// cleared so the result compares equal to the original `pack()`.
    pub fn from_packed_bytes(bits: u8, len: usize, bytes: &[u8]) -> Result<Self> {
        if !(1..=8).contains(&bits) {
            bail!("bits must be in 1..=8, got {bits}");
        }
        let total_bits = len
            .checked_mul(bits as usize)
            .ok_or_else(|| anyhow::anyhow!("code count {len} at {bits} bits overflows"))?;
        let nbytes = total_bits.div_ceil(8);
        if bytes.len() != nbytes {
            bail!(
                "packed payload is {} bytes, expected {nbytes} for {len} codes at {bits} bits",
                bytes.len()
            );
        }
        let mut words = vec![0u64; total_bits.div_ceil(64)];
        for (i, &b) in bytes.iter().enumerate() {
            words[i / 8] |= (b as u64) << (8 * (i % 8));
        }
        let tail = total_bits % 64;
        if tail != 0 {
            if let Some(last) = words.last_mut() {
                *last &= (1u64 << tail) - 1;
            }
        }
        Ok(Self { bits, len, words })
    }

    /// Deserialize; returns (value, bytes consumed).
    pub fn from_bytes(buf: &[u8]) -> Result<(Self, usize)> {
        if buf.len() < 13 {
            bail!("truncated BitPacked header");
        }
        let bits = buf[0];
        let len = u64::from_le_bytes(buf[1..9].try_into().unwrap()) as usize;
        let nwords = u32::from_le_bytes(buf[9..13].try_into().unwrap()) as usize;
        let need = 13 + nwords * 8;
        if buf.len() < need {
            bail!("truncated BitPacked payload");
        }
        if !(1..=8).contains(&bits) {
            bail!("invalid bits {bits}");
        }
        if nwords != (len * bits as usize).div_ceil(64) {
            bail!("BitPacked word count inconsistent with len/bits");
        }
        let words = buf[13..need]
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok((Self { bits, len, words }, need))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};

    #[test]
    fn pack_rejects_out_of_range() {
        assert!(BitPacked::pack(&[4], 2).is_err());
        assert!(BitPacked::pack(&[3], 2).is_ok());
        assert!(BitPacked::pack(&[0], 0).is_err());
        assert!(BitPacked::pack(&[0], 9).is_err());
    }

    #[test]
    fn storage_is_exact() {
        let p = BitPacked::pack(&vec![1u32; 1000], 3).unwrap();
        assert_eq!(p.storage_bytes(), 375); // 3000 bits
        let p = BitPacked::pack(&vec![1u32; 7], 2).unwrap();
        assert_eq!(p.storage_bytes(), 2); // 14 bits -> 2 bytes
    }

    #[test]
    fn roundtrip_all_bit_widths() {
        check(
            Config { cases: 80, seed: 0xB17 },
            |rng| {
                let bits = 1 + rng.below(8) as u8;
                let len = 1 + rng.below(500);
                let maxcode = (1u32 << bits) - 1;
                let codes: Vec<u32> =
                    (0..len).map(|_| rng.below(maxcode as usize + 1) as u32).collect();
                (bits, codes)
            },
            |(bits, codes)| {
                let p = BitPacked::pack(codes, *bits).map_err(|e| e.to_string())?;
                if p.unpack() != *codes {
                    return Err("unpack mismatch".into());
                }
                for (i, &c) in codes.iter().enumerate() {
                    if p.get(i) != c {
                        return Err(format!("get({i}) = {} != {c}", p.get(i)));
                    }
                }
                let bytes = p.to_bytes();
                let (q, used) = BitPacked::from_bytes(&bytes).map_err(|e| e.to_string())?;
                if used != bytes.len() || q != p {
                    return Err("serde roundtrip mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn straddling_3bit_boundary() {
        // 64/3 is non-integral: codes straddle word boundaries.
        let codes: Vec<u32> = (0..100).map(|i| (i % 8) as u32).collect();
        let p = BitPacked::pack(&codes, 3).unwrap();
        assert_eq!(p.unpack(), codes);
    }

    #[test]
    fn from_bytes_rejects_corrupt() {
        let p = BitPacked::pack(&[1, 2, 3], 4).unwrap();
        let mut bytes = p.to_bytes();
        bytes[0] = 11; // invalid bits
        assert!(BitPacked::from_bytes(&bytes).is_err());
        assert!(BitPacked::from_bytes(&bytes[..5]).is_err());
    }

    #[test]
    fn packed_bytes_roundtrip_all_widths_adversarial_lengths() {
        // Word-straddling widths (3/5/6/7 bits) are the dangerous cases:
        // codes cross u64 boundaries, and the final byte is partial for
        // most lengths.  Exercise every width over lengths chosen to land
        // on and around word/byte boundaries.
        for bits in 1u8..=8 {
            let maxcode = (1u32 << bits) - 1;
            for &len in &[1usize, 2, 3, 7, 8, 9, 21, 63, 64, 65, 127, 128, 129, 1000] {
                let codes: Vec<u32> = (0..len)
                    .map(|i| (i as u32).wrapping_mul(2654435761) & maxcode)
                    .collect();
                let p = BitPacked::pack(&codes, bits).unwrap();
                let wire = p.packed_bytes();
                assert_eq!(
                    wire.len(),
                    (len * bits as usize).div_ceil(8),
                    "bits={bits} len={len}: wire not byte-exact"
                );
                let q = BitPacked::from_packed_bytes(bits, len, &wire).unwrap();
                assert_eq!(q, p, "bits={bits} len={len}: struct mismatch");
                assert_eq!(q.unpack(), codes, "bits={bits} len={len}: code mismatch");
            }
        }
    }

    #[test]
    fn from_packed_bytes_validates_geometry() {
        let p = BitPacked::pack(&[1, 2, 3, 4, 5], 3).unwrap();
        let wire = p.packed_bytes();
        assert!(BitPacked::from_packed_bytes(0, 5, &wire).is_err());
        assert!(BitPacked::from_packed_bytes(9, 5, &wire).is_err());
        assert!(BitPacked::from_packed_bytes(3, 6, &wire).is_err());
        assert!(BitPacked::from_packed_bytes(3, 5, &wire[..1]).is_err());
    }

    #[test]
    fn from_packed_bytes_clears_stray_tail_bits() {
        // 3 codes x 3 bits = 9 bits -> 2 bytes with 7 stray bits in the
        // second byte; a corrupted tail must not leak into equality.
        let p = BitPacked::pack(&[7, 0, 7], 3).unwrap();
        let mut wire = p.packed_bytes();
        wire[1] |= 0xF0;
        let q = BitPacked::from_packed_bytes(3, 3, &wire).unwrap();
        assert_eq!(q, p);
        assert_eq!(q.unpack(), vec![7, 0, 7]);
    }

    #[test]
    fn view_decodes_identically_to_owned_for_all_widths() {
        // The zero-copy view must agree with the owned container on every
        // width, including the word-straddling ones, over lengths landing
        // on and around byte/word boundaries.
        for bits in 1u8..=8 {
            let maxcode = (1u32 << bits) - 1;
            for &len in &[1usize, 7, 8, 9, 63, 64, 65, 129, 1000] {
                let codes: Vec<u32> = (0..len)
                    .map(|i| (i as u32).wrapping_mul(2654435761) & maxcode)
                    .collect();
                let p = BitPacked::pack(&codes, bits).unwrap();
                let wire = p.packed_bytes();
                let v = BitPackedView::new(bits, len, &wire).unwrap();
                assert_eq!(v.bits(), bits);
                assert_eq!(v.len(), len);
                let mut out = vec![0u32; len];
                v.unpack_into(&mut out);
                assert_eq!(out, codes, "bits={bits} len={len}: unpack mismatch");
                for (i, &c) in codes.iter().enumerate() {
                    assert_eq!(v.get(i), c, "bits={bits} len={len}: get({i})");
                }
                assert_eq!(v.to_owned(), p, "bits={bits} len={len}: to_owned");
            }
        }
    }

    #[test]
    fn range_unpack_matches_full_unpack_for_all_widths() {
        // Sharded decode must agree with the full decode for every width
        // (including the word-straddling ones) at ranges starting on and
        // off byte boundaries.
        for bits in 1u8..=8 {
            let maxcode = (1u32 << bits) - 1;
            let len = 301usize;
            let codes: Vec<u32> = (0..len)
                .map(|i| (i as u32).wrapping_mul(2654435761) & maxcode)
                .collect();
            let p = BitPacked::pack(&codes, bits).unwrap();
            let wire = p.packed_bytes();
            let v = BitPackedView::new(bits, len, &wire).unwrap();
            for &(start, count) in
                &[(0usize, len), (1, 7), (3, 64), (8, 100), (64, 237), (299, 2), (150, 0)]
            {
                let mut out = vec![0u32; count];
                v.unpack_range_into(start, &mut out);
                assert_eq!(
                    out,
                    &codes[start..start + count],
                    "bits={bits} range=[{start}, +{count})"
                );
            }
        }
    }

    #[test]
    fn range_unpack_rejects_out_of_bounds() {
        let p = BitPacked::pack(&[1, 2, 3, 4, 5], 3).unwrap();
        let wire = p.packed_bytes();
        let v = BitPackedView::new(3, 5, &wire).unwrap();
        let r = std::panic::catch_unwind(|| {
            let mut out = vec![0u32; 3];
            v.unpack_range_into(4, &mut out);
        });
        assert!(r.is_err(), "range past len must panic, not decode garbage");
    }

    #[test]
    fn view_ignores_stray_tail_bits() {
        // 3 codes x 3 bits = 9 bits -> 2 bytes, 7 stray bits; the view
        // must mask them out on read without mutating the source bytes.
        let p = BitPacked::pack(&[7, 0, 7], 3).unwrap();
        let mut wire = p.packed_bytes();
        wire[1] |= 0xF0;
        let v = BitPackedView::new(3, 3, &wire).unwrap();
        let mut out = vec![0u32; 3];
        v.unpack_into(&mut out);
        assert_eq!(out, vec![7, 0, 7]);
        assert_eq!(v.to_owned(), p);
    }

    #[test]
    fn view_validates_geometry() {
        let p = BitPacked::pack(&[1, 2, 3, 4, 5], 3).unwrap();
        let wire = p.packed_bytes();
        assert!(BitPackedView::new(0, 5, &wire).is_err());
        assert!(BitPackedView::new(9, 5, &wire).is_err());
        assert!(BitPackedView::new(3, 6, &wire).is_err());
        assert!(BitPackedView::new(3, 5, &wire[..1]).is_err());
        assert!(BitPackedView::new(3, usize::MAX, &wire).is_err());
    }

    #[test]
    fn iter_matches_unpack() {
        let codes: Vec<u32> = (0..77).map(|i| (i * 7 % 32) as u32).collect();
        let p = BitPacked::pack(&codes, 5).unwrap();
        let via_iter: Vec<u32> = p.iter().collect();
        assert_eq!(via_iter, p.unpack());
    }
}
