//! Asymmetric affine quantization (paper Eq. 1-2).
//!
//! Mirrors `python/compile/kernels/ref.py` bit-for-bit (same degenerate-
//! range handling, same rounding), so codes produced here are exchangeable
//! with the AOT Pallas quantize artifact — an equivalence the integration
//! tests assert through PJRT.

use anyhow::{bail, Result};

/// Scale / zero-point pair mapping [min, max] onto [0, 2^bits - 1].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AffineParams {
    pub scale: f32,
    pub zp: f32,
    pub bits: u8,
}

impl AffineParams {
    /// Maximum code value (2^bits - 1).
    #[inline]
    pub fn qmax(&self) -> f32 {
        ((1u32 << self.bits) - 1) as f32
    }

    /// Compute parameters from a value range (Eq. 1).
    ///
    /// Degenerate range (constant tensor c): scale = |c| (or 1 if c == 0)
    /// so the constant reconstructs exactly — matches ref.py.
    pub fn from_range(min: f32, max: f32, bits: u8) -> Result<Self> {
        if !(1..=8).contains(&bits) {
            bail!("bits must be in 1..=8, got {bits}");
        }
        if !min.is_finite() || !max.is_finite() || min > max {
            bail!("invalid range [{min}, {max}]");
        }
        let qmax = ((1u32 << bits) - 1) as f32;
        let span = max - min;
        let scale = if span > 0.0 {
            span / qmax
        } else if min.abs() > 0.0 {
            min.abs()
        } else {
            1.0
        };
        let zp = (-min / scale).round();
        Ok(Self { scale, zp, bits })
    }

    /// Parameters for a data slice (per-tensor granularity).
    pub fn from_slice(data: &[f32], bits: u8) -> Result<Self> {
        if data.is_empty() {
            bail!("cannot quantize empty tensor");
        }
        let (lo, hi) = crate::util::stats::min_max(data);
        Self::from_range(lo, hi, bits)
    }

    /// Quantize one value to its integer code.
    ///
    /// `f32::round` lowers to a libm call on baseline x86-64 (no SSE4.1
    /// roundss) and dominated the quantization profile; the biased
    /// truncating cast below computes the identical round-half-away
    /// result with two cheap vectorizable ops.
    #[inline]
    pub fn quantize_value(&self, x: f32) -> u32 {
        let y = x / self.scale;
        // round-half-away == f32::round, via truncating cast (no libm).
        let r = (y + 0.5f32.copysign(y)) as i32 as f32;
        (r + self.zp).clamp(0.0, self.qmax()) as u32
    }

    /// Dequantize one code (Eq. 2).
    #[inline]
    pub fn dequantize_code(&self, q: u32) -> f32 {
        self.scale * (q as f32 - self.zp)
    }

    /// Quantize a slice into codes.  Hot path for checkpoint quantization:
    /// hoists the reciprocal so the loop is mul+round+clamp (divides are
    /// an order of magnitude slower than multiplies and don't pipeline).
    pub fn quantize_slice(&self, data: &[f32]) -> Vec<u32> {
        let inv = 1.0 / self.scale;
        let zp = self.zp;
        let qmax = self.qmax();
        data.iter()
            .map(|&x| {
                let y = x * inv;
                let r = (y + 0.5f32.copysign(y)) as i32 as f32;
                (r + zp).clamp(0.0, qmax) as u32
            })
            .collect()
    }

    /// [`quantize_slice`](Self::quantize_slice) into an existing buffer
    /// (no per-group allocation on the checkpoint-quantization path).
    pub fn quantize_extend(&self, data: &[f32], out: &mut Vec<u32>) {
        let inv = 1.0 / self.scale;
        let zp = self.zp;
        let qmax = self.qmax();
        out.extend(data.iter().map(|&x| {
            let y = x * inv;
            let r = (y + 0.5f32.copysign(y)) as i32 as f32;
            (r + zp).clamp(0.0, qmax) as u32
        }));
    }

    /// Upper bound on the rounding error |x - dq(q(x))| for in-range x
    /// (Eq. 3): scale / 2.
    #[inline]
    pub fn error_bound(&self) -> f32 {
        self.scale / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, gen_vec, Config};

    #[test]
    fn rejects_bad_inputs() {
        assert!(AffineParams::from_range(0.0, 1.0, 0).is_err());
        assert!(AffineParams::from_range(0.0, 1.0, 9).is_err());
        assert!(AffineParams::from_range(1.0, 0.0, 4).is_err());
        assert!(AffineParams::from_range(f32::NAN, 1.0, 4).is_err());
        assert!(AffineParams::from_slice(&[], 4).is_err());
    }

    #[test]
    fn codes_cover_full_range() {
        let p = AffineParams::from_range(-1.0, 1.0, 2).unwrap();
        assert_eq!(p.quantize_value(-1.0), 0);
        assert_eq!(p.quantize_value(1.0), 3);
        // midpoint maps near the middle codes
        let mid = p.quantize_value(0.0);
        assert!(mid == 1 || mid == 2);
    }

    #[test]
    fn roundtrip_error_within_eq3_bound() {
        check(
            Config { cases: 100, seed: 0xE93 },
            |rng| {
                let bits = 1 + rng.below(8) as u8;
                let v = gen_vec(rng, 300, 0.05);
                (bits, v)
            },
            |(bits, v)| {
                let p = AffineParams::from_slice(v, *bits).map_err(|e| e.to_string())?;
                let bound = p.error_bound() * (1.0 + 1e-4) + 1e-7;
                for &x in v {
                    let xh = p.dequantize_code(p.quantize_value(x));
                    if (x - xh).abs() > bound {
                        return Err(format!(
                            "bits={bits} x={x} xh={xh} err={} bound={bound}",
                            (x - xh).abs()
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn constant_tensor_reconstructs_exactly() {
        for c in [0.017f32, -3.5, 0.0] {
            let p = AffineParams::from_slice(&[c, c, c], 2).unwrap();
            let xh = p.dequantize_code(p.quantize_value(c));
            assert!((xh - c).abs() < 1e-6, "c={c} xh={xh}");
        }
    }

    #[test]
    fn narrower_range_gives_smaller_error_bound() {
        // The paper's key observation: error bound scales with range.
        let wide = AffineParams::from_range(-1.0, 1.0, 3).unwrap();
        let narrow = AffineParams::from_range(-0.1, 0.1, 3).unwrap();
        assert!(narrow.error_bound() < wide.error_bound());
        assert!((wide.error_bound() / narrow.error_bound() - 10.0).abs() < 1e-4);
    }

    #[test]
    fn matches_python_ref_numerically() {
        // Golden values computed with ref.py: x in [-0.2, 0.6], bits=3.
        let p = AffineParams::from_range(-0.2, 0.6, 3).unwrap();
        assert!((p.scale - 0.8 / 7.0).abs() < 1e-7);
        assert_eq!(p.zp, 2.0); // round(0.2 / (0.8/7)) = round(1.75) = 2
        assert_eq!(p.quantize_value(0.0), 2);
        assert_eq!(p.quantize_value(0.6), 7);
        assert_eq!(p.quantize_value(-0.2), 0);
    }
}
