//! x86_64 kernels: AVX2 (8-wide) and SSE4.1 (4-wide).
//!
//! Every function here replays the scalar op sequence lane-by-lane —
//! separate multiply and add, never an FMA intrinsic (rustc does not
//! contract the scalar loops, so a fused kernel would round
//! differently) — and the masked-scatter kernel blends the *original*
//! output bits back into untouched lanes rather than adding zeros
//! (adding `lam * 0.0` would turn `-0.0` into `+0.0`).  See the module
//! docs in [`super`] for the full determinism argument.
//!
//! # Safety
//!
//! All functions are `#[target_feature]`-gated and must only be called
//! after the matching `is_x86_feature_detected!` check — the dispatchers
//! in [`super`] guarantee that (kernels come from `active()` /
//! `detected()` / a validated `TVQ_SIMD` parse).

use std::arch::x86_64::*;

use super::tables;
use crate::quant::bitpack::unpack_blocks_scalar;

// ---------------------------------------------------------------------------
// AVX2
// ---------------------------------------------------------------------------

/// Decode full 8-code blocks for widths 1/2/4 (one broadcast word,
/// per-lane variable shifts) and width 8 (byte zero-extension); odd
/// widths fall back to the scalar block decoder.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn unpack_blocks_avx2(bits: u8, bytes: &[u8], out: &mut [u32]) -> usize {
    let (bpb, mask, shifts): (usize, i32, __m256i) = match bits {
        1 => (1, 0x1, _mm256_setr_epi32(0, 1, 2, 3, 4, 5, 6, 7)),
        2 => (2, 0x3, _mm256_setr_epi32(0, 2, 4, 6, 8, 10, 12, 14)),
        4 => (4, 0xF, _mm256_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28)),
        8 => {
            let n = (out.len() / 8).min(bytes.len() / 8);
            for i in 0..n {
                let v = _mm_loadl_epi64(bytes.as_ptr().add(i * 8) as *const __m128i);
                let w = _mm256_cvtepu8_epi32(v);
                _mm256_storeu_si256(out.as_mut_ptr().add(i * 8) as *mut __m256i, w);
            }
            return n * 8;
        }
        _ => return unpack_blocks_scalar(bits, bytes, out),
    };
    let mask8 = _mm256_set1_epi32(mask);
    // `bpb` little-endian bytes hold 8 codes (8 * bits bits); broadcast
    // them as one word and shift each lane to its own code.
    let n = (out.len() / 8).min(bytes.len() / bpb);
    for i in 0..n {
        let mut w = 0u32;
        for (s, &b) in bytes[i * bpb..(i + 1) * bpb].iter().enumerate() {
            w |= (b as u32) << (8 * s);
        }
        let v = _mm256_srlv_epi32(_mm256_set1_epi32(w as i32), shifts);
        _mm256_storeu_si256(
            out.as_mut_ptr().add(i * 8) as *mut __m256i,
            _mm256_and_si256(v, mask8),
        );
    }
    n * 8
}

/// `dst[i] += a * codes[i] + b`, 8 lanes at a time.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn axpy_affine_avx2(a: f32, b: f32, codes: &[u32], dst: &mut [f32]) {
    let a8 = _mm256_set1_ps(a);
    let b8 = _mm256_set1_ps(b);
    let n = dst.len() / 8 * 8;
    for i in (0..n).step_by(8) {
        let c = _mm256_loadu_si256(codes.as_ptr().add(i) as *const __m256i);
        // Codes are <= 255, so the signed epi32 convert equals `c as f32`.
        let cf = _mm256_cvtepi32_ps(c);
        let t = _mm256_add_ps(_mm256_mul_ps(a8, cf), b8);
        let d = _mm256_loadu_ps(dst.as_ptr().add(i));
        _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_add_ps(d, t));
    }
    super::axpy_affine_scalar(a, b, &codes[n..], &mut dst[n..]);
}

/// `out[i] = scale * (codes[i] - zp)`, 8 lanes at a time.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn dequant_affine_avx2(scale: f32, zp: f32, codes: &[u32], out: &mut [f32]) {
    let s8 = _mm256_set1_ps(scale);
    let z8 = _mm256_set1_ps(zp);
    let n = out.len() / 8 * 8;
    for i in (0..n).step_by(8) {
        let c = _mm256_loadu_si256(codes.as_ptr().add(i) as *const __m256i);
        let cf = _mm256_cvtepi32_ps(c);
        let t = _mm256_sub_ps(cf, z8);
        _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(s8, t));
    }
    super::dequant_affine_scalar(scale, zp, &codes[n..], &mut out[n..]);
}

/// Masked survivor scatter: per mask byte, expand the next `popcount`
/// survivor values into their bit lanes (rank table + permute), compute
/// `out + lam * val` on all 8, and blend so only survivor lanes change.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn sparse_scatter_axpy_avx2(
    lam: f32,
    mask: &[u8],
    vals: &[f32],
    first_rank: usize,
    out: &mut [f32],
) {
    let lam8 = _mm256_set1_ps(lam);
    let mut rank = first_rank;
    for (bi, &byte) in mask.iter().enumerate() {
        if byte == 0 {
            continue;
        }
        let o = bi * 8;
        if o + 8 <= out.len() && rank + 8 <= vals.len() {
            let m = byte as usize;
            let idx = _mm256_loadu_si256(tables::EXPAND_IDX[m].as_ptr() as *const __m256i);
            let keep = _mm256_loadu_si256(tables::LANE_MASK[m].as_ptr() as *const __m256i);
            // The window read may cover up to 8 - popcount slack floats
            // past this byte's survivors; those lanes are blended away.
            let window = _mm256_loadu_ps(vals.as_ptr().add(rank));
            let expanded = _mm256_permutevar8x32_ps(window, idx);
            let orig = _mm256_loadu_ps(out.as_ptr().add(o));
            let sum = _mm256_add_ps(orig, _mm256_mul_ps(lam8, expanded));
            let res = _mm256_blendv_ps(orig, sum, _mm256_castsi256_ps(keep));
            _mm256_storeu_ps(out.as_mut_ptr().add(o), res);
            rank += byte.count_ones() as usize;
        } else {
            // Final partial output byte / exhausted slack: scalar walk.
            let mut b = byte;
            while b != 0 {
                let bit = b.trailing_zeros() as usize;
                out[o + bit] += lam * vals[rank];
                rank += 1;
                b &= b - 1;
            }
        }
    }
}

/// One-group signed accumulate: `out[j] += ±a` from the sign bitmap,
/// whole sign bytes as `xor(a, flip_row)` + add, scalar at the edges.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn signed_axpy_avx2(a: f32, signs: &[u8], start: usize, out: &mut [f32]) {
    let h = ((8 - start % 8) % 8).min(out.len());
    super::signed_axpy_scalar(a, signs, start, &mut out[..h]);
    let a8 = _mm256_set1_ps(a);
    let mut j = h;
    while j + 8 <= out.len() {
        let byte = signs[(start + j) / 8] as usize;
        let flip = _mm256_loadu_si256(tables::SIGN_FLIP[byte].as_ptr() as *const __m256i);
        let v = _mm256_xor_ps(a8, _mm256_castsi256_ps(flip));
        let d = _mm256_loadu_ps(out.as_ptr().add(j));
        _mm256_storeu_ps(out.as_mut_ptr().add(j), _mm256_add_ps(d, v));
        j += 8;
    }
    super::signed_axpy_scalar(a, signs, start + j, &mut out[j..]);
}

// ---------------------------------------------------------------------------
// SSE4.1
// ---------------------------------------------------------------------------

/// Decode full blocks for width 4 (nibble split + byte interleave, 16
/// codes per 8 bytes) and width 8 (byte zero-extension); widths 1/2 and
/// the odd widths fall back to the scalar block decoder (the AVX2
/// variable-shift trick has no cheap SSE equivalent).
#[target_feature(enable = "sse4.1")]
pub(super) unsafe fn unpack_blocks_sse41(bits: u8, bytes: &[u8], out: &mut [u32]) -> usize {
    match bits {
        4 => {
            let lo_mask = _mm_set1_epi8(0x0F);
            let n = (out.len() / 16).min(bytes.len() / 8);
            for i in 0..n {
                let v = _mm_loadl_epi64(bytes.as_ptr().add(i * 8) as *const __m128i);
                let lo = _mm_and_si128(v, lo_mask);
                let hi = _mm_and_si128(_mm_srli_epi16::<4>(v), lo_mask);
                // lo0,hi0,lo1,hi1,... == c0,c1,c2,c3,... in stream order.
                let inter = _mm_unpacklo_epi8(lo, hi);
                widen_16_bytes(inter, out.as_mut_ptr().add(i * 16));
            }
            n * 16
        }
        8 => {
            let n = (out.len() / 16).min(bytes.len() / 16);
            for i in 0..n {
                let v = _mm_loadu_si128(bytes.as_ptr().add(i * 16) as *const __m128i);
                widen_16_bytes(v, out.as_mut_ptr().add(i * 16));
            }
            n * 16
        }
        _ => unpack_blocks_scalar(bits, bytes, out),
    }
}

/// Zero-extend 16 packed byte codes to 16 u32s.
#[target_feature(enable = "sse4.1")]
unsafe fn widen_16_bytes(v: __m128i, out: *mut u32) {
    _mm_storeu_si128(out as *mut __m128i, _mm_cvtepu8_epi32(v));
    _mm_storeu_si128(out.add(4) as *mut __m128i, _mm_cvtepu8_epi32(_mm_srli_si128::<4>(v)));
    _mm_storeu_si128(out.add(8) as *mut __m128i, _mm_cvtepu8_epi32(_mm_srli_si128::<8>(v)));
    _mm_storeu_si128(out.add(12) as *mut __m128i, _mm_cvtepu8_epi32(_mm_srli_si128::<12>(v)));
}

/// `dst[i] += a * codes[i] + b`, 4 lanes at a time.
#[target_feature(enable = "sse4.1")]
pub(super) unsafe fn axpy_affine_sse41(a: f32, b: f32, codes: &[u32], dst: &mut [f32]) {
    let a4 = _mm_set1_ps(a);
    let b4 = _mm_set1_ps(b);
    let n = dst.len() / 4 * 4;
    for i in (0..n).step_by(4) {
        let c = _mm_loadu_si128(codes.as_ptr().add(i) as *const __m128i);
        let cf = _mm_cvtepi32_ps(c);
        let t = _mm_add_ps(_mm_mul_ps(a4, cf), b4);
        let d = _mm_loadu_ps(dst.as_ptr().add(i));
        _mm_storeu_ps(dst.as_mut_ptr().add(i), _mm_add_ps(d, t));
    }
    super::axpy_affine_scalar(a, b, &codes[n..], &mut dst[n..]);
}

/// `out[i] = scale * (codes[i] - zp)`, 4 lanes at a time.
#[target_feature(enable = "sse4.1")]
pub(super) unsafe fn dequant_affine_sse41(scale: f32, zp: f32, codes: &[u32], out: &mut [f32]) {
    let s4 = _mm_set1_ps(scale);
    let z4 = _mm_set1_ps(zp);
    let n = out.len() / 4 * 4;
    for i in (0..n).step_by(4) {
        let c = _mm_loadu_si128(codes.as_ptr().add(i) as *const __m128i);
        let cf = _mm_cvtepi32_ps(c);
        let t = _mm_sub_ps(cf, z4);
        _mm_storeu_ps(out.as_mut_ptr().add(i), _mm_mul_ps(s4, t));
    }
    super::dequant_affine_scalar(scale, zp, &codes[n..], &mut out[n..]);
}

/// Survivor scatter: saturated (0xFF) mask bytes — the common case for
/// mild sparsity — take two 4-wide axpys; partial bytes walk bits
/// exactly like the scalar kernel.
#[target_feature(enable = "sse4.1")]
pub(super) unsafe fn sparse_scatter_axpy_sse41(
    lam: f32,
    mask: &[u8],
    vals: &[f32],
    first_rank: usize,
    out: &mut [f32],
) {
    let lam4 = _mm_set1_ps(lam);
    let mut rank = first_rank;
    for (bi, &byte) in mask.iter().enumerate() {
        let o = bi * 8;
        if byte == 0xFF && o + 8 <= out.len() && rank + 8 <= vals.len() {
            for half in 0..2 {
                let p = o + half * 4;
                let v = _mm_loadu_ps(vals.as_ptr().add(rank + half * 4));
                let d = _mm_loadu_ps(out.as_ptr().add(p));
                _mm_storeu_ps(out.as_mut_ptr().add(p), _mm_add_ps(d, _mm_mul_ps(lam4, v)));
            }
            rank += 8;
        } else {
            let mut b = byte;
            while b != 0 {
                let bit = b.trailing_zeros() as usize;
                out[o + bit] += lam * vals[rank];
                rank += 1;
                b &= b - 1;
            }
        }
    }
}

/// One-group signed accumulate, two 4-lane halves per sign byte.
#[target_feature(enable = "sse4.1")]
pub(super) unsafe fn signed_axpy_sse41(a: f32, signs: &[u8], start: usize, out: &mut [f32]) {
    let h = ((8 - start % 8) % 8).min(out.len());
    super::signed_axpy_scalar(a, signs, start, &mut out[..h]);
    let a4 = _mm_set1_ps(a);
    let mut j = h;
    while j + 8 <= out.len() {
        let byte = signs[(start + j) / 8] as usize;
        let row = tables::SIGN_FLIP[byte].as_ptr();
        for half in 0..2 {
            let flip = _mm_loadu_si128(row.add(half * 4) as *const __m128i);
            let v = _mm_xor_ps(a4, _mm_castsi128_ps(flip));
            let d = _mm_loadu_ps(out.as_ptr().add(j + half * 4));
            _mm_storeu_ps(out.as_mut_ptr().add(j + half * 4), _mm_add_ps(d, v));
        }
        j += 8;
    }
    super::signed_axpy_scalar(a, signs, start + j, &mut out[j..]);
}
