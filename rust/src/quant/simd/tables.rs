//! Per-mask-byte lookup tables for the vector kernels, built at compile
//! time.  All three are indexed by one LSB-first mask/sign byte and give
//! one 8-lane row (lane `j` = bit `j`), so a kernel turns a byte of
//! bitmap into vector operands with a single unaligned row load.

/// Sparse survivor expansion: lane `j` holds the *rank offset* of bit
/// `j` within its byte (the popcount of bits `0..j`) when bit `j` is
/// set, else 0.  `permute(vals_window, row)` then places `vals[rank]`
/// into each survivor lane; non-survivor lanes pick up garbage that the
/// blend discards.
#[cfg(target_arch = "x86_64")]
pub(super) static EXPAND_IDX: [[u32; 8]; 256] = build_expand_idx();

#[cfg(target_arch = "x86_64")]
const fn build_expand_idx() -> [[u32; 8]; 256] {
    let mut t = [[0u32; 8]; 256];
    let mut m = 0usize;
    while m < 256 {
        let mut rank = 0u32;
        let mut j = 0usize;
        while j < 8 {
            if (m >> j) & 1 == 1 {
                t[m][j] = rank;
                rank += 1;
            }
            j += 1;
        }
        m += 1;
    }
    t
}

/// Survivor lane mask: all-ones where the bit is set, zero elsewhere —
/// the blend selector that writes computed lanes and preserves the
/// exact original bits of untouched lanes.
#[cfg(target_arch = "x86_64")]
pub(super) static LANE_MASK: [[u32; 8]; 256] = build_lane_mask();

#[cfg(target_arch = "x86_64")]
const fn build_lane_mask() -> [[u32; 8]; 256] {
    let mut t = [[0u32; 8]; 256];
    let mut m = 0usize;
    while m < 256 {
        let mut j = 0usize;
        while j < 8 {
            if (m >> j) & 1 == 1 {
                t[m][j] = u32::MAX;
            }
            j += 1;
        }
        m += 1;
    }
    t
}

/// 1-bit sign expansion: lane `j` is `0` where the sign bit is set
/// (element reconstructs as `+a`) and the f32 sign-bit mask
/// `0x8000_0000` where clear (`-a`).  XOR-ing a broadcast `a` with a
/// row computes `±a` as an exact bit flip — identical to the scalar
/// `-a` for every value including NaN and denormal scales.
pub(super) static SIGN_FLIP: [[u32; 8]; 256] = build_sign_flip();

const fn build_sign_flip() -> [[u32; 8]; 256] {
    let mut t = [[0u32; 8]; 256];
    let mut m = 0usize;
    while m < 256 {
        let mut j = 0usize;
        while j < 8 {
            if (m >> j) & 1 == 0 {
                t[m][j] = 0x8000_0000;
            }
            j += 1;
        }
        m += 1;
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sign_flip_rows_match_bit_semantics() {
        for m in 0usize..256 {
            for j in 0..8 {
                let want = if (m >> j) & 1 == 1 { 0 } else { 0x8000_0000 };
                assert_eq!(SIGN_FLIP[m][j], want, "byte {m:#04x} lane {j}");
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn expand_rows_are_prefix_popcounts() {
        for m in 0usize..256 {
            let mut rank = 0u32;
            for j in 0..8 {
                if (m >> j) & 1 == 1 {
                    assert_eq!(EXPAND_IDX[m][j], rank, "byte {m:#04x} lane {j}");
                    assert_eq!(LANE_MASK[m][j], u32::MAX);
                    rank += 1;
                } else {
                    assert_eq!(LANE_MASK[m][j], 0);
                }
            }
            assert_eq!(rank, (m as u8).count_ones());
        }
    }
}
