//! aarch64 NEON kernels (4-wide f32).
//!
//! Same lane-exactness rules as the x86 kernels: explicit `vmul` +
//! `vadd` pairs (never `vmla`/`vfma`, which fuse), `±a` as a sign-bit
//! XOR, and scalar fallbacks wherever a vector path would have to
//! change the op sequence.  See [`super`] for the determinism contract.
//!
//! # Safety
//!
//! `#[target_feature(enable = "neon")]` — NEON is baseline on aarch64,
//! but callers still route through the detected-kernel dispatchers.

use std::arch::aarch64::*;

use super::tables;
use crate::quant::bitpack::unpack_blocks_scalar;

/// Zero-extend 8 byte codes to 8 u32s.
#[target_feature(enable = "neon")]
unsafe fn widen_8_bytes(v: uint8x8_t, out: *mut u32) {
    let w = vmovl_u8(v);
    vst1q_u32(out, vmovl_u16(vget_low_u16(w)));
    vst1q_u32(out.add(4), vmovl_u16(vget_high_u16(w)));
}

/// Decode full blocks for width 4 (nibble split + zip, 16 codes per 8
/// bytes) and width 8 (byte zero-extension); other widths fall back to
/// the scalar block decoder.
#[target_feature(enable = "neon")]
pub(super) unsafe fn unpack_blocks_neon(bits: u8, bytes: &[u8], out: &mut [u32]) -> usize {
    match bits {
        4 => {
            let n = (out.len() / 16).min(bytes.len() / 8);
            for i in 0..n {
                let v = vld1_u8(bytes.as_ptr().add(i * 8));
                let lo = vand_u8(v, vdup_n_u8(0x0F));
                let hi = vshr_n_u8::<4>(v);
                // lo0,hi0,lo1,hi1,... == c0,c1,c2,c3,... in stream order.
                let z = vzip_u8(lo, hi);
                widen_8_bytes(z.0, out.as_mut_ptr().add(i * 16));
                widen_8_bytes(z.1, out.as_mut_ptr().add(i * 16 + 8));
            }
            n * 16
        }
        8 => {
            let n = (out.len() / 8).min(bytes.len() / 8);
            for i in 0..n {
                let v = vld1_u8(bytes.as_ptr().add(i * 8));
                widen_8_bytes(v, out.as_mut_ptr().add(i * 8));
            }
            n * 8
        }
        _ => unpack_blocks_scalar(bits, bytes, out),
    }
}

/// `dst[i] += a * codes[i] + b`, 4 lanes at a time.
#[target_feature(enable = "neon")]
pub(super) unsafe fn axpy_affine_neon(a: f32, b: f32, codes: &[u32], dst: &mut [f32]) {
    let a4 = vdupq_n_f32(a);
    let b4 = vdupq_n_f32(b);
    let n = dst.len() / 4 * 4;
    for i in (0..n).step_by(4) {
        let c = vld1q_u32(codes.as_ptr().add(i));
        // Codes are <= 255: the unsigned convert equals `c as f32`.
        let cf = vcvtq_f32_u32(c);
        let t = vaddq_f32(vmulq_f32(a4, cf), b4);
        let d = vld1q_f32(dst.as_ptr().add(i));
        vst1q_f32(dst.as_mut_ptr().add(i), vaddq_f32(d, t));
    }
    super::axpy_affine_scalar(a, b, &codes[n..], &mut dst[n..]);
}

/// `out[i] = scale * (codes[i] - zp)`, 4 lanes at a time.
#[target_feature(enable = "neon")]
pub(super) unsafe fn dequant_affine_neon(scale: f32, zp: f32, codes: &[u32], out: &mut [f32]) {
    let s4 = vdupq_n_f32(scale);
    let z4 = vdupq_n_f32(zp);
    let n = out.len() / 4 * 4;
    for i in (0..n).step_by(4) {
        let c = vld1q_u32(codes.as_ptr().add(i));
        let cf = vcvtq_f32_u32(c);
        let t = vsubq_f32(cf, z4);
        vst1q_f32(out.as_mut_ptr().add(i), vmulq_f32(s4, t));
    }
    super::dequant_affine_scalar(scale, zp, &codes[n..], &mut out[n..]);
}

/// Survivor scatter: saturated (0xFF) mask bytes take two 4-wide axpys;
/// partial bytes walk bits exactly like the scalar kernel.
#[target_feature(enable = "neon")]
pub(super) unsafe fn sparse_scatter_axpy_neon(
    lam: f32,
    mask: &[u8],
    vals: &[f32],
    first_rank: usize,
    out: &mut [f32],
) {
    let lam4 = vdupq_n_f32(lam);
    let mut rank = first_rank;
    for (bi, &byte) in mask.iter().enumerate() {
        let o = bi * 8;
        if byte == 0xFF && o + 8 <= out.len() && rank + 8 <= vals.len() {
            for half in 0..2 {
                let p = o + half * 4;
                let v = vld1q_f32(vals.as_ptr().add(rank + half * 4));
                let d = vld1q_f32(out.as_ptr().add(p));
                vst1q_f32(out.as_mut_ptr().add(p), vaddq_f32(d, vmulq_f32(lam4, v)));
            }
            rank += 8;
        } else {
            let mut b = byte;
            while b != 0 {
                let bit = b.trailing_zeros() as usize;
                out[o + bit] += lam * vals[rank];
                rank += 1;
                b &= b - 1;
            }
        }
    }
}

/// One-group signed accumulate, two 4-lane halves per sign byte.
#[target_feature(enable = "neon")]
pub(super) unsafe fn signed_axpy_neon(a: f32, signs: &[u8], start: usize, out: &mut [f32]) {
    let h = ((8 - start % 8) % 8).min(out.len());
    super::signed_axpy_scalar(a, signs, start, &mut out[..h]);
    let a4 = vreinterpretq_u32_f32(vdupq_n_f32(a));
    let mut j = h;
    while j + 8 <= out.len() {
        let byte = signs[(start + j) / 8] as usize;
        let row = tables::SIGN_FLIP[byte].as_ptr();
        for half in 0..2 {
            let flip = vld1q_u32(row.add(half * 4));
            let v = vreinterpretq_f32_u32(veorq_u32(a4, flip));
            let d = vld1q_f32(out.as_ptr().add(j + half * 4));
            vst1q_f32(out.as_mut_ptr().add(j + half * 4), vaddq_f32(d, v));
        }
        j += 8;
    }
    super::signed_axpy_scalar(a, signs, start + j, &mut out[j..]);
}
