//! Runtime-dispatched SIMD kernels for the four serve-path primitives.
//!
//! Since PR 5 the pool fans fused merge across disjoint output shards,
//! but every per-shard inner loop — low-bit unpack, group dequant-axpy,
//! sparse scatter-axpy, 1-bit sign-axpy — was scalar Rust, so
//! single-core throughput capped the fleet.  This module adds explicit
//! `#[target_feature]` kernels (AVX2 and SSE4.1 on x86_64, NEON on
//! aarch64) behind a table chosen **once** at startup and threaded
//! through [`ExecCtx`](crate::util::exec::ExecCtx).
//!
//! # Determinism contract
//!
//! The PR-5 contract — bit-identical f32 output at every thread count —
//! extends here to *any thread count × any kernel*: every SIMD kernel
//! must produce **bit-identical** output to the scalar path, which stays
//! the reference (`threads=1 × scalar`).  This is possible because all
//! four primitives are purely elementwise: accumulation across tasks
//! happens sequentially in the caller's per-task loop, and no kernel
//! performs a cross-lane reduction.  Each SIMD lane therefore issues the
//! *same IEEE-754 op sequence* as the scalar loop for its element:
//!
//! * unpack: integer shift/mask — exact by construction;
//! * group axpy: `t = a * code; t = t + b; d = d + t` (never a fused
//!   multiply-add intrinsic — rustc does not contract the scalar form,
//!   so an FMA kernel would round differently);
//! * dequant: `t = code - zp; o = scale * t`;
//! * binary: `±a` is a sign-bit XOR (exact for every value, including
//!   NaN scales) followed by one add;
//! * sparse scatter: the masked-scatter kernels blend **original
//!   output bits** back into untouched lanes — adding `lam * 0.0`
//!   would flip `-0.0` to `+0.0` and break exactness.
//!
//! Group boundaries make lane-order preservation cheap: dense shards are
//! group-aligned and sparse/binary shards are mask-byte-aligned (PR-5
//! geometry), so per-group coefficients change only at positions a
//! vector never straddles mid-register without the kernel re-deriving
//! them exactly as the scalar loop would.
//!
//! `rust/tests/simd_parity.rs` pins the contract for every kernel
//! [`detected`] on the running machine; `TVQ_SIMD=off|sse4|avx2|neon`
//! overrides the automatic choice for A/B testing.

use std::sync::OnceLock;

#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
mod tables;
#[cfg(target_arch = "x86_64")]
mod x86;

/// One decode-kernel implementation.  Values are only ever produced for
/// kernels the running CPU supports (see [`active`] / [`detected`] /
/// [`Kernel::parse`]); the dispatchers debug-assert availability before
/// entering a `#[target_feature]` body.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Portable scalar loops — the determinism reference on every arch.
    Scalar,
    /// x86_64 SSE4.1: 4-wide f32, nibble/byte unpack.
    Sse41,
    /// x86_64 AVX2: 8-wide f32, variable-shift unpack, masked scatter.
    Avx2,
    /// aarch64 NEON: 4-wide f32, nibble/byte unpack.
    Neon,
}

impl Kernel {
    /// Stable lowercase label (bench rows, logs, `TVQ_SIMD` values).
    pub fn label(&self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Sse41 => "sse4",
            Kernel::Avx2 => "avx2",
            Kernel::Neon => "neon",
        }
    }

    /// Whether this kernel can run on the current CPU.
    pub fn is_available(&self) -> bool {
        match self {
            Kernel::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Kernel::Sse41 => std::arch::is_x86_feature_detected!("sse4.1"),
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }

    /// Parse a `TVQ_SIMD` value; `None` means "auto" (best available).
    /// Unknown or unavailable selections fall back to auto so a stale
    /// env var can never wedge serving (the caller warns).
    fn parse(v: &str) -> Option<Kernel> {
        match v.trim().to_ascii_lowercase().as_str() {
            "off" | "scalar" | "0" => Some(Kernel::Scalar),
            "sse4" | "sse4.1" | "sse41" => Some(Kernel::Sse41),
            "avx2" => Some(Kernel::Avx2),
            "neon" => Some(Kernel::Neon),
            _ => None,
        }
    }
}

/// Best kernel the CPU supports, in preference order.
fn best_available() -> Kernel {
    for k in [Kernel::Avx2, Kernel::Neon, Kernel::Sse41] {
        if k.is_available() {
            return k;
        }
    }
    Kernel::Scalar
}

/// The process-wide kernel choice, resolved exactly once: the `TVQ_SIMD`
/// override if set, valid, and available on this CPU, else the best
/// detected kernel.  Every [`ExecCtx`](crate::util::exec::ExecCtx)
/// defaults to this, so all serve paths agree on one kernel unless a
/// caller pins another explicitly.
pub fn active() -> Kernel {
    static ACTIVE: OnceLock<Kernel> = OnceLock::new();
    *ACTIVE.get_or_init(|| match std::env::var("TVQ_SIMD") {
        Err(_) => best_available(),
        Ok(v) if v.trim().is_empty() || v.trim().eq_ignore_ascii_case("auto") => best_available(),
        Ok(v) => match Kernel::parse(&v) {
            Some(k) if k.is_available() => k,
            Some(k) => {
                eprintln!(
                    "tvq: TVQ_SIMD={v} requests the {} kernel, which this CPU \
                     does not support; using {}",
                    k.label(),
                    best_available().label()
                );
                best_available()
            }
            None => {
                eprintln!(
                    "tvq: unknown TVQ_SIMD value {v:?} (want off|sse4|avx2|neon|auto); \
                     using {}",
                    best_available().label()
                );
                best_available()
            }
        },
    })
}

/// Every kernel usable on this machine, scalar first — the set the
/// parity suite checks against the scalar reference.
pub fn detected() -> Vec<Kernel> {
    let mut out = vec![Kernel::Scalar];
    for k in [Kernel::Sse41, Kernel::Avx2, Kernel::Neon] {
        if k.is_available() {
            out.push(k);
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Dispatched primitives.  Each takes the kernel explicitly (callers get it
// from `ExecCtx::kernel()`); the scalar arms are the exact loops the quant
// views ran before this module existed.
// ---------------------------------------------------------------------------

/// Decode leading whole byte-blocks of `out` from `bytes` (codes of
/// `bits` width, LSB-first), returning how many codes were written.  The
/// caller finishes the ragged tail code-by-code, so a kernel may stop at
/// any block multiple it likes; every decoded prefix is exact integers,
/// identical across kernels.  Odd widths (3/5/6/7) always take the
/// scalar block decoder.
pub fn unpack_blocks(k: Kernel, bits: u8, bytes: &[u8], out: &mut [u32]) -> usize {
    debug_assert!(k.is_available());
    match k {
        Kernel::Scalar => super::bitpack::unpack_blocks_scalar(bits, bytes, out),
        #[cfg(target_arch = "x86_64")]
        Kernel::Sse41 => unsafe { x86::unpack_blocks_sse41(bits, bytes, out) },
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => unsafe { x86::unpack_blocks_avx2(bits, bytes, out) },
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => unsafe { neon::unpack_blocks_neon(bits, bytes, out) },
        #[allow(unreachable_patterns)]
        _ => super::bitpack::unpack_blocks_scalar(bits, bytes, out),
    }
}

/// `dst[i] += a * codes[i] + b` — the fused group-axpy inner loop.  The
/// per-group coefficients `a = lam * scale`, `b = -a * zp` are computed
/// by the caller exactly as the scalar path always has.
pub fn axpy_affine(k: Kernel, a: f32, b: f32, codes: &[u32], dst: &mut [f32]) {
    debug_assert!(k.is_available());
    debug_assert_eq!(codes.len(), dst.len());
    match k {
        Kernel::Scalar => axpy_affine_scalar(a, b, codes, dst),
        #[cfg(target_arch = "x86_64")]
        Kernel::Sse41 => unsafe { x86::axpy_affine_sse41(a, b, codes, dst) },
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => unsafe { x86::axpy_affine_avx2(a, b, codes, dst) },
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => unsafe { neon::axpy_affine_neon(a, b, codes, dst) },
        #[allow(unreachable_patterns)]
        _ => axpy_affine_scalar(a, b, codes, dst),
    }
}

#[inline]
pub(crate) fn axpy_affine_scalar(a: f32, b: f32, codes: &[u32], dst: &mut [f32]) {
    for (d, &c) in dst.iter_mut().zip(codes) {
        *d += a * c as f32 + b;
    }
}

/// `out[i] = scale * (codes[i] - zp)` — the group dequantize inner loop.
pub fn dequant_affine(k: Kernel, scale: f32, zp: f32, codes: &[u32], out: &mut [f32]) {
    debug_assert!(k.is_available());
    debug_assert_eq!(codes.len(), out.len());
    match k {
        Kernel::Scalar => dequant_affine_scalar(scale, zp, codes, out),
        #[cfg(target_arch = "x86_64")]
        Kernel::Sse41 => unsafe { x86::dequant_affine_sse41(scale, zp, codes, out) },
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => unsafe { x86::dequant_affine_avx2(scale, zp, codes, out) },
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => unsafe { neon::dequant_affine_neon(scale, zp, codes, out) },
        #[allow(unreachable_patterns)]
        _ => dequant_affine_scalar(scale, zp, codes, out),
    }
}

#[inline]
pub(crate) fn dequant_affine_scalar(scale: f32, zp: f32, codes: &[u32], out: &mut [f32]) {
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = scale * (c as f32 - zp);
    }
}

/// Sparse scatter-accumulate over one mask-byte-aligned dense range:
/// for each set bit `j` of `mask[bi]`, `out[bi*8 + j] += lam * vals[r]`
/// where `r` starts at `first_rank` and increments in ascending
/// bit order.  `out` may end mid-byte (the final partial mask byte);
/// masked-out lanes keep their exact original bits.  The AVX2 kernel
/// reads an 8-float `vals` window per byte — callers over-allocate
/// `vals` by [`SPARSE_VALS_SLACK`] so the window never runs off the end
/// (the kernel still guards and falls back per-byte, so any geometry is
/// memory-safe).
pub fn sparse_scatter_axpy(
    k: Kernel,
    lam: f32,
    mask: &[u8],
    vals: &[f32],
    first_rank: usize,
    out: &mut [f32],
) {
    debug_assert!(k.is_available());
    debug_assert!(out.len() <= mask.len() * 8);
    match k {
        Kernel::Scalar => sparse_scatter_axpy_scalar(lam, mask, vals, first_rank, out),
        #[cfg(target_arch = "x86_64")]
        Kernel::Sse41 => unsafe { x86::sparse_scatter_axpy_sse41(lam, mask, vals, first_rank, out) },
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => unsafe { x86::sparse_scatter_axpy_avx2(lam, mask, vals, first_rank, out) },
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => unsafe { neon::sparse_scatter_axpy_neon(lam, mask, vals, first_rank, out) },
        #[allow(unreachable_patterns)]
        _ => sparse_scatter_axpy_scalar(lam, mask, vals, first_rank, out),
    }
}

/// Extra f32 slots callers append to a survivor-values scratch so the
/// vector kernels' 8-wide window loads stay in bounds on the last group.
/// The slack is never *indexed* (only lanes blended away read it), so
/// its contents are irrelevant.
pub const SPARSE_VALS_SLACK: usize = 8;

#[inline]
pub(crate) fn sparse_scatter_axpy_scalar(
    lam: f32,
    mask: &[u8],
    vals: &[f32],
    first_rank: usize,
    out: &mut [f32],
) {
    let mut r = first_rank;
    for (bi, &byte) in mask.iter().enumerate() {
        let mut b = byte;
        while b != 0 {
            let bit = b.trailing_zeros() as usize;
            out[bi * 8 + bit] += lam * vals[r];
            r += 1;
            b &= b - 1;
        }
    }
}

/// 1-bit signed accumulate over one group's dense element range:
/// `out[j] += if sign_bit(start + j) { a } else { -a }`, sign bits read
/// LSB-first from `signs` at absolute element indices.  The caller has
/// already folded `a = lam * scale(group)`, so all elements of the call
/// share one coefficient; kernels handle bit-cursor alignment
/// internally (scalar lead-in/tail around whole sign bytes).
pub fn signed_axpy(k: Kernel, a: f32, signs: &[u8], start: usize, out: &mut [f32]) {
    debug_assert!(k.is_available());
    debug_assert!(start + out.len() <= signs.len() * 8);
    match k {
        Kernel::Scalar => signed_axpy_scalar(a, signs, start, out),
        #[cfg(target_arch = "x86_64")]
        Kernel::Sse41 => unsafe { x86::signed_axpy_sse41(a, signs, start, out) },
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => unsafe { x86::signed_axpy_avx2(a, signs, start, out) },
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => unsafe { neon::signed_axpy_neon(a, signs, start, out) },
        #[allow(unreachable_patterns)]
        _ => signed_axpy_scalar(a, signs, start, out),
    }
}

#[inline]
pub(crate) fn signed_axpy_scalar(a: f32, signs: &[u8], start: usize, out: &mut [f32]) {
    for (j, o) in out.iter_mut().enumerate() {
        let i = start + j;
        let bit = (signs[i / 8] >> (i % 8)) & 1;
        *o += if bit == 1 { a } else { -a };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_round_trip_through_parse() {
        for k in [Kernel::Scalar, Kernel::Sse41, Kernel::Avx2, Kernel::Neon] {
            assert_eq!(Kernel::parse(k.label()), Some(k), "{}", k.label());
        }
        assert_eq!(Kernel::parse("off"), Some(Kernel::Scalar));
        assert_eq!(Kernel::parse("SSE4.1"), Some(Kernel::Sse41));
        assert_eq!(Kernel::parse("bogus"), None);
        assert_eq!(Kernel::parse("auto"), None, "auto is handled before parse");
    }

    #[test]
    fn detected_always_includes_scalar_and_only_available_kernels() {
        let ks = detected();
        assert_eq!(ks[0], Kernel::Scalar);
        for k in &ks {
            assert!(k.is_available(), "{} listed but unavailable", k.label());
        }
        assert!(ks.len() <= 3, "at most scalar + two per-arch kernels");
    }

    #[test]
    fn active_is_stable_and_available() {
        let a = active();
        assert!(a.is_available());
        assert_eq!(active(), a, "OnceLock: one choice per process");
    }

    #[test]
    fn scalar_primitives_match_reference_loops() {
        // The scalar arms ARE the reference; pin their arithmetic shape
        // so a refactor can't silently change the op order every SIMD
        // kernel mirrors.
        let codes = [0u32, 3, 7, 255, 128, 1, 64, 9, 2];
        let mut dst = vec![0.5f32; codes.len()];
        axpy_affine(Kernel::Scalar, 0.25, -0.75, &codes, &mut dst);
        for (i, &c) in codes.iter().enumerate() {
            assert_eq!(dst[i], 0.5 + (0.25 * c as f32 + -0.75));
        }
        let mut out = vec![0.0f32; codes.len()];
        dequant_affine(Kernel::Scalar, 0.125, 3.5, &codes, &mut out);
        for (i, &c) in codes.iter().enumerate() {
            assert_eq!(out[i], 0.125 * (c as f32 - 3.5));
        }
        // Signed axpy: -a must be an exact sign flip.
        let signs = [0b1010_0110u8, 0xFF];
        let mut acc = vec![1.0f32; 10];
        signed_axpy(Kernel::Scalar, 0.5, &signs, 3, &mut acc);
        for (j, &v) in acc.iter().enumerate() {
            let i = 3 + j;
            let bit = (signs[i / 8] >> (i % 8)) & 1;
            assert_eq!(v, 1.0 + if bit == 1 { 0.5 } else { -0.5 });
        }
        // Sparse scatter: untouched positions keep their bits (incl. -0.0).
        let mask = [0b0000_0101u8];
        let vals = [10.0f32, 20.0];
        let mut o = vec![-0.0f32; 8];
        sparse_scatter_axpy(Kernel::Scalar, 1.0, &mask, &vals, 0, &mut o);
        assert_eq!(o[0], 10.0);
        assert_eq!(o[2], 20.0);
        assert!(o[1].is_sign_negative() && o[1] == 0.0, "untouched lane keeps -0.0");
    }
}
