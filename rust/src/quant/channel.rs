//! Per-channel (a.k.a. per-row) asymmetric quantization.
//!
//! The paper quantizes per tensor; finer granularities trade metadata for
//! error.  This module provides the per-output-channel variant common in
//! deployment stacks (one (scale, zp) per leading-dimension row of a 2-D
//! weight), used by the granularity ablation (`tvq experiment ablG`):
//!
//!   per-tensor (1 pair)  <  per-group (N/g pairs)  <  per-channel (rows)
//!
//! in metadata cost, and the reverse in quantization error.

use anyhow::{bail, Result};

use super::affine::AffineParams;
use super::bitpack::BitPacked;
use crate::tensor::Tensor;

/// A 2-D tensor quantized with one affine pair per row.
#[derive(Clone, Debug)]
pub struct ChannelQuantized {
    pub bits: u8,
    pub rows: usize,
    pub cols: usize,
    pub params: Vec<AffineParams>,
    pub codes: BitPacked,
}

impl ChannelQuantized {
    /// Quantize a `[rows, cols]` tensor row-wise at `bits`.
    pub fn quantize(t: &Tensor, bits: u8) -> Result<Self> {
        if t.shape().len() != 2 {
            bail!("per-channel quantization needs a 2-D tensor, got {:?}", t.shape());
        }
        let (rows, cols) = (t.shape()[0], t.shape()[1]);
        let mut params = Vec::with_capacity(rows);
        let mut codes = Vec::with_capacity(rows * cols);
        for row in t.data().chunks_exact(cols) {
            let p = AffineParams::from_slice(row, bits)?;
            p.quantize_extend(row, &mut codes);
            params.push(p);
        }
        Ok(Self { bits, rows, cols, params, codes: BitPacked::pack(&codes, bits)? })
    }

    /// Reconstruct the full-precision tensor.
    pub fn dequantize(&self) -> Result<Tensor> {
        let mut codes = vec![0u32; self.rows * self.cols];
        self.codes.unpack_into(&mut codes);
        let mut data = Vec::with_capacity(codes.len());
        for (ri, chunk) in codes.chunks_exact(self.cols).enumerate() {
            let p = &self.params[ri];
            data.extend(chunk.iter().map(|&c| p.dequantize_code(c)));
        }
        Tensor::new(vec![self.rows, self.cols], data)
    }

    /// Exact storage: packed codes + one (scale, zp) pair per row.
    pub fn storage_bytes(&self) -> usize {
        self.codes.storage_bytes() + self.rows * 8
    }

    /// L2 reconstruction error against the source tensor.
    pub fn quant_error(&self, src: &Tensor) -> Result<f64> {
        let dq = self.dequantize()?;
        Ok(crate::util::stats::l2_dist(src.data(), dq.data()))
    }
}

/// Quantization granularity for the ablation experiment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    PerTensor,
    PerGroup(usize),
    PerChannel,
}

impl Granularity {
    pub fn label(&self) -> String {
        match self {
            Granularity::PerTensor => "per-tensor".into(),
            Granularity::PerGroup(g) => format!("per-group({g})"),
            Granularity::PerChannel => "per-channel".into(),
        }
    }
}

/// Quantize a flat view of `t` under `gran` at `bits`; returns
/// (l2 error, exact storage bytes).  The granularity ablation's kernel.
pub fn quantize_error_storage(t: &Tensor, bits: u8, gran: Granularity) -> Result<(f64, usize)> {
    match gran {
        Granularity::PerTensor => {
            let p = AffineParams::from_slice(t.data(), bits)?;
            let codes = p.quantize_slice(t.data());
            let packed = BitPacked::pack(&codes, bits)?;
            let err: f64 = t
                .data()
                .iter()
                .zip(&codes)
                .map(|(&x, &c)| {
                    let d = (x - p.dequantize_code(c)) as f64;
                    d * d
                })
                .sum();
            Ok((err.sqrt(), packed.storage_bytes() + 8))
        }
        Granularity::PerGroup(g) => {
            // Shared with the planner's sensitivity probe: pad to a
            // multiple of g (zeros quantize free), quantize, measure SSE.
            let gq = super::group::GroupQuantized::quantize_padded(t.data(), bits, g)?;
            Ok((gq.sse_against(t.data()).sqrt(), gq.storage_bytes()))
        }
        Granularity::PerChannel => {
            let cq = ChannelQuantized::quantize(t, bits)?;
            Ok((cq.quant_error(t)?, cq.storage_bytes()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tensor_with_hot_row() -> Tensor {
        // Row 0 has a 10x wider range: per-channel should isolate it.
        let mut rng = Rng::new(3);
        let mut t = Tensor::randn(&[8, 64], 0.01, &mut rng);
        for v in t.data_mut()[..64].iter_mut() {
            *v *= 10.0;
        }
        t
    }

    #[test]
    fn rejects_non_2d() {
        assert!(ChannelQuantized::quantize(&Tensor::zeros(&[8]), 4).is_err());
        assert!(ChannelQuantized::quantize(&Tensor::zeros(&[2, 2, 2]), 4).is_err());
    }

    #[test]
    fn per_channel_beats_per_tensor_on_outlier_rows() {
        let t = tensor_with_hot_row();
        let (e_tensor, _) = quantize_error_storage(&t, 3, Granularity::PerTensor).unwrap();
        let (e_chan, _) = quantize_error_storage(&t, 3, Granularity::PerChannel).unwrap();
        assert!(
            e_chan < 0.8 * e_tensor,
            "per-channel {e_chan} should be well below per-tensor {e_tensor}"
        );
    }

    #[test]
    fn granularity_storage_ordering() {
        let t = tensor_with_hot_row();
        let (_, s_tensor) = quantize_error_storage(&t, 3, Granularity::PerTensor).unwrap();
        let (_, s_group) =
            quantize_error_storage(&t, 3, Granularity::PerGroup(64)).unwrap();
        let (_, s_chan) = quantize_error_storage(&t, 3, Granularity::PerChannel).unwrap();
        assert!(s_tensor < s_chan);
        assert_eq!(s_group, s_chan); // group=64 == row length here
    }

    #[test]
    fn per_group_arm_matches_planner_probe_arithmetic() {
        // The ablation's per-group path and the planner probe both go
        // through GroupQuantized::quantize_padded/sse_against now; pin
        // that the ablation output equals the probe-style computation.
        let t = tensor_with_hot_row();
        let g = 48; // deliberately not dividing 8*64
        let (err, bytes) = quantize_error_storage(&t, 3, Granularity::PerGroup(g)).unwrap();
        let mut padded = t.data().to_vec();
        padded.resize(padded.len().div_ceil(g) * g, 0.0);
        let gq = super::super::group::GroupQuantized::quantize(&padded, 3, g).unwrap();
        let sse: f64 = t
            .data()
            .iter()
            .zip(gq.dequantize())
            .map(|(&x, y)| ((x - y) as f64).powi(2))
            .sum();
        assert!((err - sse.sqrt()).abs() < 1e-12, "{err} vs {}", sse.sqrt());
        assert_eq!(bytes, gq.storage_bytes());
    }

    #[test]
    fn roundtrip_within_per_row_bound() {
        let t = tensor_with_hot_row();
        let cq = ChannelQuantized::quantize(&t, 4).unwrap();
        let dq = cq.dequantize().unwrap();
        for (ri, (row, back)) in t
            .data()
            .chunks_exact(64)
            .zip(dq.data().chunks_exact(64))
            .enumerate()
        {
            let bound = cq.params[ri].error_bound() + 1e-6;
            for (a, b) in row.iter().zip(back) {
                assert!((a - b).abs() <= bound, "row {ri}: |{a}-{b}| > {bound}");
            }
        }
    }

    #[test]
    fn storage_accounts_metadata() {
        let t = Tensor::zeros(&[4, 16]);
        let cq = ChannelQuantized::quantize(&t, 2).unwrap();
        // 64 codes at 2 bits = 16 bytes payload + 4 rows * 8 B metadata.
        assert_eq!(cq.storage_bytes(), 16 + 32);
    }
}
