//! Sparse group quantization: a bitmask over a dense index space plus
//! group-quantized survivor values.
//!
//! This is the payload behind the planner's sparse candidate arms (DARE
//! drop-and-rescale, arXiv 2402.09997, and TALL-mask task localization,
//! arXiv 2405.07813): large fractions of a task vector carry no task
//! information, so masked-out weights are stored at **0 bits** — one mask
//! bit each — and only the survivors pay for quantized codes.  Survivors
//! are kept in ascending dense-index order, zero-padded up to a multiple
//! of the group width, and quantized with the same [`GroupQuantized`]
//! machinery the dense arms use, so the planner's byte arithmetic stays
//! exact.
//!
//! On disk this is the `QTVC` kind-4 section (see `docs/WIRE_FORMAT.md`);
//! the wire codec lives in [`crate::registry::container`].

use anyhow::{bail, Result};

use super::group::{GroupQuantized, GroupQuantizedView};

/// Structural invariants shared by the owned container and the borrowed
/// view: both funnel through here so a corrupt section fails closed with
/// the same error no matter which decode path touched it first.
fn validate_parts(
    dense_len: usize,
    n_survivors: usize,
    mask: &[u8],
    survivor_len: usize,
    group: usize,
) -> Result<()> {
    if dense_len == 0 {
        bail!("sparse payload: zero dense length");
    }
    if n_survivors == 0 || n_survivors > dense_len {
        bail!(
            "sparse payload: survivor count {n_survivors} outside 1..={dense_len}"
        );
    }
    if mask.len() != dense_len.div_ceil(8) {
        bail!(
            "sparse payload: truncated bitmask ({} bytes for dense length \
             {dense_len}, expected {})",
            mask.len(),
            dense_len.div_ceil(8)
        );
    }
    let pop: usize = mask.iter().map(|b| b.count_ones() as usize).sum();
    if pop != n_survivors {
        bail!(
            "sparse payload: bitmask/survivor-count mismatch (mask has {pop} \
             set bits, header claims {n_survivors})"
        );
    }
    // Tail bits past dense_len must be clear (they would otherwise
    // scatter out of bounds).
    if dense_len % 8 != 0 {
        let tail = mask[mask.len() - 1] >> (dense_len % 8);
        if tail != 0 {
            bail!("sparse payload: mask bits set past dense length {dense_len}");
        }
    }
    if survivor_len != n_survivors.div_ceil(group) * group {
        bail!(
            "sparse payload: survivor vector length {survivor_len} does not \
             match {n_survivors} survivors padded to group {group}"
        );
    }
    Ok(())
}

/// Scatter-accumulate survivors: `out[i] += lam * surv[s]` for each set
/// mask bit, walking set bits byte-at-a-time.  Shared by the owned and
/// borrowed serve paths.
#[inline]
fn scatter_axpy(mask: &[u8], surv: &[f32], n_survivors: usize, lam: f32, out: &mut [f32]) {
    let mut s = 0usize;
    for (byte_i, &byte) in mask.iter().enumerate() {
        let mut b = byte;
        while b != 0 {
            let bit = b.trailing_zeros() as usize;
            out[byte_i * 8 + bit] += lam * surv[s];
            s += 1;
            b &= b - 1;
        }
    }
    debug_assert_eq!(s, n_survivors);
}

/// A sparse flat vector: `dense_len` logical f32s of which `n_survivors`
/// are stored (group-quantized); the rest reconstruct as exactly 0.0.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseGroupQuantized {
    /// Logical (dense, padded) length the mask covers.
    pub dense_len: usize,
    /// Number of set bits in `mask` == number of stored survivor values.
    pub n_survivors: usize,
    /// LSB-first bitmask, `ceil(dense_len / 8)` bytes; bit `i` set means
    /// dense index `i` is a survivor.  Bits past `dense_len` must be 0.
    pub mask: Vec<u8>,
    /// Survivor values in ascending dense-index order, zero-padded to a
    /// multiple of `survivors.group` and group-quantized.
    pub survivors: GroupQuantized,
}

impl SparseGroupQuantized {
    /// Assemble from parts, validating every structural invariant — the
    /// wire decoder funnels through here so corrupt sections fail closed.
    pub fn new(
        dense_len: usize,
        n_survivors: usize,
        mask: Vec<u8>,
        survivors: GroupQuantized,
    ) -> Result<Self> {
        validate_parts(dense_len, n_survivors, &mask, survivors.len(), survivors.group)?;
        Ok(Self { dense_len, n_survivors, mask, survivors })
    }

    /// Quantize the `keep` subset of `data` (ascending, unique dense
    /// indices) at `bits`, scaling every survivor by `rescale` first
    /// (DARE's 1/(1-p); 1.0 for plain localization masks).
    pub fn quantize_indices(
        data: &[f32],
        keep: &[usize],
        rescale: f32,
        bits: u8,
        group: usize,
    ) -> Result<Self> {
        if keep.is_empty() {
            bail!("sparse quantization needs at least one survivor");
        }
        let mut mask = vec![0u8; data.len().div_ceil(8)];
        let mut vals = Vec::with_capacity(keep.len());
        let mut last = None;
        for &i in keep {
            if i >= data.len() {
                bail!("survivor index {i} out of range ({} elements)", data.len());
            }
            if last.is_some_and(|l| i <= l) {
                bail!("survivor indices must be ascending and unique");
            }
            last = Some(i);
            mask[i / 8] |= 1 << (i % 8);
            vals.push(data[i] * rescale);
        }
        let survivors = GroupQuantized::quantize_padded(&vals, bits, group)?;
        Self::new(data.len(), keep.len(), mask, survivors)
    }

    pub fn bits(&self) -> u8 {
        self.survivors.bits
    }

    pub fn group(&self) -> usize {
        self.survivors.group
    }

    /// Reconstruct the dense vector: 0.0 everywhere except survivors.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dense_len];
        self.dequantize_into(&mut out);
        out
    }

    /// Reconstruct into a caller buffer (overwrites all of `out`).
    pub fn dequantize_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.dense_len);
        out.fill(0.0);
        self.axpy_into(1.0, out);
    }

    /// Fused serve path: `out[i] += lam * value_i` for every survivor —
    /// masked-out positions are untouched, so a merge accumulates sparse
    /// tasks without materializing their dense reconstruction.
    pub fn axpy_into(&self, lam: f32, out: &mut [f32]) {
        assert_eq!(out.len(), self.dense_len);
        let surv = self.survivors.dequantize();
        scatter_axpy(&self.mask, &surv, self.n_survivors, lam, out);
    }

    /// Exact in-memory storage bytes: mask + survivor codes + affine params.
    pub fn storage_bytes(&self) -> usize {
        self.mask.len() + self.survivors.storage_bytes()
    }
}

/// A borrowed, zero-copy view over a sparse section body: the bitmask and
/// the survivor payload both stay in the backing bytes (the registry's
/// file mapping); only the dequantized survivor values are materialized,
/// into a caller-owned scratch reused across sections.  Construction runs
/// the exact same structural validation as [`SparseGroupQuantized::new`],
/// so corrupt sections fail closed identically on either path.
#[derive(Clone, Copy, Debug)]
pub struct SparseGroupQuantizedView<'a> {
    dense_len: usize,
    n_survivors: usize,
    mask: &'a [u8],
    survivors: GroupQuantizedView<'a>,
}

impl<'a> SparseGroupQuantizedView<'a> {
    pub fn new(
        dense_len: usize,
        n_survivors: usize,
        mask: &'a [u8],
        survivors: GroupQuantizedView<'a>,
    ) -> Result<Self> {
        validate_parts(dense_len, n_survivors, mask, survivors.len(), survivors.group())?;
        Ok(Self { dense_len, n_survivors, mask, survivors })
    }

    #[inline]
    pub fn dense_len(&self) -> usize {
        self.dense_len
    }

    #[inline]
    pub fn n_survivors(&self) -> usize {
        self.n_survivors
    }

    #[inline]
    pub fn bits(&self) -> u8 {
        self.survivors.bits()
    }

    #[inline]
    pub fn group(&self) -> usize {
        self.survivors.group()
    }

    /// Fused serve path: `out[i] += lam * value_i` for every survivor.
    /// `codes_scratch` / `vals_scratch` are reused across sections.
    pub fn axpy_into(
        &self,
        lam: f32,
        out: &mut [f32],
        codes_scratch: &mut Vec<u32>,
        vals_scratch: &mut Vec<f32>,
    ) {
        assert_eq!(out.len(), self.dense_len);
        self.axpy_range_into(lam, 0, out, codes_scratch, vals_scratch);
    }

    /// Sharded scatter-accumulate over the process-wide active kernel:
    /// `out` covers the dense index range
    /// `[byte0 * 8, byte0 * 8 + out.len())`, which must start on a
    /// mask-byte boundary and end on one (or at `dense_len`) — the shard
    /// geometry the parallel fused merge carves.
    pub fn axpy_range_into(
        &self,
        lam: f32,
        byte0: usize,
        out: &mut [f32],
        codes_scratch: &mut Vec<u32>,
        vals_scratch: &mut Vec<f32>,
    ) {
        self.axpy_range_into_k(super::simd::active(), lam, byte0, out, codes_scratch, vals_scratch);
    }

    /// [`axpy_range_into`](Self::axpy_range_into) over an explicit
    /// kernel.  The shard's survivor values are located by prefix
    /// popcount and decoded through the group-range decoder, so each
    /// survivor gets the exact same `scale * (code - zp)` value as in
    /// the full pass ([`axpy_into`](Self::axpy_into) delegates here
    /// with the full range), and the scatter kernels touch survivor
    /// lanes with the exact scalar op pair (`mul`, `add`) while
    /// preserving the original bits of masked-out lanes: disjoint
    /// shards reproduce the full pass bit-for-bit on any kernel.
    pub fn axpy_range_into_k(
        &self,
        kernel: super::simd::Kernel,
        lam: f32,
        byte0: usize,
        out: &mut [f32],
        codes_scratch: &mut Vec<u32>,
        vals_scratch: &mut Vec<f32>,
    ) {
        let start = byte0 * 8;
        let end = start + out.len();
        assert!(end <= self.dense_len, "dense range [{start}, {end}) past {}", self.dense_len);
        assert!(
            end == self.dense_len || end % 8 == 0,
            "sparse shard must end on a mask-byte boundary or at dense_len"
        );
        // Survivor rank of the first in-range dense index.
        let s_lo: usize = self.mask[..byte0].iter().map(|b| b.count_ones() as usize).sum();
        let mask_range = &self.mask[byte0..end.div_ceil(8)];
        let in_range: usize = mask_range.iter().map(|b| b.count_ones() as usize).sum();
        if in_range == 0 {
            return;
        }
        // Decode exactly the survivor groups covering [s_lo, s_lo + n),
        // over-allocating the scratch by the vector window slack (the
        // slack is only read by lanes the scatter kernel blends away,
        // so its stale contents never reach the output).
        let group = self.survivors.group();
        let g0 = s_lo / group;
        let g1 = (s_lo + in_range).div_ceil(group);
        let need = (g1 - g0) * group;
        vals_scratch.resize(need + super::simd::SPARSE_VALS_SLACK, 0.0);
        self.survivors
            .dequantize_groups_into_k(kernel, g0, &mut vals_scratch[..need], codes_scratch);
        super::simd::sparse_scatter_axpy(
            kernel,
            lam,
            mask_range,
            vals_scratch,
            s_lo - g0 * group,
            out,
        );
    }

    /// Reconstruct into a caller buffer (overwrites all of `out`):
    /// 0.0 everywhere except survivors — bit-identical to
    /// [`SparseGroupQuantized::dequantize_into`].
    pub fn dequantize_into(
        &self,
        out: &mut [f32],
        codes_scratch: &mut Vec<u32>,
        vals_scratch: &mut Vec<f32>,
    ) {
        self.dequantize_into_k(super::simd::active(), out, codes_scratch, vals_scratch);
    }

    /// [`dequantize_into`](Self::dequantize_into) over an explicit
    /// kernel (the serve paths thread
    /// [`ExecCtx::kernel`](crate::util::exec::ExecCtx::kernel) here).
    pub fn dequantize_into_k(
        &self,
        kernel: super::simd::Kernel,
        out: &mut [f32],
        codes_scratch: &mut Vec<u32>,
        vals_scratch: &mut Vec<f32>,
    ) {
        assert_eq!(out.len(), self.dense_len);
        out.fill(0.0);
        self.axpy_range_into_k(kernel, 1.0, 0, out, codes_scratch, vals_scratch);
    }

    /// Materialize an owned [`SparseGroupQuantized`].
    pub fn to_owned(self) -> SparseGroupQuantized {
        SparseGroupQuantized {
            dense_len: self.dense_len,
            n_survivors: self.n_survivors,
            mask: self.mask.to_vec(),
            survivors: self.survivors.to_owned(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample(len: usize, keep_every: usize, seed: u64) -> (Vec<f32>, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0f32; len];
        rng.fill_normal(&mut v, 0.05);
        let keep: Vec<usize> = (0..len).step_by(keep_every).collect();
        (v, keep)
    }

    #[test]
    fn roundtrip_scatters_survivors_and_zeros_the_rest() {
        let (v, keep) = sample(1000, 3, 1);
        let s = SparseGroupQuantized::quantize_indices(&v, &keep, 1.0, 4, 64).unwrap();
        assert_eq!(s.n_survivors, keep.len());
        let dq = s.dequantize();
        assert_eq!(dq.len(), 1000);
        let mut ki = 0;
        for (i, &x) in dq.iter().enumerate() {
            if ki < keep.len() && keep[ki] == i {
                // Survivor: within the per-group quantization bound.
                assert!((x - v[i]).abs() < 0.05, "survivor {i}: {x} vs {}", v[i]);
                ki += 1;
            } else {
                assert_eq!(x, 0.0, "dropped index {i} must be exactly zero");
            }
        }
    }

    #[test]
    fn rescale_is_applied_to_survivors_only() {
        let v = vec![1.0f32, 2.0, 3.0, 4.0];
        let s = SparseGroupQuantized::quantize_indices(&v, &[1, 3], 2.0, 8, 2).unwrap();
        let dq = s.dequantize();
        assert_eq!(dq[0], 0.0);
        assert!((dq[1] - 4.0).abs() < 0.1);
        assert!((dq[3] - 8.0).abs() < 0.1);
    }

    #[test]
    fn axpy_accumulates_without_touching_dropped_positions() {
        let (v, keep) = sample(256, 2, 2);
        let s = SparseGroupQuantized::quantize_indices(&v, &keep, 1.0, 4, 64).unwrap();
        let mut out = vec![7.0f32; 256];
        s.axpy_into(0.5, &mut out);
        let dq = s.dequantize();
        for i in 0..256 {
            assert!((out[i] - (7.0 + 0.5 * dq[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn rejects_malformed_inputs() {
        let (v, _) = sample(64, 2, 3);
        // Empty / out-of-range / unsorted survivor sets.
        assert!(SparseGroupQuantized::quantize_indices(&v, &[], 1.0, 4, 16).is_err());
        assert!(SparseGroupQuantized::quantize_indices(&v, &[64], 1.0, 4, 16).is_err());
        assert!(SparseGroupQuantized::quantize_indices(&v, &[3, 1], 1.0, 4, 16).is_err());
        assert!(SparseGroupQuantized::quantize_indices(&v, &[1, 1], 1.0, 4, 16).is_err());

        let good = SparseGroupQuantized::quantize_indices(&v, &[0, 9], 1.0, 4, 16).unwrap();
        // Popcount mismatch.
        let mut bad_mask = good.mask.clone();
        bad_mask[0] |= 1 << 4;
        assert!(SparseGroupQuantized::new(64, 2, bad_mask, good.survivors.clone()).is_err());
        // Truncated mask.
        assert!(SparseGroupQuantized::new(
            64,
            2,
            good.mask[..4].to_vec(),
            good.survivors.clone()
        )
        .is_err());
        // Mask bit past the dense length.
        let mut tail_mask = vec![0u8; 1];
        tail_mask[0] = 0b1010_0000; // bits 5 and 7, dense_len = 6
        let surv = GroupQuantized::quantize_padded(&[1.0, 2.0], 4, 2).unwrap();
        assert!(SparseGroupQuantized::new(6, 2, tail_mask, surv.clone()).is_err());
        // Survivor-vector length not matching the padded survivor count.
        let long = GroupQuantized::quantize_padded(&[1.0; 40], 4, 8).unwrap();
        let mut mask = vec![0u8; 8];
        mask[0] = 0b11;
        assert!(SparseGroupQuantized::new(64, 2, mask, long).is_err());
    }

    /// Assemble a borrowed view over the owned container's parts.
    fn view_parts(s: &SparseGroupQuantized) -> (Vec<u8>, Vec<u8>) {
        let g = &s.survivors;
        let mut params = Vec::new();
        for &sc in &g.scales {
            params.extend_from_slice(&sc.to_le_bytes());
        }
        for &z in &g.zps {
            params.extend_from_slice(&z.to_le_bytes());
        }
        (params, g.codes.packed_bytes())
    }

    #[test]
    fn view_matches_owned_bit_exactly() {
        use crate::quant::BitPackedView;
        let (v, keep) = sample(1000, 3, 31);
        let s = SparseGroupQuantized::quantize_indices(&v, &keep, 1.0, 4, 64).unwrap();
        let (params, code_bytes) = view_parts(&s);
        let codes = BitPackedView::new(4, s.survivors.len(), &code_bytes).unwrap();
        let gview =
            GroupQuantizedView::new(4, 64, s.survivors.n_groups(), &params, codes).unwrap();
        let view =
            SparseGroupQuantizedView::new(s.dense_len, s.n_survivors, &s.mask, gview).unwrap();
        assert_eq!(view.dense_len(), 1000);
        assert_eq!(view.n_survivors(), keep.len());
        assert_eq!(view.bits(), 4);
        assert_eq!(view.group(), 64);

        let (mut codes_scratch, mut vals_scratch) = (Vec::new(), Vec::new());
        let mut got = vec![0.0f32; 1000];
        view.dequantize_into(&mut got, &mut codes_scratch, &mut vals_scratch);
        assert_eq!(got, s.dequantize(), "view reconstruction must be bit-exact");

        let mut acc = vec![2.0f32; 1000];
        let mut want = vec![2.0f32; 1000];
        view.axpy_into(0.5, &mut acc, &mut codes_scratch, &mut vals_scratch);
        s.axpy_into(0.5, &mut want);
        assert_eq!(acc, want, "view axpy must match the owned scatter path");

        assert_eq!(view.to_owned(), s);
    }

    #[test]
    fn range_scatter_matches_full_scatter_bit_exactly() {
        use crate::quant::BitPackedView;
        // Irregular survivor pattern: clustered + sparse stretches, so
        // shard boundaries cut through runs of set and clear bits.
        let mut rng = Rng::new(77);
        let mut v = vec![0.0f32; 1003];
        rng.fill_normal(&mut v, 0.05);
        let keep: Vec<usize> = (0..1003)
            .filter(|&i| i % 7 == 0 || (100..140).contains(&i))
            .collect();
        let s = SparseGroupQuantized::quantize_indices(&v, &keep, 1.3, 3, 64).unwrap();
        let (params, code_bytes) = view_parts(&s);
        let codes = BitPackedView::new(3, s.survivors.len(), &code_bytes).unwrap();
        let gview =
            GroupQuantizedView::new(3, 64, s.survivors.n_groups(), &params, codes).unwrap();
        let view =
            SparseGroupQuantizedView::new(s.dense_len, s.n_survivors, &s.mask, gview).unwrap();

        let (mut cs, mut vs) = (Vec::new(), Vec::new());
        let mut want = vec![0.25f32; 1003];
        view.axpy_into(0.5, &mut want, &mut cs, &mut vs);

        // Stitch from mask-byte-aligned shards of several widths; every
        // split must reproduce the full scatter exactly.
        for shard_bytes in [1usize, 3, 16, 126] {
            let mut got = vec![0.25f32; 1003];
            let mut byte0 = 0;
            while byte0 * 8 < 1003 {
                let lo = byte0 * 8;
                let hi = (lo + shard_bytes * 8).min(1003);
                view.axpy_range_into(0.5, byte0, &mut got[lo..hi], &mut cs, &mut vs);
                byte0 += shard_bytes;
            }
            assert_eq!(got, want, "shard_bytes={shard_bytes}: scatter diverged");
        }
    }

    #[test]
    fn view_validation_matches_owned() {
        use crate::quant::BitPackedView;
        let (v, keep) = sample(64, 4, 32);
        let s = SparseGroupQuantized::quantize_indices(&v, &keep, 1.0, 4, 16).unwrap();
        let (params, code_bytes) = view_parts(&s);
        let codes = BitPackedView::new(4, s.survivors.len(), &code_bytes).unwrap();
        let gview =
            GroupQuantizedView::new(4, 16, s.survivors.n_groups(), &params, codes).unwrap();
        // Popcount mismatch fails with the same message on both paths.
        let mut bad_mask = s.mask.clone();
        bad_mask[0] ^= 1 << 1;
        let view_err = SparseGroupQuantizedView::new(64, s.n_survivors, &bad_mask, gview)
            .unwrap_err()
            .to_string();
        let owned_err =
            SparseGroupQuantized::new(64, s.n_survivors, bad_mask, s.survivors.clone())
                .unwrap_err()
                .to_string();
        assert_eq!(view_err, owned_err);
        assert!(view_err.contains("bitmask/survivor-count mismatch"));
        // Truncated mask / shrunk dense length fail closed too.
        assert!(
            SparseGroupQuantizedView::new(64, s.n_survivors, &s.mask[..4], gview).is_err()
        );
        assert!(SparseGroupQuantizedView::new(8, s.n_survivors, &s.mask, gview).is_err());
    }

    #[test]
    fn storage_accounts_mask_and_survivors() {
        let (v, keep) = sample(128, 4, 4);
        let s = SparseGroupQuantized::quantize_indices(&v, &keep, 1.0, 2, 32).unwrap();
        assert_eq!(s.storage_bytes(), 16 + s.survivors.storage_bytes());
    }
}
