//! Storage accounting (paper Table 5).
//!
//! Computes exact and model-scaled storage for every quantization scheme,
//! including the ViT-L/14 projection used to compare against the paper's
//! 9.1 / 16.0 / 22.8 GB rows.

use super::QuantScheme;

/// Parameter counts for the paper's real backbones (for Table 5 scaling).
pub const VIT_B32_PARAMS: usize = 87_849_216;
pub const VIT_L14_PARAMS: usize = 303_966_208;

/// Storage accounting for storing `n_tasks` task payloads of a model with
/// `params` parameters under a given scheme.
#[derive(Clone, Copy, Debug)]
pub struct StorageReport {
    pub scheme: QuantScheme,
    pub n_tasks: usize,
    pub params: usize,
    pub bytes: u64,
}

impl StorageReport {
    /// Idealized (metadata-free) storage: what Table 5 reports.
    pub fn ideal(scheme: QuantScheme, n_tasks: usize, params: usize) -> Self {
        let bits_total: f64 = match scheme {
            QuantScheme::Fp32 => 32.0 * params as f64 * n_tasks as f64,
            QuantScheme::Fq(b) | QuantScheme::Tvq(b) => {
                b as f64 * params as f64 * n_tasks as f64
            }
            QuantScheme::Rtvq(bb, bo) => {
                // one base at bb bits + T offsets at bo bits
                bb as f64 * params as f64 + bo as f64 * params as f64 * n_tasks as f64
            }
        };
        Self {
            scheme,
            n_tasks,
            params,
            bytes: (bits_total / 8.0).ceil() as u64,
        }
    }

    pub fn gib(&self) -> f64 {
        self.bytes as f64 / (1024.0 * 1024.0 * 1024.0)
    }

    /// Fraction of the FP32 baseline.
    pub fn fraction_of_fp32(&self) -> f64 {
        let fp32 = StorageReport::ideal(QuantScheme::Fp32, self.n_tasks, self.params);
        self.bytes as f64 / fp32.bytes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp32_vit_l14_matches_paper_scale() {
        // Paper Table 5: 8 tasks of ViT-L/14 at FP32 ~= 9.1 GB
        // (1.14 GB per checkpoint).
        let r = StorageReport::ideal(QuantScheme::Fp32, 8, VIT_L14_PARAMS);
        assert!((r.gib() - 9.06).abs() < 0.2, "gib={}", r.gib());
        let r20 = StorageReport::ideal(QuantScheme::Fp32, 20, VIT_L14_PARAMS);
        assert!((r20.gib() - 22.65).abs() < 0.5, "gib={}", r20.gib());
    }

    #[test]
    fn int2_is_16x_reduction() {
        let fp32 = StorageReport::ideal(QuantScheme::Fp32, 20, VIT_L14_PARAMS);
        let int2 = StorageReport::ideal(QuantScheme::Tvq(2), 20, VIT_L14_PARAMS);
        let ratio = fp32.bytes as f64 / int2.bytes as f64;
        assert!((ratio - 16.0).abs() < 0.01, "ratio={ratio}");
        assert!((int2.fraction_of_fp32() - 0.0625).abs() < 1e-4);
    }

    #[test]
    fn rtvq_b3o2_fraction_matches_paper() {
        // Paper: B3O2 keeps ~7.5% of FP32 at 8 tasks... exact:
        // (3 + 2*8) / (32*8) = 19/256 = 7.42%
        let r = StorageReport::ideal(QuantScheme::Rtvq(3, 2), 8, VIT_L14_PARAMS);
        assert!((r.fraction_of_fp32() - 19.0 / 256.0).abs() < 1e-6);
        // And it sits between INT2 and INT3 TVQ.
        let int2 = StorageReport::ideal(QuantScheme::Tvq(2), 8, VIT_L14_PARAMS);
        let int3 = StorageReport::ideal(QuantScheme::Tvq(3), 8, VIT_L14_PARAMS);
        assert!(r.bytes > int2.bytes && r.bytes < int3.bytes);
    }

    #[test]
    fn rtvq_per_task_cost_falls_with_more_tasks() {
        let per_task = |t: usize| {
            StorageReport::ideal(QuantScheme::Rtvq(3, 2), t, 1_000_000).bytes as f64
                / t as f64
        };
        assert!(per_task(8) > per_task(14));
        assert!(per_task(14) > per_task(20));
    }
}
