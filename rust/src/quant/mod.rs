//! Task Vector Quantization — the paper's core contribution.
//!
//! * [`affine`] — asymmetric affine quantizer (Eq. 1-2) with the error
//!   bound of Eq. 3 as a checked invariant.
//! * [`bitpack`] — dense 1..=8-bit code containers (the actual storage).
//!   Each packed container has a borrowed twin (`BitPackedView`,
//!   `GroupQuantizedView`, `SparseGroupQuantizedView`) that decodes in
//!   place from wire bytes — the registry's zero-copy mmap serve path.
//! * [`tvq`] — per-tensor quantized checkpoints: quantize the *task
//!   vector* tau = theta_ft - theta_pre (TVQ, Section 4.2) or the full
//!   fine-tuned checkpoint (FQ baseline, Fig. 5a).
//! * [`rtvq`] — Residual Task Vector Quantization (Section 4.3 /
//!   Algorithm 1): shared base vector + per-task low-bit offsets, with
//!   quantization-error correction (Eq. 6).
//! * [`group`] — per-group quantization of flat parameter vectors, the
//!   layout consumed by the AOT Pallas dequant-merge artifacts.
//! * [`sparse`] — bitmask + group-quantized survivors, the payload behind
//!   the planner's DARE / TALL-mask sparse arms (kind-4 sections).
//! * [`binary`] — 1-bit sign bitmap + per-group scales, the payload
//!   behind the planner's OneBit arm and the serve-time dynamic-merge
//!   switches (kind-5 sections).
//! * [`fused`] — native fused dequantize-and-merge (the L3 hot path).
//! * [`simd`] — runtime-dispatched SIMD kernels behind the decode/axpy
//!   hot loops (AVX2 / SSE4.1 / NEON), bit-identical to the scalar
//!   reference on every lane.
//! * [`storage`] — exact storage accounting / effective bits-per-task.

pub mod affine;
pub mod binary;
pub mod bitpack;
pub mod channel;
pub mod fused;
pub mod group;
pub mod rtvq;
pub mod simd;
pub mod sparse;
pub mod storage;
pub mod tvq;

pub use affine::AffineParams;
pub use binary::{BinarySwitch, BinarySwitchView};
pub use bitpack::{BitPacked, BitPackedView};
pub use channel::{ChannelQuantized, Granularity};
pub use group::{GroupQuantized, GroupQuantizedView};
pub use rtvq::Rtvq;
pub use simd::Kernel;
pub use sparse::{SparseGroupQuantized, SparseGroupQuantizedView};
pub use storage::StorageReport;
pub use tvq::{QuantizedCheckpoint, QuantizedTensor, Tvq};

/// Which object is quantized — used by benches/experiments to label rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantScheme {
    /// Full-precision baseline (no quantization).
    Fp32,
    /// Fine-tuned checkpoint quantization (the paper's FQ baseline).
    Fq(u8),
    /// Task vector quantization at the given bit width.
    Tvq(u8),
    /// Residual TVQ with (base_bits, offset_bits).
    Rtvq(u8, u8),
}

impl QuantScheme {
    pub fn label(&self) -> String {
        match self {
            QuantScheme::Fp32 => "FP32".into(),
            QuantScheme::Fq(b) => format!("FQ{b}"),
            QuantScheme::Tvq(b) => format!("TVQ-INT{b}"),
            QuantScheme::Rtvq(bb, bo) => format!("RTVQ-B{bb}O{bo}"),
        }
    }

    /// Effective bits per task for `n_tasks` tasks (RTVQ amortizes the
    /// base vector: b_o + b_b / T, Section 4.3).
    pub fn effective_bits(&self, n_tasks: usize) -> f64 {
        match self {
            QuantScheme::Fp32 => 32.0,
            QuantScheme::Fq(b) | QuantScheme::Tvq(b) => *b as f64,
            QuantScheme::Rtvq(bb, bo) => *bo as f64 + *bb as f64 / n_tasks as f64,
        }
    }

    /// Parse a scheme spelling: `fp32`, `fq<b>`, `tvq<b>`, `rtvq<bb>o<bo>`.
    /// Also accepts the paper's `b3o2` shorthand for RTVQ and the exact
    /// [`label`](Self::label) spellings (`TVQ-INT3`, `RTVQ-B3O2`), so
    /// `parse(label())` round-trips for every scheme — registries persist
    /// labels and rely on this.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let s = s.trim().to_ascii_lowercase();
        let bits = |t: &str| -> anyhow::Result<u8> {
            let b: u8 = t.parse().map_err(|_| anyhow::anyhow!("bad bit width {t:?}"))?;
            if !(1..=8).contains(&b) {
                anyhow::bail!("bit width {b} out of range 1..=8");
            }
            Ok(b)
        };
        if s == "fp32" {
            Ok(QuantScheme::Fp32)
        } else if let Some(rest) = s.strip_prefix("rtvq") {
            // rtvq3o2 | rtvqb3o2 | rtvq-b3o2 (label spelling)
            let rest = rest.strip_prefix('-').unwrap_or(rest);
            let (bb, bo) = rest
                .trim_start_matches('b')
                .split_once('o')
                .ok_or_else(|| anyhow::anyhow!("rtvq needs <base>o<offset>, e.g. rtvq3o2"))?;
            Ok(QuantScheme::Rtvq(bits(bb)?, bits(bo)?))
        } else if let Some(rest) = s.strip_prefix('b') {
            // paper shorthand b3o2
            let (bb, bo) = rest
                .split_once('o')
                .ok_or_else(|| anyhow::anyhow!("expected b<base>o<offset>"))?;
            Ok(QuantScheme::Rtvq(bits(bb)?, bits(bo)?))
        } else if let Some(rest) = s.strip_prefix("tvq") {
            // tvq3 | tvq-int3 (label spelling)
            let rest = rest.strip_prefix("-int").unwrap_or(rest);
            Ok(QuantScheme::Tvq(bits(rest)?))
        } else if let Some(rest) = s.strip_prefix("fq") {
            Ok(QuantScheme::Fq(bits(rest)?))
        } else {
            anyhow::bail!("unknown scheme {s:?} (fp32 | fq<b> | tvq<b> | rtvq<bb>o<bo>)")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels() {
        assert_eq!(QuantScheme::Fp32.label(), "FP32");
        assert_eq!(QuantScheme::Fq(8).label(), "FQ8");
        assert_eq!(QuantScheme::Tvq(3).label(), "TVQ-INT3");
        assert_eq!(QuantScheme::Rtvq(3, 2).label(), "RTVQ-B3O2");
    }

    #[test]
    fn parse_schemes() {
        assert_eq!(QuantScheme::parse("fp32").unwrap(), QuantScheme::Fp32);
        assert_eq!(QuantScheme::parse("FQ8").unwrap(), QuantScheme::Fq(8));
        assert_eq!(QuantScheme::parse("tvq3").unwrap(), QuantScheme::Tvq(3));
        assert_eq!(QuantScheme::parse("rtvq3o2").unwrap(), QuantScheme::Rtvq(3, 2));
        assert_eq!(QuantScheme::parse("rtvqb4o2").unwrap(), QuantScheme::Rtvq(4, 2));
        assert_eq!(QuantScheme::parse("b3o2").unwrap(), QuantScheme::Rtvq(3, 2));
        assert!(QuantScheme::parse("tvq9").is_err());
        assert!(QuantScheme::parse("tvq0").is_err());
        assert!(QuantScheme::parse("nope").is_err());
    }

    #[test]
    fn parse_label_roundtrip() {
        // Registries persist `label()` strings; parse must invert them.
        for scheme in [
            QuantScheme::Fp32,
            QuantScheme::Fq(8),
            QuantScheme::Tvq(4),
            QuantScheme::Tvq(3),
            QuantScheme::Rtvq(3, 2),
            QuantScheme::Rtvq(8, 1),
        ] {
            assert_eq!(
                QuantScheme::parse(&scheme.label()).unwrap(),
                scheme,
                "label {:?} did not round-trip",
                scheme.label()
            );
        }
    }

    #[test]
    fn effective_bits_matches_paper() {
        // Paper Section 4.3: 8 tasks, B3O2 -> 2.375 bits/task;
        // 14 -> ~2.214; 20 -> 2.15.
        let s = QuantScheme::Rtvq(3, 2);
        assert!((s.effective_bits(8) - 2.375).abs() < 1e-9);
        assert!((s.effective_bits(20) - 2.15).abs() < 1e-9);
        assert_eq!(QuantScheme::Tvq(4).effective_bits(8), 4.0);
    }
}
