//! Native fused dequantize-and-merge — the Layer-3 serving hot path.
//!
//! Reconstructs `theta_merged = theta_pre + sum_t lam_t * dq(q_t)` straight
//! from packed codes without materializing intermediate full-precision task
//! vectors.  This is the Rust counterpart of the Layer-1 Pallas kernel (the
//! integration tests check both against each other through PJRT); the
//! serving coordinator uses whichever side of the boundary the model
//! variant calls for.
//!
//! Performance notes (see EXPERIMENTS.md §Perf): the inner loop unpacks a
//! whole 64-bit word of codes at a time and applies the affine transform
//! with a fused multiply-add; for bit widths that divide 64 this avoids
//! all cross-word handling in the common case.

use anyhow::{bail, Result};

use super::group::GroupQuantized;
use super::tvq::QuantizedCheckpoint;
use crate::checkpoint::Checkpoint;

/// `theta_pre + sum_t lams[t] * dq(taus[t])` over named tensors.
pub fn dequant_merge_checkpoints(
    pre: &Checkpoint,
    taus: &[&QuantizedCheckpoint],
    lams: &[f32],
) -> Result<Checkpoint> {
    if taus.len() != lams.len() {
        bail!("taus/lams length mismatch: {} vs {}", taus.len(), lams.len());
    }
    let kernel = super::simd::active();
    let mut out = pre.clone();
    // Scratch reused across tensors and tasks.
    let mut codes: Vec<u32> = Vec::new();
    for (name, acc) in out.iter_mut() {
        for (qck, &lam) in taus.iter().zip(lams) {
            let qt = qck
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("quantized ckpt missing {name:?}"))?;
            if qt.numel() != acc.numel() {
                bail!("tensor {name:?} numel mismatch");
            }
            codes.resize(qt.numel(), 0);
            qt.codes.unpack_into(&mut codes);
            let a = lam * qt.params.scale;
            let b = -lam * qt.params.scale * qt.params.zp;
            super::simd::axpy_affine(kernel, a, b, &codes, acc.data_mut());
        }
    }
    Ok(out)
}

/// Flat-vector variant over group-quantized payloads (the same layout the
/// Pallas artifact consumes). `out` starts as theta_pre and is accumulated
/// in place.
pub fn dequant_merge_flat(
    pre: &[f32],
    taus: &[&GroupQuantized],
    lams: &[f32],
    out: &mut Vec<f32>,
) -> Result<()> {
    out.clear();
    out.extend_from_slice(pre);
    dequant_axpy(taus, lams, out)
}

/// Accumulate `out += sum_t lams[t] * dq(taus[t])` in place — the shared
/// inner loop of the TVQ and RTVQ serving paths, dispatched over the
/// process-wide active SIMD kernel (the affine axpy is elementwise, so
/// every kernel is bit-identical to the scalar reference).
pub fn dequant_axpy(
    taus: &[&GroupQuantized],
    lams: &[f32],
    out: &mut [f32],
) -> Result<()> {
    if taus.len() != lams.len() {
        bail!("taus/lams length mismatch");
    }
    let kernel = super::simd::active();
    let mut codes: Vec<u32> = Vec::new();
    for (gq, &lam) in taus.iter().zip(lams) {
        if gq.len() != out.len() {
            bail!("flat length mismatch: {} vs {}", gq.len(), out.len());
        }
        codes.resize(gq.len(), 0);
        gq.codes.unpack_into(&mut codes);
        for (gi, chunk) in codes.chunks_exact(gq.group).enumerate() {
            let a = lam * gq.scales[gi];
            let b = -a * gq.zps[gi];
            let base = gi * gq.group;
            super::simd::axpy_affine(kernel, a, b, chunk, &mut out[base..base + gq.group]);
        }
    }
    Ok(())
}

/// RTVQ serving path: fold the shared base in once (scaled by sum lam_t),
/// then accumulate the per-task offsets — all in place, no intermediate
/// full-precision copies.
pub fn dequant_merge_rtvq_flat(
    pre: &[f32],
    base: &GroupQuantized,
    offsets: &[&GroupQuantized],
    lams: &[f32],
    out: &mut Vec<f32>,
) -> Result<()> {
    let lam_sum: f32 = lams.iter().sum();
    out.clear();
    out.extend_from_slice(pre);
    dequant_axpy(&[base], &[lam_sum], out)?;
    dequant_axpy(offsets, lams, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantizedCheckpoint;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn ck(seed: u64, std: f32) -> Checkpoint {
        let mut rng = Rng::new(seed);
        let mut c = Checkpoint::new();
        c.insert("w", Tensor::randn(&[40, 30], std, &mut rng));
        c.insert("b", Tensor::randn(&[30], std, &mut rng));
        c
    }

    #[test]
    fn fused_matches_naive_checkpoint_path() {
        let pre = ck(0, 0.3);
        let taus: Vec<Checkpoint> = (1..=4).map(|s| ck(s, 0.01)).collect();
        let qs: Vec<QuantizedCheckpoint> = taus
            .iter()
            .map(|t| QuantizedCheckpoint::quantize(t, 4).unwrap())
            .collect();
        let qrefs: Vec<&QuantizedCheckpoint> = qs.iter().collect();
        let lams = [0.3f32, 0.2, 0.1, 0.4];

        let fused = dequant_merge_checkpoints(&pre, &qrefs, &lams).unwrap();

        // Naive: dequantize then axpy.
        let mut naive = pre.clone();
        for (q, &lam) in qs.iter().zip(&lams) {
            naive.axpy(lam, &q.dequantize().unwrap()).unwrap();
        }
        assert!(fused.l2_dist(&naive).unwrap() < 1e-4);
    }

    #[test]
    fn fused_flat_matches_naive() {
        let mut rng = Rng::new(7);
        let n = 4096;
        let group = 512;
        let mut pre = vec![0.0f32; n];
        rng.fill_normal(&mut pre, 0.3);
        let taus: Vec<Vec<f32>> = (0..3)
            .map(|_| {
                let mut v = vec![0.0f32; n];
                rng.fill_normal(&mut v, 0.02);
                v
            })
            .collect();
        let qs: Vec<GroupQuantized> = taus
            .iter()
            .map(|t| GroupQuantized::quantize(t, 3, group).unwrap())
            .collect();
        let qrefs: Vec<&GroupQuantized> = qs.iter().collect();
        let lams = [0.5f32, -0.2, 0.3];

        let mut fused = Vec::new();
        dequant_merge_flat(&pre, &qrefs, &lams, &mut fused).unwrap();

        let mut naive = pre.clone();
        for (q, &lam) in qs.iter().zip(&lams) {
            for (d, v) in naive.iter_mut().zip(q.dequantize()) {
                *d += lam * v;
            }
        }
        for (a, b) in fused.iter().zip(&naive) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn rtvq_flat_path_consistent() {
        let mut rng = Rng::new(9);
        let n = 2048;
        let group = 1024;
        let mut pre = vec![0.0f32; n];
        rng.fill_normal(&mut pre, 0.3);
        let mut base_v = vec![0.0f32; n];
        rng.fill_normal(&mut base_v, 0.02);
        let base = GroupQuantized::quantize(&base_v, 3, group).unwrap();
        let offs: Vec<GroupQuantized> = (0..4)
            .map(|_| {
                let mut v = vec![0.0f32; n];
                rng.fill_normal(&mut v, 0.005);
                GroupQuantized::quantize(&v, 2, group).unwrap()
            })
            .collect();
        let orefs: Vec<&GroupQuantized> = offs.iter().collect();
        let lams = [0.25f32; 4];

        let mut got = Vec::new();
        dequant_merge_rtvq_flat(&pre, &base, &orefs, &lams, &mut got).unwrap();

        // Reference: tau_t = base + off_t merged conventionally.
        let base_hat = base.dequantize();
        let mut want = pre.clone();
        for (off, &lam) in offs.iter().zip(&lams) {
            let off_hat = off.dequantize();
            for i in 0..n {
                want[i] += lam * (base_hat[i] + off_hat[i]);
            }
        }
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn length_mismatch_rejected() {
        let pre = vec![0.0f32; 1024];
        let q = GroupQuantized::quantize(&vec![0.1f32; 2048], 2, 1024).unwrap();
        let mut out = Vec::new();
        assert!(dequant_merge_flat(&pre, &[&q], &[1.0], &mut out).is_err());
        assert!(dequant_merge_flat(&pre, &[&q], &[1.0, 2.0], &mut out).is_err());
    }
}
