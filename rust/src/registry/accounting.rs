//! Exact on-disk byte accounting, cross-checked against the paper's
//! idealized storage arithmetic ([`StorageReport`]).
//!
//! Table 5's claims are about *bytes on disk*; this module measures them
//! from real registry files and decomposes the gap to the metadata-free
//! ideal (codes only): affine params, tensor names/shapes, and the offset
//! table.  The invariant checked by tests and the `tab5` experiment:
//! `ideal <= file <= ideal * (1 + overhead_budget)` for model-scale
//! payloads.

use anyhow::{bail, Result};

use super::container::RegistryScheme;
use super::index::Registry;
use crate::checkpoint::CheckpointStore;
use crate::quant::{QuantScheme, StorageReport};

/// Measured vs ideal storage for one registry file.
#[derive(Clone, Copy, Debug)]
pub struct DiskAccounting {
    pub scheme: RegistryScheme,
    pub n_tasks: usize,
    /// Parameters per task payload (decoded from the first section, or
    /// summed from the plan for planned registries).
    pub params: usize,
    /// Total registry file size on disk.
    pub file_bytes: u64,
    /// Header + offset table share of `file_bytes`.
    pub index_bytes: u64,
    /// Payload-section share of `file_bytes`.
    pub payload_bytes: u64,
    /// Metadata-free ideal: [`StorageReport::ideal`] for uniform schemes
    /// (what Table 5 reports), or the plan's code-only bytes
    /// ([`PackPlan::ideal_code_bytes`](crate::planner::PackPlan::ideal_code_bytes))
    /// for planned registries.
    pub ideal_bytes: u64,
}

impl DiskAccounting {
    /// Measure a registry: decodes exactly one task section to learn the
    /// parameter count (uniform) or reads the resident plan (planned);
    /// everything else comes from the resident index.
    pub fn measure(reg: &Registry) -> Result<Self> {
        if reg.n_tasks() == 0 {
            bail!("cannot account an empty registry");
        }
        let (params, ideal_bytes) = match reg.scheme() {
            RegistryScheme::Uniform(s) => {
                let params = reg.load_task_payload(0)?.numel();
                (params, StorageReport::ideal(s, reg.n_tasks(), params).bytes)
            }
            RegistryScheme::Planned => {
                let plan = reg
                    .plan()
                    .ok_or_else(|| anyhow::anyhow!("planned registry without a plan"))?;
                (plan.params_per_task(), plan.ideal_code_bytes())
            }
        };
        Ok(Self {
            scheme: reg.scheme(),
            n_tasks: reg.n_tasks(),
            params,
            file_bytes: reg.file_bytes(),
            index_bytes: reg.index_bytes(),
            payload_bytes: reg.payload_bytes(),
            ideal_bytes,
        })
    }

    /// Bytes above the metadata-free ideal (index + affine params +
    /// names/shapes).  Never negative for a well-formed registry.
    pub fn overhead_bytes(&self) -> u64 {
        self.file_bytes.saturating_sub(self.ideal_bytes)
    }

    /// Overhead as a fraction of ideal.
    pub fn overhead_fraction(&self) -> f64 {
        if self.ideal_bytes == 0 {
            return f64::INFINITY;
        }
        self.overhead_bytes() as f64 / self.ideal_bytes as f64
    }

    /// Measured file size as a fraction of the fp32 ideal for the same
    /// zoo (Table 5's "% of FP32" column, from real bytes).
    pub fn fraction_of_fp32(&self) -> f64 {
        let fp32 = StorageReport::ideal(QuantScheme::Fp32, self.n_tasks, self.params);
        self.file_bytes as f64 / fp32.bytes as f64
    }

    /// True when the measured file matches the ideal within
    /// `overhead_budget` (fractional, e.g. `0.05` = 5%) — the registry is
    /// at least as large as the ideal and not meaningfully larger.
    pub fn matches_ideal(&self, overhead_budget: f64) -> bool {
        self.file_bytes >= self.ideal_bytes && self.overhead_fraction() <= overhead_budget
    }
}

/// Total on-disk bytes of every `.ckpt` file in a [`CheckpointStore`] —
/// the f32 baseline a packed registry is compared against.
pub fn f32_store_bytes(store: &CheckpointStore) -> Result<u64> {
    let mut total = 0u64;
    for entry in std::fs::read_dir(store.root())? {
        let entry = entry?;
        let path = entry.path();
        if path.extension().and_then(|e| e.to_str()) == Some("ckpt") {
            total += entry.metadata()?.len();
        }
    }
    if total == 0 {
        bail!("no .ckpt files under {}", store.root().display());
    }
    Ok(total)
}

/// One-line human summary (used by the example and the tab5 experiment).
pub fn summary_line(acc: &DiskAccounting) -> String {
    format!(
        "{}: {} tasks x {} params -> {} B on disk (ideal {} B, +{:.2}% overhead, {:.1}% of FP32)",
        acc.scheme.label(),
        acc.n_tasks,
        acc.params,
        acc.file_bytes,
        acc.ideal_bytes,
        100.0 * acc.overhead_fraction(),
        100.0 * acc.fraction_of_fp32(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_arithmetic() {
        let acc = DiskAccounting {
            scheme: RegistryScheme::Uniform(QuantScheme::Tvq(4)),
            n_tasks: 8,
            params: 1000,
            file_bytes: 4200,
            index_bytes: 100,
            payload_bytes: 4100,
            ideal_bytes: 4000,
        };
        assert_eq!(acc.overhead_bytes(), 200);
        assert!((acc.overhead_fraction() - 0.05).abs() < 1e-12);
        assert!(acc.matches_ideal(0.05));
        assert!(!acc.matches_ideal(0.04));
        // fp32 ideal: 32 bits * 1000 * 8 / 8 = 32_000 bytes.
        assert!((acc.fraction_of_fp32() - 4200.0 / 32_000.0).abs() < 1e-12);
    }
}
