//! Packed task-vector registry — quantized payloads as the **durable,
//! servable artifact**.
//!
//! The paper's storage claim (quantized task vectors need ~8% of
//! full-precision bytes) only pays off if the *on-disk* zoo is packed.
//! The v1 `TVQC` container ([`crate::checkpoint`]) stores raw f32
//! tensors; this module adds the `QTVC` v2 registry: one indexed file per
//! zoo holding bit-packed codes + affine params, loaded **lazily per
//! task** so a merge request materializes only what it needs.
//!
//! # Wire format
//!
//! The single normative byte-level spec — container header, offset
//! table, section kinds 0–4, plan wire v1/v2, CRC and compatibility
//! policy — lives in **`docs/WIRE_FORMAT.md`**.  In brief: one file =
//! header + CRC'd offset table + concatenated payload sections, all
//! integers little-endian.  Three file versions exist today:
//!
//! * **v2 (uniform)** — one [`QuantScheme`](crate::quant::QuantScheme)
//!   label; kind-0 task-checkpoint sections plus at most one kind-1 RTVQ
//!   base.  Codes are stored byte-exact (no u64 padding), so the file
//!   tracks [`StorageReport::ideal`](crate::quant::StorageReport::ideal)
//!   to within per-tensor metadata — [`DiskAccounting`] measures the gap
//!   from real files.
//! * **v3 (`PLAN-MIXED`, dense arms)** — exactly one kind-3 **plan**
//!   section (a serialized [`PackPlan`](crate::planner::PackPlan)) plus
//!   kind-2 [`GroupQuantized`](crate::quant::GroupQuantized) sections,
//!   one per `(task, tensor)` slot named `task00/blk00/w` and one
//!   `__base__/<tensor>` per RTVQ-arm tensor.  The plan is decoded at
//!   open (it is the shape/slot template); payloads stay lazy and feed
//!   the fused dequant-merge path ([`crate::planner::fused_merge`]).
//! * **v4 (`PLAN-MIXED`, sparse arms)** — v3 plus kind-4
//!   [`SparseGroupQuantized`](crate::quant::SparseGroupQuantized)
//!   sections (bitmask + group-quantized survivors) for tensors the plan
//!   assigns a DARE or TALL sparse arm; the embedded plan uses wire v2.
//!
//! # Versioning / compatibility policy (summary)
//!
//! * The magic distinguishes `QTVC` registries from v1 `TVQC`
//!   checkpoints; each reader rejects the other's magic with a pointed
//!   error naming the right API.
//! * `version` is a hard gate: readers reject any version they were not
//!   built for (no silent forward parsing).  Additive evolution bumps
//!   the version — kind-2/3 did (v3), kind-4 did (v4) — and the
//!   version/scheme/section pairing is itself validated at open (a v2
//!   file may not contain group, plan or sparse sections; kind-4
//!   sections and sparse-arm plans appear only in v4 files).
//! * Per-section CRCs allow lazy readers to verify exactly the bytes
//!   they touch; the index CRC catches truncation at open time.
//!
//! # Quickstart
//!
//! ```no_run
//! use tvq::quant::QuantScheme;
//! use tvq::registry::{build_registry, merge_from_source, DiskAccounting,
//!                     PackedRegistrySource};
//! use tvq::merge::TaskArithmetic;
//! use tvq::util::exec::ExecCtx;
//!
//! # fn main() -> anyhow::Result<()> {
//! # let (pre, fts): (tvq::checkpoint::Checkpoint, Vec<tvq::checkpoint::Checkpoint>) = todo!();
//! // Pack an 8-task zoo at TVQ-INT4 (~12.5% of f32 + metadata).
//! let summary = build_registry(&pre, &fts, QuantScheme::Tvq(4), "zoo.qtvc")?;
//! println!("{} bytes on disk", summary.file_bytes);
//!
//! // Serve from it: open the index, touch only the tasks you merge.
//! let source = PackedRegistrySource::open("zoo.qtvc")?;
//! let _merged = merge_from_source(
//!     &TaskArithmetic::default(), &pre, &source, Some(&[0, 3, 5]),
//!     &ExecCtx::default())?;
//!
//! // Cross-check the bytes against the paper's ideal arithmetic.
//! let acc = DiskAccounting::measure(source.registry())?;
//! assert!(acc.matches_ideal(0.05));
//! # Ok(()) }
//! ```

pub mod accounting;
pub mod container;
pub mod index;
pub mod manifest;
mod mmap;
pub mod source;
pub mod store;
pub mod writer;

pub use accounting::{f32_store_bytes, DiskAccounting};
pub use container::{Payload, PayloadKind, PayloadView, RegistryScheme};
pub use index::{IndexEntry, IoMode, OpenOptions, Registry, SectionScratch, Validation};
pub use manifest::{
    fnv64, shard_registry, ChunkAddr, Manifest, ManifestRow, PageMeta, ShardMeta, ShardOptions,
    ShardSummary, MANIFEST_FILE_NAME,
};
pub use source::{
    merge_from_source, merge_from_source_with_pool, F32ZooSource, PackedRegistrySource,
    TaskVectorSource,
};
pub use store::{
    LocalShardStore, PlannedSectionSource, RemoteStore, SectionStore, ShardedRegistry,
    ShardedSource,
};
pub use writer::{
    build_registry, build_registry_with_pool, uniform_registry_bytes, RegistryBuilder,
    WriteSummary,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::Checkpoint;
    use crate::merge::{Merger, TaskArithmetic};
    use crate::quant::QuantScheme;
    use crate::tensor::Tensor;
    use crate::util::exec::ExecCtx;
    use crate::util::rng::Rng;

    /// Synthetic zoo in the regime RTVQ exploits: common drift + small
    /// per-task offsets.
    fn suite(n_tasks: usize, seed: u64) -> (Checkpoint, Vec<Checkpoint>) {
        let mut rng = Rng::new(seed);
        let mut pre = Checkpoint::new();
        pre.insert("blk00/w", Tensor::randn(&[48, 32], 0.3, &mut rng));
        pre.insert("blk01/w", Tensor::randn(&[48, 32], 0.3, &mut rng));
        pre.insert("head/b", Tensor::randn(&[33], 0.1, &mut rng));
        let mut drift = Checkpoint::new();
        for (name, t) in pre.iter() {
            drift.insert(name, Tensor::randn(t.shape(), 0.02, &mut rng));
        }
        let fts = (0..n_tasks)
            .map(|_| {
                let mut off = Checkpoint::new();
                for (name, t) in pre.iter() {
                    off.insert(name, Tensor::randn(t.shape(), 0.005, &mut rng));
                }
                pre.add(&drift).unwrap().add(&off).unwrap()
            })
            .collect();
        (pre, fts)
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("tvq_registry_{name}"))
    }

    #[test]
    fn tvq_registry_lazy_roundtrip_is_bit_exact() {
        let (pre, fts) = suite(4, 11);
        let dir = tmp("rt_tvq");
        let path = dir.join("zoo.qtvc");
        build_registry(&pre, &fts, QuantScheme::Tvq(4), &path).unwrap();

        let reg = Registry::open(&path).unwrap();
        assert_eq!(reg.n_tasks(), 4);
        assert_eq!(reg.scheme(), RegistryScheme::Uniform(QuantScheme::Tvq(4)));
        assert_eq!(reg.uniform_scheme(), Some(QuantScheme::Tvq(4)));
        assert_eq!(reg.version(), 2);
        assert!(reg.plan().is_none());
        assert!(!reg.has_rtvq_base());
        for (t, ft) in fts.iter().enumerate() {
            let tau = ft.sub(&pre).unwrap();
            // The lazily-loaded payload equals requantizing in memory —
            // bit-exact, not approximately.
            let q = crate::quant::QuantizedCheckpoint::quantize(&tau, 4).unwrap();
            match reg.load_task_payload(t).unwrap() {
                Payload::Checkpoint(back) => assert_eq!(back, q, "task {t}"),
                other => panic!("unexpected payload {other:?}"),
            }
            let got = reg.load_task_vector(t, &ExecCtx::sequential()).unwrap();
            assert_eq!(got, q.dequantize().unwrap());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rtvq_registry_reconstruction_matches_in_memory() {
        let (pre, fts) = suite(4, 12);
        let dir = tmp("rt_rtvq");
        let path = dir.join("zoo.qtvc");
        build_registry(&pre, &fts, QuantScheme::Rtvq(3, 2), &path).unwrap();

        let reg = Registry::open(&path).unwrap();
        assert!(reg.has_rtvq_base());
        assert_eq!(reg.n_tasks(), 4);
        let r = crate::quant::Rtvq::quantize(&pre, &fts, 3, 2, true, &ExecCtx::sequential())
            .unwrap();
        for t in 0..4 {
            let want = r.dequantize_task(t).unwrap();
            let got = reg.load_task_vector(t, &ExecCtx::sequential()).unwrap();
            assert_eq!(got, want, "task {t}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn merge_from_packed_source_matches_f32_source() {
        let (pre, fts) = suite(5, 13);
        let dir = tmp("merge_src");
        let path = dir.join("zoo.qtvc");
        build_registry(&pre, &fts, QuantScheme::Tvq(8), &path).unwrap();
        let packed = PackedRegistrySource::open(&path).unwrap();
        assert_eq!(packed.n_tasks(), 5);
        assert_eq!(packed.scheme_label(), "TVQ-INT8");

        // Merge a subset through the packed source...
        let ta = TaskArithmetic::default();
        let merged =
            merge_from_source(&ta, &pre, &packed, Some(&[1, 3]), &ExecCtx::default()).unwrap();
        // ...and the same subset from dequantized-in-memory vectors.
        let taus: Vec<Checkpoint> = [1usize, 3]
            .iter()
            .map(|&t| {
                let tau = fts[t].sub(&pre).unwrap();
                crate::quant::QuantizedCheckpoint::quantize(&tau, 8)
                    .unwrap()
                    .dequantize()
                    .unwrap()
            })
            .collect();
        let want = ta.merge(&pre, &taus).unwrap();
        match (&merged, &want) {
            (
                crate::merge::MergedModel::Shared(a),
                crate::merge::MergedModel::Shared(b),
            ) => assert_eq!(a, b),
            _ => panic!("expected shared merges"),
        }
        // Out-of-range subsets are rejected.
        assert!(merge_from_source(&ta, &pre, &packed, Some(&[7]), &ExecCtx::default()).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn open_rejects_corruption_and_wrong_format() {
        let (pre, fts) = suite(2, 14);
        let dir = tmp("corrupt");
        let path = dir.join("zoo.qtvc");
        build_registry(&pre, &fts, QuantScheme::Tvq(3), &path).unwrap();

        // Flip a byte in the index region: open() must fail.
        let bytes = std::fs::read(&path).unwrap();
        let mut bad = bytes.clone();
        bad[20] ^= 0xFF;
        let p_bad = dir.join("bad.qtvc");
        std::fs::write(&p_bad, &bad).unwrap();
        assert!(Registry::open(&p_bad).is_err());

        // Flip a byte in a payload section: open() succeeds (lazy), the
        // touched task fails its per-section CRC.
        let mut bad2 = bytes.clone();
        let n = bad2.len();
        bad2[n - 3] ^= 0xFF;
        let p_bad2 = dir.join("bad2.qtvc");
        std::fs::write(&p_bad2, &bad2).unwrap();
        let reg = Registry::open(&p_bad2).unwrap();
        let last = reg.n_tasks() - 1;
        assert!(reg.load_task_payload(last).is_err());
        assert!(reg.load_task_payload(0).is_ok(), "untouched section must still read");

        // A v1 TVQC checkpoint is not a registry, and vice versa.
        let ckpt_path = dir.join("plain.ckpt");
        pre.save(&ckpt_path).unwrap();
        let err = Registry::open(&ckpt_path).unwrap_err().to_string();
        assert!(err.contains("not a QTVC registry"), "got: {err}");
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("not a TVQC checkpoint"), "got: {err}");

        // Truncated index.
        let p_trunc = dir.join("trunc.qtvc");
        std::fs::write(&p_trunc, &bytes[..10]).unwrap();
        assert!(Registry::open(&p_trunc).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn io_modes_read_identical_sections() {
        let (pre, fts) = suite(3, 16);
        let dir = tmp("iomode");
        let path = dir.join("zoo.qtvc");
        build_registry(&pre, &fts, QuantScheme::Tvq(3), &path).unwrap();
        let mmap = Registry::open_with(&path, OpenOptions::new().io(IoMode::Mmap)).unwrap();
        let pread = Registry::open_with(&path, OpenOptions::new().io(IoMode::Pread)).unwrap();
        let reopen = Registry::open_with(&path, OpenOptions::new().io(IoMode::Reopen)).unwrap();
        // Requested modes take effect (mmap may legitimately fall back on
        // exotic platforms, but then it must report the fallback).
        #[cfg(all(unix, target_pointer_width = "64"))]
        {
            assert_eq!(mmap.io_mode(), IoMode::Mmap);
            assert_eq!(mmap.mapped_bytes(), mmap.file_bytes());
        }
        #[cfg(unix)]
        assert_eq!(pread.io_mode(), IoMode::Pread);
        assert_eq!(reopen.io_mode(), IoMode::Reopen);
        assert_eq!(pread.mapped_bytes(), 0);
        assert_eq!(reopen.mapped_bytes(), 0);
        for t in 0..3 {
            let want = reopen.load_task_vector(t, &ExecCtx::sequential()).unwrap();
            assert_eq!(
                pread.load_task_vector(t, &ExecCtx::sequential()).unwrap(),
                want,
                "task {t}: pread and reopen paths disagree"
            );
            assert_eq!(
                mmap.load_task_vector(t, &ExecCtx::sequential()).unwrap(),
                want,
                "task {t}: mmap and reopen paths disagree"
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn planned_registry_roundtrips_through_generic_source() {
        use crate::planner::{build_planned_registry, min_feasible_bytes, probe, PlannerConfig};

        let (pre, fts) = suite(3, 17);
        let dir = tmp("planned");
        let path = dir.join("zoo.qtvc");
        let cfg = PlannerConfig {
            group: 128,
            tvq_bits: vec![2, 4],
            rtvq_arms: vec![(3, 2)],
            dare_arms: vec![],
            tall_arms: vec![],
            onebit_arms: vec![],
        };
        let profile = probe(&pre, &fts, &cfg).unwrap();
        let budget = min_feasible_bytes(&profile) * 2;
        let (plan, summary) =
            build_planned_registry(&pre, &fts, budget, &cfg, &path).unwrap();
        assert_eq!(summary.scheme, RegistryScheme::Planned);

        let reg = Registry::open(&path).unwrap();
        assert_eq!(reg.scheme(), RegistryScheme::Planned);
        assert_eq!(reg.version(), 3);
        assert_eq!(reg.n_tasks(), 3);
        assert_eq!(reg.plan().unwrap(), &plan);
        // Per-task payload access is a uniform-registry API.
        assert!(reg.load_task_payload(0).is_err());
        // The generic source + merge path serves planned registries.
        let src = PackedRegistrySource::open(&path).unwrap();
        assert_eq!(src.scheme_label(), "PLAN-MIXED");
        let ta = TaskArithmetic::default();
        let merged = merge_from_source(&ta, &pre, &src, None, &ExecCtx::default()).unwrap();
        let taus: Vec<Checkpoint> =
            (0..3).map(|t| reg.load_task_vector(t, &ExecCtx::sequential()).unwrap()).collect();
        let want = ta.merge(&pre, &taus).unwrap();
        match (&merged, &want) {
            (
                crate::merge::MergedModel::Shared(a),
                crate::merge::MergedModel::Shared(b),
            ) => assert_eq!(a, b),
            _ => panic!("expected shared merges"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn builder_validates_inputs() {
        let (pre, fts) = suite(2, 15);
        let tau = fts[0].sub(&pre).unwrap();
        let q = crate::quant::QuantizedCheckpoint::quantize(&tau, 3).unwrap();
        let dir = tmp("builder");

        // Empty registry refused.
        assert!(RegistryBuilder::new(QuantScheme::Tvq(3)).write(dir.join("e.qtvc")).is_err());
        // Duplicate names refused.
        let mut b = RegistryBuilder::new(QuantScheme::Tvq(3));
        b.add_task("a", &q).unwrap();
        assert!(b.add_task("a", &q).is_err());
        // RTVQ without a base refused.
        let mut b = RegistryBuilder::new(QuantScheme::Rtvq(3, 2));
        b.add_task("a", &q).unwrap();
        assert!(b.write(dir.join("r.qtvc")).is_err());
        // Fp32 / Fq schemes refused outright.
        assert!(build_registry(&pre, &fts, QuantScheme::Fp32, dir.join("f.qtvc")).is_err());
        assert!(build_registry(&pre, &fts, QuantScheme::Fq(8), dir.join("q.qtvc")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
