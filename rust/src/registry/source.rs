//! [`TaskVectorSource`] — where merge builds get their task vectors.
//!
//! Merging methods consume full-precision task vectors; *where those come
//! from* is a deployment decision: a directory of raw f32 checkpoints
//! (the debugging / training path) or a packed `QTVC` registry (the
//! serving path, ~8-15% of the bytes).  This trait abstracts that choice
//! so `merge/` and the coordinator's [`ModelCache`](crate::coordinator::ModelCache)
//! build [`MergedModel`]s identically from either — and the packed
//! backend loads **only** the tasks a request names.

use anyhow::{bail, Result};

use super::index::Registry;
use crate::checkpoint::Checkpoint;
use crate::merge::{MergedModel, Merger};
use crate::util::exec::ExecCtx;
use crate::util::pool::Pool;

/// A provider of full-precision task vectors, one per task.
///
/// `Sync` is a supertrait: the parallel merge path
/// ([`merge_from_source_with_pool`]) fans per-task loads out across a
/// [`Pool`], so every backend must be shareable across worker threads
/// (all in-tree backends are — registries read through `&self`).
pub trait TaskVectorSource: Sync {
    fn n_tasks(&self) -> usize;

    /// Human-readable name of task `t` (used in diagnostics and cache keys).
    fn task_name(&self, t: usize) -> String;

    /// The full-precision task vector tau_t (exact or dequantized).
    fn task_vector(&self, t: usize) -> Result<Checkpoint>;

    /// [`task_vector`](Self::task_vector) with intra-task decode fanned
    /// out across `pool` — used by the merge path when only one task is
    /// requested (otherwise it parallelizes *across* tasks and keeps
    /// each load sequential, bounding total thread count to the pool
    /// width).  Backends without sub-task parallelism fall back to the
    /// sequential load; outputs must be identical either way.
    fn task_vector_with_pool(&self, t: usize, pool: &Pool) -> Result<Checkpoint> {
        let _ = pool;
        self.task_vector(t)
    }

    /// Scheme label (`"FP32"`, `"TVQ-INT4"`, ...).
    fn scheme_label(&self) -> String;

    /// Identity of the backing artifact, used as the cache-key component
    /// by [`ModelCache::get_or_build_merged`](crate::coordinator::ModelCache::get_or_build_merged).
    /// Defaults to the scheme label alone; backends that can coexist with
    /// others of the same scheme in one process (e.g. two registry files)
    /// MUST qualify it, or different zoos would share one cached variant.
    fn source_id(&self) -> String {
        self.scheme_label()
    }

    /// Owned heap bytes this source pins while serving (index tables,
    /// decoded base caches).  Counted against a
    /// [`ModelCache`](crate::coordinator::ModelCache) byte cap when the
    /// source is registered there.  Defaults to 0 for sources that merely
    /// borrow data owned elsewhere (e.g. [`F32ZooSource`]).
    fn resident_overhead_bytes(&self) -> usize {
        0
    }

    /// File-backed bytes this source serves through a memory mapping
    /// (`IoMode::Mmap`).  These live in the OS page cache — reclaimable
    /// under pressure — so capacity accounting reports them separately
    /// and does **not** charge them against a heap byte cap.
    fn mapped_bytes(&self) -> u64 {
        0
    }
}

/// The full-precision backend: an in-memory zoo of fine-tuned
/// checkpoints; tau_t = ft_t - pre computed on demand.
pub struct F32ZooSource<'a> {
    pre: &'a Checkpoint,
    fts: &'a [Checkpoint],
}

impl<'a> F32ZooSource<'a> {
    pub fn new(pre: &'a Checkpoint, fts: &'a [Checkpoint]) -> Self {
        Self { pre, fts }
    }
}

impl TaskVectorSource for F32ZooSource<'_> {
    fn n_tasks(&self) -> usize {
        self.fts.len()
    }

    fn task_name(&self, t: usize) -> String {
        format!("task{t:02}")
    }

    fn task_vector(&self, t: usize) -> Result<Checkpoint> {
        match self.fts.get(t) {
            Some(ft) => ft.sub(self.pre),
            None => bail!("task index {t} out of range ({} tasks)", self.fts.len()),
        }
    }

    fn scheme_label(&self) -> String {
        "FP32".to_string()
    }
}

/// The packed backend: a lazily-read `QTVC` registry.  Opening holds only
/// the offset table in memory; each `task_vector` call reads exactly one
/// section (plus, for RTVQ, the shared base on first touch).  Plan-packed
/// mixed-precision registries serve through the same interface — a
/// `task_vector` call there reads the task's per-tensor group sections
/// and reconstructs shapes from the embedded plan.
pub struct PackedRegistrySource {
    registry: Registry,
}

impl PackedRegistrySource {
    pub fn open<P: AsRef<std::path::Path>>(path: P) -> Result<Self> {
        Ok(Self { registry: Registry::open(path)? })
    }

    pub fn from_registry(registry: Registry) -> Self {
        Self { registry }
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }
}

impl TaskVectorSource for PackedRegistrySource {
    fn n_tasks(&self) -> usize {
        self.registry.n_tasks()
    }

    fn task_name(&self, t: usize) -> String {
        self.registry
            .task_names()
            .get(t)
            .map(|s| s.to_string())
            .unwrap_or_else(|| format!("task{t:02}"))
    }

    fn task_vector(&self, t: usize) -> Result<Checkpoint> {
        self.registry.load_task_vector(t, &ExecCtx::sequential())
    }

    fn task_vector_with_pool(&self, t: usize, pool: &Pool) -> Result<Checkpoint> {
        self.registry.load_task_vector(t, &ExecCtx::with_pool(pool))
    }

    fn scheme_label(&self) -> String {
        self.registry.scheme().label()
    }

    /// Scheme label qualified by the registry path: two registries packed
    /// at the same scheme must not collide in a shared variant cache.
    fn source_id(&self) -> String {
        format!("{}:{}", self.registry.scheme().label(), self.registry.path().display())
    }

    /// The resident index + decoded base caches; payload bytes are
    /// mapped or staged transiently, never pinned.
    fn resident_overhead_bytes(&self) -> usize {
        self.registry.resident_overhead_bytes()
    }

    fn mapped_bytes(&self) -> u64 {
        self.registry.mapped_bytes()
    }
}

/// Build a merged model from a source, touching only `tasks` (all tasks
/// when `None`).  With a [`PackedRegistrySource`] this is the serving
/// materialization path: index + the named sections are the only bytes
/// read — the full f32 zoo never exists in memory or on disk.
///
/// Task-vector loads (the decode-dominated part) fan out across the
/// [`ExecCtx`]'s pool; the merge combine itself stays on the caller's
/// thread in task order, so the merged floats are bit-identical at
/// every thread count.  Multi-task requests parallelize *across* tasks
/// (each load sequential); a single-task request parallelizes *inside*
/// the load ([`TaskVectorSource::task_vector_with_pool`]) — either way
/// the total worker count is bounded by the pool width.
pub fn merge_from_source(
    merger: &dyn Merger,
    pre: &Checkpoint,
    source: &dyn TaskVectorSource,
    tasks: Option<&[usize]>,
    ctx: &ExecCtx,
) -> Result<MergedModel> {
    let _op = ctx.op_span(crate::obs::Category::Merge);
    let pool = ctx.pool();
    let indices: Vec<usize> = match tasks {
        Some(ts) => {
            for &t in ts {
                if t >= source.n_tasks() {
                    bail!("task index {t} out of range ({} tasks)", source.n_tasks());
                }
            }
            ts.to_vec()
        }
        None => (0..source.n_tasks()).collect(),
    };
    if indices.is_empty() {
        bail!("merge needs at least one task");
    }
    let taus: Vec<Checkpoint> = if indices.len() == 1 {
        vec![source.task_vector_with_pool(indices[0], pool)?]
    } else {
        pool.try_map(indices, |_, t| source.task_vector(t))?
    };
    merger.merge(pre, &taus)
}

/// [`merge_from_source`] on an explicit pool — the PR-5 twin, superseded
/// by [`ExecCtx`].
#[deprecated(note = "use merge_from_source(..., &ExecCtx::with_pool(pool))")]
pub fn merge_from_source_with_pool(
    merger: &dyn Merger,
    pre: &Checkpoint,
    source: &dyn TaskVectorSource,
    tasks: Option<&[usize]>,
    pool: &Pool,
) -> Result<MergedModel> {
    merge_from_source(merger, pre, source, tasks, &ExecCtx::with_pool(pool))
}
