//! `QTVC` payload sections: the byte-level encoding of quantized task
//! payloads (bit-packed codes + affine params + scheme metadata).
//!
//! A section is one self-contained payload; the registry index
//! ([`super::index`]) records where each section lives and its CRC.  The
//! payload bodies (normative layouts: `docs/WIRE_FORMAT.md` §3):
//!
//! * [`PayloadKind::TaskCheckpoint`] / [`PayloadKind::RtvqBase`] — a
//!   per-tensor quantized checkpoint ([`QuantizedCheckpoint`]): TVQ task
//!   vectors, RTVQ offsets, or the shared RTVQ base.
//! * [`PayloadKind::Group`] — a flat per-group quantized vector
//!   ([`GroupQuantized`]), the layout the AOT Pallas merge artifacts
//!   consume directly.
//! * [`PayloadKind::SparseGroup`] — bitmask + group-quantized survivors
//!   ([`SparseGroupQuantized`]), the planner's sparse-arm payload.
//! * [`PayloadKind::BinarySwitch`] — sign bitmap + per-group scales
//!   ([`BinarySwitch`]), the planner's 1-bit OneBit-arm payload and the
//!   dynamic-merge switch sections.
//! * [`PayloadKind::Plan`] — the embedded pack plan (decoded by
//!   [`PackPlan::decode`](crate::planner::PackPlan::decode), not here).
//!
//! Codes are stored via [`BitPacked::packed_bytes`] — headerless and
//! byte-exact (`ceil(len * bits / 8)` bytes), so file size tracks the
//! paper's ideal storage arithmetic to within per-tensor metadata.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::quant::{
    AffineParams, BinarySwitch, BinarySwitchView, BitPacked, BitPackedView, GroupQuantized,
    GroupQuantizedView, QuantizedCheckpoint, SparseGroupQuantized, SparseGroupQuantizedView,
};
use crate::quant::tvq::QuantizedTensor;

/// Registry file magic: the bytes `"QTVC"` read as a little-endian u32.
pub const MAGIC: u32 = 0x4356_5451;
/// Registry format version for uniform-scheme registries.  v1 was the
/// raw-f32 `TVQC` checkpoint container; packed registries start at v2.
pub const VERSION: u32 = 2;
/// Registry format version for plan-packed mixed-precision registries
/// whose plans use dense arms only: v3 adds the kind-3 plan-metadata
/// section and real kind-2 group payloads.
pub const VERSION_PLANNED: u32 = 3;
/// Registry format version for plan-packed registries whose plans use
/// sparse (DARE / TALL) arms: v4 adds the kind-4 sparse sections.  Per
/// the compat policy (`docs/WIRE_FORMAT.md`), additive section kinds bump
/// the version so older readers reject the file at the header instead of
/// choking on an unknown payload kind mid-read.
pub const VERSION_SPARSE: u32 = 4;
/// Registry format version for plan-packed registries whose plans use the
/// 1-bit OneBit arm: v5 adds the kind-5 binary-switch sections (and, like
/// v4, admits kind-4 sparse sections alongside).
pub const VERSION_BINARY: u32 = 5;

/// Header scheme label used by plan-packed mixed-precision registries
/// (uniform registries store a [`QuantScheme`] label instead).
pub const PLANNED_LABEL: &str = "PLAN-MIXED";

/// What the registry as a whole stores: one uniform quantization scheme
/// applied to every task, or a mixed-precision layout compiled from a
/// [`PackPlan`](crate::planner::PackPlan).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RegistryScheme {
    /// Every payload quantized under one [`QuantScheme`] (QTVC v2).
    Uniform(crate::quant::QuantScheme),
    /// Budget-planned mixed precision: per-tensor group payloads whose
    /// bit widths come from the embedded pack plan (QTVC v3).
    Planned,
}

impl RegistryScheme {
    /// Header label; `parse(label())` round-trips.
    pub fn label(&self) -> String {
        match self {
            RegistryScheme::Uniform(s) => s.label(),
            RegistryScheme::Planned => PLANNED_LABEL.to_string(),
        }
    }

    /// Parse a registry header label: [`PLANNED_LABEL`] or any
    /// [`QuantScheme`] spelling.
    pub fn parse(s: &str) -> Result<Self> {
        if s == PLANNED_LABEL {
            Ok(RegistryScheme::Planned)
        } else {
            Ok(RegistryScheme::Uniform(crate::quant::QuantScheme::parse(s)?))
        }
    }

    /// The uniform scheme, if this is not a planned registry.
    pub fn uniform(&self) -> Option<crate::quant::QuantScheme> {
        match self {
            RegistryScheme::Uniform(s) => Some(*s),
            RegistryScheme::Planned => None,
        }
    }
}

/// What a section body contains.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PayloadKind {
    /// A per-task quantized checkpoint (TVQ task vector, RTVQ offset, or
    /// FQ checkpoint).
    TaskCheckpoint,
    /// The shared RTVQ base vector (stored once, amortized across tasks).
    RtvqBase,
    /// A flat group-quantized vector (Pallas kernel layout).
    Group,
    /// Pack-plan metadata (v3+): the serialized
    /// [`PackPlan`](crate::planner::PackPlan) that maps payload sections
    /// back to (task, tensor) slots and records the bit allocation.
    Plan,
    /// A sparse flat vector (v4): bitmask + group-quantized survivors
    /// ([`SparseGroupQuantized`]), produced by the planner's DARE / TALL
    /// sparse arms.
    SparseGroup,
    /// A 1-bit flat vector (v5): sign bitmap + per-group scales
    /// ([`BinarySwitch`]), produced by the planner's OneBit arm — the
    /// dynamic-merge task switches.
    BinarySwitch,
}

impl PayloadKind {
    pub fn to_u8(self) -> u8 {
        match self {
            PayloadKind::TaskCheckpoint => 0,
            PayloadKind::RtvqBase => 1,
            PayloadKind::Group => 2,
            PayloadKind::Plan => 3,
            PayloadKind::SparseGroup => 4,
            PayloadKind::BinarySwitch => 5,
        }
    }

    pub fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            0 => PayloadKind::TaskCheckpoint,
            1 => PayloadKind::RtvqBase,
            2 => PayloadKind::Group,
            3 => PayloadKind::Plan,
            4 => PayloadKind::SparseGroup,
            5 => PayloadKind::BinarySwitch,
            other => bail!("unknown QTVC payload kind {other}"),
        })
    }
}

/// A decoded section body.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    Checkpoint(QuantizedCheckpoint),
    Group(GroupQuantized),
    SparseGroup(SparseGroupQuantized),
    Binary(BinarySwitch),
}

impl Payload {
    /// Parameter count carried by this payload (logical dense count for
    /// sparse sections — what a merge touches, not what is stored).
    pub fn numel(&self) -> usize {
        match self {
            Payload::Checkpoint(q) => q.numel(),
            Payload::Group(g) => g.len(),
            Payload::SparseGroup(s) => s.dense_len,
            Payload::Binary(b) => b.len(),
        }
    }

    /// Encode to the section wire form for `kind`.
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Payload::Checkpoint(q) => encode_checkpoint_payload(q),
            Payload::Group(g) => encode_group_payload(g),
            Payload::SparseGroup(s) => encode_sparse_payload(s),
            Payload::Binary(b) => encode_binary_payload(b),
        }
    }

    /// Decode a section body according to its index `kind`.
    pub fn decode(kind: PayloadKind, buf: &[u8]) -> Result<Payload> {
        Ok(match kind {
            PayloadKind::TaskCheckpoint | PayloadKind::RtvqBase => {
                Payload::Checkpoint(decode_checkpoint_payload(buf)?)
            }
            PayloadKind::Group => Payload::Group(decode_group_payload(buf)?),
            PayloadKind::SparseGroup => Payload::SparseGroup(decode_sparse_payload(buf)?),
            PayloadKind::BinarySwitch => Payload::Binary(decode_binary_payload(buf)?),
            PayloadKind::Plan => bail!(
                "plan sections decode via PackPlan::decode (Registry::plan), \
                 not Payload::decode"
            ),
        })
    }
}

/// A decoded section body that *borrows* the section bytes — the zero-copy
/// serve path.  Group and sparse bodies stay entirely in the backing bytes
/// (a file mapping, in `IoMode::Mmap`); only checkpoint payloads (kind
/// 0/1) materialize owned tensors, because their per-tensor `BTreeMap`
/// template has no flat borrowed form.  Every validation the owned
/// [`Payload::decode`] runs, runs here too — the owned decoders are in
/// fact implemented as `view + to_owned`, so there is exactly one parse
/// path for a section body.
#[derive(Debug)]
pub enum PayloadView<'a> {
    Checkpoint(QuantizedCheckpoint),
    Group(GroupQuantizedView<'a>),
    SparseGroup(SparseGroupQuantizedView<'a>),
    Binary(BinarySwitchView<'a>),
}

impl<'a> PayloadView<'a> {
    /// Decode a section body according to its index `kind`, borrowing
    /// group/sparse/binary payloads from `buf`.
    pub fn decode(kind: PayloadKind, buf: &'a [u8]) -> Result<PayloadView<'a>> {
        Ok(match kind {
            PayloadKind::TaskCheckpoint | PayloadKind::RtvqBase => {
                PayloadView::Checkpoint(decode_checkpoint_payload(buf)?)
            }
            PayloadKind::Group => PayloadView::Group(decode_group_payload_view(buf)?),
            PayloadKind::SparseGroup => {
                PayloadView::SparseGroup(decode_sparse_payload_view(buf)?)
            }
            PayloadKind::BinarySwitch => {
                PayloadView::Binary(decode_binary_payload_view(buf)?)
            }
            PayloadKind::Plan => bail!(
                "plan sections decode via PackPlan::decode (Registry::plan), \
                 not PayloadView::decode"
            ),
        })
    }

    /// Materialize the owned [`Payload`].
    pub fn to_owned(&self) -> Payload {
        match self {
            PayloadView::Checkpoint(q) => Payload::Checkpoint(q.clone()),
            // Explicit derefs: the views' inherent `to_owned(self)` takes
            // the Copy value — through `&view` the blanket
            // `ToOwned for T: Clone` would win resolution and hand back a
            // view clone instead of the owned container.
            PayloadView::Group(g) => Payload::Group((*g).to_owned()),
            PayloadView::SparseGroup(s) => Payload::SparseGroup((*s).to_owned()),
            PayloadView::Binary(b) => Payload::Binary((*b).to_owned()),
        }
    }

    /// The borrowed group payload, or an error naming what was found.
    pub fn as_group(&self) -> Result<&GroupQuantizedView<'a>> {
        match self {
            PayloadView::Group(g) => Ok(g),
            other => bail!("expected a group payload, got {other:?}"),
        }
    }

    /// The borrowed sparse payload, or an error naming what was found.
    pub fn as_sparse(&self) -> Result<&SparseGroupQuantizedView<'a>> {
        match self {
            PayloadView::SparseGroup(s) => Ok(s),
            other => bail!("expected a sparse payload, got {other:?}"),
        }
    }

    /// The borrowed binary-switch payload, or an error naming what was
    /// found.
    pub fn as_binary(&self) -> Result<&BinarySwitchView<'a>> {
        match self {
            PayloadView::Binary(b) => Ok(b),
            other => bail!("expected a binary-switch payload, got {other:?}"),
        }
    }
}

/// Little-endian read cursor over a section body.
pub(crate) struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "truncated QTVC section at byte {} (wanted {n} more of {})",
                self.pos,
                self.buf.len()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(std::str::from_utf8(self.take(n)?)?.to_string())
    }

    /// Bytes left to read — the bound every untrusted count must respect
    /// before any allocation sized from it.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

fn push_str(buf: &mut Vec<u8>, s: &str) {
    buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
}

/// Encode a per-tensor quantized checkpoint:
/// ```text
///   bits u8, tensor_count u32
///   per tensor (name order):
///     name_len u32, name bytes
///     ndim u32, dims u64 * ndim
///     scale f32, zp f32
///     packed codes: ceil(numel * bits / 8) bytes
/// ```
pub fn encode_checkpoint_payload(q: &QuantizedCheckpoint) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.push(q.bits);
    buf.extend_from_slice(&(q.len() as u32).to_le_bytes());
    for (name, qt) in q.iter() {
        push_str(&mut buf, name);
        buf.extend_from_slice(&(qt.shape.len() as u32).to_le_bytes());
        for &d in &qt.shape {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        buf.extend_from_slice(&qt.params.scale.to_le_bytes());
        buf.extend_from_slice(&qt.params.zp.to_le_bytes());
        buf.extend_from_slice(&qt.codes.packed_bytes());
    }
    buf
}

/// Inverse of [`encode_checkpoint_payload`]; the whole buffer must be
/// consumed (trailing garbage is corruption).
pub fn decode_checkpoint_payload(buf: &[u8]) -> Result<QuantizedCheckpoint> {
    let mut c = Cursor::new(buf);
    let bits = c.u8()?;
    if !(1..=8).contains(&bits) {
        bail!("QTVC checkpoint payload: invalid bit width {bits}");
    }
    let count = c.u32()? as usize;
    let mut tensors = BTreeMap::new();
    for _ in 0..count {
        let name = c.str()?;
        let ndim = c.u32()? as usize;
        if ndim > 16 {
            bail!("QTVC checkpoint payload: implausible ndim {ndim} for {name:?}");
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(c.u64()? as usize);
        }
        // Dims are untrusted: a crafted shape must fail cleanly, not
        // overflow (debug panic / silent release wraparound).
        let numel = shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .ok_or_else(|| {
                anyhow::anyhow!("QTVC checkpoint payload: shape overflow for {name:?}")
            })?;
        let scale = c.f32()?;
        let zp = c.f32()?;
        let nbytes = numel
            .checked_mul(bits as usize)
            .ok_or_else(|| {
                anyhow::anyhow!("QTVC checkpoint payload: code size overflow for {name:?}")
            })?
            .div_ceil(8);
        let codes = BitPacked::from_packed_bytes(bits, numel, c.take(nbytes)?)?;
        if tensors
            .insert(
                name.clone(),
                QuantizedTensor { shape, params: AffineParams { scale, zp, bits }, codes },
            )
            .is_some()
        {
            bail!("QTVC checkpoint payload: duplicate tensor {name:?}");
        }
    }
    if !c.done() {
        bail!("QTVC checkpoint payload: trailing bytes after {count} tensors");
    }
    Ok(QuantizedCheckpoint::from_tensors(bits, tensors))
}

/// Encode a group-quantized flat vector:
/// ```text
///   bits u8, group u64, n_groups u64
///   scales f32 * n_groups, zps f32 * n_groups
///   packed codes: ceil(group * n_groups * bits / 8) bytes
/// ```
pub fn encode_group_payload(g: &GroupQuantized) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.push(g.bits);
    buf.extend_from_slice(&(g.group as u64).to_le_bytes());
    buf.extend_from_slice(&(g.n_groups() as u64).to_le_bytes());
    for &s in &g.scales {
        buf.extend_from_slice(&s.to_le_bytes());
    }
    for &z in &g.zps {
        buf.extend_from_slice(&z.to_le_bytes());
    }
    buf.extend_from_slice(&g.codes.packed_bytes());
    buf
}

/// Zero-copy inverse of [`encode_group_payload`]: scales, zps and codes
/// all stay borrowed from `buf`.  This is the single parse path for kind-2
/// bodies — the owned [`decode_group_payload`] materializes from it.
pub fn decode_group_payload_view(buf: &[u8]) -> Result<GroupQuantizedView<'_>> {
    let mut c = Cursor::new(buf);
    let bits = c.u8()?;
    if !(1..=8).contains(&bits) {
        bail!("QTVC group payload: invalid bit width {bits}");
    }
    let group = c.u64()? as usize;
    let n_groups = c.u64()? as usize;
    if group == 0 {
        bail!("QTVC group payload: zero group size");
    }
    // Untrusted counts: scales + zps occupy 8 bytes per group, so
    // n_groups must fit what's actually left in the section before any
    // allocation is sized from it.
    if n_groups > c.remaining() / 8 {
        bail!(
            "QTVC group payload: n_groups {n_groups} exceeds section size ({} bytes left)",
            c.remaining()
        );
    }
    let params = c.take(n_groups * 8)?;
    let len = group
        .checked_mul(n_groups)
        .ok_or_else(|| anyhow::anyhow!("QTVC group payload: group*n_groups overflows"))?;
    let nbytes = len
        .checked_mul(bits as usize)
        .ok_or_else(|| anyhow::anyhow!("QTVC group payload: code size overflows"))?
        .div_ceil(8);
    let codes = BitPackedView::new(bits, len, c.take(nbytes)?)?;
    if !c.done() {
        bail!("QTVC group payload: trailing bytes");
    }
    GroupQuantizedView::new(bits, group, n_groups, params, codes)
}

/// Inverse of [`encode_group_payload`].
pub fn decode_group_payload(buf: &[u8]) -> Result<GroupQuantized> {
    Ok(decode_group_payload_view(buf)?.to_owned())
}

/// Encode a sparse group-quantized vector (kind-4 section body):
/// ```text
///   dense_len u64, n_survivors u64
///   mask: ceil(dense_len / 8) bytes (LSB-first)
///   survivor group payload, as encode_group_payload
/// ```
pub fn encode_sparse_payload(s: &SparseGroupQuantized) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(&(s.dense_len as u64).to_le_bytes());
    buf.extend_from_slice(&(s.n_survivors as u64).to_le_bytes());
    buf.extend_from_slice(&s.mask);
    buf.extend_from_slice(&encode_group_payload(&s.survivors));
    buf
}

/// Zero-copy inverse of [`encode_sparse_payload`]: bitmask and survivor
/// payload stay borrowed from `buf`.  Every structural invariant — mask
/// length, popcount vs survivor count, tail bits, survivor-vector
/// geometry — is re-validated so corrupt sections fail closed; this is
/// the single parse path for kind-4 bodies (the owned
/// [`decode_sparse_payload`] materializes from it).
pub fn decode_sparse_payload_view(buf: &[u8]) -> Result<SparseGroupQuantizedView<'_>> {
    let mut c = Cursor::new(buf);
    let dense_len = c.u64()? as usize;
    let n_survivors = c.u64()? as usize;
    if dense_len == 0 {
        bail!("QTVC sparse payload: zero dense length");
    }
    // Untrusted length: the mask must fit what is actually left in the
    // section before any allocation is sized from it.
    let mask_bytes = dense_len.div_ceil(8);
    if mask_bytes > c.remaining() {
        bail!(
            "QTVC sparse payload: truncated bitmask ({} bytes left for a \
             {mask_bytes}-byte mask over {dense_len} elements)",
            c.remaining()
        );
    }
    let mask = c.take(mask_bytes)?;
    let survivors = decode_group_payload_view(c.take(c.remaining())?)?;
    SparseGroupQuantizedView::new(dense_len, n_survivors, mask, survivors)
}

/// Inverse of [`encode_sparse_payload`].
pub fn decode_sparse_payload(buf: &[u8]) -> Result<SparseGroupQuantized> {
    Ok(decode_sparse_payload_view(buf)?.to_owned())
}

/// Encode a binary-switch vector (kind-5 section body):
/// ```text
///   group u64, n_groups u64
///   scales f32 * n_groups
///   signs: ceil(group * n_groups / 8) bytes (LSB-first; tail bits 0)
/// ```
pub fn encode_binary_payload(b: &BinarySwitch) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(&(b.group as u64).to_le_bytes());
    buf.extend_from_slice(&(b.n_groups() as u64).to_le_bytes());
    for &s in &b.scales {
        buf.extend_from_slice(&s.to_le_bytes());
    }
    buf.extend_from_slice(&b.signs);
    buf
}

/// Zero-copy inverse of [`encode_binary_payload`]: scale table and sign
/// bitmap stay borrowed from `buf`.  Every structural invariant — scale
/// count vs bitmap length, tail bits, overflow on `group * n_groups` — is
/// validated so corrupt sections fail closed; this is the single parse
/// path for kind-5 bodies (the owned [`decode_binary_payload`]
/// materializes from it).
pub fn decode_binary_payload_view(buf: &[u8]) -> Result<BinarySwitchView<'_>> {
    let mut c = Cursor::new(buf);
    let group = c.u64()? as usize;
    let n_groups = c.u64()? as usize;
    // Untrusted count: the scale table occupies 4 bytes per group, so
    // n_groups must fit what is actually left in the section before any
    // slice is sized from it.
    if n_groups > c.remaining() / 4 {
        bail!(
            "QTVC binary payload: n_groups {n_groups} exceeds section size \
             ({} bytes left)",
            c.remaining()
        );
    }
    let scales = c.take(n_groups * 4)?;
    let signs = c.take(c.remaining())?;
    BinarySwitchView::new(group, n_groups, scales, signs)
}

/// Inverse of [`encode_binary_payload`].
pub fn decode_binary_payload(buf: &[u8]) -> Result<BinarySwitch> {
    Ok(decode_binary_payload_view(buf)?.to_owned())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::Checkpoint;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn sample_q(bits: u8, seed: u64) -> QuantizedCheckpoint {
        let mut rng = Rng::new(seed);
        let mut ck = Checkpoint::new();
        // Adversarial numels: word-straddling for 3/5/6/7-bit widths.
        ck.insert("a/w", Tensor::randn(&[7, 9], 0.02, &mut rng));
        ck.insert("b/w", Tensor::randn(&[65], 0.02, &mut rng));
        ck.insert("c/w", Tensor::randn(&[3, 2, 4], 0.02, &mut rng));
        QuantizedCheckpoint::quantize(&ck, bits).unwrap()
    }

    #[test]
    fn checkpoint_payload_roundtrips_all_widths() {
        for bits in 1u8..=8 {
            let q = sample_q(bits, 100 + bits as u64);
            let wire = encode_checkpoint_payload(&q);
            let back = decode_checkpoint_payload(&wire).unwrap();
            assert_eq!(back, q, "bits={bits}");
        }
    }

    #[test]
    fn group_payload_roundtrips() {
        let mut rng = Rng::new(7);
        let mut v = vec![0.0f32; 4096];
        rng.fill_normal(&mut v, 0.05);
        for bits in [2u8, 3, 4, 8] {
            let g = GroupQuantized::quantize(&v, bits, 512).unwrap();
            let wire = encode_group_payload(&g);
            let back = decode_group_payload(&wire).unwrap();
            assert_eq!(back, g, "bits={bits}");
        }
    }

    #[test]
    fn decode_rejects_corruption() {
        let q = sample_q(4, 9);
        let wire = encode_checkpoint_payload(&q);
        // Truncation at every structural boundary fails cleanly.
        assert!(decode_checkpoint_payload(&wire[..wire.len() - 1]).is_err());
        assert!(decode_checkpoint_payload(&wire[..3]).is_err());
        assert!(decode_checkpoint_payload(&[]).is_err());
        // Trailing garbage is rejected too.
        let mut padded = wire.clone();
        padded.push(0);
        assert!(decode_checkpoint_payload(&padded).is_err());
        // Invalid bit width.
        let mut bad = wire;
        bad[0] = 11;
        assert!(decode_checkpoint_payload(&bad).is_err());
    }

    #[test]
    fn decode_rejects_adversarial_counts_without_allocating() {
        // A group section claiming 2^61 groups in a 33-byte body must
        // bail on the bounds check before sizing any allocation from it.
        let mut wire = Vec::new();
        wire.push(4u8); // bits
        wire.extend_from_slice(&8u64.to_le_bytes()); // group
        wire.extend_from_slice(&(1u64 << 61).to_le_bytes()); // n_groups
        wire.extend_from_slice(&[0u8; 16]);
        let err = decode_group_payload(&wire).unwrap_err().to_string();
        assert!(err.contains("exceeds section size"), "got: {err}");

        // A checkpoint tensor whose dims multiply past usize::MAX must
        // bail on checked arithmetic, not wrap or panic.
        let mut wire = Vec::new();
        wire.push(4u8); // bits
        wire.extend_from_slice(&1u32.to_le_bytes()); // tensor count
        wire.extend_from_slice(&1u32.to_le_bytes()); // name_len
        wire.push(b'x');
        wire.extend_from_slice(&2u32.to_le_bytes()); // ndim
        wire.extend_from_slice(&(1u64 << 33).to_le_bytes());
        wire.extend_from_slice(&(1u64 << 33).to_le_bytes());
        wire.extend_from_slice(&0f32.to_le_bytes()); // scale
        wire.extend_from_slice(&0f32.to_le_bytes()); // zp
        let err = decode_checkpoint_payload(&wire).unwrap_err().to_string();
        assert!(err.contains("shape overflow"), "got: {err}");
    }

    #[test]
    fn payload_enum_dispatch() {
        let q = sample_q(3, 10);
        let p = Payload::Checkpoint(q.clone());
        let wire = p.encode();
        let back = Payload::decode(PayloadKind::TaskCheckpoint, &wire).unwrap();
        assert_eq!(back, p);
        assert_eq!(p.numel(), q.numel());
        for kind in [
            PayloadKind::TaskCheckpoint,
            PayloadKind::RtvqBase,
            PayloadKind::Group,
            PayloadKind::Plan,
            PayloadKind::SparseGroup,
            PayloadKind::BinarySwitch,
        ] {
            assert_eq!(PayloadKind::from_u8(kind.to_u8()).unwrap(), kind);
        }
        assert!(PayloadKind::from_u8(9).is_err());
        // Plan sections have no Payload decode — they carry a PackPlan.
        assert!(Payload::decode(PayloadKind::Plan, &[]).is_err());
    }

    fn sample_sparse(seed: u64) -> SparseGroupQuantized {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0f32; 500];
        rng.fill_normal(&mut v, 0.05);
        let keep: Vec<usize> = (0..500).step_by(4).collect();
        SparseGroupQuantized::quantize_indices(&v, &keep, 1.0, 3, 64).unwrap()
    }

    #[test]
    fn sparse_payload_roundtrips() {
        let s = sample_sparse(21);
        let wire = encode_sparse_payload(&s);
        let back = decode_sparse_payload(&wire).unwrap();
        assert_eq!(back, s);
        // Through the Payload enum too.
        let p = Payload::SparseGroup(s.clone());
        assert_eq!(p.numel(), 500);
        let back = Payload::decode(PayloadKind::SparseGroup, &p.encode()).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn sparse_payload_truncated_bitmask_rejected() {
        let s = sample_sparse(22);
        let wire = encode_sparse_payload(&s);
        // Cut inside the bitmask region (mask starts at byte 16).
        let err = decode_sparse_payload(&wire[..16 + s.mask.len() / 2])
            .unwrap_err()
            .to_string();
        assert!(err.contains("truncated bitmask"), "got: {err}");
        // Cut inside the survivor payload: clean error, no panic.
        assert!(decode_sparse_payload(&wire[..wire.len() - 3]).is_err());
        // Empty and header-only buffers.
        assert!(decode_sparse_payload(&[]).is_err());
        assert!(decode_sparse_payload(&wire[..16]).is_err());
        // Trailing garbage is corruption.
        let mut padded = wire.clone();
        padded.push(0);
        assert!(decode_sparse_payload(&padded).is_err());
    }

    #[test]
    fn sparse_payload_mask_survivor_mismatch_rejected() {
        let s = sample_sparse(23);
        let mut wire = encode_sparse_payload(&s);
        // Set one extra mask bit: popcount no longer matches the header's
        // survivor count.  (At the registry level the section CRC catches
        // this first; the decoder must catch it even with a fixed CRC.)
        wire[16] |= 0b10; // index 1 is not in the keep-every-4 set
        let err = decode_sparse_payload(&wire).unwrap_err().to_string();
        assert!(err.contains("bitmask/survivor-count mismatch"), "got: {err}");
        // Survivor count claiming more than dense_len.
        let mut bad = encode_sparse_payload(&s);
        bad[8..16].copy_from_slice(&(501u64).to_le_bytes());
        assert!(decode_sparse_payload(&bad).is_err());
        // Zero dense length.
        let mut bad = encode_sparse_payload(&s);
        bad[0..8].copy_from_slice(&0u64.to_le_bytes());
        assert!(decode_sparse_payload(&bad).is_err());
        // Absurd dense length must bail on the mask bound, not allocate.
        let mut bad = encode_sparse_payload(&s);
        bad[0..8].copy_from_slice(&(1u64 << 61).to_le_bytes());
        let err = decode_sparse_payload(&bad).unwrap_err().to_string();
        assert!(err.contains("truncated bitmask"), "got: {err}");
    }

    #[test]
    fn payload_view_decodes_identically_to_owned() {
        // Group sections: the borrowed view and the owned decode agree
        // bit-for-bit, and the view's dequantization matches the owned one.
        let mut rng = Rng::new(41);
        let mut v = vec![0.0f32; 2048];
        rng.fill_normal(&mut v, 0.05);
        let g = GroupQuantized::quantize(&v, 3, 256).unwrap();
        let wire = encode_group_payload(&g);
        let view = decode_group_payload_view(&wire).unwrap();
        assert_eq!(view.to_owned(), g);
        let mut scratch = Vec::new();
        let mut out = vec![0.0f32; 2048];
        view.dequantize_into(&mut out, &mut scratch);
        assert_eq!(out, g.dequantize());

        // Sparse sections, through the PayloadView dispatch.
        let s = sample_sparse(42);
        let wire = encode_sparse_payload(&s);
        match PayloadView::decode(PayloadKind::SparseGroup, &wire).unwrap() {
            PayloadView::SparseGroup(sv) => assert_eq!(sv.to_owned(), s),
            other => panic!("unexpected view {other:?}"),
        }
        // Checkpoint payloads come back owned either way.
        let q = sample_q(4, 43);
        let wire = encode_checkpoint_payload(&q);
        match PayloadView::decode(PayloadKind::TaskCheckpoint, &wire).unwrap() {
            PayloadView::Checkpoint(back) => assert_eq!(back, q),
            other => panic!("unexpected view {other:?}"),
        }
        // Plan sections have no view decode either.
        assert!(PayloadView::decode(PayloadKind::Plan, &[]).is_err());
        // as_group / as_sparse guards.
        let gwire = encode_group_payload(&g);
        let pv = PayloadView::decode(PayloadKind::Group, &gwire).unwrap();
        assert!(pv.as_group().is_ok());
        assert!(pv.as_sparse().is_err());
    }

    #[test]
    fn view_and_owned_decoders_reject_corruption_identically() {
        // The owned decoder is view + to_owned, so every corruption that
        // fails one must fail the other with the same error.
        let s = sample_sparse(44);
        let wire = encode_sparse_payload(&s);
        for cut in [0, 8, 16, 16 + s.mask.len() / 2, wire.len() - 3] {
            let owned = decode_sparse_payload(&wire[..cut]).unwrap_err().to_string();
            let viewed =
                decode_sparse_payload_view(&wire[..cut]).unwrap_err().to_string();
            assert_eq!(owned, viewed, "cut={cut}");
        }
        let mut bad = wire.clone();
        bad[16] |= 0b10;
        assert_eq!(
            decode_sparse_payload(&bad).unwrap_err().to_string(),
            decode_sparse_payload_view(&bad).unwrap_err().to_string()
        );
    }

    fn sample_binary(seed: u64) -> BinarySwitch {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0f32; 300];
        rng.fill_normal(&mut v, 0.05);
        BinarySwitch::quantize(&v, 60).unwrap()
    }

    #[test]
    fn binary_payload_roundtrips() {
        let b = sample_binary(31);
        let wire = encode_binary_payload(&b);
        // Byte-exact wire size: 16-byte header + scales + sign bitmap.
        assert_eq!(wire.len(), 16 + 4 * b.n_groups() + b.signs.len());
        let back = decode_binary_payload(&wire).unwrap();
        assert_eq!(back, b);
        // Through the Payload enum too.
        let p = Payload::Binary(b.clone());
        assert_eq!(p.numel(), 300);
        let back = Payload::decode(PayloadKind::BinarySwitch, &p.encode()).unwrap();
        assert_eq!(back, p);
        // And the zero-copy view path: identical container, identical
        // reconstruction, as_binary guard behaves.
        let pv = PayloadView::decode(PayloadKind::BinarySwitch, &wire).unwrap();
        assert_eq!(pv.to_owned(), p);
        let view = pv.as_binary().unwrap();
        let mut out = vec![0.0f32; 300];
        view.dequantize_into(&mut out);
        assert_eq!(out, b.dequantize());
        assert!(pv.as_group().is_err());
        assert!(pv.as_sparse().is_err());
        let gwire = {
            let mut rng = Rng::new(32);
            let mut v = vec![0.0f32; 512];
            rng.fill_normal(&mut v, 0.05);
            encode_group_payload(&GroupQuantized::quantize(&v, 3, 64).unwrap())
        };
        assert!(PayloadView::decode(PayloadKind::Group, &gwire).unwrap().as_binary().is_err());
    }

    #[test]
    fn binary_payload_rejects_corruption() {
        let b = sample_binary(33);
        let wire = encode_binary_payload(&b);
        // Cut inside the sign bitmap: pointed truncation error.
        let err = decode_binary_payload(&wire[..wire.len() - 3]).unwrap_err().to_string();
        assert!(err.contains("truncated sign bitmap"), "got: {err}");
        // Cut inside the scale table, header-only, and empty buffers.
        assert!(decode_binary_payload(&wire[..20]).is_err());
        assert!(decode_binary_payload(&wire[..16]).is_err());
        assert!(decode_binary_payload(&[]).is_err());
        // Trailing garbage is corruption.
        let mut padded = wire.clone();
        padded.push(0);
        assert!(decode_binary_payload(&padded).is_err());
        // Scale-count mismatch against the bitmap (decoder-level: the
        // registry CRC catches a re-stamp first, the decoder must catch
        // it even with a fixed CRC).
        let mut bad = wire.clone();
        bad[8..16].copy_from_slice(&4u64.to_le_bytes()); // 5 groups -> 4
        assert!(decode_binary_payload(&bad).is_err());
        // Zero group width / zero scale count.
        let mut bad = wire.clone();
        bad[0..8].copy_from_slice(&0u64.to_le_bytes());
        assert!(decode_binary_payload(&bad).is_err());
        let mut bad = wire.clone();
        bad[8..16].copy_from_slice(&0u64.to_le_bytes());
        assert!(decode_binary_payload(&bad).is_err());
        // Sign bits set past the logical length: non-canonical tail.
        let mut v = vec![0.0f32; 5];
        v[0] = 1.0;
        let small = BinarySwitch::quantize(&v, 5).unwrap();
        let mut bad = encode_binary_payload(&small);
        let last = bad.len() - 1;
        bad[last] |= 0b1110_0000;
        let err = decode_binary_payload(&bad).unwrap_err().to_string();
        assert!(err.contains("past length"), "got: {err}");
    }

    #[test]
    fn binary_payload_rejects_adversarial_counts_without_allocating() {
        // A 2^61 group count in a 20-byte body must bail on the bounds
        // check before sizing any slice from it; a group width that
        // overflows group * n_groups must bail on checked arithmetic.
        let mut wire = Vec::new();
        wire.extend_from_slice(&8u64.to_le_bytes()); // group
        wire.extend_from_slice(&(1u64 << 61).to_le_bytes()); // n_groups
        wire.extend_from_slice(&[0u8; 4]);
        let err = decode_binary_payload(&wire).unwrap_err().to_string();
        assert!(err.contains("exceeds section size"), "got: {err}");

        let mut wire = Vec::new();
        wire.extend_from_slice(&u64::MAX.to_le_bytes()); // group
        wire.extend_from_slice(&2u64.to_le_bytes()); // n_groups
        wire.extend_from_slice(&[0u8; 8]); // 2 scales
        let err = decode_binary_payload(&wire).unwrap_err().to_string();
        assert!(err.contains("overflows"), "got: {err}");
    }

    #[test]
    fn binary_view_and_owned_decoders_reject_corruption_identically() {
        let b = sample_binary(34);
        let wire = encode_binary_payload(&b);
        for cut in [0, 8, 16, 16 + 2, wire.len() - 3] {
            let owned = decode_binary_payload(&wire[..cut]).unwrap_err().to_string();
            let viewed =
                decode_binary_payload_view(&wire[..cut]).unwrap_err().to_string();
            assert_eq!(owned, viewed, "cut={cut}");
        }
    }

    #[test]
    fn registry_scheme_label_roundtrip() {
        use crate::quant::QuantScheme;
        for scheme in [
            RegistryScheme::Uniform(QuantScheme::Tvq(4)),
            RegistryScheme::Uniform(QuantScheme::Rtvq(3, 2)),
            RegistryScheme::Planned,
        ] {
            assert_eq!(RegistryScheme::parse(&scheme.label()).unwrap(), scheme);
        }
        assert_eq!(RegistryScheme::Planned.uniform(), None);
        assert_eq!(
            RegistryScheme::Uniform(QuantScheme::Tvq(3)).uniform(),
            Some(QuantScheme::Tvq(3))
        );
        assert!(RegistryScheme::parse("nonsense").is_err());
    }

    #[test]
    fn group_payload_truncated_params_rejected() {
        let mut rng = Rng::new(11);
        let mut v = vec![0.0f32; 1024];
        rng.fill_normal(&mut v, 0.05);
        let g = GroupQuantized::quantize(&v, 3, 256).unwrap();
        let wire = encode_group_payload(&g);
        // Cut inside the scales/zps region: must fail cleanly.
        assert!(decode_group_payload(&wire[..20]).is_err());
        // Cut inside the packed codes: truncation error, no panic.
        assert!(decode_group_payload(&wire[..wire.len() - 2]).is_err());
        // Trailing garbage rejected.
        let mut padded = wire.clone();
        padded.push(0);
        assert!(decode_group_payload(&padded).is_err());
        // Zero group size rejected before any division.
        let mut zero = wire.clone();
        zero[1..9].copy_from_slice(&0u64.to_le_bytes());
        assert!(decode_group_payload(&zero).is_err());
        // Invalid bit width.
        let mut bad = wire;
        bad[0] = 0;
        assert!(decode_group_payload(&bad).is_err());
    }
}
