//! Read-only file memory mapping for the zero-copy registry serve path.
//!
//! The offline build vendors no `libc` crate, so the two syscalls the
//! mapping needs (`mmap(2)` / `munmap(2)`) are declared directly against
//! the platform C library every unix target already links.  The wrapper
//! is deliberately tiny: map the whole file once, read-only and private,
//! hand out `&[u8]`, unmap on drop.
//!
//! # Portability
//!
//! Enabled on 64-bit unix only: `PROT_READ == 1` and `MAP_PRIVATE == 2`
//! hold across Linux, macOS and the BSDs, and on LP64 targets the
//! `off_t` offset argument is 64-bit so the raw declaration below matches
//! the libc ABI.  On 32-bit unix (where glibc's plain `mmap` takes a
//! 32-bit offset) and on non-unix targets, [`supported()`] returns false
//! and [`Registry`](super::Registry) falls back to positioned reads —
//! callers never see a wrong-ABI call, just a clean fallback.
//!
//! # Lifetime / mutation hazards
//!
//! The mapping pins the file's *inode*, not its path: the registry
//! writer's atomic rename-over replaces the path but leaves an existing
//! mapping intact and consistent.  In-place truncation of the mapped file
//! is the one hazard — touching pages past the new EOF raises `SIGBUS`,
//! which no userspace bounds check can intercept.  Registry files are
//! written via temp-file + rename and never modified in place, so the
//! hazard requires an external actor; `docs/WIRE_FORMAT.md` §7 records
//! the operational rule (replace registries by rename, never truncate).

use std::fs;

use anyhow::{bail, Result};

/// Whether this target gets a real `mmap(2)` path.
pub(crate) fn supported() -> bool {
    cfg!(all(unix, target_pointer_width = "64"))
}

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    use std::ffi::c_void;
    use std::os::raw::c_int;

    // Stable across Linux / macOS / BSD.
    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

/// A whole-file read-only mapping.
pub(crate) struct Mmap {
    #[cfg(all(unix, target_pointer_width = "64"))]
    ptr: *const u8,
    len: usize,
}

// SAFETY: the mapping is read-only (PROT_READ) and private; the wrapper
// exposes only shared `&[u8]` access, which is safe from any thread.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map all of `file` read-only.  Fails (cleanly, for the caller to
    /// fall back on) for empty files, unsupported targets, or a refused
    /// `mmap(2)`.
    #[cfg_attr(not(all(unix, target_pointer_width = "64")), allow(unused_variables))]
    pub fn map(file: &fs::File) -> Result<Self> {
        let len = file.metadata()?.len();
        if len == 0 {
            bail!("refusing to map an empty file");
        }
        let len = usize::try_from(len)
            .map_err(|_| anyhow::anyhow!("file too large to map on this target"))?;
        #[cfg(all(unix, target_pointer_width = "64"))]
        {
            use std::os::unix::io::AsRawFd;
            // SAFETY: fd is a valid open file descriptor for the lifetime
            // of the call; len > 0; a private read-only mapping of a
            // regular file has no aliasing requirements on our side.
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ,
                    sys::MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr as isize == -1 {
                bail!("mmap(2) failed (len {len})");
            }
            Ok(Mmap { ptr: ptr as *const u8, len })
        }
        #[cfg(not(all(unix, target_pointer_width = "64")))]
        {
            bail!("mmap unsupported on this target")
        }
    }

    /// The mapped file bytes.
    pub fn bytes(&self) -> &[u8] {
        #[cfg(all(unix, target_pointer_width = "64"))]
        // SAFETY: ptr is a live PROT_READ mapping of exactly `len` bytes,
        // valid until munmap in Drop; no mutable aliases exist.
        unsafe {
            std::slice::from_raw_parts(self.ptr, self.len)
        }
        #[cfg(not(all(unix, target_pointer_width = "64")))]
        unreachable!("Mmap cannot be constructed on unsupported targets")
    }

    pub fn len(&self) -> usize {
        self.len
    }
}

#[cfg(all(unix, target_pointer_width = "64"))]
impl Drop for Mmap {
    fn drop(&mut self) {
        // SAFETY: (ptr, len) is exactly what mmap returned; unmapping a
        // private read-only mapping cannot fail in a way we could handle.
        unsafe {
            sys::munmap(self.ptr as *mut std::ffi::c_void, self.len);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn maps_a_real_file_and_reads_it_back() {
        if !supported() {
            return;
        }
        let path = std::env::temp_dir().join("tvq_mmap_unit");
        let body: Vec<u8> = (0..10_000u32).map(|i| (i % 251) as u8).collect();
        {
            let mut f = fs::File::create(&path).unwrap();
            f.write_all(&body).unwrap();
        }
        let f = fs::File::open(&path).unwrap();
        let m = Mmap::map(&f).unwrap();
        assert_eq!(m.len(), body.len());
        assert_eq!(m.bytes(), &body[..]);
        drop(f); // mapping outlives the descriptor
        assert_eq!(&m.bytes()[4096..4100], &body[4096..4100]);
        drop(m);
        fs::remove_file(&path).ok();
    }

    #[test]
    fn refuses_empty_files() {
        let path = std::env::temp_dir().join("tvq_mmap_empty");
        fs::File::create(&path).unwrap();
        let f = fs::File::open(&path).unwrap();
        assert!(Mmap::map(&f).is_err());
        fs::remove_file(&path).ok();
    }
}
