//! The `MANIFEST.qtvm` sharded-registry wire format: content-addressed
//! section chunks spread across shard files, behind a paged row index.
//!
//! A monolithic `.qtvc` file holds every section of every task plus one
//! resident offset table — fine for hundreds of tasks, hostile at fleet
//! scale where a serve node touches a handful of tasks out of thousands.
//! Sharding splits the same sections into N `*.qtvs` shard files and one
//! small `MANIFEST.qtvm` that maps section **names** to **chunks**
//! `(shard, offset, length, crc, content-hash)`:
//!
//! * **Content addressing / dedup** — two sections with byte-identical
//!   bodies (shared RTVQ bases, TALL mtl masks, duplicated deltas) point
//!   at one chunk; the bytes are stored once.  [`shard_registry`]
//!   confirms every hash hit with a full byte compare, so an FNV
//!   collision can never silently alias two different sections.
//! * **Paged index** — rows are sorted by name and grouped into fixed
//!   CRC'd pages behind a tiny directory; opening a sharded zoo reads
//!   the header + directory only, and a lookup loads (and caches) just
//!   the one page it needs.  See `docs/WIRE_FORMAT.md` §"MANIFEST.qtvm".
//! * **Tier independence** — a chunk address is meaningful without the
//!   shard file in hand (the manifest records every shard's size), so
//!   the same manifest drives tier-0 local reads and tier-1 TCP fetches
//!   ([`super::store`]), with identical fail-closed verification.
//!
//! Byte layout (all little-endian, strings are `u32` length + UTF-8):
//!
//! ```text
//! magic "QTVM"  u32          version u32 (=1)
//! scheme        str          (must be "PLAN-MIXED")
//! source_version u32         (the .qtvc version sharded from: 3/4/5)
//! plan_len u32  plan bytes   plan_crc u32   (verbatim kind-3 plan body)
//! shard_cnt u32  { name str, file_bytes u64 } * shard_cnt
//! row_cnt   u64
//! page_cnt  u32  { first str, rows u32, offset u64, length u64, crc u32 } *
//! index_crc u32              (CRC-32 of all preceding bytes)
//! page bodies: { name str, kind u8, shard u32, offset u64,
//!                length u64, crc u32, hash u64 } * rows, per page
//! ```
//!
//! Shard files are 8 bytes of header (magic "QTVS" u32, version u32)
//! followed by raw chunk bodies at the offsets the manifest records.

use std::collections::HashMap;
use std::fs;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::container::{
    Cursor, PayloadKind, RegistryScheme, VERSION_BINARY, VERSION_PLANNED, VERSION_SPARSE,
};
use super::index::{HeaderReader, Registry, SectionScratch};
use crate::obs;
use crate::planner::PackPlan;
use crate::util::crc32;

/// `"QTVM"` little-endian.
pub const MANIFEST_MAGIC: u32 = 0x4D56_5451;
/// Manifest wire version this build reads and writes.
pub const MANIFEST_VERSION: u32 = 1;
/// `"QTVS"` little-endian — shard-file magic.
pub const SHARD_MAGIC: u32 = 0x5356_5451;
/// Shard-file wire version.
pub const SHARD_VERSION: u32 = 1;
/// Shard files carry an 8-byte header (magic + version) before chunk 0.
pub const SHARD_HEADER_BYTES: u64 = 8;
/// Canonical manifest file name inside a sharded-zoo directory.
pub const MANIFEST_FILE_NAME: &str = "MANIFEST.qtvm";
/// Default rows per index page (a page is the unit of lazy index load).
pub const DEFAULT_PAGE_ROWS: usize = 64;

/// Hard caps guarding against nonsense headers, mirroring the monolithic
/// registry's fail-fast posture.
const MAX_SHARDS: usize = 1 << 10;
const MAX_PAGES: usize = 1 << 20;
const MAX_ROWS: u64 = 1 << 20;
const MAX_NAME_LEN: usize = 4096;
const MAX_PLAN_BYTES: usize = 1 << 28;

/// FNV-1a 64-bit — the chunk content hash.  Dedup candidates found by
/// hash are always confirmed by a full byte compare before aliasing, and
/// readers re-hash every fetched chunk, so FNV's weakness as a
/// cryptographic hash costs nothing here.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in bytes {
        h = (h ^ u64::from(b)).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// The content-addressed location of one section body.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkAddr {
    /// Index into the manifest's shard table.
    pub shard: u32,
    /// Absolute offset of the chunk body inside the shard file.
    pub offset: u64,
    /// Chunk body length in bytes.
    pub length: u64,
    /// CRC-32 of the chunk body.
    pub crc: u32,
    /// FNV-1a 64 of the chunk body — the dedup/content address.
    pub hash: u64,
}

/// One row of the paged manifest index: section name → chunk.
#[derive(Clone, Debug)]
pub struct ManifestRow {
    pub name: String,
    pub kind: PayloadKind,
    pub chunk: ChunkAddr,
}

/// One shard file as the manifest records it.
#[derive(Clone, Debug)]
pub struct ShardMeta {
    /// File name relative to the manifest's directory (no path
    /// separators — validated at read).
    pub name: String,
    /// Total shard size including the 8-byte header; chunk ranges are
    /// validated against this without touching the shard itself.
    pub file_bytes: u64,
}

/// Directory entry for one index page.
#[derive(Clone, Debug)]
pub struct PageMeta {
    /// Name of the page's first row (pages partition the sorted row
    /// space, so the directory alone binary-searches to the right page).
    pub first: String,
    /// Rows in this page.
    pub rows: u32,
    /// Absolute offset of the page body inside the manifest file.
    pub offset: u64,
    /// Page body length in bytes.
    pub length: u64,
    /// CRC-32 of the page body.
    pub crc: u32,
}

/// A decoded `MANIFEST.qtvm` header: everything except the row pages,
/// which load lazily through [`Manifest::read_page`].
pub struct Manifest {
    scheme: RegistryScheme,
    source_version: u32,
    plan: PackPlan,
    shards: Vec<ShardMeta>,
    row_cnt: u64,
    pages: Vec<PageMeta>,
    /// Bytes of header + directory + trailing CRC.
    header_bytes: u64,
    /// Manifest file size at read time (bounds pages).
    file_bytes: u64,
}

impl Manifest {
    /// Read and verify the manifest header + page directory (CRC'd as a
    /// unit); page bodies stay on disk until [`Manifest::read_page`].
    pub fn read(path: &Path) -> Result<Manifest> {
        let _span = obs::span(obs::Category::Registry, "manifest_open");
        let file = fs::File::open(path)
            .with_context(|| format!("opening manifest {}", path.display()))?;
        let file_bytes = file.metadata()?.len();
        let mut r = HeaderReader { inner: std::io::BufReader::new(file), raw: Vec::new() };

        let magic = r.u32()?;
        if magic != MANIFEST_MAGIC {
            bail!(
                "not a QTVM manifest: {} (magic {magic:#010x}, expected {MANIFEST_MAGIC:#010x})",
                path.display()
            );
        }
        let version = r.u32()?;
        if version != MANIFEST_VERSION {
            bail!(
                "unsupported QTVM version {version} in {} (this build reads v{MANIFEST_VERSION})",
                path.display()
            );
        }
        let label = r.str(64)?;
        let scheme = RegistryScheme::parse(&label)
            .with_context(|| format!("manifest {} carries bad scheme label", path.display()))?;
        if scheme != RegistryScheme::Planned {
            bail!(
                "manifest {} carries uniform scheme {label:?}; only PLAN-MIXED \
                 registries shard (uniform zoos have no per-tensor sections to chunk)",
                path.display()
            );
        }
        let source_version = r.u32()?;
        if source_version != VERSION_PLANNED
            && source_version != VERSION_SPARSE
            && source_version != VERSION_BINARY
        {
            bail!(
                "manifest {} claims source version {source_version} (planned \
                 registries are v{VERSION_PLANNED}/v{VERSION_SPARSE}/v{VERSION_BINARY})",
                path.display()
            );
        }

        let plan_len = r.u32()? as usize;
        if plan_len > MAX_PLAN_BYTES {
            bail!("QTVM plan section claims {plan_len} bytes (cap {MAX_PLAN_BYTES})");
        }
        let plan_bytes = r.take(plan_len)?.to_vec();
        let plan_crc = r.u32()?;
        if crc32(&plan_bytes) != plan_crc {
            bail!(
                "QTVM plan section CRC mismatch in {} (corrupt manifest)",
                path.display()
            );
        }
        let plan = PackPlan::decode(&plan_bytes)
            .with_context(|| format!("decoding plan embedded in {}", path.display()))?;
        // Same version/arm-set consistency contract as Registry::open_with:
        // the recorded source version must match the plan's arm families.
        if plan.has_onebit_arms() != (source_version == VERSION_BINARY) {
            bail!(
                "manifest {} source version {source_version} disagrees with its \
                 plan's 1-bit arm set (binary-arm registries are v{VERSION_BINARY})",
                path.display()
            );
        }
        if plan.has_sparse_arms()
            && source_version != VERSION_SPARSE
            && source_version != VERSION_BINARY
        {
            bail!(
                "manifest {} source version {source_version} disagrees with its \
                 plan's sparse arm set (sparse-arm registries are \
                 v{VERSION_SPARSE}/v{VERSION_BINARY})",
                path.display()
            );
        }

        let shard_cnt = r.u32()? as usize;
        if shard_cnt == 0 || shard_cnt > MAX_SHARDS {
            bail!("QTVM manifest claims {shard_cnt} shards (must be 1..={MAX_SHARDS})");
        }
        let mut shards = Vec::with_capacity(shard_cnt);
        for _ in 0..shard_cnt {
            let name = r.str(MAX_NAME_LEN)?;
            if name.is_empty()
                || name == "."
                || name == ".."
                || name.contains('/')
                || name.contains('\\')
            {
                bail!(
                    "QTVM shard name {name:?} is not a plain file name \
                     (manifest-relative, no path separators)"
                );
            }
            let file_bytes = r.u64()?;
            if file_bytes < SHARD_HEADER_BYTES {
                bail!(
                    "QTVM shard {name:?} claims {file_bytes} bytes, below the \
                     {SHARD_HEADER_BYTES}-byte shard header"
                );
            }
            shards.push(ShardMeta { name, file_bytes });
        }

        let row_cnt = r.u64()?;
        if row_cnt > MAX_ROWS {
            bail!("QTVM manifest claims {row_cnt} rows (cap {MAX_ROWS}) — corrupt header?");
        }
        let expected = plan.expected_sections();
        if row_cnt != expected.len() as u64 {
            bail!(
                "manifest {} indexes {row_cnt} sections; its plan expects {}",
                path.display(),
                expected.len()
            );
        }

        let page_cnt = r.u32()? as usize;
        if page_cnt > MAX_PAGES {
            bail!("QTVM manifest claims {page_cnt} index pages (cap {MAX_PAGES})");
        }
        let mut pages = Vec::with_capacity(page_cnt);
        for _ in 0..page_cnt {
            let first = r.str(MAX_NAME_LEN)?;
            let rows = r.u32()?;
            let offset = r.u64()?;
            let length = r.u64()?;
            let crc = r.u32()?;
            if rows == 0 {
                bail!("QTVM index page {first:?} claims 0 rows");
            }
            pages.push(PageMeta { first, rows, offset, length, crc });
        }

        let mut crc_buf = [0u8; 4];
        r.inner
            .read_exact(&mut crc_buf)
            .map_err(|_| anyhow::anyhow!("truncated QTVM manifest (missing index CRC)"))?;
        if u32::from_le_bytes(crc_buf) != crc32(&r.raw) {
            bail!(
                "QTVM index CRC mismatch in {} (corrupt or truncated manifest)",
                path.display()
            );
        }
        let header_bytes = r.raw.len() as u64 + 4;

        // Directory invariants: strictly ascending firsts (binary-search
        // correctness), page bodies inside the file past the header, and
        // row counts summing to the declared total.
        for w in pages.windows(2) {
            if w[0].first >= w[1].first {
                bail!(
                    "QTVM index pages out of order ({:?} then {:?}) — corrupt directory",
                    w[0].first,
                    w[1].first
                );
            }
        }
        let mut rows_total = 0u64;
        for pg in &pages {
            match pg.offset.checked_add(pg.length) {
                Some(end) if pg.offset >= header_bytes && end <= file_bytes => {}
                _ => bail!(
                    "QTVM index page {:?} spans [{}, +{}) outside the manifest \
                     file ({} bytes, {header_bytes}-byte header)",
                    pg.first,
                    pg.offset,
                    pg.length,
                    file_bytes
                ),
            }
            rows_total += u64::from(pg.rows);
        }
        if rows_total != row_cnt {
            bail!(
                "QTVM index pages carry {rows_total} rows but the header \
                 declares {row_cnt}"
            );
        }

        Ok(Manifest {
            scheme,
            source_version,
            plan,
            shards,
            row_cnt,
            pages,
            header_bytes,
            file_bytes,
        })
    }

    pub fn scheme(&self) -> RegistryScheme {
        self.scheme
    }

    /// Wire version of the `.qtvc` registry this manifest was sharded
    /// from (3 dense-planned, 4 sparse, 5 binary).
    pub fn source_version(&self) -> u32 {
        self.source_version
    }

    pub fn plan(&self) -> &PackPlan {
        &self.plan
    }

    pub fn shards(&self) -> &[ShardMeta] {
        &self.shards
    }

    pub fn pages(&self) -> &[PageMeta] {
        &self.pages
    }

    pub fn row_count(&self) -> u64 {
        self.row_cnt
    }

    /// Bytes of resident header + directory (what an open costs before
    /// any page loads).
    pub fn header_bytes(&self) -> u64 {
        self.header_bytes
    }

    /// Index of the page that would hold `name`, by directory binary
    /// search — `None` when `name` sorts before every page.
    pub fn page_for(&self, name: &str) -> Option<usize> {
        let n = self.pages.partition_point(|pg| pg.first.as_str() <= name);
        n.checked_sub(1)
    }

    /// Read, CRC-verify and decode one index page from the manifest file.
    /// Every row is validated against the shard table before it is handed
    /// out, so a chunk address from a verified page is always in range.
    pub fn read_page(&self, path: &Path, p: usize) -> Result<Vec<ManifestRow>> {
        let pg = self
            .pages
            .get(p)
            .ok_or_else(|| {
                anyhow::anyhow!("page index {p} out of range ({} pages)", self.pages.len())
            })?;
        let mut f = fs::File::open(path)
            .with_context(|| format!("reopening manifest {}", path.display()))?;
        f.seek(SeekFrom::Start(pg.offset))?;
        let mut buf = vec![0u8; pg.length as usize];
        f.read_exact(&mut buf).map_err(|_| {
            anyhow::anyhow!(
                "truncated QTVM index page {:?} in {} (corrupt manifest)",
                pg.first,
                path.display()
            )
        })?;
        if crc32(&buf) != pg.crc {
            bail!(
                "QTVM index page {:?} CRC mismatch in {} (corrupt manifest)",
                pg.first,
                path.display()
            );
        }
        let mut c = Cursor::new(&buf);
        let mut rows: Vec<ManifestRow> = Vec::with_capacity(pg.rows as usize);
        for _ in 0..pg.rows {
            let name = c.str()?;
            if name.len() > MAX_NAME_LEN {
                bail!("QTVM row name exceeds {MAX_NAME_LEN} bytes");
            }
            let kind = PayloadKind::from_u8(c.u8()?)?;
            if kind == PayloadKind::Plan {
                bail!(
                    "QTVM row {name:?} claims a plan-kind chunk (the plan is \
                     embedded in the manifest header, never a chunk)"
                );
            }
            let shard = c.u32()?;
            let offset = c.u64()?;
            let length = c.u64()?;
            let crc = c.u32()?;
            let hash = c.u64()?;
            let meta = self.shards.get(shard as usize).ok_or_else(|| {
                anyhow::anyhow!(
                    "QTVM row {name:?} references shard {shard} of {}",
                    self.shards.len()
                )
            })?;
            match offset.checked_add(length) {
                Some(end) if offset >= SHARD_HEADER_BYTES && end <= meta.file_bytes => {}
                _ => bail!(
                    "QTVM row {name:?} chunk spans [{offset}, +{length}) outside \
                     shard {:?} ({} bytes)",
                    meta.name,
                    meta.file_bytes
                ),
            }
            if let Some(prev) = rows.last() {
                if prev.name.as_str() >= name.as_str() {
                    bail!(
                        "QTVM index page {:?} rows out of order ({:?} then {name:?})",
                        pg.first,
                        prev.name
                    );
                }
            } else if name != pg.first {
                bail!(
                    "QTVM index page starts with row {name:?} but the directory \
                     says {:?}",
                    pg.first
                );
            }
            rows.push(ManifestRow {
                name,
                kind,
                chunk: ChunkAddr { shard, offset, length, crc, hash },
            });
        }
        if !c.done() {
            bail!(
                "QTVM index page {:?} carries {} trailing bytes past its rows",
                pg.first,
                c.remaining()
            );
        }
        if let Some(next) = self.pages.get(p + 1) {
            if rows.last().map(|r| r.name.as_str()) >= Some(next.first.as_str()) {
                bail!(
                    "QTVM index page {:?} overlaps the next page ({:?})",
                    pg.first,
                    next.first
                );
            }
        }
        Ok(rows)
    }

    /// Manifest file size recorded at read time.
    pub fn file_bytes(&self) -> u64 {
        self.file_bytes
    }
}

/// Knobs for [`shard_registry`].
#[derive(Clone, Copy, Debug)]
pub struct ShardOptions {
    /// Number of shard files to spread unique chunks across.
    pub n_shards: usize,
    /// Rows per index page.
    pub page_rows: usize,
}

impl Default for ShardOptions {
    fn default() -> Self {
        ShardOptions { n_shards: 4, page_rows: DEFAULT_PAGE_ROWS }
    }
}

/// What [`shard_registry`] produced, for reporting and assertions.
#[derive(Clone, Debug)]
pub struct ShardSummary {
    pub manifest_path: PathBuf,
    pub shard_paths: Vec<PathBuf>,
    /// Sections indexed (manifest rows).
    pub n_sections: usize,
    /// Unique chunks actually stored.
    pub n_unique_chunks: usize,
    /// Rows that aliased an earlier row's chunk (dedup hits).
    pub n_dedup_hits: usize,
    /// Total bytes across all shard files (headers included).
    pub shard_bytes: u64,
    /// Manifest file bytes.
    pub manifest_bytes: u64,
    /// The monolithic source registry's size, for the savings headline.
    pub source_bytes: u64,
}

impl ShardSummary {
    /// Total on-disk footprint of the sharded zoo.
    pub fn total_bytes(&self) -> u64 {
        self.shard_bytes + self.manifest_bytes
    }
}

fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_str(buf: &mut Vec<u8>, s: &str) {
    push_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let mut f = fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    fs::rename(&tmp, path)
        .with_context(|| format!("publishing {}", path.display()))?;
    Ok(())
}

/// Split a planned (`PLAN-MIXED`) registry into `opts.n_shards` shard
/// files plus a `MANIFEST.qtvm` under `out_dir`, deduplicating
/// byte-identical section bodies by content hash.  Every section is read
/// back CRC-verified from the source before it is chunked, and both
/// outputs are written atomically (`.tmp` + rename), so a crash mid-shard
/// never leaves a half-valid manifest behind.
pub fn shard_registry(src: &Registry, out_dir: &Path, opts: &ShardOptions) -> Result<ShardSummary> {
    let _span = obs::span(obs::Category::Registry, "registry_shard");
    if src.plan().is_none() {
        bail!(
            "only PLAN-MIXED registries shard; {} is {:?} — repack it with \
             `tvq registry pack --planned` first",
            src.path().display(),
            src.scheme().label()
        );
    }
    if opts.n_shards == 0 || opts.n_shards > MAX_SHARDS {
        bail!("shard count {} out of range (1..={MAX_SHARDS})", opts.n_shards);
    }
    if opts.page_rows == 0 {
        bail!("page_rows must be at least 1");
    }
    fs::create_dir_all(out_dir)
        .with_context(|| format!("creating shard directory {}", out_dir.display()))?;

    // The verbatim plan body rides inside the manifest header, so a
    // sharded zoo opens without touching any shard file.
    let mut scratch = SectionScratch::default();
    let plan_entry = src
        .entries()
        .iter()
        .find(|e| e.kind == PayloadKind::Plan)
        .expect("planned registries always carry a plan section");
    let plan_bytes = src.section_bytes(plan_entry, &mut scratch)?.to_vec();

    // Sections in sorted-name order (the manifest's row order).
    let mut sections: Vec<&super::index::IndexEntry> =
        src.entries().iter().filter(|e| e.kind != PayloadKind::Plan).collect();
    sections.sort_by(|a, b| a.name.cmp(&b.name));

    let mut shard_bufs: Vec<Vec<u8>> = (0..opts.n_shards)
        .map(|_| {
            let mut b = Vec::new();
            push_u32(&mut b, SHARD_MAGIC);
            push_u32(&mut b, SHARD_VERSION);
            b
        })
        .collect();
    // hash -> chunks already stored with that hash (usually exactly one;
    // more only under an FNV collision between distinct bodies).
    let mut by_hash: HashMap<u64, Vec<ChunkAddr>> = HashMap::new();
    let mut rows: Vec<ManifestRow> = Vec::with_capacity(sections.len());
    let mut next_shard = 0usize;
    let mut n_unique = 0usize;
    let mut n_dups = 0usize;

    for entry in sections {
        let bytes = src.section_bytes(entry, &mut scratch)?;
        let hash = fnv64(bytes);
        let existing = by_hash.get(&hash).and_then(|cands| {
            cands.iter().copied().find(|c| {
                c.length == bytes.len() as u64 && {
                    let buf = &shard_bufs[c.shard as usize];
                    let start = c.offset as usize;
                    &buf[start..start + bytes.len()] == bytes
                }
            })
        });
        let chunk = match existing {
            Some(c) => {
                n_dups += 1;
                c
            }
            None => {
                let shard = next_shard;
                next_shard = (next_shard + 1) % opts.n_shards;
                let buf = &mut shard_bufs[shard];
                let offset = buf.len() as u64;
                buf.extend_from_slice(bytes);
                n_unique += 1;
                let c = ChunkAddr {
                    shard: shard as u32,
                    offset,
                    length: bytes.len() as u64,
                    crc: entry.crc,
                    hash,
                };
                by_hash.entry(hash).or_default().push(c);
                c
            }
        };
        rows.push(ManifestRow { name: entry.name.clone(), kind: entry.kind, chunk });
    }

    // Shard files first: a manifest must never exist before the chunks
    // it points at.
    let width = if opts.n_shards > 100 { 4 } else { 2 };
    let mut shard_paths = Vec::with_capacity(opts.n_shards);
    let mut shard_metas = Vec::with_capacity(opts.n_shards);
    let mut shard_bytes_total = 0u64;
    for (i, buf) in shard_bufs.iter().enumerate() {
        let name = format!("shard-{i:0width$}.qtvs");
        let path = out_dir.join(&name);
        write_atomic(&path, buf)?;
        shard_bytes_total += buf.len() as u64;
        shard_metas.push(ShardMeta { name, file_bytes: buf.len() as u64 });
        shard_paths.push(path);
    }

    // Manifest header + directory, two-pass: directory offsets are
    // fixed-width, so serialize once with zeros to learn the header
    // length, then again with real page offsets.
    let page_bodies: Vec<Vec<u8>> = rows
        .chunks(opts.page_rows)
        .map(|page| {
            let mut b = Vec::new();
            for row in page {
                push_str(&mut b, &row.name);
                b.push(row.kind.to_u8());
                push_u32(&mut b, row.chunk.shard);
                push_u64(&mut b, row.chunk.offset);
                push_u64(&mut b, row.chunk.length);
                push_u32(&mut b, row.chunk.crc);
                push_u64(&mut b, row.chunk.hash);
            }
            b
        })
        .collect();
    let page_firsts: Vec<&str> =
        rows.chunks(opts.page_rows).map(|page| page[0].name.as_str()).collect();
    let page_rows_cnt: Vec<u32> =
        rows.chunks(opts.page_rows).map(|page| page.len() as u32).collect();

    let encode_header = |offsets: &[u64]| -> Vec<u8> {
        let mut h = Vec::new();
        push_u32(&mut h, MANIFEST_MAGIC);
        push_u32(&mut h, MANIFEST_VERSION);
        push_str(&mut h, &src.scheme().label());
        push_u32(&mut h, src.version());
        push_u32(&mut h, plan_bytes.len() as u32);
        h.extend_from_slice(&plan_bytes);
        push_u32(&mut h, crc32(&plan_bytes));
        push_u32(&mut h, shard_metas.len() as u32);
        for m in &shard_metas {
            push_str(&mut h, &m.name);
            push_u64(&mut h, m.file_bytes);
        }
        push_u64(&mut h, rows.len() as u64);
        push_u32(&mut h, page_bodies.len() as u32);
        for (i, body) in page_bodies.iter().enumerate() {
            push_str(&mut h, page_firsts[i]);
            push_u32(&mut h, page_rows_cnt[i]);
            push_u64(&mut h, offsets.get(i).copied().unwrap_or(0));
            push_u64(&mut h, body.len() as u64);
            push_u32(&mut h, crc32(body));
        }
        h
    };
    let header_len = encode_header(&vec![0; page_bodies.len()]).len() as u64 + 4;
    let mut offsets = Vec::with_capacity(page_bodies.len());
    let mut at = header_len;
    for body in &page_bodies {
        offsets.push(at);
        at += body.len() as u64;
    }
    let mut manifest_bytes = encode_header(&offsets);
    let index_crc = crc32(&manifest_bytes);
    push_u32(&mut manifest_bytes, index_crc);
    for body in &page_bodies {
        manifest_bytes.extend_from_slice(body);
    }

    let manifest_path = out_dir.join(MANIFEST_FILE_NAME);
    write_atomic(&manifest_path, &manifest_bytes)?;

    Ok(ShardSummary {
        manifest_path,
        shard_paths,
        n_sections: rows.len(),
        n_unique_chunks: n_unique,
        n_dedup_hits: n_dups,
        shard_bytes: shard_bytes_total,
        manifest_bytes: manifest_bytes.len() as u64,
        source_bytes: src.file_bytes(),
    })
}
