//! Building and writing `QTVC` v2 registry files.
//!
//! [`RegistryBuilder`] assembles named quantized payloads and serializes
//! them atomically (write-to-temp + rename, like the `TVQC` store);
//! [`build_registry`] is the one-call path from a raw zoo `(pre, fts)` to
//! a packed registry under any TVQ/RTVQ scheme.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::container::{encode_checkpoint_payload, PayloadKind, MAGIC, VERSION};
use crate::checkpoint::Checkpoint;
use crate::quant::{QuantScheme, QuantizedCheckpoint, Rtvq};
use crate::util::crc32;

/// Exact byte accounting returned by a registry write.
#[derive(Clone, Debug)]
pub struct WriteSummary {
    pub path: PathBuf,
    pub scheme: QuantScheme,
    pub n_tasks: usize,
    /// Total file size (== `index_bytes + payload_bytes`).
    pub file_bytes: u64,
    /// Header + offset table + index CRC.
    pub index_bytes: u64,
    /// Sum of all payload sections.
    pub payload_bytes: u64,
}

struct PendingEntry {
    name: String,
    kind: PayloadKind,
    body: Vec<u8>,
}

/// Assembles a registry in memory, then writes it in one pass.
pub struct RegistryBuilder {
    scheme: QuantScheme,
    base: Option<PendingEntry>,
    tasks: Vec<PendingEntry>,
}

impl RegistryBuilder {
    pub fn new(scheme: QuantScheme) -> Self {
        Self { scheme, base: None, tasks: Vec::new() }
    }

    fn check_name(&self, name: &str) -> Result<()> {
        if name.is_empty() {
            bail!("registry entry name must be non-empty");
        }
        if self.tasks.iter().any(|e| e.name == name) {
            bail!("duplicate registry entry name {name:?}");
        }
        Ok(())
    }

    /// Add one task's quantized payload (a TVQ task vector, an RTVQ
    /// offset, or an FQ checkpoint, depending on the scheme).
    pub fn add_task(&mut self, name: &str, q: &QuantizedCheckpoint) -> Result<&mut Self> {
        self.check_name(name)?;
        self.tasks.push(PendingEntry {
            name: name.to_string(),
            kind: PayloadKind::TaskCheckpoint,
            body: encode_checkpoint_payload(q),
        });
        Ok(self)
    }

    /// Set the shared RTVQ base payload (stored once, amortized).
    pub fn set_rtvq_base(&mut self, q: &QuantizedCheckpoint) -> Result<&mut Self> {
        if self.base.is_some() {
            bail!("RTVQ base already set");
        }
        self.base = Some(PendingEntry {
            name: "__rtvq_base__".to_string(),
            kind: PayloadKind::RtvqBase,
            body: encode_checkpoint_payload(q),
        });
        Ok(self)
    }

    /// Serialize to `path` (atomic: temp file + rename).
    pub fn write<P: AsRef<Path>>(&self, path: P) -> Result<WriteSummary> {
        let path = path.as_ref();
        if self.tasks.is_empty() {
            bail!("refusing to write an empty registry");
        }
        match self.scheme {
            QuantScheme::Rtvq(..) if self.base.is_none() => {
                bail!("RTVQ registry needs set_rtvq_base before write")
            }
            QuantScheme::Fp32 => bail!("fp32 zoos use the TVQC checkpoint store, not QTVC"),
            _ => {}
        }

        // Entry order on disk: the shared base first, then tasks.
        let entries: Vec<&PendingEntry> =
            self.base.iter().chain(self.tasks.iter()).collect();

        let label = self.scheme.label();
        // Header prefix: magic + version + scheme label + entry count.
        let mut index: Vec<u8> = Vec::new();
        index.extend_from_slice(&MAGIC.to_le_bytes());
        index.extend_from_slice(&VERSION.to_le_bytes());
        index.extend_from_slice(&(label.len() as u32).to_le_bytes());
        index.extend_from_slice(label.as_bytes());
        index.extend_from_slice(&(entries.len() as u32).to_le_bytes());

        // The offset table's own size must be known before offsets can be
        // assigned: each row is name_len(4) + name + kind(1) + offset(8)
        // + length(8) + crc(4), and the table ends with a 4-byte CRC.
        let rows_bytes: usize =
            entries.iter().map(|e| 4 + e.name.len() + 1 + 8 + 8 + 4).sum();
        let index_bytes = (index.len() + rows_bytes + 4) as u64;

        let mut offset = index_bytes;
        for e in &entries {
            index.extend_from_slice(&(e.name.len() as u32).to_le_bytes());
            index.extend_from_slice(e.name.as_bytes());
            index.push(e.kind.to_u8());
            index.extend_from_slice(&offset.to_le_bytes());
            index.extend_from_slice(&(e.body.len() as u64).to_le_bytes());
            index.extend_from_slice(&crc32(&e.body).to_le_bytes());
            offset += e.body.len() as u64;
        }
        let index_crc = crc32(&index);
        index.extend_from_slice(&index_crc.to_le_bytes());
        debug_assert_eq!(index.len() as u64, index_bytes);

        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let tmp = path.with_extension("tmp");
        {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(&index)?;
            for e in &entries {
                f.write_all(&e.body)?;
            }
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?;

        let payload_bytes: u64 = entries.iter().map(|e| e.body.len() as u64).sum();
        Ok(WriteSummary {
            path: path.to_path_buf(),
            scheme: self.scheme,
            n_tasks: self.tasks.len(),
            file_bytes: index_bytes + payload_bytes,
            index_bytes,
            payload_bytes,
        })
    }
}

/// Quantize a zoo `(pre, fts)` under `scheme` and write the packed
/// registry to `path`.  Task names default to `task00`, `task01`, ...
///
/// * `Tvq(b)`       — each task vector tau_t = ft_t - pre quantized at b bits.
/// * `Rtvq(bb, bo)` — Algorithm 1 with error correction: one shared base
///   at bb bits + per-task offsets at bo bits.
/// * `Fq` / `Fp32`  — rejected: FQ payloads need the trunk at read time
///   and fp32 zoos already have the TVQC store.
pub fn build_registry<P: AsRef<Path>>(
    pre: &Checkpoint,
    fts: &[Checkpoint],
    scheme: QuantScheme,
    path: P,
) -> Result<WriteSummary> {
    if fts.is_empty() {
        bail!("cannot build a registry from zero fine-tuned checkpoints");
    }
    let mut b = RegistryBuilder::new(scheme);
    match scheme {
        QuantScheme::Tvq(bits) => {
            for (t, ft) in fts.iter().enumerate() {
                let tau = ft.sub(pre)?;
                b.add_task(&format!("task{t:02}"), &QuantizedCheckpoint::quantize(&tau, bits)?)?;
            }
        }
        QuantScheme::Rtvq(bb, bo) => {
            let r = Rtvq::quantize(pre, fts, bb, bo, true)?;
            b.set_rtvq_base(&r.base)?;
            for (t, off) in r.offsets.iter().enumerate() {
                b.add_task(&format!("task{t:02}"), off)?;
            }
        }
        QuantScheme::Fq(_) | QuantScheme::Fp32 => {
            bail!("registries store packed task payloads; {:?} is not supported", scheme)
        }
    }
    b.write(path)
}
