//! Building and writing `QTVC` registry files.
//!
//! [`RegistryBuilder`] assembles named quantized payloads and serializes
//! them atomically (write-to-temp + rename, like the `TVQC` store);
//! [`build_registry`] is the one-call path from a raw zoo `(pre, fts)` to
//! a uniform packed registry under any TVQ/RTVQ scheme.  Plan-packed
//! mixed-precision registries are assembled through
//! [`RegistryBuilder::new_planned`] — normally via
//! [`write_planned_registry`](crate::planner::write_planned_registry),
//! which also enforces that the written bytes match the plan's cost model
//! exactly.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::container::{
    encode_binary_payload, encode_checkpoint_payload, encode_group_payload,
    encode_sparse_payload, PayloadKind, RegistryScheme, MAGIC, VERSION, VERSION_BINARY,
    VERSION_PLANNED, VERSION_SPARSE,
};
use crate::checkpoint::Checkpoint;
use crate::planner::PackPlan;
use crate::quant::{
    BinarySwitch, GroupQuantized, QuantScheme, QuantizedCheckpoint, Rtvq, SparseGroupQuantized,
};
use crate::util::crc32;
use crate::util::exec::ExecCtx;
use crate::util::pool::Pool;

/// Exact byte accounting returned by a registry write.
#[derive(Clone, Debug)]
pub struct WriteSummary {
    pub path: PathBuf,
    pub scheme: RegistryScheme,
    pub n_tasks: usize,
    /// Total file size (== `index_bytes + payload_bytes`).
    pub file_bytes: u64,
    /// Header + offset table + index CRC.
    pub index_bytes: u64,
    /// Sum of all payload sections.
    pub payload_bytes: u64,
}

struct PendingEntry {
    name: String,
    kind: PayloadKind,
    body: Vec<u8>,
}

/// Assembles a registry in memory, then writes it in one pass.
pub struct RegistryBuilder {
    scheme: RegistryScheme,
    base: Option<PendingEntry>,
    tasks: Vec<PendingEntry>,
    /// Planned registries: kind-2 group sections, written in insertion
    /// order after the plan section.
    groups: Vec<PendingEntry>,
    plan: Option<PendingEntry>,
    plan_tasks: usize,
}

impl RegistryBuilder {
    /// A uniform-scheme (v2) registry builder.
    pub fn new(scheme: QuantScheme) -> Self {
        Self {
            scheme: RegistryScheme::Uniform(scheme),
            base: None,
            tasks: Vec::new(),
            groups: Vec::new(),
            plan: None,
            plan_tasks: 0,
        }
    }

    /// A plan-packed mixed-precision (v3) registry builder.
    pub fn new_planned() -> Self {
        Self {
            scheme: RegistryScheme::Planned,
            base: None,
            tasks: Vec::new(),
            groups: Vec::new(),
            plan: None,
            plan_tasks: 0,
        }
    }

    fn check_name(&self, name: &str) -> Result<()> {
        if name.is_empty() {
            bail!("registry entry name must be non-empty");
        }
        if self.tasks.iter().chain(&self.groups).any(|e| e.name == name) {
            bail!("duplicate registry entry name {name:?}");
        }
        Ok(())
    }

    /// Add one task's quantized payload (a TVQ task vector, an RTVQ
    /// offset, or an FQ checkpoint, depending on the scheme).
    pub fn add_task(&mut self, name: &str, q: &QuantizedCheckpoint) -> Result<&mut Self> {
        if matches!(self.scheme, RegistryScheme::Planned) {
            bail!("planned registries take group sections, not checkpoint payloads");
        }
        self.check_name(name)?;
        self.tasks.push(PendingEntry {
            name: name.to_string(),
            kind: PayloadKind::TaskCheckpoint,
            body: encode_checkpoint_payload(q),
        });
        Ok(self)
    }

    /// Set the shared RTVQ base payload (stored once, amortized).
    pub fn set_rtvq_base(&mut self, q: &QuantizedCheckpoint) -> Result<&mut Self> {
        if matches!(self.scheme, RegistryScheme::Planned) {
            bail!("planned registries store per-tensor bases as group sections");
        }
        if self.base.is_some() {
            bail!("RTVQ base already set");
        }
        self.base = Some(PendingEntry {
            name: "__rtvq_base__".to_string(),
            kind: PayloadKind::RtvqBase,
            body: encode_checkpoint_payload(q),
        });
        Ok(self)
    }

    /// Add one kind-2 group-quantized section (planned registries only).
    pub fn add_group(&mut self, name: &str, g: &GroupQuantized) -> Result<&mut Self> {
        if !matches!(self.scheme, RegistryScheme::Planned) {
            bail!("group sections require a planned registry (RegistryBuilder::new_planned)");
        }
        if name == crate::planner::plan::PLAN_SECTION_NAME {
            bail!("{name:?} is reserved for the plan section");
        }
        self.check_name(name)?;
        self.groups.push(PendingEntry {
            name: name.to_string(),
            kind: PayloadKind::Group,
            body: encode_group_payload(g),
        });
        Ok(self)
    }

    /// Add one kind-4 sparse section (planned registries only).  Any
    /// sparse section bumps the written file to QTVC v4.
    pub fn add_sparse(&mut self, name: &str, s: &SparseGroupQuantized) -> Result<&mut Self> {
        if !matches!(self.scheme, RegistryScheme::Planned) {
            bail!("sparse sections require a planned registry (RegistryBuilder::new_planned)");
        }
        if name == crate::planner::plan::PLAN_SECTION_NAME {
            bail!("{name:?} is reserved for the plan section");
        }
        self.check_name(name)?;
        self.groups.push(PendingEntry {
            name: name.to_string(),
            kind: PayloadKind::SparseGroup,
            body: encode_sparse_payload(s),
        });
        Ok(self)
    }

    /// Add one kind-5 binary-switch section (planned registries only).
    /// Any binary section bumps the written file to QTVC v5.
    pub fn add_binary(&mut self, name: &str, b: &BinarySwitch) -> Result<&mut Self> {
        if !matches!(self.scheme, RegistryScheme::Planned) {
            bail!("binary sections require a planned registry (RegistryBuilder::new_planned)");
        }
        if name == crate::planner::plan::PLAN_SECTION_NAME {
            bail!("{name:?} is reserved for the plan section");
        }
        self.check_name(name)?;
        self.groups.push(PendingEntry {
            name: name.to_string(),
            kind: PayloadKind::BinarySwitch,
            body: encode_binary_payload(b),
        });
        Ok(self)
    }

    /// Embed the pack plan (planned registries only; exactly once).
    pub fn set_plan(&mut self, plan: &PackPlan) -> Result<&mut Self> {
        if !matches!(self.scheme, RegistryScheme::Planned) {
            bail!("only planned registries carry a plan section");
        }
        if self.plan.is_some() {
            bail!("plan section already set");
        }
        plan.validate()?;
        self.plan = Some(PendingEntry {
            name: crate::planner::plan::PLAN_SECTION_NAME.to_string(),
            kind: PayloadKind::Plan,
            body: plan.encode(),
        });
        self.plan_tasks = plan.n_tasks();
        Ok(self)
    }

    /// Entry order on disk: plan first (planned), or base then tasks
    /// (uniform), then group sections in insertion order.
    fn entries(&self) -> Vec<&PendingEntry> {
        self.plan
            .iter()
            .chain(self.base.iter())
            .chain(self.tasks.iter())
            .chain(self.groups.iter())
            .collect()
    }

    fn validate(&self) -> Result<()> {
        match self.scheme {
            RegistryScheme::Planned => {
                if self.plan.is_none() {
                    bail!("planned registry needs set_plan before write");
                }
                if self.groups.is_empty() {
                    bail!("refusing to write a planned registry with no group sections");
                }
            }
            RegistryScheme::Uniform(scheme) => {
                if self.tasks.is_empty() {
                    bail!("refusing to write an empty registry");
                }
                match scheme {
                    QuantScheme::Rtvq(..) if self.base.is_none() => {
                        bail!("RTVQ registry needs set_rtvq_base before write")
                    }
                    QuantScheme::Fp32 => {
                        bail!("fp32 zoos use the TVQC checkpoint store, not QTVC")
                    }
                    _ => {}
                }
            }
        }
        Ok(())
    }

    /// Serialize to `path` (atomic: temp file + rename).
    pub fn write<P: AsRef<Path>>(&self, path: P) -> Result<WriteSummary> {
        let path = path.as_ref();
        self.validate()?;
        let entries = self.entries();
        let (index, payload_bytes) = self.layout(&entries);
        let index_bytes = index.len() as u64;

        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let tmp = path.with_extension("tmp");
        {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(&index)?;
            for e in &entries {
                f.write_all(&e.body)?;
            }
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?;

        Ok(WriteSummary {
            path: path.to_path_buf(),
            scheme: self.scheme,
            n_tasks: match self.scheme {
                RegistryScheme::Planned => self.plan_tasks,
                RegistryScheme::Uniform(_) => self.tasks.len(),
            },
            file_bytes: index_bytes + payload_bytes,
            index_bytes,
            payload_bytes,
        })
    }

    /// Exact file size this builder would write, without touching disk.
    pub fn projected_file_bytes(&self) -> Result<u64> {
        self.validate()?;
        let entries = self.entries();
        let (index, payload_bytes) = self.layout(&entries);
        Ok(index.len() as u64 + payload_bytes)
    }

    /// Serialize the header + offset table; returns it with the total
    /// payload byte count.
    fn layout(&self, entries: &[&PendingEntry]) -> (Vec<u8>, u64) {
        let label = self.scheme.label();
        let has_sparse = self
            .groups
            .iter()
            .any(|e| e.kind == PayloadKind::SparseGroup);
        let has_binary = self
            .groups
            .iter()
            .any(|e| e.kind == PayloadKind::BinarySwitch);
        // Highest section kind wins: v5 files may also carry kind-4
        // sections, per the compat policy.
        let version = match self.scheme {
            RegistryScheme::Planned if has_binary => VERSION_BINARY,
            RegistryScheme::Planned if has_sparse => VERSION_SPARSE,
            RegistryScheme::Planned => VERSION_PLANNED,
            RegistryScheme::Uniform(_) => VERSION,
        };
        // Header prefix: magic + version + scheme label + entry count.
        let mut index: Vec<u8> = Vec::new();
        index.extend_from_slice(&MAGIC.to_le_bytes());
        index.extend_from_slice(&version.to_le_bytes());
        index.extend_from_slice(&(label.len() as u32).to_le_bytes());
        index.extend_from_slice(label.as_bytes());
        index.extend_from_slice(&(entries.len() as u32).to_le_bytes());

        // The offset table's own size must be known before offsets can be
        // assigned: each row is name_len(4) + name + kind(1) + offset(8)
        // + length(8) + crc(4), and the table ends with a 4-byte CRC.
        let rows_bytes: usize =
            entries.iter().map(|e| 4 + e.name.len() + 1 + 8 + 8 + 4).sum();
        let index_bytes = (index.len() + rows_bytes + 4) as u64;

        let mut offset = index_bytes;
        for e in entries {
            index.extend_from_slice(&(e.name.len() as u32).to_le_bytes());
            index.extend_from_slice(e.name.as_bytes());
            index.push(e.kind.to_u8());
            index.extend_from_slice(&offset.to_le_bytes());
            index.extend_from_slice(&(e.body.len() as u64).to_le_bytes());
            index.extend_from_slice(&crc32(&e.body).to_le_bytes());
            offset += e.body.len() as u64;
        }
        let index_crc = crc32(&index);
        index.extend_from_slice(&index_crc.to_le_bytes());
        debug_assert_eq!(index.len() as u64, index_bytes);
        let payload_bytes: u64 = entries.iter().map(|e| e.body.len() as u64).sum();
        (index, payload_bytes)
    }
}

/// Assemble (without writing) the uniform registry builder for a zoo —
/// shared by [`build_registry`] and [`uniform_registry_bytes`].
/// Per-task quantization fans out across `pool`; tasks are added to the
/// builder in task-index order regardless of completion order, so the
/// serialized bytes are identical at every thread count.
fn uniform_builder(
    pre: &Checkpoint,
    fts: &[Checkpoint],
    scheme: QuantScheme,
    pool: &Pool,
) -> Result<RegistryBuilder> {
    if fts.is_empty() {
        bail!("cannot build a registry from zero fine-tuned checkpoints");
    }
    let mut b = RegistryBuilder::new(scheme);
    match scheme {
        QuantScheme::Tvq(bits) => {
            let qs = pool.try_map(fts.iter().collect(), |_, ft: &Checkpoint| {
                QuantizedCheckpoint::quantize(&ft.sub(pre)?, bits)
            })?;
            for (t, q) in qs.iter().enumerate() {
                b.add_task(&format!("task{t:02}"), q)?;
            }
        }
        QuantScheme::Rtvq(bb, bo) => {
            let r = Rtvq::quantize(pre, fts, bb, bo, true, &ExecCtx::with_pool(pool))?;
            b.set_rtvq_base(&r.base)?;
            for (t, off) in r.offsets.iter().enumerate() {
                b.add_task(&format!("task{t:02}"), off)?;
            }
        }
        QuantScheme::Fq(_) | QuantScheme::Fp32 => {
            bail!("registries store packed task payloads; {:?} is not supported", scheme)
        }
    }
    Ok(b)
}

/// Quantize a zoo `(pre, fts)` under `scheme` and write the packed
/// registry to `path`.  Task names default to `task00`, `task01`, ...
///
/// * `Tvq(b)`       — each task vector tau_t = ft_t - pre quantized at b bits.
/// * `Rtvq(bb, bo)` — Algorithm 1 with error correction: one shared base
///   at bb bits + per-task offsets at bo bits.
/// * `Fq` / `Fp32`  — rejected: FQ payloads need the trunk at read time
///   and fp32 zoos already have the TVQC store.
///
/// Per-task quantization runs on the shared [`Pool`]; written bytes are
/// thread-count-independent (see [`build_registry_with_pool`] to pin the
/// width explicitly).
pub fn build_registry<P: AsRef<Path>>(
    pre: &Checkpoint,
    fts: &[Checkpoint],
    scheme: QuantScheme,
    path: P,
) -> Result<WriteSummary> {
    build_registry_with_pool(pre, fts, scheme, path, Pool::global())
}

/// [`build_registry`] on an explicit pool (thread-scaling benches and
/// the determinism suite pin thread counts through this).
pub fn build_registry_with_pool<P: AsRef<Path>>(
    pre: &Checkpoint,
    fts: &[Checkpoint],
    scheme: QuantScheme,
    path: P,
    pool: &Pool,
) -> Result<WriteSummary> {
    uniform_builder(pre, fts, scheme, pool)?.write(path)
}

/// Exact file bytes the uniform registry for `(pre, fts, scheme)` would
/// occupy, without writing it — the natural budget anchor for the pack
/// planner ("fit into what RTVQ-B3O2 would cost").
///
/// Deliberately computed by assembling the real encoded payloads rather
/// than closed-form arithmetic: it costs one extra quantization pass of
/// the zoo, but it can never drift from the encoder, which is what the
/// "budget anchor == actual uniform file bytes" guarantee rests on.
pub fn uniform_registry_bytes(
    pre: &Checkpoint,
    fts: &[Checkpoint],
    scheme: QuantScheme,
) -> Result<u64> {
    uniform_builder(pre, fts, scheme, Pool::global())?.projected_file_bytes()
}
