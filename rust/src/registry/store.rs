//! Tiered section stores and the sharded registry reader.
//!
//! A [`ShardedRegistry`] is the fleet-scale twin of [`Registry`](super::Registry): it
//! opens a `MANIFEST.qtvm` (header + page directory only — the row pages
//! load lazily, see [`super::manifest`]) and reads section chunks
//! through a [`SectionStore`] tier:
//!
//! * **tier 0** — [`LocalShardStore`]: shard files on local disk, read
//!   through the same mmap/pread/reopen [`IoMode`] ladder as the
//!   monolithic registry.
//! * **tier 1** — [`RemoteStore`]: chunks fetched over TCP from a
//!   `tvq registry fetch-serve` node (`{"cmd":"fetch_section"}` on
//!   `TcpFront`), with an LRU byte-capped local chunk cache keyed by
//!   content hash and a background prefetch worker that warms hot tasks.
//!
//! Every chunk is verified identically regardless of tier — length, then
//! CRC-32, then FNV-64 content hash, all recorded by the manifest — so a
//! corrupt byte produces the **same error** whether it came off a local
//! mmap or a socket, and the bit-exactness contract of the decode paths
//! (shared with [`Registry`](super::Registry) through [`PlannedSectionSource`]) holds
//! across tiers and thread counts.
//!
//! Prefetch policy: a task becomes *hot* on its second section read; its
//! remaining chunks are queued to the store's prefetch worker, filtered
//! by the PR-7 section-read histogram (chunks larger than 4x the
//! process-wide p90 section read are skipped, so one huge outlier tensor
//! cannot monopolize the cache).  See `docs/ARCHITECTURE.md` §"Tiered
//! fetch".

use std::collections::HashMap;
use std::fs;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Context, Result};

use super::container::{PayloadView, RegistryScheme};
use super::index::{
    check_view_against_spec, IoMode, OpenOptions, SectionIo, SectionScratch, Validation,
};
use super::manifest::{
    fnv64, ChunkAddr, Manifest, ManifestRow, ShardMeta, SHARD_HEADER_BYTES, SHARD_MAGIC,
    SHARD_VERSION,
};
use crate::checkpoint::Checkpoint;
use crate::obs;
use crate::planner::plan::{base_section_name, task_section_name};
use crate::planner::{Arm, PackPlan, SectionRole};
use crate::quant::GroupQuantizedView;
use crate::tensor::Tensor;
use crate::util::crc32;
use crate::util::exec::ExecCtx;
use crate::util::json::Json;
use crate::util::pool::Pool;

/// Reads on a task after which its remaining sections are prefetched.
const HOT_TASK_READS: u32 = 2;
/// Prefetch queue depth; excess requests are dropped, never blocked on.
const PREFETCH_QUEUE: usize = 256;
/// Hot chunks larger than this multiple of the p90 section read are not
/// prefetched.
const PREFETCH_P90_FACTOR: u64 = 4;

/// A planned (`PLAN-MIXED`) source of per-slot section views — the
/// abstraction [`crate::planner::fused_merge`] and the shared
/// task-vector decode run against, implemented by both the monolithic
/// [`Registry`](super::Registry) and [`ShardedRegistry`].  One decode path, two storage
/// layouts: bit-exactness across tiers falls out by construction.
pub trait PlannedSectionSource: Sync {
    /// The embedded pack plan; errors for non-planned sources.
    fn pack_plan(&self) -> Result<&PackPlan>;

    /// Borrowed, CRC-verified, spec-cross-checked view of task `t`'s
    /// payload for tensor `l`.
    fn planned_task_view<'a>(
        &'a self,
        t: usize,
        l: usize,
        scratch: &'a mut SectionScratch,
    ) -> Result<PayloadView<'a>>;

    /// Borrowed view of the shared RTVQ base section for tensor `l`.
    fn planned_base_view<'a>(
        &'a self,
        l: usize,
        scratch: &'a mut SectionScratch,
    ) -> Result<GroupQuantizedView<'a>>;

    /// Dequantized per-tensor bases, decoded at most once and cached by
    /// the implementation.
    fn planned_base_hats(&self) -> Result<&[Option<Vec<f32>>]>;

    /// The backing artifact's path, for error messages.
    fn source_path(&self) -> &Path;
}

/// Decode every RTVQ-arm tensor's shared base — the cache-fill body both
/// [`PlannedSectionSource`] implementations run exactly once.
pub(crate) fn decode_planned_base_hats<S: PlannedSectionSource + ?Sized>(
    src: &S,
) -> Result<Vec<Option<Vec<f32>>>> {
    let plan = src.pack_plan()?;
    let mut scratch = SectionScratch::default();
    let mut hats = Vec::with_capacity(plan.n_tensors());
    for l in 0..plan.n_tensors() {
        hats.push(match plan.assignments[l].arm {
            Arm::Rtvq { .. } => {
                Some(src.planned_base_view(l, &mut scratch)?.to_owned().dequantize())
            }
            _ => None,
        });
    }
    Ok(hats)
}

/// Reconstruct task `t`'s full-precision task vector from a planned
/// source, one pool job per tensor.  Tensors assemble in plan order and
/// no job touches another's output, so the reconstruction is
/// bit-identical at every thread count *and* across storage tiers (the
/// sharded tiers feed this same loop); each section decodes through the
/// context's SIMD kernel, itself bit-identical to the scalar reference.
pub(crate) fn planned_task_vector<S: PlannedSectionSource + ?Sized>(
    src: &S,
    t: usize,
    ctx: &ExecCtx,
) -> Result<Checkpoint> {
    let plan = src.pack_plan()?;
    if t >= plan.n_tasks() {
        bail!("task index {t} out of range ({} tasks)", plan.n_tasks());
    }
    let kern = ctx.kernel();
    let base_hats = src.planned_base_hats()?;
    let slots: Vec<usize> = (0..plan.n_tensors()).collect();
    let parts: Vec<Tensor> = ctx.pool().try_map(slots, |_, l| {
        let tensor = &plan.tensors[l];
        let a = &plan.assignments[l];
        // Per-job scratches: in Mmap mode every section is dequantized
        // straight out of the mapping — no byte is staged or copied on
        // this path.
        let mut scratch = SectionScratch::default();
        let mut codes: Vec<u32> = Vec::new();
        let mut vals: Vec<f32> = Vec::new();
        let mut buf = vec![0.0f32; tensor.padded()];
        match src.planned_task_view(t, l, &mut scratch)? {
            PayloadView::Group(gq) => {
                gq.dequantize_into_k(kern, &mut buf, &mut codes);
                if let Arm::Rtvq { .. } = a.arm {
                    let base = base_hats[l]
                        .as_ref()
                        .expect("rtvq-arm tensors always carry a base");
                    for (d, &b) in buf.iter_mut().zip(base) {
                        *d += b;
                    }
                }
            }
            // Sparse arms: survivors scatter into a zeroed dense buffer;
            // masked-out weights reconstruct as 0.
            PayloadView::SparseGroup(s) => {
                s.dequantize_into_k(kern, &mut buf, &mut codes, &mut vals)
            }
            // 1-bit arms: ±scale per sign bit, straight from the bitmap.
            PayloadView::Binary(b) => b.dequantize_into_k(kern, &mut buf),
            other => bail!("planned task section decoded to an unexpected payload: {other:?}"),
        }
        buf.truncate(tensor.numel());
        Tensor::new(tensor.shape.clone(), buf)
    })?;
    let mut out = Checkpoint::new();
    for (tensor, part) in plan.tensors.iter().zip(parts) {
        out.insert(&tensor.name, part);
    }
    Ok(out)
}

/// Where section chunks physically come from.  Implementations return
/// **raw, unverified** bytes; [`ShardedRegistry`] layers the identical
/// length/CRC/hash verification on top of every tier.
pub trait SectionStore: Send + Sync {
    /// 0 = local shard files, 1 = remote TCP fetch.
    fn tier(&self) -> u8;

    /// The raw chunk body: borrowed from a mapping where possible,
    /// staged into `scratch` otherwise.
    fn fetch<'a>(
        &'a self,
        name: &str,
        chunk: &ChunkAddr,
        scratch: &'a mut Vec<u8>,
    ) -> Result<&'a [u8]>;

    /// Queue chunks for background warming.  Best-effort: stores without
    /// a cache (tier 0) ignore it, and a full queue drops requests.
    fn prefetch(&self, chunks: Vec<(String, ChunkAddr)>) {
        let _ = chunks;
    }

    /// `(hits, misses)` of the store's chunk cache, if it has one.
    fn cache_stats(&self) -> (u64, u64) {
        (0, 0)
    }

    /// File-backed bytes served through memory mappings (tier 0 mmap).
    fn mapped_bytes(&self) -> u64 {
        0
    }
}

struct ShardHandle {
    path: PathBuf,
    file_bytes: u64,
    io: SectionIo,
}

/// Tier 0: shard files in a local directory, opened lazily (a reader
/// touching 3 tasks of a 64-shard zoo opens only the shards those tasks'
/// chunks live in) and validated on first open: existence, exact size
/// against the manifest, and the `QTVS` header.
pub struct LocalShardStore {
    dir: PathBuf,
    metas: Vec<ShardMeta>,
    io_mode: IoMode,
    handles: Vec<OnceLock<ShardHandle>>,
}

impl LocalShardStore {
    /// `dir` is the manifest's directory; `metas` its shard table.
    pub fn open(dir: &Path, metas: &[ShardMeta], io_mode: IoMode) -> LocalShardStore {
        LocalShardStore {
            dir: dir.to_path_buf(),
            metas: metas.to_vec(),
            io_mode,
            handles: metas.iter().map(|_| OnceLock::new()).collect(),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.metas.len()
    }

    fn handle(&self, shard: u32) -> Result<&ShardHandle> {
        let meta = self.metas.get(shard as usize).ok_or_else(|| {
            anyhow::anyhow!("chunk references shard {shard} of {}", self.metas.len())
        })?;
        let cell = &self.handles[shard as usize];
        if let Some(h) = cell.get() {
            return Ok(h);
        }
        let built = self.open_shard(meta)?;
        Ok(cell.get_or_init(|| built))
    }

    fn open_shard(&self, meta: &ShardMeta) -> Result<ShardHandle> {
        let path = self.dir.join(&meta.name);
        let len = match fs::metadata(&path) {
            Ok(m) => m.len(),
            Err(_) => bail!(
                "shard file {} is missing (the manifest lists it at {} bytes)",
                path.display(),
                meta.file_bytes
            ),
        };
        if len != meta.file_bytes {
            bail!(
                "shard file {} is {len} bytes but the manifest records {} \
                 (stale or swapped shard)",
                path.display(),
                meta.file_bytes
            );
        }
        let io = SectionIo::new(&path, self.io_mode)?;
        let mut tmp = Vec::new();
        let header = io.read_range(&path, "shard header", 0, 8, &mut tmp)?;
        let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
        let version = u32::from_le_bytes(header[4..8].try_into().unwrap());
        if magic != SHARD_MAGIC {
            bail!(
                "not a QTVS shard: {} (magic {magic:#010x}, expected {SHARD_MAGIC:#010x})",
                path.display()
            );
        }
        if version != SHARD_VERSION {
            bail!(
                "unsupported QTVS version {version} in {} (this build reads v{SHARD_VERSION})",
                path.display()
            );
        }
        Ok(ShardHandle { path, file_bytes: meta.file_bytes, io })
    }

    /// Raw range read for the fetch server: validates the range against
    /// the manifest's shard size, nothing more (the requesting client
    /// verifies CRC + hash against *its* manifest).
    pub fn read_chunk(&self, shard: u32, offset: u64, length: u64) -> Result<Vec<u8>> {
        let meta = self.metas.get(shard as usize).ok_or_else(|| {
            anyhow::anyhow!("fetch_section references shard {shard} of {}", self.metas.len())
        })?;
        match offset.checked_add(length) {
            Some(end) if offset >= SHARD_HEADER_BYTES && end <= meta.file_bytes => {}
            _ => bail!(
                "fetch_section range [{offset}, +{length}) outside shard {:?} ({} bytes)",
                meta.name,
                meta.file_bytes
            ),
        }
        let h = self.handle(shard)?;
        let mut buf = Vec::new();
        let bytes = h.io.read_range(&h.path, "fetched chunk", offset, length, &mut buf)?.to_vec();
        Ok(bytes)
    }
}

impl SectionStore for LocalShardStore {
    fn tier(&self) -> u8 {
        0
    }

    fn fetch<'a>(
        &'a self,
        name: &str,
        chunk: &ChunkAddr,
        scratch: &'a mut Vec<u8>,
    ) -> Result<&'a [u8]> {
        let h = self.handle(chunk.shard)?;
        h.io.read_range(&h.path, name, chunk.offset, chunk.length, scratch)
    }

    fn mapped_bytes(&self) -> u64 {
        self.handles
            .iter()
            .filter_map(|c| c.get())
            .map(|h| h.io.mapped_len(h.file_bytes))
            .sum()
    }
}

/// LRU chunk cache keyed by content hash: dedup'd sections (shared
/// bases) occupy one slot no matter how many rows alias them.
struct ChunkCache {
    map: HashMap<u64, (Vec<u8>, u64)>,
    bytes: usize,
    cap: usize,
    tick: u64,
}

impl ChunkCache {
    fn new(cap: usize) -> ChunkCache {
        ChunkCache { map: HashMap::new(), bytes: 0, cap, tick: 0 }
    }

    fn get(&mut self, hash: u64) -> Option<&Vec<u8>> {
        self.tick += 1;
        let tick = self.tick;
        match self.map.get_mut(&hash) {
            Some((bytes, last)) => {
                *last = tick;
                Some(&*bytes)
            }
            None => None,
        }
    }

    fn contains(&self, hash: u64) -> bool {
        self.map.contains_key(&hash)
    }

    fn insert(&mut self, hash: u64, bytes: Vec<u8>) {
        if bytes.len() > self.cap || self.map.contains_key(&hash) {
            return;
        }
        while self.bytes + bytes.len() > self.cap {
            // O(n) victim scan — caches hold at most a few thousand
            // chunks, and eviction is off the hit path.
            let victim = self
                .map
                .iter()
                .min_by_key(|(_, (_, last))| *last)
                .map(|(&h, _)| h);
            match victim {
                Some(h) => {
                    if let Some((b, _)) = self.map.remove(&h) {
                        self.bytes -= b.len();
                    }
                }
                None => break,
            }
        }
        self.tick += 1;
        self.bytes += bytes.len();
        self.map.insert(hash, (bytes, self.tick));
    }
}

struct RemoteShared {
    addr: String,
    cache: Mutex<ChunkCache>,
    hits: AtomicU64,
    misses: AtomicU64,
    prefetched: AtomicU64,
    prefetch_dropped: AtomicU64,
}

struct FetchConn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl FetchConn {
    fn connect(addr: &str) -> Result<FetchConn> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to section server {addr}"))?;
        let _ = stream.set_nodelay(true);
        let writer = stream.try_clone().context("cloning fetch stream")?;
        Ok(FetchConn { reader: BufReader::new(stream), writer })
    }

    /// One request/response exchange.  Transport failures surface as
    /// `std::io::Error` (retriable); server-reported errors surface as
    /// plain messages, **verbatim**, so tier-1 callers see exactly what
    /// tier 0 would have said for the same fault.
    fn request(&mut self, chunk: &ChunkAddr, out: &mut Vec<u8>) -> Result<()> {
        let req = Json::obj(vec![
            ("cmd", Json::str("fetch_section")),
            ("shard", Json::num(chunk.shard as f64)),
            ("offset", Json::num(chunk.offset as f64)),
            ("length", Json::num(chunk.length as f64)),
        ]);
        writeln!(self.writer, "{req}")?;
        self.writer.flush()?;
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "section server closed the connection",
            )
            .into());
        }
        let reply = Json::parse(line.trim_end())
            .with_context(|| format!("parsing fetch reply {line:?}"))?;
        if let Some(err) = reply.get("error") {
            bail!("{}", err.as_str().unwrap_or("unknown section-server error"));
        }
        let length = reply.req("length")?.as_f64()? as u64;
        if length != chunk.length {
            bail!(
                "section server returned {length} bytes for a {}-byte chunk",
                chunk.length
            );
        }
        out.clear();
        out.resize(length as usize, 0);
        self.reader.read_exact(out)?;
        Ok(())
    }
}

/// Tier 1: chunks fetched over TCP, cached locally (LRU, byte-capped,
/// keyed by content hash), with a background prefetch worker on its own
/// connection.  Transport errors reconnect-and-retry once; errors the
/// *server* reports (missing shard, bad range) are relayed verbatim so
/// tier-1 failures read identically to tier 0.
pub struct RemoteStore {
    shared: Arc<RemoteShared>,
    conn: Mutex<Option<FetchConn>>,
    prefetch_tx: Option<SyncSender<(String, ChunkAddr)>>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl RemoteStore {
    /// Connect eagerly (fast failure on a bad address) and start the
    /// prefetch worker.  `cache_bytes` caps the local chunk cache.
    pub fn connect(addr: &str, cache_bytes: usize) -> Result<RemoteStore> {
        let conn = FetchConn::connect(addr)?;
        let shared = Arc::new(RemoteShared {
            addr: addr.to_string(),
            cache: Mutex::new(ChunkCache::new(cache_bytes)),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            prefetched: AtomicU64::new(0),
            prefetch_dropped: AtomicU64::new(0),
        });
        let (tx, rx) = sync_channel::<(String, ChunkAddr)>(PREFETCH_QUEUE);
        let worker_shared = shared.clone();
        let worker = std::thread::Builder::new()
            .name("tvq-prefetch".to_string())
            .spawn(move || prefetch_loop(worker_shared, rx))
            .context("spawning prefetch worker")?;
        Ok(RemoteStore {
            shared,
            conn: Mutex::new(Some(conn)),
            prefetch_tx: Some(tx),
            worker: Some(worker),
        })
    }

    /// `(prefetched, dropped)` counters of the background warmer.
    pub fn prefetch_stats(&self) -> (u64, u64) {
        (
            self.shared.prefetched.load(Ordering::Relaxed),
            self.shared.prefetch_dropped.load(Ordering::Relaxed),
        )
    }

    fn fetch_uncached(&self, chunk: &ChunkAddr, out: &mut Vec<u8>) -> Result<()> {
        let mut guard = self.conn.lock().unwrap();
        fetch_on(&self.shared.addr, &mut guard, chunk, out)
    }
}

/// Fetch through an optional persistent connection, reconnecting and
/// retrying exactly once on transport errors.  Server-reported errors
/// are final (the server already looked at its disk).
fn fetch_on(
    addr: &str,
    slot: &mut Option<FetchConn>,
    chunk: &ChunkAddr,
    out: &mut Vec<u8>,
) -> Result<()> {
    for attempt in 0..2 {
        if slot.is_none() {
            *slot = Some(FetchConn::connect(addr)?);
        }
        match slot.as_mut().expect("just ensured").request(chunk, out) {
            Ok(()) => return Ok(()),
            Err(e) => {
                let transport = e.downcast_ref::<std::io::Error>().is_some();
                if transport {
                    *slot = None;
                    if attempt == 0 {
                        continue;
                    }
                }
                return Err(e);
            }
        }
    }
    unreachable!("loop returns on every path")
}

fn prefetch_loop(shared: Arc<RemoteShared>, rx: Receiver<(String, ChunkAddr)>) {
    let mut conn: Option<FetchConn> = None;
    let mut buf = Vec::new();
    while let Ok((_name, chunk)) = rx.recv() {
        if shared.cache.lock().unwrap().contains(chunk.hash) {
            continue;
        }
        match fetch_on(&shared.addr, &mut conn, &chunk, &mut buf) {
            Ok(()) => {
                // Verify before caching: a corrupt prefetched chunk must
                // not turn into a poisoned cache hit.
                if buf.len() as u64 == chunk.length
                    && crc32(&buf) == chunk.crc
                    && fnv64(&buf) == chunk.hash
                {
                    shared.cache.lock().unwrap().insert(chunk.hash, buf.clone());
                    shared.prefetched.fetch_add(1, Ordering::Relaxed);
                } else {
                    shared.prefetch_dropped.fetch_add(1, Ordering::Relaxed);
                }
            }
            Err(_) => {
                shared.prefetch_dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

impl SectionStore for RemoteStore {
    fn tier(&self) -> u8 {
        1
    }

    fn fetch<'a>(
        &'a self,
        _name: &str,
        chunk: &ChunkAddr,
        scratch: &'a mut Vec<u8>,
    ) -> Result<&'a [u8]> {
        {
            let mut cache = self.shared.cache.lock().unwrap();
            if let Some(bytes) = cache.get(chunk.hash) {
                scratch.clear();
                scratch.extend_from_slice(bytes);
                drop(cache);
                self.shared.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(&scratch[..]);
            }
        }
        self.shared.misses.fetch_add(1, Ordering::Relaxed);
        self.fetch_uncached(chunk, scratch)?;
        // Cache whatever arrived; the registry's verification layer runs
        // next either way, and a bad insert fails identically on re-read.
        self.shared
            .cache
            .lock()
            .unwrap()
            .insert(chunk.hash, scratch.clone());
        Ok(&scratch[..])
    }

    fn prefetch(&self, chunks: Vec<(String, ChunkAddr)>) {
        let Some(tx) = &self.prefetch_tx else { return };
        for item in chunks {
            match tx.try_send(item) {
                Ok(()) => {}
                Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {
                    self.shared.prefetch_dropped.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    fn cache_stats(&self) -> (u64, u64) {
        (
            self.shared.hits.load(Ordering::Relaxed),
            self.shared.misses.load(Ordering::Relaxed),
        )
    }
}

impl Drop for RemoteStore {
    fn drop(&mut self) {
        // Close the queue, then join the worker so no thread outlives
        // the store (its connection dies with it).
        drop(self.prefetch_tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// A sharded registry: `MANIFEST.qtvm` + chunks through a tier store.
/// The serving twin of [`Registry`](super::Registry) for fleet-scale zoos — same planned
/// accessors, same verification, same bit-exact decode (shared via
/// [`PlannedSectionSource`]), but the index pages lazily and the bytes
/// can live across shard files or across the network.
pub struct ShardedRegistry {
    manifest_path: PathBuf,
    manifest: Manifest,
    store: Arc<dyn SectionStore>,
    /// Lazily loaded, CRC-verified index pages.
    pages: Mutex<HashMap<usize, Arc<Vec<ManifestRow>>>>,
    planned_base_cache: OnceLock<Vec<Option<Vec<f32>>>>,
    /// Per-task section-read counters driving hot-task prefetch.
    task_reads: Vec<AtomicU32>,
    opts: OpenOptions,
}

impl ShardedRegistry {
    /// Open over tier 0 (local shard files next to the manifest) with
    /// default [`OpenOptions`].
    pub fn open<P: AsRef<Path>>(manifest_path: P) -> Result<ShardedRegistry> {
        Self::open_with(manifest_path, OpenOptions::default())
    }

    /// Open over tier 0 with explicit options ([`IoMode`] selects how
    /// shard files are read; [`Validation::Deep`] verifies every chunk;
    /// `paged_index(false)` eagerly loads + CRC-verifies all index pages).
    pub fn open_with<P: AsRef<Path>>(
        manifest_path: P,
        opts: OpenOptions,
    ) -> Result<ShardedRegistry> {
        let manifest_path = manifest_path.as_ref();
        let manifest = Manifest::read(manifest_path)?;
        let dir = manifest_path.parent().unwrap_or_else(|| Path::new("."));
        let store = Arc::new(LocalShardStore::open(dir, manifest.shards(), opts.io_mode()));
        Self::open_with_store(manifest_path, manifest, store, opts)
    }

    /// Open over tier 1: the (small) manifest is read locally, chunks
    /// come from a `tvq registry fetch-serve` node at `addr`, cached
    /// locally under a `cache_bytes` LRU cap.
    pub fn open_remote<P: AsRef<Path>>(
        manifest_path: P,
        addr: &str,
        cache_bytes: usize,
        opts: OpenOptions,
    ) -> Result<ShardedRegistry> {
        let manifest_path = manifest_path.as_ref();
        let manifest = Manifest::read(manifest_path)?;
        let store = Arc::new(RemoteStore::connect(addr, cache_bytes)?);
        Self::open_with_store(manifest_path, manifest, store, opts)
    }

    /// Open over an explicit store (the general constructor).
    pub fn open_with_store(
        manifest_path: &Path,
        manifest: Manifest,
        store: Arc<dyn SectionStore>,
        opts: OpenOptions,
    ) -> Result<ShardedRegistry> {
        let n_tasks = manifest.plan().n_tasks();
        let reg = ShardedRegistry {
            manifest_path: manifest_path.to_path_buf(),
            manifest,
            store,
            pages: Mutex::new(HashMap::new()),
            planned_base_cache: OnceLock::new(),
            task_reads: (0..n_tasks).map(|_| AtomicU32::new(0)).collect(),
            opts,
        };
        if !opts.wants_paged_index() || opts.validation_depth() == Validation::Deep {
            for p in 0..reg.manifest.pages().len() {
                reg.page(p)?;
            }
        }
        if opts.validation_depth() == Validation::Deep {
            reg.validate_deep()?;
        }
        Ok(reg)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn manifest_path(&self) -> &Path {
        &self.manifest_path
    }

    pub fn plan(&self) -> &PackPlan {
        self.manifest.plan()
    }

    pub fn scheme(&self) -> RegistryScheme {
        self.manifest.scheme()
    }

    pub fn n_tasks(&self) -> usize {
        self.manifest.plan().n_tasks()
    }

    pub fn task_names(&self) -> Vec<&str> {
        self.manifest.plan().task_names.iter().map(|s| s.as_str()).collect()
    }

    pub fn task_index(&self, name: &str) -> Option<usize> {
        self.manifest.plan().task_names.iter().position(|n| n == name)
    }

    /// 0 for local shard files, 1 for remote fetch.
    pub fn tier(&self) -> u8 {
        self.store.tier()
    }

    /// `(hits, misses)` of the store's chunk cache (all zeros on tier 0).
    pub fn cache_stats(&self) -> (u64, u64) {
        self.store.cache_stats()
    }

    /// Owned heap bytes pinned for serving: manifest header + loaded
    /// index pages + decoded base caches (mirrors
    /// [`Registry::resident_overhead_bytes`](super::Registry::resident_overhead_bytes)).
    pub fn resident_overhead_bytes(&self) -> usize {
        let mut bytes = self.manifest.header_bytes() as usize;
        for rows in self.pages.lock().unwrap().values() {
            bytes += rows
                .iter()
                .map(|r| r.name.len() + std::mem::size_of::<ManifestRow>())
                .sum::<usize>();
        }
        if let Some(hats) = self.planned_base_cache.get() {
            bytes += hats
                .iter()
                .flatten()
                .map(|v| v.len() * std::mem::size_of::<f32>())
                .sum::<usize>();
        }
        bytes
    }

    /// File-backed bytes served through shard mappings (tier 0 mmap).
    pub fn mapped_bytes(&self) -> u64 {
        self.store.mapped_bytes()
    }

    fn page(&self, p: usize) -> Result<Arc<Vec<ManifestRow>>> {
        if let Some(rows) = self.pages.lock().unwrap().get(&p) {
            return Ok(rows.clone());
        }
        let rows = Arc::new(self.manifest.read_page(&self.manifest_path, p)?);
        Ok(self
            .pages
            .lock()
            .unwrap()
            .entry(p)
            .or_insert_with(|| rows.clone())
            .clone())
    }

    fn lookup(&self, name: &str) -> Result<ManifestRow> {
        let missing = || {
            anyhow::anyhow!(
                "sharded registry {} has no section {name:?}",
                self.manifest_path.display()
            )
        };
        let p = self.manifest.page_for(name).ok_or_else(missing)?;
        let rows = self.page(p)?;
        match rows.binary_search_by(|r| r.name.as_str().cmp(name)) {
            Ok(i) => Ok(rows[i].clone()),
            Err(_) => Err(missing()),
        }
    }

    /// The tier-independent verification wrapper: every chunk read —
    /// local or remote, demand or validation — passes length, CRC-32 and
    /// FNV-64 checks against the manifest row before a byte is decoded,
    /// and feeds the same section-read histograms as the monolithic
    /// registry.
    fn chunk_bytes<'a>(
        &'a self,
        row: &ManifestRow,
        scratch: &'a mut SectionScratch,
    ) -> Result<&'a [u8]> {
        let _span = obs::span(obs::Category::Registry, "section_read")
            .with_arg("bytes", row.chunk.length);
        let t0 = std::time::Instant::now();
        let bytes = self.store.fetch(&row.name, &row.chunk, scratch.buf_mut())?;
        if bytes.len() as u64 != row.chunk.length {
            bail!(
                "QTVC section {:?} fetched {} bytes but the manifest records {} \
                 (corrupt fetch)",
                row.name,
                bytes.len(),
                row.chunk.length
            );
        }
        if crc32(bytes) != row.chunk.crc {
            bail!(
                "QTVC section {:?} CRC mismatch in {} (corrupt registry)",
                row.name,
                self.manifest_path.display()
            );
        }
        if fnv64(bytes) != row.chunk.hash {
            bail!(
                "QTVC section {:?} content-hash mismatch in {} (chunk aliasing corruption)",
                row.name,
                self.manifest_path.display()
            );
        }
        obs::stats().section_read_ns.record_ns(t0.elapsed());
        obs::stats().section_read_bytes.record(row.chunk.length);
        Ok(bytes)
    }

    /// Borrowed, verified view of task `t`'s payload for tensor `l` —
    /// same contract (and same spec cross-check) as
    /// [`Registry::planned_task_view`](super::Registry::planned_task_view).
    pub fn planned_task_view<'a>(
        &'a self,
        t: usize,
        l: usize,
        scratch: &'a mut SectionScratch,
    ) -> Result<PayloadView<'a>> {
        let plan = self.manifest.plan();
        if t >= plan.n_tasks() {
            bail!("task index {t} out of range ({} tasks)", plan.n_tasks());
        }
        if l >= plan.n_tensors() {
            bail!("tensor index {l} out of range ({} tensors)", plan.n_tensors());
        }
        let name = task_section_name(&plan.task_names[t], &plan.tensors[l].name);
        let row = self.lookup(&name)?;
        let view = PayloadView::decode(row.kind, self.chunk_bytes(&row, scratch)?)?;
        check_view_against_spec(
            &view,
            plan.section_spec(SectionRole::Task { task: t, tensor: l }),
            &row.name,
        )?;
        self.note_task_read(t);
        Ok(view)
    }

    /// Borrowed view of the shared base section for tensor `l` — same
    /// contract as [`Registry::planned_base_view`](super::Registry::planned_base_view).
    pub fn planned_base_view<'a>(
        &'a self,
        l: usize,
        scratch: &'a mut SectionScratch,
    ) -> Result<GroupQuantizedView<'a>> {
        let plan = self.manifest.plan();
        if l >= plan.n_tensors() {
            bail!("tensor index {l} out of range ({} tensors)", plan.n_tensors());
        }
        if !matches!(plan.assignments[l].arm, Arm::Rtvq { .. }) {
            bail!(
                "tensor {:?} has no RTVQ arm — no shared base section",
                plan.tensors[l].name
            );
        }
        let name = base_section_name(&plan.tensors[l].name);
        let row = self.lookup(&name)?;
        let view = PayloadView::decode(row.kind, self.chunk_bytes(&row, scratch)?)?;
        let spec = plan.section_spec(SectionRole::Base { tensor: l });
        check_view_against_spec(&view, spec, &row.name)?;
        match view {
            PayloadView::Group(g) => Ok(g),
            other => bail!("base section decoded to a non-group payload: {other:?}"),
        }
    }

    /// Reconstruct task `t`'s full-precision task vector — the sharded
    /// twin of [`Registry::load_task_vector`](super::Registry::load_task_vector),
    /// running the identical shared decode loop.
    pub fn load_task_vector(&self, t: usize, ctx: &ExecCtx) -> Result<Checkpoint> {
        let _op = ctx.op_span(obs::Category::Registry);
        planned_task_vector(self, t, ctx)
    }

    /// Fetch-and-verify every chunk plus a full row-vs-plan binding
    /// check — the publish gate for sharded generations.
    fn validate_deep(&self) -> Result<()> {
        let plan = self.manifest.plan();
        let mut scratch = SectionScratch::default();
        for (name, role) in plan.expected_sections() {
            let row = self.lookup(&name).with_context(|| {
                format!("deep-validating manifest {}", self.manifest_path.display())
            })?;
            let want_kind = plan.expected_section_kind(role);
            if row.kind != want_kind {
                bail!(
                    "sharded registry {}: section {name:?} has kind {:?} but the \
                     plan requires {want_kind:?}",
                    self.manifest_path.display(),
                    row.kind
                );
            }
            self.chunk_bytes(&row, &mut scratch).with_context(|| {
                format!("deep-validating manifest {}", self.manifest_path.display())
            })?;
        }
        Ok(())
    }

    /// Count a section read against task `t`; on the read that makes the
    /// task *hot*, queue its chunks for background prefetch (sized-
    /// filtered by the process-wide section-read p90).
    fn note_task_read(&self, t: usize) {
        let prev = self.task_reads[t].fetch_add(1, Ordering::Relaxed);
        if prev + 1 != HOT_TASK_READS {
            return;
        }
        let plan = self.manifest.plan();
        let hist = &obs::stats().section_read_bytes;
        let size_cap = if hist.count() == 0 {
            u64::MAX
        } else {
            hist.quantile(0.9).saturating_mul(PREFETCH_P90_FACTOR).max(1)
        };
        let mut batch = Vec::new();
        for l in 0..plan.n_tensors() {
            let name = task_section_name(&plan.task_names[t], &plan.tensors[l].name);
            if let Ok(row) = self.lookup(&name) {
                if row.chunk.length <= size_cap {
                    batch.push((row.name, row.chunk));
                }
            }
        }
        if !batch.is_empty() {
            self.store.prefetch(batch);
        }
    }
}

impl PlannedSectionSource for ShardedRegistry {
    fn pack_plan(&self) -> Result<&PackPlan> {
        Ok(self.manifest.plan())
    }

    fn planned_task_view<'a>(
        &'a self,
        t: usize,
        l: usize,
        scratch: &'a mut SectionScratch,
    ) -> Result<PayloadView<'a>> {
        ShardedRegistry::planned_task_view(self, t, l, scratch)
    }

    fn planned_base_view<'a>(
        &'a self,
        l: usize,
        scratch: &'a mut SectionScratch,
    ) -> Result<GroupQuantizedView<'a>> {
        ShardedRegistry::planned_base_view(self, l, scratch)
    }

    fn planned_base_hats(&self) -> Result<&[Option<Vec<f32>>]> {
        if let Some(h) = self.planned_base_cache.get() {
            return Ok(h);
        }
        let hats = decode_planned_base_hats(self)?;
        Ok(self.planned_base_cache.get_or_init(|| hats))
    }

    fn source_path(&self) -> &Path {
        &self.manifest_path
    }
}

/// [`TaskVectorSource`](super::TaskVectorSource) over a sharded registry
/// — plugs a sharded zoo into `merge_from_source`, [`crate::coordinator::ModelCache`]
/// and the dynamic-merge router exactly like a monolithic one.
pub struct ShardedSource {
    reg: Arc<ShardedRegistry>,
}

impl ShardedSource {
    pub fn new(reg: Arc<ShardedRegistry>) -> ShardedSource {
        ShardedSource { reg }
    }

    pub fn registry(&self) -> &ShardedRegistry {
        &self.reg
    }
}

impl super::TaskVectorSource for ShardedSource {
    fn n_tasks(&self) -> usize {
        self.reg.n_tasks()
    }

    fn task_name(&self, t: usize) -> String {
        self.reg
            .plan()
            .task_names
            .get(t)
            .cloned()
            .unwrap_or_else(|| format!("task{t:02}"))
    }

    fn task_vector(&self, t: usize) -> Result<Checkpoint> {
        self.reg.load_task_vector(t, &ExecCtx::sequential())
    }

    fn task_vector_with_pool(&self, t: usize, pool: &Pool) -> Result<Checkpoint> {
        self.reg.load_task_vector(t, &ExecCtx::with_pool(pool))
    }

    fn scheme_label(&self) -> String {
        self.reg.scheme().label()
    }

    /// Qualified by manifest path *and* tier: a local and a remote view
    /// of the same zoo must not share cached variants blindly.
    fn source_id(&self) -> String {
        format!(
            "{}:{}#tier{}",
            self.reg.scheme().label(),
            self.reg.manifest_path().display(),
            self.reg.tier()
        )
    }

    fn resident_overhead_bytes(&self) -> usize {
        self.reg.resident_overhead_bytes()
    }

    fn mapped_bytes(&self) -> u64 {
        self.reg.mapped_bytes()
    }
}
