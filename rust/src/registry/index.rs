//! The indexed multi-task registry file: header + offset table + lazy
//! section reads.
//!
//! [`Registry::open`] reads and CRC-verifies **only** the header and
//! offset table (plus, for plan-packed registries, the small kind-3 plan
//! section that maps group sections back to `(task, tensor)` slots);
//! payload sections are read on demand by absolute offset, so a merge
//! request touching 3 of 20 tasks performs 3 section reads — the full
//! zoo is never materialized.  See [`super`] (module docs) for the
//! byte-level wire format and [`crate::planner`] for the plan section.
//!
//! Section reads go through one of three [`IoMode`]s: `Mmap` maps the
//! whole file once at open and hands out CRC-checked **borrowed** section
//! slices (zero-copy: the decode views in [`crate::quant`] dequantize
//! straight out of the mapping, no staging buffer — the default where
//! supported), `Pread` keeps a single file handle open and reads each
//! section with positioned I/O (`read_exact_at`, no seek, no reopen — the
//! fallback when mapping fails or is unsupported), and `Reopen` opens the
//! file per read (the conservative fallback everywhere else, and the
//! pre-PR-2 behavior kept for comparison).  `perf_registry` benches all
//! three; mapping-lifetime and mutation hazards are documented in
//! `docs/WIRE_FORMAT.md` §7.

use std::collections::HashMap;
use std::fs;
use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};
use std::sync::OnceLock;

use anyhow::{bail, Context, Result};

use super::container::{
    Payload, PayloadKind, PayloadView, RegistryScheme, MAGIC, VERSION, VERSION_BINARY,
    VERSION_PLANNED, VERSION_SPARSE,
};
use super::mmap::{self, Mmap};
use crate::checkpoint::Checkpoint;
use crate::obs;
use crate::planner::{PackPlan, SectionRole, SectionSpec};
use crate::quant::{GroupQuantized, GroupQuantizedView, QuantScheme, SparseGroupQuantized};
use crate::util::crc32;
use crate::util::exec::ExecCtx;

/// Hard caps guarding against nonsense headers (corrupt or adversarial
/// files must fail fast, not allocate gigabytes).
const MAX_ENTRIES: usize = 1 << 20;
const MAX_NAME_LEN: usize = 4096;

/// One row of the registry offset table.
#[derive(Clone, Debug)]
pub struct IndexEntry {
    pub name: String,
    pub kind: PayloadKind,
    /// Absolute file offset of the section body.
    pub offset: u64,
    /// Section body length in bytes.
    pub length: u64,
    /// CRC-32 of the section body.
    pub crc: u32,
}

/// How payload sections are read off disk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoMode {
    /// Map the whole file once (`mmap(2)`, read-only, private) and hand
    /// out CRC-checked borrowed section slices — zero-copy: nothing is
    /// staged, decode views read straight from the mapping.  64-bit unix
    /// only; falls back to [`IoMode::Pread`] when mapping is unsupported
    /// or refused ([`Registry::io_mode`] reports what actually happened).
    Mmap,
    /// One persistent handle + positioned reads (`read_exact_at`): no
    /// seek, no reopen, safe under concurrent readers.  Unix only;
    /// silently falls back to [`IoMode::Reopen`] elsewhere.
    Pread,
    /// Open the file for every section read (the conservative fallback).
    Reopen,
}

/// Reusable scratch for section reads.  In `Mmap` mode it stays empty
/// (sections are borrowed from the mapping); in `Pread`/`Reopen` mode it
/// is the single staging buffer, reused across reads so a steady-state
/// serve loop allocates nothing per section.
#[derive(Default)]
pub struct SectionScratch {
    buf: Vec<u8>,
}

impl SectionScratch {
    /// The staging buffer itself — shared with the sharded-registry store
    /// layer ([`super::store`]), which stages fetched chunks here.
    pub(crate) fn buf_mut(&mut self) -> &mut Vec<u8> {
        &mut self.buf
    }
}

/// Positioned-read backend for one on-disk file, shared by the monolithic
/// registry and the shard files of a sharded registry
/// ([`super::store::LocalShardStore`]).
pub(crate) enum SectionIo {
    Mmap(Mmap),
    #[cfg(unix)]
    Pread(fs::File),
    Reopen,
}

impl SectionIo {
    #[cfg_attr(not(unix), allow(unused_variables))]
    pub(crate) fn new(path: &Path, mode: IoMode) -> Result<Self> {
        match mode {
            IoMode::Mmap => {
                if mmap::supported() {
                    let file = fs::File::open(path)
                        .with_context(|| format!("opening registry {}", path.display()))?;
                    if let Ok(map) = Mmap::map(&file) {
                        return Ok(SectionIo::Mmap(map));
                    }
                }
                // Mapping unsupported or refused: fall back to the next
                // cheapest mode for the platform.
                Self::new(path, IoMode::Pread)
            }
            #[cfg(unix)]
            IoMode::Pread => Ok(SectionIo::Pread(
                fs::File::open(path)
                    .with_context(|| format!("opening registry {}", path.display()))?,
            )),
            #[cfg(not(unix))]
            IoMode::Pread => Ok(SectionIo::Reopen),
            IoMode::Reopen => Ok(SectionIo::Reopen),
        }
    }

    /// The [`IoMode`] actually in effect after fallbacks.
    fn mode(&self) -> IoMode {
        match self {
            SectionIo::Mmap(_) => IoMode::Mmap,
            #[cfg(unix)]
            SectionIo::Pread(_) => IoMode::Pread,
            SectionIo::Reopen => IoMode::Reopen,
        }
    }

    /// The raw (not yet CRC-checked) section body: borrowed straight from
    /// the mapping in `Mmap` mode, read into `scratch` otherwise.
    fn bytes_for<'a>(
        &'a self,
        path: &Path,
        entry: &IndexEntry,
        scratch: &'a mut Vec<u8>,
    ) -> Result<&'a [u8]> {
        self.read_range(path, &entry.name, entry.offset, entry.length, scratch)
    }

    /// The raw bytes at `[offset, offset+length)`: borrowed from the
    /// mapping in `Mmap` mode, read into `scratch` otherwise.  `what`
    /// names the range in error messages (a section name or chunk label).
    pub(crate) fn read_range<'a>(
        &'a self,
        path: &Path,
        what: &str,
        offset: u64,
        length: u64,
        scratch: &'a mut Vec<u8>,
    ) -> Result<&'a [u8]> {
        match self {
            SectionIo::Mmap(map) => {
                // Ranges were bounds-checked against the file size at
                // open; re-check against the mapping defensively (a file
                // that shrank between stat and map must fail closed, not
                // slice out of bounds).
                let oob = || {
                    anyhow::anyhow!(
                        "section {what:?} spans past the {} mapped bytes of {}",
                        map.len(),
                        path.display()
                    )
                };
                let start = usize::try_from(offset).map_err(|_| oob())?;
                let end = start
                    .checked_add(usize::try_from(length).map_err(|_| oob())?)
                    .filter(|&e| e <= map.len())
                    .ok_or_else(oob)?;
                Ok(&map.bytes()[start..end])
            }
            #[cfg(unix)]
            SectionIo::Pread(f) => {
                use std::os::unix::fs::FileExt;
                scratch.clear();
                scratch.resize(length as usize, 0);
                f.read_exact_at(scratch, offset)
                    .with_context(|| format!("reading section {what:?}"))?;
                Ok(&scratch[..])
            }
            SectionIo::Reopen => {
                let mut f = fs::File::open(path)
                    .with_context(|| format!("reopening registry {}", path.display()))?;
                scratch.clear();
                scratch.resize(length as usize, 0);
                f.seek(SeekFrom::Start(offset))?;
                f.read_exact(scratch)
                    .with_context(|| format!("reading section {what:?}"))?;
                Ok(&scratch[..])
            }
        }
    }

    /// The [`IoMode`] actually in effect after fallbacks — also used by
    /// the shard store to report which backend each shard file landed on.
    pub(crate) fn effective_mode(&self) -> IoMode {
        self.mode()
    }

    /// Bytes served through a file mapping by this backend (0 unless
    /// `Mmap` took effect); `file_bytes` is the caller-known file size.
    pub(crate) fn mapped_len(&self, file_bytes: u64) -> u64 {
        match self {
            SectionIo::Mmap(_) => file_bytes,
            _ => 0,
        }
    }
}

/// Incremental header reader that retains the raw bytes for the index CRC.
/// Shared with [`super::manifest`], whose `MANIFEST.qtvm` header uses the
/// same length-prefixed little-endian primitives and trailing-CRC scheme.
pub(crate) struct HeaderReader<R: Read> {
    pub(crate) inner: R,
    pub(crate) raw: Vec<u8>,
}

impl<R: Read> HeaderReader<R> {
    pub(crate) fn take(&mut self, n: usize) -> Result<&[u8]> {
        let start = self.raw.len();
        self.raw.resize(start + n, 0);
        self.inner
            .read_exact(&mut self.raw[start..])
            .map_err(|_| anyhow::anyhow!("truncated QTVC index at byte {start}"))?;
        Ok(&self.raw[start..])
    }

    pub(crate) fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn str(&mut self, max: usize) -> Result<String> {
        let n = self.u32()? as usize;
        if n > max {
            bail!("QTVC index string length {n} exceeds cap {max}");
        }
        Ok(std::str::from_utf8(self.take(n)?)?.to_string())
    }
}

/// Cross-check a decoded payload view against the exact [`SectionSpec`]
/// the plan demands for its slot.  Shared verbatim by the monolithic
/// [`Registry`] and the sharded registry ([`super::store`]) so a
/// spec-mismatched section produces the identical error from every tier.
pub(crate) fn check_view_against_spec(
    view: &PayloadView<'_>,
    spec: SectionSpec,
    name: &str,
) -> Result<()> {
    match (view, spec) {
        (PayloadView::Group(gq), SectionSpec::Dense { bits, group, len }) => {
            if gq.bits() != bits || gq.group() != group || gq.len() != len {
                bail!(
                    "section {name:?} decodes to bits={} group={} len={} but the \
                     plan requires bits={bits} group={group} len={len}",
                    gq.bits(),
                    gq.group(),
                    gq.len()
                );
            }
        }
        (
            PayloadView::SparseGroup(s),
            SectionSpec::Sparse { bits, group, dense_len, survivors },
        ) => {
            if s.bits() != bits
                || s.group() != group
                || s.dense_len() != dense_len
                || s.n_survivors() != survivors
            {
                bail!(
                    "section {name:?} decodes to bits={} group={} dense={} \
                     survivors={} but the plan requires bits={bits} \
                     group={group} dense={dense_len} survivors={survivors}",
                    s.bits(),
                    s.group(),
                    s.dense_len(),
                    s.n_survivors()
                );
            }
        }
        (PayloadView::Binary(b), SectionSpec::Binary { group, len }) => {
            if b.group() != group || b.len() != len {
                bail!(
                    "section {name:?} decodes to group={} len={} but the \
                     plan requires group={group} len={len}",
                    b.group(),
                    b.len()
                );
            }
        }
        (other, spec) => bail!(
            "section {name:?} payload does not match the plan's {spec:?}: {other:?}"
        ),
    }
    Ok(())
}

/// How much of the file [`Registry::open_with`] verifies before handing
/// the registry out.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Validation {
    /// Header, offset table and (for planned registries) the plan section
    /// — the default.  Payload CRCs are still checked lazily on every
    /// access, so corruption fails closed either way; `Index` just defers
    /// the cost to first touch.
    Index,
    /// Additionally read and CRC-verify **every** payload section at open.
    /// This is what the control plane's publish gate wants: a staged
    /// generation is rejected before the swap if any byte of it is bad.
    Deep,
}

/// Builder-style options for [`Registry::open_with`] — the single opening
/// API behind which the PR-2 io-mode variants and the control-plane
/// reopen path now live.
///
/// ```no_run
/// use tvq::registry::{IoMode, OpenOptions, Registry, Validation};
/// # fn main() -> anyhow::Result<()> {
/// let reg = Registry::open_with(
///     "zoo.qtvc",
///     OpenOptions::new().io(IoMode::Pread).validation(Validation::Deep),
/// )?;
/// # Ok(()) }
/// ```
#[derive(Clone, Copy, Debug)]
pub struct OpenOptions {
    io: IoMode,
    validation: Validation,
    paged_index: bool,
}

impl Default for OpenOptions {
    fn default() -> Self {
        OpenOptions { io: IoMode::Mmap, validation: Validation::Index, paged_index: true }
    }
}

impl OpenOptions {
    /// Platform defaults: `Mmap` (with automatic fallback), index-only
    /// validation, paged manifest index.
    pub fn new() -> Self {
        Self::default()
    }

    /// Section I/O backend to request (fallbacks still apply; see
    /// [`IoMode`]).
    pub fn io(mut self, mode: IoMode) -> Self {
        self.io = mode;
        self
    }

    /// Validation depth at open ([`Validation`]).
    pub fn validation(mut self, v: Validation) -> Self {
        self.validation = v;
        self
    }

    /// Whether a sharded registry loads its manifest row pages lazily
    /// (`true`, the default) or eagerly CRC-verifies all of them at open.
    /// Monolithic `.qtvc` files keep their whole offset table resident
    /// either way — the flag only affects `ShardedRegistry`.
    pub fn paged_index(mut self, paged: bool) -> Self {
        self.paged_index = paged;
        self
    }

    /// The requested [`IoMode`].
    pub fn io_mode(&self) -> IoMode {
        self.io
    }

    /// The requested [`Validation`] depth.
    pub fn validation_depth(&self) -> Validation {
        self.validation
    }

    /// Whether the manifest index pages lazily.
    pub fn wants_paged_index(&self) -> bool {
        self.paged_index
    }
}

/// An opened packed task-vector registry (index resident, payloads lazy).
pub struct Registry {
    path: PathBuf,
    version: u32,
    scheme: RegistryScheme,
    entries: Vec<IndexEntry>,
    /// Uniform registries: indices into `entries` for per-task payloads,
    /// in file order.
    tasks: Vec<usize>,
    /// Uniform RTVQ registries: index of the shared base section.
    base: Option<usize>,
    /// Dequantized RTVQ base, decoded at most once and shared by every
    /// subsequent `load_task_vector` call.
    base_cache: OnceLock<Checkpoint>,
    /// Planned registries: the decoded kind-3 pack plan.
    plan: Option<PackPlan>,
    /// Planned registries: `[task][tensor] -> entries` index.
    planned_tasks: Vec<Vec<usize>>,
    /// Planned registries: `[tensor] -> entries` index of the shared base
    /// (RTVQ-arm tensors only).
    planned_bases: Vec<Option<usize>>,
    /// Dequantized per-tensor bases, decoded at most once.
    planned_base_cache: OnceLock<Vec<Option<Vec<f32>>>>,
    io: SectionIo,
    /// The [`OpenOptions`] the caller asked for (before fallbacks), so
    /// [`Registry::reopen`] can re-evaluate the same request against a
    /// replaced file.
    opts: OpenOptions,
    index_bytes: u64,
    file_bytes: u64,
}

impl Registry {
    /// Open a registry with the default [`OpenOptions`]: `Mmap` where
    /// supported (64-bit unix, degrading automatically to `Pread` and
    /// then `Reopen`), index-only validation.  [`Registry::io_mode`]
    /// reports what took effect.
    pub fn open<P: AsRef<Path>>(path: P) -> Result<Registry> {
        Self::open_with(path, OpenOptions::default())
    }

    /// Open a registry at an explicit [`IoMode`].
    #[deprecated(note = "use Registry::open_with(path, OpenOptions::new().io(mode))")]
    pub fn open_with_io<P: AsRef<Path>>(path: P, mode: IoMode) -> Result<Registry> {
        Self::open_with(path, OpenOptions::new().io(mode))
    }

    /// Open a registry: read and verify the header + offset table (and,
    /// for planned registries, the plan section) — payloads stay lazy
    /// unless `opts` asks for [`Validation::Deep`], which additionally
    /// CRC-verifies every payload section before returning.
    pub fn open_with<P: AsRef<Path>>(path: P, opts: OpenOptions) -> Result<Registry> {
        let path = path.as_ref();
        let mode = opts.io_mode();
        let _span = obs::span(obs::Category::Registry, "registry_open");
        let file = fs::File::open(path)
            .with_context(|| format!("opening registry {}", path.display()))?;
        let file_bytes = file.metadata()?.len();
        let mut r = HeaderReader { inner: std::io::BufReader::new(file), raw: Vec::new() };

        let magic = r.u32()?;
        if magic != MAGIC {
            bail!(
                "not a QTVC registry: {} (magic {magic:#010x}, expected {MAGIC:#010x})",
                path.display()
            );
        }
        let version = r.u32()?;
        if version != VERSION
            && version != VERSION_PLANNED
            && version != VERSION_SPARSE
            && version != VERSION_BINARY
        {
            bail!(
                "unsupported QTVC version {version} in {} \
                 (this build reads v{VERSION}, v{VERSION_PLANNED}, v{VERSION_SPARSE} \
                 and v{VERSION_BINARY})",
                path.display()
            );
        }
        let label = r.str(64)?;
        let scheme = RegistryScheme::parse(&label)
            .with_context(|| format!("registry {} carries bad scheme label", path.display()))?;
        match (version, scheme) {
            (VERSION, RegistryScheme::Uniform(_)) => {}
            (VERSION_PLANNED | VERSION_SPARSE | VERSION_BINARY, RegistryScheme::Planned) => {}
            _ => bail!(
                "registry {} pairs version {version} with scheme {label:?} \
                 (uniform registries are v{VERSION}, planned are \
                 v{VERSION_PLANNED}/v{VERSION_SPARSE}/v{VERSION_BINARY})",
                path.display()
            ),
        }
        let count = r.u32()? as usize;
        if count > MAX_ENTRIES {
            bail!("QTVC index claims {count} entries (cap {MAX_ENTRIES}) — corrupt header?");
        }

        let mut entries = Vec::with_capacity(count);
        let mut tasks = Vec::new();
        let mut base = None;
        let mut plan_idx = None;
        for i in 0..count {
            let name = r.str(MAX_NAME_LEN)?;
            let kind = PayloadKind::from_u8(r.u8()?)?;
            let offset = r.u64()?;
            let length = r.u64()?;
            let crc = r.u32()?;
            match offset.checked_add(length) {
                Some(end) if end <= file_bytes => {}
                _ => bail!(
                    "QTVC entry {name:?} spans [{offset}, +{length}) beyond file size {file_bytes}"
                ),
            }
            match (scheme, kind) {
                (RegistryScheme::Uniform(_), PayloadKind::RtvqBase) => {
                    if base.replace(i).is_some() {
                        bail!("QTVC registry has more than one RTVQ base section");
                    }
                }
                (RegistryScheme::Uniform(_), PayloadKind::TaskCheckpoint) => tasks.push(i),
                (
                    RegistryScheme::Uniform(_),
                    PayloadKind::Group
                    | PayloadKind::Plan
                    | PayloadKind::SparseGroup
                    | PayloadKind::BinarySwitch,
                ) => {
                    bail!(
                        "uniform registry {} contains a {kind:?} section {name:?} \
                         (group/sparse/binary/plan sections belong to PLAN-MIXED registries)",
                        path.display()
                    )
                }
                (RegistryScheme::Planned, PayloadKind::Plan) => {
                    if plan_idx.replace(i).is_some() {
                        bail!("planned registry has more than one plan section");
                    }
                }
                (RegistryScheme::Planned, PayloadKind::Group) => {}
                (RegistryScheme::Planned, PayloadKind::SparseGroup) => {
                    // Highest section kind wins the header version, so
                    // sparse sections are legal in v4 *and* v5 files.
                    if version != VERSION_SPARSE && version != VERSION_BINARY {
                        bail!(
                            "registry {} is v{version} but contains a kind-4 sparse \
                             section {name:?} (sparse sections require \
                             v{VERSION_SPARSE}/v{VERSION_BINARY})",
                            path.display()
                        );
                    }
                }
                (RegistryScheme::Planned, PayloadKind::BinarySwitch) => {
                    if version != VERSION_BINARY {
                        bail!(
                            "registry {} is v{version} but contains a kind-5 binary-switch \
                             section {name:?} (binary sections require v{VERSION_BINARY})",
                            path.display()
                        );
                    }
                }
                (RegistryScheme::Planned, other) => bail!(
                    "planned registry {} contains a {other:?} section {name:?} \
                     (only group/sparse/binary + plan sections are valid)",
                    path.display()
                ),
            }
            entries.push(IndexEntry { name, kind, offset, length, crc });
        }
        // Read the trailing index CRC without folding it into `raw`.
        let mut crc_buf = [0u8; 4];
        r.inner
            .read_exact(&mut crc_buf)
            .map_err(|_| anyhow::anyhow!("truncated QTVC index (missing CRC)"))?;
        let stored_crc = u32::from_le_bytes(crc_buf);
        let index_end = r.raw.len() as u64 + 4;
        if stored_crc != crc32(&r.raw) {
            bail!(
                "QTVC index CRC mismatch in {} (corrupt or truncated registry)",
                path.display()
            );
        }
        if matches!(scheme, RegistryScheme::Uniform(QuantScheme::Rtvq(..))) && base.is_none() {
            bail!("RTVQ registry {} is missing its base section", path.display());
        }

        let io = SectionIo::new(path, mode)?;

        // Planned registries: decode the plan now (it is the shape/slot
        // template everything else needs) and bind every expected
        // section to its index entry.
        let (plan, planned_tasks, planned_bases) = match scheme {
            RegistryScheme::Uniform(_) => (None, Vec::new(), Vec::new()),
            RegistryScheme::Planned => {
                let pi = plan_idx.ok_or_else(|| {
                    anyhow::anyhow!(
                        "planned registry {} is missing its plan section",
                        path.display()
                    )
                })?;
                let entry = &entries[pi];
                let mut scratch = Vec::new();
                let bytes = io.bytes_for(path, entry, &mut scratch)?;
                if crc32(bytes) != entry.crc {
                    bail!(
                        "QTVC plan section CRC mismatch in {} (corrupt registry)",
                        path.display()
                    );
                }
                let plan = PackPlan::decode(bytes).with_context(|| {
                    format!("decoding plan section of {}", path.display())
                })?;
                // Version / arm-set consistency: the header version is the
                // plan's highest arm family (binary > sparse > dense), so a
                // reader can trust the header version before decoding any
                // payload.  Sparse arms are legal inside v5 files — a plan
                // may mix 1-bit and sparse slots — but the reverse is not:
                // a v4 file must carry no binary arms.
                if plan.has_onebit_arms() && version != VERSION_BINARY {
                    bail!(
                        "registry {} is v{version} but its plan uses 1-bit binary \
                         arms (binary-arm registries are v{VERSION_BINARY})",
                        path.display()
                    );
                }
                if !plan.has_onebit_arms() && version == VERSION_BINARY {
                    bail!(
                        "registry {} is v{VERSION_BINARY} but its plan has no \
                         1-bit binary arms (sparse-planned registries are \
                         v{VERSION_SPARSE}, dense-planned v{VERSION_PLANNED})",
                        path.display()
                    );
                }
                if plan.has_sparse_arms()
                    && version != VERSION_SPARSE
                    && version != VERSION_BINARY
                {
                    bail!(
                        "registry {} is v{version} but its plan uses sparse arms \
                         (sparse-arm registries are v{VERSION_SPARSE}/v{VERSION_BINARY})",
                        path.display()
                    );
                }
                if !plan.has_sparse_arms() && version == VERSION_SPARSE {
                    bail!(
                        "registry {} is v{VERSION_SPARSE} but its plan has no \
                         sparse arms (dense-planned registries are v{VERSION_PLANNED})",
                        path.display()
                    );
                }
                let by_name: HashMap<&str, usize> = entries
                    .iter()
                    .enumerate()
                    .map(|(i, e)| (e.name.as_str(), i))
                    .collect();
                if by_name.len() != entries.len() {
                    bail!("planned registry {} has duplicate section names", path.display());
                }
                let expected = plan.expected_sections();
                if entries.len() != expected.len() + 1 {
                    bail!(
                        "planned registry {} has {} sections; the plan expects {} (+1 plan)",
                        path.display(),
                        entries.len(),
                        expected.len()
                    );
                }
                let mut planned_tasks =
                    vec![vec![usize::MAX; plan.n_tensors()]; plan.n_tasks()];
                let mut planned_bases = vec![None; plan.n_tensors()];
                for (name, role) in expected {
                    let &i = by_name.get(name.as_str()).ok_or_else(|| {
                        anyhow::anyhow!(
                            "planned registry {} is missing section {name:?}",
                            path.display()
                        )
                    })?;
                    // The offset-table kind must match the arm family the
                    // plan assigns this slot — a kind-2 section where the
                    // plan demands kind-4 (or vice versa) fails at open,
                    // before any payload byte is read.
                    let want_kind = plan.expected_section_kind(role);
                    if entries[i].kind != want_kind {
                        bail!(
                            "planned registry {}: section {name:?} has kind \
                             {:?} but the plan requires {want_kind:?}",
                            path.display(),
                            entries[i].kind
                        );
                    }
                    match role {
                        SectionRole::Base { tensor } => planned_bases[tensor] = Some(i),
                        SectionRole::Task { task, tensor } => planned_tasks[task][tensor] = i,
                    }
                }
                (Some(plan), planned_tasks, planned_bases)
            }
        };

        let reg = Registry {
            path: path.to_path_buf(),
            version,
            scheme,
            entries,
            tasks,
            base,
            base_cache: OnceLock::new(),
            plan,
            planned_tasks,
            planned_bases,
            planned_base_cache: OnceLock::new(),
            io,
            opts,
            index_bytes: index_end,
            file_bytes,
        };
        if opts.validation_depth() == Validation::Deep {
            // Publish-gate mode: touch (and thereby CRC-verify) every
            // payload section now, so a corrupt byte anywhere rejects the
            // open instead of surfacing mid-serve.
            let mut scratch = SectionScratch::default();
            for entry in &reg.entries {
                reg.section_bytes(entry, &mut scratch).with_context(|| {
                    format!("deep-validating registry {}", reg.path.display())
                })?;
            }
        }
        Ok(reg)
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Wire version this file was written at (2 uniform, 3 dense-planned,
    /// 4 sparse-planned, 5 binary-planned).
    pub fn version(&self) -> u32 {
        self.version
    }

    /// The [`IoMode`] actually in effect: `Mmap` requests degrade to
    /// `Pread` (and `Pread` to `Reopen` off-unix) when unsupported, and
    /// this reports where the fallback landed.
    pub fn io_mode(&self) -> IoMode {
        self.io.mode()
    }

    /// The [`IoMode`] originally requested at open, before any fallback.
    pub fn requested_io_mode(&self) -> IoMode {
        self.opts.io_mode()
    }

    /// The full [`OpenOptions`] this registry was opened with.
    pub fn open_options(&self) -> OpenOptions {
        self.opts
    }

    /// Open the same path again at the originally requested
    /// [`OpenOptions`], re-evaluating fallbacks for whatever file now
    /// lives there.  This is the generation-aware reload primitive: after
    /// an atomic rename-swap the existing `Registry` keeps serving the
    /// old inode through its mapping/handle, and `reopen` picks up the
    /// new file under the same name (see
    /// `coordinator::control::generation`).
    pub fn reopen(&self) -> Result<Registry> {
        Self::open_with(&self.path, self.opts)
    }

    /// Bytes served through the file mapping: the whole file in `Mmap`
    /// mode, 0 otherwise.  These are file-backed (reclaimable page cache),
    /// not process heap — capacity accounting must not confuse the two.
    pub fn mapped_bytes(&self) -> u64 {
        match self.io.mode() {
            IoMode::Mmap => self.file_bytes,
            _ => 0,
        }
    }

    /// Owned heap bytes this open registry pins for serving: the resident
    /// index plus any decoded RTVQ base caches.  Payload bytes are *not*
    /// here — they are either mapped ([`Registry::mapped_bytes`]) or
    /// staged transiently per read.
    pub fn resident_overhead_bytes(&self) -> usize {
        let mut bytes = self.index_bytes as usize;
        if let Some(ck) = self.base_cache.get() {
            bytes += ck.fp32_bytes();
        }
        if let Some(hats) = self.planned_base_cache.get() {
            bytes += hats
                .iter()
                .flatten()
                .map(|v| v.len() * std::mem::size_of::<f32>())
                .sum::<usize>();
        }
        bytes
    }

    pub fn scheme(&self) -> RegistryScheme {
        self.scheme
    }

    /// The uniform [`QuantScheme`], if this is not a planned registry.
    pub fn uniform_scheme(&self) -> Option<QuantScheme> {
        self.scheme.uniform()
    }

    /// The embedded pack plan, for planned registries.
    pub fn plan(&self) -> Option<&PackPlan> {
        self.plan.as_ref()
    }

    /// Number of tasks served by this registry.
    pub fn n_tasks(&self) -> usize {
        match &self.plan {
            Some(p) => p.n_tasks(),
            None => self.tasks.len(),
        }
    }

    pub fn task_names(&self) -> Vec<&str> {
        match &self.plan {
            Some(p) => p.task_names.iter().map(|s| s.as_str()).collect(),
            None => self.tasks.iter().map(|&i| self.entries[i].name.as_str()).collect(),
        }
    }

    /// Position of a task by name, if present.
    pub fn task_index(&self, name: &str) -> Option<usize> {
        self.task_names().iter().position(|&n| n == name)
    }

    pub fn has_rtvq_base(&self) -> bool {
        self.base.is_some()
    }

    /// Raw offset-table rows (diagnostics / accounting).
    pub fn entries(&self) -> &[IndexEntry] {
        &self.entries
    }

    /// Bytes occupied by the header + offset table (including its CRC).
    pub fn index_bytes(&self) -> u64 {
        self.index_bytes
    }

    /// Bytes occupied by all payload sections.
    pub fn payload_bytes(&self) -> u64 {
        self.entries.iter().map(|e| e.length).sum()
    }

    /// Total on-disk size recorded at open time.
    pub fn file_bytes(&self) -> u64 {
        self.file_bytes
    }

    /// CRC-verified section bytes: **borrowed straight from the file
    /// mapping** in `Mmap` mode (zero-copy — `scratch` is untouched),
    /// staged into `scratch` under `Pread`/`Reopen`.  This is the no-copy
    /// decode API the serve path is built on; the CRC is checked on every
    /// access, so a lazily-touched corrupt section fails closed
    /// identically in all three modes.
    pub fn section_bytes<'a>(
        &'a self,
        entry: &IndexEntry,
        scratch: &'a mut SectionScratch,
    ) -> Result<&'a [u8]> {
        // Read + CRC time and delivered bytes feed the process-wide
        // section-read histograms (serve-time reconstruction lives or
        // dies on these); the span carries the byte count per read.
        let _span =
            obs::span(obs::Category::Registry, "section_read").with_arg("bytes", entry.length);
        let t0 = std::time::Instant::now();
        let bytes = self.io.bytes_for(&self.path, entry, &mut scratch.buf)?;
        if crc32(bytes) != entry.crc {
            bail!(
                "QTVC section {:?} CRC mismatch in {} (corrupt registry)",
                entry.name,
                self.path.display()
            );
        }
        obs::stats().section_read_ns.record_ns(t0.elapsed());
        obs::stats().section_read_bytes.record(entry.length);
        Ok(bytes)
    }

    /// Lazily load one task's quantized payload (no dequantization).
    /// Uniform registries only — planned tasks span several per-tensor
    /// group sections.
    pub fn load_task_payload(&self, t: usize) -> Result<Payload> {
        if self.plan.is_some() {
            bail!(
                "planned registries store per-tensor group sections; use \
                 load_task_vector or load_planned_task_section"
            );
        }
        let &i = self
            .tasks
            .get(t)
            .ok_or_else(|| anyhow::anyhow!("task index {t} out of range ({} tasks)", self.tasks.len()))?;
        let entry = &self.entries[i];
        let mut scratch = SectionScratch::default();
        Payload::decode(entry.kind, self.section_bytes(entry, &mut scratch)?)
    }

    /// Lazily load the shared RTVQ base payload (uniform registries).
    pub fn load_base_payload(&self) -> Result<Payload> {
        let i = self
            .base
            .ok_or_else(|| anyhow::anyhow!("registry has no RTVQ base section"))?;
        let entry = &self.entries[i];
        let mut scratch = SectionScratch::default();
        Payload::decode(entry.kind, self.section_bytes(entry, &mut scratch)?)
    }

    /// Decode one payload section as a borrowed view and cross-check it
    /// against the exact [`SectionSpec`] the plan demands for its slot.
    fn planned_view<'a>(
        &'a self,
        entry_idx: usize,
        role: SectionRole,
        scratch: &'a mut SectionScratch,
    ) -> Result<PayloadView<'a>> {
        let plan = self.plan.as_ref().expect("planned accessors gated on plan");
        let entry = &self.entries[entry_idx];
        let view = PayloadView::decode(entry.kind, self.section_bytes(entry, scratch)?)?;
        check_view_against_spec(&view, plan.section_spec(role), &entry.name)?;
        Ok(view)
    }

    /// Planned registries: the borrowed view of task `t`'s payload for
    /// tensor `l` — the zero-copy serve path.  In `Mmap` mode the view's
    /// codes, params and bitmask all point into the file mapping; in
    /// `Pread`/`Reopen` they point into `scratch`.  Every view is
    /// CRC-verified and cross-checked against the plan's
    /// [`SectionSpec`] before it is handed out.
    pub fn planned_task_view<'a>(
        &'a self,
        t: usize,
        l: usize,
        scratch: &'a mut SectionScratch,
    ) -> Result<PayloadView<'a>> {
        let plan = self
            .plan
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("not a planned registry"))?;
        if t >= plan.n_tasks() {
            bail!("task index {t} out of range ({} tasks)", plan.n_tasks());
        }
        if l >= plan.n_tensors() {
            bail!("tensor index {l} out of range ({} tensors)", plan.n_tensors());
        }
        self.planned_view(
            self.planned_tasks[t][l],
            SectionRole::Task { task: t, tensor: l },
            scratch,
        )
    }

    /// Planned registries: the borrowed view of the shared base section
    /// for tensor `l` (RTVQ-arm tensors only) — zero-copy counterpart of
    /// [`Registry::load_planned_base_section`].
    pub fn planned_base_view<'a>(
        &'a self,
        l: usize,
        scratch: &'a mut SectionScratch,
    ) -> Result<GroupQuantizedView<'a>> {
        let plan = self
            .plan
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("not a planned registry"))?;
        if l >= plan.n_tensors() {
            bail!("tensor index {l} out of range ({} tensors)", plan.n_tensors());
        }
        let i = self.planned_bases[l].ok_or_else(|| {
            anyhow::anyhow!(
                "tensor {:?} has no RTVQ arm — no shared base section",
                plan.tensors[l].name
            )
        })?;
        match self.planned_view(i, SectionRole::Base { tensor: l }, scratch)? {
            PayloadView::Group(g) => Ok(g),
            other => bail!("base section decoded to a non-group payload: {other:?}"),
        }
    }

    /// Planned registries: task `t`'s kind-2 group section for tensor `l`
    /// (dense-arm tensors; sparse-arm tensors serve through
    /// [`Registry::load_planned_sparse_section`]).
    pub fn load_planned_task_section(&self, t: usize, l: usize) -> Result<GroupQuantized> {
        match self.load_planned_task_payload(t, l)? {
            Payload::Group(g) => Ok(g),
            _ => bail!(
                "tensor index {l} has a sparse (DARE/TALL) arm; use \
                 load_planned_sparse_section"
            ),
        }
    }

    /// Planned registries: task `t`'s kind-4 sparse section for tensor
    /// `l` (DARE / TALL-arm tensors only).
    pub fn load_planned_sparse_section(&self, t: usize, l: usize) -> Result<SparseGroupQuantized> {
        match self.load_planned_task_payload(t, l)? {
            Payload::SparseGroup(s) => Ok(s),
            _ => bail!(
                "tensor index {l} has a dense arm; use load_planned_task_section"
            ),
        }
    }

    /// Planned registries: task `t`'s payload for tensor `l`, whatever
    /// kind the plan assigns that slot — the owned materialization of
    /// [`Registry::planned_task_view`].
    pub fn load_planned_task_payload(&self, t: usize, l: usize) -> Result<Payload> {
        let mut scratch = SectionScratch::default();
        Ok(self.planned_task_view(t, l, &mut scratch)?.to_owned())
    }

    /// Planned registries: the shared base section for tensor `l`
    /// (RTVQ-arm tensors only).
    pub fn load_planned_base_section(&self, l: usize) -> Result<GroupQuantized> {
        let mut scratch = SectionScratch::default();
        Ok(self.planned_base_view(l, &mut scratch)?.to_owned())
    }

    /// Dequantized uniform RTVQ base, decoded once and cached.
    fn base_checkpoint(&self) -> Result<&Checkpoint> {
        if let Some(b) = self.base_cache.get() {
            return Ok(b);
        }
        let ck = match self.load_base_payload()? {
            Payload::Checkpoint(q) => q.dequantize()?,
            other => bail!("RTVQ base must be a checkpoint payload, got {other:?}"),
        };
        Ok(self.base_cache.get_or_init(|| ck))
    }

    /// Dequantized per-tensor planned bases, decoded once and cached.
    fn planned_base_hats(&self) -> Result<&Vec<Option<Vec<f32>>>> {
        if let Some(b) = self.planned_base_cache.get() {
            return Ok(b);
        }
        let plan = self.plan.as_ref().expect("planned accessors gated on plan");
        let mut hats = Vec::with_capacity(plan.n_tensors());
        for l in 0..plan.n_tensors() {
            hats.push(match self.planned_bases[l] {
                Some(_) => Some(self.load_planned_base_section(l)?.dequantize()),
                None => None,
            });
        }
        Ok(self.planned_base_cache.get_or_init(|| hats))
    }

    /// Reconstruct task `t`'s full-precision task vector from its packed
    /// payload(s) alone: dq(offset) + dq(base) for RTVQ, dq(codes) for
    /// TVQ, and the per-tensor plan arms for planned registries.
    ///
    /// Per-tensor decode fans out across `ctx`'s pool: planned registries
    /// dequantize each tensor's section(s) as an independent job; uniform
    /// registries fan out the per-tensor dequantize of the task payload.
    /// Tensors assemble in a fixed order and no job touches another's
    /// output, so the reconstruction is bit-identical at every thread
    /// count.
    pub fn load_task_vector(&self, t: usize, ctx: &ExecCtx) -> Result<Checkpoint> {
        let _op = ctx.op_span(obs::Category::Registry);
        if self.plan.is_some() {
            // Planned decode is shared with the sharded registry (one
            // code path, bit-identical output across tiers).
            return super::store::planned_task_vector(self, t, ctx);
        }
        let payload = self.load_task_payload(t)?;
        let q = match payload {
            Payload::Checkpoint(q) => q,
            _ => bail!(
                "task {t} is a flat group/sparse payload; decode it via \
                 load_task_payload (those payloads carry no tensor-shape template)"
            ),
        };
        match self.scheme {
            RegistryScheme::Uniform(QuantScheme::Rtvq(..)) => {
                q.dequantize_with_pool(ctx.pool())?.add(self.base_checkpoint()?)
            }
            RegistryScheme::Uniform(QuantScheme::Tvq(_)) => q.dequantize_with_pool(ctx.pool()),
            RegistryScheme::Uniform(QuantScheme::Fq(_)) => bail!(
                "FQ registries store quantized checkpoints, not task vectors; \
                 subtract the pre-trained trunk from load_task_payload's result"
            ),
            RegistryScheme::Uniform(QuantScheme::Fp32) => {
                bail!("fp32 zoos use the TVQC checkpoint store, not QTVC")
            }
            RegistryScheme::Planned => unreachable!("handled above"),
        }
    }

    /// [`Registry::load_task_vector`] over an explicit pool.
    #[deprecated(note = "use load_task_vector(t, &ExecCtx::with_pool(pool))")]
    pub fn load_task_vector_with_pool(
        &self,
        t: usize,
        pool: &crate::util::pool::Pool,
    ) -> Result<Checkpoint> {
        self.load_task_vector(t, &ExecCtx::with_pool(pool))
    }
}

impl super::store::PlannedSectionSource for Registry {
    fn pack_plan(&self) -> Result<&PackPlan> {
        self.plan
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("not a planned registry"))
    }

    fn planned_task_view<'a>(
        &'a self,
        t: usize,
        l: usize,
        scratch: &'a mut SectionScratch,
    ) -> Result<PayloadView<'a>> {
        Registry::planned_task_view(self, t, l, scratch)
    }

    fn planned_base_view<'a>(
        &'a self,
        l: usize,
        scratch: &'a mut SectionScratch,
    ) -> Result<GroupQuantizedView<'a>> {
        Registry::planned_base_view(self, l, scratch)
    }

    fn planned_base_hats(&self) -> Result<&[Option<Vec<f32>>]> {
        Registry::planned_base_hats(self).map(|v| v.as_slice())
    }

    fn source_path(&self) -> &Path {
        &self.path
    }
}
