//! The indexed multi-task registry file: header + offset table + lazy
//! section reads.
//!
//! [`Registry::open`] reads and CRC-verifies **only** the header and
//! offset table; payload sections are read on demand by absolute offset,
//! so a merge request touching 3 of 20 tasks performs 3 section reads —
//! the full zoo is never materialized.  See [`super`] (module docs) for
//! the byte-level wire format.

use std::fs;
use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};
use std::sync::OnceLock;

use anyhow::{bail, Context, Result};

use super::container::{Payload, PayloadKind, MAGIC, VERSION};
use crate::checkpoint::Checkpoint;
use crate::quant::QuantScheme;
use crate::util::crc32;

/// Hard caps guarding against nonsense headers (corrupt or adversarial
/// files must fail fast, not allocate gigabytes).
const MAX_ENTRIES: usize = 1 << 20;
const MAX_NAME_LEN: usize = 4096;

/// One row of the registry offset table.
#[derive(Clone, Debug)]
pub struct IndexEntry {
    pub name: String,
    pub kind: PayloadKind,
    /// Absolute file offset of the section body.
    pub offset: u64,
    /// Section body length in bytes.
    pub length: u64,
    /// CRC-32 of the section body.
    pub crc: u32,
}

/// Incremental header reader that retains the raw bytes for the index CRC.
struct HeaderReader<R: Read> {
    inner: R,
    raw: Vec<u8>,
}

impl<R: Read> HeaderReader<R> {
    fn take(&mut self, n: usize) -> Result<&[u8]> {
        let start = self.raw.len();
        self.raw.resize(start + n, 0);
        self.inner
            .read_exact(&mut self.raw[start..])
            .map_err(|_| anyhow::anyhow!("truncated QTVC index at byte {start}"))?;
        Ok(&self.raw[start..])
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self, max: usize) -> Result<String> {
        let n = self.u32()? as usize;
        if n > max {
            bail!("QTVC index string length {n} exceeds cap {max}");
        }
        Ok(std::str::from_utf8(self.take(n)?)?.to_string())
    }
}

/// An opened packed task-vector registry (index resident, payloads lazy).
pub struct Registry {
    path: PathBuf,
    scheme: QuantScheme,
    entries: Vec<IndexEntry>,
    /// Indices into `entries` for per-task payloads, in file order.
    tasks: Vec<usize>,
    /// Index into `entries` for the shared RTVQ base, if present.
    base: Option<usize>,
    /// Dequantized RTVQ base, decoded at most once and shared by every
    /// subsequent `load_task_vector` call.
    base_cache: OnceLock<Checkpoint>,
    index_bytes: u64,
    file_bytes: u64,
}

impl Registry {
    /// Open a registry: read and verify the header + offset table only.
    pub fn open<P: AsRef<Path>>(path: P) -> Result<Registry> {
        let path = path.as_ref();
        let file = fs::File::open(path)
            .with_context(|| format!("opening registry {}", path.display()))?;
        let file_bytes = file.metadata()?.len();
        let mut r = HeaderReader { inner: std::io::BufReader::new(file), raw: Vec::new() };

        let magic = r.u32()?;
        if magic != MAGIC {
            bail!(
                "not a QTVC registry: {} (magic {magic:#010x}, expected {MAGIC:#010x})",
                path.display()
            );
        }
        let version = r.u32()?;
        if version != VERSION {
            bail!(
                "unsupported QTVC version {version} in {} (this build reads v{VERSION})",
                path.display()
            );
        }
        let label = r.str(64)?;
        let scheme = QuantScheme::parse(&label)
            .with_context(|| format!("registry {} carries bad scheme label", path.display()))?;
        let count = r.u32()? as usize;
        if count > MAX_ENTRIES {
            bail!("QTVC index claims {count} entries (cap {MAX_ENTRIES}) — corrupt header?");
        }

        let mut entries = Vec::with_capacity(count);
        let mut tasks = Vec::new();
        let mut base = None;
        for i in 0..count {
            let name = r.str(MAX_NAME_LEN)?;
            let kind = PayloadKind::from_u8(r.u8()?)?;
            let offset = r.u64()?;
            let length = r.u64()?;
            let crc = r.u32()?;
            match offset.checked_add(length) {
                Some(end) if end <= file_bytes => {}
                _ => bail!(
                    "QTVC entry {name:?} spans [{offset}, +{length}) beyond file size {file_bytes}"
                ),
            }
            match kind {
                PayloadKind::RtvqBase => {
                    if base.replace(i).is_some() {
                        bail!("QTVC registry has more than one RTVQ base section");
                    }
                }
                PayloadKind::TaskCheckpoint | PayloadKind::Group => tasks.push(i),
            }
            entries.push(IndexEntry { name, kind, offset, length, crc });
        }
        // Read the trailing index CRC without folding it into `raw`.
        let mut crc_buf = [0u8; 4];
        r.inner
            .read_exact(&mut crc_buf)
            .map_err(|_| anyhow::anyhow!("truncated QTVC index (missing CRC)"))?;
        let stored_crc = u32::from_le_bytes(crc_buf);
        let index_end = r.raw.len() as u64 + 4;
        if stored_crc != crc32(&r.raw) {
            bail!(
                "QTVC index CRC mismatch in {} (corrupt or truncated registry)",
                path.display()
            );
        }
        if matches!(scheme, QuantScheme::Rtvq(..)) && base.is_none() {
            bail!("RTVQ registry {} is missing its base section", path.display());
        }

        Ok(Registry {
            path: path.to_path_buf(),
            scheme,
            entries,
            tasks,
            base,
            base_cache: OnceLock::new(),
            index_bytes: index_end,
            file_bytes,
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn scheme(&self) -> QuantScheme {
        self.scheme
    }

    /// Number of per-task payloads (the RTVQ base is not a task).
    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    pub fn task_names(&self) -> Vec<&str> {
        self.tasks.iter().map(|&i| self.entries[i].name.as_str()).collect()
    }

    /// Position of a task by name, if present.
    pub fn task_index(&self, name: &str) -> Option<usize> {
        self.tasks.iter().position(|&i| self.entries[i].name == name)
    }

    pub fn has_rtvq_base(&self) -> bool {
        self.base.is_some()
    }

    /// Raw offset-table rows (diagnostics / accounting).
    pub fn entries(&self) -> &[IndexEntry] {
        &self.entries
    }

    /// Bytes occupied by the header + offset table (including its CRC).
    pub fn index_bytes(&self) -> u64 {
        self.index_bytes
    }

    /// Bytes occupied by all payload sections.
    pub fn payload_bytes(&self) -> u64 {
        self.entries.iter().map(|e| e.length).sum()
    }

    /// Total on-disk size recorded at open time.
    pub fn file_bytes(&self) -> u64 {
        self.file_bytes
    }

    /// Read + CRC-verify one section body (one seek, one read).
    fn read_section(&self, entry: &IndexEntry) -> Result<Vec<u8>> {
        let mut f = fs::File::open(&self.path)
            .with_context(|| format!("reopening registry {}", self.path.display()))?;
        f.seek(SeekFrom::Start(entry.offset))?;
        let mut buf = vec![0u8; entry.length as usize];
        f.read_exact(&mut buf)
            .with_context(|| format!("reading section {:?}", entry.name))?;
        if crc32(&buf) != entry.crc {
            bail!(
                "QTVC section {:?} CRC mismatch in {} (corrupt registry)",
                entry.name,
                self.path.display()
            );
        }
        Ok(buf)
    }

    /// Lazily load one task's quantized payload (no dequantization).
    pub fn load_task_payload(&self, t: usize) -> Result<Payload> {
        let &i = self
            .tasks
            .get(t)
            .ok_or_else(|| anyhow::anyhow!("task index {t} out of range ({} tasks)", self.tasks.len()))?;
        let entry = &self.entries[i];
        Payload::decode(entry.kind, &self.read_section(entry)?)
    }

    /// Lazily load the shared RTVQ base payload.
    pub fn load_base_payload(&self) -> Result<Payload> {
        let i = self
            .base
            .ok_or_else(|| anyhow::anyhow!("registry has no RTVQ base section"))?;
        let entry = &self.entries[i];
        Payload::decode(entry.kind, &self.read_section(entry)?)
    }

    /// Dequantized RTVQ base, decoded once and cached.
    fn base_checkpoint(&self) -> Result<&Checkpoint> {
        if let Some(b) = self.base_cache.get() {
            return Ok(b);
        }
        let ck = match self.load_base_payload()? {
            Payload::Checkpoint(q) => q.dequantize()?,
            Payload::Group(_) => bail!("RTVQ base must be a checkpoint payload"),
        };
        Ok(self.base_cache.get_or_init(|| ck))
    }

    /// Reconstruct task `t`'s full-precision task vector from its packed
    /// payload alone: dq(offset) + dq(base) for RTVQ, dq(codes) for TVQ.
    pub fn load_task_vector(&self, t: usize) -> Result<Checkpoint> {
        let payload = self.load_task_payload(t)?;
        let q = match payload {
            Payload::Checkpoint(q) => q,
            Payload::Group(_) => bail!(
                "task {t} is a flat group payload; decode it via load_task_payload \
                 (group payloads carry no tensor-shape template)"
            ),
        };
        match self.scheme {
            QuantScheme::Rtvq(..) => q.dequantize()?.add(self.base_checkpoint()?),
            QuantScheme::Tvq(_) => q.dequantize(),
            QuantScheme::Fq(_) => bail!(
                "FQ registries store quantized checkpoints, not task vectors; \
                 subtract the pre-trained trunk from load_task_payload's result"
            ),
            QuantScheme::Fp32 => bail!("fp32 zoos use the TVQC checkpoint store, not QTVC"),
        }
    }
}
