//! Ties-Merging (Yadav et al., NeurIPS 2023): Trim, elect sign, disjoint
//! merge — resolves parameter interference before summing task vectors.

use anyhow::Result;

use super::{MergedModel, Merger};
use crate::checkpoint::Checkpoint;
use crate::tensor::Tensor;

#[derive(Clone, Copy, Debug)]
pub struct Ties {
    pub lambda: f32,
    /// Fraction of weights (by magnitude, per tensor) RETAINED by the trim
    /// step; the original paper keeps the top 20%.
    pub keep_frac: f64,
}

impl Default for Ties {
    fn default() -> Self {
        // The disjoint MEAN already normalizes away the task count, so the
        // coefficient operates on single-task-vector scale: the TIES paper
        // validates lambda ~= 1 (vs 0.3 for task arithmetic's raw sum).
        // keep_frac 0.3: our synthetic task vectors are dense Gaussians
        // without the heavy tail of real fine-tuning deltas, so the trim
        // step is kept mild (see EXPERIMENTS.md for the deviation note).
        Self { lambda: 1.0, keep_frac: 0.3 }
    }
}

impl Ties {
    pub fn new(lambda: f32, keep_frac: f64) -> Self {
        Self { lambda, keep_frac }
    }

    /// Trim: zero all but the top `keep_frac` magnitudes of each tensor.
    fn trim(&self, tau: &Checkpoint) -> Checkpoint {
        let mut out = Checkpoint::new();
        for (name, t) in tau.iter() {
            let thresh = t.abs_quantile(1.0 - self.keep_frac);
            out.insert(name, t.map(|x| if x.abs() >= thresh { x } else { 0.0 }));
        }
        out
    }
}

impl Merger for Ties {
    fn name(&self) -> &'static str {
        "ties"
    }

    fn merge(&self, pre: &Checkpoint, taus: &[Checkpoint]) -> Result<MergedModel> {
        if taus.is_empty() {
            return Ok(MergedModel::Shared(pre.clone()));
        }
        let trimmed: Vec<Checkpoint> = taus.iter().map(|t| self.trim(t)).collect();

        let mut merged = pre.clone();
        for (name, out_t) in merged.iter_mut() {
            let parts: Vec<&Tensor> =
                trimmed.iter().map(|ck| ck.get(name).unwrap()).collect();
            let n = out_t.numel();
            let dst = out_t.data_mut();
            for i in 0..n {
                // Elect sign: sign of the summed values (mass vote).
                let mut pos = 0.0f64;
                let mut neg = 0.0f64;
                for p in &parts {
                    let v = p.data()[i];
                    if v > 0.0 {
                        pos += v as f64;
                    } else {
                        neg -= v as f64;
                    }
                }
                let sign = if pos >= neg { 1.0f32 } else { -1.0f32 };
                // Disjoint mean over sign-agreeing, non-zero entries.
                let mut sum = 0.0f64;
                let mut cnt = 0usize;
                for p in &parts {
                    let v = p.data()[i];
                    if v != 0.0 && v.signum() == sign {
                        sum += v as f64;
                        cnt += 1;
                    }
                }
                if cnt > 0 {
                    dst[i] += self.lambda * (sum / cnt as f64) as f32;
                }
            }
        }
        Ok(MergedModel::Shared(merged))
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::fixture;
    use super::*;

    #[test]
    fn trim_keeps_top_fraction() {
        let (_, taus) = fixture(1, 4);
        let ties = Ties::new(0.3, 0.2);
        let trimmed = ties.trim(&taus[0]);
        for (_, t) in trimmed.iter() {
            let frac_nonzero = 1.0 - t.sparsity();
            assert!(
                frac_nonzero <= 0.30,
                "trim kept {frac_nonzero} of weights"
            );
        }
    }

    #[test]
    fn identical_tasks_reduce_to_trimmed_task_arithmetic() {
        // With T identical task vectors, disjoint mean == the task vector,
        // so merged == pre + lambda * trimmed(tau).
        let (pre, taus) = fixture(1, 5);
        let ties = Ties::new(0.3, 0.5);
        let three = vec![taus[0].clone(), taus[0].clone(), taus[0].clone()];
        let m = ties.merge(&pre, &three).unwrap();
        let mut want = pre.clone();
        want.axpy(0.3, &ties.trim(&taus[0])).unwrap();
        assert!(m.for_task(0).l2_dist(&want).unwrap() < 1e-5);
    }

    #[test]
    fn opposite_signs_interfere_less_than_plain_sum() {
        // Two exactly-opposite task vectors: elected sign keeps one side,
        // so the merged delta is NOT zero-sum-cancelled into noise.
        let (pre, taus) = fixture(1, 6);
        let opp = taus[0].scale(-1.0);
        let pair = vec![taus[0].clone(), opp];
        let m = Ties::new(1.0, 1.0).merge(&pre, &pair).unwrap();
        let delta = m.for_task(0).sub(&pre).unwrap();
        // Each coordinate keeps the (positive-elected) side value or the
        // negative one, never the cancelled average of 0.
        let mut nonzero = 0usize;
        for (_, t) in delta.iter() {
            nonzero += t.data().iter().filter(|&&x| x != 0.0).count();
        }
        assert!(nonzero > 0);
    }

    #[test]
    fn empty_tasks_is_identity() {
        let (pre, _) = fixture(0, 7);
        let m = Ties::default().merge(&pre, &[]).unwrap();
        assert_eq!(m.for_task(0), &pre);
    }
}
