//! MagMax (Marczak et al., ECCV 2024): per-parameter maximum-magnitude
//! selection across task vectors — the weight that changed most wins.

use anyhow::Result;

use super::{MergedModel, Merger};
use crate::checkpoint::Checkpoint;

#[derive(Clone, Copy, Debug)]
pub struct MagMax {
    pub lambda: f32,
}

impl Default for MagMax {
    fn default() -> Self {
        // 0.5: max-magnitude election yields a single-task-scale vector;
        // full strength (1.0) over-applies it across dissimilar tasks.
        Self { lambda: 0.5 }
    }
}

impl Merger for MagMax {
    fn name(&self) -> &'static str {
        "magmax"
    }

    fn merge(&self, pre: &Checkpoint, taus: &[Checkpoint]) -> Result<MergedModel> {
        if taus.is_empty() {
            return Ok(MergedModel::Shared(pre.clone()));
        }
        let mut out = pre.clone();
        for (name, out_t) in out.iter_mut() {
            let n = out_t.numel();
            let dst = out_t.data_mut();
            for i in 0..n {
                let mut best = 0.0f32;
                for tau in taus {
                    let v = tau.get(name)?.data()[i];
                    if v.abs() > best.abs() {
                        best = v;
                    }
                }
                dst[i] += self.lambda * best;
            }
        }
        Ok(MergedModel::Shared(out))
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::fixture;
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn picks_largest_magnitude_per_weight() {
        let mut pre = Checkpoint::new();
        pre.insert("w", Tensor::zeros(&[3]));
        let mk = |vals: [f32; 3]| {
            let mut c = Checkpoint::new();
            c.insert("w", Tensor::from_vec(vals.to_vec()));
            c
        };
        let taus = vec![mk([0.5, -2.0, 0.1]), mk([-1.0, 1.0, 0.05])];
        let m = MagMax { lambda: 1.0 }.merge(&pre, &taus).unwrap();
        assert_eq!(m.for_task(0).get("w").unwrap().data(), &[-1.0, -2.0, 0.1]);
    }

    #[test]
    fn single_task_recovers_finetuned() {
        let (pre, taus) = fixture(1, 12);
        // At lambda = 1 a single task reconstructs the fine-tuned model.
        let m = MagMax { lambda: 1.0 }.merge(&pre, &taus[..1]).unwrap();
        let ft = pre.add(&taus[0]).unwrap();
        assert!(m.for_task(0).l2_dist(&ft).unwrap() < 1e-5);
    }

    #[test]
    fn merged_delta_magnitude_bounded_by_max_tau() {
        let (pre, taus) = fixture(4, 13);
        let m = MagMax::default().merge(&pre, &taus).unwrap();
        let delta = m.for_task(0).sub(&pre).unwrap();
        for (name, t) in delta.iter() {
            for i in 0..t.numel() {
                let max_mag = taus
                    .iter()
                    .map(|tau| tau.get(name).unwrap().data()[i].abs())
                    .fold(0.0f32, f32::max);
                assert!(t.data()[i].abs() <= max_mag + 1e-6);
            }
        }
    }
}
