//! Model-merging algorithms (the frameworks the paper's quantization plugs
//! into — Appendix A.2 reimplements all of them, and so do we).
//!
//! Every method consumes the pre-trained checkpoint plus the task vectors
//! tau_t = theta_ft^t - theta_pre (full precision or dequantized — the
//! paper's point is that quantization is transparent to the merger) and
//! produces a [`MergedModel`].
//!
//! | method | module | output |
//! |---|---|---|
//! | Individual            | [`individual`]      | per-task |
//! | Task Arithmetic [23]  | [`task_arithmetic`] | shared |
//! | Ties-Merging [55]     | [`ties`]            | shared |
//! | LiNeS [49]            | [`lines`]           | shared |
//! | Consensus TA [48]     | [`consensus`]       | shared |
//! | MagMax [34]           | [`magmax`]          | shared |
//! | Breadcrumbs [12]      | [`breadcrumbs`]     | shared |
//! | EMR-Merging [20]      | [`emr`]             | per-task |
//! | AdaMerging [58]       | [`adamerging`]      | shared (test-time opt) |

pub mod adamerging;
pub mod breadcrumbs;
pub mod consensus;
pub mod dare;
pub mod emr;
pub mod individual;
pub mod lines;
pub mod magmax;
pub mod task_arithmetic;
pub mod ties;

pub use adamerging::AdaMerging;
pub use breadcrumbs::Breadcrumbs;
pub use consensus::ConsensusTa;
pub use dare::Dare;
pub use emr::EmrMerging;
pub use individual::Individual;
pub use lines::LiNeS;
pub use magmax::MagMax;
pub use task_arithmetic::TaskArithmetic;
pub use ties::Ties;

use anyhow::Result;

use crate::checkpoint::Checkpoint;

/// The result of merging: either one shared multi-task model or a
/// per-task family (EMR-style mask-modulated models, or Individual).
#[derive(Clone, Debug)]
pub enum MergedModel {
    Shared(Checkpoint),
    PerTask(Vec<Checkpoint>),
}

impl MergedModel {
    /// The model to evaluate on task `t`.
    pub fn for_task(&self, t: usize) -> &Checkpoint {
        match self {
            MergedModel::Shared(ck) => ck,
            MergedModel::PerTask(cks) => &cks[t],
        }
    }

    pub fn n_variants(&self) -> usize {
        match self {
            MergedModel::Shared(_) => 1,
            MergedModel::PerTask(cks) => cks.len(),
        }
    }
}

/// A merging algorithm over task vectors.
pub trait Merger {
    fn name(&self) -> &'static str;

    /// Merge task vectors into a multi-task model.
    fn merge(&self, pre: &Checkpoint, taus: &[Checkpoint]) -> Result<MergedModel>;
}

/// Layer index of a parameter name under the ViT naming scheme
/// (`embed/*`, `pos` -> 0; `blkNN/*` -> NN+1; `ln_f/*` -> depth+1;
/// anything else -> 0). Used by LiNeS' depth-linear scaling.
pub fn layer_index(name: &str) -> usize {
    if let Some(rest) = name.strip_prefix("blk") {
        let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
        if let Ok(i) = digits.parse::<usize>() {
            return i + 1;
        }
    }
    if name.starts_with("ln_f") {
        return usize::MAX; // resolved against max depth by the caller
    }
    0
}

/// The default merging-method lineup used by the classification tables
/// (Tables 1-2): everything except AdaMerging, which needs a test-time
/// entropy oracle and is driven separately by the experiment harness.
pub fn standard_methods() -> Vec<Box<dyn Merger>> {
    vec![
        Box::new(TaskArithmetic::default()),
        Box::new(Ties::default()),
        Box::new(LiNeS::default()),
        Box::new(ConsensusTa::default()),
        Box::new(EmrMerging::default()),
    ]
}

/// The dense-prediction lineup (Table 3).
pub fn dense_methods() -> Vec<Box<dyn Merger>> {
    vec![
        Box::new(TaskArithmetic::default()),
        Box::new(Ties::default()),
        Box::new(MagMax::default()),
        Box::new(Breadcrumbs::default()),
        Box::new(EmrMerging::default()),
    ]
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    /// Small synthetic (pre, taus) fixture shared by merge-method tests.
    pub fn fixture(n_tasks: usize, seed: u64) -> (Checkpoint, Vec<Checkpoint>) {
        let mut rng = Rng::new(seed);
        let mut pre = Checkpoint::new();
        pre.insert("blk00/w", Tensor::randn(&[16, 8], 0.3, &mut rng));
        pre.insert("blk01/w", Tensor::randn(&[16, 8], 0.3, &mut rng));
        pre.insert("embed/w", Tensor::randn(&[4, 16], 0.3, &mut rng));
        pre.insert("ln_f/g", Tensor::randn(&[16], 0.3, &mut rng));
        let taus = (0..n_tasks)
            .map(|_| {
                let mut tau = Checkpoint::new();
                for (name, t) in pre.iter() {
                    tau.insert(name, Tensor::randn(t.shape(), 0.02, &mut rng));
                }
                tau
            })
            .collect();
        (pre, taus)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_index_parses_names() {
        assert_eq!(layer_index("embed/w"), 0);
        assert_eq!(layer_index("pos"), 0);
        assert_eq!(layer_index("blk00/attn/wq"), 1);
        assert_eq!(layer_index("blk07/mlp/w1"), 8);
        assert_eq!(layer_index("ln_f/g"), usize::MAX);
    }

    #[test]
    fn merged_model_for_task() {
        let (pre, taus) = testutil::fixture(2, 0);
        let shared = MergedModel::Shared(pre.clone());
        assert_eq!(shared.n_variants(), 1);
        assert_eq!(shared.for_task(0), shared.for_task(1));
        let per = MergedModel::PerTask(taus.clone());
        assert_eq!(per.n_variants(), 2);
        assert_eq!(per.for_task(1), &taus[1]);
    }
}
