//! Consensus Task Arithmetic (Wang et al., ICML 2024): TALL masks localize
//! per-task information; weights used by >= k tasks ("general") are kept,
//! selfish/catastrophic weights are dropped from the merged task vector.

use anyhow::Result;

use super::{MergedModel, Merger};
use crate::checkpoint::Checkpoint;

#[derive(Clone, Copy, Debug)]
pub struct ConsensusTa {
    /// Final task-arithmetic coefficient.
    pub lambda: f32,
    /// TALL-mask hyperparameter: tau_t is "localized" where
    /// |tau_t| >= lambda_tall * |tau_mtl - tau_t|.
    pub lambda_tall: f32,
    /// Minimum number of tasks that must claim a weight for consensus.
    pub k: usize,
}

impl Default for ConsensusTa {
    fn default() -> Self {
        // lambda_tall = 0.2 sits at the permissive end of the TALL-mask
        // range the paper sweeps ([0.2, 0.6]); with many near-orthogonal
        // task vectors |tau_mtl - tau_t| ~ sqrt(T-1)|tau_t|, so stricter
        // thresholds empty the consensus mask and collapse to theta_pre.
        Self { lambda: 0.3, lambda_tall: 0.2, k: 2 }
    }
}

impl Merger for ConsensusTa {
    fn name(&self) -> &'static str {
        "consensus_ta"
    }

    fn merge(&self, pre: &Checkpoint, taus: &[Checkpoint]) -> Result<MergedModel> {
        if taus.is_empty() {
            return Ok(MergedModel::Shared(pre.clone()));
        }
        // tau_mtl = sum_t tau_t
        let mut tau_mtl = taus[0].clone();
        for tau in &taus[1..] {
            tau_mtl.axpy(1.0, tau)?;
        }
        let mut out = pre.clone();
        for (name, out_t) in out.iter_mut() {
            let mtl = tau_mtl.get(name)?;
            let n = mtl.numel();
            // Count TALL-mask votes per weight.
            let mut votes = vec![0u32; n];
            for tau in taus {
                let t = tau.get(name)?;
                for i in 0..n {
                    let ti = t.data()[i];
                    let rest = mtl.data()[i] - ti;
                    if ti.abs() >= self.lambda_tall * rest.abs() {
                        votes[i] += 1;
                    }
                }
            }
            let dst = out_t.data_mut();
            for i in 0..n {
                if votes[i] >= self.k as u32 {
                    dst[i] += self.lambda * mtl.data()[i];
                }
            }
        }
        Ok(MergedModel::Shared(out))
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::fixture;
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn empty_is_identity() {
        let (pre, _) = fixture(0, 10);
        let m = ConsensusTa::default().merge(&pre, &[]).unwrap();
        assert_eq!(m.for_task(0), &pre);
    }

    #[test]
    fn consensus_keeps_shared_weights_drops_selfish() {
        // Build 3 tasks over a 4-weight tensor:
        //  w0: all tasks agree (shared) -> kept
        //  w1: only task 0 uses it (selfish) -> dropped with k=2
        //  w2, w3: unused.
        let mut pre = Checkpoint::new();
        pre.insert("w", Tensor::zeros(&[4]));
        let mk = |vals: [f32; 4]| {
            let mut c = Checkpoint::new();
            c.insert("w", Tensor::from_vec(vals.to_vec()));
            c
        };
        let taus = vec![
            mk([1.0, 2.0, 0.0, 0.0]),
            mk([1.0, 0.0, 0.0, 0.0]),
            mk([1.0, 0.0, 0.0, 0.0]),
        ];
        let m = ConsensusTa { lambda: 1.0, lambda_tall: 0.4, k: 2 }
            .merge(&pre, &taus)
            .unwrap();
        let out = m.for_task(0).get("w").unwrap();
        // w0: each tau=1, rest=2 -> 1 >= 0.4*2 -> all 3 vote -> kept (sum=3)
        assert!((out.data()[0] - 3.0).abs() < 1e-6);
        // w1: only task0 votes (2 >= 0) -> 1 vote < k=2 -> dropped
        assert_eq!(out.data()[1], 0.0);
        assert_eq!(out.data()[2], 0.0);
    }

    #[test]
    fn merged_stays_close_to_task_arithmetic_subset() {
        // Consensus output delta must be a masked version of lambda*tau_mtl:
        // each coordinate either matches TA's delta or is zero.
        let (pre, taus) = fixture(4, 11);
        let cta = ConsensusTa::default();
        let m = cta.merge(&pre, &taus).unwrap();
        let ta = super::super::TaskArithmetic::new(cta.lambda)
            .merge(&pre, &taus)
            .unwrap();
        let d_c = m.for_task(0).sub(&pre).unwrap();
        let d_t = ta.for_task(0).sub(&pre).unwrap();
        for (name, t) in d_c.iter() {
            let full = d_t.get(name).unwrap();
            for (a, b) in t.data().iter().zip(full.data()) {
                assert!(*a == 0.0 || (a - b).abs() < 1e-6);
            }
        }
    }
}
