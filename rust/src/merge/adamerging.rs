//! AdaMerging (Yang et al., ICLR 2024): test-time adaptive merging
//! coefficients.  The original optimizes per-layer/per-task lambdas by
//! minimizing prediction entropy on unlabeled test data with gradients;
//! since our coefficients live outside the AOT graph we optimize the
//! per-task coefficient vector with derivative-free coordinate descent
//! against the same entropy objective, evaluated through the PJRT runtime.

use anyhow::Result;

use crate::checkpoint::Checkpoint;

use super::{MergedModel, Merger, TaskArithmetic};

/// Oracle signature: mean prediction entropy of a candidate merged model
/// over the unlabeled adaptation set (lower = more confident = better).
pub type EntropyOracle<'a> = dyn FnMut(&Checkpoint) -> Result<f64> + 'a;

#[derive(Clone, Copy, Debug)]
pub struct AdaMerging {
    /// Initial per-task coefficient (the paper initializes at 0.3).
    pub init_lambda: f32,
    /// Coordinate-descent sweeps over the task coefficients.
    pub sweeps: usize,
    /// Multiplicative step grid tried per coordinate.
    pub step: f32,
}

impl Default for AdaMerging {
    fn default() -> Self {
        Self { init_lambda: 0.3, sweeps: 2, step: 0.1 }
    }
}

impl AdaMerging {
    /// Merge with per-task coefficients optimized against `oracle`.
    /// Returns (merged model, final lambdas, entropy trace).
    pub fn optimize(
        &self,
        pre: &Checkpoint,
        taus: &[Checkpoint],
        oracle: &mut EntropyOracle,
    ) -> Result<(MergedModel, Vec<f32>, Vec<f64>)> {
        let t = taus.len();
        let mut lambdas = vec![self.init_lambda; t];
        let build = |lams: &[f32]| -> Result<Checkpoint> {
            let mut out = pre.clone();
            for (tau, &lam) in taus.iter().zip(lams) {
                out.axpy(lam, tau)?;
            }
            Ok(out)
        };
        let mut best = oracle(&build(&lambdas)?)?;
        let mut trace = vec![best];
        for _ in 0..self.sweeps {
            for i in 0..t {
                for delta in [self.step, -self.step] {
                    let cand_l = (lambdas[i] + delta).clamp(0.0, 1.0);
                    if cand_l == lambdas[i] {
                        continue;
                    }
                    let mut cand = lambdas.clone();
                    cand[i] = cand_l;
                    let e = oracle(&build(&cand)?)?;
                    if e < best {
                        best = e;
                        lambdas = cand;
                    }
                }
            }
            trace.push(best);
        }
        Ok((MergedModel::Shared(build(&lambdas)?), lambdas, trace))
    }
}

/// Fallback `Merger` impl (no oracle): equivalent to task arithmetic at
/// the initial coefficient — used only where a full test-time adaptation
/// pass is out of scope (the experiment harness always calls `optimize`).
impl Merger for AdaMerging {
    fn name(&self) -> &'static str {
        "adamerging"
    }

    fn merge(&self, pre: &Checkpoint, taus: &[Checkpoint]) -> Result<MergedModel> {
        TaskArithmetic::new(self.init_lambda).merge(pre, taus)
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::fixture;
    use super::*;

    #[test]
    fn optimizer_reduces_oracle_value() {
        let (pre, taus) = fixture(3, 21);
        // Synthetic oracle: entropy is minimized at lambda = (0.5, 0.1, 0.3).
        let target = [0.5f32, 0.1, 0.3];
        let pre_c = pre.clone();
        let taus_c = taus.clone();
        let mut oracle = move |ck: &Checkpoint| -> Result<f64> {
            // Recover implied lambdas by projecting (ck - pre) onto taus
            // (orthogonal-ish random taus make this well-posed enough).
            let delta = ck.sub(&pre_c)?;
            let mut err = 0.0f64;
            for (tau, &tgt) in taus_c.iter().zip(&target) {
                let mut dot = 0.0f64;
                let mut nrm = 0.0f64;
                for (name, t) in tau.iter() {
                    let d = delta.get(name)?;
                    for (a, b) in t.data().iter().zip(d.data()) {
                        dot += (*a as f64) * (*b as f64);
                        nrm += (*a as f64) * (*a as f64);
                    }
                }
                let implied = dot / nrm;
                err += (implied - tgt as f64).powi(2);
            }
            Ok(err)
        };
        let ada = AdaMerging { init_lambda: 0.3, sweeps: 4, step: 0.1 };
        let (_, lambdas, trace) = ada.optimize(&pre, &taus, &mut oracle).unwrap();
        assert!(trace.last().unwrap() <= trace.first().unwrap());
        // Should have moved toward the target on at least one coordinate.
        assert!((lambdas[0] - 0.5).abs() < 0.15, "{lambdas:?}");
    }

    #[test]
    fn entropy_trace_is_monotone_nonincreasing() {
        let (pre, taus) = fixture(2, 22);
        let mut calls = 0;
        let mut oracle = |_: &Checkpoint| -> Result<f64> {
            calls += 1;
            Ok(1.0 / calls as f64) // strictly decreasing -> accepts all
        };
        let ada = AdaMerging::default();
        let (_, _, trace) = ada.optimize(&pre, &taus, &mut oracle).unwrap();
        for w in trace.windows(2) {
            assert!(w[1] <= w[0]);
        }
    }

    #[test]
    fn fallback_merge_matches_task_arithmetic() {
        let (pre, taus) = fixture(2, 23);
        let a = AdaMerging::default().merge(&pre, &taus).unwrap();
        let b = TaskArithmetic::new(0.3).merge(&pre, &taus).unwrap();
        assert!(a.for_task(0).l2_dist(b.for_task(0)).unwrap() < 1e-6);
    }
}
