//! LiNeS (Wang et al., ICLR 2025): layer-increasing network scaling.
//! Shallow layers keep small coefficients (protecting general features),
//! deep layers get larger ones: lambda_l = alpha + beta * l / (L - 1).

use anyhow::Result;

use super::{layer_index, MergedModel, Merger};
use crate::checkpoint::Checkpoint;

#[derive(Clone, Copy, Debug)]
pub struct LiNeS {
    /// Coefficient at the first layer.
    pub alpha: f32,
    /// Added linearly up to the last layer.
    pub beta: f32,
}

impl Default for LiNeS {
    fn default() -> Self {
        Self { alpha: 0.1, beta: 0.4 }
    }
}

impl LiNeS {
    pub fn new(alpha: f32, beta: f32) -> Self {
        Self { alpha, beta }
    }

    /// Per-tensor coefficient given the model's max layer index.
    fn coeff(&self, name: &str, max_layer: usize) -> f32 {
        let l = match layer_index(name) {
            usize::MAX => max_layer,
            l => l,
        };
        if max_layer == 0 {
            self.alpha
        } else {
            self.alpha + self.beta * l as f32 / max_layer as f32
        }
    }
}

impl Merger for LiNeS {
    fn name(&self) -> &'static str {
        "lines"
    }

    fn merge(&self, pre: &Checkpoint, taus: &[Checkpoint]) -> Result<MergedModel> {
        // Establish model depth from the parameter names.
        let max_layer = pre
            .names()
            .map(layer_index)
            .filter(|&l| l != usize::MAX)
            .max()
            .unwrap_or(0)
            + 1; // ln_f sits one past the deepest block
        let mut out = pre.clone();
        for tau in taus {
            for (name, t) in out.iter_mut() {
                let c = self.coeff(name, max_layer);
                t.axpy(c, tau.get(name)?)?;
            }
        }
        Ok(MergedModel::Shared(out))
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::fixture;
    use super::*;

    #[test]
    fn coefficients_increase_with_depth() {
        let l = LiNeS::new(0.1, 0.4);
        let c_embed = l.coeff("embed/w", 3);
        let c_blk0 = l.coeff("blk00/w", 3);
        let c_blk1 = l.coeff("blk01/w", 3);
        let c_lnf = l.coeff("ln_f/g", 3);
        assert!(c_embed < c_blk0 && c_blk0 < c_blk1 && c_blk1 < c_lnf);
        assert!((c_embed - 0.1).abs() < 1e-6);
        assert!((c_lnf - 0.5).abs() < 1e-6);
    }

    #[test]
    fn beta_zero_equals_task_arithmetic() {
        let (pre, taus) = fixture(3, 8);
        let m_lines = LiNeS::new(0.3, 0.0).merge(&pre, &taus).unwrap();
        let m_ta = super::super::TaskArithmetic::new(0.3)
            .merge(&pre, &taus)
            .unwrap();
        assert!(m_lines.for_task(0).l2_dist(m_ta.for_task(0)).unwrap() < 1e-5);
    }

    #[test]
    fn shallow_layers_move_less() {
        let (pre, taus) = fixture(2, 9);
        let m = LiNeS::new(0.0, 1.0).merge(&pre, &taus).unwrap();
        let delta = m.for_task(0).sub(&pre).unwrap();
        // embed gets coefficient 0 -> unchanged
        assert_eq!(delta.get("embed/w").unwrap().l2_norm(), 0.0);
        // ln_f gets full coefficient -> moved
        assert!(delta.get("ln_f/g").unwrap().l2_norm() > 0.0);
    }
}
