//! Individual baseline: no merging — each task keeps its own fine-tuned
//! model (pre + tau_t). The upper bound on per-task accuracy and the
//! memory-cost motivation for everything else.

use anyhow::Result;

use super::{MergedModel, Merger};
use crate::checkpoint::Checkpoint;

#[derive(Clone, Copy, Debug, Default)]
pub struct Individual;

impl Merger for Individual {
    fn name(&self) -> &'static str {
        "individual"
    }

    fn merge(&self, pre: &Checkpoint, taus: &[Checkpoint]) -> Result<MergedModel> {
        let models = taus
            .iter()
            .map(|tau| pre.add(tau))
            .collect::<Result<Vec<_>>>()?;
        Ok(MergedModel::PerTask(models))
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::fixture;
    use super::*;

    #[test]
    fn reconstructs_each_finetuned_model() {
        let (pre, taus) = fixture(3, 20);
        let m = Individual.merge(&pre, &taus).unwrap();
        assert_eq!(m.n_variants(), 3);
        for (t, tau) in taus.iter().enumerate() {
            let ft = pre.add(tau).unwrap();
            assert!(m.for_task(t).l2_dist(&ft).unwrap() < 1e-6);
        }
    }
}
